"""AOT pipeline: lower every (config x routing-mode x entry-point) to HLO
*text* plus a JSON manifest the rust coordinator parses.

HLO text — NOT ``lowered.compiler_ir("hlo")`` protos and NOT
``.serialize()`` — is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the published xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run as:  cd python && python -m compile.aot --out-dir ../artifacts \
             [--configs tiny,moe16-bench,moe64-bench] [--force]

The pipeline is content-addressed: each artifact records the sha256 of the
generating sources + config in the manifest, and lowering is skipped when
unchanged (so ``make artifacts`` is a no-op on a fresh tree).
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import CONFIGS, ModelConfig, with_bip_T

# T grid from Tables 2/3; tiny keeps the test matrix small.
BIP_T_GRID = {
    "tiny": (2, 4),
    "moe16-bench": (2, 4, 8, 14),
    "moe64-bench": (2, 4, 8, 14),
    "moe16": (2, 4, 8, 14),
    "moe64": (2, 4, 8, 14),
}
DEFAULT_CONFIGS = ("tiny", "moe16-bench", "moe64-bench")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _abstract(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_io(cfg: ModelConfig, total: int):
    L, m = cfg.n_layers, cfg.n_experts
    ins = [
        _spec("theta", (total,), "f32"),
        _spec("adam_m", (total,), "f32"),
        _spec("adam_v", (total,), "f32"),
        _spec("step", (), "i32"),
        _spec("route_state", (L, m), "f32"),
        _spec("tokens", (cfg.batch_size, cfg.seq_len + 1), "i32"),
    ]
    outs = [
        _spec("theta", (total,), "f32"),
        _spec("adam_m", (total,), "f32"),
        _spec("adam_v", (total,), "f32"),
        _spec("step", (), "i32"),
        _spec("route_state", (L, m), "f32"),
        _spec("nll_sum", (), "f32"),
        _spec("loads", (L, m), "f32"),
        _spec("drops", (L,), "f32"),
    ]
    return ins, outs


def eval_io(cfg: ModelConfig, total: int):
    L, m = cfg.n_layers, cfg.n_experts
    ins = [
        _spec("theta", (total,), "f32"),
        _spec("route_state", (L, m), "f32"),
        _spec("tokens", (cfg.batch_size, cfg.seq_len + 1), "i32"),
    ]
    outs = [
        _spec("nll_sum", (), "f32"),
        _spec("loads", (L, m), "f32"),
        _spec("drops", (L,), "f32"),
    ]
    return ins, outs


def lower_train(cfg: ModelConfig, mode: str, total: int):
    fn = functools.partial(model.train_step, mode=mode, cfg=cfg)
    args = (
        _abstract((total,)), _abstract((total,)), _abstract((total,)),
        _abstract((), jnp.int32),
        _abstract((cfg.n_layers, cfg.n_experts)),
        _abstract((cfg.batch_size, cfg.seq_len + 1), jnp.int32),
    )
    return jax.jit(fn, donate_argnums=(0, 1, 2, 3, 4)).lower(*args)


def lower_eval(cfg: ModelConfig, mode: str, total: int):
    fn = functools.partial(model.eval_step, mode=mode, cfg=cfg)
    args = (
        _abstract((total,)),
        _abstract((cfg.n_layers, cfg.n_experts)),
        _abstract((cfg.batch_size, cfg.seq_len + 1), jnp.int32),
    )
    return jax.jit(fn).lower(*args)


def lower_init(cfg: ModelConfig):
    fn = functools.partial(model.init_theta, cfg)
    return jax.jit(fn).lower(_abstract((), jnp.int32))


def lower_probe(cfg: ModelConfig, mode: str, total: int, layer: int):
    fn = functools.partial(model.route_probe, layer=layer, mode=mode, cfg=cfg)
    args = (
        _abstract((total,)),
        _abstract((cfg.n_layers, cfg.n_experts)),
        _abstract((cfg.batch_size, cfg.seq_len + 1), jnp.int32),
    )
    return jax.jit(fn).lower(*args)


def source_fingerprint() -> str:
    h = hashlib.sha256()
    base = os.path.dirname(os.path.abspath(__file__))
    files = [os.path.join(base, f) for f in
             ("model.py", "configs.py", "aot.py")]
    files += [os.path.join(base, "kernels", f) for f in
              sorted(os.listdir(os.path.join(base, "kernels")))
              if f.endswith(".py")]
    for f in files:
        with open(f, "rb") as fh:
            h.update(fh.read())
    return h.hexdigest()[:16]


def build(out_dir: str, config_names, force: bool, probe: bool):
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    old = {}
    if os.path.exists(manifest_path) and not force:
        with open(manifest_path) as f:
            old = json.load(f)
    fp = source_fingerprint()
    fresh = old.get("fingerprint") == fp
    manifest = {"fingerprint": fp, "configs": {}, "artifacts": []}
    prev_files = {a["file"]: a for a in old.get("artifacts", [])}
    prev_cfgs = set(old.get("configs", {}).keys()) if fresh else set()

    def emit(name, lower_fn, entry):
        path = os.path.join(out_dir, name)
        if fresh and name in prev_files and os.path.exists(path):
            manifest["artifacts"].append(prev_files[name])
            print(f"  [cached] {name}")
            return
        text = to_hlo_text(lower_fn())
        with open(path, "w") as f:
            f.write(text)
        entry["file"] = name
        entry["bytes"] = len(text)
        manifest["artifacts"].append(entry)
        print(f"  [lowered] {name} ({len(text)//1024} KiB)")

    for cname in config_names:
        cfg = CONFIGS[cname]
        specs, total = model.param_specs(cfg)
        cdict = cfg.to_dict()
        cdict["theta_size"] = total
        cdict["params"] = [
            {"name": sp.name, "shape": list(sp.shape), "offset": sp.offset,
             "std": sp.std, "decay": sp.decay} for sp in specs
        ]
        manifest["configs"][cname] = cdict
        print(f"config {cname}: theta={total}")

        tio = train_io(cfg, total)
        eio = eval_io(cfg, total)

        emit(f"{cname}_init.hlo.txt", lambda cfg=cfg: lower_init(cfg), {
            "config": cname, "mode": "-", "kind": "init",
            "inputs": [_spec("seed", (), "i32")],
            "outputs": [_spec("theta", (total,), "f32")],
        })
        for mode in ("aux", "lossfree"):
            emit(f"{cname}_{mode}_train.hlo.txt",
                 lambda cfg=cfg, mode=mode: lower_train(cfg, mode, total), {
                     "config": cname, "mode": mode, "kind": "train",
                     "inputs": tio[0], "outputs": tio[1],
                 })
        for T in BIP_T_GRID[cname]:
            bcfg = with_bip_T(cfg, T)
            emit(f"{cname}_bip_T{T}_train.hlo.txt",
                 lambda bcfg=bcfg: lower_train(bcfg, "bip", total), {
                     "config": cname, "mode": "bip", "bip_T": T,
                     "kind": "train", "inputs": tio[0], "outputs": tio[1],
                 })
        for mode in ("aux", "lossfree", "bip"):
            emit(f"{cname}_{mode}_eval.hlo.txt",
                 lambda cfg=cfg, mode=mode: lower_eval(cfg, mode, total), {
                     "config": cname, "mode": mode, "kind": "eval",
                     "inputs": eio[0], "outputs": eio[1],
                 })
        if probe:
            emit(f"{cname}_probe_l0.hlo.txt",
                 lambda cfg=cfg: lower_probe(cfg, "bip", total, 0), {
                     "config": cname, "mode": "bip", "kind": "probe",
                     "layer": 0,
                     "inputs": eio[0],
                     "outputs": [_spec("scores",
                                       (cfg.n_tokens, cfg.n_experts), "f32")],
                 })

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {manifest_path} ({len(manifest['artifacts'])} artifacts)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default=",".join(DEFAULT_CONFIGS))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-probe", action="store_true")
    args = ap.parse_args()
    names = [c for c in args.configs.split(",") if c]
    for c in names:
        if c not in CONFIGS:
            sys.exit(f"unknown config {c!r}; have {sorted(CONFIGS)}")
    build(args.out_dir, names, args.force, probe=not args.no_probe)


if __name__ == "__main__":
    main()
