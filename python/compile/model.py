"""L2: Minimind-style MoE transformer LM in JAX — forward, backward, AdamW.

Everything the rust coordinator executes per training step is defined here
and AOT-lowered once by ``aot.py``; python never runs on the request path.

Design notes
------------
* **Flat parameter vector.** All trainable parameters live in one f32
  vector ``theta``; ``ParamSpec`` (also exported to the artifact manifest)
  records each tensor's (name, shape, offset, init-std).  This collapses
  the rust<->PJRT interface to a handful of arrays and makes buffer
  donation trivial.
* **Layers are scanned.** Per-layer parameters are stored stacked with a
  leading ``n_layers`` axis and the decoder runs as ``lax.scan`` over
  layers, so the lowered HLO is O(1) in depth.
* **Routing modes.** ``mode in {aux, lossfree, bip}`` is baked at trace
  time.  A single ``route_state`` (n_layers, m) f32 array threads the
  per-layer bias vector: q for BIP (Alg. 1, warm-started across batches),
  b for Loss-Free, and an ignored zero vector for Loss-Controlled.
* **L1 kernels.** The BIP dual update, the biased top-k gate, and the
  grouped expert FFN (fwd + custom-VJP bwd) are the Pallas kernels from
  ``kernels/``; the dual update and gate run on ``stop_gradient`` scores
  (they produce integer routing decisions / non-differentiable state), and
  gate *values* are re-gathered from the live scores so gradients flow
  exactly as in the paper (g_ij = s_ij on the selected experts).
* **Capacity dispatch.** Tokens are dispatched to per-expert buffers of
  ``capacity`` slots (GShard-style); overflow tokens are dropped and the
  drop fraction is reported per layer.  With BIP balancing, loads stay
  <= n*k/m < capacity, so drops are structurally impossible — one of the
  operational payoffs the paper claims.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels.bip_balance import bip_dual_pallas
from .kernels.topk_gate import biased_topk_gate_pallas
from .kernels.moe_ffn import expert_ffn


# --------------------------------------------------------------------------
# Parameter layout
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple
    offset: int
    std: float          # init: Normal(0, std); std==0 -> ones (norm gains)
    decay: bool         # weight decay applies


def param_specs(cfg: ModelConfig):
    """Static flat-theta layout. Order is load-bearing: rust and aot share it
    through the manifest."""
    specs = []
    off = 0

    def add(name, shape, std, decay):
        nonlocal off
        size = int(np.prod(shape))
        specs.append(ParamSpec(name, tuple(shape), off, std, decay))
        off += size

    L, d, m, f = cfg.n_layers, cfg.d_model, cfg.n_experts, cfg.d_ff
    std = cfg.init_std
    out_std = std / math.sqrt(2.0 * L)   # residual-branch output scaling
    add("embed", (cfg.vocab_size, d), std, True)
    add("attn_norm", (L, d), 0.0, False)
    add("wq", (L, d, d), std, True)
    add("wk", (L, d, d), std, True)
    add("wv", (L, d, d), std, True)
    add("wo", (L, d, d), out_std, True)
    add("ffn_norm", (L, d), 0.0, False)
    add("w_gate", (L, d, m), std, True)
    add("w1", (L, m, d, f), std, True)
    add("w3", (L, m, d, f), std, True)
    add("w2", (L, m, f, d), out_std, True)
    add("final_norm", (d,), 0.0, False)
    return specs, off


def unpack(theta, specs):
    out = {}
    for sp in specs:
        size = int(np.prod(sp.shape))
        out[sp.name] = jax.lax.dynamic_slice(
            theta, (sp.offset,), (size,)
        ).reshape(sp.shape)
    return out


def decay_mask(specs, total):
    """Weight-decay mask over flat theta, built from broadcast segments so
    it lowers to O(#tensors) HLO ops, not a theta-sized literal constant."""
    parts = []
    for sp in specs:
        size = int(np.prod(sp.shape))
        val = 1.0 if sp.decay else 0.0
        parts.append(jnp.broadcast_to(jnp.float32(val), (size,)))
    return jnp.concatenate(parts)


def init_theta(cfg: ModelConfig, seed):
    """theta from a scalar seed — AOT-lowered as its own artifact so rust
    never needs to replicate jax's init RNG."""
    specs, total = param_specs(cfg)
    key = jax.random.PRNGKey(seed)
    parts = []
    for i, sp in enumerate(specs):
        size = int(np.prod(sp.shape))
        if sp.std == 0.0:
            parts.append(jnp.ones((size,), jnp.float32))
        else:
            sub = jax.random.fold_in(key, i)
            parts.append(jax.random.normal(sub, (size,), jnp.float32) * sp.std)
    return jnp.concatenate(parts)


# --------------------------------------------------------------------------
# Transformer pieces
# --------------------------------------------------------------------------

def rmsnorm(x, g, eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def rope_tables(cfg: ModelConfig):
    hd = cfg.head_dim
    pos = np.arange(cfg.seq_len, dtype=np.float32)
    inv = cfg.rope_theta ** (-np.arange(0, hd, 2, dtype=np.float32) / hd)
    ang = pos[:, None] * inv[None, :]                     # (S, hd/2)
    return jnp.asarray(np.cos(ang)), jnp.asarray(np.sin(ang))


def apply_rope(x, cos, sin):
    # x: (B, S, H, hd)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    c, s = cos[None, :, None, :], sin[None, :, None, :]
    ro = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return ro.reshape(x.shape)


def attention(x, p, cos, sin, cfg: ModelConfig):
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = apply_rope((x @ p["wq"]).reshape(B, S, H, hd), cos, sin)
    k = apply_rope((x @ p["wk"]).reshape(B, S, H, hd), cos, sin)
    v = (x @ p["wv"]).reshape(B, S, H, hd)
    logits = jnp.einsum("bshd,bthd->bhst", q, k) / math.sqrt(hd)
    # causal mask via iota comparison (never a materialized S*S constant —
    # keeps the HLO text small)
    row = jax.lax.broadcasted_iota(jnp.int32, (S, S), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (S, S), 1)
    logits = jnp.where((row >= col)[None, None], logits, -1e30)
    attn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", attn, v).reshape(B, S, d)
    return out @ p["wo"]


# --------------------------------------------------------------------------
# MoE layer: routing (3 modes) + capacity dispatch + grouped FFN
# --------------------------------------------------------------------------

def route_scores(h_flat, w_gate):
    """Softmax router (Minimind / Table 1)."""
    return jax.nn.softmax(h_flat @ w_gate, axis=-1)


def _positions_in_expert(flat_e, m):
    """For the flattened (n*k,) expert assignment, the arrival rank of each
    entry within its expert (0-based), via a stable counting sort."""
    nk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jax.ops.segment_sum(jnp.ones((nk,), jnp.int32), flat_e,
                                 num_segments=m)
    offsets = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(nk, dtype=jnp.int32) - offsets[sorted_e]
    return jnp.zeros((nk,), jnp.int32).at[order].set(pos_sorted), counts


def moe_dispatch_ffn(x_flat, idx, gate, lp, cfg: ModelConfig):
    """Capacity dispatch -> grouped Pallas FFN -> weighted combine.

    x_flat (n, d); idx/gate (n, k). Returns (y (n, d), drop_frac scalar)."""
    n, d = x_flat.shape
    m, k, c = cfg.n_experts, cfg.top_k, cfg.capacity
    flat_e = idx.reshape(-1)
    pos, _counts = _positions_in_expert(flat_e, m)
    valid = pos < c
    slot = jnp.where(valid, flat_e * c + pos, m * c)      # m*c = dump row
    token_id = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    buf = jnp.zeros((m * c + 1, d), x_flat.dtype).at[slot].set(
        x_flat[token_id]
    )
    y_buf = expert_ffn(
        buf[: m * c].reshape(m, c, d), lp["w1"], lp["w3"], lp["w2"]
    ).reshape(m * c, d)
    y_buf = jnp.concatenate([y_buf, jnp.zeros((1, d), y_buf.dtype)])
    contrib = (
        y_buf[slot]
        * gate.reshape(-1)[:, None]
        * valid[:, None].astype(y_buf.dtype)
    )
    y = contrib.reshape(n, k, d).sum(axis=1)
    drop_frac = 1.0 - jnp.mean(valid.astype(jnp.float32))
    return y, drop_frac


def moe_layer(h_flat, lp, q_in, mode: str, cfg: ModelConfig,
              frozen_route: bool = False):
    """One MoE FFN block. Returns (y, q_out, loads, aux, drop_frac).

    q_in/q_out: the (m,) routing-state vector for this layer (meaning
    depends on mode — see module docstring). ``frozen_route=True`` is the
    deployment/eval semantics: use the carried state as-is (no dual
    iterations, no bias update)."""
    m, k = cfg.n_experts, cfg.top_k
    n = h_flat.shape[0]
    s = route_scores(h_flat, lp["w_gate"])
    s_ng = jax.lax.stop_gradient(s)

    if mode == "bip":
        if frozen_route:
            q_new = q_in
        else:
            q_new, _p = bip_dual_pallas(s_ng, q_in, k=k, cap=cfg.expert_cap,
                                        T=cfg.bip_T)
        bias = -q_new
        q_out = q_new
    elif mode == "lossfree":
        bias = q_in                    # b is ADDED (Wang et al. 2024)
        q_out = q_in                   # updated below, after loads
    else:                              # "aux" (Loss-Controlled) / greedy
        bias = jnp.zeros((m,), s.dtype)
        q_out = q_in

    idx, _gate_ng, loads = biased_topk_gate_pallas(s_ng, bias, k=k)
    # gate weights re-gathered from the LIVE scores: grads flow through s.
    gate = jnp.take_along_axis(s, idx, axis=1)

    if mode == "lossfree" and not frozen_route:
        mean = n * k / m
        q_out = q_in + cfg.lossfree_u * jnp.sign(mean - loads)

    if mode == "aux":
        f_frac = loads * (m / (k * n))
        P = s.mean(axis=0)
        aux = cfg.aux_alpha * jnp.sum(f_frac * P)
    else:
        aux = jnp.zeros((), s.dtype)

    y, drop_frac = moe_dispatch_ffn(h_flat, idx, gate, lp, cfg)
    return y, q_out, loads, aux, drop_frac


# --------------------------------------------------------------------------
# Full forward
# --------------------------------------------------------------------------

LAYER_PARAMS = ("attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "w_gate",
                "w1", "w3", "w2")


def forward(theta, route_state, tokens, mode: str, cfg: ModelConfig,
            specs=None, frozen_route: bool = False):
    """tokens (B, S+1) int32 -> (nll_sum, aux_total, q_out (L,m),
    loads (L,m), drops (L,)). nll_sum is the summed token NLL."""
    if specs is None:
        specs = param_specs(cfg)[0]
    p = unpack(theta, specs)
    B, S = cfg.batch_size, cfg.seq_len
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    x = p["embed"][inputs]                                # (B, S, d)
    cos, sin = rope_tables(cfg)

    def layer_step(x, xs):
        lp, q_in = xs
        h = x + attention(rmsnorm(x, lp["attn_norm"], cfg.norm_eps),
                          lp, cos, sin, cfg)
        hn = rmsnorm(h, lp["ffn_norm"], cfg.norm_eps)
        y, q_out, loads, aux, drop = moe_layer(
            hn.reshape(B * S, cfg.d_model), lp, q_in, mode, cfg,
            frozen_route=frozen_route)
        out = h + y.reshape(B, S, cfg.d_model)
        return out, (q_out, loads, aux, drop)

    layer_stack = {k: p[k] for k in LAYER_PARAMS}
    x, (q_out, loads, aux, drops) = jax.lax.scan(
        layer_step, x, (layer_stack, route_state))

    x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    logits = x @ p["embed"].T                              # weight-tied head
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt_logit = jnp.take_along_axis(
        logits, targets[..., None], axis=-1)[..., 0]
    nll_sum = jnp.sum(logz - tgt_logit)
    return nll_sum, jnp.sum(aux), q_out, loads, drops


# --------------------------------------------------------------------------
# Train / eval steps (the AOT-lowered entry points)
# --------------------------------------------------------------------------

def lr_at(step, cfg: ModelConfig):
    warm = cfg.lr * (step + 1.0) / cfg.warmup_steps
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (0.1 + 0.45 * (1.0 + jnp.cos(math.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def train_step(theta, m_adam, v_adam, step, route_state, tokens,
               mode: str, cfg: ModelConfig):
    """One optimizer step. Returns
    (theta', m', v', step+1, route_state', loss_sum, loads (L,m), drops (L,))."""
    specs, total = param_specs(cfg)
    n_tok = cfg.batch_size * cfg.seq_len
    wd_mask = decay_mask(specs, total)

    def loss_fn(th):
        nll, aux, q_out, loads, drops = forward(
            th, route_state, tokens, mode, cfg, specs)
        return nll / n_tok + aux, (nll, q_out, loads, drops)

    (loss, (nll, q_out, loads, drops)), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(theta)

    gnorm = jnp.sqrt(jnp.sum(jnp.square(grads)) + 1e-12)
    scale = jnp.minimum(1.0, cfg.grad_clip / gnorm)
    grads = grads * scale

    stepf = step.astype(jnp.float32)
    lr = lr_at(stepf, cfg)
    m_new = cfg.beta1 * m_adam + (1 - cfg.beta1) * grads
    v_new = cfg.beta2 * v_adam + (1 - cfg.beta2) * jnp.square(grads)
    mhat = m_new / (1 - cfg.beta1 ** (stepf + 1))
    vhat = v_new / (1 - cfg.beta2 ** (stepf + 1))
    upd = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * wd_mask * theta
    theta_new = theta - lr * upd
    return (theta_new, m_new, v_new, step + 1, q_out,
            nll, loads, drops)


def eval_step(theta, route_state, tokens, mode: str, cfg: ModelConfig):
    """Held-out evaluation: summed NLL + loads. Routing uses the carried
    bias state frozen (deployment semantics — no dual iterations, no bias
    updates on test data). Perplexity = exp(nll/ntokens), computed
    rust-side over the full test set."""
    nll, _aux, _q, loads, drops = forward(
        theta, route_state, tokens, mode, cfg, frozen_route=True)
    # aux mode never reads route_state; keep the argument alive so the
    # lowered module's signature matches the manifest for every mode
    nll = nll + 0.0 * jnp.sum(route_state)
    return nll, loads, drops


def route_probe(theta, route_state, tokens, layer: int, mode: str,
                cfg: ModelConfig):
    """Expose one layer's router scores for a batch — used by the rust
    solver-equivalence tests and the online-matching demo feeds."""
    specs = param_specs(cfg)[0]
    p = unpack(theta, specs)
    B, S = cfg.batch_size, cfg.seq_len
    x = p["embed"][tokens[:, :-1]]
    cos, sin = rope_tables(cfg)
    lp_all = {k: p[k] for k in LAYER_PARAMS}
    for l in range(layer + 1):
        lp = {k: v[l] for k, v in lp_all.items()}
        h = x + attention(rmsnorm(x, lp["attn_norm"], cfg.norm_eps),
                          lp, cos, sin, cfg)
        hn = rmsnorm(h, lp["ffn_norm"], cfg.norm_eps)
        if l == layer:
            s = route_scores(hn.reshape(B * S, cfg.d_model), lp["w_gate"])
            # keep route_state alive as an input even when probing layer 0
            # (jax would otherwise DCE the argument out of the lowered
            # module and the manifest I/O spec would no longer match)
            return s + 0.0 * jnp.sum(route_state)
        y, _, _, _, _ = moe_layer(hn.reshape(B * S, cfg.d_model), lp,
                                  route_state[l], mode, cfg)
        x = h + y.reshape(B, S, cfg.d_model)
    raise ValueError("unreachable")
