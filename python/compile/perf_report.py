"""L1/L2 performance analysis (EXPERIMENTS.md §Perf inputs).

Interpret-mode Pallas wallclock is CPU-numpy, NOT a TPU proxy, so L1 is
assessed structurally: VMEM footprints and MXU tile-quantization from the
BlockSpecs; L2 via XLA's compiled cost analysis (FLOPs / bytes per train
step) and an operator census of the lowered HLO (fusion sanity: no
redundant recomputation of the forward inside the backward beyond the
planned rematerialization).

Run:  cd python && python -m compile.perf_report [--configs tiny,...]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from . import aot, model
from .configs import CONFIGS
from .kernels.bip_balance import vmem_footprint_bytes
from .kernels.moe_ffn import mxu_utilization_estimate


def l1_report(cfg):
    n, m = cfg.n_tokens, cfg.n_experts
    print(f"  L1 bip_balance: resident VMEM "
          f"{vmem_footprint_bytes(n, m) / 1024:.1f} KiB "
          f"(n={n}, m={m}); blocked(256): "
          f"{vmem_footprint_bytes(n, m, blocked=True) / 1024:.1f} KiB")
    c, d, f = cfg.capacity, cfg.d_model, cfg.d_ff
    util = mxu_utilization_estimate(c, d, f)
    vmem = 4 * (c * d * 2 + 2 * d * f + f * d + c * f)
    print(f"  L1 moe_ffn: per-expert tile (c={c}, d={d}, f={f}) "
          f"VMEM {vmem / 1024:.1f} KiB, MXU tile-quantization "
          f"utilization {util:.2%}")
    flops = 2 * 3 * m * c * d * f
    print(f"  L1 moe_ffn fwd FLOPs/layer: {flops / 1e6:.1f} MF "
          f"({m} experts x 3 matmuls)")


def l2_report(cfg, mode: str):
    total = model.param_specs(cfg)[1]
    lowered = aot.lower_train(cfg, mode, total)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = cost.get("flops", float("nan"))
    bytes_acc = cost.get("bytes accessed", float("nan"))
    print(f"  L2 {mode:>8} train step: {flops / 1e9:.3f} GFLOP, "
          f"{bytes_acc / 1e6:.1f} MB accessed, "
          f"arithmetic intensity {flops / max(bytes_acc, 1):.2f} F/B")
    # operator census from the optimized HLO
    hlo = compiled.as_text()
    census = {}
    for op in ("fusion", "dot", "sort", "scatter", "gather",
               "all-reduce", "while", "custom-call"):
        census[op] = hlo.count(f" {op}(") + hlo.count(f" {op}.")
    print(f"      op census: {census}")
    return flops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--configs", default="tiny,moe16-bench,moe64-bench")
    ap.add_argument("--modes", default="aux,bip")
    args = ap.parse_args()
    for name in args.configs.split(","):
        cfg = CONFIGS[name]
        print(f"== {name} (theta {model.param_specs(cfg)[1]:,}) ==")
        l1_report(cfg)
        flops = None
        for mode in args.modes.split(","):
            flops = l2_report(cfg, mode)
        if flops:
            # roofline context: CPU testbed vs the paper's devices
            for dev, peak in [("cpu-testbed ~50 GF/s", 50e9),
                              ("rtx4090 bf16 ~80 TF/s", 8.0e13)]:
                print(f"      ideal step time on {dev}: "
                      f"{flops / peak * 1e3:.1f} ms")
        print()


if __name__ == "__main__":
    main()
