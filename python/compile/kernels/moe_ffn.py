"""L1 Pallas kernel: grouped per-expert SwiGLU FFN — the MoE compute hot-spot.

Input is the capacity-dispatched token buffer x (m, c, d): c slots per
expert, zero-padded where an expert received fewer tokens. Each grid step
processes one expert's buffer with three MXU matmuls:

    h = silu(x_e @ w1_e) * (x_e @ w3_e);   y_e = h @ w2_e

TPU mapping (the paper trains on GPUs; see DESIGN.md §Hardware-Adaptation):
  * grid over experts — one (c, d) token tile + that expert's three weight
    matrices resident in VMEM per step; weights stream HBM->VMEM once per
    expert instead of the GPU's threadblock-per-expert shared-memory pass.
  * c and d are padded by the caller to multiples of the 128x128 MXU tile
    where it matters; the matmuls accumulate in f32
    (``preferred_element_type``) as the MXU does for bf16 inputs.

VMEM footprint per step: c*d + 2*d*f + c*f + f*d + c*d floats; e.g.
c=512, d=256, f=512 -> ~2.5 MiB, comfortably under ~16 MiB VMEM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _expert_ffn_kernel(x_ref, w1_ref, w3_ref, w2_ref, y_ref):
    x = x_ref[...]          # (c, d)   this expert's dispatched tokens
    w1 = w1_ref[...]        # (d, f)
    w3 = w3_ref[...]        # (d, f)
    w2 = w2_ref[...]        # (f, d)
    h1 = jnp.dot(x, w1, preferred_element_type=jnp.float32)
    h3 = jnp.dot(x, w3, preferred_element_type=jnp.float32)
    h = jax.nn.silu(h1) * h3
    y_ref[...] = jnp.dot(h, w2, preferred_element_type=jnp.float32).astype(
        y_ref.dtype
    )


def swiglu_expert_ffn_pallas(x, w1, w3, w2):
    """Pallas version of ``ref.swiglu_expert_ffn``.

    x (m, c, d), w1/w3 (m, d, f), w2 (m, f, d) -> (m, c, d)."""
    m, c, d = x.shape
    f = w1.shape[2]
    return pl.pallas_call(
        _expert_ffn_kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((None, c, d), lambda e: (e, 0, 0)),
            pl.BlockSpec((None, d, f), lambda e: (e, 0, 0)),
            pl.BlockSpec((None, d, f), lambda e: (e, 0, 0)),
            pl.BlockSpec((None, f, d), lambda e: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, c, d), lambda e: (e, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, c, d), x.dtype),
        interpret=INTERPRET,
    )(x, w1, w3, w2)


def _expert_ffn_bwd_kernel(x_ref, w1_ref, w3_ref, w2_ref, dy_ref,
                           dx_ref, dw1_ref, dw3_ref, dw2_ref):
    """Backward kernel (one expert per grid step), rematerializing the
    activations instead of stashing them (VMEM over HBM traffic):

        a = x@w1; b = x@w3; h = silu(a)*b; y = h@w2
        dh  = dy @ w2^T          dw2 = h^T @ dy
        da  = dh * b * silu'(a)  db  = dh * silu(a)
        dx  = da @ w1^T + db @ w3^T
        dw1 = x^T @ da           dw3 = x^T @ db
    """
    x = x_ref[...]
    w1 = w1_ref[...]
    w3 = w3_ref[...]
    w2 = w2_ref[...]
    dy = dy_ref[...]
    a = jnp.dot(x, w1, preferred_element_type=jnp.float32)
    b = jnp.dot(x, w3, preferred_element_type=jnp.float32)
    sig = jax.nn.sigmoid(a)
    sa = a * sig                      # silu(a)
    h = sa * b
    dh = jnp.dot(dy, w2.T, preferred_element_type=jnp.float32)
    dw2_ref[...] = jnp.dot(h.T, dy, preferred_element_type=jnp.float32)
    dsilu = sig * (1.0 + a * (1.0 - sig))   # d silu / da
    da = dh * b * dsilu
    db = dh * sa
    dx_ref[...] = (
        jnp.dot(da, w1.T, preferred_element_type=jnp.float32)
        + jnp.dot(db, w3.T, preferred_element_type=jnp.float32)
    ).astype(dx_ref.dtype)
    dw1_ref[...] = jnp.dot(x.T, da, preferred_element_type=jnp.float32)
    dw3_ref[...] = jnp.dot(x.T, db, preferred_element_type=jnp.float32)


def _ffn_bwd_pallas(x, w1, w3, w2, dy):
    m, c, d = x.shape
    f = w1.shape[2]
    return pl.pallas_call(
        _expert_ffn_bwd_kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((None, c, d), lambda e: (e, 0, 0)),
            pl.BlockSpec((None, d, f), lambda e: (e, 0, 0)),
            pl.BlockSpec((None, d, f), lambda e: (e, 0, 0)),
            pl.BlockSpec((None, f, d), lambda e: (e, 0, 0)),
            pl.BlockSpec((None, c, d), lambda e: (e, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((None, c, d), lambda e: (e, 0, 0)),
            pl.BlockSpec((None, d, f), lambda e: (e, 0, 0)),
            pl.BlockSpec((None, d, f), lambda e: (e, 0, 0)),
            pl.BlockSpec((None, f, d), lambda e: (e, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((m, c, d), x.dtype),
            jax.ShapeDtypeStruct((m, d, f), w1.dtype),
            jax.ShapeDtypeStruct((m, d, f), w3.dtype),
            jax.ShapeDtypeStruct((m, f, d), w2.dtype),
        ),
        interpret=INTERPRET,
    )(x, w1, w3, w2, dy)


@jax.custom_vjp
def expert_ffn(x, w1, w3, w2):
    """Differentiable grouped expert FFN: Pallas forward + Pallas backward.

    Pallas kernels have no automatic VJP, so the backward pass is its own
    hand-derived kernel (tested against jax.grad of the jnp reference)."""
    return swiglu_expert_ffn_pallas(x, w1, w3, w2)


def _expert_ffn_fwd(x, w1, w3, w2):
    return swiglu_expert_ffn_pallas(x, w1, w3, w2), (x, w1, w3, w2)


def _expert_ffn_bwd(res, dy):
    x, w1, w3, w2 = res
    return _ffn_bwd_pallas(x, w1, w3, w2, dy)


expert_ffn.defvjp(_expert_ffn_fwd, _expert_ffn_bwd)


def mxu_utilization_estimate(c: int, d: int, f: int) -> float:
    """Fraction of MXU-issue slots doing useful work for one expert tile,
    from tile-quantization alone (128-lane MXU): used in EXPERIMENTS §Perf."""
    def ceil_div(a, b):
        return -(-a // b)

    useful = 2 * c * d * f * 3  # three matmuls (w1, w3, w2) fwd
    issued = (
        2 * (ceil_div(c, 128) * 128) * (ceil_div(d, 128) * 128)
        * (ceil_div(f, 128) * 128) * 3
    )
    return useful / issued
