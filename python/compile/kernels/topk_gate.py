"""L1 Pallas kernel: biased top-k gate (Algorithm 1 line 13) + load counts.

Given scores ``s`` (n, m) and an additive bias ``bias`` (m,) — which is
``-q`` for BIP-Based Balancing, ``+b`` for the Loss-Free baseline, and
zero for Loss-Controlled / greedy — select the top-k experts per token on
the *biased* scores while emitting the *original* scores as gate weights,
plus the per-expert load histogram the coordinator's MaxVio metrics need.

TPU mapping: token-blocked grid; each program owns a (block_n, m) tile in
VMEM, runs top-k on the VPU, and accumulates its partial load histogram
into the output block (the grid is sequential on TPU, so the accumulation
is race-free; in interpret mode it is a scan).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import topk_desc

INTERPRET = True


def _gate_kernel(s_ref, bias_ref, idx_ref, gate_ref, loads_ref, *, k: int):
    i = pl.program_id(0)
    s = s_ref[...]
    bias = bias_ref[...]
    m = s.shape[1]
    biased = s + bias[None, :]
    _, idx = topk_desc(biased, k)
    gate = jnp.take_along_axis(s, idx, axis=1)
    idx_ref[...] = idx.astype(jnp.int32)
    gate_ref[...] = gate
    one_hot = jax.nn.one_hot(idx.reshape(-1), m, dtype=s.dtype)
    partial = one_hot.sum(axis=0)

    @pl.when(i == 0)
    def _init():
        loads_ref[...] = jnp.zeros_like(loads_ref)

    loads_ref[...] += partial


def biased_topk_gate_pallas(s, bias, *, k: int, block_n: int = 256):
    """Pallas version of ``ref.biased_topk_gate`` (+ loads).

    Returns (idx (n,k) i32, gate (n,k) f32, loads (m,) f32). ``bias`` is
    ADDED to the scores before top-k (callers pass -q for BIP).
    """
    n, m = s.shape
    if n % block_n != 0:
        block_n = n  # degenerate single block for odd test sizes
    grid = (n // block_n,)
    idx, gate, loads = pl.pallas_call(
        functools.partial(_gate_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),  # shared accumulator
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n, k), jnp.int32),
            jax.ShapeDtypeStruct((n, k), s.dtype),
            jax.ShapeDtypeStruct((m,), s.dtype),
        ),
        interpret=INTERPRET,
    )(s, bias)
    return idx, gate, loads
