"""Pure-jnp reference oracle for every L1 kernel.

These implementations are deliberately written as straight-line jnp with no
Pallas, no tiling and no cleverness: they are the correctness ground truth
that pytest (and hypothesis sweeps) compare the Pallas kernels against, and
they double as readable documentation of the math in the paper:

  * ``bip_dual_update``    — Algorithm 1 lines 7-12 (T dual-ascent iterations)
  * ``biased_topk_gate``   — Algorithm 1 line 13 (g_ij = s_ij on Topk(s - q))
  * ``expert_loads``       — per-expert token counts (MaxVio numerator)
  * ``swiglu_expert_ffn``  — the per-expert SwiGLU FFN the MoE layer applies
  * ``lossfree_bias_update`` — Wang et al. 2024 sign update (baseline)
  * ``aux_loss``           — GShard/Switch auxiliary loss (baseline)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Order-statistic helpers.
#
# NOTE: deliberately sort-based, NOT ``jax.lax.top_k``.  jax >= 0.5 lowers
# top_k to the dedicated ``topk`` HLO instruction, which the xla crate's
# XLA 0.5.1 text parser does not know; ``sort`` round-trips fine and at the
# routing sizes involved (m <= 64 per row, n <= a few thousand per column)
# the cost difference is irrelevant.
# ---------------------------------------------------------------------------

def kth_largest(x, kth: int):
    """k-th largest value along the last axis (kth is 1-based)."""
    n = x.shape[-1]
    return jnp.sort(x, axis=-1)[..., n - kth]


def topk_desc(x, k: int):
    """(values, indices) of the k largest along the last axis, descending,
    ties broken by lower index (same convention as lax.top_k)."""
    idx = jnp.argsort(-x, axis=-1, stable=True)[..., :k]
    return jnp.take_along_axis(x, idx, axis=-1), idx


def bip_dual_update(s, q0, k: int, cap: int, T: int):
    """T iterations of the (D-LP) dual ascent from Algorithm 1 (lines 7-12).

    Args:
      s:   (n, m) routing score matrix for the current batch.
      q0:  (m,) warm-start dual vector (carried across batches, Alg. 1 line 2).
      k:   experts selected per token.
      cap: per-expert capacity n*k/m (the RHS of BIP constraint (2)).
      T:   number of dual iterations.

    Returns (q_T, p_T): the expert duals (m,) and token duals (n,) after the
    final iteration.  Routing then uses Topk(s_i - q, k) per token.
    """
    n, m = s.shape
    kk = min(k + 1, m)
    cc = min(cap + 1, n)

    def body(q, _):
        # p_i = max(0, (k+1)-th largest of row i of  P = s - 1 q)
        P = s - q[None, :]
        p = jnp.maximum(0.0, kth_largest(P, kk))
        # q_j = max(0, (cap+1)-th largest of row j of  Q = s^T - 1 p)
        Q = s - p[:, None]
        q_new = jnp.maximum(0.0, kth_largest(Q.T, cc))
        return q_new, p

    q, p = jax.lax.scan(body, q0.astype(s.dtype), None, length=T)
    return q, p[-1]


def biased_topk_gate(s, q, k: int):
    """Algorithm 1 line 13: route token i to Topk_j(s_ij - q_j, k).

    Gate values are the ORIGINAL scores s_ij (the bias reorders, it never
    rescales — same convention as Loss-Free).  Returns:
      idx   (n, k) int32   selected expert ids per token
      gate  (n, k) f32     gate weights (original s at the selected experts)
    """
    biased = s - q[None, :]
    _, idx = topk_desc(biased, k)
    gate = jnp.take_along_axis(s, idx, axis=1)
    return idx.astype(jnp.int32), gate


def expert_loads(idx, m: int):
    """Per-expert token counts from a (n, k) assignment. Returns (m,) f32."""
    one_hot = jax.nn.one_hot(idx.reshape(-1), m, dtype=jnp.float32)
    return one_hot.sum(axis=0)


def max_violation(loads, n: int, k: int, m: int):
    """MaxVio_batch = max_j load_j / mean_load - 1 (Wang et al. 2024)."""
    mean = n * k / m
    return jnp.max(loads) / mean - 1.0


def swiglu_expert_ffn(x, w1, w3, w2):
    """Per-expert SwiGLU: (silu(x @ w1) * (x @ w3)) @ w2.

    x:  (m, c, d) gathered token buffers (c = capacity slots per expert)
    w1: (m, d, f)   w3: (m, d, f)   w2: (m, f, d)
    Returns (m, c, d).
    """
    h1 = jnp.einsum("mcd,mdf->mcf", x, w1)
    h3 = jnp.einsum("mcd,mdf->mcf", x, w3)
    h = jax.nn.silu(h1) * h3
    return jnp.einsum("mcf,mfd->mcd", h, w2)


def lossfree_bias_update(b, loads, n: int, k: int, m: int, u: float):
    """Loss-Free baseline (Wang et al. 2024): b_j += u * sign(mean - load_j)."""
    mean = n * k / m
    return b + u * jnp.sign(mean - loads)


def aux_loss(s, idx, n: int, k: int, m: int, alpha: float):
    """Loss-Controlled baseline (GShard/Switch): alpha * m/(k n) sum_j f_j P_j
    with f_j the token fraction routed to j and P_j the mean score of j."""
    f = expert_loads(idx, m) * (m / (k * n))
    P = s.mean(axis=0)
    return alpha * jnp.sum(f * P)


def bip_route(s, q0, k: int, cap: int, T: int):
    """Full reference routing for one gate: dual update + biased top-k.

    Returns (q_new, idx, gate, loads)."""
    q, _ = bip_dual_update(s, q0, k, cap, T)
    idx, gate = biased_topk_gate(s, q, k)
    loads = expert_loads(idx, s.shape[1])
    return q, idx, gate, loads
