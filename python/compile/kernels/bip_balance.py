"""L1 Pallas kernel: BIP-Based Balancing dual update (Algorithm 1, lines 7-12).

The kernel runs T dual-ascent iterations over the routing score matrix
``s`` (n tokens x m experts) held resident in VMEM, producing the expert
dual vector ``q`` that reorders the top-k routing.

Hardware adaptation (paper targets GPUs, we target the TPU model):
  * the whole score matrix for one batch is small — n*m*4 bytes, e.g.
    8192 x 64 x 4B = 2 MiB — so it fits VMEM (~16 MiB) as a single block;
    the BlockSpec therefore keeps ``s`` resident and streams nothing,
    which removes all HBM traffic from the T-iteration loop (the GPU
    version would round-trip through L2 every iteration).
  * the inner loop is two order-statistic reductions; on TPU these lower
    to sort/top-k on the VPU — there is no MXU work here, so the kernel
    is bandwidth-bound on its single VMEM load.
  * for n beyond VMEM capacity, ``bip_dual_pallas_blocked`` tiles the
    token axis and keeps a per-block running top-(cap+1) — see below.

``interpret=True`` everywhere: the CPU PJRT backend cannot execute Mosaic
custom-calls, so the kernel is traced to plain HLO. Correctness vs.
``ref.bip_dual_update`` is enforced by pytest + hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import kth_largest

INTERPRET = True  # CPU PJRT: Mosaic custom-calls are not executable.


def _bip_dual_kernel(s_ref, q0_ref, q_ref, p_ref, *, k: int, cap: int, T: int):
    """Single-block kernel body: s and q both VMEM-resident.

    Runs the full T-iteration dual ascent:
        p_i = max(0, (k+1)-th largest of (s - q)_i·)
        q_j = max(0, (cap+1)-th largest of (s^T - p)_j·)
    """
    s = s_ref[...]
    n, m = s.shape
    kk = min(k + 1, m)
    cc = min(cap + 1, n)

    def body(_, carry):
        q, _p = carry
        P = s - q[None, :]
        p = jnp.maximum(0.0, kth_largest(P, kk))
        Q = s - p[:, None]
        q_new = jnp.maximum(0.0, kth_largest(Q.T, cc))
        return q_new, p

    q0 = q0_ref[...]
    p0 = jnp.zeros((n,), dtype=s.dtype)
    q, p = jax.lax.fori_loop(0, T, body, (q0, p0))
    q_ref[...] = q
    p_ref[...] = p


def bip_dual_pallas(s, q0, *, k: int, cap: int, T: int):
    """Pallas version of ``ref.bip_dual_update``. Returns (q, p)."""
    n, m = s.shape
    kernel = functools.partial(_bip_dual_kernel, k=k, cap=cap, T=T)
    q, p = pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((m,), s.dtype),
            jax.ShapeDtypeStruct((n,), s.dtype),
        ),
        interpret=INTERPRET,
    )(s, q0.astype(s.dtype))
    return q, p


def _p_stat_kernel(s_ref, q_ref, p_ref, *, k: int):
    """Row-blocked token-dual stat: p_i = max(0, (k+1)-th largest of s_i - q).

    Grid over token blocks: each program holds one (block_n, m) tile of s
    in VMEM plus the shared q vector, so arbitrary n streams through a
    fixed VMEM footprint (the HBM->VMEM schedule the GPU code expressed
    with one threadblock per token tile).
    """
    s = s_ref[...]
    q = q_ref[...]
    m = s.shape[1]
    kk = min(k + 1, m)
    P = s - q[None, :]
    p_ref[...] = jnp.maximum(0.0, kth_largest(P, kk))


def bip_p_stat_blocked(s, q, *, k: int, block_n: int = 256):
    """Blocked token-dual computation for n too large for one VMEM block."""
    n, m = s.shape
    if n % block_n != 0:
        raise ValueError(f"n={n} must be a multiple of block_n={block_n}")
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_p_stat_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), s.dtype),
        interpret=INTERPRET,
    )(s, q)


def bip_dual_pallas_blocked(s, q0, *, k: int, cap: int, T: int,
                            block_n: int = 256):
    """Token-blocked dual ascent: p via the blocked kernel, q via a top-k
    over the (cap+1) largest entries of each expert column.

    The column statistic needs a cross-block reduction; we compute it as a
    top-k over per-block partial top-(cap+1) lists, which is exact because
    the global (cap+1)-th largest is always contained in the union of the
    per-block (cap+1) largest.
    """
    n, m = s.shape
    cc = min(cap + 1, n)
    q = q0.astype(s.dtype)
    p = jnp.zeros((n,), s.dtype)
    for _ in range(T):
        # p is computed from the PREVIOUS q — same iteration order as the
        # resident kernel / ref (the returned p corresponds to q_{T-1}).
        p = bip_p_stat_blocked(s, q, k=k, block_n=block_n)
        Q = s - p[:, None]
        nb = n // block_n
        # per-block partial top-cb per expert column: (nb, m, cb)
        cb = min(cc, block_n)
        parts = jax.vmap(
            lambda blk: jnp.sort(blk.T, axis=-1)[:, block_n - cb:]
        )(Q.reshape(nb, block_n, m))
        merged = jnp.transpose(parts, (1, 0, 2)).reshape(m, -1)
        q = jnp.maximum(0.0, kth_largest(merged, cc))
    return q, p


def vmem_footprint_bytes(n: int, m: int, dtype_bytes: int = 4,
                         blocked: bool = False, block_n: int = 256) -> int:
    """Analytic VMEM footprint of the kernel (used by DESIGN/EXPERIMENTS
    perf notes; interpret-mode wallclock is not a TPU proxy)."""
    rows = block_n if blocked else n
    # s tile + biased copy + q + p
    return dtype_bytes * (rows * m * 2 + m + rows)
