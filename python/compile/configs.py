"""Model/run configurations shared by the AOT pipeline and (via the
artifact manifest) the rust coordinator.

The paper's Table 1 settings are kept exactly where they govern routing
behaviour — expert count m, top-k k, 8 MoE layers, softmax router, vocab
6400 — while d_model / d_ff / seq_len are scaled to the CPU testbed (see
DESIGN.md §Substitutions).  ``n_tokens = batch_size * seq_len`` is the
``n`` of Algorithm 1 and of MaxVio's mean load n*k/m.
"""

from __future__ import annotations

from dataclasses import dataclass, asdict, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab_size: int = 6400
    d_model: int = 128
    n_heads: int = 8
    n_layers: int = 8          # every layer is a MoE layer (Minimind-MoE)
    d_ff: int = 128            # per-expert SwiGLU hidden size
    n_experts: int = 16        # m
    top_k: int = 4             # k
    seq_len: int = 256
    batch_size: int = 4
    capacity_factor: float = 2.0
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    init_std: float = 0.02
    # optimizer (baked into the train-step HLO)
    lr: float = 3e-4
    warmup_steps: int = 32
    total_steps: int = 4096    # cosine horizon; training may stop earlier
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    # routing-mode hyperparameters (Table 2/3 settings)
    aux_alpha: float = 0.1     # Loss-Controlled
    lossfree_u: float = 1e-3   # Loss-Free
    bip_T: int = 4             # BIP dual iterations (paper sweeps 2/4/8/14)

    @property
    def n_tokens(self) -> int:
        return self.batch_size * self.seq_len

    @property
    def capacity(self) -> int:
        """Per-expert buffer slots c = ceil(cf * n * k / m)."""
        exact = self.n_tokens * self.top_k / self.n_experts
        return int(-(-self.capacity_factor * exact // 1))

    @property
    def expert_cap(self) -> int:
        """BIP constraint (2) RHS: n*k/m (integral in all paper configs)."""
        return self.n_tokens * self.top_k // self.n_experts

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def to_dict(self):
        d = asdict(self)
        d["n_tokens"] = self.n_tokens
        d["capacity"] = self.capacity
        d["expert_cap"] = self.expert_cap
        return d


# Test-speed config: tiny everything, still a real 2-layer MoE LM.
TINY = ModelConfig(
    name="tiny", vocab_size=512, d_model=32, n_heads=4, n_layers=2,
    d_ff=32, n_experts=8, top_k=2, seq_len=32, batch_size=2,
    warmup_steps=4, total_steps=256,
)

# Bench configs: paper routing fabric (m, k, 8 layers, vocab 6400), compute
# scaled so the 3-method x {T} grids of Tables 2-5 run in CPU bench budget.
MOE16_BENCH = ModelConfig(
    name="moe16-bench", d_model=64, n_heads=8, n_layers=8, d_ff=64,
    n_experts=16, top_k=4, seq_len=128, batch_size=4, capacity_factor=1.5,
)
MOE64_BENCH = ModelConfig(
    name="moe64-bench", d_model=64, n_heads=8, n_layers=8, d_ff=64,
    n_experts=64, top_k=8, seq_len=128, batch_size=4, capacity_factor=1.5,
)

# E2E configs for examples/train_moe.rs: paper 8-layer routing fabric at the
# largest parameter count the CPU testbed trains in a few hundred steps
# (~35M / ~67M; the paper's 0.3B/1.1B don't fit the budget — DESIGN.md §4).
MOE16 = ModelConfig(
    name="moe16", d_model=256, n_heads=8, n_layers=8, d_ff=320,
    n_experts=16, top_k=4, seq_len=256, batch_size=4,
)
MOE64 = ModelConfig(
    name="moe64", d_model=256, n_heads=8, n_layers=8, d_ff=160,
    n_experts=64, top_k=8, seq_len=256, batch_size=4,
)

CONFIGS = {c.name: c for c in [TINY, MOE16_BENCH, MOE64_BENCH, MOE16, MOE64]}

ROUTING_MODES = ("aux", "lossfree", "bip")


def with_bip_T(cfg: ModelConfig, T: int) -> ModelConfig:
    return replace(cfg, bip_T=T)
