"""AOT pipeline tests: manifest integrity, HLO text shape, cache no-op.

These run against the checked-out ``artifacts/`` tree when present (built
by ``make artifacts``); the tiny config is rebuilt into a tmpdir otherwise,
so the suite is self-contained.
"""

import json
import os

import pytest

from compile import aot, model
from compile.configs import CONFIGS

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """Path to a directory holding tiny artifacts + manifest."""
    man = os.path.join(ARTIFACTS, "manifest.json")
    if os.path.exists(man):
        with open(man) as f:
            if "tiny" in json.load(f).get("configs", {}):
                return ARTIFACTS
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build(out, ["tiny"], force=True, probe=True)
    return out


def load_manifest(built):
    with open(os.path.join(built, "manifest.json")) as f:
        return json.load(f)


def test_manifest_lists_every_file(built):
    man = load_manifest(built)
    for art in man["artifacts"]:
        assert os.path.exists(os.path.join(built, art["file"])), art["file"]


def test_manifest_tiny_artifact_grid(built):
    man = load_manifest(built)
    tiny = [a for a in man["artifacts"] if a["config"] == "tiny"]
    kinds = {(a["kind"], a["mode"], a.get("bip_T")) for a in tiny}
    assert ("init", "-", None) in kinds
    assert ("train", "aux", None) in kinds
    assert ("train", "lossfree", None) in kinds
    assert ("train", "bip", 2) in kinds and ("train", "bip", 4) in kinds
    for mode in ("aux", "lossfree", "bip"):
        assert ("eval", mode, None) in kinds


def test_manifest_io_specs_match_model(built):
    man = load_manifest(built)
    cfg = CONFIGS["tiny"]
    total = model.param_specs(cfg)[1]
    assert man["configs"]["tiny"]["theta_size"] == total
    train = next(a for a in man["artifacts"]
                 if a["config"] == "tiny" and a["kind"] == "train")
    names = [s["name"] for s in train["inputs"]]
    assert names == ["theta", "adam_m", "adam_v", "step", "route_state",
                     "tokens"]
    assert train["inputs"][0]["shape"] == [total]
    out_names = [s["name"] for s in train["outputs"]]
    assert out_names[:5] == names[:5]        # state threads through
    assert "loads" in out_names and "nll_sum" in out_names


def test_param_table_covers_theta(built):
    man = load_manifest(built)
    cfg = man["configs"]["tiny"]
    covered = 0
    for p in cfg["params"]:
        size = 1
        for s in p["shape"]:
            size *= s
        assert p["offset"] == covered
        covered += size
    assert covered == cfg["theta_size"]


def test_hlo_text_is_old_parser_compatible(built):
    """The xla_extension 0.5.1 text parser rejects the ``topk`` instruction
    (jax >= 0.5 lowers lax.top_k to it). Our kernels must therefore never
    emit it — this is the regression test for that gotcha."""
    man = load_manifest(built)
    for art in man["artifacts"]:
        if art["config"] != "tiny":
            continue
        with open(os.path.join(built, art["file"])) as f:
            text = f.read()
        assert "ENTRY" in text and "HloModule" in text
        for op in (" topk(", " top-k(", " approx-topk("):
            assert op not in text, f"{art['file']} contains {op.strip()}"


def test_fingerprint_cache_no_op(tmp_path):
    """Second build with unchanged sources must lower nothing."""
    out = str(tmp_path)
    aot.build(out, ["tiny"], force=True, probe=False)
    first = {f: os.path.getmtime(os.path.join(out, f))
             for f in os.listdir(out)}
    aot.build(out, ["tiny"], force=False, probe=False)
    second = {f: os.path.getmtime(os.path.join(out, f))
              for f in os.listdir(out)}
    for f, t in first.items():
        if f.endswith(".hlo.txt"):
            assert second[f] == t, f"{f} was re-lowered"


def test_source_fingerprint_is_stable():
    assert aot.source_fingerprint() == aot.source_fingerprint()
