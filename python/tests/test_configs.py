"""Config invariants: the routing fabric must match the paper's Table 1
wherever it matters, and derived quantities must be consistent."""

import pytest

from compile.configs import (
    CONFIGS, MOE16, MOE16_BENCH, MOE64, MOE64_BENCH, TINY, with_bip_T,
)


def test_registry_contains_all_presets():
    assert set(CONFIGS) == {"tiny", "moe16-bench", "moe64-bench",
                            "moe16", "moe64"}


@pytest.mark.parametrize("cfg", [MOE16_BENCH, MOE16])
def test_16_expert_models_match_table1_fabric(cfg):
    assert cfg.vocab_size == 6400
    assert cfg.n_layers == 8
    assert cfg.n_experts == 16
    assert cfg.top_k == 4
    assert cfg.n_heads == 8


@pytest.mark.parametrize("cfg", [MOE64_BENCH, MOE64])
def test_64_expert_models_match_table1_fabric(cfg):
    assert cfg.vocab_size == 6400
    assert cfg.n_layers == 8
    assert cfg.n_experts == 64
    assert cfg.top_k == 8


@pytest.mark.parametrize("cfg", list(CONFIGS.values()))
def test_derived_quantities(cfg):
    assert cfg.n_tokens == cfg.batch_size * cfg.seq_len
    # BIP constraint (2) RHS must be integral (paper configs satisfy m | nk)
    assert cfg.n_tokens * cfg.top_k % cfg.n_experts == 0
    assert cfg.expert_cap == cfg.n_tokens * cfg.top_k // cfg.n_experts
    # capacity must exceed the balanced load, else BIP itself would drop
    assert cfg.capacity > cfg.expert_cap
    assert cfg.d_model % cfg.n_heads == 0


def test_with_bip_T_only_changes_T():
    c = with_bip_T(TINY, 9)
    assert c.bip_T == 9
    assert c.name == TINY.name
    assert c.n_experts == TINY.n_experts


def test_to_dict_includes_derived():
    d = MOE16_BENCH.to_dict()
    for key in ("n_tokens", "capacity", "expert_cap", "aux_alpha",
                "lossfree_u", "bip_T"):
        assert key in d
    assert d["aux_alpha"] == 0.1      # paper: Minimind default
    assert d["lossfree_u"] == 1e-3    # paper: Wang et al. 2024


def test_tiny_is_actually_tiny():
    assert TINY.n_tokens <= 128
    assert TINY.vocab_size <= 1024
