"""Multi-step training dynamics on the tiny config — the L2-level version
of the paper's Figure 1 story, checked numerically in-process (the full
PJRT path is exercised by the rust integration tests)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import TINY

CFG = TINY


def run_steps(mode, steps, seed=0):
    theta = model.init_theta(CFG, seed)
    step_fn = jax.jit(
        lambda th, m_, v, st, q, t: model.train_step(
            th, m_, v, st, q, t, mode, CFG))
    m_, v = jnp.zeros_like(theta), jnp.zeros_like(theta)
    st = jnp.zeros((), jnp.int32)
    q = jnp.zeros((CFG.n_layers, CFG.n_experts))
    key = jax.random.PRNGKey(seed + 100)
    history = {"loss": [], "maxvio": [], "drops": [], "q": []}
    mean = CFG.n_tokens * CFG.top_k / CFG.n_experts
    for i in range(steps):
        tok = jax.random.randint(
            jax.random.fold_in(key, i),
            (CFG.batch_size, CFG.seq_len + 1), 0, CFG.vocab_size)
        theta, m_, v, st, q, nll, loads, drops = step_fn(
            theta, m_, v, st, q, tok)
        history["loss"].append(float(nll) / CFG.n_tokens)
        history["maxvio"].append(
            float((loads.max(axis=1) / mean - 1.0).mean()))
        history["drops"].append(float(drops.mean()))
        history["q"].append(np.asarray(q))
    return history


@pytest.fixture(scope="module")
def runs():
    return {mode: run_steps(mode, 12) for mode in
            ["aux", "lossfree", "bip"]}


def test_loss_finite_and_comparable_across_modes(runs):
    for mode, h in runs.items():
        assert all(np.isfinite(h["loss"])), mode
        # all start from ~ln(V)
        assert abs(h["loss"][0] - np.log(CFG.vocab_size)) < 0.5


def test_bip_maxvio_low_from_step_one(runs):
    """The headline claim at L2: balanced from the FIRST step."""
    assert runs["bip"]["maxvio"][0] < runs["aux"]["maxvio"][0]
    assert max(runs["bip"]["maxvio"]) < 0.5
    assert np.mean(runs["bip"]["maxvio"]) < np.mean(runs["aux"]["maxvio"])


def test_bip_never_drops_tokens(runs):
    assert all(d == 0.0 for d in runs["bip"]["drops"])


def test_bip_q_warm_start_evolves(runs):
    q = runs["bip"]["q"]
    assert np.abs(q[0]).max() > 0
    # q keeps adapting but stays bounded (scores are softmax, q < 1)
    assert not np.array_equal(q[0], q[-1])
    assert np.abs(q[-1]).max() < 1.0


def test_lossfree_bias_magnitude_grows_linearly(runs):
    q = runs["lossfree"]["q"]
    # sign updates move each coordinate by exactly u per step while
    # unbalanced; magnitudes must be multiples of u and non-decreasing
    # in the early phase
    u = CFG.lossfree_u
    mags = [np.abs(x).max() for x in q]
    assert mags[0] == pytest.approx(u, rel=1e-4)
    assert mags[-1] <= 12 * u + 1e-9
    assert mags[-1] >= mags[0] - 1e-9


def test_aux_q_state_stays_zero(runs):
    for x in runs["aux"]["q"]:
        assert np.abs(x).max() == 0.0


def test_modes_differ_in_routing_not_loss_scale(runs):
    # all three losses stay in the same ballpark over 12 steps (routing
    # changes which experts train, not the LM objective's magnitude)
    finals = {m: h["loss"][-1] for m, h in runs.items()}
    lo, hi = min(finals.values()), max(finals.values())
    assert hi - lo < 0.5, finals
