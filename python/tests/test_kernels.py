"""Kernel-vs-oracle correctness: every Pallas kernel against ref.py.

This is the CORE correctness signal of L1: hypothesis sweeps shapes,
seeds and score distributions; assert_allclose against the pure-jnp
reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bip_balance import (
    bip_dual_pallas,
    bip_dual_pallas_blocked,
    bip_p_stat_blocked,
    vmem_footprint_bytes,
)
from compile.kernels.topk_gate import biased_topk_gate_pallas
from compile.kernels.moe_ffn import (
    expert_ffn,
    mxu_utilization_estimate,
    swiglu_expert_ffn_pallas,
)


def scores(seed, n, m, temp=2.0):
    """Softmax-distributed routing scores, like the model's router."""
    key = jax.random.PRNGKey(seed)
    return jax.nn.softmax(jax.random.normal(key, (n, m)) * temp, axis=-1)


# ---------------------------------------------------------------------------
# order-statistic helpers
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(1, 6), st.integers(2, 40))
@settings(max_examples=30, deadline=None)
def test_kth_largest_matches_numpy(seed, kth, width):
    kth = min(kth, width)
    x = jax.random.normal(jax.random.PRNGKey(seed), (5, width))
    got = ref.kth_largest(x, kth)
    want = np.sort(np.asarray(x), axis=-1)[:, width - kth]
    np.testing.assert_allclose(got, want, rtol=1e-6)


@given(st.integers(0, 2**31 - 1), st.integers(1, 8), st.integers(8, 32))
@settings(max_examples=30, deadline=None)
def test_topk_desc_matches_lax_topk(seed, k, width):
    k = min(k, width)
    x = jax.random.normal(jax.random.PRNGKey(seed), (7, width))
    vals, idx = ref.topk_desc(x, k)
    lvals, lidx = jax.lax.top_k(x, k)
    np.testing.assert_allclose(vals, lvals, rtol=1e-6)
    np.testing.assert_array_equal(idx, lidx)


# ---------------------------------------------------------------------------
# BIP dual update kernel
# ---------------------------------------------------------------------------

@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.sampled_from([64, 128, 256]),
    m=st.sampled_from([8, 16, 64]),
    k=st.sampled_from([2, 4, 8]),
    T=st.sampled_from([1, 2, 4, 8]),
)
@settings(max_examples=25, deadline=None)
def test_bip_dual_pallas_matches_ref(seed, n, m, k, T):
    k = min(k, m)
    cap = n * k // m
    s = scores(seed, n, m)
    q0 = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed + 1), (m,))) * 0.01
    qr, pr = ref.bip_dual_update(s, q0, k=k, cap=cap, T=T)
    qp, pp = bip_dual_pallas(s, q0, k=k, cap=cap, T=T)
    np.testing.assert_allclose(qp, qr, atol=1e-6)
    np.testing.assert_allclose(pp, pr, atol=1e-6)


@given(
    seed=st.integers(0, 2**31 - 1),
    nb=st.sampled_from([2, 4]),
    block=st.sampled_from([64, 128]),
    m=st.sampled_from([8, 16]),
    T=st.sampled_from([1, 4]),
)
@settings(max_examples=15, deadline=None)
def test_bip_dual_blocked_exactness(seed, nb, block, m, T):
    """The token-blocked variant must be bit-identical to the resident one:
    the partial-top-(cap+1) merge is exact, not approximate."""
    n, k = nb * block, 4
    cap = n * k // m
    s = scores(seed, n, m)
    q0 = jnp.zeros((m,))
    qr, pr = ref.bip_dual_update(s, q0, k=k, cap=cap, T=T)
    qb, pb = bip_dual_pallas_blocked(s, q0, k=k, cap=cap, T=T, block_n=block)
    np.testing.assert_allclose(qb, qr, atol=1e-6)
    np.testing.assert_allclose(pb, pr, atol=1e-6)


def test_bip_dual_dtype_bf16():
    s = scores(0, 128, 16).astype(jnp.bfloat16)
    q0 = jnp.zeros((16,), jnp.bfloat16)
    qr, _ = ref.bip_dual_update(s, q0, k=4, cap=32, T=4)
    qp, _ = bip_dual_pallas(s, q0, k=4, cap=32, T=4)
    np.testing.assert_allclose(
        qp.astype(np.float32), qr.astype(np.float32), atol=1e-2)


def test_p_stat_blocked_rejects_ragged_n():
    s = scores(0, 100, 8)
    with pytest.raises(ValueError):
        bip_p_stat_blocked(s, jnp.zeros((8,)), k=2, block_n=64)


def test_vmem_footprint_scales_with_block_not_n():
    big = vmem_footprint_bytes(1 << 20, 64, blocked=True, block_n=256)
    small = vmem_footprint_bytes(1 << 10, 64, blocked=True, block_n=256)
    assert big == small
    assert vmem_footprint_bytes(8192, 64) < 16 * 1024 * 1024  # fits VMEM


# ---------------------------------------------------------------------------
# biased top-k gate kernel
# ---------------------------------------------------------------------------

@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.sampled_from([64, 128, 256]),
    m=st.sampled_from([8, 16, 64]),
    k=st.sampled_from([1, 2, 4, 8]),
    block=st.sampled_from([64, 128]),
)
@settings(max_examples=25, deadline=None)
def test_gate_pallas_matches_ref(seed, n, m, k, block):
    k = min(k, m)
    s = scores(seed, n, m)
    q = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed + 2), (m,))) * 0.05
    idx_r, gate_r = ref.biased_topk_gate(s, q, k)
    loads_r = ref.expert_loads(idx_r, m)
    idx_p, gate_p, loads_p = biased_topk_gate_pallas(s, -q, k=k, block_n=block)
    np.testing.assert_array_equal(idx_p, idx_r)
    np.testing.assert_allclose(gate_p, gate_r, atol=1e-6)
    np.testing.assert_allclose(loads_p, loads_r, atol=1e-6)


def test_gate_loads_sum_to_nk():
    n, m, k = 256, 16, 4
    s = scores(3, n, m)
    _, _, loads = biased_topk_gate_pallas(s, jnp.zeros((m,)), k=k)
    assert float(loads.sum()) == n * k


def test_gate_zero_bias_is_plain_topk():
    n, m, k = 128, 8, 2
    s = scores(7, n, m)
    idx, _, _ = biased_topk_gate_pallas(s, jnp.zeros((m,)), k=k)
    _, lidx = jax.lax.top_k(s, k)
    np.testing.assert_array_equal(idx, lidx)


# ---------------------------------------------------------------------------
# grouped expert FFN kernel (fwd + custom VJP)
# ---------------------------------------------------------------------------

@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.sampled_from([2, 4, 8]),
    c=st.sampled_from([8, 16, 32]),
    d=st.sampled_from([8, 16]),
    f=st.sampled_from([8, 24]),
)
@settings(max_examples=20, deadline=None)
def test_ffn_forward_matches_ref(seed, m, c, d, f):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (m, c, d))
    w1 = jax.random.normal(ks[1], (m, d, f)) * 0.2
    w3 = jax.random.normal(ks[2], (m, d, f)) * 0.2
    w2 = jax.random.normal(ks[3], (m, f, d)) * 0.2
    np.testing.assert_allclose(
        swiglu_expert_ffn_pallas(x, w1, w3, w2),
        ref.swiglu_expert_ffn(x, w1, w3, w2),
        atol=1e-4,
    )


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_ffn_custom_vjp_matches_autodiff_of_ref(seed):
    m, c, d, f = 3, 8, 6, 10
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (m, c, d)) * 0.5
    w1 = jax.random.normal(ks[1], (m, d, f)) * 0.3
    w3 = jax.random.normal(ks[2], (m, d, f)) * 0.3
    w2 = jax.random.normal(ks[3], (m, f, d)) * 0.3

    def lp(x, w1, w3, w2):
        return jnp.sum(jnp.tanh(expert_ffn(x, w1, w3, w2)))

    def lr(x, w1, w3, w2):
        return jnp.sum(jnp.tanh(ref.swiglu_expert_ffn(x, w1, w3, w2)))

    gp = jax.grad(lp, argnums=(0, 1, 2, 3))(x, w1, w3, w2)
    gr = jax.grad(lr, argnums=(0, 1, 2, 3))(x, w1, w3, w2)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(a, b, atol=1e-4)


def test_ffn_jittable_and_scannable():
    m, c, d, f = 2, 4, 4, 4
    x = jnp.ones((m, c, d))
    w1 = jnp.ones((m, d, f)) * 0.1
    w3 = jnp.ones((m, d, f)) * 0.1
    w2 = jnp.ones((m, f, d)) * 0.1

    def step(carry, _):
        return carry + expert_ffn(x, w1, w3, w2).sum(), None

    out, _ = jax.jit(lambda: jax.lax.scan(step, 0.0, None, length=3))()
    assert np.isfinite(float(out))


def test_mxu_estimate_bounds():
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    assert 0.0 < mxu_utilization_estimate(100, 60, 60) < 1.0
