"""L2 model tests: parameter layout, forward/train/eval semantics, the
three routing modes, dispatch correctness and drop accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import CONFIGS, TINY, ModelConfig, with_bip_T

CFG = TINY


@pytest.fixture(scope="module")
def theta():
    return model.init_theta(CFG, 0)


@pytest.fixture(scope="module")
def tokens():
    return jax.random.randint(
        jax.random.PRNGKey(7), (CFG.batch_size, CFG.seq_len + 1),
        0, CFG.vocab_size)


def zeros_state(cfg=CFG):
    return jnp.zeros((cfg.n_layers, cfg.n_experts))


# ---------------------------------------------------------------------------
# parameter layout
# ---------------------------------------------------------------------------

def test_param_specs_contiguous_and_total():
    specs, total = model.param_specs(CFG)
    off = 0
    for sp in specs:
        assert sp.offset == off
        off += int(np.prod(sp.shape))
    assert off == total


def test_unpack_round_trips(theta):
    specs, total = model.param_specs(CFG)
    p = model.unpack(theta, specs)
    flat_again = jnp.concatenate([p[sp.name].reshape(-1) for sp in specs])
    np.testing.assert_allclose(flat_again, theta)


def test_init_norm_gains_are_ones(theta):
    specs, _ = model.param_specs(CFG)
    p = model.unpack(theta, specs)
    np.testing.assert_allclose(p["attn_norm"], 1.0)
    np.testing.assert_allclose(p["final_norm"], 1.0)


def test_init_stds_roughly_respected(theta):
    specs, _ = model.param_specs(CFG)
    p = model.unpack(theta, specs)
    emp = float(p["embed"].std())
    assert 0.7 * CFG.init_std < emp < 1.3 * CFG.init_std


def test_decay_mask_excludes_norms():
    specs, total = model.param_specs(CFG)
    mask = np.asarray(model.decay_mask(specs, total))
    for sp in specs:
        size = int(np.prod(sp.shape))
        seg = mask[sp.offset:sp.offset + size]
        assert (seg == (1.0 if sp.decay else 0.0)).all(), sp.name


def test_param_count_magnitudes():
    # the e2e configs must be materially larger than the bench configs
    sizes = {n: model.param_specs(c)[1] for n, c in CONFIGS.items()}
    assert sizes["tiny"] < sizes["moe16-bench"] < sizes["moe16"]
    assert sizes["moe64"] > 60_000_000 * 0.9  # ~67M params


# ---------------------------------------------------------------------------
# forward pass
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["aux", "lossfree", "bip"])
def test_forward_shapes_and_finiteness(theta, tokens, mode):
    nll, aux, q, loads, drops = model.forward(
        theta, zeros_state(), tokens, mode, CFG)
    L, m = CFG.n_layers, CFG.n_experts
    assert q.shape == (L, m) and loads.shape == (L, m)
    assert drops.shape == (L,)
    assert np.isfinite(float(nll))
    n_tok = CFG.n_tokens
    assert abs(float(nll) / n_tok - np.log(CFG.vocab_size)) < 0.5


def test_loads_sum_to_nk_per_layer(theta, tokens):
    _, _, _, loads, _ = model.forward(
        theta, zeros_state(), tokens, "bip", CFG)
    np.testing.assert_allclose(
        loads.sum(axis=1), CFG.n_tokens * CFG.top_k)


def test_bip_mode_balances_better_than_aux_at_init(theta, tokens):
    _, _, _, loads_a, _ = model.forward(
        theta, zeros_state(), tokens, "aux", CFG)
    _, _, _, loads_b, _ = model.forward(
        theta, zeros_state(), tokens, "bip", CFG)
    mean = CFG.n_tokens * CFG.top_k / CFG.n_experts
    vio_a = float((loads_a.max(axis=1) / mean - 1).mean())
    vio_b = float((loads_b.max(axis=1) / mean - 1).mean())
    assert vio_b <= vio_a + 1e-6


def test_aux_loss_positive_and_scales_with_alpha(theta, tokens):
    from dataclasses import replace
    _, aux_a, _, _, _ = model.forward(
        theta, zeros_state(), tokens, "aux", CFG)
    assert 0.0 < float(aux_a) < 1.0
    cfg2 = replace(CFG, aux_alpha=CFG.aux_alpha * 2)
    _, aux_2, _, _, _ = model.forward(
        theta, zeros_state(), tokens, "aux", cfg2)
    np.testing.assert_allclose(float(aux_2), 2 * float(aux_a), rtol=1e-5)


def test_bip_q_state_updates_and_lossfree_bias_moves(theta, tokens):
    _, _, q_bip, _, _ = model.forward(
        theta, zeros_state(), tokens, "bip", CFG)
    assert float(jnp.abs(q_bip).max()) > 0.0
    _, _, b_lf, loads, _ = model.forward(
        theta, zeros_state(), tokens, "lossfree", CFG)
    # sign update: |b| == u wherever load != mean
    mean = CFG.n_tokens * CFG.top_k / CFG.n_experts
    moved = np.asarray(jnp.abs(b_lf) > 0)
    unbalanced = np.asarray(loads != mean)
    assert (moved == unbalanced).all()


def test_frozen_route_leaves_state(theta, tokens):
    q0 = jnp.abs(jax.random.normal(jax.random.PRNGKey(3),
                                   (CFG.n_layers, CFG.n_experts))) * 0.01
    _, _, q_out, _, _ = model.forward(
        theta, q0, tokens, "bip", CFG, frozen_route=True)
    np.testing.assert_allclose(q_out, q0)


# ---------------------------------------------------------------------------
# dispatch internals
# ---------------------------------------------------------------------------

def test_positions_in_expert_are_dense_ranks():
    flat_e = jnp.asarray([0, 1, 0, 2, 1, 0], jnp.int32)
    pos, counts = model._positions_in_expert(flat_e, 4)
    np.testing.assert_array_equal(pos, [0, 0, 1, 0, 1, 2])
    np.testing.assert_array_equal(counts, [3, 2, 1, 0])


def test_dispatch_matches_dense_compute():
    """Capacity dispatch + grouped FFN == dense masked mixture, when no
    token is dropped."""
    cfg = TINY
    n, d = 16, cfg.d_model
    m, k = cfg.n_experts, cfg.top_k
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d))
    lp = {
        "w1": jax.random.normal(jax.random.PRNGKey(1), (m, d, cfg.d_ff)) * .2,
        "w3": jax.random.normal(jax.random.PRNGKey(2), (m, d, cfg.d_ff)) * .2,
        "w2": jax.random.normal(jax.random.PRNGKey(3), (m, cfg.d_ff, d)) * .2,
    }
    idx = jnp.stack([jnp.arange(n) % m, (jnp.arange(n) + 1) % m], axis=1)
    idx = idx.astype(jnp.int32)
    gate = jnp.full((n, k), 0.5)
    y, drop = model.moe_dispatch_ffn(x, idx, gate, lp, cfg)
    assert float(drop) == 0.0
    # dense reference
    from compile.kernels import ref as kref
    y_dense = jnp.zeros_like(x)
    for slot in range(k):
        per_tok = []
        for i in range(n):
            e = int(idx[i, slot])
            out = kref.swiglu_expert_ffn(
                x[i][None, None, :], lp["w1"][e][None], lp["w3"][e][None],
                lp["w2"][e][None])[0, 0]
            per_tok.append(out * gate[i, slot])
        y_dense = y_dense + jnp.stack(per_tok)
    np.testing.assert_allclose(y, y_dense, atol=1e-4)


def test_dispatch_drops_overflow_tokens():
    cfg = TINY
    n, d = 32, cfg.d_model
    k = cfg.top_k
    x = jnp.ones((n, d))
    lp = {
        "w1": jnp.ones((cfg.n_experts, d, cfg.d_ff)) * 0.1,
        "w3": jnp.ones((cfg.n_experts, d, cfg.d_ff)) * 0.1,
        "w2": jnp.ones((cfg.n_experts, cfg.d_ff, d)) * 0.1,
    }
    idx = jnp.zeros((n, k), jnp.int32)          # everyone -> expert 0
    gate = jnp.full((n, k), 1.0 / k)
    _, drop = model.moe_dispatch_ffn(x, idx, gate, lp, cfg)
    expected = 1.0 - cfg.capacity / (n * k)
    assert abs(float(drop) - max(expected, 0.0)) < 1e-6


# ---------------------------------------------------------------------------
# train / eval steps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["aux", "lossfree", "bip"])
def test_train_step_reduces_loss(theta, tokens, mode):
    step_fn = jax.jit(
        lambda th, m_, v, st, q, t: model.train_step(
            th, m_, v, st, q, t, mode, CFG))
    th, m_, v = theta, jnp.zeros_like(theta), jnp.zeros_like(theta)
    st, q = jnp.zeros((), jnp.int32), zeros_state()
    first = None
    for _ in range(8):
        th, m_, v, st, q, nll, loads, drops = step_fn(th, m_, v, st, q, tokens)
        if first is None:
            first = float(nll)
    assert float(nll) < first  # same batch: loss must drop
    assert int(st) == 8


def test_train_step_updates_every_tensor(theta, tokens):
    specs, _ = model.param_specs(CFG)
    out = model.train_step(
        theta, jnp.zeros_like(theta), jnp.zeros_like(theta),
        jnp.zeros((), jnp.int32), zeros_state(), tokens, "bip", CFG)
    th2 = out[0]
    p0 = model.unpack(theta, specs)
    p1 = model.unpack(th2, specs)
    for sp in specs:
        diff = float(jnp.abs(p1[sp.name] - p0[sp.name]).max())
        assert diff > 0.0, f"{sp.name} did not train"


def test_eval_step_deterministic(theta, tokens):
    a = model.eval_step(theta, zeros_state(), tokens, "bip", CFG)
    b = model.eval_step(theta, zeros_state(), tokens, "bip", CFG)
    np.testing.assert_allclose(a[0], b[0])


def test_lr_schedule_warmup_and_decay():
    lrs = [float(model.lr_at(jnp.float32(s), CFG)) for s in
           [0, CFG.warmup_steps // 2, CFG.warmup_steps,
            CFG.total_steps // 2, CFG.total_steps]]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup rises
    assert lrs[2] >= lrs[3] >= lrs[4]        # cosine decays
    assert lrs[4] >= 0.09 * CFG.lr           # floor ~10%


def test_bip_T_changes_routing(theta, tokens):
    outs = {}
    for T in (1, 8):
        cfg = with_bip_T(CFG, T)
        _, _, q, loads, _ = model.forward(
            theta, zeros_state(cfg), tokens, "bip", cfg)
        outs[T] = np.asarray(loads)
    assert not np.array_equal(outs[1], outs[8])


def test_route_probe_returns_softmax_rows(theta, tokens):
    s = model.route_probe(theta, zeros_state(), tokens, 0, "bip", CFG)
    assert s.shape == (CFG.n_tokens, CFG.n_experts)
    np.testing.assert_allclose(s.sum(axis=1), 1.0, rtol=1e-5)
