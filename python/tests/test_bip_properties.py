"""Algorithmic properties of BIP-Based Balancing (paper §3).

These tests pin down WHY the algorithm works, not just that the kernel
matches the oracle:

  * the routing it induces is near-feasible for BIP constraint (2)
    (per-expert load <= n*k/m, i.e. MaxVio ~ 0) from the very first batch;
  * it beats greedy top-k on balance while keeping most of the score mass;
  * its objective is close to the LP relaxation optimum (verified against
    scipy.optimize.linprog on small instances — the (P-LP)/(D-LP) pair of
    the paper);
  * duals are nonnegative and the balancing effect is monotone in T.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def scores(seed, n, m, temp=2.0, skew=0.0):
    """Routing-score batches; ``skew`` adds a per-expert popularity offset
    (the hard case: everyone wants the same experts)."""
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (n, m)) * temp
    if skew:
        pref = jnp.linspace(skew, 0.0, m)
        logits = logits + pref[None, :]
    return jax.nn.softmax(logits, axis=-1)


def maxvio(loads, n, k, m):
    return float(jnp.max(loads) / (n * k / m) - 1.0)


@given(
    seed=st.integers(0, 2**31 - 1),
    skew=st.sampled_from([0.0, 1.0, 3.0]),
)
@settings(max_examples=20, deadline=None)
def test_bip_routing_is_balanced_from_first_batch(seed, skew):
    """Paper's headline: balance holds at step 1, no learning needed."""
    n, m, k, T = 256, 16, 4, 8
    s = scores(seed, n, m, skew=skew)
    q, idx, _, loads = ref.bip_route(s, jnp.zeros((m,)), k, n * k // m, T)
    greedy_idx, _ = ref.biased_topk_gate(s, jnp.zeros((m,)), k)
    greedy_loads = ref.expert_loads(greedy_idx, m)
    assert maxvio(loads, n, k, m) <= 0.25
    # strictly better than greedy whenever greedy is meaningfully unbalanced
    if maxvio(greedy_loads, n, k, m) > 0.5:
        assert maxvio(loads, n, k, m) < maxvio(greedy_loads, n, k, m)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_balance_does_not_degrade_with_expert_count(seed):
    """Table 3's observation: MaxVio stays low going m=16 -> m=64."""
    n, k, T = 512, 8, 8
    for m in (16, 64):
        s = scores(seed, n, m, skew=2.0)
        _, _, _, loads = ref.bip_route(s, jnp.zeros((m,)), k, n * k // m, T)
        assert maxvio(loads, n, k, m) <= 0.4


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_duals_nonnegative_and_zero_when_underloaded(seed):
    n, m, k = 128, 16, 4
    s = scores(seed, n, m)
    q, p = ref.bip_dual_update(s, jnp.zeros((m,)), k=k, cap=n * k // m, T=6)
    assert float(q.min()) >= 0.0
    assert float(p.min()) >= 0.0
    # capacity >= n => constraint (2) never binds => q stays 0
    q_loose, _ = ref.bip_dual_update(s, jnp.zeros((m,)), k=k, cap=n, T=6)
    np.testing.assert_allclose(q_loose, 0.0)


@given(seed=st.integers(0, 2**31 - 1), T=st.sampled_from([2, 4, 8, 14]))
@settings(max_examples=16, deadline=None)
def test_score_mass_retention(seed, T):
    """Balancing must not trash routing quality: the selected score mass
    stays close to greedy's (the BIP objective trades a bounded amount)."""
    n, m, k = 256, 16, 4
    s = scores(seed, n, m, skew=1.0)
    _, _, gate_b, _ = ref.bip_route(s, jnp.zeros((m,)), k, n * k // m, T)
    _, gate_g = ref.biased_topk_gate(s, jnp.zeros((m,)), k)
    assert float(gate_b.sum()) >= 0.75 * float(gate_g.sum())


def test_lp_relaxation_bound_scipy():
    """(BIP) <= (P-LP): our routed objective is <= the LP optimum and,
    with enough dual iterations, close to it (the paper's primal-dual
    argument). Small instance; scipy.linprog is the independent referee."""
    from scipy.optimize import linprog

    rng = np.random.default_rng(0)
    n, m, k = 24, 6, 2
    cap = n * k // m
    s = np.asarray(scores(11, n, m, skew=2.0))
    # LP: maximize sum s_ij x_ij -> minimize -s
    c = -s.reshape(-1)
    A = []
    b = []
    for i in range(n):          # sum_j x_ij <= k
        row = np.zeros(n * m)
        row[i * m:(i + 1) * m] = 1.0
        A.append(row)
        b.append(k)
    for j in range(m):          # sum_i x_ij <= cap
        row = np.zeros(n * m)
        row[j::m] = 1.0
        A.append(row)
        b.append(cap)
    res = linprog(c, A_ub=np.asarray(A), b_ub=np.asarray(b),
                  bounds=(0, 1), method="highs")
    assert res.status == 0
    lp_opt = -res.fun

    q, idx, gate, loads = ref.bip_route(
        jnp.asarray(s), jnp.zeros((m,)), k, cap, T=16)
    routed = float(gate.sum())
    _, gate_g = ref.biased_topk_gate(jnp.asarray(s), jnp.zeros((m,)), k)
    greedy = float(gate_g.sum())
    # greedy top-k maximizes the per-token objective, so it upper-bounds
    # both the LP optimum and any biased routing (BIP only reorders).
    assert lp_opt <= greedy + 1e-5
    assert routed <= greedy + 1e-5
    # the dual heuristic is NEAR-feasible (MaxVio ~ 0.1): its objective can
    # sit slightly above the (strictly capacity-feasible) LP optimum, but
    # must stay close to it, and loads must be near the capacity bound.
    assert routed >= 0.8 * lp_opt
    assert routed <= 1.1 * lp_opt
    assert float(loads.max()) <= cap * 1.35


def test_warm_start_carries_balance_across_batches():
    """Algorithm 1 line 2: q persists; a warm-started q should balance a
    *fresh* batch from the same distribution better than q=0 with tiny T."""
    n, m, k, cap = 256, 16, 4, 64
    q = jnp.zeros((m,))
    for seed in range(5):
        s = scores(seed, n, m, skew=3.0)
        q, _ = ref.bip_dual_update(s, q, k=k, cap=cap, T=4)
    s_new = scores(99, n, m, skew=3.0)
    idx_w, _ = ref.biased_topk_gate(s_new, q, k)
    idx_c, _ = ref.biased_topk_gate(s_new, jnp.zeros((m,)), k)
    vio_w = maxvio(ref.expert_loads(idx_w, m), n, k, m)
    vio_c = maxvio(ref.expert_loads(idx_c, m), n, k, m)
    assert vio_w < vio_c


def test_lossfree_needs_many_batches_bip_does_not():
    """The paper's motivating contrast (Fig. 1): Loss-Free's sign update
    moves b by u per batch and takes many batches to balance a skewed
    distribution; BIP balances the first batch."""
    n, m, k, cap, u = 256, 16, 4, 64, 1e-3
    s = scores(1, n, m, skew=3.0)
    # loss-free after ONE batch
    b = jnp.zeros((m,))
    idx, _ = ref.biased_topk_gate(s, -b, k)   # b is added
    loads = ref.expert_loads(idx, m)
    b = ref.lossfree_bias_update(b, loads, n, k, m, u)
    idx2, _ = ref.biased_topk_gate(s, -b, k)
    vio_lf = maxvio(ref.expert_loads(idx2, m), n, k, m)
    # bip after ONE batch
    _, _, _, loads_bip = ref.bip_route(s, jnp.zeros((m,)), k, cap, T=4)
    vio_bip = maxvio(loads_bip, n, k, m)
    assert vio_bip < vio_lf * 0.5


@pytest.mark.parametrize("n,m,k", [(128, 16, 4), (512, 64, 8)])
def test_aux_loss_decreases_with_balance(n, m, k):
    """Sanity on the Loss-Controlled baseline: the auxiliary loss is larger
    for unbalanced routings than for balanced ones."""
    s_skew = scores(5, n, m, skew=4.0)
    s_flat = scores(5, n, m, skew=0.0)
    idx_s, _ = ref.biased_topk_gate(s_skew, jnp.zeros((m,)), k)
    idx_f, _ = ref.biased_topk_gate(s_flat, jnp.zeros((m,)), k)
    a_s = float(ref.aux_loss(s_skew, idx_s, n, k, m, alpha=0.1))
    a_f = float(ref.aux_loss(s_flat, idx_f, n, k, m, alpha=0.1))
    assert a_s > a_f
