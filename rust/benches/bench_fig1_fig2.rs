//! Reproduces **Figure 1** (16-expert) and **Figure 2** (64-expert):
//! MaxVio_batch vs training step for Loss-Controlled (blue), Loss-Free
//! (green) and BIP (red).
//!
//! Reuses the cached Table 2/3 runs when present (same reports/ cache),
//! writes combined CSVs `reports/fig1.csv` / `reports/fig2.csv` with one
//! column per method, and draws an ASCII rendition of each figure.

use std::path::Path;

use bip_moe::bench::experiments::run_or_load;
use bip_moe::bench::BenchConfig;
use bip_moe::metrics::table::ascii_plot;
use bip_moe::runtime::Engine;
use bip_moe::train::TrainDriver;
use bip_moe::util::csv::CsvWriter;

fn main() {
    bip_moe::util::log::init_from_env();
    let bench = BenchConfig::from_env(80, 400);
    for (fig, config, bip_t) in
        [("fig1", "moe16-bench", 4usize), ("fig2", "moe64-bench", 14)]
    {
        if let Err(e) = run(&bench, fig, config, bip_t) {
            eprintln!("bench_{fig}: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run(
    bench: &BenchConfig,
    fig: &str,
    config: &str,
    bip_t: usize,
) -> anyhow::Result<()> {
    let engine = Engine::new(Path::new("artifacts"))?;
    let reports = Path::new("reports");

    let methods: [(&str, &str, usize); 3] = [
        ("Loss-Controlled", "aux", 0),
        ("Loss-Free", "lossfree", 0),
        ("BIP", "bip", bip_t),
    ];
    let mut series = Vec::new();
    for (label, mode, t) in methods {
        let mut driver = TrainDriver::new(config, mode, t, bench.steps);
        driver.eval_batches = bench.eval_batches;
        let summary = run_or_load(&engine, &driver, reports)?;
        series.push((label.to_string(), summary.series("global")?));
    }

    // combined CSV: step, <method columns>
    let path = reports.join(format!("{fig}.csv"));
    let headers: Vec<&str> = std::iter::once("step")
        .chain(series.iter().map(|(l, _)| l.as_str()))
        .collect();
    let mut w = CsvWriter::create(&path, &headers)?;
    let steps = series.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
    for i in 0..steps {
        let mut row = vec![i.to_string()];
        for (_, s) in &series {
            row.push(
                s.get(i).map(|v| format!("{v:.6}")).unwrap_or_default());
        }
        w.row(row)?;
    }
    w.finish()?;

    println!(
        "\n=== {} — MaxVio_batch vs step ({config}) ===",
        fig.to_uppercase()
    );
    let plot_series: Vec<(&str, &[f32])> = series
        .iter()
        .map(|(l, s)| (l.as_str(), s.as_slice()))
        .collect();
    print!("{}", ascii_plot(&plot_series, 72, 16));
    println!("series csv: {}", path.display());

    // shape assertion the paper's figure makes visually: the BIP line sits
    // low and flat from the very first step
    let bip = &series[2].1;
    let aux = &series[0].1;
    let bip_max = bip.iter().cloned().fold(0.0f32, f32::max);
    let aux_early = aux.iter().take(10).cloned().fold(0.0f32, f32::max);
    println!(
        "shape: BIP max over run {:.3} vs Loss-Controlled early max {:.3} \
         (paper: red line flat near 0, blue line high/fluctuating)",
        bip_max, aux_early
    );
    Ok(())
}
