//! Reproduces **Table 4** and **Table 5**: per-layer AvgMaxVio on the
//! 16-expert (BIP T=4) and 64-expert (BIP T=14) models, for Auxiliary
//! Loss, Loss-Free and BIP.
//!
//! Reuses the cached runs from bench_table2/3 (same reports/ cache) and
//! prints the 8-layer rows with the paper's values in parens.

use std::path::Path;

use bip_moe::bench::experiments::{
    paper_table4, paper_table5, run_or_load,
};
use bip_moe::bench::BenchConfig;
use bip_moe::metrics::TablePrinter;
use bip_moe::runtime::Engine;
use bip_moe::train::TrainDriver;

fn main() {
    bip_moe::util::log::init_from_env();
    let bench = BenchConfig::from_env(80, 400);
    let t4 = paper_table4();
    let t5 = paper_table5();
    for (title, config, bip_t, paper) in [
        ("Table 4: per-layer AvgMaxVio (m=16, k=4)", "moe16-bench", 4,
         &t4),
        ("Table 5: per-layer AvgMaxVio (m=64, k=8)", "moe64-bench", 14,
         &t5),
    ] {
        if let Err(e) = run(&bench, title, config, bip_t, paper) {
            eprintln!("bench_table4_5: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run(
    bench: &BenchConfig,
    title: &str,
    config: &str,
    bip_t: usize,
    paper: &[(&str, [f64; 8])],
) -> anyhow::Result<()> {
    let engine = Engine::new(Path::new("artifacts"))?;
    let reports = Path::new("reports");
    let n_layers = engine.manifest().config(config)?.n_layers;

    let methods: [(&str, &str, usize); 3] = [
        ("Auxiliary Loss", "aux", 0),
        ("Loss Free", "lossfree", 0),
        (if bip_t == 4 { "BIP, T=4" } else { "BIP, T=14" }, "bip", bip_t),
    ];

    let mut headers = vec!["Algorithm".to_string()];
    for l in 1..=n_layers {
        headers.push(format!("Layer {l}"));
    }
    let headers_ref: Vec<&str> =
        headers.iter().map(|s| s.as_str()).collect();
    let mut table = TablePrinter::new(
        &format!("{title} — measured (paper)"),
        &headers_ref,
    );

    for ((label, mode, t), (plabel, pvals)) in
        methods.into_iter().zip(paper)
    {
        assert_eq!(&label, plabel);
        let mut driver = TrainDriver::new(config, mode, t, bench.steps);
        driver.eval_batches = bench.eval_batches;
        let summary = run_or_load(&engine, &driver, reports)?;
        let mut row = vec![label.to_string()];
        for l in 0..n_layers {
            row.push(format!(
                "{:.3} ({:.3})",
                summary.layer_avg.get(l).copied().unwrap_or(f64::NAN),
                pvals[l]
            ));
        }
        table.row(row);
    }
    table.print();
    println!(
        "shape: the BIP row should sit well below both baselines on EVERY \
         layer (the paper's per-layer claim).\n"
    );
    Ok(())
}
