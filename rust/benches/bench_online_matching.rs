//! Benchmarks for the §5 application: multi-slot online ad matching.
//!
//! Regenerates the discussion's comparisons: competitive ratio and load
//! violation of Algorithm 3 (exact heaps) vs Algorithm 4 (constant-space
//! histograms) vs greedy, and the state-size separation that motivates
//! Algorithm 4 (O(nk) vs O(mb) as the flow count grows).

use bip_moe::bench::Bencher;
use bip_moe::matching::simulator::{run_policy, MatchPolicy, Workload};
use bip_moe::metrics::TablePrinter;

fn main() {
    let quick = std::env::var("BIP_MOE_FULL").as_deref() != Ok("1");
    let flow_counts: &[usize] = if quick {
        &[1024, 4096]
    } else {
        &[1024, 4096, 16384, 65536]
    };

    let mut table = TablePrinter::new(
        "online multi-slot matching (32 ads, 2 slots/page)",
        &["flows", "policy", "CTR sum", "vs hindsight", "MaxVio",
          "state bytes"],
    );
    for &flows in flow_counts {
        let w = Workload::synthetic(flows, 32, 2, 42);
        for policy in [
            MatchPolicy::Greedy,
            MatchPolicy::Online { t_iters: 4 },
            MatchPolicy::Approx { t_iters: 4, buckets: 128 },
        ] {
            let r = run_policy(&w, policy);
            table.row(vec![
                flows.to_string(),
                r.policy.clone(),
                format!("{:.1}", r.objective),
                format!("{:.3}", r.competitive_ratio),
                format!("{:.3}", r.max_violation),
                r.state_bytes.to_string(),
            ]);
        }
    }
    table.print();
    println!(
        "shape: Online/Approx MaxVio far below Greedy at every scale; \
         Approx state stays CONSTANT in flows while Online grows until \
         its heaps fill (the §5.2 motivation).\n"
    );

    // bucket sweep: accuracy/space tradeoff of Algorithm 4
    let mut table = TablePrinter::new(
        "Algorithm 4 bucket sweep (4096 flows, 32 ads)",
        &["buckets", "vs hindsight", "MaxVio", "state bytes"],
    );
    let w = Workload::synthetic(4096, 32, 2, 43);
    for buckets in [8usize, 32, 128, 512] {
        let r = run_policy(&w, MatchPolicy::Approx { t_iters: 4, buckets });
        table.row(vec![
            buckets.to_string(),
            format!("{:.3}", r.competitive_ratio),
            format!("{:.3}", r.max_violation),
            r.state_bytes.to_string(),
        ]);
    }
    table.print();

    // throughput
    let mut b = Bencher::default();
    let w = Workload::synthetic(8192, 32, 2, 44);
    let mut online =
        bip_moe::bip::online::OnlineGate::new(32, 2, 512, 4);
    let mut i = 0usize;
    b.bench("Alg3 per-flow (32 ads)", || {
        online.route_token(w.row(i % w.n_flows));
        i += 1;
    });
    let mut approx =
        bip_moe::bip::approx::ApproxGate::new(32, 2, 512, 4, 128);
    let mut j = 0usize;
    b.bench("Alg4 per-flow (32 ads)", || {
        approx.route_token(w.row(j % w.n_flows));
        j += 1;
    });
}
