//! Reproduces **Figures 3-10** (16-expert, layers 1-8) and
//! **Figures 11-18** (64-expert, layers 1-8): per-layer MaxVio_batch vs
//! training step for the three methods.
//!
//! Reuses the cached Table 2/3 runs; emits one combined CSV per figure
//! under reports/figs3_18/ and ASCII-plots a sample layer per model.

use std::path::Path;

use bip_moe::bench::experiments::run_or_load;
use bip_moe::bench::BenchConfig;
use bip_moe::metrics::table::ascii_plot;
use bip_moe::runtime::Engine;
use bip_moe::train::TrainDriver;
use bip_moe::util::csv::CsvWriter;

fn main() {
    bip_moe::util::log::init_from_env();
    let bench = BenchConfig::from_env(80, 400);
    for (config, bip_t, first_fig) in
        [("moe16-bench", 4usize, 3usize), ("moe64-bench", 14, 11)]
    {
        if let Err(e) = run(&bench, config, bip_t, first_fig) {
            eprintln!("bench_figs3_18: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run(
    bench: &BenchConfig,
    config: &str,
    bip_t: usize,
    first_fig: usize,
) -> anyhow::Result<()> {
    let engine = Engine::new(Path::new("artifacts"))?;
    let reports = Path::new("reports");
    let n_layers = engine.manifest().config(config)?.n_layers;

    let methods: [(&str, &str, usize); 3] = [
        ("Loss-Controlled", "aux", 0),
        ("Loss-Free", "lossfree", 0),
        ("BIP", "bip", bip_t),
    ];
    let mut summaries = Vec::new();
    for (label, mode, t) in methods {
        let mut driver = TrainDriver::new(config, mode, t, bench.steps);
        driver.eval_batches = bench.eval_batches;
        summaries.push((label, run_or_load(&engine, &driver, reports)?));
    }

    let out_dir = reports.join("figs3_18");
    for layer in 0..n_layers {
        let fig_no = first_fig + layer;
        let mut series = Vec::new();
        for (label, summary) in &summaries {
            series.push((
                label.to_string(),
                summary.series(&format!("layer{}", layer + 1))?,
            ));
        }
        let path = out_dir.join(format!("fig{fig_no}_{config}_layer{}.csv",
                                        layer + 1));
        let headers: Vec<&str> = std::iter::once("step")
            .chain(series.iter().map(|(l, _)| l.as_str()))
            .collect();
        let mut w = CsvWriter::create(&path, &headers)?;
        let steps = series.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
        for i in 0..steps {
            let mut row = vec![i.to_string()];
            for (_, s) in &series {
                row.push(s.get(i).map(|v| format!("{v:.6}"))
                         .unwrap_or_default());
            }
            w.row(row)?;
        }
        w.finish()?;

        if layer == 0 {
            println!(
                "\n=== Figure {fig_no}: {config} layer 1, MaxVio vs step ==="
            );
            let plot: Vec<(&str, &[f32])> = series
                .iter()
                .map(|(l, s)| (l.as_str(), s.as_slice()))
                .collect();
            print!("{}", ascii_plot(&plot, 72, 14));
        }
    }
    println!(
        "figures {}-{} written under {}",
        first_fig,
        first_fig + n_layers - 1,
        out_dir.display()
    );

    // per-layer shape assertion: BIP below baselines on every layer's mean
    for layer in 0..n_layers {
        let mean = |s: &[f32]| {
            s.iter().map(|&x| x as f64).sum::<f64>() / s.len().max(1) as f64
        };
        let aux = mean(&summaries[0].1.series(
            &format!("layer{}", layer + 1))?);
        let bip = mean(&summaries[2].1.series(
            &format!("layer{}", layer + 1))?);
        if bip > aux {
            println!(
                "WARNING layer {}: BIP mean {bip:.3} above aux {aux:.3}",
                layer + 1
            );
        }
    }
    Ok(())
}
