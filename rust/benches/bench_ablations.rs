//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!   A. warm start — Algorithm 1 initializes q once per gate (line 2); how
//!      much does carrying q across batches buy at each T?
//!   B. T sweep — the balance/score-quality tradeoff behind Tables 2-3's
//!      T grid, isolated on the host solver (no LM in the loop).
//!   C. placement vs balancing — can load-aware expert placement (LPT)
//!      rescue an unbalanced router instead? (paper's implicit claim: no —
//!      balancing at the router dominates fixing it downstream.)
//!   D. capacity tightness — MaxVio of the dual heuristic as the capacity
//!      RHS is scaled, showing constraint (2) is what does the work.

use bip_moe::bip::dual::DualState;
use bip_moe::bip::{dual, greedy_topk, Instance};
use bip_moe::metrics::TablePrinter;
use bip_moe::parallel::placement::{greedy_placement, Placement};
use bip_moe::parallel::Mesh;
use bip_moe::util::rng::Pcg64;

fn batches(seed: u64, count: usize, n: usize, m: usize, k: usize,
           skew: f64) -> Vec<Instance> {
    let mut rng = Pcg64::new(seed);
    (0..count)
        .map(|_| Instance::synthetic(n, m, k, 2.0, skew, &mut rng))
        .collect()
}

fn main() {
    let (n, m, k) = (512usize, 16usize, 4usize);
    let insts = batches(7, 24, n, m, k, 3.0);

    // -- A: warm start ----------------------------------------------------
    let mut table = TablePrinter::new(
        "ablation A: warm-started q vs cold start (24 skewed batches)",
        &["T", "AvgMaxVio warm", "AvgMaxVio cold", "warm advantage"],
    );
    for t in [1usize, 2, 4, 8] {
        let mut warm_state = DualState::new(m);
        let mut warm = 0.0;
        let mut cold = 0.0;
        for inst in &insts {
            warm_state.update(inst, t);
            warm += warm_state.route(inst).max_violation(inst);
            cold += dual::solve(inst, t).0.max_violation(inst);
        }
        let (w, c) = (warm / insts.len() as f64, cold / insts.len() as f64);
        table.row(vec![
            t.to_string(),
            format!("{w:.4}"),
            format!("{c:.4}"),
            format!("{:+.1}%", (c - w) / c * 100.0),
        ]);
    }
    table.print();

    // -- B: T sweep (balance vs score quality) ----------------------------
    let mut table = TablePrinter::new(
        "ablation B: dual iterations T — balance vs routed score",
        &["T", "AvgMaxVio", "score kept vs greedy", "solver µs/batch"],
    );
    let greedy_obj: f64 = insts
        .iter()
        .map(|i| greedy_topk(i).objective(i))
        .sum();
    for t in [0usize, 1, 2, 4, 8, 14, 28] {
        let t0 = std::time::Instant::now();
        let mut vio = 0.0;
        let mut obj = 0.0;
        for inst in &insts {
            let routing = if t == 0 {
                greedy_topk(inst)
            } else {
                dual::solve(inst, t).0
            };
            vio += routing.max_violation(inst);
            obj += routing.objective(inst);
        }
        table.row(vec![
            t.to_string(),
            format!("{:.4}", vio / insts.len() as f64),
            format!("{:.1}%", obj / greedy_obj * 100.0),
            format!("{:.0}", t0.elapsed().as_secs_f64() * 1e6
                    / insts.len() as f64),
        ]);
    }
    table.print();

    // -- C: placement vs balancing -----------------------------------------
    let mut table = TablePrinter::new(
        "ablation C: fix imbalance downstream (LPT placement) vs at the \
         router (BIP)",
        &["router", "placement", "device imbalance (max/mean)"],
    );
    let mesh = Mesh::new(4, m);
    for (router, routing) in [
        ("greedy", greedy_topk(&insts[0])),
        ("BIP T=4", dual::solve(&insts[0], 4).0),
    ] {
        let loads: Vec<f32> = routing
            .loads(m)
            .into_iter()
            .map(|x| x as f32)
            .collect();
        for (pname, placement) in [
            ("block", Placement::block(&mesh)),
            ("LPT", greedy_placement(&loads, 4, Some(m / 4))),
        ] {
            table.row(vec![
                router.to_string(),
                pname.to_string(),
                format!("{:.4}", placement.imbalance(&loads)),
            ]);
        }
    }
    table.print();
    println!(
        "shape: LPT helps the greedy router but cannot reach BIP+any \
         placement — balancing at the router dominates.\n"
    );

    // -- D: capacity tightness ---------------------------------------------
    let mut table = TablePrinter::new(
        "ablation D: capacity RHS scale  (cap = s * nk/m)",
        &["cap scale", "AvgMaxVio", "score kept vs greedy"],
    );
    for scale in [0.5f64, 0.75, 1.0, 1.5, 2.0, 8.0] {
        let mut vio = 0.0;
        let mut obj = 0.0;
        for inst in &insts {
            let mut relaxed = inst.clone();
            relaxed.cap = ((inst.cap as f64 * scale) as usize).max(1);
            let routing = dual::solve(&relaxed, 4).0;
            vio += routing.max_violation(inst);
            obj += routing.objective(inst);
        }
        table.row(vec![
            format!("{scale:.2}"),
            format!("{:.4}", vio / insts.len() as f64),
            format!("{:.1}%", obj / greedy_obj * 100.0),
        ]);
    }
    table.print();
    println!(
        "shape: at scale >= ~8 the duals never bind and routing degrades \
         to greedy; at 1.0 (the paper's setting) balance is enforced at \
         a few percent score cost."
    );
}
