//! Serving-stack benchmarks.
//!
//! Two measurements back the serving claims:
//!   * the router hot path — `route_batch` cost per policy at the
//!     default gate size (the per-micro-batch overhead a real deployment
//!     would pay on the critical path);
//!   * the end-to-end sweep — every scenario x policy through the full
//!     traffic -> admission -> micro-batch -> router -> SLO pipeline,
//!     reporting throughput, p99 and balance.
//!
//! Results land in reports/BENCH_serving.json (see
//! `bip_moe::bench::write_bench_json`) so the perf trajectory is tracked
//! across PRs. BIP_MOE_FULL=1 runs the full-scale sweep.

use bip_moe::bench::{write_bench_json, Bencher};
use bip_moe::metrics::TablePrinter;
use bip_moe::serve::{
    run_scenario, Policy, Request, RouterConfig, SchedulerConfig,
    Scenario, ServeConfig, ServeReport, ServingRouter, TrafficConfig,
    TrafficGenerator,
};
use bip_moe::util::json::Json;

fn batch_of(scenario: Scenario, n: usize, seed: u64) -> Vec<Request> {
    TrafficGenerator::new(TrafficConfig {
        scenario,
        n_requests: n,
        seed,
        ..Default::default()
    })
    .collect()
}

fn main() {
    let full = std::env::var("BIP_MOE_FULL").as_deref() == Ok("1");
    let n_requests = if full { 65_536 } else { 8_192 };
    let mut json_results = Vec::new();

    println!("== route_batch hot path (batch=64, m=16, k=4, L=4) ==");
    let mut b = Bencher::default();
    let batch = batch_of(Scenario::Steady, 64, 13);
    for policy in Policy::all() {
        let mut router = ServingRouter::new(
            policy,
            RouterConfig { expected_stream: 1 << 20, ..Default::default() },
        );
        b.bench(&format!("route_batch {}", policy.name()), || {
            router.route_batch(&batch);
        });
    }
    json_results.push(Json::obj(vec![(
        "route_batch_us",
        Json::Arr(b.results.iter().map(|m| m.to_json()).collect()),
    )]));

    println!("\n== end-to-end scenario sweep ({n_requests} requests) ==");
    let mut sweep_rows = Vec::new();
    for scenario in Scenario::all() {
        let mut table = TablePrinter::new(
            &format!("serving {}", scenario.name()),
            ServeReport::headers(),
        );
        for policy in Policy::all() {
            let cfg = ServeConfig::new(
                TrafficConfig {
                    scenario,
                    n_requests,
                    seed: 2,
                    ..Default::default()
                },
                SchedulerConfig::default(),
                RouterConfig::default(),
                policy,
            );
            let t0 = std::time::Instant::now();
            let outcome = run_scenario(&cfg);
            let wall_s = t0.elapsed().as_secs_f64();
            table.row(outcome.report.table_row());
            let mut row = outcome.report.to_json();
            if let Json::Obj(map) = &mut row {
                map.insert("wall_s".into(), Json::Num(wall_s));
                map.insert(
                    "sim_rps".into(),
                    Json::Num(outcome.report.completed as f64 / wall_s),
                );
            }
            sweep_rows.push(row);
        }
        table.print();
    }
    json_results.push(Json::obj(vec![("sweep", Json::Arr(sweep_rows))]));

    match write_bench_json("serving", Json::Arr(json_results)) {
        Ok(path) => println!("perf record: {}", path.display()),
        Err(e) => eprintln!("warning: BENCH_serving.json not written: {e}"),
    }
}
