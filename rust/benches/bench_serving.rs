//! Serving-stack benchmarks.
//!
//! Two measurements back the serving claims:
//!   * the router hot path — `route_batch` cost per policy at the
//!     default gate size (the per-micro-batch overhead a real deployment
//!     would pay on the critical path);
//!   * the end-to-end sweep — every scenario x policy through the full
//!     traffic -> admission -> micro-batch -> router -> SLO pipeline,
//!     reporting throughput, p99 and balance.
//!
//! Results land in reports/BENCH_serving.json (see
//! `bip_moe::bench::write_bench_json`) so the perf trajectory is tracked
//! across PRs — and gated: before overwriting the record, the previous
//! run's replica-sweep batches/vsec rows are loaded and compared; a
//! geomean throughput ratio below 0.90 fails the bench (the CI perf
//! gate) unless the baseline is the committed seed placeholder
//! (`"seeded_placeholder": true`, warn-only) or BIP_MOE_PERF_GATE is
//! set to off|warn. BIP_MOE_FULL=1 runs the full-scale sweep.

use std::collections::BTreeMap;

use bip_moe::bench::{write_bench_json, Bencher};
use bip_moe::metrics::TablePrinter;
use bip_moe::prof;
use bip_moe::serve::{
    run_replicated, run_scenario, Policy, ReplicaConfig, Request,
    RouterConfig, SchedulerConfig, Scenario, ServeConfig, ServeReport,
    ServingRouter, TrafficConfig, TrafficGenerator,
};
use bip_moe::util::json::Json;

fn batch_of(scenario: Scenario, n: usize, seed: u64) -> Vec<Request> {
    TrafficGenerator::new(TrafficConfig {
        scenario,
        n_requests: n,
        seed,
        ..Default::default()
    })
    .collect()
}

/// The previous BENCH_serving.json's replica-sweep batches/vsec per
/// row (keyed `"<policy> R=<replicas>"`), read BEFORE this run
/// overwrites the record, plus whether that baseline is the committed
/// seed placeholder (warn-only for the perf gate).
fn load_prev_baseline() -> Option<(BTreeMap<String, f64>, bool)> {
    let dir = std::env::var("BIP_MOE_REPORTS")
        .unwrap_or_else(|_| "reports".into());
    let path = std::path::Path::new(&dir).join("BENCH_serving.json");
    let body = std::fs::read_to_string(&path).ok()?;
    let doc = Json::parse(&body).ok()?;
    let placeholder = doc
        .path("seeded_placeholder")
        .and_then(|j| j.as_bool())
        .unwrap_or(false);
    let mut rows = BTreeMap::new();
    if let Some(sections) = doc.path("results").and_then(|j| j.as_arr())
    {
        for sec in sections {
            let Some(sweep) =
                sec.path("replica_sweep").and_then(|j| j.as_arr())
            else {
                continue;
            };
            for row in sweep {
                let (Some(policy), Some(r), Some(bvs)) = (
                    row.path("policy").and_then(|j| j.as_str()),
                    row.path("replicas").and_then(|j| j.as_f64()),
                    row.path("batches_per_vsec").and_then(|j| j.as_f64()),
                ) else {
                    continue;
                };
                if bvs > 0.0 {
                    rows.insert(format!("{policy} R={r}"), bvs);
                }
            }
        }
    }
    Some((rows, placeholder))
}

/// Compare this run's replica-sweep throughput against the previous
/// record; returns the regression JSON section and whether the gate
/// failed hard.
fn regression_gate(
    prev: &Option<(BTreeMap<String, f64>, bool)>,
    cur: &[(String, f64)],
    bench: &str,
) -> (Option<Json>, bool) {
    let gate_env =
        std::env::var("BIP_MOE_PERF_GATE").unwrap_or_default();
    match prev {
        None => {
            println!(
                "\nno previous {bench} record — recording the first \
                 baseline"
            );
            (None, false)
        }
        Some(_) if gate_env == "off" => {
            println!(
                "\nperf gate: BIP_MOE_PERF_GATE=off — regression \
                 check skipped"
            );
            (None, false)
        }
        Some((prev_rows, placeholder)) => {
            let mut dt = TablePrinter::new(
                &format!("throughput vs previous {bench} record"),
                &["Row", "Previous", "Current", "Delta"],
            );
            let mut ratio_product = 1.0f64;
            let mut matched = 0u32;
            for (key, cur_v) in cur {
                let Some(prev_v) = prev_rows.get(key) else {
                    continue;
                };
                let ratio = cur_v / prev_v;
                ratio_product *= ratio;
                matched += 1;
                dt.row(vec![
                    key.clone(),
                    format!("{prev_v:.2}"),
                    format!("{cur_v:.2}"),
                    format!("{:+.1}%", (ratio - 1.0) * 100.0),
                ]);
            }
            if matched == 0 {
                println!(
                    "\nprevious {bench} record has no comparable \
                     rows{} — gate skipped",
                    if *placeholder {
                        " (seeded placeholder)"
                    } else {
                        ""
                    }
                );
                return (None, false);
            }
            println!();
            dt.print();
            let geomean = ratio_product.powf(1.0 / matched as f64);
            println!(
                "  geomean throughput ratio: {geomean:.3} over \
                 {matched} row(s) (gate fails below 0.90)"
            );
            let section = Json::obj(vec![(
                "regression",
                Json::obj(vec![
                    ("geomean_ratio", Json::Num(geomean)),
                    ("rows_compared", Json::Num(matched as f64)),
                    ("gate_threshold", Json::Num(0.90)),
                    ("baseline_placeholder", Json::Bool(*placeholder)),
                ]),
            )]);
            let mut failed = false;
            if geomean < 0.90 {
                if *placeholder {
                    eprintln!(
                        "perf gate WARNING: geomean {geomean:.3} < \
                         0.90 vs the seeded placeholder baseline — \
                         not failing"
                    );
                } else if gate_env == "warn" {
                    eprintln!(
                        "perf gate WARNING: geomean {geomean:.3} < \
                         0.90 (BIP_MOE_PERF_GATE=warn — not failing)"
                    );
                } else {
                    eprintln!(
                        "perf gate FAILED: geomean ratio \
                         {geomean:.3} < 0.90 vs the previous record"
                    );
                    failed = true;
                }
            }
            (Some(section), failed)
        }
    }
}

fn main() {
    let full = std::env::var("BIP_MOE_FULL").as_deref() == Ok("1");
    let n_requests = if full { 65_536 } else { 8_192 };
    // read the previous record before anything overwrites it
    let prev = load_prev_baseline();
    let prev_prof = prof::load_prev_prof("serving");
    prof::reset();
    let mut json_results = Vec::new();

    println!("== route_batch hot path (batch=64, m=16, k=4, L=4) ==");
    let mut b = Bencher::default();
    let batch = batch_of(Scenario::Steady, 64, 13);
    for policy in Policy::all() {
        let mut router = ServingRouter::new(
            policy,
            RouterConfig { expected_stream: 1 << 20, ..Default::default() },
        );
        b.bench(&format!("route_batch {}", policy.name()), || {
            router.route_batch(&batch);
        });
    }
    json_results.push(Json::obj(vec![(
        "route_batch_us",
        Json::Arr(b.results.iter().map(|m| m.to_json()).collect()),
    )]));

    println!("\n== end-to-end scenario sweep ({n_requests} requests) ==");
    let mut sweep_rows = Vec::new();
    for scenario in Scenario::all() {
        let mut table = TablePrinter::new(
            &format!("serving {}", scenario.name()),
            ServeReport::headers(),
        );
        for policy in Policy::all() {
            let cfg = ServeConfig::new(
                TrafficConfig {
                    scenario,
                    n_requests,
                    seed: 2,
                    ..Default::default()
                },
                SchedulerConfig::default(),
                RouterConfig::default(),
                policy,
            );
            let t0 = std::time::Instant::now();
            let outcome = run_scenario(&cfg);
            let wall_s = t0.elapsed().as_secs_f64();
            table.row(outcome.report.table_row());
            let mut row = outcome.report.to_json();
            if let Json::Obj(map) = &mut row {
                map.insert("wall_s".into(), Json::Num(wall_s));
                map.insert(
                    "sim_rps".into(),
                    Json::Num(outcome.report.completed as f64 / wall_s),
                );
            }
            sweep_rows.push(row);
        }
        table.print();
    }
    json_results.push(Json::obj(vec![("sweep", Json::Arr(sweep_rows))]));

    // Replica scaling: R routers behind one queue on a 4-thread pool,
    // bursty traffic offered well above one server's service rate so
    // the set — not the arrival process — is the bottleneck. The
    // virtual-time micro-batches/sec must scale with R (the acceptance
    // bar: R=4 >= 2x R=1) while the policy ordering
    // (bip-* < lossfree < greedy on MaxVio) holds at every R.
    println!("\n== replica scaling sweep (bursty, saturating load) ==");
    // longer stream than the SLO sweep: under saturation the routed
    // batch count scales with the arrival window, and the policy
    // ordering needs enough batches per replica to be stable
    let sweep_requests = if full { 65_536 } else { 16_384 };
    let mut replica_rows = Vec::new();
    let mut cur_bvs: Vec<(String, f64)> = Vec::new();
    for &r in &[1usize, 2, 4] {
        let mut table = TablePrinter::new(
            &format!("replicas={r} threads=4 sync_every=8"),
            &["Policy", "Batches", "Batches/vs", "Done", "AvgMaxVio",
              "SupMaxVio", "Syncs", "Wall_s"],
        );
        for policy in Policy::all() {
            let cfg = ServeConfig::new(
                TrafficConfig {
                    scenario: Scenario::Bursty,
                    n_requests: sweep_requests,
                    rate_per_s: 2_000_000.0,
                    seed: 2,
                    slo_us: 500_000,
                    ..Default::default()
                },
                SchedulerConfig::default(),
                RouterConfig::default(),
                policy,
            );
            let rcfg = ReplicaConfig {
                replicas: r,
                threads: 4,
                sync_every: 8,
            };
            let t0 = std::time::Instant::now();
            let out = run_replicated(&cfg, &rcfg);
            let wall_s = t0.elapsed().as_secs_f64();
            let batches_per_vs = if out.report.horizon_s > 0.0 {
                out.batches as f64 / out.report.horizon_s
            } else {
                0.0
            };
            cur_bvs.push((
                format!("{} R={r}", out.report.policy),
                batches_per_vs,
            ));
            table.row(vec![
                out.report.policy.clone(),
                format!("{}", out.batches),
                format!("{batches_per_vs:.0}"),
                format!("{}", out.report.completed),
                format!("{:.4}", out.report.avg_max_vio),
                format!("{:.4}", out.report.sup_max_vio),
                format!("{}", out.syncs.len()),
                format!("{wall_s:.2}"),
            ]);
            replica_rows.push(Json::obj(vec![
                ("replicas", Json::Num(r as f64)),
                ("threads", Json::Num(4.0)),
                ("sync_every", Json::Num(8.0)),
                ("policy", Json::Str(out.report.policy.clone())),
                ("scenario", Json::Str("bursty".into())),
                ("batches", Json::Num(out.batches as f64)),
                ("batches_per_vsec", Json::Num(batches_per_vs)),
                ("completed", Json::Num(out.report.completed as f64)),
                ("avg_max_vio", Json::Num(out.report.avg_max_vio)),
                ("sup_max_vio", Json::Num(out.report.sup_max_vio)),
                ("overflow", Json::Num(out.report.overflow as f64)),
                ("horizon_s", Json::Num(out.report.horizon_s)),
                ("syncs", Json::Num(out.syncs.len() as f64)),
                (
                    "sync_div_before_last",
                    Json::Num(
                        out.syncs
                            .last()
                            .map_or(0.0, |s| s.state_div_before),
                    ),
                ),
                ("wall_s", Json::Num(wall_s)),
            ]));
        }
        table.print();
    }
    json_results.push(Json::obj(vec![(
        "replica_sweep",
        Json::Arr(replica_rows),
    )]));

    let (section, regression_failed) =
        regression_gate(&prev, &cur_bvs, "BENCH_serving.json");
    if let Some(s) = section {
        json_results.push(s);
    }

    match write_bench_json("serving", Json::Arr(json_results)) {
        Ok(path) => println!("perf record: {}", path.display()),
        Err(e) => eprintln!("warning: BENCH_serving.json not written: {e}"),
    }
    // the run's call-path profile rides along with the report so a
    // failed gate attributes the loss to a phase, not just a row
    let cur_prof = prof::Profile::scrape();
    match prof::write_prof_json("serving", &cur_prof) {
        Ok(path) => println!("profile: {}", path.display()),
        Err(e) => {
            eprintln!("warning: PROF_serving.json not written: {e}")
        }
    }

    if regression_failed {
        eprintln!(
            "bench_serving FAILED: replica-sweep throughput regressed \
             past the 10% geomean gate"
        );
        if let Some(pp) = &prev_prof {
            let top = prof::top_regressions(pp, &cur_prof, 5);
            if !top.is_empty() {
                eprint!(
                    "{}",
                    prof::render_table(
                        "top regressed call paths vs previous \
                         PROF_serving.json",
                        &top,
                    )
                    .render()
                );
            }
        }
        std::process::exit(1);
    }
}
