//! Solver micro-benchmarks backing the paper's "very small time costs"
//! claim (§3) and the §5 complexity discussion:
//!
//!   * Algorithm 1 dual update: cost vs (n, m, T) — should be linear in
//!     each and microseconds at gate sizes;
//!   * per-token cost of Algorithm 3 (heaps) vs Algorithm 4 (histograms);
//!   * exact min-cost-flow for reference (orders of magnitude slower);
//!   * optimality gap of the dual heuristic vs the exact optimum.

use bip_moe::bench::{write_bench_json, Bencher};
use bip_moe::util::json::Json;
use bip_moe::bip::approx::ApproxGate;
use bip_moe::bip::dual::DualState;
use bip_moe::bip::flow::solve_exact;
use bip_moe::bip::online::OnlineGate;
use bip_moe::bip::{dual, greedy_topk, Instance};
use bip_moe::metrics::TablePrinter;
use bip_moe::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::default();
    println!("== Algorithm 1 dual update: T iterations over (n x m) ==");
    for (n, m, k) in [(512usize, 16usize, 4usize), (1024, 16, 4),
                      (1024, 64, 8), (4096, 64, 8)] {
        let mut rng = Pcg64::new(7);
        let inst = Instance::synthetic(n, m, k, 2.0, 2.0, &mut rng);
        for t in [2usize, 4, 8, 14] {
            let mut state = DualState::new(m);
            b.bench(&format!("dual n={n} m={m} T={t}"), || {
                state.update(&inst, t);
            });
        }
    }

    println!("\n== per-token online variants (m=64, k=8) ==");
    {
        let mut rng = Pcg64::new(9);
        let inst = Instance::synthetic(4096, 64, 8, 2.0, 2.0, &mut rng);
        let mut online = OnlineGate::new(64, 8, 512, 4);
        let mut i = 0usize;
        b.bench("Alg3 online route_token (T=4)", || {
            online.route_token(inst.row(i % inst.n));
            i += 1;
        });
        let mut approx = ApproxGate::new(64, 8, 512, 4, 128);
        let mut j = 0usize;
        b.bench("Alg4 approx route_token (T=4,b=128)", || {
            approx.route_token(inst.row(j % inst.n));
            j += 1;
        });
    }

    println!("\n== exact min-cost-flow reference ==");
    {
        let mut rng = Pcg64::new(11);
        let inst = Instance::synthetic(128, 16, 4, 2.0, 2.0, &mut rng);
        b.bench("exact flow n=128 m=16", || {
            let _ = solve_exact(&inst);
        });
    }

    // optimality-gap table: dual vs exact across skews
    println!();
    let mut table = TablePrinter::new(
        "dual-ascent optimality gap vs exact (n=96, m=8, k=2)",
        &["skew", "greedy obj", "dual obj (T=8)", "exact obj",
          "dual/exact", "dual MaxVio", "exact MaxVio"],
    );
    for skew in [0.0f64, 1.0, 2.0, 4.0] {
        let mut rng = Pcg64::new(13);
        let inst = Instance::synthetic(96, 8, 2, 2.0, skew, &mut rng);
        let greedy = greedy_topk(&inst);
        let (routing, _) = dual::solve(&inst, 8);
        let (exact, exact_obj) = solve_exact(&inst);
        table.row(vec![
            format!("{skew:.1}"),
            format!("{:.4}", greedy.objective(&inst)),
            format!("{:.4}", routing.objective(&inst)),
            format!("{exact_obj:.4}"),
            format!("{:.4}", routing.objective(&inst) / exact_obj),
            format!("{:.4}", routing.max_violation(&inst)),
            format!("{:.4}", exact.max_violation(&inst)),
        ]);
    }
    table.print();

    // the §3 time-cost claim in context: dual cost as a fraction of one
    // simulated training step at gate size
    let mut rng = Pcg64::new(17);
    let inst = Instance::synthetic(1024, 64, 8, 2.0, 2.0, &mut rng);
    let mut state = DualState::new(64);
    let m = b.bench("dual n=1024 m=64 T=14 (paper gate size)", || {
        state.update(&inst, 14);
    });
    println!(
        "\nsolver cost per gate: {:.1} µs — vs ~O(100ms) GPU step times, \
         i.e. ~1% overhead at T=14 (µs-scale at the 16-expert gate) ('very small time costs', §3)",
        m.secs_per_iter.mean * 1e6
    );

    // machine-readable perf record for cross-PR tracking
    let rows: Vec<Json> = b.results.iter().map(|m| m.to_json()).collect();
    match write_bench_json("solver", Json::Arr(rows)) {
        Ok(path) => println!("perf record: {}", path.display()),
        Err(e) => eprintln!("warning: BENCH_solver.json not written: {e}"),
    }
}
