//! Forecast-subsystem benchmarks — the evidence behind the forecast/
//! claims, written to reports/BENCH_forecast.json:
//!
//!   * forecast error by horizon: every forecaster kind, walk-forward
//!     on a held-out suffix of a recorded greedy (demand) trace, per
//!     scenario, against the naive last-value baseline;
//!   * warm vs cold first-batch MaxVio: `routing::PredictiveBip`
//!     seeded from the fitted forecast vs cold-start `routing::Bip` on
//!     the first micro-batch of the same stream, swept over the dual
//!     iteration count T (the acceptance bar: warm strictly below cold
//!     on >= 3 of the 5 scenarios at equal-or-lower T), plus the
//!     dual-iteration savings at equal MaxVio;
//!   * serve-level warm start: full cold vs warm runs (first-batch
//!     MaxVio, AvgMaxVio, p99);
//!   * predictive vs reactive autoscaling on bursty overload: SLO
//!     deltas and hindsight-oracle match rates.
//!
//! BIP_MOE_FULL=1 runs the full-scale sweep.
//!
//! The record is regression-gated: before overwriting
//! reports/BENCH_forecast.json, the previous run's per-(scenario,
//! kind, horizon) MAE rows are loaded and compared; a geomean
//! accuracy ratio (previous MAE / current MAE) below 0.90 fails the
//! bench unless the baseline is the committed seed placeholder
//! (`"seeded_placeholder": true`, warn-only) or BIP_MOE_PERF_GATE is
//! set to off|warn.

use std::collections::BTreeMap;

use bip_moe::bench::write_bench_json;
use bip_moe::bip::Instance;
use bip_moe::forecast::{
    dual_seed, fit_model, seed_states, AutoScaler, ForecastConfig,
    ForecasterKind, LoadSeries, ScalePolicy, DEFAULT_SEED_GAIN,
};
use bip_moe::metrics::TablePrinter;
use bip_moe::prof;
use bip_moe::routing::{Bip, PredictiveBip, RoutingStrategy};
use bip_moe::serve::{
    run_autoscaled, run_scenario, run_scenario_seeded, run_scenario_with,
    Policy, ReplicaConfig, Request, RouterConfig, SchedulerConfig,
    Scenario, ServeConfig, TrafficConfig, TrafficGenerator,
};
use bip_moe::trace::{Trace, TraceRecorder};
use bip_moe::util::json::Json;

const TRAFFIC_SEED: u64 = 7;
const T_SWEEP: [usize; 4] = [0, 1, 2, 4];

fn serve_cfg(
    scenario: Scenario,
    policy: Policy,
    n_requests: usize,
) -> ServeConfig {
    ServeConfig::new(
        TrafficConfig {
            scenario,
            n_requests,
            seed: TRAFFIC_SEED,
            ..Default::default()
        },
        SchedulerConfig::default(),
        RouterConfig::default(),
        policy,
    )
}

/// Record the *demand* trace: greedy routing exposes the raw skew the
/// duals must counter (a BIP trace is already balanced — nothing to
/// learn from).
fn record_demand_trace(scenario: Scenario, n_requests: usize) -> Trace {
    let cfg = serve_cfg(scenario, Policy::Greedy, n_requests);
    let mut rec = TraceRecorder::new(&cfg, &ReplicaConfig::default());
    run_scenario_with(
        &cfg,
        TrafficGenerator::new(cfg.traffic.clone()),
        Some(&mut rec),
    );
    rec.into_trace()
}

/// One layer of the stream's first `n` requests as a solver instance
/// with the paper's capacity n*k/m (strategy-level: no serving cap
/// enforcement, so the warm/cold contrast is not clipped).
fn layer_instance(
    reqs: &[Request],
    l: usize,
    m: usize,
    k: usize,
) -> Instance {
    let n = reqs.len();
    let mut scores = Vec::with_capacity(n * m);
    for r in reqs {
        scores.extend_from_slice(r.layer_scores(l, m));
    }
    Instance { n, m, k, cap: (n * k / m).max(1), scores }
}

/// The previous BENCH_forecast.json's MAE per (scenario, kind,
/// horizon) row, read BEFORE this run overwrites the record, plus
/// whether that baseline is the committed seed placeholder.
fn load_prev_baseline() -> Option<(BTreeMap<String, f64>, bool)> {
    let dir = std::env::var("BIP_MOE_REPORTS")
        .unwrap_or_else(|_| "reports".into());
    let path = std::path::Path::new(&dir).join("BENCH_forecast.json");
    let body = std::fs::read_to_string(&path).ok()?;
    let doc = Json::parse(&body).ok()?;
    let placeholder = doc
        .path("seeded_placeholder")
        .and_then(|j| j.as_bool())
        .unwrap_or(false);
    let mut rows = BTreeMap::new();
    if let Some(sections) = doc.path("results").and_then(|j| j.as_arr())
    {
        for sec in sections {
            let Some(errs) =
                sec.path("forecast_error").and_then(|j| j.as_arr())
            else {
                continue;
            };
            for row in errs {
                let (Some(sc), Some(kind), Some(h), Some(mae)) = (
                    row.path("scenario").and_then(|j| j.as_str()),
                    row.path("kind").and_then(|j| j.as_str()),
                    row.path("horizon").and_then(|j| j.as_f64()),
                    row.path("mae").and_then(|j| j.as_f64()),
                ) else {
                    continue;
                };
                rows.insert(format!("{sc} {kind} h={h}"), mae);
            }
        }
    }
    Some((rows, placeholder))
}

/// Accuracy gate: geomean of (previous MAE / current MAE) over the
/// matching rows — below 0.90 means forecasts got ~11% worse. Returns
/// the regression JSON section and whether the gate failed hard.
fn regression_gate(
    prev: &Option<(BTreeMap<String, f64>, bool)>,
    cur: &[(String, f64)],
) -> (Option<Json>, bool) {
    let gate_env =
        std::env::var("BIP_MOE_PERF_GATE").unwrap_or_default();
    match prev {
        None => {
            println!(
                "no previous BENCH_forecast.json — recording the \
                 first baseline"
            );
            (None, false)
        }
        Some(_) if gate_env == "off" => {
            println!(
                "accuracy gate: BIP_MOE_PERF_GATE=off — regression \
                 check skipped"
            );
            (None, false)
        }
        Some((prev_rows, placeholder)) => {
            // denominator floor keeps near-zero MAEs from exploding
            // the ratio either way
            const EPS: f64 = 1e-6;
            let mut ratio_product = 1.0f64;
            let mut matched = 0u32;
            let mut worst: Option<(String, f64)> = None;
            for (key, cur_v) in cur {
                let Some(prev_v) = prev_rows.get(key) else {
                    continue;
                };
                let ratio = (prev_v + EPS) / (cur_v + EPS);
                ratio_product *= ratio;
                matched += 1;
                if worst.as_ref().map_or(true, |(_, w)| ratio < *w) {
                    worst = Some((key.clone(), ratio));
                }
            }
            if matched == 0 {
                println!(
                    "previous BENCH_forecast.json has no comparable \
                     MAE rows{} — gate skipped",
                    if *placeholder {
                        " (seeded placeholder)"
                    } else {
                        ""
                    }
                );
                return (None, false);
            }
            let geomean = ratio_product.powf(1.0 / matched as f64);
            println!(
                "accuracy vs previous BENCH_forecast.json: geomean \
                 prev/cur MAE ratio {geomean:.3} over {matched} \
                 row(s) (gate fails below 0.90)"
            );
            if let Some((key, ratio)) = &worst {
                println!("  worst row: {key} at {ratio:.3}");
            }
            let section = Json::obj(vec![(
                "regression",
                Json::obj(vec![
                    ("geomean_ratio", Json::Num(geomean)),
                    ("rows_compared", Json::Num(matched as f64)),
                    ("gate_threshold", Json::Num(0.90)),
                    ("baseline_placeholder", Json::Bool(*placeholder)),
                ]),
            )]);
            let mut failed = false;
            if geomean < 0.90 {
                if *placeholder {
                    eprintln!(
                        "accuracy gate WARNING: geomean {geomean:.3} \
                         < 0.90 vs the seeded placeholder baseline — \
                         not failing"
                    );
                } else if gate_env == "warn" {
                    eprintln!(
                        "accuracy gate WARNING: geomean {geomean:.3} \
                         < 0.90 (BIP_MOE_PERF_GATE=warn — not \
                         failing)"
                    );
                } else {
                    eprintln!(
                        "accuracy gate FAILED: geomean prev/cur MAE \
                         ratio {geomean:.3} < 0.90 vs the previous \
                         record"
                    );
                    failed = true;
                }
            }
            (Some(section), failed)
        }
    }
}

fn main() {
    let full = std::env::var("BIP_MOE_FULL").as_deref() == Ok("1");
    let n_requests = if full { 16_384 } else { 4_096 };
    let horizons = [1usize, 4, 16];
    let (m, k, n_layers) = (16usize, 4usize, 4usize);
    // read the previous record before anything overwrites it
    let prev = load_prev_baseline();
    let prev_prof = prof::load_prev_prof("forecast");
    prof::reset();
    let mut json_results = Vec::new();

    // ---- forecast error by horizon + warm-start sweep, per scenario --
    let mut err_rows = Vec::new();
    let mut cur_mae: Vec<(String, f64)> = Vec::new();
    let mut warm_rows = Vec::new();
    let mut wins_by_t = vec![0usize; T_SWEEP.len()];
    for scenario in Scenario::all() {
        let trace = record_demand_trace(scenario, n_requests);
        let series = LoadSeries::from_trace(&trace).expect("series");

        let mut table = TablePrinter::new(
            &format!(
                "forecast error — {} ({} steps, holdout 25%)",
                scenario.name(),
                series.steps()
            ),
            bip_moe::forecast::FitReport::headers(),
        );
        for kind in ForecasterKind::all() {
            let (_, report) = fit_model(
                kind,
                &ForecastConfig::default(),
                &series,
                &horizons,
                0.25,
            )
            .expect("fit");
            for row in report.table_rows() {
                table.row(row);
            }
            for h in &report.by_horizon {
                cur_mae.push((
                    format!(
                        "{} {} h={}",
                        scenario.name(),
                        kind.name(),
                        h.horizon
                    ),
                    h.mae,
                ));
                err_rows.push(Json::obj(vec![
                    ("scenario", Json::Str(scenario.name().into())),
                    ("kind", Json::Str(kind.name().into())),
                    ("horizon", Json::Num(h.horizon as f64)),
                    ("mae", Json::Num(h.mae)),
                    ("naive_mae", Json::Num(h.naive_mae)),
                    ("samples", Json::Num(h.samples as f64)),
                ]));
            }
        }
        table.print();

        // warm vs cold first batch, strategy level: the fitted EWMA's
        // one-step forecast seeds each layer's duals
        let (model, _) = fit_model(
            ForecasterKind::Ewma,
            &ForecastConfig::default(),
            &series,
            &[1],
            0.25,
        )
        .expect("fit ewma");
        let first: Vec<Request> =
            TrafficGenerator::new(TrafficConfig {
                scenario,
                n_requests,
                seed: TRAFFIC_SEED,
                ..Default::default()
            })
            .take(256)
            .collect();
        let mut sweep = Vec::new();
        for (ti, &t) in T_SWEEP.iter().enumerate() {
            let (mut cold_sum, mut warm_sum) = (0.0f64, 0.0f64);
            for l in 0..n_layers {
                let inst = layer_instance(&first, l, m, k);
                let seed = dual_seed(
                    &model.layer_forecast(l, 1),
                    k,
                    DEFAULT_SEED_GAIN,
                );
                let mut cold = Bip::new(t);
                let mut warm = PredictiveBip::new(t, seed);
                cold_sum +=
                    cold.route_batch(&inst).max_violation(&inst);
                warm_sum +=
                    warm.route_batch(&inst).max_violation(&inst);
            }
            let (cold_vio, warm_vio) = (
                cold_sum / n_layers as f64,
                warm_sum / n_layers as f64,
            );
            if warm_vio < cold_vio {
                wins_by_t[ti] += 1;
            }
            sweep.push((t, cold_vio, warm_vio));
        }
        // dual-iteration savings: smallest warm T whose first-batch
        // MaxVio already matches what cold start needs T=4 for
        let cold_at_4 = sweep.last().unwrap().1;
        let t_equal = sweep
            .iter()
            .find(|&&(_, _, w)| w <= cold_at_4)
            .map(|&(t, _, _)| t)
            .unwrap_or(4);

        let mut table = TablePrinter::new(
            &format!(
                "warm vs cold first-batch MaxVio — {} (256 tokens, \
                 seed gain {DEFAULT_SEED_GAIN})",
                scenario.name()
            ),
            &["T", "Cold", "Warm", "Delta", "WarmWins"],
        );
        for &(t, c, w) in &sweep {
            table.row(vec![
                format!("{t}"),
                format!("{c:.4}"),
                format!("{w:.4}"),
                format!("{:+.4}", w - c),
                format!("{}", w < c),
            ]);
        }
        table.print();
        println!(
            "  {}: warm T={t_equal} matches cold T=4 (dual-iteration \
             savings {})",
            scenario.name(),
            4usize.saturating_sub(t_equal)
        );

        // serve-level: full cold bip-batch vs warm predictive run on
        // the same arrivals
        let seeds =
            seed_states(&model, n_layers, k, DEFAULT_SEED_GAIN);
        let cold_out = run_scenario(&serve_cfg(
            scenario,
            Policy::BipBatch,
            n_requests,
        ));
        let warm_out = run_scenario_seeded(
            &serve_cfg(scenario, Policy::Predictive, n_requests),
            &seeds,
        );
        println!(
            "  serve-level first-batch MaxVio: cold {:.4} -> warm \
             {:.4}; AvgMaxVio {:.4} -> {:.4}\n",
            cold_out.first_batch_vio,
            warm_out.first_batch_vio,
            cold_out.report.avg_max_vio,
            warm_out.report.avg_max_vio,
        );
        warm_rows.push(Json::obj(vec![
            ("scenario", Json::Str(scenario.name().into())),
            (
                "sweep",
                Json::Arr(
                    sweep
                        .iter()
                        .map(|&(t, c, w)| {
                            Json::obj(vec![
                                ("t", Json::Num(t as f64)),
                                ("cold_vio", Json::Num(c)),
                                ("warm_vio", Json::Num(w)),
                                ("warm_wins", Json::Bool(w < c)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("warm_t_equal_cold_t4", Json::Num(t_equal as f64)),
            (
                "dual_iteration_savings",
                Json::Num(4usize.saturating_sub(t_equal) as f64),
            ),
            (
                "serve_first_batch_cold",
                Json::Num(cold_out.first_batch_vio),
            ),
            (
                "serve_first_batch_warm",
                Json::Num(warm_out.first_batch_vio),
            ),
            (
                "serve_avg_max_vio_cold",
                Json::Num(cold_out.report.avg_max_vio),
            ),
            (
                "serve_avg_max_vio_warm",
                Json::Num(warm_out.report.avg_max_vio),
            ),
            ("serve_p99_cold", Json::Num(cold_out.report.p99_ms)),
            ("serve_p99_warm", Json::Num(warm_out.report.p99_ms)),
        ]));
    }
    let n_scenarios = Scenario::all().len();
    for (ti, &t) in T_SWEEP.iter().enumerate() {
        println!(
            "warm start wins at T={t}: {}/{} scenarios",
            wins_by_t[ti], n_scenarios
        );
    }
    json_results.push(Json::obj(vec![(
        "forecast_error",
        Json::Arr(err_rows),
    )]));
    json_results.push(Json::obj(vec![
        ("warm_start", Json::Arr(warm_rows)),
        (
            "warm_wins_by_t",
            Json::Arr(
                T_SWEEP
                    .iter()
                    .zip(&wins_by_t)
                    .map(|(&t, &wins)| {
                        Json::obj(vec![
                            ("t", Json::Num(t as f64)),
                            ("wins", Json::Num(wins as f64)),
                            (
                                "scenarios",
                                Json::Num(n_scenarios as f64),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]));

    // ---- predictive vs reactive autoscaling on bursty overload ------
    println!("== autoscaling: predictive vs reactive (bursty) ==");
    // calibrate one server's serviceable rate under saturation
    let calib_cfg = ServeConfig::new(
        TrafficConfig {
            scenario: Scenario::Bursty,
            n_requests: n_requests / 2,
            rate_per_s: 2_000_000.0,
            slo_us: 500_000,
            seed: TRAFFIC_SEED,
            ..Default::default()
        },
        SchedulerConfig::default(),
        RouterConfig::default(),
        Policy::Online,
    );
    let replica_rps =
        run_scenario(&calib_cfg).report.throughput_rps.max(1.0);
    // offer ~2.5 servers' worth of traffic so the set must scale
    let offered_rps = replica_rps * 2.5;
    let scale_cfg = ServeConfig::new(
        TrafficConfig {
            scenario: Scenario::Bursty,
            n_requests,
            rate_per_s: offered_rps,
            slo_us: 100_000,
            seed: TRAFFIC_SEED,
            ..Default::default()
        },
        SchedulerConfig::default(),
        RouterConfig::default(),
        Policy::Online,
    );
    let rcfg =
        ReplicaConfig { replicas: 4, threads: 2, sync_every: 8 };
    // ~24 scale windows across the arrival horizon
    let horizon_us = n_requests as f64 / offered_rps * 1e6;
    let window_us = ((horizon_us / 24.0) as u64).max(500);
    let mut table = TablePrinter::new(
        &format!(
            "autoscale bursty @ {offered_rps:.0} rps offered, replica \
             capacity {replica_rps:.0} rps, window {window_us} us"
        ),
        &[
            "Mode", "Done", "Goodput", "p99ms", "SloVio", "Scales",
            "OracleMatch",
        ],
    );
    let mut scale_rows = Vec::new();
    for mode in [ScalePolicy::Predictive, ScalePolicy::Reactive] {
        let mut scaler = AutoScaler::new(
            mode, window_us, replica_rps, 0.8, 1, 4,
        );
        let t0 = std::time::Instant::now();
        let out = run_autoscaled(&scale_cfg, &rcfg, None, &mut scaler);
        let wall_s = t0.elapsed().as_secs_f64();
        table.row(vec![
            mode.name().into(),
            format!("{}", out.report.completed),
            format!("{:.0}", out.report.goodput_rps),
            format!("{:.2}", out.report.p99_ms),
            format!("{}", out.report.slo_violations),
            format!("{}", out.scale_events.len()),
            format!("{:.3}", scaler.oracle_match_rate()),
        ]);
        scale_rows.push(Json::obj(vec![
            ("mode", Json::Str(mode.name().into())),
            ("offered_rps", Json::Num(offered_rps)),
            ("replica_rps", Json::Num(replica_rps)),
            ("window_us", Json::Num(window_us as f64)),
            ("completed", Json::Num(out.report.completed as f64)),
            ("goodput_rps", Json::Num(out.report.goodput_rps)),
            ("p99_ms", Json::Num(out.report.p99_ms)),
            (
                "slo_violations",
                Json::Num(out.report.slo_violations as f64),
            ),
            (
                "scale_events",
                Json::Num(out.scale_events.len() as f64),
            ),
            (
                "oracle_match",
                Json::Num(scaler.oracle_match_rate()),
            ),
            ("wall_s", Json::Num(wall_s)),
        ]));
    }
    table.print();
    json_results.push(Json::obj(vec![(
        "autoscale",
        Json::Arr(scale_rows),
    )]));

    let (section, regression_failed) =
        regression_gate(&prev, &cur_mae);
    if let Some(s) = section {
        json_results.push(s);
    }

    match write_bench_json("forecast", Json::Arr(json_results)) {
        Ok(path) => println!("perf record: {}", path.display()),
        Err(e) => {
            eprintln!("warning: BENCH_forecast.json not written: {e}")
        }
    }
    // call-path profile (fit + seeded-serve phases) alongside the
    // report so an accuracy gate failure can rule routing cost in/out
    let cur_prof = prof::Profile::scrape();
    match prof::write_prof_json("forecast", &cur_prof) {
        Ok(path) => println!("profile: {}", path.display()),
        Err(e) => {
            eprintln!("warning: PROF_forecast.json not written: {e}")
        }
    }

    if regression_failed {
        eprintln!(
            "bench_forecast FAILED: forecast accuracy regressed past \
             the 10% geomean gate"
        );
        if let Some(pp) = &prev_prof {
            let top = prof::top_regressions(pp, &cur_prof, 5);
            if !top.is_empty() {
                eprint!(
                    "{}",
                    prof::render_table(
                        "top regressed call paths vs previous \
                         PROF_forecast.json",
                        &top,
                    )
                    .render()
                );
            }
        }
        std::process::exit(1);
    }
}
