//! Trace-subsystem benchmarks: what recording costs on the serving hot
//! path, how big traces are on disk, and how fast the regression replay
//! and the counterfactual reroute run.
//!
//! Three claims back the record/replay design:
//!   * recording is cheap — the `Option<&mut TraceRecorder>` seam
//!     clones each arrival once and *moves* the per-batch
//!     assignment/load buffers into the frame, so the overhead over a
//!     bare `run_scenario` stays small;
//!   * the binary format is compact — bytes/request is dominated by
//!     the (n_layers x m) f32 gate scores, everything else is framing;
//!   * replay is at least as fast as the original run (it skips traffic
//!     generation) and the counterfactual reroute is cheaper still (no
//!     event loop, just routing).
//!
//! Results land in reports/BENCH_trace.json. BIP_MOE_FULL=1 scales the
//! stream up.

use bip_moe::bench::{write_bench_json, Bencher};
use bip_moe::serve::{
    run_scenario, run_scenario_with, Policy, ReplicaConfig, RouterConfig,
    SchedulerConfig, Scenario, ServeConfig, TrafficConfig,
    TrafficGenerator,
};
use bip_moe::trace::{replay, reroute, Trace, TraceRecorder};
use bip_moe::util::json::Json;

fn main() {
    let full = std::env::var("BIP_MOE_FULL").as_deref() == Ok("1");
    let n_requests = if full { 32_768 } else { 4_096 };

    let cfg = ServeConfig::new(
        TrafficConfig {
            scenario: Scenario::Steady,
            n_requests,
            seed: 3,
            ..Default::default()
        },
        SchedulerConfig::default(),
        RouterConfig::default(),
        Policy::Online,
    );
    let rcfg = ReplicaConfig { replicas: 1, threads: 1, sync_every: 0 };

    println!(
        "== record overhead (steady / bip-online, {n_requests} requests) =="
    );
    let mut b = Bencher::quick();
    let base = b
        .bench("run_scenario (no recording)", || {
            std::hint::black_box(run_scenario(&cfg));
        })
        .secs_per_iter
        .mean;
    let recorded = b
        .bench("run_scenario + TraceRecorder", || {
            let mut rec = TraceRecorder::new(&cfg, &rcfg);
            run_scenario_with(
                &cfg,
                TrafficGenerator::new(cfg.traffic.clone()),
                Some(&mut rec),
            );
            std::hint::black_box(rec.into_trace());
        })
        .secs_per_iter
        .mean;
    let overhead_pct = (recorded / base - 1.0) * 100.0;
    println!("record overhead: {overhead_pct:+.1}%");

    // one canonical trace for the replay-side benches
    let mut rec = TraceRecorder::new(&cfg, &rcfg);
    run_scenario_with(
        &cfg,
        TrafficGenerator::new(cfg.traffic.clone()),
        Some(&mut rec),
    );
    let trace = rec.into_trace();
    let bytes = trace.to_bytes();
    let bytes_per_request = bytes.len() as f64 / n_requests as f64;
    println!(
        "trace: {} frames, {} bytes ({bytes_per_request:.1} per request)",
        trace.frames.len(),
        bytes.len()
    );

    println!("\n== replay throughput ==");
    b.bench("Trace::from_bytes (decode)", || {
        std::hint::black_box(Trace::from_bytes(&bytes).unwrap());
    });
    let rep = b
        .bench("replay (regression mode)", || {
            let r = replay(&trace);
            assert!(r.mismatches.is_empty());
            std::hint::black_box(r);
        })
        .secs_per_iter
        .mean;
    let replay_rps = n_requests as f64 / rep;
    println!("replay throughput: {replay_rps:.0} requests/s");

    println!("\n== counterfactual reroute (per policy) ==");
    let mut reroute_rows = Vec::new();
    for policy in
        [Policy::Greedy, Policy::LossFree, Policy::BipBatch, Policy::Approx]
    {
        let m = b.bench(&format!("reroute {}", policy.name()), || {
            std::hint::black_box(reroute(&trace, policy).unwrap());
        });
        let tokens_per_s =
            trace.routed_tokens() as f64 / m.secs_per_iter.mean;
        reroute_rows.push(Json::obj(vec![
            ("policy", Json::Str(policy.name().into())),
            ("mean_us", Json::Num(m.secs_per_iter.mean * 1e6)),
            ("tokens_per_s", Json::Num(tokens_per_s)),
        ]));
    }

    let doc = Json::Arr(vec![Json::obj(vec![
        ("n_requests", Json::Num(n_requests as f64)),
        ("record_overhead_pct", Json::Num(overhead_pct)),
        ("trace_bytes", Json::Num(bytes.len() as f64)),
        ("bytes_per_request", Json::Num(bytes_per_request)),
        ("frames", Json::Num(trace.frames.len() as f64)),
        ("replay_rps", Json::Num(replay_rps)),
        ("reroute", Json::Arr(reroute_rows)),
        (
            "measurements",
            Json::Arr(b.results.iter().map(|m| m.to_json()).collect()),
        ),
    ])]);
    match write_bench_json("trace", doc) {
        Ok(path) => println!("\nperf record: {}", path.display()),
        Err(e) => eprintln!("warning: BENCH_trace.json not written: {e}"),
    }
}
