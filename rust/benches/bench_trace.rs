//! Trace-subsystem benchmarks: what recording costs on the serving hot
//! path, how big traces are on disk, and how fast the regression replay
//! and the counterfactual reroute run.
//!
//! Three claims back the record/replay design:
//!   * recording is cheap — the `Option<&mut TraceRecorder>` seam
//!     clones each arrival once and *moves* the per-batch
//!     assignment/load buffers into the frame, so the overhead over a
//!     bare `run_scenario` stays small;
//!   * the binary format is compact — bytes/request is dominated by
//!     the (n_layers x m) f32 gate scores, everything else is framing;
//!   * replay is at least as fast as the original run (it skips traffic
//!     generation) and the counterfactual reroute is cheaper still (no
//!     event loop, just routing).
//!
//! Results land in reports/BENCH_trace.json. BIP_MOE_FULL=1 scales the
//! stream up.
//!
//! Like the other gated benches, the previous record's throughput rows
//! (replay requests/s and per-policy reroute tokens/s) are loaded
//! BEFORE this run overwrites the file; a geomean ratio below 0.90
//! fails the bench unless BIP_MOE_PERF_GATE=off|warn overrides it. The
//! committed reports/BENCH_trace.json carries conservative throughput
//! floors in the real row schema, so the gate is *enforced* from the
//! first CI run (a `"seeded_placeholder": true` baseline downgrades
//! the gate to warn-only; the committed record no longer sets it).

use std::collections::BTreeMap;

use bip_moe::bench::{write_bench_json, Bencher};
use bip_moe::metrics::TablePrinter;
use bip_moe::serve::{
    run_scenario, run_scenario_with, Policy, ReplicaConfig, RouterConfig,
    SchedulerConfig, Scenario, ServeConfig, TrafficConfig,
    TrafficGenerator,
};
use bip_moe::trace::{replay, reroute, Trace, TraceRecorder};
use bip_moe::util::json::Json;

/// The previous BENCH_trace.json's throughput rows, read BEFORE this
/// run overwrites the record, plus whether that baseline is the
/// committed seed placeholder (warn-only for the perf gate).
fn load_prev_baseline() -> Option<(BTreeMap<String, f64>, bool)> {
    let dir = std::env::var("BIP_MOE_REPORTS")
        .unwrap_or_else(|_| "reports".into());
    let path = std::path::Path::new(&dir).join("BENCH_trace.json");
    let body = std::fs::read_to_string(&path).ok()?;
    let doc = Json::parse(&body).ok()?;
    let placeholder = doc
        .path("seeded_placeholder")
        .and_then(|j| j.as_bool())
        .unwrap_or(false);
    let mut rows = BTreeMap::new();
    if let Some(sections) = doc.path("results").and_then(|j| j.as_arr()) {
        for sec in sections {
            if let Some(rps) =
                sec.path("replay_rps").and_then(|j| j.as_f64())
            {
                rows.insert("replay_rps".to_string(), rps);
            }
            let Some(rr) = sec.path("reroute").and_then(|j| j.as_arr())
            else {
                continue;
            };
            for row in rr {
                let (Some(policy), Some(tps)) = (
                    row.path("policy").and_then(|j| j.as_str()),
                    row.path("tokens_per_s").and_then(|j| j.as_f64()),
                ) else {
                    continue;
                };
                rows.insert(
                    format!("reroute {policy} tokens_per_s"),
                    tps,
                );
            }
        }
    }
    Some((rows, placeholder))
}

fn main() {
    let full = std::env::var("BIP_MOE_FULL").as_deref() == Ok("1");
    let n_requests = if full { 32_768 } else { 4_096 };
    // read the previous record before anything overwrites it
    let prev = load_prev_baseline();

    let cfg = ServeConfig::new(
        TrafficConfig {
            scenario: Scenario::Steady,
            n_requests,
            seed: 3,
            ..Default::default()
        },
        SchedulerConfig::default(),
        RouterConfig::default(),
        Policy::Online,
    );
    let rcfg = ReplicaConfig { replicas: 1, threads: 1, sync_every: 0 };

    println!(
        "== record overhead (steady / bip-online, {n_requests} requests) =="
    );
    let mut b = Bencher::quick();
    let base = b
        .bench("run_scenario (no recording)", || {
            std::hint::black_box(run_scenario(&cfg));
        })
        .secs_per_iter
        .mean;
    let recorded = b
        .bench("run_scenario + TraceRecorder", || {
            let mut rec = TraceRecorder::new(&cfg, &rcfg);
            run_scenario_with(
                &cfg,
                TrafficGenerator::new(cfg.traffic.clone()),
                Some(&mut rec),
            );
            std::hint::black_box(rec.into_trace());
        })
        .secs_per_iter
        .mean;
    let overhead_pct = (recorded / base - 1.0) * 100.0;
    println!("record overhead: {overhead_pct:+.1}%");

    // one canonical trace for the replay-side benches
    let mut rec = TraceRecorder::new(&cfg, &rcfg);
    run_scenario_with(
        &cfg,
        TrafficGenerator::new(cfg.traffic.clone()),
        Some(&mut rec),
    );
    let trace = rec.into_trace();
    let bytes = trace.to_bytes();
    let bytes_per_request = bytes.len() as f64 / n_requests as f64;
    println!(
        "trace: {} frames, {} bytes ({bytes_per_request:.1} per request)",
        trace.frames.len(),
        bytes.len()
    );

    println!("\n== replay throughput ==");
    b.bench("Trace::from_bytes (decode)", || {
        std::hint::black_box(Trace::from_bytes(&bytes).unwrap());
    });
    let rep = b
        .bench("replay (regression mode)", || {
            let r = replay(&trace);
            assert!(r.mismatches.is_empty());
            std::hint::black_box(r);
        })
        .secs_per_iter
        .mean;
    let replay_rps = n_requests as f64 / rep;
    println!("replay throughput: {replay_rps:.0} requests/s");

    println!("\n== counterfactual reroute (per policy) ==");
    let mut reroute_rows = Vec::new();
    let mut cur_rows: Vec<(String, f64)> =
        vec![("replay_rps".to_string(), replay_rps)];
    for policy in
        [Policy::Greedy, Policy::LossFree, Policy::BipBatch, Policy::Approx]
    {
        let m = b.bench(&format!("reroute {}", policy.name()), || {
            std::hint::black_box(reroute(&trace, policy).unwrap());
        });
        let tokens_per_s =
            trace.routed_tokens() as f64 / m.secs_per_iter.mean;
        cur_rows.push((
            format!("reroute {} tokens_per_s", policy.name()),
            tokens_per_s,
        ));
        reroute_rows.push(Json::obj(vec![
            ("policy", Json::Str(policy.name().into())),
            ("mean_us", Json::Num(m.secs_per_iter.mean * 1e6)),
            ("tokens_per_s", Json::Num(tokens_per_s)),
        ]));
    }

    let mut sections = vec![Json::obj(vec![
        ("n_requests", Json::Num(n_requests as f64)),
        ("record_overhead_pct", Json::Num(overhead_pct)),
        ("trace_bytes", Json::Num(bytes.len() as f64)),
        ("bytes_per_request", Json::Num(bytes_per_request)),
        ("frames", Json::Num(trace.frames.len() as f64)),
        ("replay_rps", Json::Num(replay_rps)),
        ("reroute", Json::Arr(reroute_rows)),
        (
            "measurements",
            Json::Arr(b.results.iter().map(|m| m.to_json()).collect()),
        ),
    ])];

    // Regression history: delta table vs the previous record, gated on
    // geomean throughput ratio (BIP_MOE_PERF_GATE=off|warn overrides).
    let gate_env =
        std::env::var("BIP_MOE_PERF_GATE").unwrap_or_default();
    let mut regression_failed = false;
    match &prev {
        None => println!(
            "\nno previous BENCH_trace.json — recording the first \
             baseline"
        ),
        Some(_) if gate_env == "off" => println!(
            "\nperf gate: BIP_MOE_PERF_GATE=off — regression check \
             skipped"
        ),
        Some((prev_rows, placeholder)) => {
            let mut dt = TablePrinter::new(
                "throughput vs previous BENCH_trace.json (replay \
                 req/s, reroute tokens/s)",
                &["Row", "Previous", "Current", "Delta"],
            );
            let mut ratio_product = 1.0f64;
            let mut matched = 0u32;
            for (key, cur) in &cur_rows {
                let Some(prev_v) = prev_rows.get(key) else {
                    continue;
                };
                let ratio = cur / prev_v;
                ratio_product *= ratio;
                matched += 1;
                dt.row(vec![
                    key.clone(),
                    format!("{prev_v:.0}"),
                    format!("{cur:.0}"),
                    format!("{:+.1}%", (ratio - 1.0) * 100.0),
                ]);
            }
            if matched == 0 {
                println!(
                    "\nprevious BENCH_trace.json has no comparable \
                     throughput rows{} — gate skipped",
                    if *placeholder {
                        " (seeded placeholder)"
                    } else {
                        ""
                    }
                );
            } else {
                println!();
                dt.print();
                let geomean =
                    ratio_product.powf(1.0 / matched as f64);
                println!(
                    "  geomean throughput ratio: {geomean:.3} over \
                     {matched} row(s) (gate fails below 0.90)"
                );
                sections.push(Json::obj(vec![(
                    "regression",
                    Json::obj(vec![
                        ("geomean_ratio", Json::Num(geomean)),
                        ("rows_compared", Json::Num(matched as f64)),
                        ("gate_threshold", Json::Num(0.90)),
                        (
                            "baseline_placeholder",
                            Json::Bool(*placeholder),
                        ),
                    ]),
                )]));
                if geomean < 0.90 {
                    if *placeholder {
                        eprintln!(
                            "perf gate WARNING: geomean {geomean:.3} < \
                             0.90 vs the seeded placeholder baseline — \
                             not failing"
                        );
                    } else if gate_env == "warn" {
                        eprintln!(
                            "perf gate WARNING: geomean {geomean:.3} < \
                             0.90 (BIP_MOE_PERF_GATE=warn — not \
                             failing)"
                        );
                    } else {
                        eprintln!(
                            "perf gate FAILED: geomean throughput \
                             ratio {geomean:.3} < 0.90 vs the previous \
                             record"
                        );
                        regression_failed = true;
                    }
                }
            }
        }
    }

    match write_bench_json("trace", Json::Arr(sections)) {
        Ok(path) => println!("\nperf record: {}", path.display()),
        Err(e) => eprintln!("warning: BENCH_trace.json not written: {e}"),
    }

    if regression_failed {
        eprintln!(
            "bench_trace FAILED: replay/reroute throughput regressed \
             past the 10% geomean gate"
        );
        std::process::exit(1);
    }
}
