//! Hot-path benchmark + zero-allocation gate (ISSUE 5).
//!
//! Four measurements back the `perf/` claims, all written to
//! reports/BENCH_hotpath.json:
//!
//!   * **route_batch throughput** — tokens/sec through
//!     `ServingRouter::route_batch_into` (the arena path) vs a
//!     faithful in-file replica of the pre-PR allocating hot loop
//!     (fresh score `Vec` per layer, per-token `Vec<Vec<u32>>` routing,
//!     allocating placement accounting), per policy, swept over
//!     (batch, m, k) gate shapes on the skewed steady scenario;
//!   * **allocation counts** — a counting global allocator
//!     (`perf::alloc::CountingAlloc`) is installed in this binary; the
//!     arena path must report **0 heap allocations per batch** in
//!     steady state for every policy (the bench exits nonzero
//!     otherwise — this is the CI gate), while the baseline's per-batch
//!     allocation count is recorded alongside;
//!   * **adaptive solver** — iterations and MaxVio of
//!     `--solver-tol`-style adaptive Algorithm 1 vs the fixed-T solver
//!     at equal t_max, quantifying iteration savings at equal balance;
//!   * **replica scaling** — wall-clock micro-batch throughput of the
//!     replicated engine at R ∈ {1, 2, 4} on the same arena path;
//!   * **telemetry overhead** — route_batch with the global metrics
//!     registry enabled vs disabled (the ISSUE-6 < 2% claim,
//!     informational);
//!   * **kernel twins** — the ISSUE-10 specialized kernels
//!     (branch-free top-K, cache-blocked transpose, shard-staged
//!     parallel dual update) against their scalar / shared-write
//!     reference twins, each bit-identity-checked before timing; rows
//!     join the regression history under `"kernel ..."` keys;
//!   * **regression history** — before overwriting
//!     reports/BENCH_hotpath.json, the previous record's per-row arena
//!     tokens/sec are loaded and a delta table + geomean ratio is
//!     printed; a geomean below 0.90 fails the bench (the CI perf
//!     gate) unless the baseline is the committed seed placeholder
//!     (`"seeded_placeholder": true`, warn-only) or
//!     BIP_MOE_PERF_GATE=off|warn overrides it.
//!
//! BIP_MOE_FULL=1 widens the sweep.

use std::collections::BTreeMap;

use bip_moe::bench::{write_bench_json, Bencher};
use bip_moe::bip::{dual::DualState, Instance};
use bip_moe::metrics::maxvio::BalanceTracker;
use bip_moe::metrics::TablePrinter;
use bip_moe::parallel::placement::Placement;
use bip_moe::parallel::Mesh;
use bip_moe::perf::alloc::{
    reset_thread_counts, thread_allocs, CountingAlloc,
};
use bip_moe::prof;
use bip_moe::routing::{
    ApproxBip, Bip, Greedy, LossFree, OnlineBip, PredictiveBip,
    RoutingStrategy,
};
use bip_moe::serve::{
    run_replicated, Policy, ReplicaConfig, Request, RouterConfig,
    SchedulerConfig, Scenario, ServeConfig, ServingRouter,
    TrafficConfig, TrafficGenerator,
};
use bip_moe::telemetry;
use bip_moe::util::json::Json;
use bip_moe::util::rng::Pcg64;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Requests for one (m, k) gate shape on the skewed steady scenario.
fn batch_of(n: usize, m: usize, k: usize, seed: u64) -> Vec<Request> {
    TrafficGenerator::new(TrafficConfig {
        scenario: Scenario::Steady,
        n_requests: n,
        m,
        k,
        seed,
        ..Default::default()
    })
    .collect()
}

fn router_cfg(m: usize, k: usize) -> RouterConfig {
    RouterConfig {
        m,
        k,
        // bounded so the online gate's eager heap reservation stays
        // modest at m=64
        expected_stream: 1 << 16,
        ..Default::default()
    }
}

/// Faithful replica of the pre-PR `ServingRouter::route_batch` hot
/// loop: fresh score buffer per layer, allocating
/// `RoutingStrategy::route_batch`, fresh occupancy/choice scratch and
/// allocating placement accounting per call. This is the measured
/// baseline the arena path is priced against.
struct BaselineRouter {
    cfg: RouterConfig,
    layers: Vec<Box<dyn RoutingStrategy>>,
    placement: Placement,
    cum_loads: Vec<f64>,
    balance: BalanceTracker,
}

impl BaselineRouter {
    fn new(policy: Policy, cfg: RouterConfig) -> BaselineRouter {
        let gate_cap = (cfg.expected_stream * cfg.k / cfg.m).max(1);
        let layers: Vec<Box<dyn RoutingStrategy>> = (0..cfg.n_layers)
            .map(|_| -> Box<dyn RoutingStrategy> {
                match policy {
                    Policy::Greedy => Box::new(Greedy),
                    Policy::LossFree => {
                        Box::new(LossFree::new(cfg.m, cfg.lossfree_u))
                    }
                    Policy::BipBatch => Box::new(Bip::new(cfg.t_iters)),
                    Policy::Predictive => Box::new(PredictiveBip::new(
                        cfg.t_iters,
                        Vec::new(),
                    )),
                    Policy::Online => Box::new(OnlineBip::new(
                        cfg.m, cfg.k, gate_cap, cfg.t_iters,
                    )),
                    Policy::Approx => Box::new(ApproxBip::new(
                        cfg.m, cfg.k, gate_cap, cfg.t_iters, cfg.buckets,
                    )),
                }
            })
            .collect();
        let placement =
            Placement::block(&Mesh::new(cfg.n_devices, cfg.m));
        let balance = BalanceTracker::new(cfg.n_layers, 0, cfg.k);
        BaselineRouter {
            cum_loads: vec![0.0; cfg.m],
            cfg,
            layers,
            placement,
            balance,
        }
    }

    fn batch_cap(&self, n: usize) -> usize {
        ((n * self.cfg.k) as f64 / self.cfg.m as f64
            * self.cfg.capacity_factor)
            .ceil()
            .max(1.0) as usize
    }

    fn route_batch(&mut self, batch: &[Request]) -> Vec<f32> {
        let (m, k, n_layers) =
            (self.cfg.m, self.cfg.k, self.cfg.n_layers);
        let n = batch.len();
        let cap = self.batch_cap(n);
        let mut loads = vec![0.0f32; n_layers * m];
        let mut occ = vec![0u32; m];
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        let mut imbalance_sum = 0.0;
        for l in 0..n_layers {
            let mut scores = Vec::with_capacity(n * m);
            for r in batch {
                scores.extend_from_slice(r.layer_scores(l, m));
            }
            let inst = Instance { n, m, k, cap, scores };
            let routing = self.layers[l].route_batch(&inst);
            occ.iter_mut().for_each(|o| *o = 0);
            for (i, experts) in routing.assignment.iter().enumerate() {
                chosen.clear();
                for &e in experts.iter().take(k) {
                    let e = e as usize;
                    if occ[e] < cap as u32 && !chosen.contains(&e) {
                        chosen.push(e);
                        occ[e] += 1;
                        continue;
                    }
                    let row = inst.row(i);
                    let mut best: Option<usize> = None;
                    for j in 0..m {
                        if occ[j] < cap as u32
                            && !chosen.contains(&j)
                            && best.map_or(true, |b| row[j] > row[b])
                        {
                            best = Some(j);
                        }
                    }
                    if let Some(j) = best {
                        chosen.push(j);
                        occ[j] += 1;
                    }
                }
                let lrow = &mut loads[l * m..(l + 1) * m];
                for &e in &chosen {
                    lrow[e] += 1.0;
                }
            }
            let lrow = &loads[l * m..(l + 1) * m];
            imbalance_sum += self.placement.imbalance(lrow);
            for (j, &x) in lrow.iter().enumerate() {
                self.cum_loads[j] += x as f64;
            }
        }
        self.balance.push_batch_sized(&loads, m, n);
        std::hint::black_box(imbalance_sum);
        loads
    }
}

/// The previous BENCH_hotpath.json's arena tokens/sec per route row
/// (keyed `"<policy> n=N m=M k=K"`), read BEFORE this run overwrites
/// the record, plus whether that baseline is the committed seed
/// placeholder (warn-only for the perf gate).
fn load_prev_baseline() -> Option<(BTreeMap<String, f64>, bool)> {
    let dir = std::env::var("BIP_MOE_REPORTS")
        .unwrap_or_else(|_| "reports".into());
    let path = std::path::Path::new(&dir).join("BENCH_hotpath.json");
    let body = std::fs::read_to_string(&path).ok()?;
    let doc = Json::parse(&body).ok()?;
    let placeholder = doc
        .path("seeded_placeholder")
        .and_then(|j| j.as_bool())
        .unwrap_or(false);
    let mut rows = BTreeMap::new();
    if let Some(sections) = doc.path("results").and_then(|j| j.as_arr()) {
        for sec in sections {
            // kernel rows carry their regression key + rate explicitly
            if let Some(kr) = sec.path("kernels").and_then(|j| j.as_arr())
            {
                for row in kr {
                    if let (Some(key), Some(v)) = (
                        row.path("row_key").and_then(|j| j.as_str()),
                        row.path("per_sec").and_then(|j| j.as_f64()),
                    ) {
                        rows.insert(key.to_string(), v);
                    }
                }
            }
            let Some(rb) =
                sec.path("route_batch").and_then(|j| j.as_arr())
            else {
                continue;
            };
            for row in rb {
                let (Some(policy), Some(n), Some(m), Some(k), Some(tps)) = (
                    row.path("policy").and_then(|j| j.as_str()),
                    row.path("batch").and_then(|j| j.as_f64()),
                    row.path("m").and_then(|j| j.as_f64()),
                    row.path("k").and_then(|j| j.as_f64()),
                    row.path("arena_tokens_per_sec")
                        .and_then(|j| j.as_f64()),
                ) else {
                    continue;
                };
                rows.insert(
                    format!("{policy} n={n} m={m} k={k}"),
                    tps,
                );
            }
        }
    }
    Some((rows, placeholder))
}

/// Allocations per call over a post-warm-up window. The warm-up is
/// sized so the balance tracker's unbounded series (the one amortized
/// grower on the path) cannot double inside the window.
fn allocs_per_batch(
    mut call: impl FnMut(),
    warmup: usize,
    window: usize,
) -> f64 {
    for _ in 0..warmup {
        call();
    }
    reset_thread_counts();
    for _ in 0..window {
        call();
    }
    thread_allocs() as f64 / window as f64
}

fn main() {
    let full = std::env::var("BIP_MOE_FULL").as_deref() == Ok("1");
    // read the previous record before anything overwrites it
    let prev = load_prev_baseline();
    let prev_prof = prof::load_prev_prof("hotpath");
    prof::reset();
    let mut sections = Vec::new();

    // (batch tokens, experts, top-k) gate shapes
    let mut shapes = vec![(64usize, 16usize, 4usize), (256, 16, 4)];
    if full {
        shapes.push((256, 64, 8));
        shapes.push((1024, 16, 4));
    } else {
        shapes.push((128, 64, 8));
    }

    println!("== route_batch: arena vs pre-PR baseline (steady/skewed) ==");
    let mut rows = Vec::new();
    let mut cur_tps: Vec<(String, f64)> = Vec::new();
    let mut zero_alloc_ok = true;
    let mut speedup_product = 1.0f64;
    let mut speedup_count = 0u32;
    for &(n, m, k) in &shapes {
        let batch = batch_of(n, m, k, 13);
        for policy in Policy::all() {
            let mut arena_router =
                ServingRouter::new(policy, router_cfg(m, k));
            let mut out = bip_moe::serve::BatchOutcome::default();
            let mut bench = Bencher::default();
            let label =
                format!("route {} n={n} m={m} k={k}", policy.name());
            let meas = bench.bench(&format!("{label} [arena]"), || {
                arena_router.route_batch_into(&batch, &mut out);
            });
            let arena_us = meas.secs_per_iter.mean * 1e6;

            let mut base_router =
                BaselineRouter::new(policy, router_cfg(m, k));
            let meas = bench.bench(&format!("{label} [baseline]"), || {
                std::hint::black_box(base_router.route_batch(&batch));
            });
            let base_us = meas.secs_per_iter.mean * 1e6;

            // allocation accounting on fresh routers (same shapes)
            let mut ar = ServingRouter::new(policy, router_cfg(m, k));
            let mut aout = bip_moe::serve::BatchOutcome::default();
            let arena_allocs = allocs_per_batch(
                || ar.route_batch_into(&batch, &mut aout),
                300,
                100,
            );
            let mut br = BaselineRouter::new(policy, router_cfg(m, k));
            let base_allocs = allocs_per_batch(
                || {
                    std::hint::black_box(br.route_batch(&batch));
                },
                20,
                20,
            );
            if arena_allocs != 0.0 {
                zero_alloc_ok = false;
                eprintln!(
                    "ZERO-ALLOC VIOLATION: {} n={n} m={m} k={k}: \
                     {arena_allocs} allocs/batch in steady state",
                    policy.name()
                );
            }
            let speedup = base_us / arena_us;
            speedup_product *= speedup;
            speedup_count += 1;
            cur_tps.push((
                format!("{} n={n} m={m} k={k}", policy.name()),
                n as f64 / (arena_us / 1e6),
            ));
            println!(
                "  {:<14} n={n:<5} m={m:<3} k={k}: {arena_us:>8.2} us \
                 vs {base_us:>8.2} us  ({speedup:.2}x, allocs/batch \
                 {arena_allocs:.1} vs {base_allocs:.1})",
                policy.name()
            );
            rows.push(Json::obj(vec![
                ("policy", Json::Str(policy.name().into())),
                ("scenario", Json::Str("steady".into())),
                ("batch", Json::Num(n as f64)),
                ("m", Json::Num(m as f64)),
                ("k", Json::Num(k as f64)),
                ("arena_us_per_batch", Json::Num(arena_us)),
                ("baseline_us_per_batch", Json::Num(base_us)),
                (
                    "arena_tokens_per_sec",
                    Json::Num(n as f64 / (arena_us / 1e6)),
                ),
                (
                    "baseline_tokens_per_sec",
                    Json::Num(n as f64 / (base_us / 1e6)),
                ),
                ("speedup", Json::Num(speedup)),
                ("arena_allocs_per_batch", Json::Num(arena_allocs)),
                ("baseline_allocs_per_batch", Json::Num(base_allocs)),
            ]));
        }
    }
    let speedup_geomean =
        speedup_product.powf(1.0 / speedup_count.max(1) as f64);
    sections.push(Json::obj(vec![
        ("route_batch", Json::Arr(rows)),
        ("speedup_geomean", Json::Num(speedup_geomean)),
        ("zero_alloc_steady_state", Json::Bool(zero_alloc_ok)),
    ]));
    println!("  speedup geomean: {speedup_geomean:.2}x");

    // Kernel micro-benches (ISSUE 10): each specialized kernel vs its
    // scalar reference twin, with a bit-identity check before timing
    // so the comparison always prices two equal computations. Rows
    // join the same regression history as the route rows (keyed
    // "kernel ..."), and each bench runs under its profiler frame so a
    // failed gate's PROF_ diff names the guilty kernel.
    println!("\n== kernels: specialized vs scalar reference twins ==");
    let mut kernel_rows = Vec::new();
    {
        use bip_moe::perf::{block, kernels, ScoreArena};
        use bip_moe::prof::{Frame, ProfGuard};
        use bip_moe::util::pool::Pool;

        // branch-free top-K vs comparator quickselect, per gate shape
        // (network k <= 4, heap k <= 32, fallback beyond)
        let rows_n = 4096usize;
        for &(m, k) in
            &[(16usize, 4usize), (64, 2), (64, 8), (256, 32), (256, 48)]
        {
            let mut rng = Pcg64::new(21);
            let scores: Vec<f32> =
                (0..rows_n * m).map(|_| rng.next_f32() - 0.5).collect();
            let mut idx = vec![0u32; m];
            let mut out = vec![0u32; m];
            let mut rout = vec![0u32; m];
            for r in 0..rows_n {
                let xs = &scores[r * m..(r + 1) * m];
                let a =
                    kernels::topk_keys_into(xs, k, &mut idx, &mut out);
                let b = kernels::topk_ref(xs, k, &mut idx, &mut rout);
                assert_eq!(a, b, "m={m} k={k}");
                assert_eq!(out[..a], rout[..b], "m={m} k={k} row {r}");
            }
            let mut bench = Bencher::quick();
            let _prof = ProfGuard::enter(Frame::TopK);
            let kern_us = bench
                .bench(&format!("kernel topk m={m} k={k}"), || {
                    for r in 0..rows_n {
                        let xs = &scores[r * m..(r + 1) * m];
                        std::hint::black_box(kernels::topk_keys_into(
                            xs, k, &mut idx, &mut out,
                        ));
                    }
                })
                .secs_per_iter
                .mean
                * 1e6;
            let ref_us = bench
                .bench(&format!("ref topk m={m} k={k}"), || {
                    for r in 0..rows_n {
                        let xs = &scores[r * m..(r + 1) * m];
                        std::hint::black_box(kernels::topk_ref(
                            xs, k, &mut idx, &mut rout,
                        ));
                    }
                })
                .secs_per_iter
                .mean
                * 1e6;
            drop(_prof);
            let per_sec = rows_n as f64 / (kern_us / 1e6);
            let key = format!("kernel topk m={m} k={k}");
            println!(
                "  {key:<28}: {kern_us:>9.2} us vs ref {ref_us:>9.2} \
                 us per {rows_n} rows ({:.2}x)",
                ref_us / kern_us
            );
            cur_tps.push((key.clone(), per_sec));
            kernel_rows.push(Json::obj(vec![
                ("row_key", Json::Str(key)),
                ("kind", Json::Str("topk".into())),
                ("m", Json::Num(m as f64)),
                ("k", Json::Num(k as f64)),
                ("rows", Json::Num(rows_n as f64)),
                ("kernel_us_per_pass", Json::Num(kern_us)),
                ("ref_us_per_pass", Json::Num(ref_us)),
                ("per_sec", Json::Num(per_sec)),
                ("speedup", Json::Num(ref_us / kern_us)),
            ]));
        }

        // cache-blocked vs naive transpose, per batch shape
        for &(n, m) in &[(256usize, 16usize), (1024, 64), (4096, 64)] {
            let mut rng = Pcg64::new(23);
            let src: Vec<f32> =
                (0..n * m).map(|_| rng.next_f32()).collect();
            let mut dst = vec![0.0f32; n * m];
            let mut ref_dst = vec![0.0f32; n * m];
            block::transpose_into(&src, n, m, &mut dst);
            block::transpose_ref(&src, n, m, &mut ref_dst);
            assert_eq!(dst, ref_dst, "blocked diverged n={n} m={m}");
            let mut bench = Bencher::quick();
            let _prof = ProfGuard::enter(Frame::Transpose);
            let kern_us = bench
                .bench(&format!("kernel transpose n={n} m={m}"), || {
                    block::transpose_into(&src, n, m, &mut dst);
                })
                .secs_per_iter
                .mean
                * 1e6;
            let ref_us = bench
                .bench(&format!("ref transpose n={n} m={m}"), || {
                    block::transpose_ref(&src, n, m, &mut ref_dst);
                })
                .secs_per_iter
                .mean
                * 1e6;
            drop(_prof);
            let per_sec = (n * m) as f64 / (kern_us / 1e6);
            let key = format!("kernel transpose n={n} m={m}");
            println!(
                "  {key:<28}: {kern_us:>9.2} us vs ref {ref_us:>9.2} \
                 us per pass ({:.2}x)",
                ref_us / kern_us
            );
            cur_tps.push((key.clone(), per_sec));
            kernel_rows.push(Json::obj(vec![
                ("row_key", Json::Str(key)),
                ("kind", Json::Str("transpose".into())),
                ("n", Json::Num(n as f64)),
                ("m", Json::Num(m as f64)),
                ("kernel_us_per_pass", Json::Num(kern_us)),
                ("ref_us_per_pass", Json::Num(ref_us)),
                ("per_sec", Json::Num(per_sec)),
                ("speedup", Json::Num(ref_us / kern_us)),
            ]));
        }

        // sharded parallel dual update vs the pre-sharding
        // direct-write twin (false-sharing price), per thread count
        let (n, m, k, t_iters) = (1024usize, 16usize, 4usize, 4usize);
        for &threads in &[2usize, 4] {
            let pool = Pool::new(threads);
            let mut rng = Pcg64::new(29);
            let inst = Instance::synthetic(n, m, k, 2.0, 3.0, &mut rng);
            let mut sharded = DualState::new(m);
            let mut shared = DualState::new(m);
            let mut sharded_arena = ScoreArena::new();
            let mut shared_arena = ScoreArena::new();
            sharded.update_parallel_in(
                &inst,
                t_iters,
                &pool,
                &mut sharded_arena,
            );
            shared.update_parallel_shared_in(
                &inst,
                t_iters,
                &pool,
                &mut shared_arena,
            );
            assert_eq!(sharded.q, shared.q, "threads={threads}");
            assert_eq!(sharded.p, shared.p, "threads={threads}");
            let mut bench = Bencher::quick();
            let kern_us = bench
                .bench(
                    &format!("kernel dual sharded threads={threads}"),
                    || {
                        sharded.update_parallel_in(
                            &inst,
                            t_iters,
                            &pool,
                            &mut sharded_arena,
                        );
                    },
                )
                .secs_per_iter
                .mean
                * 1e6;
            let ref_us = bench
                .bench(
                    &format!("ref dual shared threads={threads}"),
                    || {
                        shared.update_parallel_shared_in(
                            &inst,
                            t_iters,
                            &pool,
                            &mut shared_arena,
                        );
                    },
                )
                .secs_per_iter
                .mean
                * 1e6;
            pool.join();
            let per_sec = n as f64 / (kern_us / 1e6);
            let key = format!("kernel dual-shard threads={threads}");
            println!(
                "  {key:<28}: {kern_us:>9.2} us vs shared-write \
                 {ref_us:>9.2} us per solve ({:.2}x)",
                ref_us / kern_us
            );
            cur_tps.push((key.clone(), per_sec));
            kernel_rows.push(Json::obj(vec![
                ("row_key", Json::Str(key)),
                ("kind", Json::Str("dual_shard".into())),
                ("n", Json::Num(n as f64)),
                ("m", Json::Num(m as f64)),
                ("threads", Json::Num(threads as f64)),
                ("t_iters", Json::Num(t_iters as f64)),
                ("kernel_us_per_pass", Json::Num(kern_us)),
                ("ref_us_per_pass", Json::Num(ref_us)),
                ("per_sec", Json::Num(per_sec)),
                ("speedup", Json::Num(ref_us / kern_us)),
            ]));
        }
    }
    sections.push(Json::obj(vec![(
        "kernels",
        Json::Arr(kernel_rows),
    )]));

    // Regression history: delta table vs the previous record, gated on
    // geomean throughput ratio (BIP_MOE_PERF_GATE=off|warn overrides).
    let gate_env =
        std::env::var("BIP_MOE_PERF_GATE").unwrap_or_default();
    let mut regression_failed = false;
    match &prev {
        None => println!(
            "\nno previous BENCH_hotpath.json — recording the first \
             baseline"
        ),
        Some(_) if gate_env == "off" => println!(
            "\nperf gate: BIP_MOE_PERF_GATE=off — regression check \
             skipped"
        ),
        Some((prev_rows, placeholder)) => {
            let mut dt = TablePrinter::new(
                "throughput vs previous BENCH_hotpath.json (arena \
                 tokens/sec)",
                &["Row", "Previous", "Current", "Delta"],
            );
            let mut ratio_product = 1.0f64;
            let mut matched = 0u32;
            for (key, cur) in &cur_tps {
                let Some(prev_v) = prev_rows.get(key) else {
                    continue;
                };
                let ratio = cur / prev_v;
                ratio_product *= ratio;
                matched += 1;
                dt.row(vec![
                    key.clone(),
                    format!("{prev_v:.0}"),
                    format!("{cur:.0}"),
                    format!("{:+.1}%", (ratio - 1.0) * 100.0),
                ]);
            }
            if matched == 0 {
                println!(
                    "\nprevious BENCH_hotpath.json has no comparable \
                     route rows{} — gate skipped",
                    if *placeholder { " (seeded placeholder)" } else { "" }
                );
            } else {
                println!();
                dt.print();
                let geomean =
                    ratio_product.powf(1.0 / matched as f64);
                println!(
                    "  geomean throughput ratio: {geomean:.3} over \
                     {matched} row(s) (gate fails below 0.90)"
                );
                sections.push(Json::obj(vec![(
                    "regression",
                    Json::obj(vec![
                        ("geomean_ratio", Json::Num(geomean)),
                        ("rows_compared", Json::Num(matched as f64)),
                        ("gate_threshold", Json::Num(0.90)),
                        (
                            "baseline_placeholder",
                            Json::Bool(*placeholder),
                        ),
                    ]),
                )]));
                if geomean < 0.90 {
                    if *placeholder {
                        eprintln!(
                            "perf gate WARNING: geomean {geomean:.3} < \
                             0.90 vs the seeded placeholder baseline — \
                             not failing"
                        );
                    } else if gate_env == "warn" {
                        eprintln!(
                            "perf gate WARNING: geomean {geomean:.3} < \
                             0.90 (BIP_MOE_PERF_GATE=warn — not \
                             failing)"
                        );
                    } else {
                        eprintln!(
                            "perf gate FAILED: geomean tokens/sec \
                             ratio {geomean:.3} < 0.90 vs the previous \
                             record"
                        );
                        regression_failed = true;
                    }
                }
            }
        }
    }

    // Telemetry overhead: the same arena route loop with the global
    // registry live vs compiled to early returns (set_enabled(false)).
    // Informational — ISSUE 6's acceptance asks for < 2%.
    println!("\n== telemetry overhead: registry on vs off ==");
    {
        let (n, m, k) = (256usize, 16usize, 4usize);
        let batch = batch_of(n, m, k, 17);
        let mut bench = Bencher::default();
        let mut r_on = ServingRouter::new(Policy::Online, router_cfg(m, k));
        let mut out_on = bip_moe::serve::BatchOutcome::default();
        telemetry::set_enabled(true);
        let on_us = bench
            .bench("route online n=256 [telemetry on]", || {
                r_on.route_batch_into(&batch, &mut out_on);
            })
            .secs_per_iter
            .mean
            * 1e6;
        let mut r_off =
            ServingRouter::new(Policy::Online, router_cfg(m, k));
        let mut out_off = bip_moe::serve::BatchOutcome::default();
        telemetry::set_enabled(false);
        let off_us = bench
            .bench("route online n=256 [telemetry off]", || {
                r_off.route_batch_into(&batch, &mut out_off);
            })
            .secs_per_iter
            .mean
            * 1e6;
        telemetry::set_enabled(true);
        let overhead_pct = (on_us / off_us - 1.0) * 100.0;
        println!(
            "  on {on_us:.2} us vs off {off_us:.2} us per batch \
             ({overhead_pct:+.2}%)"
        );
        sections.push(Json::obj(vec![(
            "telemetry_overhead",
            Json::obj(vec![
                ("on_us_per_batch", Json::Num(on_us)),
                ("off_us_per_batch", Json::Num(off_us)),
                ("overhead_pct", Json::Num(overhead_pct)),
            ]),
        )]));
    }

    // Adaptive Algorithm 1: iteration savings at equal MaxVio. The
    // solver regime (tight cap = n*k/m) on a warm-started skewed
    // stream, fixed T=16 vs --solver-tol-style early exit.
    println!("\n== adaptive solver: iterations vs MaxVio (T<=16) ==");
    let t_max = 16usize;
    let batches = if full { 32 } else { 12 };
    let mut adaptive_rows = Vec::new();
    for tol in [0.0f32, 0.02, 0.05, 0.1] {
        let mut state = DualState::new(16);
        let mut rng = Pcg64::new(7);
        let mut iters_total = 0usize;
        let mut vio_sum = 0.0f64;
        let t0 = std::time::Instant::now();
        for _ in 0..batches {
            let inst =
                Instance::synthetic(1024, 16, 4, 2.0, 3.0, &mut rng);
            iters_total += if tol > 0.0 {
                state.update_adaptive(&inst, t_max, tol)
            } else {
                state.update(&inst, t_max);
                t_max
            };
            vio_sum += state.route(&inst).max_violation(&inst);
        }
        let wall_us =
            t0.elapsed().as_secs_f64() * 1e6 / batches as f64;
        let avg_iters = iters_total as f64 / batches as f64;
        let avg_vio = vio_sum / batches as f64;
        println!(
            "  tol={tol:<5}: {avg_iters:>5.2} iters/batch, avg MaxVio \
             {avg_vio:.4}, {wall_us:>8.1} us/batch"
        );
        adaptive_rows.push(Json::obj(vec![
            ("tol", Json::Num(tol as f64)),
            ("t_max", Json::Num(t_max as f64)),
            ("avg_iters", Json::Num(avg_iters)),
            ("avg_max_vio", Json::Num(avg_vio)),
            ("us_per_batch", Json::Num(wall_us)),
        ]));
    }
    sections.push(Json::obj(vec![(
        "adaptive_solver",
        Json::Arr(adaptive_rows),
    )]));

    // Replica scaling on the arena path: virtual-time micro-batch
    // throughput of the replicated engine under saturating load.
    println!("\n== replica scaling (bursty, bip-batch, threads=4) ==");
    let requests = if full { 65_536 } else { 8_192 };
    let mut replica_rows = Vec::new();
    for &r in &[1usize, 2, 4] {
        let cfg = ServeConfig::new(
            TrafficConfig {
                scenario: Scenario::Bursty,
                n_requests: requests,
                rate_per_s: 2_000_000.0,
                seed: 2,
                slo_us: 500_000,
                ..Default::default()
            },
            SchedulerConfig::default(),
            RouterConfig::default(),
            Policy::BipBatch,
        );
        let rcfg = ReplicaConfig { replicas: r, threads: 4, sync_every: 8 };
        let t0 = std::time::Instant::now();
        let out = run_replicated(&cfg, &rcfg);
        let wall_s = t0.elapsed().as_secs_f64();
        let batches_per_vs = if out.report.horizon_s > 0.0 {
            out.batches as f64 / out.report.horizon_s
        } else {
            0.0
        };
        println!(
            "  R={r}: {} batches, {batches_per_vs:.0} batches/vsec, \
             wall {wall_s:.2}s, AvgMaxVio {:.4}",
            out.batches, out.report.avg_max_vio
        );
        replica_rows.push(Json::obj(vec![
            ("replicas", Json::Num(r as f64)),
            ("threads", Json::Num(4.0)),
            ("batches", Json::Num(out.batches as f64)),
            ("batches_per_vsec", Json::Num(batches_per_vs)),
            ("avg_max_vio", Json::Num(out.report.avg_max_vio)),
            ("completed", Json::Num(out.report.completed as f64)),
            ("wall_s", Json::Num(wall_s)),
        ]));
    }
    sections.push(Json::obj(vec![(
        "replica_scaling",
        Json::Arr(replica_rows),
    )]));

    match write_bench_json("hotpath", Json::Arr(sections)) {
        Ok(path) => println!("\nperf record: {}", path.display()),
        Err(e) => {
            eprintln!("warning: BENCH_hotpath.json not written: {e}")
        }
    }
    // capture the run's call-path profile alongside the report so a
    // failed gate can name the phase that regressed, not just the row
    let cur_prof = prof::Profile::scrape();
    match prof::write_prof_json("hotpath", &cur_prof) {
        Ok(path) => println!("profile: {}", path.display()),
        Err(e) => {
            eprintln!("warning: PROF_hotpath.json not written: {e}")
        }
    }

    if !zero_alloc_ok || regression_failed {
        if !zero_alloc_ok {
            eprintln!(
                "bench_hotpath FAILED: steady-state allocations \
                 detected on the arena path"
            );
        }
        if regression_failed {
            eprintln!(
                "bench_hotpath FAILED: throughput regressed past the \
                 10% geomean gate"
            );
            if let Some(pp) = &prev_prof {
                let top = prof::top_regressions(pp, &cur_prof, 5);
                if !top.is_empty() {
                    eprint!(
                        "{}",
                        prof::render_table(
                            "top regressed call paths vs previous \
                             PROF_hotpath.json",
                            &top,
                        )
                        .render()
                    );
                }
            }
        }
        std::process::exit(1);
    }
    println!("zero-alloc steady state: OK (every policy, every shape)");
}
