//! Runtime-layer benchmarks: the L3 hot path around PJRT execution.
//!
//! Measures (a) HLO compile time per artifact, (b) train-step execution
//! wall time per config, (c) host<->literal conversion overhead at theta
//! size, and (d) data-loader throughput — the inputs to the §Perf
//! analysis in EXPERIMENTS.md (which of these bounds step time).

use std::path::Path;
use std::sync::Arc;

use bip_moe::bench::Bencher;
use bip_moe::data::{Corpus, CorpusSpec, Loader, Split};
use bip_moe::runtime::{Engine, Tensor};
use bip_moe::train::state::TrainState;

fn main() {
    let Ok(engine) = Engine::new(Path::new("artifacts")) else {
        eprintln!("artifacts/ missing; run `make artifacts` first");
        std::process::exit(0);
    };
    let mut b = Bencher::quick();

    // data loader throughput (no PJRT involved)
    let corpus = Arc::new(Corpus::build(CorpusSpec::default()));
    let loader = Loader::new(corpus, 4, 128, Split::Train);
    let mut idx = 0u64;
    let m = b.bench("loader.batch (4x128, vocab 6400)", || {
        std::hint::black_box(loader.batch(idx));
        idx += 1;
    });
    println!(
        "  -> {:.1} Mtok/s generation",
        4.0 * 129.0 / m.secs_per_iter.mean / 1e6
    );

    for config in ["tiny", "moe16-bench", "moe64-bench"] {
        let Ok(cfg) = engine.manifest().config(config) else { continue };
        let cfg = cfg.clone();
        let Ok(train_art) =
            engine.manifest().train_artifact(config, "bip", 4)
        else {
            continue;
        };
        let train_art = train_art.clone();
        let init_art = engine
            .manifest()
            .find(config, "init", "-", None)
            .unwrap()
            .clone();

        // compile (cold) timing happens implicitly on first run; report it
        let t0 = std::time::Instant::now();
        let theta = engine
            .run(&init_art, &[Tensor::scalar_i32(0)])
            .unwrap()
            .pop()
            .unwrap();
        println!(
            "{config}: init artifact compile+run {:.2}s (theta {} elems)",
            t0.elapsed().as_secs_f64(),
            theta.len()
        );

        let mut state = TrainState::fresh(theta, &cfg);
        let tokens: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
            .map(|i| (i % cfg.vocab_size) as i32)
            .collect();
        let tokens =
            Tensor::from_i32(&[cfg.batch_size, cfg.seq_len + 1], tokens);

        // literal conversion alone (host -> xla)
        b.bench(&format!("{config}: theta->literal ({})", state.theta.len()),
                || {
                    std::hint::black_box(
                        state.theta.to_literal().unwrap());
                });

        // full train step (compile amortized after first call)
        let t0 = std::time::Instant::now();
        let outs = engine
            .run(&train_art, &state.as_inputs(tokens.clone()))
            .unwrap();
        println!(
            "{config}: train step first call (incl. compile) {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        state.absorb(outs);
        b.bench(&format!("{config}: train step (warm)"), || {
            let outs = engine
                .run(&train_art, &state.as_inputs(tokens.clone()))
                .unwrap();
            state.absorb(outs);
        });
    }

    let st = engine.stats();
    println!(
        "\nengine totals: {} compiles {:.1}s | {} execs {:.1}s \
         ({:.1}ms mean)",
        st.compiles,
        st.compile_seconds,
        st.executions,
        st.execute_seconds,
        1e3 * st.execute_seconds / st.executions.max(1) as f64
    );
}
