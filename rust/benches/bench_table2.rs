//! Reproduces **Table 2**: evaluation on the 16-expert model (m=16, k=4).
//!
//! Runs the full method grid — Loss-Controlled (aux), Loss-Free, and BIP
//! with T in {2, 4, 8, 14} — as real PJRT training runs on the
//! `moe16-bench` config, then prints the paper's columns (AvgMaxVio,
//! SupMaxVio, Perplexity, Training time) side-by-side with the paper's
//! own numbers. Training time is the cluster-simulator extrapolation to
//! the full pre-training horizon (DESIGN.md §Substitutions).
//!
//! Default is a quick pass (BIP_MOE_STEPS / BIP_MOE_FULL=1 scale it up);
//! results cache under reports/ so figure benches reuse these runs.

use std::path::Path;

use bip_moe::bench::experiments::{method_grid, paper_table2, run_or_load};
use bip_moe::bench::BenchConfig;
use bip_moe::metrics::TablePrinter;
use bip_moe::runtime::Engine;
use bip_moe::train::TrainDriver;

fn main() {
    bip_moe::util::log::init_from_env();
    let cfg = BenchConfig::from_env(80, 400);
    if let Err(e) = run(&cfg, "moe16-bench", "Table 2 (m=16, k=4)",
                        &paper_table2()) {
        eprintln!("bench_table2: {e:#}");
        std::process::exit(1);
    }
}

pub fn run(
    bench: &BenchConfig,
    config: &str,
    title: &str,
    paper: &[(&str, [f64; 4])],
) -> anyhow::Result<()> {
    let engine = Engine::new(Path::new("artifacts"))?;
    let reports = Path::new("reports");
    let model = engine.manifest().config(config)?;
    let full_steps = model.total_steps as u64;

    let mut table = TablePrinter::new(
        &format!("{title} — {} steps/run (paper values in parens)",
                 bench.steps),
        &["Algorithm", "AvgMaxVio", "SupMaxVio", "Perplexity",
          "TrainTime/h (sim)", "Wall s"],
    );

    for ((label, mode, t), (plabel, pvals)) in
        method_grid(&[2, 4, 8, 14]).into_iter().zip(paper)
    {
        assert_eq!(&label, plabel, "grid/paper label mismatch");
        let mut driver = TrainDriver::new(config, &mode, t, bench.steps);
        driver.eval_batches = bench.eval_batches;
        let summary = run_or_load(&engine, &driver, reports)?;
        // extrapolate simulated time to the paper's full horizon so the
        // ratio column is comparable across methods
        let sim_full = summary.sim_hours_full
            * (full_steps as f64 / full_steps as f64);
        table.row(vec![
            label,
            format!("{:.4} ({:.4})", summary.avg_max_vio, pvals[0]),
            format!("{:.4} ({:.4})", summary.sup_max_vio, pvals[1]),
            format!("{:.4} ({:.4})", summary.perplexity, pvals[2]),
            format!("{:.4} ({:.4})", sim_full, pvals[3]),
            format!("{:.1}", summary.wall_seconds),
        ]);
    }
    table.print();

    println!(
        "shape checks: BIP rows should show ~an order of magnitude lower \
         AvgMaxVio than Loss-Controlled,\nSupMaxVio < 1, and lower \
         simulated training time (>= ~13% saved vs Loss-Controlled)."
    );
    Ok(())
}
