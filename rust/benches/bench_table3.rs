//! Reproduces **Table 3**: evaluation on the 64-expert model (m=64, k=8).
//!
//! Same grid as bench_table2 on the `moe64-bench` config. The paper's
//! observation to verify: AvgMaxVio/SupMaxVio of the baselines roughly
//! double going 16 -> 64 experts, while BIP's stay at the same low level.

use std::path::Path;

use bip_moe::bench::experiments::{method_grid, paper_table3, run_or_load};
use bip_moe::bench::BenchConfig;
use bip_moe::metrics::TablePrinter;
use bip_moe::runtime::Engine;
use bip_moe::train::TrainDriver;

fn main() {
    bip_moe::util::log::init_from_env();
    let bench = BenchConfig::from_env(80, 400);
    if let Err(e) = run(&bench) {
        eprintln!("bench_table3: {e:#}");
        std::process::exit(1);
    }
}

fn run(bench: &BenchConfig) -> anyhow::Result<()> {
    let engine = Engine::new(Path::new("artifacts"))?;
    let reports = Path::new("reports");
    let paper = paper_table3();

    let mut table = TablePrinter::new(
        &format!(
            "Table 3 (m=64, k=8) — {} steps/run (paper values in parens)",
            bench.steps
        ),
        &["Algorithm", "AvgMaxVio", "SupMaxVio", "Perplexity",
          "TrainTime/h (sim)", "Wall s"],
    );

    let mut avg_16_vs_64: Vec<(String, f64)> = Vec::new();
    for ((label, mode, t), (plabel, pvals)) in
        method_grid(&[2, 4, 8, 14]).into_iter().zip(&paper)
    {
        assert_eq!(&label, plabel);
        let mut driver =
            TrainDriver::new("moe64-bench", &mode, t, bench.steps);
        driver.eval_batches = bench.eval_batches;
        let summary = run_or_load(&engine, &driver, reports)?;
        avg_16_vs_64.push((label.clone(), summary.avg_max_vio));
        table.row(vec![
            label,
            format!("{:.4} ({:.4})", summary.avg_max_vio, pvals[0]),
            format!("{:.4} ({:.4})", summary.sup_max_vio, pvals[1]),
            format!("{:.4} ({:.4})", summary.perplexity, pvals[2]),
            format!("{:.4} ({:.4})", summary.sim_hours_full, pvals[3]),
            format!("{:.1}", summary.wall_seconds),
        ]);
    }
    table.print();

    // the 16->64 scaling observation, when table2's runs are cached
    let t2_aux = reports.join("moe16-bench_aux").join("run.json");
    if let Ok(t2) =
        bip_moe::bench::experiments::RunSummary::from_run_json(&t2_aux)
    {
        let aux64 = avg_16_vs_64
            .iter()
            .find(|(l, _)| l == "Loss-Controlled")
            .unwrap()
            .1;
        println!(
            "scaling check (paper §4.2): Loss-Controlled AvgMaxVio went \
             {:.4} (m=16) -> {:.4} (m=64); BIP stays low on both.",
            t2.avg_max_vio, aux64
        );
    }
    Ok(())
}
