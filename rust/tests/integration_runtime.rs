//! Integration tests over the PJRT runtime + tiny artifacts.
//!
//! These require `make artifacts` to have produced the tiny config; when
//! artifacts/ is missing the tests skip (printing why) so `cargo test`
//! stays green on a fresh checkout.

use std::path::{Path, PathBuf};

use bip_moe::bip::dual::DualState;
use bip_moe::bip::Instance;
use bip_moe::runtime::{Engine, Tensor};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn engine() -> Option<Engine> {
    artifacts_dir().map(|d| Engine::new(&d).expect("engine"))
}

fn init_theta(engine: &Engine, seed: i32) -> Tensor {
    let art = engine.manifest().find("tiny", "init", "-", None).unwrap();
    engine
        .run(art, &[Tensor::scalar_i32(seed)])
        .unwrap()
        .pop()
        .unwrap()
}

fn tiny_tokens(engine: &Engine, seed: u64) -> Tensor {
    let cfg = engine.manifest().config("tiny").unwrap();
    let mut rng = bip_moe::util::rng::Pcg64::new(seed);
    let data: Vec<i32> = (0..cfg.batch_size * (cfg.seq_len + 1))
        .map(|_| rng.below(cfg.vocab_size as u64) as i32)
        .collect();
    Tensor::from_i32(&[cfg.batch_size, cfg.seq_len + 1], data)
}

#[test]
fn init_artifact_is_deterministic_and_seed_sensitive() {
    let Some(engine) = engine() else { return };
    let a = init_theta(&engine, 0);
    let b = init_theta(&engine, 0);
    let c = init_theta(&engine, 1);
    assert_eq!(a.f32s().unwrap(), b.f32s().unwrap());
    assert_ne!(a.f32s().unwrap(), c.f32s().unwrap());
    let cfg = engine.manifest().config("tiny").unwrap();
    assert_eq!(a.len(), cfg.theta_size);
    // init respects the spec: norm gains exactly 1.0 somewhere, embed
    // values small
    let theta = a.f32s().unwrap();
    assert!(theta.iter().any(|&x| x == 1.0));
    assert!(theta[..100].iter().all(|&x| x.abs() < 0.5));
}

#[test]
fn train_step_runs_and_threads_state_for_every_mode() {
    let Some(engine) = engine() else { return };
    let cfg = engine.manifest().config("tiny").unwrap().clone();
    let tokens = tiny_tokens(&engine, 3);
    for (mode, t) in [("aux", 0), ("lossfree", 0), ("bip", 4)] {
        let art = engine.manifest().train_artifact("tiny", mode, t).unwrap();
        let theta = init_theta(&engine, 0);
        let mut state =
            bip_moe::train::state::TrainState::fresh(theta, &cfg);
        let theta_before = state.theta.f32s().unwrap().to_vec();
        let outs = engine
            .run(art, &state.as_inputs(tokens.clone()))
            .unwrap_or_else(|e| panic!("{mode}: {e:#}"));
        let rest = state.absorb(outs);
        assert_eq!(state.step_count(), 1, "{mode}");
        assert_ne!(state.theta.f32s().unwrap(), theta_before.as_slice());
        let nll = rest[0].scalar_f32().unwrap();
        let per_tok = nll / cfg.n_tokens as f32;
        assert!((per_tok - (cfg.vocab_size as f32).ln()).abs() < 1.0,
                "{mode}: loss/token {per_tok}");
        // loads: (L, m), each layer sums to n*k
        let loads = rest[1].f32s().unwrap();
        for l in 0..cfg.n_layers {
            let s: f32 =
                loads[l * cfg.n_experts..(l + 1) * cfg.n_experts].iter()
                    .sum();
            assert_eq!(s as usize, cfg.n_tokens * cfg.top_k, "{mode} l{l}");
        }
        // route_state behavior per mode
        let q = state.route_state.f32s().unwrap();
        match mode {
            "aux" => assert!(q.iter().all(|&x| x == 0.0)),
            "lossfree" => assert!(q.iter().all(|&x| x.abs() <= 1.1e-3)),
            _ => assert!(q.iter().any(|&x| x > 0.0)),
        }
    }
}

#[test]
fn train_step_is_deterministic() {
    let Some(engine) = engine() else { return };
    let cfg = engine.manifest().config("tiny").unwrap().clone();
    let art = engine.manifest().train_artifact("tiny", "bip", 4).unwrap();
    let tokens = tiny_tokens(&engine, 9);
    let run = || {
        let mut state = bip_moe::train::state::TrainState::fresh(
            init_theta(&engine, 7), &cfg);
        let outs = engine.run(art, &state.as_inputs(tokens.clone())).unwrap();
        let rest = state.absorb(outs);
        (state.theta.f32s().unwrap().to_vec(),
         rest[0].scalar_f32().unwrap())
    };
    let (t1, l1) = run();
    let (t2, l2) = run();
    assert_eq!(l1, l2);
    assert_eq!(t1, t2);
}

#[test]
fn eval_step_agrees_with_frozen_semantics() {
    let Some(engine) = engine() else { return };
    let cfg = engine.manifest().config("tiny").unwrap().clone();
    let eval_art = engine.manifest().find("tiny", "eval", "bip", None)
        .unwrap();
    let theta = init_theta(&engine, 0);
    let tokens = tiny_tokens(&engine, 5);
    let q = Tensor::zeros_f32(&[cfg.n_layers, cfg.n_experts]);
    let a = engine
        .run(eval_art, &[theta.clone(), q.clone(), tokens.clone()])
        .unwrap();
    let b = engine.run(eval_art, &[theta, q, tokens]).unwrap();
    assert_eq!(a[0].scalar_f32().unwrap(), b[0].scalar_f32().unwrap());
    assert!(a[0].scalar_f32().unwrap() > 0.0);
}

/// The L1<->L3 equivalence test: the q vector the in-graph Pallas kernel
/// computes for layer 0 must match the host-side dual solver run on the
/// probe artifact's scores (same math, two implementations).
#[test]
fn in_graph_bip_dual_matches_host_solver() {
    let Some(engine) = engine() else { return };
    let cfg = engine.manifest().config("tiny").unwrap().clone();
    let Ok(probe) = engine.manifest().find("tiny", "probe", "bip", None)
    else {
        eprintln!("skipping: probe artifact not built");
        return;
    };
    let train_art =
        engine.manifest().train_artifact("tiny", "bip", 4).unwrap();
    let theta = init_theta(&engine, 0);
    let tokens = tiny_tokens(&engine, 11);
    let q0 = Tensor::zeros_f32(&[cfg.n_layers, cfg.n_experts]);

    // layer-0 router scores via the probe artifact
    let scores = engine
        .run(probe, &[theta.clone(), q0.clone(), tokens.clone()])
        .unwrap()
        .pop()
        .unwrap();
    let inst = Instance {
        n: cfg.n_tokens,
        m: cfg.n_experts,
        k: cfg.top_k,
        cap: cfg.expert_cap,
        scores: scores.f32s().unwrap().to_vec(),
    };
    let mut host = DualState::new(cfg.n_experts);
    host.update(&inst, 4); // tiny bip_T = 4

    // in-graph q for layer 0 comes back in the train step's route_state
    let mut state = bip_moe::train::state::TrainState::fresh(theta, &cfg);
    let outs = engine.run(train_art, &state.as_inputs(tokens)).unwrap();
    state.absorb(outs);
    let q_graph = &state.route_state.f32s().unwrap()[..cfg.n_experts];

    for (j, (&hq, &gq)) in host.q.iter().zip(q_graph).enumerate() {
        assert!(
            (hq - gq).abs() < 1e-5,
            "expert {j}: host {hq} vs graph {gq}"
        );
    }
}

#[test]
fn engine_caches_compilations() {
    let Some(engine) = engine() else { return };
    let art = engine.manifest().find("tiny", "init", "-", None).unwrap();
    engine.run(art, &[Tensor::scalar_i32(0)]).unwrap();
    let compiles_after_first = engine.stats().compiles;
    engine.run(art, &[Tensor::scalar_i32(1)]).unwrap();
    assert_eq!(engine.stats().compiles, compiles_after_first);
    assert_eq!(engine.stats().executions, 2);
}

#[test]
fn engine_rejects_bad_inputs() {
    let Some(engine) = engine() else { return };
    let art = engine.manifest().train_artifact("tiny", "bip", 4).unwrap();
    // wrong arity
    assert!(engine.run(art, &[Tensor::scalar_i32(0)]).is_err());
    // wrong dtype in position 0
    let cfg = engine.manifest().config("tiny").unwrap();
    let mut inputs = vec![
        Tensor::from_i32(&[cfg.theta_size], vec![0; cfg.theta_size]),
        Tensor::zeros_f32(&[cfg.theta_size]),
        Tensor::zeros_f32(&[cfg.theta_size]),
        Tensor::scalar_i32(0),
        Tensor::zeros_f32(&[cfg.n_layers, cfg.n_experts]),
        tiny_tokens(&engine, 0),
    ];
    assert!(engine.run(art, &inputs).is_err());
    // wrong shape
    inputs[0] = Tensor::zeros_f32(&[cfg.theta_size + 1]);
    assert!(engine.run(art, &inputs).is_err());
}
