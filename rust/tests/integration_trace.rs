//! Record → replay determinism, byte-format round trips, and the
//! counterfactual diff invariants the ISSUE pins:
//!
//!   * for every (scenario, policy): replaying a recorded trace
//!     reproduces the completion log field-for-field (the R = 1
//!     analogue of the replica equivalence tests);
//!   * the same holds through a save/load byte round trip, and for a
//!     replicated (R > 1, threaded) recording with sync events;
//!   * re-routing a trace under its *own* policy is the identity
//!     counterfactual: top-K agreement 1.0, zero MaxVio delta, equal
//!     SLO percentiles;
//!   * re-routing a greedy recording under the BIP policies recovers
//!     the paper's balance ordering on the very same token stream.

use bip_moe::serve::{
    run_replicated_with, run_scenario, run_scenario_with, Policy,
    ReplicaConfig, RouterConfig, SchedulerConfig, Scenario, ServeConfig,
    TrafficConfig, TrafficGenerator,
};
use bip_moe::trace::{
    diff_policies, replay, reroute, Trace, TraceRecorder,
};

fn config(
    scenario: Scenario,
    policy: Policy,
    n_requests: usize,
) -> ServeConfig {
    ServeConfig::new(
        TrafficConfig {
            scenario,
            n_requests,
            rate_per_s: 80_000.0,
            n_layers: 2,
            slo_us: 25_000,
            seed: 17,
            ..Default::default()
        },
        SchedulerConfig {
            queue_cap: 256,
            batch_max: 32,
            max_wait_us: 1_500,
            drop_expired: true,
        },
        RouterConfig::default(),
        policy,
    )
}

fn record_single(cfg: &ServeConfig) -> Trace {
    let rcfg = ReplicaConfig { replicas: 1, threads: 1, sync_every: 0 };
    let mut rec = TraceRecorder::new(cfg, &rcfg);
    run_scenario_with(
        cfg,
        TrafficGenerator::new(cfg.traffic.clone()),
        Some(&mut rec),
    );
    rec.into_trace()
}

#[test]
fn every_scenario_policy_replays_bit_identically() {
    // the determinism property: record once, replay from the trace,
    // completions must match field-for-field
    for scenario in Scenario::all() {
        for policy in Policy::all() {
            let cfg = config(scenario, policy, 384);
            let trace = record_single(&cfg);
            assert!(
                !trace.frames.is_empty(),
                "{}/{}: nothing recorded",
                scenario.name(),
                policy.name()
            );
            assert_eq!(
                trace.completions.len() as u64,
                trace.routed_tokens(),
                "{}/{}: every batched request completes",
                scenario.name(),
                policy.name()
            );
            let rep = replay(&trace);
            assert!(
                rep.mismatches.is_empty(),
                "{}/{}: {:?}",
                scenario.name(),
                policy.name(),
                rep.mismatches
            );
            assert_eq!(rep.completions, trace.completions);
        }
    }
}

#[test]
fn recording_does_not_change_the_run() {
    // the Option<recorder> seam must be invisible: the recorded run's
    // outcome equals a bare run_scenario on the same config
    for policy in [Policy::Greedy, Policy::Online, Policy::BipBatch] {
        let cfg = config(Scenario::Bursty, policy, 512);
        let bare = run_scenario(&cfg);
        let rcfg =
            ReplicaConfig { replicas: 1, threads: 1, sync_every: 0 };
        let mut rec = TraceRecorder::new(&cfg, &rcfg);
        let recorded = run_scenario_with(
            &cfg,
            TrafficGenerator::new(cfg.traffic.clone()),
            Some(&mut rec),
        );
        assert_eq!(bare.completions, recorded.completions, "{policy:?}");
        assert_eq!(
            bare.report.avg_max_vio, recorded.report.avg_max_vio,
            "{policy:?}"
        );
        assert_eq!(bare.report.p99_ms, recorded.report.p99_ms);
        let trace = rec.into_trace();
        assert_eq!(trace.arrivals.len(), 512, "every offer is recorded");
    }
}

#[test]
fn traces_survive_a_byte_round_trip_and_replay_from_disk() {
    let cfg = config(Scenario::MultiTenant, Policy::Approx, 300);
    let trace = record_single(&cfg);
    let bytes = trace.to_bytes();
    let back = Trace::from_bytes(&bytes).expect("decode");
    assert_eq!(back, trace, "byte round trip must be lossless");

    let dir = std::env::temp_dir();
    let path = dir.join(format!("bipmoe-trace-{}.bin", std::process::id()));
    trace.save(&path).expect("save");
    let loaded = Trace::load(&path).expect("load");
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded, trace);
    let rep = replay(&loaded);
    assert!(rep.mismatches.is_empty(), "{:?}", rep.mismatches);
}

#[test]
fn replicated_recordings_replay_bit_identically() {
    // offered well above one server's service rate so several replicas
    // genuinely engage (mirrors the replica.rs engine tests)
    let mut cfg = config(Scenario::Bursty, Policy::Online, 1200);
    cfg.traffic.rate_per_s = 250_000.0;
    cfg.traffic.slo_us = 500_000;
    let rcfg = ReplicaConfig { replicas: 3, threads: 2, sync_every: 8 };
    let mut rec = TraceRecorder::new(&cfg, &rcfg);
    let out = run_replicated_with(
        &cfg,
        &rcfg,
        TrafficGenerator::new(cfg.traffic.clone()),
        Some(&mut rec),
    );
    let trace = rec.into_trace();
    assert_eq!(trace.completions.len() as u64, out.report.completed);
    assert_eq!(trace.frames.len() as u64, out.batches);
    assert_eq!(trace.syncs.len(), out.syncs.len());
    let replicas_seen: std::collections::BTreeSet<u32> =
        trace.frames.iter().map(|f| f.replica).collect();
    assert!(
        replicas_seen.len() > 1,
        "frames must be tagged by replica: {replicas_seen:?}"
    );

    let rep = replay(&trace);
    assert!(rep.mismatches.is_empty(), "{:?}", rep.mismatches);
    assert_eq!(rep.completions, trace.completions);
    assert_eq!(rep.report.avg_max_vio, out.report.avg_max_vio);
}

#[test]
fn same_policy_reroute_is_the_identity_counterfactual() {
    for policy in [Policy::Greedy, Policy::LossFree, Policy::Online] {
        let cfg = config(Scenario::Steady, policy, 448);
        let trace = record_single(&cfg);
        let d = reroute(&trace, policy).expect("reroute");
        assert_eq!(d.topk_agreement, 1.0, "{policy:?}");
        assert_eq!(d.vio_delta_mean, 0.0, "{policy:?}");
        assert_eq!(d.avg_max_vio, d.avg_max_vio_recorded, "{policy:?}");
        assert_eq!(d.sup_max_vio, d.sup_max_vio_recorded);
        // frozen batching over identical service times reproduces the
        // recorded latency distribution exactly
        assert_eq!(d.p50_ms, d.p50_ms_recorded, "{policy:?}");
        assert_eq!(d.p99_ms, d.p99_ms_recorded, "{policy:?}");
        assert_eq!(d.slo_violations, d.slo_violations_recorded);
        assert_eq!(d.scenario, "replayed");
        assert_eq!(d.recorded_policy, d.policy);
    }
}

#[test]
fn bip_counterfactuals_beat_the_recorded_greedy_stream() {
    // the acceptance shape: diff a greedy recording under the BIP
    // family + lossfree; every BIP policy must come back better
    // balanced than the recorded greedy routing of the *same* tokens
    let cfg = config(Scenario::Steady, Policy::Greedy, 768);
    let trace = record_single(&cfg);
    let diffs = diff_policies(
        &trace,
        &[
            Policy::BipBatch,
            Policy::LossFree,
            Policy::Online,
            Policy::Approx,
        ],
    )
    .expect("diff");
    assert_eq!(diffs.len(), 4);
    let recorded = diffs[0].avg_max_vio_recorded;
    for d in &diffs {
        assert_eq!(d.recorded_policy, "greedy");
        assert_eq!(d.avg_max_vio_recorded, recorded, "{}", d.policy);
        assert!(d.topk_agreement > 0.0 && d.topk_agreement <= 1.0);
        assert!(d.avg_max_vio.is_finite());
        assert!(d.p99_ms.is_finite());
    }
    for d in diffs.iter().filter(|d| d.policy.starts_with("bip")) {
        assert!(
            d.avg_max_vio < recorded,
            "{}: counterfactual vio {} !< recorded greedy {recorded}",
            d.policy,
            d.avg_max_vio
        );
        assert!(
            d.vio_delta_mean < 0.0,
            "{}: delta {}",
            d.policy,
            d.vio_delta_mean
        );
    }
}

#[test]
fn corrupted_traces_are_rejected_cleanly() {
    let cfg = config(Scenario::Steady, Policy::Greedy, 64);
    let trace = record_single(&cfg);
    let mut bytes = trace.to_bytes();
    // truncation mid-stream
    bytes.truncate(bytes.len() / 2);
    assert!(Trace::from_bytes(&bytes).is_err());
    // bad magic
    let mut bytes = trace.to_bytes();
    bytes[0] = b'X';
    assert!(Trace::from_bytes(&bytes).is_err());
    // future version
    let mut bytes = trace.to_bytes();
    bytes[4] = 0xfe;
    let err = Trace::from_bytes(&bytes).unwrap_err();
    assert!(format!("{err}").contains("version"), "{err}");
}

#[test]
fn json_export_mirrors_the_trace() {
    use bip_moe::util::Json;
    let cfg = config(Scenario::Steady, Policy::Online, 96);
    let trace = record_single(&cfg);
    let doc = trace.to_json();
    // round-trips through the emitter/parser
    let re = Json::parse(&doc.to_string()).expect("reparse");
    assert_eq!(
        re.path("meta.scenario").unwrap().as_str(),
        Some("steady")
    );
    assert_eq!(
        re.path("meta.policy").unwrap().as_str(),
        Some("bip-online")
    );
    assert_eq!(
        re.path("arrivals").unwrap().as_arr().unwrap().len(),
        trace.arrivals.len()
    );
    assert_eq!(
        re.path("frames").unwrap().as_arr().unwrap().len(),
        trace.frames.len()
    );
    assert_eq!(
        re.path("completions").unwrap().as_arr().unwrap().len(),
        trace.completions.len()
    );
    // spot-check one frame's ids against the source
    let ids = re.path("frames[0].ids").unwrap().as_arr().unwrap();
    assert_eq!(ids.len(), trace.frames[0].ids.len());
    assert_eq!(
        ids[0].as_usize(),
        Some(trace.frames[0].ids[0] as usize)
    );
}
