//! End-to-end telemetry (ISSUE 6): a real serving run must light up
//! the global registry's core series, the exposition surfaces must
//! agree with it, and a v3 trace must carry the scrape through a
//! byte round trip.
//!
//! Every assertion on the global registry is a *delta* (after >=
//! before) or an existence check — the tests in this binary run in
//! parallel and all feed the same process-wide registry.

use bip_moe::serve::{
    self, Policy, RouterConfig, Scenario, SchedulerConfig, ServeConfig,
    TrafficConfig, TrafficGenerator,
};
use bip_moe::telemetry::{self, Counter, Gauge, Hist};
use bip_moe::trace::{Trace, TraceRecorder};

fn small_cfg(policy: Policy, seed: u64) -> ServeConfig {
    ServeConfig::new(
        TrafficConfig {
            scenario: Scenario::Steady,
            n_requests: 512,
            seed,
            ..Default::default()
        },
        SchedulerConfig::default(),
        RouterConfig::default(),
        policy,
    )
}

#[test]
fn serve_run_lights_up_the_core_series() {
    let before = telemetry::scrape(telemetry::global());
    let cfg = small_cfg(Policy::Online, 11);
    let out = serve::run_scenario(&cfg);
    assert!(out.report.completed > 0, "scenario must actually serve");
    let after = telemetry::scrape(telemetry::global());

    for c in [
        Counter::RouterBatches,
        Counter::RouterTokens,
        Counter::SolverSolves,
        Counter::SolverIterations,
    ] {
        assert!(
            after.counter(c) > before.counter(c),
            "{} must advance across a served run",
            c.name()
        );
    }
    assert!(
        after.hist(Hist::RouteBatchSeconds).count()
            > before.hist(Hist::RouteBatchSeconds).count(),
        "route spans must land in the route_batch_seconds histogram"
    );
    assert!(
        after.gauge(Gauge::RouterExperts) > 0.0,
        "router construction must publish the expert count"
    );
    assert!(
        !after.expert_tokens.is_empty()
            && after.expert_tokens.iter().flatten().any(|&v| v > 0),
        "per-(layer, expert) token counters must accumulate"
    );
}

#[test]
fn exposition_surfaces_agree_with_the_registry() {
    // drive at least one batch so the scrape is non-trivial even if
    // this test runs first
    let cfg = small_cfg(Policy::Greedy, 23);
    serve::run_scenario(&cfg);
    let snap = telemetry::scrape(telemetry::global());

    let text = snap.to_prometheus();
    assert!(text.contains("# TYPE bip_moe_router_batches_total counter"));
    assert!(text.contains("bip_moe_route_batch_seconds_bucket"));

    let json = snap.to_json().to_string();
    let doc = bip_moe::util::Json::parse(&json)
        .expect("snapshot JSON must parse");
    assert_eq!(
        doc.path("format").and_then(|j| j.as_str()),
        Some(telemetry::SNAPSHOT_FORMAT)
    );
    let batches = doc
        .path("counters.router_batches_total")
        .and_then(|j| j.as_f64())
        .expect("counters must expose router_batches_total");
    assert_eq!(batches, snap.counter(Counter::RouterBatches) as f64);

    // file writer: extension picks the format
    let dir = std::env::temp_dir();
    let jpath = dir.join("bip_moe_itest_metrics.json");
    let ppath = dir.join("bip_moe_itest_metrics.prom");
    snap.write(&jpath).unwrap();
    snap.write(&ppath).unwrap();
    let jbody = std::fs::read_to_string(&jpath).unwrap();
    assert!(bip_moe::util::Json::parse(&jbody).is_ok());
    let pbody = std::fs::read_to_string(&ppath).unwrap();
    assert!(pbody.starts_with("# HELP bip_moe_"));
    let _ = std::fs::remove_file(&jpath);
    let _ = std::fs::remove_file(&ppath);
}

#[test]
fn recorded_trace_carries_telemetry_through_bytes() {
    let cfg = small_cfg(Policy::Online, 37);
    let rcfg = serve::ReplicaConfig::default();
    let mut rec = TraceRecorder::new(&cfg, &rcfg);
    serve::run_scenario_with(
        &cfg,
        TrafficGenerator::new(cfg.traffic.clone()),
        Some(&mut rec),
    );
    rec.capture_telemetry();
    let trace = rec.into_trace();
    assert!(
        !trace.telemetry.is_empty(),
        "capture_telemetry must embed the scrape"
    );
    let batches = trace
        .telemetry
        .iter()
        .find(|(n, _)| n == "router_batches_total")
        .map(|&(_, v)| v)
        .expect("scrape must include router_batches_total");
    assert!(batches > 0.0);

    let back = Trace::from_bytes(&trace.to_bytes()).unwrap();
    assert_eq!(back.telemetry, trace.telemetry);
    assert_eq!(back.version, bip_moe::trace::TRACE_VERSION);
}

/// ISSUE 8 satellite: the span ring under a many-writer storm with a
/// concurrent scraper. Slots are single `AtomicU64` stores, so a
/// reader must never observe a torn record (nonsense kind, negative
/// or absurd duration), the ring must end up full and fully
/// parseable, and the span-fed histogram must catch every drop (ring
/// loss is bounded by capacity; histogram loss must be zero).
#[test]
fn span_ring_survives_many_writers_under_concurrent_scrape() {
    use bip_moe::telemetry::span::RING_SLOTS;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    const WRITERS: usize = 8;
    const SPANS_EACH: u64 = 2_000;

    telemetry::set_enabled(true);
    let before_hist = telemetry::scrape(telemetry::global())
        .hist(Hist::ReplicaDispatchSeconds)
        .count();

    let stop = Arc::new(AtomicBool::new(false));
    let reader = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut scrapes = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let spans = telemetry::recent_spans(RING_SLOTS);
                assert!(spans.len() <= RING_SLOTS);
                for s in &spans {
                    assert!(
                        s.secs >= 0.0 && s.secs < 3600.0,
                        "torn span duration: {s:?}"
                    );
                    assert!(
                        s.at_secs >= 0.0,
                        "torn span end time: {s:?}"
                    );
                }
                scrapes += 1;
            }
            scrapes
        })
    };

    let writers: Vec<_> = (0..WRITERS)
        .map(|_| {
            std::thread::spawn(|| {
                for _ in 0..SPANS_EACH {
                    let span = telemetry::Span::enter(
                        telemetry::SpanKind::ReplicaDispatch,
                    );
                    std::hint::black_box(&span);
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    let scrapes = reader.join().unwrap();
    assert!(scrapes > 0, "the scraper must have run concurrently");

    // 16k writes into 256 slots: the ring is full and every slot
    // parses back into a valid record — an interrupted writer leaves
    // the slot's previous (valid) value, never a torn one
    assert_eq!(
        telemetry::recent_spans(RING_SLOTS).len(),
        RING_SLOTS,
        "the ring must be full and fully parseable after the storm"
    );

    // zero histogram loss: every span drop observed exactly once
    // (delta, not absolute — other tests in this binary also dispatch)
    let after_hist = telemetry::scrape(telemetry::global())
        .hist(Hist::ReplicaDispatchSeconds)
        .count();
    assert!(
        after_hist - before_hist >= WRITERS as u64 * SPANS_EACH,
        "histogram must catch all {} spans (saw {})",
        WRITERS as u64 * SPANS_EACH,
        after_hist - before_hist
    );
}
