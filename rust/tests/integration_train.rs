//! End-to-end coordinator tests: full TrainDriver runs on the tiny config
//! (PJRT execution, data pipeline, metrics, checkpointing, reports).
//! Skipped gracefully when artifacts/ is absent.

use std::path::{Path, PathBuf};

use bip_moe::runtime::Engine;
use bip_moe::train::state::TrainState;
use bip_moe::train::TrainDriver;

fn engine() -> Option<Engine> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(Engine::new(&dir).expect("engine"))
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn tmp_reports(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "bipmoe-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn full_run_all_modes_records_everything() {
    let Some(engine) = engine() else { return };
    let reports = tmp_reports("modes");
    for (mode, t) in [("aux", 0), ("lossfree", 0), ("bip", 4)] {
        let mut driver = TrainDriver::new("tiny", mode, t, 6);
        driver.eval_batches = 2;
        let outcome = driver.run(&engine).unwrap();
        assert_eq!(outcome.recorder.balance.batches(), 6);
        assert!(outcome.perplexity.is_finite() && outcome.perplexity > 1.0);
        assert_eq!(outcome.sim.steps, 6);
        assert!(outcome.sim.total_seconds > 0.0);
        let out = outcome.dump(&reports).unwrap();
        assert!(out.join("run.json").exists());
        assert!(out.join("maxvio_global.csv").exists());
        assert!(out.join("maxvio_layer2.csv").exists());
    }
    let _ = std::fs::remove_dir_all(&reports);
}

#[test]
fn training_reduces_loss_over_repeated_data() {
    let Some(engine) = engine() else { return };
    // 60 steps over the deterministic loader; the tiny model learns
    // slowly (lr warmup eats the first 4 steps) but the trend must be
    // clearly downward
    let mut driver = TrainDriver::new("tiny", "bip", 4, 60);
    driver.eval_batches = 2;
    let outcome = driver.run(&engine).unwrap();
    let losses = &outcome.recorder.loss_series;
    let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(tail < head - 0.03,
            "loss did not improve: head {head} -> tail {tail}");
}

#[test]
fn bip_balances_better_than_aux_from_step_one() {
    let Some(engine) = engine() else { return };
    // the paper's central claim, observable even on tiny: the FIRST batch
    // is already balanced under BIP, while aux-loss starts unbalanced
    let mut aux = TrainDriver::new("tiny", "aux", 0, 4);
    aux.eval_batches = 1;
    let mut bip = TrainDriver::new("tiny", "bip", 4, 4);
    bip.eval_batches = 1;
    let out_aux = aux.run(&engine).unwrap();
    let out_bip = bip.run(&engine).unwrap();
    let first_aux = out_aux.recorder.balance.global_series[0];
    let first_bip = out_bip.recorder.balance.global_series[0];
    assert!(first_bip <= first_aux + 1e-6,
            "step-1 balance: bip {first_bip} vs aux {first_aux}");
    assert!(out_bip.recorder.balance.avg_max_vio()
            <= out_aux.recorder.balance.avg_max_vio() + 1e-6);
}

#[test]
fn runs_are_reproducible() {
    let Some(engine) = engine() else { return };
    let mk = || {
        let mut d = TrainDriver::new("tiny", "lossfree", 0, 5);
        d.eval_batches = 2;
        d
    };
    let a = mk().run(&engine).unwrap();
    let b = mk().run(&engine).unwrap();
    assert_eq!(a.recorder.loss_series, b.recorder.loss_series);
    assert_eq!(a.perplexity, b.perplexity);
    assert_eq!(a.recorder.balance.global_series,
               b.recorder.balance.global_series);
}

#[test]
fn checkpoint_resume_matches_eval() {
    let Some(engine) = engine() else { return };
    let mut driver = TrainDriver::new("tiny", "bip", 4, 5);
    driver.eval_batches = 2;
    let outcome = driver.run(&engine).unwrap();
    let path = std::env::temp_dir().join(format!(
        "bipmoe-it-ckpt-{}.bin", std::process::id()));
    outcome.state.save(&path, "tiny", "bip").unwrap();
    let (loaded, config, mode) = TrainState::load(&path).unwrap();
    assert_eq!((config.as_str(), mode.as_str()), ("tiny", "bip"));
    assert_eq!(loaded.step_count(), 5);
    assert_eq!(loaded.theta, outcome.state.theta);
    // evaluating the loaded state reproduces the driver's perplexity
    let cfg = engine.manifest().config("tiny").unwrap().clone();
    let eval_art =
        engine.manifest().find("tiny", "eval", "bip", None).unwrap();
    let corpus = std::sync::Arc::new(bip_moe::data::Corpus::build(
        bip_moe::data::CorpusSpec {
            vocab_size: cfg.vocab_size,
            ..Default::default()
        },
    ));
    let loader = bip_moe::data::Loader::new(
        corpus, cfg.batch_size, cfg.seq_len, bip_moe::data::Split::Test);
    let mut ppl = bip_moe::metrics::Perplexity::default();
    for i in 0..2 {
        let batch = loader.batch(i);
        let tokens = bip_moe::runtime::Tensor::from_i32(
            &[cfg.batch_size, cfg.seq_len + 1], batch.tokens);
        let outs = engine
            .run(eval_art,
                 &[loaded.theta.clone(), loaded.route_state.clone(),
                   tokens])
            .unwrap();
        ppl.push(outs[0].scalar_f32().unwrap() as f64,
                 cfg.n_tokens as u64);
    }
    assert!((ppl.value() - outcome.perplexity).abs() < 1e-3,
            "{} vs {}", ppl.value(), outcome.perplexity);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn drops_never_happen_under_bip() {
    let Some(engine) = engine() else { return };
    let mut driver = TrainDriver::new("tiny", "bip", 4, 8);
    driver.eval_batches = 1;
    let outcome = driver.run(&engine).unwrap();
    // BIP keeps loads <= n*k/m < capacity, so the dispatch buffer can
    // never overflow — an operational guarantee the baselines lack
    assert!(outcome.recorder.drop_series.iter().all(|&d| d == 0.0),
            "{:?}", outcome.recorder.drop_series);
}
