//! Self-test for the `analysis/` static lint suite (ISSUE 7).
//!
//! Two halves:
//! 1. **Fixtures** — every lint is proven *live* by an in-memory
//!    [`SourceSet`] whose planted violation it must catch (and whose
//!    annotated twin it must pass). A lint that silently stops firing
//!    fails here, not in some future regression.
//! 2. **The tree itself** — the whole crate (`src/` + `benches/`)
//!    lexes, models, and lints clean under the checked-in waivers and
//!    unsafe inventory. This is the same run CI gates merges on via
//!    `bip-moe lint --deny`.

use std::path::Path;

use bip_moe::analysis::{run, SourceSet};

/// Build a SourceSet from fixture files with empty policy files.
fn set(files: &[(&str, &str)]) -> SourceSet {
    SourceSet {
        files: files
            .iter()
            .map(|(p, s)| (p.to_string(), s.to_string()))
            .collect(),
        waivers: String::new(),
        inventory: String::new(),
    }
}

fn lints_of(findings: &[bip_moe::analysis::Finding]) -> Vec<&str> {
    findings.iter().map(|f| f.lint.as_str()).collect()
}

// ---------------------------------------------------------------- tree

#[test]
fn whole_tree_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let srcs = SourceSet::from_root(root).expect("crate sources readable");
    assert!(
        srcs.files.len() > 30,
        "expected the whole crate, got {} files",
        srcs.files.len()
    );
    let findings = run(&srcs, None);
    assert!(
        findings.is_empty(),
        "tree must lint clean under checked-in waivers; got:\n{}",
        bip_moe::analysis::render_text(&findings)
    );
}

#[test]
fn whole_tree_lexes_and_round_trips() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let srcs = SourceSet::from_root(root).expect("crate sources readable");
    for (rel, src) in &srcs.files {
        let toks = match bip_moe::analysis::lexer::lex(src) {
            Ok(t) => t,
            Err(e) => panic!("{rel}: {e}"),
        };
        // round-trip: the lexer must neither drop nor duplicate any
        // non-whitespace char anywhere in the crate
        let got: String = toks
            .iter()
            .flat_map(|t| t.text.chars())
            .filter(|c| !c.is_whitespace())
            .collect();
        let want: String =
            src.chars().filter(|c| !c.is_whitespace()).collect();
        assert_eq!(got, want, "{rel}: lexer round-trip drift");
    }
}

// ------------------------------------------------------------ fixtures

#[test]
fn fires_hot_path_alloc() {
    // route_batch_into is a hot root; the vec! must be flagged, both
    // directly and transitively through a helper call
    let dirty = set(&[(
        "src/serve/router.rs",
        "pub fn route_batch_into(n: usize) -> usize { helper(n) }\n\
         fn helper(n: usize) -> usize { let v = vec![0u32; n]; v.len() }\n",
    )]);
    let f = run(&dirty, None);
    assert_eq!(lints_of(&f), vec!["hot-path-alloc"], "{f:?}");
    assert_eq!(f[0].line, 2);
    assert!(f[0].msg.contains("vec!"), "{}", f[0].msg);

    // a `// COLD` marker stops the walk at the documented seam
    let cold = set(&[(
        "src/serve/router.rs",
        "pub fn route_batch_into(n: usize) -> usize { n }\n\
         // COLD: allocating compat seam\n\
         fn helper(n: usize) -> usize { let v = vec![0u32; n]; v.len() }\n",
    )]);
    assert!(run(&cold, None).is_empty());
}

#[test]
fn fires_unsafe_audit() {
    let dirty = set(&[(
        "src/util/x.rs",
        "pub fn f(p: *const u8) -> u8 { unsafe { *p } }\n",
    )]);
    let f = run(&dirty, None);
    // missing SAFETY comment + missing inventory entry
    assert_eq!(lints_of(&f), vec!["unsafe-audit", "unsafe-audit"], "{f:?}");
    assert!(f[0].msg.contains("SAFETY"), "{}", f[0].msg);
    assert!(f[1].msg.contains("inventory"), "{}", f[1].msg);

    let mut clean = set(&[(
        "src/util/x.rs",
        "pub fn f(p: *const u8) -> u8 {\n\
             // SAFETY: caller guarantees p is valid\n\
             unsafe { *p }\n\
         }\n",
    )]);
    clean.inventory = "src/util/x.rs 1\n".to_string();
    assert!(run(&clean, None).is_empty());

    // census drift in the other direction: listed but unsafe-free
    let mut stale = set(&[("src/util/x.rs", "pub fn f() {}\n")]);
    stale.inventory = "src/util/x.rs 1\n".to_string();
    let f = run(&stale, None);
    assert_eq!(lints_of(&f), vec!["unsafe-audit"], "{f:?}");
    assert!(f[0].msg.contains("no unsafe code"), "{}", f[0].msg);
}

#[test]
fn fires_panic_path() {
    let dirty = set(&[(
        "src/bip/x.rs",
        "pub fn f(v: &[u32]) -> u32 { v.first().copied().unwrap() }\n\
         pub fn g(v: &[u32]) -> u32 { v[0] }\n\
         pub fn h() { unreachable!(\"nope\") }\n",
    )]);
    let f = run(&dirty, None);
    assert_eq!(
        lints_of(&f),
        vec!["panic-path", "panic-path", "panic-path"],
        "{f:?}"
    );

    // LINT-ALLOW and #[cfg(test)] both suppress
    let clean = set(&[(
        "src/bip/x.rs",
        "pub fn f(v: &[u32]) -> u32 {\n\
             // LINT-ALLOW(panic): caller checks non-empty\n\
             v.first().copied().unwrap()\n\
         }\n\
         #[cfg(test)]\n\
         mod tests {\n\
             fn t(v: &[u32]) -> u32 { v[0] }\n\
         }\n",
    )]);
    assert!(run(&clean, None).is_empty());

    // outside the serving dirs the same code is not in scope
    let out_of_scope = set(&[(
        "src/util/x.rs",
        "pub fn f(v: &[u32]) -> u32 { v[0] }\n",
    )]);
    assert!(run(&out_of_scope, None).is_empty());
}

#[test]
fn fires_telemetry_naming() {
    let dirty = set(&[(
        "src/telemetry/registry.rs",
        "impl Counter {\n\
             pub fn name(self) -> &'static str {\n\
                 match self {\n\
                     Counter::A => \"requests_total\",\n\
                     Counter::B => \"requests_total\",\n\
                     Counter::C => \"Bad-Name\",\n\
                 }\n\
             }\n\
             pub fn help(self) -> &'static str {\n\
                 match self {\n\
                     Counter::A => \"requests\",\n\
                     Counter::B => \"\",\n\
                 }\n\
             }\n\
         }\n",
    )]);
    let f = run(&dirty, None);
    let lints = lints_of(&f);
    // duplicate name + bad charset + empty help + count mismatch
    assert_eq!(lints.len(), 4, "{f:?}");
    assert!(lints.iter().all(|l| *l == "telemetry-naming"), "{f:?}");
    assert!(f.iter().any(|x| x.msg.contains("duplicate")), "{f:?}");
    assert!(f.iter().any(|x| x.msg.contains("Bad-Name")), "{f:?}");
    assert!(f.iter().any(|x| x.msg.contains("empty help")), "{f:?}");
    assert!(f.iter().any(|x| x.msg.contains("3 metric names")), "{f:?}");
}

#[test]
fn fires_lock_discipline() {
    let dirty = set(&[(
        "src/util/x.rs",
        "// HOT: per-batch\n\
         pub fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap() }\n",
    )]);
    let f = run(&dirty, None);
    // the `.lock()` call in the body fires; the Mutex in the signature
    // is outside the body span and intentionally does not
    assert_eq!(lints_of(&f), vec!["lock-discipline"], "{f:?}");

    // same body without the HOT marker is out of contract
    let unmarked = set(&[(
        "src/util/x.rs",
        "pub fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap() }\n",
    )]);
    assert!(run(&unmarked, None).is_empty());
}

#[test]
fn fires_bench_honesty() {
    let dirty = set(&[(
        "src/bench/x.rs",
        "pub fn dump(doc: &str) {\n\
             let path = format!(\"BENCH_{}.json\", \"x\");\n\
             std::fs::write(path, doc).ok();\n\
         }\n",
    )]);
    let f = run(&dirty, None);
    assert_eq!(lints_of(&f), vec!["bench-honesty"], "{f:?}");
    assert!(f[0].msg.contains("schema_version"), "{}", f[0].msg);

    let clean = set(&[(
        "src/bench/x.rs",
        "pub fn dump(doc: &str) {\n\
             let path = format!(\"BENCH_{}.json\", \"x\");\n\
             let doc = format!(\"{{\\\"schema_version\\\":1,{doc}}}\");\n\
             std::fs::write(path, doc).ok();\n\
         }\n",
    )]);
    assert!(run(&clean, None).is_empty());
}

// ------------------------------------------------------------- waivers

#[test]
fn waivers_suppress_and_go_stale() {
    let dirty_src = (
        "src/bip/x.rs",
        "pub fn f(v: &[u32]) -> u32 { v[0] }\n",
    );
    // keyed waiver with a reason suppresses the finding
    let mut s = set(&[dirty_src]);
    s.waivers = "panic-path src/bip/x.rs:1 bounds proven by caller\n".into();
    assert!(run(&s, None).is_empty());

    // a waiver with no reason is rejected (and suppresses nothing)
    let mut s = set(&[dirty_src]);
    s.waivers = "panic-path src/bip/x.rs:1\n".into();
    let f = run(&s, None);
    assert_eq!(lints_of(&f), vec!["panic-path", "waiver-syntax"], "{f:?}");
    assert!(f[1].msg.contains("reason"), "{}", f[1].msg);

    // a waiver whose line no longer matches is reported as stale
    let mut s = set(&[dirty_src]);
    s.waivers =
        "panic-path src/bip/x.rs:1 bounds proven by caller\n\
         panic-path src/bip/x.rs:99 drifted line key\n"
            .into();
    let f = run(&s, None);
    assert_eq!(lints_of(&f), vec!["stale-waiver"], "{f:?}");
    assert_eq!(f[0].line, 2, "stale report keys the waiver file line");
}

#[test]
fn filter_restricts_to_one_lint() {
    let s = set(&[(
        "src/bip/x.rs",
        "// HOT: marked\n\
         pub fn f(v: &[u32], m: &std::sync::Mutex<u32>) -> u32 {\n\
             let _ = m.lock();\n\
             v[0]\n\
         }\n",
    )]);
    let all = run(&s, None);
    assert_eq!(lints_of(&all), vec!["lock-discipline", "panic-path"], "{all:?}");
    let only = run(&s, Some("panic-path"));
    assert_eq!(lints_of(&only), vec!["panic-path"], "{only:?}");
}
