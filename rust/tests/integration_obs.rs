//! End-to-end observability (ISSUE 8): a planted routing collapse
//! must raise the early-warning alert and dump an incident whose
//! causal chain (request -> batch -> per-layer route -> solver exit)
//! is asserted field for field; a steady run must stay alert-free
//! (false-positive pin); and a flash crowd must NOT look like a
//! collapse (the detector discriminates load surges from
//! concentration).
//!
//! Everything lives in ONE test fn run sequentially: the causal event
//! ring and the telemetry registry are process-global, and batch
//! ordinals are only unique per router — concurrent serve runs in the
//! same binary would interleave events under colliding causal ids.

use bip_moe::obs::{
    event::{self, EventKind},
    AlertKind, DetectorConfig, Incident, ObsConfig, ObsController,
    RecorderConfig, Trigger, INCIDENT_VERSION,
};
use bip_moe::serve::{
    self, Policy, RouterConfig, Scenario, SchedulerConfig, ServeConfig,
    TrafficConfig,
};
use bip_moe::telemetry;

const N_REQUESTS: usize = 8192;
const N_LAYERS: usize = 4;

fn observed_cfg(
    scenario: Scenario,
    router: RouterConfig,
    seed: u64,
) -> ServeConfig {
    ServeConfig::new(
        TrafficConfig {
            scenario,
            n_requests: N_REQUESTS,
            seed,
            ..Default::default()
        },
        SchedulerConfig::default(),
        router,
        Policy::BipBatch,
    )
}

fn controller(dir: &std::path::Path, scenario: Scenario) -> ObsController {
    ObsController::new(ObsConfig {
        // 4 routed batches per detector tick: ~32 ticks over the run,
        // plenty past warmup (3) + sustain (2) for the mid-stream ramp
        tick_every: 4,
        detector: DetectorConfig::default(),
        recorder: RecorderConfig {
            out_dir: dir.to_path_buf(),
            scenario: scenario.name().to_string(),
            policy: Policy::BipBatch.name().to_string(),
            ..Default::default()
        },
    })
}

#[test]
fn planted_collapse_alerts_and_dumps_a_walkable_incident() {
    telemetry::set_enabled(true);
    let root = std::env::temp_dir()
        .join(format!("bip_moe_obs_itest_{}", std::process::id()));

    // ---- phase 1: planted collapse -------------------------------
    // Degraded traffic ramps the first m/8 experts mid-stream, and
    // t_iters = 0 disables the Algorithm 1 refinement: the router
    // greedily follows the skewed gate, so concentration and MaxVio
    // climb together — the paper-§1 collapse signature.
    let dir = root.join("degraded");
    let cfg = observed_cfg(
        Scenario::Degraded,
        RouterConfig { t_iters: 0, ..Default::default() },
        7,
    );
    let mut obs = controller(&dir, Scenario::Degraded);
    let out = serve::run_scenario_observed(&cfg, &mut obs);
    assert!(out.report.completed > 0, "degraded run must serve");
    assert!(
        obs.ticks() > DetectorConfig::default().warmup_ticks,
        "run too short for the detector to clear warmup"
    );

    let collapse = obs
        .alerts
        .iter()
        .find(|a| a.kind == AlertKind::RoutingCollapse)
        .expect("planted collapse must raise the early warning");
    assert!(collapse.tick > DetectorConfig::default().warmup_ticks);
    assert!((collapse.layer as usize) < N_LAYERS);
    assert!(
        collapse.score > DetectorConfig::default().share_threshold,
        "top-K share {} must cross the threshold",
        collapse.score
    );
    assert!(!collapse.detail.is_empty());

    assert!(!obs.incidents.is_empty(), "the alert must dump an incident");
    let fname = obs.incidents[0]
        .file_name()
        .expect("incident path has a file name")
        .to_string_lossy()
        .into_owned();
    assert!(
        fname.starts_with("incident-degraded-bip-batch-t")
            && fname.ends_with(".bipi"),
        "incident file name carries scenario/policy/tick: {fname}"
    );

    let inc = Incident::load(&obs.incidents[0]).expect("incident loads");
    assert_eq!(inc.header.version, INCIDENT_VERSION);
    assert!(!inc.header.crate_version.is_empty());
    assert_eq!(inc.header.scenario, "degraded");
    assert_eq!(inc.header.policy, "bip-batch");
    assert_eq!(inc.header.trigger, Trigger::Alert);
    assert!(!inc.header.reason.is_empty());
    assert!(inc.header.tick >= 1);
    assert!(!inc.alerts.is_empty(), "dump carries the alert feed");
    assert!(!inc.scrapes.is_empty(), "dump carries the scrape history");

    assert_causal_chain(&inc);

    // byte + file round trip: the BIPI codec is lossless
    let back =
        Incident::from_bytes(&inc.to_bytes()).expect("round trip parses");
    assert_eq!(back, inc);

    // ---- phase 2: steady false-positive pin ----------------------
    // Fresh detector, default solver: a balanced run must end with
    // zero alerts and zero incidents.
    let dir = root.join("steady");
    let cfg =
        observed_cfg(Scenario::Steady, RouterConfig::default(), 11);
    let mut obs = controller(&dir, Scenario::Steady);
    let out = serve::run_scenario_observed(&cfg, &mut obs);
    assert!(out.report.completed > 0, "steady run must serve");
    assert!(
        obs.ticks() > DetectorConfig::default().warmup_ticks,
        "steady run must clear warmup to make the pin meaningful"
    );
    assert!(
        obs.alerts.is_empty(),
        "steady serving must stay alert-free, got {:?}",
        obs.alerts
    );
    assert!(obs.incidents.is_empty());

    // ---- phase 3: flash crowd is not a collapse ------------------
    // A 6x mid-stream rate surge stresses the queue, but routing
    // stays balanced: whatever else fires, the collapse rule must not.
    let dir = root.join("flashcrowd");
    let cfg =
        observed_cfg(Scenario::FlashCrowd, RouterConfig::default(), 13);
    let mut obs = controller(&dir, Scenario::FlashCrowd);
    let out = serve::run_scenario_observed(&cfg, &mut obs);
    assert!(out.report.offered > 0, "flash crowd run must serve");
    assert!(
        obs.ticks() > DetectorConfig::default().warmup_ticks,
        "flash-crowd run must clear warmup"
    );
    assert!(
        !obs
            .alerts
            .iter()
            .any(|a| a.kind == AlertKind::RoutingCollapse),
        "a load surge must not read as routing collapse, got {:?}",
        obs.alerts
    );

    let _ = std::fs::remove_dir_all(&root);
}

/// Walk the last completed batch in the incident's event ring and
/// assert the full causal chain field for field: admission of the
/// first request -> BatchStart -> LayerRoute/SolverExit per layer ->
/// BatchDone, all under one batch ordinal, in seq order, replica 0.
fn assert_causal_chain(inc: &Incident) {
    let done = inc
        .events
        .iter()
        .rev()
        .find(|e| e.kind == EventKind::BatchDone)
        .expect("ring holds at least one completed batch");
    let b = done.id;
    // events are oldest-first; keep only this batch's routing chain
    // (Admit/Alert events reuse the id field for request id / tick)
    let chain: Vec<_> = inc
        .events
        .iter()
        .filter(|e| {
            e.id == b
                && matches!(
                    e.kind,
                    EventKind::BatchStart
                        | EventKind::LayerRoute
                        | EventKind::SolverExit
                        | EventKind::DualExit
                        | EventKind::BatchDone
                )
        })
        .collect();
    assert!(
        chain.iter().all(|e| e.replica == 0),
        "single-server run: every chain event carries replica 0"
    );

    let starts: Vec<_> = chain
        .iter()
        .filter(|e| e.kind == EventKind::BatchStart)
        .collect();
    assert_eq!(starts.len(), 1, "exactly one BatchStart for batch {b}");
    let start = starts[0];
    let (first_req, n_tokens) = event::batch_start_fields(start.payload);
    assert!(
        (1..=SchedulerConfig::default().batch_max).contains(&n_tokens),
        "batch size {n_tokens} within scheduler bounds"
    );
    assert!((first_req as usize) < N_REQUESTS);
    // request -> batch: the admission of the batch's first request is
    // still in the ring (it happened at most a few batches earlier)
    let admit = inc
        .events
        .iter()
        .find(|e| e.kind == EventKind::Admit && e.id == first_req)
        .expect("first request's Admit event links into the batch");
    assert!(admit.seq < start.seq, "admission precedes the batch");

    let layers: Vec<_> = chain
        .iter()
        .filter(|e| e.kind == EventKind::LayerRoute)
        .collect();
    assert_eq!(layers.len(), N_LAYERS, "one LayerRoute per MoE layer");
    for (l, e) in layers.iter().enumerate() {
        assert_eq!(e.layer as usize, l, "layer context in order");
        assert_eq!(e.payload, l as u64, "LayerRoute payload = layer");
    }

    let solves: Vec<_> = chain
        .iter()
        .filter(|e| e.kind == EventKind::SolverExit)
        .collect();
    assert_eq!(solves.len(), N_LAYERS, "one solver exit per layer");
    for (l, e) in solves.iter().enumerate() {
        assert_eq!(e.layer as usize, l, "solve recorded under its layer");
        let (mode, capped, iters) = event::solver_exit_fields(e.payload);
        assert_eq!(mode, 0, "single-threaded fixed-T = fixed-serial");
        assert!(!capped, "the fixed path never reports a cap hit");
        assert_eq!(iters, 0, "t_iters = 0 plants the greedy solve");
    }
    assert!(
        chain.iter().all(|e| e.kind != EventKind::DualExit),
        "fixed-T solves never take the adaptive dual exit"
    );

    let dones: Vec<_> = chain
        .iter()
        .filter(|e| e.kind == EventKind::BatchDone)
        .collect();
    assert_eq!(dones.len(), 1, "exactly one BatchDone for batch {b}");
    let vio = f64::from_bits(dones[0].payload);
    assert!(
        vio.is_finite() && vio >= 0.0,
        "BatchDone carries the batch MaxVio, got {vio}"
    );

    // seq order: BatchStart < (LayerRoute l < SolverExit l) < BatchDone
    let mut prev = start.seq;
    for l in 0..N_LAYERS {
        assert!(layers[l].seq > prev, "layer {l} routes in seq order");
        assert!(
            solves[l].seq > layers[l].seq,
            "layer {l} solver exits after its route begins"
        );
        prev = solves[l].seq;
    }
    assert!(dones[0].seq > prev, "BatchDone closes the chain");
}
