//! Zero-allocation hot-path integration (ISSUE 5).
//!
//! This test binary installs the counting global allocator
//! (`perf::alloc::CountingAlloc` — thread-local tallies, so the
//! harness's parallel test threads cannot pollute each other) and pins
//! the tentpole claim end-to-end: after warm-up,
//! `ServingRouter::route_batch_into` makes **zero heap allocations per
//! micro-batch** for every policy, and the arena path takes decisions
//! bit-identical to the allocating compatibility path.

use bip_moe::perf::alloc::{
    reset_thread_counts, thread_allocs, CountingAlloc,
};
use bip_moe::perf::{AssignmentBuf, ScoreArena};
use bip_moe::serve::{
    BatchOutcome, Policy, Request, RouterConfig, Scenario,
    ServingRouter, TrafficConfig, TrafficGenerator,
};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn requests(n: usize, seed: u64) -> Vec<Request> {
    TrafficGenerator::new(TrafficConfig {
        scenario: Scenario::Steady,
        n_requests: n,
        seed,
        ..Default::default()
    })
    .collect()
}

#[test]
fn steady_state_route_batch_is_zero_alloc_for_every_policy() {
    let batch = requests(64, 3);
    for policy in Policy::all() {
        let mut router =
            ServingRouter::new(policy, RouterConfig::default());
        let mut out = BatchOutcome::default();
        // warm-up: arena capacity + the balance tracker's series
        // vectors settle (70 pushes => capacity 128, so the 40-call
        // window below cannot trigger an amortized doubling)
        for _ in 0..70 {
            router.route_batch_into(&batch, &mut out);
        }
        reset_thread_counts();
        for _ in 0..40 {
            router.route_batch_into(&batch, &mut out);
        }
        let allocs = thread_allocs();
        assert_eq!(
            allocs, 0,
            "{policy:?}: {allocs} steady-state allocations in 40 \
             batches — the arena hot path must not touch the heap"
        );
    }
}

#[test]
fn adaptive_solver_path_is_zero_alloc_too() {
    let batch = requests(64, 5);
    let mut router = ServingRouter::new(
        Policy::BipBatch,
        RouterConfig {
            solver_tol: 0.05,
            solver_t_max: 16,
            ..Default::default()
        },
    );
    let mut out = BatchOutcome::default();
    for _ in 0..70 {
        router.route_batch_into(&batch, &mut out);
    }
    reset_thread_counts();
    for _ in 0..40 {
        router.route_batch_into(&batch, &mut out);
    }
    assert_eq!(
        thread_allocs(),
        0,
        "adaptive Algorithm 1 must stay allocation-free in steady state"
    );
}

#[test]
fn ragged_batches_stay_zero_alloc_once_the_largest_shape_is_warm() {
    // micro-batches shrink under load spikes; a smaller batch must
    // never re-allocate arena capacity sized by a larger one
    let reqs = requests(256, 7);
    let mut router =
        ServingRouter::new(Policy::BipBatch, RouterConfig::default());
    let mut out = BatchOutcome::default();
    for _ in 0..70 {
        router.route_batch_into(&reqs[..128], &mut out);
    }
    reset_thread_counts();
    for &(a, b) in
        &[(0usize, 128usize), (0, 17), (17, 20), (20, 148), (148, 212)]
    {
        router.route_batch_into(&reqs[a..b], &mut out);
    }
    assert_eq!(thread_allocs(), 0, "ragged steady state allocated");
}

#[test]
fn arena_and_compat_paths_agree_end_to_end() {
    let reqs = requests(4 * 64, 9);
    for policy in Policy::all() {
        let mut compat =
            ServingRouter::new(policy, RouterConfig::default());
        let mut arena =
            ServingRouter::new(policy, RouterConfig::default());
        let mut out = BatchOutcome::default();
        for chunk in reqs.chunks(64) {
            let want = compat.route_batch(chunk);
            arena.route_batch_into(chunk, &mut out);
            assert_eq!(out.loads, want.loads, "{policy:?}");
            assert_eq!(out.batch_vio, want.batch_vio, "{policy:?}");
            assert_eq!(out.overflow, want.overflow, "{policy:?}");
        }
        assert_eq!(
            compat.balance.avg_max_vio(),
            arena.balance.avg_max_vio(),
            "{policy:?}"
        );
    }
}

#[test]
fn solver_scratch_reuse_is_allocation_free_at_the_dual_level() {
    use bip_moe::bip::dual::DualState;
    use bip_moe::bip::Instance;
    use bip_moe::util::rng::Pcg64;

    let mut rng = Pcg64::new(11);
    let insts: Vec<Instance> = (0..8)
        .map(|_| Instance::synthetic(256, 16, 4, 2.0, 3.0, &mut rng))
        .collect();
    let mut state = DualState::new(16);
    let mut arena = ScoreArena::new();
    let mut buf = AssignmentBuf::new();
    // warm
    for inst in &insts[..4] {
        state.update_in(inst, 4, &mut arena);
        state.route_into(inst, &mut arena, &mut buf);
    }
    reset_thread_counts();
    for inst in &insts[4..] {
        state.update_in(inst, 4, &mut arena);
        state.route_into(inst, &mut arena, &mut buf);
    }
    assert_eq!(thread_allocs(), 0, "dual update/route allocated");
}
