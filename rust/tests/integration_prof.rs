//! Hierarchical-profiler integration (ISSUE 9 acceptance gates).
//!
//! This binary installs the counting global allocator and pins the
//! three profiler claims end-to-end:
//!
//! 1. steady-state serving with profiling **enabled** still makes zero
//!    heap allocations per micro-batch (same harness as
//!    `integration_perf`, now with `ProfGuard` frames live);
//! 2. the scraped tree is self-consistent — inclusive >= exclusive at
//!    every node, every parent covers its children — and the `serve`
//!    root accounts for >= 95% of the measured wall-clock;
//! 3. `profile diff` of two runs that differ only in the solver
//!    iteration cap attributes the regression to the dual-update
//!    phase, not to admission or dispatch.
//!
//! The profiler's path tables are process-global, so the tests that
//! reset/scrape them serialize on one mutex (test threads run in
//! parallel by default).

use std::sync::Mutex;
use std::time::Instant;

use bip_moe::perf::alloc::{
    reset_thread_counts, thread_allocs, CountingAlloc,
};
use bip_moe::prof::{self, Frame, ProfGuard, Profile};
use bip_moe::serve::{
    run_scenario, BatchOutcome, Policy, Request, RouterConfig, Scenario,
    SchedulerConfig, ServeConfig, ServingRouter, TrafficConfig,
    TrafficGenerator,
};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Serializes every test that resets or scrapes the global path
/// tables; poisoning is ignored (a failed test must not mask others).
static GATE: Mutex<()> = Mutex::new(());

fn requests(n: usize, seed: u64) -> Vec<Request> {
    TrafficGenerator::new(TrafficConfig {
        scenario: Scenario::Steady,
        n_requests: n,
        seed,
        ..Default::default()
    })
    .collect()
}

#[test]
fn steady_state_serving_with_profiling_is_zero_alloc() {
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    prof::set_enabled(true);
    prof::reset();
    let batch = requests(64, 3);
    for policy in Policy::all() {
        let mut router =
            ServingRouter::new(policy, RouterConfig::default());
        let mut out = BatchOutcome::default();
        // warm-up: arena capacities, the TLS frame stack, and every
        // path-table slot this workload touches settle here
        for _ in 0..70 {
            let _prof = ProfGuard::enter(Frame::Dispatch);
            router.route_batch_into(&batch, &mut out);
        }
        reset_thread_counts();
        for _ in 0..40 {
            let _prof = ProfGuard::enter(Frame::Dispatch);
            router.route_batch_into(&batch, &mut out);
        }
        let allocs = thread_allocs();
        assert_eq!(
            allocs, 0,
            "{policy:?}: {allocs} steady-state allocations in 40 \
             profiled batches — the record path must not touch the heap"
        );
    }
    // the frames really were recorded, not silently dropped
    let profile = Profile::scrape();
    let dispatch_calls: u64 = profile
        .paths
        .iter()
        .filter(|p| p.depth == 1 && p.path == "dispatch")
        .map(|p| p.calls)
        .sum();
    assert!(
        dispatch_calls >= 110 * Policy::all().len() as u64,
        "expected every wrapped batch recorded, saw {dispatch_calls} \
         dispatch calls"
    );
}

#[test]
fn profile_tree_is_consistent_and_covers_serve_wall_clock() {
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    prof::set_enabled(true);
    prof::reset();
    let cfg = ServeConfig::new(
        TrafficConfig {
            scenario: Scenario::Steady,
            n_requests: 4_096,
            seed: 7,
            ..Default::default()
        },
        SchedulerConfig::default(),
        RouterConfig::default(),
        Policy::BipBatch,
    );
    let t0 = Instant::now();
    let outcome = run_scenario(&cfg);
    let wall_ns = t0.elapsed().as_nanos() as u64;
    assert!(outcome.report.completed > 0);

    let profile = Profile::scrape();
    assert!(!profile.paths.is_empty(), "serve run recorded nothing");

    // every node: inclusive >= exclusive, and at least one call
    for p in &profile.paths {
        assert!(
            p.inclusive_ns >= p.exclusive_ns,
            "{}: inclusive {} < exclusive {}",
            p.path,
            p.inclusive_ns,
            p.exclusive_ns
        );
        assert!(p.calls > 0, "{}: zero calls", p.path);
    }

    // every parent covers the sum of its children's inclusive time
    let mut child_sums: std::collections::BTreeMap<&str, u64> =
        std::collections::BTreeMap::new();
    for p in &profile.paths {
        if let Some((parent, _leaf)) = p.path.rsplit_once(';') {
            *child_sums.entry(parent).or_insert(0) += p.inclusive_ns;
        }
    }
    for (parent, sum) in &child_sums {
        let node = profile
            .paths
            .iter()
            .find(|p| p.path == *parent)
            .unwrap_or_else(|| panic!("orphan call path under {parent}"));
        assert!(
            node.inclusive_ns >= *sum,
            "{parent}: inclusive {} < children sum {sum}",
            node.inclusive_ns
        );
    }

    // the serve root accounts for >= 95% of the measured wall-clock
    let serve_ns = profile.root_ns("serve");
    assert!(
        serve_ns as f64 >= 0.95 * wall_ns as f64,
        "serve root {serve_ns} ns < 95% of wall {wall_ns} ns"
    );
    assert!(
        serve_ns <= wall_ns,
        "serve root {serve_ns} ns exceeds wall {wall_ns} ns"
    );
}

/// One profiled serve run at the given adaptive-solver iteration cap.
fn profiled_serve(t_max: usize) -> Profile {
    let cfg = ServeConfig::new(
        TrafficConfig {
            scenario: Scenario::Steady,
            n_requests: 2_048,
            seed: 11,
            ..Default::default()
        },
        SchedulerConfig::default(),
        RouterConfig {
            // a tolerance this tight never converges early, so the
            // cap is the only thing that changes between the runs
            solver_tol: 1e-6,
            solver_t_max: t_max,
            ..Default::default()
        },
        Policy::BipBatch,
    );
    prof::reset();
    let outcome = run_scenario(&cfg);
    assert!(outcome.report.completed > 0);
    Profile::scrape()
}

#[test]
fn diff_attributes_solver_cap_regression_to_dual_update() {
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    prof::set_enabled(true);
    let fast = profiled_serve(4);
    let slow = profiled_serve(64);
    // both runs saw the dual phases at all
    assert!(
        slow.paths.iter().any(|p| p.path.ends_with("dual_update")),
        "slow run recorded no dual_update path"
    );
    let top = prof::top_regressions(&fast, &slow, 5);
    assert!(!top.is_empty(), "16x more solver iterations, no regression");
    let worst = &top[0];
    let leaf = worst.path.rsplit(';').next().unwrap_or(&worst.path);
    assert!(
        leaf.starts_with("dual"),
        "worst regression should be a dual-update phase, got `{}` \
         (delta {} ns)",
        worst.path,
        worst.delta_excl_ns
    );
    assert!(
        leaf != "admission" && leaf != "dispatch",
        "regression misattributed to `{leaf}`"
    );
    // the dual family's combined growth dwarfs admission's drift
    let delta_for = |rows: &[prof::DiffRow], pred: &dyn Fn(&str) -> bool| {
        rows.iter()
            .filter(|r| {
                pred(r.path.rsplit(';').next().unwrap_or(&r.path))
            })
            .map(|r| r.delta_excl_ns)
            .sum::<i64>()
    };
    let all = prof::diff(&fast, &slow);
    let dual_delta = delta_for(&all, &|l| l.starts_with("dual"));
    let admission_delta = delta_for(&all, &|l| l == "admission");
    assert!(
        dual_delta > admission_delta.abs(),
        "dual growth {dual_delta} ns should dominate admission drift \
         {admission_delta} ns"
    );
}
