//! Cross-module property tests (hand-rolled proptest: Pcg64-driven random
//! instances, many trials, shrink-free but seeded and reproducible).
//!
//! These pin the relationships BETWEEN subsystems: solver family
//! consistency (dual vs exact vs online), cost-model/metric coupling,
//! and the data->routing pipeline.

use bip_moe::bip::approx::ApproxGate;
use bip_moe::bip::dual;
use bip_moe::bip::flow::solve_exact;
use bip_moe::bip::online::OnlineGate;
use bip_moe::bip::{greedy_topk, Instance};
use bip_moe::metrics::maxvio::max_violation;
use bip_moe::parallel::{ClusterSim, DeviceProfile, Mesh, ModelCost};
use bip_moe::util::rng::Pcg64;

fn random_instance(rng: &mut Pcg64) -> Instance {
    let m = *[8usize, 16, 32].get(rng.below(3) as usize).unwrap();
    let k = 1 + rng.below(4.min(m as u64 / 2)) as usize;
    let n = m * (4 + rng.below(12) as usize);
    let temp = 0.5 + rng.next_f64() * 2.5;
    let skew = rng.next_f64() * 4.0;
    Instance::synthetic(n, m, k, temp, skew, rng)
}

/// Property: the dual heuristic's objective always sits between the exact
/// optimum scaled down and the greedy upper bound, and its violation is
/// bounded. 30 random instances.
#[test]
fn prop_dual_objective_sandwiched() {
    let mut rng = Pcg64::new(0xD1A1);
    for trial in 0..30 {
        let inst = random_instance(&mut rng);
        let (routing, q) = dual::solve(&inst, 8);
        let obj = routing.objective(&inst);
        let greedy_obj = greedy_topk(&inst).objective(&inst);
        assert!(obj <= greedy_obj + 1e-6, "trial {trial}");
        assert!(obj >= 0.5 * greedy_obj, "trial {trial}: obj {obj} \
                 greedy {greedy_obj}");
        assert!(q.iter().all(|&x| x >= 0.0), "trial {trial}");
        assert!(routing.max_violation(&inst) < 1.0,
                "trial {trial}: vio {}", routing.max_violation(&inst));
        assert!(routing.is_row_feasible(inst.k), "trial {trial}");
    }
}

/// Property: on small instances the dual heuristic reaches >= 85% of the
/// exact flow optimum while cutting greedy's violation.
#[test]
fn prop_dual_near_optimal_vs_flow() {
    let mut rng = Pcg64::new(0xF10);
    for trial in 0..8 {
        let m = 8;
        let k = 2;
        let n = 48;
        let inst = Instance::synthetic(
            n, m, k, 1.5, 1.0 + rng.next_f64() * 3.0, &mut rng);
        let (exact, exact_obj) = solve_exact(&inst);
        assert!(exact.is_col_feasible(m, inst.cap), "trial {trial}");
        let (routing, _) = dual::solve(&inst, 14);
        let obj = routing.objective(&inst);
        assert!(obj >= 0.85 * exact_obj,
                "trial {trial}: {obj} vs exact {exact_obj}");
        let greedy = greedy_topk(&inst);
        if greedy.max_violation(&inst) > 0.5 {
            assert!(routing.max_violation(&inst)
                    < greedy.max_violation(&inst), "trial {trial}");
        }
    }
}

/// Property: processing a batch token-by-token through Algorithm 3 ends
/// with duals correlated with the batch dual solver's (same constraint
/// structure, different update schedule).
#[test]
fn prop_online_duals_track_batch_duals() {
    let mut rng = Pcg64::new(0x0917);
    for trial in 0..6 {
        let inst = Instance::synthetic(512, 16, 4, 2.0,
                                       2.0 + rng.next_f64() * 2.0, &mut rng);
        let (_, q_batch) = dual::solve(&inst, 8);
        let mut gate = OnlineGate::new(16, 4, inst.cap, 4);
        for i in 0..inst.n {
            gate.route_token(inst.row(i));
        }
        // experts the batch solver prices highest should also be the
        // online gate's most-penalized experts (rank correlation on top-4)
        let top_batch = bip_moe::util::stats::topk_indices(&q_batch, 4);
        let top_online = bip_moe::util::stats::topk_indices(&gate.q, 4);
        let overlap = top_batch
            .iter()
            .filter(|e| top_online.contains(e))
            .count();
        assert!(overlap >= 2,
                "trial {trial}: batch {top_batch:?} online {top_online:?}");
    }
}

/// Property: Algorithm 4 approaches Algorithm 3 as buckets increase, for
/// the same stream.
#[test]
fn prop_approx_converges_to_online_in_buckets() {
    let mut rng = Pcg64::new(0xA44);
    let inst = Instance::synthetic(768, 16, 4, 2.0, 3.0, &mut rng);
    let mut online = OnlineGate::new(16, 4, inst.cap, 2);
    for i in 0..inst.n {
        online.route_token(inst.row(i));
    }
    let mut errs = Vec::new();
    for buckets in [4usize, 32, 512] {
        let mut approx = ApproxGate::new(16, 4, inst.cap, 2, buckets);
        for i in 0..inst.n {
            approx.route_token(inst.row(i));
        }
        let err: f32 = online
            .q
            .iter()
            .zip(&approx.q)
            .map(|(a, b)| (a - b).abs())
            .sum();
        errs.push(err);
    }
    assert!(errs[2] <= errs[0] + 1e-5, "errs {errs:?}");
    assert!(errs[2] < 0.1, "512-bucket err {}", errs[2]);
}

/// Property: simulated step time is monotone in MaxVio when total load is
/// held fixed — the mechanism behind the paper's training-time savings.
#[test]
fn prop_sim_time_monotone_in_maxvio() {
    let mut rng = Pcg64::new(0x517);
    let sim = ClusterSim::new(
        Mesh::new(4, 16),
        DeviceProfile::rtx4090(),
        ModelCost::paper_16e(),
        false,
    );
    for _ in 0..10 {
        let n_tokens = 4096usize;
        let mean = n_tokens as f32 / 16.0;
        // two load vectors with the same total, different concentration
        let spread = rng.next_f32() * 0.5;
        let mild: Vec<f32> = (0..16)
            .map(|j| mean * (1.0 + spread * ((j as f32 / 8.0) - 1.0)))
            .collect();
        let mut hot = vec![mean * 0.8; 16];
        hot[0] = mean * 0.8 + (mean * 0.2) * 16.0;
        let vio_mild = max_violation(&mild, n_tokens, 1);
        let vio_hot = max_violation(&hot, n_tokens, 1);
        assert!(vio_hot > vio_mild);
        let t_mild = sim.step_time(&mild, 16);
        let t_hot = sim.step_time(&hot, 16);
        assert!(t_hot > t_mild,
                "vio {vio_mild}->{vio_hot}, t {t_mild}->{t_hot}");
    }
}

/// Property: MaxVio of any routing is >= 0 with equality iff perfectly
/// balanced, and greedy's violation grows with score skew.
#[test]
fn prop_maxvio_semantics() {
    let mut rng = Pcg64::new(0x3a3);
    let mut prev_vio = -1.0f64;
    for skew_step in 0..5 {
        let skew = skew_step as f64;
        let inst = Instance::synthetic(512, 16, 4, 1.0, skew, &mut rng);
        let routing = greedy_topk(&inst);
        let vio = routing.max_violation(&inst);
        assert!(vio >= -1e-9);
        if skew_step >= 2 {
            // skew 2+: strictly more unbalanced than skew 0
            assert!(vio > prev_vio.min(0.3),
                    "skew {skew}: vio {vio} prev {prev_vio}");
        }
        if skew_step == 0 {
            prev_vio = vio;
        }
    }
    // perfectly balanced loads -> exactly 0
    let loads = vec![128.0f32; 16];
    assert!(max_violation(&loads, 512, 4).abs() < 1e-12);
}

/// Property: the data pipeline's batches route like language data — the
/// corpus's Zipf skew induces router-score imbalance under a random
/// projection gate (the situation the paper's Figure 1 starts from).
#[test]
fn prop_corpus_induces_router_imbalance() {
    use bip_moe::data::{Corpus, CorpusSpec, Loader, Split};
    let corpus = std::sync::Arc::new(Corpus::build(CorpusSpec {
        vocab_size: 1024,
        ..Default::default()
    }));
    let loader = Loader::new(corpus, 4, 64, Split::Train);
    let mut rng = Pcg64::new(0xC0);
    // random embedding + gate: token -> expert scores (softmax rows)
    let m = 16;
    let emb: Vec<f32> =
        (0..1024 * m).map(|_| rng.normal() as f32 * 1.5).collect();
    let mut all_vio = 0.0;
    let batches = 5;
    for b in 0..batches {
        let batch = loader.batch(b);
        let n = batch.n_tokens();
        let mut scores = Vec::with_capacity(n * m);
        for &tok in &batch.tokens[..n] {
            let row = &emb[(tok as usize) * m..(tok as usize + 1) * m];
            let mx = row.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> =
                row.iter().map(|&x| (x - mx).exp()).collect();
            let total: f32 = exps.iter().sum();
            scores.extend(exps.iter().map(|&e| e / total));
        }
        let inst = Instance { n, m, k: 4, cap: n * 4 / m, scores };
        all_vio += greedy_topk(&inst).max_violation(&inst);
    }
    let avg = all_vio / batches as f64;
    assert!(avg > 0.3, "corpus should induce imbalance, got {avg}");
}
