//! End-to-end serving integration: small bursty scenarios through the
//! full traffic -> admission -> micro-batch -> BIP router -> SLO
//! pipeline, plus the cross-policy claims the ISSUE pins:
//!
//!   * work conservation (offered = admitted + rejected,
//!     admitted = completed + expired) for every policy;
//!   * per-expert capacity is a hard bound (checked in the router's own
//!     property tests; here the overflow accounting must stay finite);
//!   * at equal throughput, the BIP-balanced policies show strictly
//!     lower per-expert max-violation than greedy top-k;
//!   * no reordering within a tenant;
//!   * Algorithm 4's state stays small while Algorithm 3's grows.

use bip_moe::serve::{
    run_replicated, run_scenario, Policy, ReplicaConfig, RouterConfig,
    SchedulerConfig, Scenario, ServeConfig, ServeOutcome, TrafficConfig,
};

fn config(scenario: Scenario, policy: Policy) -> ServeConfig {
    ServeConfig::new(
        TrafficConfig {
            scenario,
            n_requests: 3_000,
            rate_per_s: 60_000.0,
            n_layers: 2,
            slo_us: 25_000,
            seed: 7,
            ..Default::default()
        },
        SchedulerConfig {
            queue_cap: 256,
            batch_max: 64,
            max_wait_us: 1_500,
            drop_expired: true,
        },
        RouterConfig::default(),
        policy,
    )
}

fn run(scenario: Scenario, policy: Policy) -> ServeOutcome {
    run_scenario(&config(scenario, policy))
}

#[test]
fn bursty_end_to_end_bip_beats_greedy_at_equal_throughput() {
    let greedy = run(Scenario::Bursty, Policy::Greedy);
    let online = run(Scenario::Bursty, Policy::Online);
    let approx = run(Scenario::Bursty, Policy::Approx);
    let batch = run(Scenario::Bursty, Policy::BipBatch);

    // equal throughput: the load is moderate, every policy serves the
    // whole stream — same offered, same completed
    for out in [&greedy, &online, &approx, &batch] {
        assert!(out.report.conserves_work(), "{:?}", out.report);
        assert_eq!(out.report.offered, 3_000);
        assert_eq!(out.report.rejected, 0, "{}", out.report.policy);
        assert_eq!(out.report.completed, 3_000, "{}", out.report.policy);
        assert!(out.report.throughput_rps > 0.0);
    }

    // the paper's claim, at serving time: strictly lower per-expert
    // max-violation for every BIP-balanced policy
    let gv = greedy.report.avg_max_vio;
    for out in [&online, &approx, &batch] {
        assert!(
            out.report.avg_max_vio < gv,
            "{} vio {} !< greedy {gv}",
            out.report.policy,
            out.report.avg_max_vio
        );
    }
    // and strictly fewer capacity overflows
    for out in [&online, &approx, &batch] {
        assert!(
            out.report.overflow < greedy.report.overflow,
            "{} overflow {} !< greedy {}",
            out.report.policy,
            out.report.overflow,
            greedy.report.overflow
        );
    }
}

#[test]
fn every_policy_conserves_work_on_every_scenario() {
    for scenario in Scenario::all() {
        for policy in Policy::all() {
            let out = run(scenario, policy);
            assert!(
                out.report.conserves_work(),
                "{}/{}: {:?}",
                scenario.name(),
                policy.name(),
                out.report
            );
            assert_eq!(
                out.report.completed,
                out.completions.len() as u64
            );
            assert!(out.report.p50_ms <= out.report.p95_ms);
            assert!(out.report.p95_ms <= out.report.p99_ms);
        }
    }
}

#[test]
fn tenants_are_never_reordered() {
    for policy in [Policy::Greedy, Policy::Online] {
        let out = run(Scenario::MultiTenant, policy);
        let mut last_id = std::collections::BTreeMap::new();
        for c in &out.completions {
            if let Some(&prev) = last_id.get(&c.tenant) {
                assert!(
                    c.id > prev,
                    "tenant {} saw {} after {}",
                    c.tenant,
                    c.id,
                    prev
                );
            }
            last_id.insert(c.tenant, c.id);
        }
        assert!(last_id.len() > 1, "want multiple tenants exercised");
    }
}

#[test]
fn approx_state_is_smaller_than_online_on_long_streams() {
    let online = run(Scenario::Steady, Policy::Online);
    let approx = run(Scenario::Steady, Policy::Approx);
    assert!(
        approx.report.state_bytes < online.report.state_bytes,
        "approx {} !< online {}",
        approx.report.state_bytes,
        online.report.state_bytes
    );
    // and the constant-space policy still balances
    assert!(approx.report.avg_max_vio < 1.0);
}

#[test]
fn replica_set_with_r1_reproduces_the_single_router_sim_exactly() {
    // the replicated event loop must be a strict generalization: one
    // replica (even on a multi-thread pool, which exercises the
    // chunked Algorithm 1 dual update) reproduces run_scenario
    // bit-for-bit — completions, balance, capacity and state accounting
    for policy in [Policy::BipBatch, Policy::Online, Policy::LossFree] {
        let cfg = config(Scenario::Bursty, policy);
        let single = run_scenario(&cfg);
        let rep = run_replicated(
            &cfg,
            &ReplicaConfig { replicas: 1, threads: 3, sync_every: 8 },
        );
        let (a, b) = (&single.report, &rep.report);
        assert_eq!(a.offered, b.offered, "{policy:?}");
        assert_eq!(a.admitted, b.admitted, "{policy:?}");
        assert_eq!(a.rejected, b.rejected, "{policy:?}");
        assert_eq!(a.expired, b.expired, "{policy:?}");
        assert_eq!(a.completed, b.completed, "{policy:?}");
        assert_eq!(a.p50_ms, b.p50_ms, "{policy:?}");
        assert_eq!(a.p99_ms, b.p99_ms, "{policy:?}");
        assert_eq!(a.avg_max_vio, b.avg_max_vio, "{policy:?}");
        assert_eq!(a.sup_max_vio, b.sup_max_vio, "{policy:?}");
        assert_eq!(a.overflow, b.overflow, "{policy:?}");
        assert_eq!(a.degraded, b.degraded, "{policy:?}");
        assert_eq!(a.state_bytes, b.state_bytes, "{policy:?}");
        assert_eq!(a.horizon_s, b.horizon_s, "{policy:?}");
        assert_eq!(
            single.completions.len(),
            rep.completions.len(),
            "{policy:?}"
        );
        for (x, y) in single.completions.iter().zip(&rep.completions) {
            assert_eq!(x.id, y.id, "{policy:?}");
            assert_eq!(x.completion_us, y.completion_us, "{policy:?}");
        }
        // R = 1 never syncs (nothing to reconcile with)
        assert!(rep.syncs.is_empty(), "{policy:?}");
    }
}

#[test]
fn merged_state_keeps_replicas_near_single_router_balance() {
    // the mergeable-state claim: with periodic reconciliation, each
    // replica — though it sees only a 1/R shard of the bursty stream —
    // stays within a constant factor of the single router's balance
    for policy in [Policy::LossFree, Policy::BipBatch] {
        let cfg = config(Scenario::Bursty, policy);
        let single = run_scenario(&cfg);
        let rep = run_replicated(
            &cfg,
            &ReplicaConfig { replicas: 4, threads: 2, sync_every: 8 },
        );
        assert!(rep.report.conserves_work());
        assert!(!rep.syncs.is_empty(), "{policy:?}: syncs must fire");
        let last = rep.syncs.last().unwrap();
        assert!(
            last.state_div_after <= 1e-6,
            "{policy:?}: post-merge divergence {}",
            last.state_div_after
        );
        let bound = single.report.avg_max_vio * 2.5 + 0.30;
        for p in &rep.per_replica {
            assert!(
                p.avg_max_vio <= bound,
                "{policy:?} replica {}: vio {} > bound {bound} \
                 (single {})",
                p.replica,
                p.avg_max_vio,
                single.report.avg_max_vio
            );
        }
    }
}

#[test]
fn replicated_bip_still_beats_greedy_on_bursty() {
    // the paper's ordering must survive scale-out: at R=4 with state
    // syncing, every BIP policy stays better-balanced than greedy
    let rcfg = ReplicaConfig { replicas: 4, threads: 2, sync_every: 8 };
    let greedy =
        run_replicated(&config(Scenario::Bursty, Policy::Greedy), &rcfg);
    for policy in [Policy::Online, Policy::Approx, Policy::BipBatch] {
        let out =
            run_replicated(&config(Scenario::Bursty, policy), &rcfg);
        assert!(
            out.report.avg_max_vio < greedy.report.avg_max_vio,
            "{policy:?} vio {} !< greedy {}",
            out.report.avg_max_vio,
            greedy.report.avg_max_vio
        );
    }
}

#[test]
fn adversarial_drift_is_survivable() {
    // rotating hot experts: the online balancer must still beat greedy
    // on average, even though each rotation resets its advantage
    let greedy = run(Scenario::Adversarial, Policy::Greedy);
    let online = run(Scenario::Adversarial, Policy::Online);
    assert!(
        online.report.avg_max_vio < greedy.report.avg_max_vio,
        "online {} !< greedy {}",
        online.report.avg_max_vio,
        greedy.report.avg_max_vio
    );
}
