//! End-to-end serving integration: small bursty scenarios through the
//! full traffic -> admission -> micro-batch -> BIP router -> SLO
//! pipeline, plus the cross-policy claims the ISSUE pins:
//!
//!   * work conservation (offered = admitted + rejected,
//!     admitted = completed + expired) for every policy;
//!   * per-expert capacity is a hard bound (checked in the router's own
//!     property tests; here the overflow accounting must stay finite);
//!   * at equal throughput, the BIP-balanced policies show strictly
//!     lower per-expert max-violation than greedy top-k;
//!   * no reordering within a tenant;
//!   * Algorithm 4's state stays small while Algorithm 3's grows.

use bip_moe::serve::{
    run_scenario, Policy, RouterConfig, SchedulerConfig, Scenario,
    ServeConfig, ServeOutcome, TrafficConfig,
};

fn config(scenario: Scenario, policy: Policy) -> ServeConfig {
    ServeConfig::new(
        TrafficConfig {
            scenario,
            n_requests: 3_000,
            rate_per_s: 60_000.0,
            n_layers: 2,
            slo_us: 25_000,
            seed: 7,
            ..Default::default()
        },
        SchedulerConfig {
            queue_cap: 256,
            batch_max: 64,
            max_wait_us: 1_500,
            drop_expired: true,
        },
        RouterConfig::default(),
        policy,
    )
}

fn run(scenario: Scenario, policy: Policy) -> ServeOutcome {
    run_scenario(&config(scenario, policy))
}

#[test]
fn bursty_end_to_end_bip_beats_greedy_at_equal_throughput() {
    let greedy = run(Scenario::Bursty, Policy::Greedy);
    let online = run(Scenario::Bursty, Policy::Online);
    let approx = run(Scenario::Bursty, Policy::Approx);
    let batch = run(Scenario::Bursty, Policy::BipBatch);

    // equal throughput: the load is moderate, every policy serves the
    // whole stream — same offered, same completed
    for out in [&greedy, &online, &approx, &batch] {
        assert!(out.report.conserves_work(), "{:?}", out.report);
        assert_eq!(out.report.offered, 3_000);
        assert_eq!(out.report.rejected, 0, "{}", out.report.policy);
        assert_eq!(out.report.completed, 3_000, "{}", out.report.policy);
        assert!(out.report.throughput_rps > 0.0);
    }

    // the paper's claim, at serving time: strictly lower per-expert
    // max-violation for every BIP-balanced policy
    let gv = greedy.report.avg_max_vio;
    for out in [&online, &approx, &batch] {
        assert!(
            out.report.avg_max_vio < gv,
            "{} vio {} !< greedy {gv}",
            out.report.policy,
            out.report.avg_max_vio
        );
    }
    // and strictly fewer capacity overflows
    for out in [&online, &approx, &batch] {
        assert!(
            out.report.overflow < greedy.report.overflow,
            "{} overflow {} !< greedy {}",
            out.report.policy,
            out.report.overflow,
            greedy.report.overflow
        );
    }
}

#[test]
fn every_policy_conserves_work_on_every_scenario() {
    for scenario in Scenario::all() {
        for policy in Policy::all() {
            let out = run(scenario, policy);
            assert!(
                out.report.conserves_work(),
                "{}/{}: {:?}",
                scenario.name(),
                policy.name(),
                out.report
            );
            assert_eq!(
                out.report.completed,
                out.completions.len() as u64
            );
            assert!(out.report.p50_ms <= out.report.p95_ms);
            assert!(out.report.p95_ms <= out.report.p99_ms);
        }
    }
}

#[test]
fn tenants_are_never_reordered() {
    for policy in [Policy::Greedy, Policy::Online] {
        let out = run(Scenario::MultiTenant, policy);
        let mut last_id = std::collections::BTreeMap::new();
        for c in &out.completions {
            if let Some(&prev) = last_id.get(&c.tenant) {
                assert!(
                    c.id > prev,
                    "tenant {} saw {} after {}",
                    c.tenant,
                    c.id,
                    prev
                );
            }
            last_id.insert(c.tenant, c.id);
        }
        assert!(last_id.len() > 1, "want multiple tenants exercised");
    }
}

#[test]
fn approx_state_is_smaller_than_online_on_long_streams() {
    let online = run(Scenario::Steady, Policy::Online);
    let approx = run(Scenario::Steady, Policy::Approx);
    assert!(
        approx.report.state_bytes < online.report.state_bytes,
        "approx {} !< online {}",
        approx.report.state_bytes,
        online.report.state_bytes
    );
    // and the constant-space policy still balances
    assert!(approx.report.avg_max_vio < 1.0);
}

#[test]
fn adversarial_drift_is_survivable() {
    // rotating hot experts: the online balancer must still beat greedy
    // on average, even though each rotation resets its advantage
    let greedy = run(Scenario::Adversarial, Policy::Greedy);
    let online = run(Scenario::Adversarial, Policy::Online);
    assert!(
        online.report.avg_max_vio < greedy.report.avg_max_vio,
        "online {} !< greedy {}",
        online.report.avg_max_vio,
        greedy.report.avg_max_vio
    );
}
