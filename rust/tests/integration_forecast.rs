//! End-to-end forecast-subsystem integration: forecaster accuracy on
//! synthetic drifting/periodic loads, seed_state round-trips for every
//! BalanceState variant, the warm-start claim (forecast-seeded
//! PredictiveBip strictly lowers first-batch MaxVio on bursty traffic),
//! and deterministic fits from recorded traces.

use bip_moe::bip::Instance;
use bip_moe::forecast::{
    dual_seed, fit_model, ForecastConfig, ForecastModel, ForecasterKind,
    LoadSeries, DEFAULT_SEED_GAIN,
};
use bip_moe::routing::{
    ApproxBip, BalanceState, Bip, Greedy, LossFree, OnlineBip,
    PredictiveBip, RoutingStrategy,
};
use bip_moe::serve::{
    run_scenario_with, Policy, ReplicaConfig, Request, RouterConfig,
    Scenario, SchedulerConfig, ServeConfig, TrafficConfig,
    TrafficGenerator,
};
use bip_moe::trace::{Trace, TraceRecorder};
use bip_moe::util::json::Json;
use bip_moe::util::rng::Pcg64;

fn demand_trace(scenario: Scenario, n_requests: usize, seed: u64) -> Trace {
    let cfg = ServeConfig::new(
        TrafficConfig { scenario, n_requests, seed, ..Default::default() },
        SchedulerConfig::default(),
        RouterConfig::default(),
        Policy::Greedy,
    );
    let mut rec = TraceRecorder::new(&cfg, &ReplicaConfig::default());
    run_scenario_with(
        &cfg,
        TrafficGenerator::new(cfg.traffic.clone()),
        Some(&mut rec),
    );
    rec.into_trace()
}

fn mae(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f64>()
        / a.len() as f64
}

#[test]
fn forecasters_beat_naive_on_drifting_and_periodic_loads() {
    // drifting: a hot set that migrates linearly across 8 experts
    // slope kept small enough that every fraction stays positive over
    // the whole series (no clamping to distort the linearity)
    let drift: Vec<Vec<f64>> = (0..160)
        .map(|t| {
            let d = 0.0015 * t as f64;
            vec![
                0.30 - d,
                0.20,
                0.15,
                0.10 + d,
                0.0625,
                0.0625,
                0.0625,
                0.0625,
            ]
        })
        .collect();
    let series =
        LoadSeries { m: 8, layers: vec![drift.clone(), drift] };
    for kind in [ForecasterKind::Linear, ForecasterKind::HoltWinters] {
        let (_, report) = fit_model(
            kind,
            &ForecastConfig::default(),
            &series,
            &[4, 16],
            0.25,
        )
        .unwrap();
        for h in &report.by_horizon {
            assert!(
                h.mae < h.naive_mae,
                "{kind:?} h={}: mae {} !< naive {}",
                h.horizon,
                h.mae,
                h.naive_mae
            );
        }
    }

    // periodic: period-12 alternation between two expert groups — the
    // diurnal shape; Holt-Winters with the matching period must beat
    // both naive and the period-blind EWMA
    let periodic: Vec<Vec<f64>> = (0..144)
        .map(|t| {
            if (t / 6) % 2 == 0 {
                vec![0.4, 0.3, 0.1, 0.1, 0.05, 0.05]
            } else {
                vec![0.1, 0.1, 0.4, 0.3, 0.05, 0.05]
            }
        })
        .collect();
    let series = LoadSeries { m: 6, layers: vec![periodic] };
    let hw_cfg = ForecastConfig {
        period: 12,
        gamma: 0.5,
        beta: 0.0,
        ..Default::default()
    };
    let (_, hw) = fit_model(
        ForecasterKind::HoltWinters,
        &hw_cfg,
        &series,
        &[6],
        0.25,
    )
    .unwrap();
    let (_, ewma) = fit_model(
        ForecasterKind::Ewma,
        &ForecastConfig::default(),
        &series,
        &[6],
        0.25,
    )
    .unwrap();
    // horizon 6 lands in the opposite phase: last-value is maximally
    // wrong, the seasonal model is nearly exact
    assert!(
        hw.by_horizon[0].mae < hw.by_horizon[0].naive_mae,
        "hw {} !< naive {}",
        hw.by_horizon[0].mae,
        hw.by_horizon[0].naive_mae
    );
    assert!(
        hw.by_horizon[0].mae < ewma.by_horizon[0].mae,
        "hw {} !< ewma {}",
        hw.by_horizon[0].mae,
        ewma.by_horizon[0].mae
    );
}

#[test]
fn seed_state_round_trips_every_balance_state_variant() {
    let mut rng = Pcg64::new(41);
    let insts: Vec<Instance> = (0..4)
        .map(|_| Instance::synthetic(128, 16, 4, 2.0, 3.0, &mut rng))
        .collect();
    let (m, k, cap) = (16usize, 4usize, 512usize);

    // Bias: LossFree
    let mut lf = LossFree::new(m, 1e-2);
    for inst in &insts {
        lf.route_batch(inst);
    }
    let state = lf.export_state();
    let mut fresh = LossFree::new(m, 1e-2);
    fresh.seed_state(&state);
    assert_eq!(fresh.bias, lf.bias, "Bias round trip");

    // Dual: Bip (and PredictiveBip shares the variant)
    let mut bip = Bip::new(3);
    for inst in &insts {
        bip.route_batch(inst);
    }
    let state = bip.export_state();
    let mut fresh = Bip::new(3);
    fresh.seed_state(&state);
    assert_eq!(fresh.q(), bip.q(), "Dual round trip");
    let mut fresh_pred = PredictiveBip::new(3, Vec::new());
    fresh_pred.seed_state(&state);
    // the seeded strategy routes the next batch exactly like the donor
    let probe = Instance::synthetic(128, 16, 4, 2.0, 3.0, &mut rng);
    assert_eq!(
        fresh_pred.route_batch(&probe).assignment,
        bip.route_batch(&probe).assignment,
        "a Dual-seeded PredictiveBip continues the donor's trajectory"
    );

    // Online: q + bounded heaps
    let mut online = OnlineBip::new(m, k, cap, 3);
    for inst in &insts {
        online.route_batch(inst);
    }
    let state = online.export_state();
    let mut fresh = OnlineBip::new(m, k, cap, 3);
    fresh.seed_state(&state);
    assert_eq!(fresh.gate.q, online.gate.q, "Online duals round trip");
    let (mut a, mut b) =
        (fresh.gate.heap_values(), online.gate.heap_values());
    for (x, y) in a.iter_mut().zip(b.iter_mut()) {
        x.sort_by(|p, q| p.partial_cmp(q).unwrap());
        y.sort_by(|p, q| p.partial_cmp(q).unwrap());
    }
    assert_eq!(a, b, "Online heaps round trip as multisets");

    // Approx: q + histogram counts
    let mut approx = ApproxBip::new(m, k, cap, 3, 64);
    for inst in &insts {
        approx.route_batch(inst);
    }
    let state = approx.export_state();
    let mut fresh = ApproxBip::new(m, k, cap, 3, 64);
    fresh.seed_state(&state);
    assert_eq!(fresh.gate.q, approx.gate.q, "Approx duals round trip");
    assert_eq!(
        fresh.gate.hist_counts(),
        approx.gate.hist_counts(),
        "Approx histograms round trip"
    );

    // None: stateless strategies export None and ignore any seed
    let mut g = Greedy;
    let state = g.export_state();
    assert!(matches!(state, BalanceState::None));
    g.seed_state(&BalanceState::Dual(vec![1.0; m]));
    g.seed_state(&state);
    assert!(matches!(g.export_state(), BalanceState::None));
    // seeding None into a stateful strategy is a no-op, not a reset
    let bias = fresh_bias_after_none_seed();
    assert!(bias.iter().any(|&x| x != 0.0));
}

fn fresh_bias_after_none_seed() -> Vec<f32> {
    let mut rng = Pcg64::new(42);
    let inst = Instance::synthetic(128, 16, 4, 2.0, 3.0, &mut rng);
    let mut lf = LossFree::new(16, 1e-2);
    lf.route_batch(&inst);
    lf.seed_state(&BalanceState::None);
    lf.bias
}

#[test]
fn warm_start_strictly_lowers_first_batch_maxvio_on_bursty() {
    // the acceptance claim, end to end: record a demand trace, fit a
    // forecaster on its load series, seed Algorithm 1's duals from the
    // forecast, and the very first micro-batch of the same workload
    // routes strictly more balanced than cold start at equal T
    let trace = demand_trace(Scenario::Bursty, 2_048, 7);
    let series = LoadSeries::from_trace(&trace).unwrap();
    let (model, _) = fit_model(
        ForecasterKind::Ewma,
        &ForecastConfig::default(),
        &series,
        &[1],
        0.25,
    )
    .unwrap();
    let (m, k, n_layers) = (16usize, 4usize, 4usize);
    let first: Vec<Request> = TrafficGenerator::new(TrafficConfig {
        scenario: Scenario::Bursty,
        n_requests: 2_048,
        seed: 7,
        ..Default::default()
    })
    .take(256)
    .collect();

    let vio_at = |t: usize, warm: bool| -> f64 {
        let mut sum = 0.0;
        for l in 0..n_layers {
            let n = first.len();
            let mut scores = Vec::with_capacity(n * m);
            for r in &first {
                scores.extend_from_slice(r.layer_scores(l, m));
            }
            let inst =
                Instance { n, m, k, cap: n * k / m, scores };
            let seed = if warm {
                dual_seed(
                    &model.layer_forecast(l, 1),
                    k,
                    DEFAULT_SEED_GAIN,
                )
            } else {
                Vec::new()
            };
            let mut s = PredictiveBip::new(t, seed);
            sum += s.route_batch(&inst).max_violation(&inst);
        }
        sum / n_layers as f64
    };

    // T = 0 isolates the seed itself: cold T=0 routes greedily, warm
    // T=0 routes against the forecast duals — the margin is wide
    let (cold0, warm0) = (vio_at(0, false), vio_at(0, true));
    assert!(
        warm0 < cold0,
        "warm {warm0} !< cold {cold0} at T=0 (first batch)"
    );
    assert!(
        cold0 - warm0 > 0.1,
        "warm-start margin collapsed: cold {cold0} warm {warm0}"
    );
    // and the advantage survives refinement iterations (weakly: the
    // dual fixpoint washes the seed out as T grows)
    let (cold2, warm2) = (vio_at(2, false), vio_at(2, true));
    assert!(
        warm2 < cold2 + 0.05,
        "warm start must not hurt at T=2: cold {cold2} warm {warm2}"
    );
}

#[test]
fn fit_from_recorded_trace_is_deterministic_and_round_trips() {
    let fit_once = || -> (String, Vec<f64>) {
        let trace = demand_trace(Scenario::Steady, 1_024, 11);
        let series = LoadSeries::from_trace(&trace).unwrap();
        let (model, report) = fit_model(
            ForecasterKind::HoltWinters,
            &ForecastConfig::default(),
            &series,
            &[1, 4],
            0.25,
        )
        .unwrap();
        assert!(report.by_horizon.iter().all(|h| h.samples > 0));
        (model.to_json().to_string(), model.layer_forecast(0, 4))
    };
    let (json_a, pred_a) = fit_once();
    let (json_b, pred_b) = fit_once();
    assert_eq!(json_a, json_b, "same trace must fit bit-identically");
    assert_eq!(pred_a, pred_b);

    // disk round trip preserves forecasts exactly
    let path = std::env::temp_dir().join(format!(
        "bipmoe-forecast-{}.json",
        std::process::id()
    ));
    std::fs::write(&path, format!("{json_a}\n")).unwrap();
    let loaded = ForecastModel::load(&path).unwrap();
    assert_eq!(loaded.layer_forecast(0, 4), pred_a);
    let _ = std::fs::remove_file(&path);

    // and the JSON is structurally sane
    let j = Json::parse(&json_a).unwrap();
    assert_eq!(
        j.path("format").and_then(Json::as_str),
        Some("bip-moe-forecast")
    );
    assert_eq!(j.path("m").and_then(Json::as_usize), Some(16));
}

#[test]
fn warm_serve_runs_are_deterministic_and_work_conserving() {
    use bip_moe::forecast::seed_states;
    use bip_moe::serve::run_scenario_seeded;
    let trace = demand_trace(Scenario::Bursty, 1_024, 13);
    let series = LoadSeries::from_trace(&trace).unwrap();
    let (model, _) = fit_model(
        ForecasterKind::Ewma,
        &ForecastConfig::default(),
        &series,
        &[1],
        0.25,
    )
    .unwrap();
    let seeds = seed_states(&model, 4, 4, DEFAULT_SEED_GAIN);
    assert_eq!(seeds.len(), 4);
    for s in &seeds {
        match s {
            BalanceState::Dual(q) => {
                assert_eq!(q.len(), 16);
                assert!(q.iter().all(|&x| x >= 0.0));
            }
            other => panic!("expected Dual seeds, got {other:?}"),
        }
    }
    let cfg = ServeConfig::new(
        TrafficConfig {
            scenario: Scenario::Bursty,
            n_requests: 1_024,
            seed: 13,
            ..Default::default()
        },
        SchedulerConfig::default(),
        RouterConfig::default(),
        Policy::Predictive,
    );
    let a = run_scenario_seeded(&cfg, &seeds);
    let b = run_scenario_seeded(&cfg, &seeds);
    assert!(a.report.conserves_work(), "{:?}", a.report);
    assert_eq!(a.report.completed, b.report.completed);
    assert_eq!(a.report.avg_max_vio, b.report.avg_max_vio);
    assert_eq!(a.first_batch_vio, b.first_batch_vio);
    assert_eq!(a.report.policy, "bip-predictive");
}
