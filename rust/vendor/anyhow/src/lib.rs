//! Offline stand-in for the `anyhow` crate.
//!
//! The build container has no crates.io access, so this vendored path
//! dependency provides the subset of anyhow the workspace uses: the
//! context-chained [`Error`] type, [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros. Semantics match
//! upstream where it matters:
//!
//! * `{err}` displays the outermost message, `{err:#}` the whole chain
//!   joined by `": "`, and `{err:?}` an anyhow-style "Caused by:" report;
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`
//!   (its source chain is preserved);
//! * `.context(..)` / `.with_context(..)` work on `Result` (including
//!   `anyhow::Result`) and `Option`.

use std::fmt;

/// `Result` defaulted to [`Error`], as in upstream anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chained error. `chain[0]` is the outermost context, the last
/// entry the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an [`Error`] from a format string, as `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return an `Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e = Error::msg("root").context("mid").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: root");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(format!("{e}").contains("gone"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening file").unwrap_err();
        assert_eq!(format!("{e:#}"), "opening file: gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn context_stacks_on_anyhow_results() {
        fn inner() -> Result<()> {
            bail!("bad {}", 42)
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: bad 42");
        assert_eq!(e.root_cause(), "bad 42");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn ensure_macro() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(30).is_err());
    }
}
