//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate wraps `xla_extension` (PJRT CPU client + HLO
//! compilation), which cannot exist in this network-less build image.
//! This stub keeps the whole workspace compiling and keeps every
//! *host-data* path fully functional:
//!
//! * [`Literal`] — a real host tensor value (f32 / i32 / tuple) with
//!   `vec1` / `reshape` / `to_vec` / `to_tuple`, enough for the
//!   `runtime::Tensor` round-trip tests;
//! * [`PjRtClient::cpu`] — succeeds (platform `"stub-host"`) so
//!   `Engine::new` still validates the artifact manifest;
//! * [`HloModuleProto::from_text_file`] — reads the HLO text;
//! * [`PjRtClient::compile`] — returns a clear error: actually executing
//!   AOT artifacts requires the real bindings. Integration tests already
//!   skip when `artifacts/` is absent, so `cargo test` stays green.

use std::fmt;
use std::path::Path;

/// Stub error type (message-only).
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Storage behind a [`Literal`]. Public only because the [`NativeType`]
/// trait must name it; treat as an implementation detail.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + PartialEq + fmt::Debug {
    #[doc(hidden)]
    fn store(v: Vec<Self>) -> Storage;
    #[doc(hidden)]
    fn read(s: &Storage) -> Option<&[Self]>;
    fn type_name() -> &'static str;
}

impl NativeType for f32 {
    fn store(v: Vec<f32>) -> Storage {
        Storage::F32(v)
    }

    fn read(s: &Storage) -> Option<&[f32]> {
        match s {
            Storage::F32(v) => Some(v),
            _ => None,
        }
    }

    fn type_name() -> &'static str {
        "f32"
    }
}

impl NativeType for i32 {
    fn store(v: Vec<i32>) -> Storage {
        Storage::I32(v)
    }

    fn read(s: &Storage) -> Option<&[i32]> {
        match s {
            Storage::I32(v) => Some(v),
            _ => None,
        }
    }

    fn type_name() -> &'static str {
        "i32"
    }
}

/// A host literal: typed flat data plus dimensions.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            storage: T::store(data.to_vec()),
            dims: vec![data.len() as i64],
        }
    }

    /// Tuple literal (what executables return with `return_tuple=True`).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        let n = parts.len() as i64;
        Literal { storage: Storage::Tuple(parts), dims: vec![n] }
    }

    pub fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::Tuple(t) => t.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Same data, new dimensions; the element count must match
    /// (an empty `dims` is a scalar: product 1).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count < 0 || count as usize != self.element_count() {
            return Err(Error::new(format!(
                "cannot reshape {} elements into {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    /// Copy out the typed data.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read(&self.storage).map(<[T]>::to_vec).ok_or_else(|| {
            Error::new(format!("literal is not {}", T::type_name()))
        })
    }

    /// Decompose a tuple literal; a non-tuple decomposes to itself.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.storage {
            Storage::Tuple(parts) => Ok(parts),
            _ => Ok(vec![self]),
        }
    }
}

/// Parsed HLO module (the stub just keeps the text).
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        std::fs::read_to_string(path.as_ref())
            .map(|text| HloModuleProto { text })
            .map_err(|e| {
                Error::new(format!("reading {:?}: {e}", path.as_ref()))
            })
    }
}

pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text: proto.text.clone() }
    }
}

/// Stub PJRT client: construction succeeds, compilation does not.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "stub-host".to_string()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(
            "HLO compilation is unavailable in the offline build; install \
             the real `xla` bindings (xla_extension) to execute AOT \
             artifacts",
        ))
    }
}

/// Anything `execute` accepts as an argument buffer.
pub trait AsLiteral {
    fn as_literal(&self) -> &Literal;
}

impl AsLiteral for Literal {
    fn as_literal(&self) -> &Literal {
        self
    }
}

pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: AsLiteral>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new("execution is unavailable in the offline build"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_vec1_reshape_to_vec() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(lit.dims(), &[4]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[3]).is_err());
    }

    #[test]
    fn scalar_reshape() {
        let lit = Literal::vec1(&[42i32]).reshape(&[]).unwrap();
        assert_eq!(lit.dims(), &[] as &[i64]);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![42]);
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::tuple(vec![
            Literal::vec1(&[1.0f32]),
            Literal::vec1(&[2i32, 3]),
        ]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].to_vec::<i32>().unwrap(), vec![2, 3]);
        // non-tuple yields itself
        let single = Literal::vec1(&[5i32]).to_tuple().unwrap();
        assert_eq!(single.len(), 1);
    }

    #[test]
    fn client_constructs_but_cannot_compile() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "stub-host");
        let comp = XlaComputation::from_proto(&HloModuleProto {
            text: "HloModule m".into(),
        });
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("offline"));
    }
}
