//! Ad-slot matching simulator driving Algorithms 3/4 on a CTR workload.

use crate::bip::approx::ApproxGate;
use crate::bip::flow::solve_exact;
use crate::bip::online::OnlineGate;
use crate::bip::Instance;
use crate::util::rng::{Pcg64, Zipf};

/// A stream of flows with CTRs over `n_ads` advertisers.
/// CTRs mix a per-advertiser popularity (Zipf — a few advertisers are
/// broadly attractive, the congestion the capacity constraint fights)
/// with per-flow idiosyncratic taste.
pub struct Workload {
    pub n_flows: usize,
    pub n_ads: usize,
    pub slots: usize,
    pub ctrs: Vec<f32>, // row-major (n_flows, n_ads), in (0, 1)
}

impl Workload {
    pub fn synthetic(
        n_flows: usize,
        n_ads: usize,
        slots: usize,
        seed: u64,
    ) -> Workload {
        let mut rng = Pcg64::new(seed);
        let zipf = Zipf::new(n_ads, 1.1);
        // popularity weights from Zipf rank frequencies
        let mut pop = vec![0.0f64; n_ads];
        for _ in 0..n_ads * 64 {
            pop[zipf.sample(&mut rng)] += 1.0;
        }
        let max_pop = pop.iter().cloned().fold(0.0, f64::max);
        let mut ctrs = Vec::with_capacity(n_flows * n_ads);
        for _ in 0..n_flows {
            for j in 0..n_ads {
                let base = 0.02 + 0.1 * pop[j] / max_pop;
                let noise = rng.next_f64() * 0.05;
                ctrs.push((base + noise) as f32);
            }
        }
        Workload { n_flows, n_ads, slots, ctrs }
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.ctrs[i * self.n_ads..(i + 1) * self.n_ads]
    }

    pub fn capacity(&self) -> usize {
        self.n_flows * self.slots / self.n_ads
    }

    fn as_instance(&self) -> Instance {
        Instance {
            n: self.n_flows,
            m: self.n_ads,
            k: self.slots,
            cap: self.capacity(),
            scores: self.ctrs.clone(),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatchPolicy {
    /// Plain top-k CTR — ignores advertiser caps.
    Greedy,
    /// Algorithm 3 (exact per-advertiser heaps).
    Online { t_iters: usize },
    /// Algorithm 4 (b-bucket histograms).
    Approx { t_iters: usize, buckets: usize },
}

#[derive(Clone, Debug)]
pub struct MatchReport {
    pub policy: String,
    pub objective: f64,
    pub hindsight_objective: f64,
    pub competitive_ratio: f64,
    pub max_violation: f64,
    pub state_bytes: usize,
}

/// Run one policy over the workload; hindsight optimum via min-cost flow.
pub fn run_policy(w: &Workload, policy: MatchPolicy) -> MatchReport {
    let cap = w.capacity();
    let mut loads = vec![0u64; w.n_ads];
    let mut objective = 0.0f64;
    let mut state_bytes = w.n_ads * 4;

    match policy {
        MatchPolicy::Greedy => {
            for i in 0..w.n_flows {
                for j in crate::util::stats::topk_indices(w.row(i), w.slots) {
                    loads[j] += 1;
                    objective += w.row(i)[j] as f64;
                }
            }
        }
        MatchPolicy::Online { t_iters } => {
            let mut gate = OnlineGate::new(w.n_ads, w.slots, cap, t_iters);
            for i in 0..w.n_flows {
                for &j in &gate.route_token(w.row(i)) {
                    loads[j as usize] += 1;
                    objective += w.row(i)[j as usize] as f64;
                }
            }
            state_bytes = gate.state_bytes();
        }
        MatchPolicy::Approx { t_iters, buckets } => {
            let mut gate =
                ApproxGate::new(w.n_ads, w.slots, cap, t_iters, buckets);
            for i in 0..w.n_flows {
                for &j in &gate.route_token(w.row(i)) {
                    loads[j as usize] += 1;
                    objective += w.row(i)[j as usize] as f64;
                }
            }
            state_bytes = gate.state_bytes();
        }
    }

    let inst = w.as_instance();
    let (_, hindsight) = solve_exact(&inst);
    let mean = (w.n_flows * w.slots) as f64 / w.n_ads as f64;
    let max_violation =
        *loads.iter().max().unwrap() as f64 / mean - 1.0;
    MatchReport {
        policy: format!("{policy:?}"),
        objective,
        hindsight_objective: hindsight,
        competitive_ratio: objective / hindsight,
        max_violation,
        state_bytes,
    }
}

/// Convenience: greedy vs Alg 3 vs Alg 4 on one workload.
pub fn compare_policies(w: &Workload, t_iters: usize, buckets: usize)
    -> Vec<MatchReport>
{
    vec![
        run_policy(w, MatchPolicy::Greedy),
        run_policy(w, MatchPolicy::Online { t_iters }),
        run_policy(w, MatchPolicy::Approx { t_iters, buckets }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn workload() -> Workload {
        Workload::synthetic(256, 16, 2, 42)
    }

    #[test]
    fn greedy_overloads_popular_advertisers() {
        let r = run_policy(&workload(), MatchPolicy::Greedy);
        assert!(r.max_violation > 0.5, "vio {}", r.max_violation);
    }

    #[test]
    fn online_respects_balance_with_small_objective_loss() {
        let w = workload();
        let greedy = run_policy(&w, MatchPolicy::Greedy);
        let online = run_policy(&w, MatchPolicy::Online { t_iters: 4 });
        assert!(online.max_violation < greedy.max_violation * 0.6,
                "online {} greedy {}", online.max_violation,
                greedy.max_violation);
        // CTR spreads are narrow (0.02..0.17), so enforcing the cap costs
        // real objective; the LP argument still keeps it within ~30%
        assert!(online.objective >= 0.70 * greedy.objective,
                "online {} greedy {}", online.objective, greedy.objective);
        assert!(online.competitive_ratio > 0.70,
                "ratio {}", online.competitive_ratio);
        // objective can never beat greedy (greedy is per-flow optimal)
        assert!(online.objective <= greedy.objective + 1e-6);
    }

    #[test]
    fn approx_tracks_online_with_constant_space() {
        let w = Workload::synthetic(512, 16, 2, 7);
        let online = run_policy(&w, MatchPolicy::Online { t_iters: 4 });
        let approx = run_policy(
            &w, MatchPolicy::Approx { t_iters: 4, buckets: 128 });
        assert!((approx.competitive_ratio - online.competitive_ratio).abs()
                < 0.10);
        // Alg 4 state is O(m*b); Alg 3 grows toward O(m*cap)
        assert!(approx.state_bytes <= 16 * 128 * 12 + 16 * 8 + 16 * 4 + 64);
    }

    #[test]
    fn hindsight_dominates_every_feasible_policy() {
        let w = workload();
        for r in compare_policies(&w, 4, 64) {
            if r.max_violation <= 0.0 {
                assert!(r.objective <= r.hindsight_objective + 1e-6,
                        "{}", r.policy);
            }
        }
    }
}
