//! Section 5 application: multi-slot online matching for recommendation.
//!
//! Scenario (paper §5.1): a webpage has k advertisement slots; flows
//! (page views) arrive online; each (flow, advertiser) pair has a CTR;
//! we maximize total expected CTR while capping the most popular
//! advertiser's share — exactly problem (BIP) with advertisers as
//! "experts". Algorithm 3 (exact heaps) and Algorithm 4 (constant-space
//! histograms) are the online policies; hindsight min-cost-flow gives
//! the offline optimum for the competitive-ratio column.

pub mod simulator;

pub use simulator::{MatchPolicy, MatchReport, Workload};
