//! The versioned binary trace format.
//!
//! A trace file is a length-prefixed little-endian container:
//!
//! ```text
//! magic "BIPT" (4)  version u32
//! meta block        — the full (ServeConfig, ReplicaConfig) pair, so a
//!                     replay rebuilds the *identical* pipeline
//! arrivals          — count u64, then one block per offered request
//!                     (id, tenant, arrival_us, deadline_us, and the
//!                     row-major (n_layers, m) gate scores)
//! frames            — count u64, then one block per routed micro-batch
//!                     (seq, replica tag, dispatch virtual time, priced
//!                     service time, request ids, per-layer per-token
//!                     enforced top-K, per-layer per-expert loads)
//! syncs             — count u64, then the replica merge-sync events
//! completions       — count u64, then the completion log in dispatch
//!                     order (id, tenant, arrival_us, completion_us)
//! telemetry (v3+)   — count u64, then one (name, f64) block per
//!                     recorded metric series (the recorder's
//!                     counter/gauge scrape), so replay can diff
//!                     recorded-vs-replayed telemetry
//! ```
//!
//! Every record is a `u32` length-prefixed block, so a reader can skip
//! records it does not understand; any change to a record's *interior*
//! layout must bump [`TRACE_VERSION`]. Version 1 stores each token's
//! enforced top-K count as a `u8`, so k <= 255 (asserted at recording
//! time — far above any MoE top-K in the paper's range). Readers reject unknown magic and
//! versions up front and report truncation with a byte offset. For
//! small traces [`Trace::to_json`] exports the same content through
//! `util::json` for inspection and tooling.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::serve::{
    Completion, Policy, ReplicaConfig, Request, RouterConfig, Scenario,
    SchedulerConfig, ServeConfig, SyncEvent, TrafficConfig,
};
use crate::util::json::Json;

pub const TRACE_MAGIC: [u8; 4] = *b"BIPT";
/// v2 appends the adaptive-solver knobs (`solver_tol`,
/// `solver_t_max`) to the router block of the meta header — they
/// change routing, so a faithful replay must rebuild them. Readers
/// still accept v1 (the knobs default to 0/0, which is exactly the
/// fixed-T solver every v1 run used).
///
/// v3 appends a telemetry section after the completion log: the
/// recording process's counter/gauge scrape
/// (`telemetry::scrape_named`), one length-prefixed `(name, f64)`
/// block per series, so a replay can diff recorded-vs-replayed
/// metrics. Readers still accept v1/v2 (the section defaults to
/// empty).
pub const TRACE_VERSION: u32 = 3;

/// Everything needed to re-drive the recorded run: the exact serving
/// configuration (traffic, scheduler, router, policy) plus the replica
/// topology the stream was served on.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceMeta {
    pub serve: ServeConfig,
    pub replicas: ReplicaConfig,
}

impl TraceMeta {
    pub fn new(cfg: &ServeConfig, rcfg: &ReplicaConfig) -> TraceMeta {
        TraceMeta { serve: cfg.clone(), replicas: *rcfg }
    }

    /// Whether the recorded run went through the replicated engine
    /// (`run_replicated`) rather than the single-server loop — replay
    /// must branch the same way to stay bit-identical.
    pub fn is_replicated(&self) -> bool {
        self.replicas.replicas > 1 || self.replicas.threads > 1
    }
}

/// One routed micro-batch.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceFrame {
    /// global dispatch order across all replicas
    pub seq: u64,
    /// which replica routed the batch (0 for the single-server loop)
    pub replica: u32,
    /// virtual dispatch time
    pub now_us: u64,
    /// priced service time (completion = now_us + service_us)
    pub service_us: u64,
    /// requests in the batch, FIFO order
    pub ids: Vec<u64>,
    /// `[layer][token]` enforced chosen experts, post capacity
    /// enforcement (fewer than k entries when slots were degraded)
    pub topk: Vec<Vec<Vec<u16>>>,
    /// row-major (n_layers, m) enforced per-expert loads
    pub loads: Vec<f32>,
}

/// A recorded serving run: the offered stream, every routing decision,
/// the replica sync events, and the completion log.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// Format version this trace was read with (or [`TRACE_VERSION`]
    /// for freshly recorded traces) — kept so JSON exports report the
    /// on-disk version, not the reader's.
    pub version: u32,
    pub meta: TraceMeta,
    pub arrivals: Vec<Request>,
    pub frames: Vec<TraceFrame>,
    pub syncs: Vec<SyncEvent>,
    pub completions: Vec<Completion>,
    /// The recording process's counter/gauge scrape at the end of the
    /// run (`telemetry::scrape_named`), empty for v1/v2 traces.
    pub telemetry: Vec<(String, f64)>,
}

impl Trace {
    /// Tokens actually routed (batched), summed over frames.
    pub fn routed_tokens(&self) -> u64 {
        self.frames.iter().map(|f| f.ids.len() as u64).sum()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.raw(&TRACE_MAGIC);
        w.u32(TRACE_VERSION);

        let start = w.begin_block();
        write_meta(&mut w, &self.meta);
        w.end_block(start);

        w.u64(self.arrivals.len() as u64);
        for r in &self.arrivals {
            let start = w.begin_block();
            w.u64(r.id);
            w.u32(r.tenant);
            w.u64(r.arrival_us);
            w.u64(r.deadline_us);
            w.u32(r.scores.len() as u32);
            for &s in &r.scores {
                w.f32(s);
            }
            w.end_block(start);
        }

        w.u64(self.frames.len() as u64);
        for f in &self.frames {
            let start = w.begin_block();
            write_frame(&mut w, f);
            w.end_block(start);
        }

        w.u64(self.syncs.len() as u64);
        for s in &self.syncs {
            let start = w.begin_block();
            w.u64(s.at_batch);
            w.f64(s.vio_spread_before);
            w.f64(s.vio_spread_after);
            w.f64(s.state_div_before);
            w.f64(s.state_div_after);
            w.end_block(start);
        }

        w.u64(self.completions.len() as u64);
        for c in &self.completions {
            let start = w.begin_block();
            w.u64(c.id);
            w.u32(c.tenant);
            w.u64(c.arrival_us);
            w.u64(c.completion_us);
            w.end_block(start);
        }

        w.u64(self.telemetry.len() as u64);
        for (name, value) in &self.telemetry {
            let start = w.begin_block();
            w.str(name);
            w.f64(*value);
            w.end_block(start);
        }

        w.buf
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Trace> {
        let mut r = ByteReader::new(bytes);
        let magic = r.take(4)?;
        if magic != &TRACE_MAGIC[..] {
            bail!("not a bip-moe trace (bad magic {:02x?})", magic);
        }
        let version = r.u32()?;
        if version == 0 || version > TRACE_VERSION {
            bail!(
                "unsupported trace version {version} (this build reads \
                 versions 1..={TRACE_VERSION})"
            );
        }

        let mut mb = r.block()?;
        let meta = read_meta(&mut mb, version)?;

        let n = r.u64()? as usize;
        let mut arrivals = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            let mut b = r.block()?;
            let id = b.u64()?;
            let tenant = b.u32()?;
            let arrival_us = b.u64()?;
            let deadline_us = b.u64()?;
            let ns = b.u32()? as usize;
            let mut scores = Vec::with_capacity(ns.min(1 << 16));
            for _ in 0..ns {
                scores.push(b.f32()?);
            }
            arrivals.push(Request {
                id,
                tenant,
                arrival_us,
                deadline_us,
                scores,
            });
        }

        let n = r.u64()? as usize;
        let mut frames = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            let mut b = r.block()?;
            frames.push(read_frame(&mut b)?);
        }

        let n = r.u64()? as usize;
        let mut syncs = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            let mut b = r.block()?;
            syncs.push(SyncEvent {
                at_batch: b.u64()?,
                vio_spread_before: b.f64()?,
                vio_spread_after: b.f64()?,
                state_div_before: b.f64()?,
                state_div_after: b.f64()?,
            });
        }

        let n = r.u64()? as usize;
        let mut completions = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            let mut b = r.block()?;
            completions.push(Completion {
                id: b.u64()?,
                tenant: b.u32()?,
                arrival_us: b.u64()?,
                completion_us: b.u64()?,
            });
        }

        let telemetry = if version >= 3 {
            let n = r.u64()? as usize;
            let mut tele = Vec::with_capacity(n.min(1 << 10));
            for _ in 0..n {
                let mut b = r.block()?;
                let name = b.str()?;
                let value = b.f64()?;
                tele.push((name, value));
            }
            tele
        } else {
            Vec::new()
        };

        Ok(Trace {
            version,
            meta,
            arrivals,
            frames,
            syncs,
            completions,
            telemetry,
        })
    }

    /// Number of bytes written.
    pub fn save(&self, path: &Path) -> Result<usize> {
        let bytes = self.to_bytes();
        std::fs::write(path, &bytes)
            .with_context(|| format!("writing trace {}", path.display()))?;
        Ok(bytes.len())
    }

    pub fn load(path: &Path) -> Result<Trace> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        Trace::from_bytes(&bytes)
            .with_context(|| format!("parsing trace {}", path.display()))
    }

    /// Full JSON export (intended for *small* traces: the score matrix
    /// of every arrival is inlined).
    pub fn to_json(&self) -> Json {
        let t = &self.meta.serve.traffic;
        let rc = &self.meta.replicas;
        Json::obj(vec![
            ("format", Json::Str("bip-moe-trace".into())),
            ("version", Json::Num(self.version as f64)),
            (
                "meta",
                Json::obj(vec![
                    ("scenario", Json::Str(t.scenario.name().into())),
                    (
                        "policy",
                        Json::Str(self.meta.serve.policy.name().into()),
                    ),
                    ("n_requests", Json::Num(t.n_requests as f64)),
                    ("rate_per_s", Json::Num(t.rate_per_s)),
                    ("m", Json::Num(t.m as f64)),
                    ("k", Json::Num(t.k as f64)),
                    ("n_layers", Json::Num(t.n_layers as f64)),
                    ("slo_us", Json::Num(t.slo_us as f64)),
                    ("seed", Json::Num(t.seed as f64)),
                    ("replicas", Json::Num(rc.replicas as f64)),
                    ("threads", Json::Num(rc.threads as f64)),
                    ("sync_every", Json::Num(rc.sync_every as f64)),
                ]),
            ),
            (
                "arrivals",
                Json::Arr(
                    self.arrivals
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", Json::Num(r.id as f64)),
                                ("tenant", Json::Num(r.tenant as f64)),
                                (
                                    "arrival_us",
                                    Json::Num(r.arrival_us as f64),
                                ),
                                (
                                    "deadline_us",
                                    Json::Num(r.deadline_us as f64),
                                ),
                                ("scores", Json::from_f32s(&r.scores)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "frames",
                Json::Arr(self.frames.iter().map(frame_json).collect()),
            ),
            (
                "syncs",
                Json::Arr(
                    self.syncs
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("at_batch", Json::Num(s.at_batch as f64)),
                                (
                                    "vio_spread_before",
                                    Json::Num(s.vio_spread_before),
                                ),
                                (
                                    "vio_spread_after",
                                    Json::Num(s.vio_spread_after),
                                ),
                                (
                                    "state_div_before",
                                    Json::Num(s.state_div_before),
                                ),
                                (
                                    "state_div_after",
                                    Json::Num(s.state_div_after),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "completions",
                Json::Arr(
                    self.completions
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("id", Json::Num(c.id as f64)),
                                ("tenant", Json::Num(c.tenant as f64)),
                                (
                                    "arrival_us",
                                    Json::Num(c.arrival_us as f64),
                                ),
                                (
                                    "completion_us",
                                    Json::Num(c.completion_us as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "telemetry",
                Json::Obj(
                    self.telemetry
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v)))
                        .collect(),
                ),
            ),
        ])
    }
}

fn frame_json(f: &TraceFrame) -> Json {
    Json::obj(vec![
        ("seq", Json::Num(f.seq as f64)),
        ("replica", Json::Num(f.replica as f64)),
        ("now_us", Json::Num(f.now_us as f64)),
        ("service_us", Json::Num(f.service_us as f64)),
        (
            "ids",
            Json::Arr(f.ids.iter().map(|&i| Json::Num(i as f64)).collect()),
        ),
        (
            "topk",
            Json::Arr(
                f.topk
                    .iter()
                    .map(|layer| {
                        Json::Arr(
                            layer
                                .iter()
                                .map(|tok| {
                                    Json::Arr(
                                        tok.iter()
                                            .map(|&e| Json::Num(e as f64))
                                            .collect(),
                                    )
                                })
                                .collect(),
                        )
                    })
                    .collect(),
            ),
        ),
        ("loads", Json::from_f32s(&f.loads)),
    ])
}

fn write_frame(w: &mut ByteWriter, f: &TraceFrame) {
    w.u64(f.seq);
    w.u32(f.replica);
    w.u64(f.now_us);
    w.u64(f.service_us);
    w.u32(f.ids.len() as u32);
    for &id in &f.ids {
        w.u64(id);
    }
    w.u32(f.topk.len() as u32);
    for layer in &f.topk {
        debug_assert_eq!(layer.len(), f.ids.len());
        for tok in layer {
            debug_assert!(tok.len() <= u8::MAX as usize);
            w.u8(tok.len() as u8);
            for &e in tok {
                w.u16(e);
            }
        }
    }
    w.u32(f.loads.len() as u32);
    for &x in &f.loads {
        w.f32(x);
    }
}

fn read_frame(b: &mut ByteReader) -> Result<TraceFrame> {
    let seq = b.u64()?;
    let replica = b.u32()?;
    let now_us = b.u64()?;
    let service_us = b.u64()?;
    let n_tokens = b.u32()? as usize;
    let mut ids = Vec::with_capacity(n_tokens.min(1 << 16));
    for _ in 0..n_tokens {
        ids.push(b.u64()?);
    }
    let n_layers = b.u32()? as usize;
    let mut topk = Vec::with_capacity(n_layers.min(1 << 10));
    for _ in 0..n_layers {
        let mut layer = Vec::with_capacity(n_tokens.min(1 << 16));
        for _ in 0..n_tokens {
            let len = b.u8()? as usize;
            let mut tok = Vec::with_capacity(len);
            for _ in 0..len {
                tok.push(b.u16()?);
            }
            layer.push(tok);
        }
        topk.push(layer);
    }
    let nl = b.u32()? as usize;
    let mut loads = Vec::with_capacity(nl.min(1 << 16));
    for _ in 0..nl {
        loads.push(b.f32()?);
    }
    Ok(TraceFrame { seq, replica, now_us, service_us, ids, topk, loads })
}

fn write_meta(w: &mut ByteWriter, meta: &TraceMeta) {
    let t = &meta.serve.traffic;
    w.str(t.scenario.name());
    w.u64(t.n_requests as u64);
    w.f64(t.rate_per_s);
    w.u64(t.n_layers as u64);
    w.u64(t.m as u64);
    w.u64(t.k as u64);
    w.u64(t.n_tenants as u64);
    w.u64(t.slo_us);
    w.f64(t.temp);
    w.f64(t.skew);
    w.u64(t.seed);

    let s = &meta.serve.sched;
    w.u64(s.queue_cap as u64);
    w.u64(s.batch_max as u64);
    w.u64(s.max_wait_us);
    w.u8(s.drop_expired as u8);

    let r = &meta.serve.router;
    w.u64(r.m as u64);
    w.u64(r.k as u64);
    w.u64(r.n_layers as u64);
    w.u64(r.t_iters as u64);
    w.u64(r.buckets as u64);
    w.u64(r.expected_stream as u64);
    w.f64(r.capacity_factor);
    w.u64(r.n_devices as u64);
    // 0 encodes None (Some(0) is rejected by the router's constructor)
    w.u64(r.lpt_refresh.unwrap_or(0));
    w.f32(r.lossfree_u);
    w.f64(r.solver_tol);
    w.u64(r.solver_t_max as u64);

    w.str(meta.serve.policy.name());

    let rc = &meta.replicas;
    w.u64(rc.replicas as u64);
    w.u64(rc.threads as u64);
    w.u64(rc.sync_every);
}

fn read_meta(b: &mut ByteReader, version: u32) -> Result<TraceMeta> {
    let scenario_name = b.str()?;
    let scenario = Scenario::parse(&scenario_name)
        .ok_or_else(|| anyhow!("unknown trace scenario {scenario_name}"))?;
    let traffic = TrafficConfig {
        scenario,
        n_requests: b.u64()? as usize,
        rate_per_s: b.f64()?,
        n_layers: b.u64()? as usize,
        m: b.u64()? as usize,
        k: b.u64()? as usize,
        n_tenants: b.u64()? as usize,
        slo_us: b.u64()?,
        temp: b.f64()?,
        skew: b.f64()?,
        seed: b.u64()?,
    };
    let sched = SchedulerConfig {
        queue_cap: b.u64()? as usize,
        batch_max: b.u64()? as usize,
        max_wait_us: b.u64()?,
        drop_expired: b.u8()? != 0,
    };
    let router = RouterConfig {
        m: b.u64()? as usize,
        k: b.u64()? as usize,
        n_layers: b.u64()? as usize,
        t_iters: b.u64()? as usize,
        buckets: b.u64()? as usize,
        expected_stream: b.u64()? as usize,
        capacity_factor: b.f64()?,
        n_devices: b.u64()? as usize,
        lpt_refresh: match b.u64()? {
            0 => None,
            n => Some(n),
        },
        lossfree_u: b.f32()?,
        // v1 predates the adaptive solver: every v1 run used the
        // fixed-T path, which 0/0 rebuilds bit-faithfully
        solver_tol: if version >= 2 { b.f64()? } else { 0.0 },
        solver_t_max: if version >= 2 { b.u64()? as usize } else { 0 },
    };
    let policy_name = b.str()?;
    let policy = Policy::parse(&policy_name)
        .ok_or_else(|| anyhow!("unknown trace policy {policy_name}"))?;
    let replicas = ReplicaConfig {
        replicas: b.u64()? as usize,
        threads: b.u64()? as usize,
        sync_every: b.u64()?,
    };
    Ok(TraceMeta {
        serve: ServeConfig { traffic, sched, router, policy },
        replicas,
    })
}

// ---- little-endian length-prefixed primitives --------------------------

pub(crate) struct ByteWriter {
    pub buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter { buf: Vec::new() }
    }

    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    pub fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    pub fn u16(&mut self, x: u16) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn f32(&mut self, x: f32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn f64(&mut self, x: f64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.raw(s.as_bytes());
    }

    /// Start a length-prefixed block; returns the position to hand to
    /// [`ByteWriter::end_block`], which patches the length in place.
    pub fn begin_block(&mut self) -> usize {
        self.u32(0);
        self.buf.len()
    }

    pub fn end_block(&mut self, start: usize) {
        let len = (self.buf.len() - start) as u32;
        self.buf[start - 4..start].copy_from_slice(&len.to_le_bytes());
    }
}

pub(crate) struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                anyhow!(
                    "trace truncated at byte {} (wanted {} more of {})",
                    self.pos,
                    n,
                    self.buf.len()
                )
            })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| anyhow!("trace string is not utf-8"))
    }

    /// Read one length-prefixed block as a sub-reader.
    pub fn block(&mut self) -> Result<ByteReader<'a>> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        Ok(ByteReader::new(bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_round_trip() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.u16(65_535);
        w.u32(123_456);
        w.u64(1 << 60);
        w.f32(-0.5);
        w.f64(std::f64::consts::PI);
        w.str("héllo");
        let start = w.begin_block();
        w.u32(42);
        w.end_block(start);

        let mut r = ByteReader::new(&w.buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 65_535);
        assert_eq!(r.u32().unwrap(), 123_456);
        assert_eq!(r.u64().unwrap(), 1 << 60);
        assert_eq!(r.f32().unwrap(), -0.5);
        assert_eq!(r.f64().unwrap(), std::f64::consts::PI);
        assert_eq!(r.str().unwrap(), "héllo");
        let mut b = r.block().unwrap();
        assert_eq!(b.u32().unwrap(), 42);
        assert!(b.u8().is_err(), "block must bound its reads");
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = ByteWriter::new();
        w.u64(9);
        let mut r = ByteReader::new(&w.buf[..5]);
        let err = r.u64().unwrap_err();
        assert!(format!("{err}").contains("truncated"), "{err}");
    }

    #[test]
    fn meta_round_trips_bit_exactly() {
        let cfg = ServeConfig::new(
            TrafficConfig {
                scenario: Scenario::Bursty,
                n_requests: 777,
                rate_per_s: 123_456.789,
                temp: 1.75,
                skew: 3.125,
                seed: 99,
                ..Default::default()
            },
            SchedulerConfig { queue_cap: 33, ..Default::default() },
            RouterConfig {
                lpt_refresh: Some(5),
                capacity_factor: 1.5,
                solver_tol: 0.0625,
                solver_t_max: 24,
                ..Default::default()
            },
            Policy::Approx,
        );
        let rcfg =
            ReplicaConfig { replicas: 3, threads: 2, sync_every: 11 };
        let meta = TraceMeta::new(&cfg, &rcfg);
        let mut w = ByteWriter::new();
        write_meta(&mut w, &meta);
        let mut r = ByteReader::new(&w.buf);
        let back = read_meta(&mut r, TRACE_VERSION).unwrap();
        assert_eq!(back, meta);
        assert!(back.is_replicated());
    }

    #[test]
    fn v1_meta_without_solver_knobs_still_reads() {
        // a v1 trace header ends at lossfree_u + policy + replicas;
        // the reader must default the appended v2 solver knobs to the
        // fixed-T configuration instead of rejecting the trace
        let cfg = ServeConfig::new(
            TrafficConfig::default(),
            SchedulerConfig::default(),
            RouterConfig { solver_tol: 0.5, solver_t_max: 9, ..Default::default() },
            Policy::Online,
        );
        let rcfg = ReplicaConfig::default();
        let meta = TraceMeta::new(&cfg, &rcfg);
        let mut w = ByteWriter::new();
        write_meta(&mut w, &meta);
        // carve the v2 buffer into v1 shape by dropping the 16 solver
        // bytes (f64 solver_tol + u64 solver_t_max), which sit between
        // lossfree_u and the trailing policy string (u32 len + bytes)
        // + replicas block (3 u64s = 24 bytes)
        let tail = 24 + 4 + meta.serve.policy.name().len();
        let cut = w.buf.len() - tail - 16;
        let mut buf = w.buf[..cut].to_vec();
        buf.extend_from_slice(&w.buf[w.buf.len() - tail..]);
        let mut r = ByteReader::new(&buf);
        let back = read_meta(&mut r, 1).unwrap();
        assert_eq!(back.serve.router.solver_tol, 0.0);
        assert_eq!(back.serve.router.solver_t_max, 0);
        assert_eq!(back.serve.router.m, meta.serve.router.m);
        assert_eq!(back.serve.policy, meta.serve.policy);
        assert_eq!(back.replicas, meta.replicas);
    }

    fn tiny_trace() -> Trace {
        let cfg = ServeConfig::new(
            TrafficConfig { n_requests: 0, ..Default::default() },
            SchedulerConfig::default(),
            RouterConfig::default(),
            Policy::Online,
        );
        Trace {
            version: TRACE_VERSION,
            meta: TraceMeta::new(&cfg, &ReplicaConfig::default()),
            arrivals: Vec::new(),
            frames: Vec::new(),
            syncs: Vec::new(),
            completions: Vec::new(),
            telemetry: Vec::new(),
        }
    }

    #[test]
    fn v3_telemetry_section_round_trips() {
        let mut trace = tiny_trace();
        trace.telemetry = vec![
            ("router_batches_total".to_string(), 42.0),
            ("solver_last_maxvio".to_string(), 0.125),
        ];
        let back = Trace::from_bytes(&trace.to_bytes()).unwrap();
        assert_eq!(back.telemetry, trace.telemetry);
        assert_eq!(back, trace);
        let json = format!("{}", back.to_json());
        assert!(json.contains("\"router_batches_total\":42"), "{json}");
    }

    #[test]
    fn v2_trace_without_telemetry_still_reads() {
        // a v2 file ends right after the completion log: carve the v3
        // buffer into v2 shape by dropping the (empty) telemetry count
        // and patching the version field
        let trace = tiny_trace();
        let mut bytes = trace.to_bytes();
        bytes.truncate(bytes.len() - 8);
        bytes[4..8].copy_from_slice(&2u32.to_le_bytes());
        let back = Trace::from_bytes(&bytes).unwrap();
        assert_eq!(back.version, 2);
        assert!(back.telemetry.is_empty());
        assert_eq!(back.meta, trace.meta);
    }

    #[test]
    fn bad_magic_and_version_are_rejected() {
        assert!(Trace::from_bytes(b"nope").is_err());
        let err = Trace::from_bytes(b"XXXX\x01\x00\x00\x00").unwrap_err();
        assert!(format!("{err}").contains("magic"), "{err}");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&TRACE_MAGIC);
        bytes.extend_from_slice(&99u32.to_le_bytes());
        let err = Trace::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err}").contains("version"), "{err}");
    }
}
