//! Deterministic replay and counterfactual policy re-evaluation.
//!
//! **Regression mode** ([`replay`]): rebuild the exact pipeline from the
//! trace header (same engine: single-server or replicated, same
//! scheduler/router/policy config) and re-drive it from the recorded
//! arrival stream instead of a `TrafficGenerator`. Every stage is
//! deterministic, so the replayed completion log must equal the
//! recorded one *field for field* — any divergence is a behavior change
//! in the serving stack and is reported per completion.
//!
//! **Counterfactual mode** ([`reroute`] / [`diff_policies`]): freeze the
//! recorded workload — the same micro-batches, in the same dispatch
//! order — and re-route the recorded gate scores under a *different*
//! [`Policy`]. Admission and batch formation stay as recorded (the
//! "frozen batching" approximation); service times are re-priced from
//! the counterfactual loads and chained per replica
//! (`start = max(recorded dispatch, replica busy-until)`), which yields
//! counterfactual SLO percentiles next to the recorded ones. For a
//! replicated trace the merged dispatch stream flows through one
//! counterfactual router, so the comparison isolates the balancing
//! policy from replica-state sharding. Re-routing a trace under its own
//! recorded policy is the identity: top-K agreement 1.0, zero MaxVio
//! delta, equal SLO — pinned by tests.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::metrics::max_violation;
use crate::serve::sim::serve_cost_for;
use crate::serve::{
    run_replicated_with, run_scenario_with, Completion, Policy, Request,
    Scenario, ServeReport, ServingRouter, SloTracker,
};
use crate::util::json::Json;
use crate::util::stats::Summary;

use super::format::Trace;

/// Outcome of a regression replay.
pub struct Replay {
    /// the replayed run's report (same shape as the recorded run)
    pub report: ServeReport,
    pub completions: Vec<Completion>,
    /// empty iff the replay is bit-identical to the recording
    pub mismatches: Vec<String>,
}

/// Re-drive the recorded run and diff its completions against the
/// recording.
pub fn replay(trace: &Trace) -> Replay {
    let cfg = trace.meta.serve.clone();
    let rcfg = trace.meta.replicas;
    let source = trace.arrivals.iter().cloned();
    let (report, completions) = if trace.meta.is_replicated() {
        let out = run_replicated_with(&cfg, &rcfg, source, None);
        (out.report, out.completions)
    } else {
        let out = run_scenario_with(&cfg, source, None);
        (out.report, out.completions)
    };
    let mismatches = diff_completions(&trace.completions, &completions);
    Replay { report, completions, mismatches }
}

const MAX_REPORTED_MISMATCHES: usize = 8;

fn diff_completions(
    recorded: &[Completion],
    replayed: &[Completion],
) -> Vec<String> {
    let mut out = Vec::new();
    if recorded.len() != replayed.len() {
        out.push(format!(
            "completion count: recorded {} vs replayed {}",
            recorded.len(),
            replayed.len()
        ));
    }
    let mut extra = 0usize;
    for (i, (a, b)) in recorded.iter().zip(replayed).enumerate() {
        if a != b {
            if out.len() < MAX_REPORTED_MISMATCHES {
                out.push(format!(
                    "completion {i}: recorded id={} tenant={} \
                     arrival={} completion={} vs replayed id={} \
                     tenant={} arrival={} completion={}",
                    a.id,
                    a.tenant,
                    a.arrival_us,
                    a.completion_us,
                    b.id,
                    b.tenant,
                    b.arrival_us,
                    b.completion_us
                ));
            } else {
                extra += 1;
            }
        }
    }
    if extra > 0 {
        out.push(format!("... and {extra} more mismatched completions"));
    }
    out
}

/// One counterfactual policy's diff against the recording.
#[derive(Clone, Debug)]
pub struct PolicyDiff {
    /// the counterfactual policy
    pub policy: String,
    pub recorded_policy: String,
    /// always [`Scenario::Replayed`]'s name — the workload is the trace
    pub scenario: String,
    pub frames: u64,
    pub tokens: u64,
    pub avg_max_vio_recorded: f64,
    pub avg_max_vio: f64,
    pub sup_max_vio_recorded: f64,
    pub sup_max_vio: f64,
    /// mean over frames of (counterfactual − recorded) per-frame MaxVio
    pub vio_delta_mean: f64,
    /// fraction of recorded (token, layer) expert slots the
    /// counterfactual policy also chose
    pub topk_agreement: f64,
    pub overflow: u64,
    pub degraded: u64,
    pub p50_ms_recorded: f64,
    pub p50_ms: f64,
    pub p95_ms_recorded: f64,
    pub p95_ms: f64,
    pub p99_ms_recorded: f64,
    pub p99_ms: f64,
    pub slo_violations_recorded: u64,
    pub slo_violations: u64,
}

impl PolicyDiff {
    pub fn headers() -> &'static [&'static str] {
        &[
            "Policy", "AvgVioRec", "AvgVioCf", "dVio", "TopKAgree",
            "Overflow", "p99Rec", "p99Cf", "SloVioRec", "SloVioCf",
        ]
    }

    pub fn table_row(&self) -> Vec<String> {
        vec![
            self.policy.clone(),
            format!("{:.4}", self.avg_max_vio_recorded),
            format!("{:.4}", self.avg_max_vio),
            format!("{:+.4}", self.vio_delta_mean),
            format!("{:.3}", self.topk_agreement),
            format!("{}", self.overflow),
            format!("{:.2}", self.p99_ms_recorded),
            format!("{:.2}", self.p99_ms),
            format!("{}", self.slo_violations_recorded),
            format!("{}", self.slo_violations),
        ]
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::Str(self.policy.clone())),
            ("recorded_policy", Json::Str(self.recorded_policy.clone())),
            ("scenario", Json::Str(self.scenario.clone())),
            ("frames", Json::Num(self.frames as f64)),
            ("tokens", Json::Num(self.tokens as f64)),
            (
                "avg_max_vio_recorded",
                Json::Num(self.avg_max_vio_recorded),
            ),
            ("avg_max_vio", Json::Num(self.avg_max_vio)),
            (
                "sup_max_vio_recorded",
                Json::Num(self.sup_max_vio_recorded),
            ),
            ("sup_max_vio", Json::Num(self.sup_max_vio)),
            ("vio_delta_mean", Json::Num(self.vio_delta_mean)),
            ("topk_agreement", Json::Num(self.topk_agreement)),
            ("overflow", Json::Num(self.overflow as f64)),
            ("degraded", Json::Num(self.degraded as f64)),
            ("p50_ms_recorded", Json::Num(self.p50_ms_recorded)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p95_ms_recorded", Json::Num(self.p95_ms_recorded)),
            ("p95_ms", Json::Num(self.p95_ms)),
            ("p99_ms_recorded", Json::Num(self.p99_ms_recorded)),
            ("p99_ms", Json::Num(self.p99_ms)),
            (
                "slo_violations_recorded",
                Json::Num(self.slo_violations_recorded as f64),
            ),
            ("slo_violations", Json::Num(self.slo_violations as f64)),
        ])
    }
}

/// Mean over layers of the per-layer MaxVio of one (n_layers, m) load
/// matrix — the same f64 arithmetic `BalanceTracker` records, so a
/// same-policy reroute produces *exactly* zero delta.
fn frame_vio(
    loads: &[f32],
    n_tokens: usize,
    m: usize,
    k: usize,
    n_layers: usize,
) -> f64 {
    let mut sum = 0.0;
    for l in 0..n_layers {
        sum += max_violation(&loads[l * m..(l + 1) * m], n_tokens, k);
    }
    sum / n_layers as f64
}

/// Re-route the recorded stream under `policy` (frozen batching).
pub fn reroute(trace: &Trace, policy: Policy) -> Result<PolicyDiff> {
    let meta = &trace.meta;
    let rc = meta.serve.router.clone();
    let (m, k, n_layers) = (rc.m, rc.k, rc.n_layers);
    let mut router = ServingRouter::new(policy, rc.clone());
    router.capture_assignments = true;
    let cost = serve_cost_for(&rc);
    let by_id: HashMap<u64, &Request> =
        trace.arrivals.iter().map(|r| (r.id, r)).collect();

    let n_replicas = meta.replicas.replicas.max(1);
    let mut replica_free = vec![0u64; n_replicas];
    let slo_us = meta.serve.traffic.slo_us;
    let mut slo_cf = SloTracker::new(slo_us);
    let mut rec_vio = Summary::new();
    let mut cf_vio = Summary::new();
    let mut delta = Summary::new();
    let (mut agree_num, mut agree_den) = (0u64, 0u64);
    let mut tokens = 0u64;

    for f in &trace.frames {
        if f.replica as usize >= n_replicas {
            bail!(
                "frame {}: replica {} outside the recorded set of {}",
                f.seq,
                f.replica,
                n_replicas
            );
        }
        if f.ids.is_empty() {
            bail!("frame {}: empty micro-batch", f.seq);
        }
        if f.topk.len() != n_layers || f.loads.len() != n_layers * m {
            bail!(
                "frame {}: shape mismatch (topk layers {}, loads {}, \
                 expected {} layers x {} experts)",
                f.seq,
                f.topk.len(),
                f.loads.len(),
                n_layers,
                m
            );
        }
        let mut batch = Vec::with_capacity(f.ids.len());
        for &id in &f.ids {
            match by_id.get(&id) {
                Some(r) => batch.push((*r).clone()),
                None => bail!(
                    "frame {}: request {id} missing from the arrival \
                     stream",
                    f.seq
                ),
            }
        }
        let out = router.route_batch(&batch);
        let rv = frame_vio(&f.loads, batch.len(), m, k, n_layers);
        let cv = frame_vio(&out.loads, batch.len(), m, k, n_layers);
        rec_vio.push(rv);
        cf_vio.push(cv);
        delta.push(cv - rv);

        let cf_asn = out.assignment.as_ref().expect("capture is on");
        for l in 0..n_layers {
            if f.topk[l].len() != batch.len() {
                bail!(
                    "frame {}: layer {} has {} token entries for {} \
                     tokens",
                    f.seq,
                    l,
                    f.topk[l].len(),
                    batch.len()
                );
            }
            for (t, rec_tok) in f.topk[l].iter().enumerate() {
                let cf_tok = &cf_asn[l][t];
                agree_den += rec_tok.len() as u64;
                agree_num += rec_tok
                    .iter()
                    .filter(|&&e| cf_tok.contains(&e))
                    .count() as u64;
            }
        }
        tokens += batch.len() as u64;

        // frozen batching: the batch still dispatches no earlier than it
        // did in the recording, and no earlier than its replica is free
        // under the counterfactual service times
        let service = cost
            .batch_us(&router.placement, &out.loads, m)
            .max(1.0) as u64;
        let free = &mut replica_free[f.replica as usize];
        let start = f.now_us.max(*free);
        let end = start + service;
        *free = end;
        for r in &batch {
            slo_cf.record(r.arrival_us, end, r.deadline_us);
        }
    }

    let mut slo_rec = SloTracker::new(slo_us);
    for c in &trace.completions {
        let deadline = by_id
            .get(&c.id)
            .map(|r| r.deadline_us)
            .unwrap_or(c.arrival_us + slo_us);
        slo_rec.record(c.arrival_us, c.completion_us, deadline);
    }

    Ok(PolicyDiff {
        policy: router.policy().name().to_string(),
        recorded_policy: meta.serve.policy.name().to_string(),
        scenario: Scenario::Replayed.name().to_string(),
        frames: trace.frames.len() as u64,
        tokens,
        avg_max_vio_recorded: if rec_vio.n > 0 { rec_vio.mean } else { 0.0 },
        avg_max_vio: if cf_vio.n > 0 { cf_vio.mean } else { 0.0 },
        sup_max_vio_recorded: if rec_vio.n > 0 { rec_vio.max } else { 0.0 },
        sup_max_vio: if cf_vio.n > 0 { cf_vio.max } else { 0.0 },
        vio_delta_mean: if delta.n > 0 { delta.mean } else { 0.0 },
        topk_agreement: if agree_den > 0 {
            agree_num as f64 / agree_den as f64
        } else {
            1.0
        },
        overflow: router.overflow_total,
        degraded: router.degraded_total,
        p50_ms_recorded: slo_rec.latency_us(0.50) / 1e3,
        p50_ms: slo_cf.latency_us(0.50) / 1e3,
        p95_ms_recorded: slo_rec.latency_us(0.95) / 1e3,
        p95_ms: slo_cf.latency_us(0.95) / 1e3,
        p99_ms_recorded: slo_rec.latency_us(0.99) / 1e3,
        p99_ms: slo_cf.latency_us(0.99) / 1e3,
        slo_violations_recorded: slo_rec.violations,
        slo_violations: slo_cf.violations,
    })
}

/// Counterfactual diff of the trace under every requested policy.
pub fn diff_policies(
    trace: &Trace,
    policies: &[Policy],
) -> Result<Vec<PolicyDiff>> {
    policies.iter().map(|&p| reroute(trace, p)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{
        ReplicaConfig, RouterConfig, SchedulerConfig, ServeConfig,
        TrafficConfig,
    };
    use crate::trace::format::TraceMeta;

    fn empty_trace() -> Trace {
        let cfg = ServeConfig::new(
            TrafficConfig {
                n_requests: 0,
                ..Default::default()
            },
            SchedulerConfig::default(),
            RouterConfig::default(),
            Policy::Online,
        );
        Trace {
            version: crate::trace::format::TRACE_VERSION,
            meta: TraceMeta::new(&cfg, &ReplicaConfig::default()),
            arrivals: Vec::new(),
            frames: Vec::new(),
            syncs: Vec::new(),
            completions: Vec::new(),
            telemetry: Vec::new(),
        }
    }

    #[test]
    fn zero_admission_trace_diffs_to_quiet_zeros_not_nan() {
        // the slo guard matters here: no frames, no completions — every
        // percentile and vio statistic must come back 0.0, never NaN
        let trace = empty_trace();
        for policy in Policy::all() {
            let d = reroute(&trace, policy).unwrap();
            assert_eq!(d.frames, 0);
            assert_eq!(d.tokens, 0);
            assert_eq!(d.avg_max_vio, 0.0, "{policy:?}");
            assert_eq!(d.avg_max_vio_recorded, 0.0);
            assert_eq!(d.sup_max_vio, 0.0);
            assert_eq!(d.vio_delta_mean, 0.0);
            assert_eq!(d.topk_agreement, 1.0);
            assert_eq!(d.p50_ms, 0.0);
            assert_eq!(d.p99_ms_recorded, 0.0);
            assert!(d.p99_ms.is_finite());
            assert_eq!(d.scenario, "replayed");
        }
    }

    #[test]
    fn replaying_an_empty_trace_is_clean() {
        let trace = empty_trace();
        let rep = replay(&trace);
        assert!(rep.mismatches.is_empty(), "{:?}", rep.mismatches);
        assert_eq!(rep.completions.len(), 0);
        assert_eq!(rep.report.offered, 0);
    }

    #[test]
    fn malformed_frames_error_instead_of_panicking() {
        use crate::trace::format::TraceFrame;
        let mut trace = empty_trace();
        trace.frames.push(TraceFrame {
            seq: 0,
            replica: 7, // outside the recorded 1-replica set
            now_us: 0,
            service_us: 1,
            ids: vec![],
            topk: vec![],
            loads: vec![],
        });
        let err = reroute(&trace, Policy::Greedy).unwrap_err();
        assert!(format!("{err}").contains("replica"), "{err}");

        trace.frames[0].replica = 0;
        let err = reroute(&trace, Policy::Greedy).unwrap_err();
        assert!(format!("{err}").contains("empty"), "{err}");

        trace.frames[0].ids = vec![0];
        let err = reroute(&trace, Policy::Greedy).unwrap_err();
        assert!(format!("{err}").contains("shape"), "{err}");

        // well-shaped frame, but the request is absent from arrivals
        let (m, l) = (16, 4);
        trace.frames[0].topk = vec![vec![vec![0u16]]; l];
        trace.frames[0].loads = vec![0.0; l * m];
        let err = reroute(&trace, Policy::Greedy).unwrap_err();
        assert!(format!("{err}").contains("missing"), "{err}");
    }

    #[test]
    fn diff_table_rows_align_with_headers() {
        let trace = empty_trace();
        let d = reroute(&trace, Policy::LossFree).unwrap();
        assert_eq!(d.table_row().len(), PolicyDiff::headers().len());
        let j = d.to_json();
        assert_eq!(j.path("policy").unwrap().as_str(), Some("lossfree"));
        assert_eq!(
            j.path("recorded_policy").unwrap().as_str(),
            Some("bip-online")
        );
        assert_eq!(j.path("topk_agreement").unwrap().as_f64(), Some(1.0));
    }
}
