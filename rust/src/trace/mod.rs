//! Routing-trace capture, deterministic replay, and counterfactual
//! policy re-evaluation.
//!
//! The paper's headline claim — balance on every expert in every layer
//! from the first step to the last — is a *trajectory* claim, and a
//! trajectory can only be audited if every routing decision is recorded
//! and replayable. This subsystem gives `serve/` that seam:
//!
//! * [`format`] — a compact versioned binary trace: header carrying the
//!   full serving configuration, the offered arrival stream (ids,
//!   tenants, timestamps, per-layer gate scores), one frame per routed
//!   micro-batch (replica tag, virtual-time stamps, enforced top-K,
//!   per-expert loads), replica merge-sync events, and the completion
//!   log; length-prefixed records, magic/version checking, JSON export
//!   for small traces;
//! * [`record`] — the [`TraceRecorder`] sink threaded through
//!   `run_scenario` / `run_replicated` behind a zero-cost `Option`, so
//!   any existing scenario (including replicated runs) can be frozen;
//! * [`replay`] — regression mode (re-drive the recorded stream through
//!   the identical pipeline and assert bit-identical completions) and
//!   counterfactual mode (re-route the recorded gate scores under a
//!   different policy, reporting MaxVio trajectory deltas, top-K
//!   agreement and SLO deltas).
//!
//! Driven by `bip-moe trace record|replay|diff|export` and measured by
//! `bench_trace` (record overhead, replay throughput).

pub mod format;
pub mod record;
pub mod replay;

pub use format::{Trace, TraceFrame, TraceMeta, TRACE_MAGIC, TRACE_VERSION};
pub use record::TraceRecorder;
pub use replay::{diff_policies, replay, reroute, PolicyDiff, Replay};
