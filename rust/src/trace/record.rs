//! The recording sink the serving loops thread through.
//!
//! A [`TraceRecorder`] is handed to `serve::run_scenario_with` /
//! `serve::run_replicated_with` as an `Option<&mut TraceRecorder>`:
//! `None` is the production path and costs nothing (the router does not
//! even allocate per-token assignment buffers); `Some` captures the
//! offered arrival stream in generation order, one [`TraceFrame`] per
//! routed micro-batch (tagged with the routing replica), the replica
//! merge-sync events, and the completion log.

use crate::serve::router::BatchOutcome;
use crate::serve::{Completion, ReplicaConfig, Request, ServeConfig, SyncEvent};

use super::format::{Trace, TraceFrame, TraceMeta};

pub struct TraceRecorder {
    trace: Trace,
    next_seq: u64,
}

impl TraceRecorder {
    pub fn new(cfg: &ServeConfig, rcfg: &ReplicaConfig) -> TraceRecorder {
        assert!(
            cfg.router.k <= u8::MAX as usize,
            "trace format v1 stores per-token top-K counts as u8 \
             (k = {} > 255)",
            cfg.router.k
        );
        TraceRecorder {
            trace: Trace {
                version: super::format::TRACE_VERSION,
                meta: TraceMeta::new(cfg, rcfg),
                arrivals: Vec::new(),
                frames: Vec::new(),
                syncs: Vec::new(),
                completions: Vec::new(),
                telemetry: Vec::new(),
            },
            next_seq: 0,
        }
    }

    /// Embed the process's current counter/gauge scrape
    /// (`telemetry::scrape_named`) into the trace header (v3+), so a
    /// later replay can diff recorded-vs-replayed metrics. Call once,
    /// after the serving run finishes and before saving.
    pub fn capture_telemetry(&mut self) {
        self.trace.telemetry = crate::telemetry::scrape_named();
    }

    /// Record one offered request (admitted *or* rejected — admission
    /// control is part of what a replay must reproduce).
    pub fn record_arrival(&mut self, req: &Request) {
        self.trace.arrivals.push(req.clone());
    }

    /// Record one routed micro-batch. The router must have been run
    /// with `capture_assignments` on so the outcome carries the
    /// per-token enforced top-K. The outcome's assignment and load
    /// buffers are *moved* into the frame (recording is their last
    /// use at both call sites), so nothing is deep-cloned per batch.
    pub fn record_frame(
        &mut self,
        replica: usize,
        now_us: u64,
        service_us: u64,
        batch: &[Request],
        outcome: &mut BatchOutcome,
    ) {
        let topk = outcome
            .assignment
            .take()
            .expect("recording requires ServingRouter::capture_assignments");
        self.trace.frames.push(TraceFrame {
            seq: self.next_seq,
            replica: replica as u32,
            now_us,
            service_us,
            ids: batch.iter().map(|r| r.id).collect(),
            topk,
            loads: std::mem::take(&mut outcome.loads),
        });
        self.next_seq += 1;
    }

    pub fn set_syncs(&mut self, syncs: &[SyncEvent]) {
        self.trace.syncs = syncs.to_vec();
    }

    pub fn set_completions(&mut self, completions: &[Completion]) {
        self.trace.completions = completions.to_vec();
    }

    pub fn frames_recorded(&self) -> u64 {
        self.next_seq
    }

    pub fn into_trace(self) -> Trace {
        self.trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{
        Policy, RouterConfig, Scenario, SchedulerConfig, ServingRouter,
        TrafficConfig, TrafficGenerator,
    };

    #[test]
    fn frames_are_sequenced_and_capture_the_enforced_topk() {
        let traffic = TrafficConfig {
            scenario: Scenario::Steady,
            n_requests: 32,
            seed: 5,
            ..Default::default()
        };
        let cfg = ServeConfig::new(
            traffic.clone(),
            SchedulerConfig::default(),
            RouterConfig::default(),
            Policy::Greedy,
        );
        let rcfg = ReplicaConfig::default();
        let mut rec = TraceRecorder::new(&cfg, &rcfg);
        let reqs: Vec<Request> =
            TrafficGenerator::new(traffic).collect();
        let mut router =
            ServingRouter::new(Policy::Greedy, cfg.router.clone());
        router.capture_assignments = true;
        for (i, chunk) in reqs.chunks(16).enumerate() {
            for r in chunk {
                rec.record_arrival(r);
            }
            let mut out = router.route_batch(chunk);
            rec.record_frame(0, i as u64 * 100, 50, chunk, &mut out);
            assert!(out.assignment.is_none(), "buffers move into the frame");
        }
        let trace = rec.into_trace();
        assert_eq!(trace.arrivals.len(), 32);
        assert_eq!(trace.frames.len(), 2);
        assert_eq!(trace.frames[0].seq, 0);
        assert_eq!(trace.frames[1].seq, 1);
        for f in &trace.frames {
            assert_eq!(f.ids.len(), 16);
            assert_eq!(f.topk.len(), 4, "one entry per layer");
            for layer in &f.topk {
                assert_eq!(layer.len(), 16, "one entry per token");
                for tok in layer {
                    assert!(tok.len() <= 4, "at most k experts");
                }
            }
            // frame loads must equal the replayed count of topk slots
            let routed: f32 = f.loads.iter().sum();
            let slots: usize =
                f.topk.iter().flatten().map(|t| t.len()).sum();
            assert_eq!(routed as usize, slots);
        }
    }
}
