//! Host-side routing strategies behind one trait.
//!
//! The production routing runs inside the AOT train step (L2/L1); these
//! host mirrors exist for (a) the solver benches and cluster-sim ablations
//! that sweep routing policies without touching PJRT, and (b) equivalence
//! tests against the in-graph implementations through the probe artifact.

use std::sync::Arc;

use crate::bip::approx::ApproxGate;
use crate::bip::dual::DualState;
use crate::bip::online::OnlineGate;
use crate::bip::{Instance, Routing};
use crate::obs::event::{self, EventKind};
use crate::perf::{AssignmentBuf, ScoreArena};
use crate::telemetry;
use crate::util::pool::Pool;
use crate::util::stats::{topk_indices, topk_into};

/// Snapshot of a strategy's *mergeable* balancing state, exchanged by
/// the replica-sharded serving engine (`serve::replica`). Every policy's
/// shareable core is tiny — an O(m) dual/bias vector plus, for the
/// online gates, the bounded per-expert order-statistic sketch — which
/// is what makes periodic cross-replica reconciliation cheap.
#[derive(Clone, Debug)]
pub enum BalanceState {
    /// stateless (greedy, aux-loss mirror) or not-yet-initialized
    None,
    /// Loss-Free additive bias b (Wang et al. 2024)
    Bias(Vec<f32>),
    /// Algorithm 1 dual vector q
    Dual(Vec<f32>),
    /// Algorithm 3: duals + per-expert top-heap contents
    Online { q: Vec<f32>, heaps: Vec<Vec<f32>> },
    /// Algorithm 4: duals + per-expert histogram bucket counts
    Approx { q: Vec<f32>, hists: Vec<Vec<u32>> },
}

impl BalanceState {
    /// The policy's primary dual/bias vector, if it has one — what the
    /// replica engine measures divergence over.
    pub fn primary(&self) -> Option<&[f32]> {
        match self {
            BalanceState::None => None,
            BalanceState::Bias(b) => Some(b),
            BalanceState::Dual(q) => Some(q),
            BalanceState::Online { q, .. } => Some(q),
            BalanceState::Approx { q, .. } => Some(q),
        }
    }
}

/// Element-wise mean of same-length vectors (replica order is fixed, so
/// the f32 summation order — hence the result — is deterministic).
fn mean_vec(vecs: &[&[f32]]) -> Vec<f32> {
    let r = vecs.len() as f32;
    // LINT-ALLOW(panic): both callers check `vecs` is non-empty first
    let mut out = vec![0.0f32; vecs[0].len()];
    for v in vecs {
        for (o, x) in out.iter_mut().zip(v.iter()) {
            *o += *x;
        }
    }
    for o in out.iter_mut() {
        *o /= r;
    }
    out
}

/// A stateful routing policy over a stream of score batches.
///
/// `Send` is a supertrait: the serving engine moves per-replica routers
/// across its worker threads.
pub trait RoutingStrategy: Send {
    fn name(&self) -> String;
    /// Route one batch, updating internal state (bias vectors etc.).
    /// This is the allocating compatibility path (per-token `Vec`s);
    /// the serving hot loop drives
    /// [`RoutingStrategy::route_batch_into`] instead.
    fn route_batch(&mut self, inst: &Instance) -> Routing;
    /// Allocation-free routing: identical decisions to
    /// [`RoutingStrategy::route_batch`], written into the reusable
    /// `out` buffer using `arena` scratch. Every production strategy
    /// overrides this with a zero-allocation implementation; the
    /// default falls back to the allocating path (correct, not fast).
    fn route_batch_into(
        &mut self,
        inst: &Instance,
        arena: &mut ScoreArena,
        out: &mut AssignmentBuf,
    ) {
        let _ = arena;
        let routing = self.route_batch(inst);
        out.reset(inst.n, inst.k);
        for (i, experts) in routing.assignment.iter().enumerate() {
            out.put(i, experts);
        }
    }
    /// Bytes of persistent balancing state (dual vectors, heaps,
    /// histograms) — the §5.2 footprint the serving report tracks.
    fn state_bytes(&self) -> usize {
        0
    }
    /// Snapshot the mergeable balance state (None for stateless
    /// policies). Cheap: O(m) vectors plus bounded sketches.
    fn export_state(&self) -> BalanceState {
        BalanceState::None
    }
    /// Reconcile with the exported states of *all* replicas (self
    /// included). Every replica receives the identical slice, and the
    /// merge is a deterministic function of it, so replicas leave the
    /// sync with identical balance state. States of a foreign variant
    /// or shape are ignored; a no-op by default.
    fn merge_state(&mut self, _states: &[BalanceState]) {}
    /// Warm-start from a snapshot *before* routing anything: adopt the
    /// state wholesale (unlike [`RoutingStrategy::merge_state`], which
    /// blends). The seam `forecast::control::seed_states` and a prior
    /// run's `export_state` both feed. States of a foreign variant or
    /// shape are ignored; a no-op by default (stateless policies).
    fn seed_state(&mut self, _state: &BalanceState) {}
    /// Whether this strategy's solve consumes the (m, n) column-major
    /// score transpose, so the router should build it once on the fill
    /// side (`ScoreArena::fill_transpose`) while the batch scores are
    /// still cache-hot, instead of the solver re-reading them. Only
    /// the BIP dual solvers want it; stateless/greedy policies read
    /// the row-major scores directly.
    fn wants_transpose(&self) -> bool {
        false
    }
}

/// Plain top-k on raw scores.
pub struct Greedy;

impl RoutingStrategy for Greedy {
    fn name(&self) -> String {
        "greedy".into()
    }

    // COLD: allocating compat seam — serving drives route_batch_into;
    // the static hot-path lint stops here
    fn route_batch(&mut self, inst: &Instance) -> Routing {
        crate::bip::greedy_topk(inst)
    }

    fn route_batch_into(
        &mut self,
        inst: &Instance,
        arena: &mut ScoreArena,
        out: &mut AssignmentBuf,
    ) {
        arena.prepare_gate(inst.m);
        out.reset(inst.n, inst.k);
        for i in 0..inst.n {
            let len = topk_into(
                inst.row(i),
                inst.k,
                &mut arena.topk_idx,
                out.row_mut(i),
            );
            out.set_len(i, len);
        }
    }
}

/// Loss-Controlled baseline. The auxiliary loss influences routing only
/// through training the router weights, which a host-side mirror cannot
/// do — so its *routing decision* is greedy top-k (as in the real method)
/// and the aux-loss value is tracked for reporting.
pub struct AuxLoss {
    pub alpha: f64,
    pub last_aux_loss: f64,
}

impl AuxLoss {
    pub fn new(alpha: f64) -> Self {
        AuxLoss { alpha, last_aux_loss: 0.0 }
    }
}

impl RoutingStrategy for AuxLoss {
    fn name(&self) -> String {
        format!("aux(alpha={})", self.alpha)
    }

    // COLD: allocating compat seam — serving drives route_batch_into;
    // the static hot-path lint stops here
    fn route_batch(&mut self, inst: &Instance) -> Routing {
        let routing = crate::bip::greedy_topk(inst);
        let loads = routing.loads(inst.m);
        let scale = inst.m as f64 / (inst.k * inst.n) as f64;
        let mut aux = 0.0;
        for j in 0..inst.m {
            let f_j = loads[j] as f64 * scale;
            let p_j: f64 = (0..inst.n)
                .map(|i| inst.score(i, j) as f64)
                .sum::<f64>()
                / inst.n as f64;
            aux += f_j * p_j;
        }
        self.last_aux_loss = self.alpha * aux;
        routing
    }
}

/// Loss-Free baseline (Wang et al. 2024): additive bias b, per-batch sign
/// update b_j += u * sign(mean - load_j).
pub struct LossFree {
    pub u: f32,
    pub bias: Vec<f32>,
}

impl LossFree {
    pub fn new(m: usize, u: f32) -> Self {
        LossFree { u, bias: vec![0.0; m] }
    }

    /// The per-batch sign update shared by both routing paths:
    /// b_j += u * sign(mean - load_j) with sign(0) = 0, per Wang et
    /// al. — f32::signum(0.0) is 1.0, which would *raise* the bias of
    /// an expert sitting exactly at the mean load.
    fn bias_step(&mut self, loads: &[u32], n: usize, k: usize) {
        let mean = n as f32 * k as f32 / self.bias.len() as f32;
        for (b, &load) in self.bias.iter_mut().zip(loads) {
            let e = mean - load as f32;
            if e != 0.0 {
                *b += self.u * e.signum();
            }
        }
    }
}

impl RoutingStrategy for LossFree {
    fn name(&self) -> String {
        format!("lossfree(u={})", self.u)
    }

    // COLD: allocating compat seam — serving drives route_batch_into;
    // the static hot-path lint stops here
    fn route_batch(&mut self, inst: &Instance) -> Routing {
        let mut biased = vec![0.0f32; inst.m];
        let assignment: Vec<Vec<u32>> = (0..inst.n)
            .map(|i| {
                let row = inst.row(i);
                for j in 0..inst.m {
                    biased[j] = row[j] + self.bias[j];
                }
                topk_indices(&biased, inst.k)
                    .into_iter()
                    .map(|e| e as u32)
                    .collect()
            })
            .collect();
        let routing = Routing { assignment };
        let loads = routing.loads(inst.m);
        self.bias_step(&loads, inst.n, inst.k);
        routing
    }

    fn route_batch_into(
        &mut self,
        inst: &Instance,
        arena: &mut ScoreArena,
        out: &mut AssignmentBuf,
    ) {
        arena.prepare_gate(inst.m);
        out.reset(inst.n, inst.k);
        arena.loads_scratch.iter_mut().for_each(|x| *x = 0);
        for i in 0..inst.n {
            let row = inst.row(i);
            for j in 0..inst.m {
                arena.biased[j] = row[j] + self.bias[j];
            }
            let len = topk_into(
                &arena.biased,
                inst.k,
                &mut arena.topk_idx,
                out.row_mut(i),
            );
            out.set_len(i, len);
            for &e in out.token(i) {
                arena.loads_scratch[e as usize] += 1;
            }
        }
        // the same sign update as the allocating path, from the same
        // integer load counts
        self.bias_step(&arena.loads_scratch, inst.n, inst.k);
    }

    fn state_bytes(&self) -> usize {
        self.bias.len() * 4
    }

    fn export_state(&self) -> BalanceState {
        BalanceState::Bias(self.bias.clone())
    }

    /// Replica merge: element-wise mean of every replica's bias — each
    /// replica saw a shard of the traffic, and the averaged bias is the
    /// bias a single router would have learned from the blended stream
    /// (the sign updates are additive and commutative).
    fn merge_state(&mut self, states: &[BalanceState]) {
        let biases: Vec<&[f32]> = states
            .iter()
            .filter_map(|s| match s {
                BalanceState::Bias(b) if b.len() == self.bias.len() => {
                    Some(b.as_slice())
                }
                _ => None,
            })
            .collect();
        if !biases.is_empty() {
            self.bias = mean_vec(&biases);
        }
    }

    // COLD: sync/warm-start seam (replica merge, forecast seeding) —
    // outside the steady-state zero-alloc contract
    fn seed_state(&mut self, state: &BalanceState) {
        match state {
            BalanceState::Bias(b) if b.len() == self.bias.len() => {
                self.bias = b.clone();
            }
            // a forecast dual seed maps onto the bias with flipped
            // sign: Loss-Free *adds* its bias where Alg. 1 *subtracts*
            // its duals
            BalanceState::Dual(q) if q.len() == self.bias.len() => {
                self.bias = q.iter().map(|&x| -x).collect();
            }
            _ => {}
        }
    }
}

/// BIP-Based Balancing (Algorithm 1): warm-started dual state + T
/// iterations per batch. With a shared thread pool attached, the
/// per-batch dual update runs the chunked p/q phases
/// ([`DualState::update_parallel`]) — bit-identical to the serial path.
/// With `tol > 0` the per-batch solve is the convergence-adaptive
/// [`DualState::update_adaptive`] capped at `t_iters` iterations.
pub struct Bip {
    pub t_iters: usize,
    /// adaptive-solver tolerance (`--solver-tol`); 0 = fixed-T solve
    pub tol: f32,
    /// iterations the most recent batch actually ran (= `t_iters` on
    /// the fixed path; the bench reads this for the savings record)
    pub last_iters: usize,
    state: Option<DualState>,
    pool: Option<Arc<Pool>>,
}

impl Bip {
    pub fn new(t_iters: usize) -> Self {
        Bip {
            t_iters,
            tol: 0.0,
            last_iters: 0,
            state: None,
            pool: None,
        }
    }

    pub fn with_pool(t_iters: usize, pool: Arc<Pool>) -> Self {
        Bip { pool: Some(pool), ..Bip::new(t_iters) }
    }

    /// Enable the convergence-adaptive solver (`tol > 0`); `tol = 0`
    /// restores the fixed-T path bit-identically.
    pub fn set_solver_tol(&mut self, tol: f32) {
        assert!(tol.is_finite() && tol >= 0.0, "solver tol {tol}");
        self.tol = tol;
    }

    pub fn q(&self) -> Option<&[f32]> {
        self.state.as_ref().map(|s| s.q.as_slice())
    }

    /// One per-batch dual solve against the given arena, honoring the
    /// pool and tolerance knobs; records the iterations run. The
    /// compat path routes through here too (with the state's fallback
    /// arena), so the dispatch exists once.
    fn solve_batch(&mut self, inst: &Instance, arena: &mut ScoreArena) {
        let t = self.t_iters;
        let tol = self.tol;
        let state = self
            .state
            .get_or_insert_with(|| DualState::new(inst.m));
        self.last_iters =
            dispatch_solve(state, self.pool.as_deref(), inst, t, tol, arena);
    }
}

/// The one (pool, tol) -> solver-mode dispatch both `Bip` entry points
/// share: fixed-T or convergence-adaptive, serial or pool-chunked.
/// Returns the iterations run.
fn dispatch_solve(
    state: &mut DualState,
    pool: Option<&Pool>,
    inst: &Instance,
    t: usize,
    tol: f32,
    arena: &mut ScoreArena,
) -> usize {
    // the span and counters below are preallocated telemetry atomics;
    // the solve stays allocation-free (integration_perf pins it)
    let _span = telemetry::Span::enter(telemetry::SpanKind::SolverSolve);
    let adaptive = tol > 0.0;
    let (mode, iters) = match (pool, adaptive) {
        (Some(pool), true) => (
            3u8,
            state.update_adaptive_parallel_in(inst, t, tol, pool, arena),
        ),
        (Some(pool), false) => {
            state.update_parallel_in(inst, t, pool, arena);
            (1u8, t)
        }
        (None, true) => {
            (2u8, state.update_adaptive_in(inst, t, tol, arena))
        }
        (None, false) => {
            state.update_in(inst, t, arena);
            (0u8, t)
        }
    };
    event::record_ctx_event(
        EventKind::SolverExit,
        event::solver_exit_payload(mode, adaptive && iters == t, iters),
    );
    telemetry::counter_add(telemetry::Counter::SolverSolves, 1);
    telemetry::counter_add(
        telemetry::Counter::SolverIterations,
        iters as u64,
    );
    telemetry::gauge_set(
        telemetry::Gauge::SolverLastIters,
        iters as f64,
    );
    telemetry::hist_observe(
        telemetry::Hist::SolverItersPerSolve,
        iters as f64,
    );
    iters
}

impl RoutingStrategy for Bip {
    fn name(&self) -> String {
        if self.tol > 0.0 {
            format!("bip(T<={},tol={})", self.t_iters, self.tol)
        } else {
            format!("bip(T={})", self.t_iters)
        }
    }

    // COLD: allocating compat seam — serving drives route_batch_into;
    // the static hot-path lint stops here
    fn route_batch(&mut self, inst: &Instance) -> Routing {
        let t = self.t_iters;
        let tol = self.tol;
        let pool = self.pool.clone();
        let state = self
            .state
            .get_or_insert_with(|| DualState::new(inst.m));
        self.last_iters = state.with_fallback_arena(|s, a| {
            dispatch_solve(s, pool.as_deref(), inst, t, tol, a)
        });
        state.route(inst)
    }

    fn route_batch_into(
        &mut self,
        inst: &Instance,
        arena: &mut ScoreArena,
        out: &mut AssignmentBuf,
    ) {
        self.solve_batch(inst, arena);
        self.state
            .as_ref()
            // LINT-ALLOW(panic): solve_batch always populates state
            .expect("solved above")
            .route_into(inst, arena, out);
    }

    fn state_bytes(&self) -> usize {
        // q + p, plus whatever the state's *fallback* arena retains —
        // the full O(n·m) footprint when Algorithm 1 runs standalone.
        // On the serving path the shared arena is counted once at the
        // router level instead (`ServingRouter::state_bytes`).
        self.state.as_ref().map(|s| s.state_bytes()).unwrap_or(0)
    }

    fn export_state(&self) -> BalanceState {
        match &self.state {
            Some(s) => BalanceState::Dual(s.q.clone()),
            None => BalanceState::None,
        }
    }

    /// Replica merge: element-wise mean of the dual vectors q. The dual
    /// update is a fixed-point iteration warm-started from q, so every
    /// replica restarts from the blended duals (a replica that has not
    /// routed yet adopts them wholesale).
    fn merge_state(&mut self, states: &[BalanceState]) {
        let qs: Vec<&[f32]> = states
            .iter()
            .filter_map(|s| match s {
                BalanceState::Dual(q) => Some(q.as_slice()),
                _ => None,
            })
            .collect();
        if qs.is_empty() {
            return;
        }
        // LINT-ALLOW(panic): the is_empty early-return above proves
        // qs[0] exists
        let m = qs[0].len();
        if qs.iter().any(|q| q.len() != m) {
            return;
        }
        let merged = mean_vec(&qs);
        let state =
            self.state.get_or_insert_with(|| DualState::new(m));
        if state.q.len() == m {
            state.q = merged;
        }
    }

    // COLD: sync/warm-start seam (replica merge, forecast seeding) —
    // outside the steady-state zero-alloc contract
    fn seed_state(&mut self, state: &BalanceState) {
        if let BalanceState::Dual(q) = state {
            match &mut self.state {
                Some(s) if s.q.len() == q.len() => s.q = q.clone(),
                Some(_) => {}
                None => {
                    let mut s = DualState::new(q.len());
                    s.q = q.clone();
                    self.state = Some(s);
                }
            }
        }
    }

    /// The dual solve's q-phase walks expert columns of `scores_t`, so
    /// the router should transpose fill-side while the scores are hot.
    fn wants_transpose(&self) -> bool {
        true
    }
}

/// Algorithm 1 warm-started from a forecast-derived dual seed
/// (`forecast::control::dual_seed`): a thin wrapper over [`Bip`] that
/// installs its seed lazily before the first batch, so the *first*
/// micro-batch already routes against the predicted hot set instead of
/// an all-zero dual. Everything else — the per-batch dual update, the
/// replica merge, the state footprint — IS [`Bip`]; with an empty (or
/// misshapen) seed the wrapper is bit-identical to cold start.
pub struct PredictiveBip {
    inner: Bip,
    /// pending constructor seed, consumed at the first route
    seed: Vec<f32>,
}

impl PredictiveBip {
    pub fn new(t_iters: usize, seed: Vec<f32>) -> Self {
        PredictiveBip { inner: Bip::new(t_iters), seed }
    }

    pub fn with_pool(
        t_iters: usize,
        seed: Vec<f32>,
        pool: Arc<Pool>,
    ) -> Self {
        PredictiveBip { inner: Bip::with_pool(t_iters, pool), seed }
    }

    /// Forwarded [`Bip::set_solver_tol`].
    pub fn set_solver_tol(&mut self, tol: f32) {
        self.inner.set_solver_tol(tol);
    }

    pub fn q(&self) -> Option<&[f32]> {
        self.inner.q()
    }

    /// Install the pending constructor seed if it matches this gate's
    /// width (a misshapen forecast degrades to cold start, never a
    /// panic) and nothing has routed or seeded the duals yet.
    fn consume_seed(&mut self, m: usize) {
        if !self.seed.is_empty() {
            let seed = std::mem::take(&mut self.seed);
            if seed.len() == m && self.inner.q().is_none() {
                self.inner.seed_state(&BalanceState::Dual(seed));
            }
        }
    }
}

impl RoutingStrategy for PredictiveBip {
    fn name(&self) -> String {
        format!("bip-predictive(T={})", self.inner.t_iters)
    }

    // COLD: allocating compat seam — serving drives route_batch_into;
    // the static hot-path lint stops here
    fn route_batch(&mut self, inst: &Instance) -> Routing {
        self.consume_seed(inst.m);
        self.inner.route_batch(inst)
    }

    fn route_batch_into(
        &mut self,
        inst: &Instance,
        arena: &mut ScoreArena,
        out: &mut AssignmentBuf,
    ) {
        self.consume_seed(inst.m);
        self.inner.route_batch_into(inst, arena, out);
    }

    fn state_bytes(&self) -> usize {
        self.inner.state_bytes()
    }

    fn export_state(&self) -> BalanceState {
        self.inner.export_state()
    }

    fn merge_state(&mut self, states: &[BalanceState]) {
        self.inner.merge_state(states);
    }

    // COLD: sync/warm-start seam (replica merge, forecast seeding) —
    // outside the steady-state zero-alloc contract
    fn seed_state(&mut self, state: &BalanceState) {
        // an explicit seed supersedes whatever the constructor carried
        self.seed.clear();
        self.inner.seed_state(state);
    }

    fn wants_transpose(&self) -> bool {
        self.inner.wants_transpose()
    }
}

/// Algorithm 3 (`bip::online::OnlineGate`) as a batch strategy: tokens
/// stream through the gate in row order and the duals + per-expert
/// top-heaps persist across batches. This is the serving router's exact
/// online policy; `cap` is the *stream-level* expert capacity
/// (total_tokens * k / m), per §5 semantics.
pub struct OnlineBip {
    pub gate: OnlineGate,
}

impl OnlineBip {
    pub fn new(m: usize, k: usize, cap: usize, t_iters: usize) -> Self {
        OnlineBip { gate: OnlineGate::new(m, k, cap, t_iters) }
    }
}

impl RoutingStrategy for OnlineBip {
    fn name(&self) -> String {
        format!("bip-online(T={})", self.gate.t_iters)
    }

    // COLD: allocating compat seam — serving drives route_batch_into;
    // the static hot-path lint stops here
    fn route_batch(&mut self, inst: &Instance) -> Routing {
        let assignment = (0..inst.n)
            .map(|i| self.gate.route_token(inst.row(i)))
            .collect();
        Routing { assignment }
    }

    fn route_batch_into(
        &mut self,
        inst: &Instance,
        arena: &mut ScoreArena,
        out: &mut AssignmentBuf,
    ) {
        arena.prepare_gate(self.gate.m);
        out.reset(inst.n, inst.k);
        for i in 0..inst.n {
            let len = self.gate.route_token_into(
                inst.row(i),
                &mut arena.topk_idx,
                out.row_mut(i),
            );
            out.set_len(i, len);
        }
    }

    fn state_bytes(&self) -> usize {
        self.gate.state_bytes()
    }

    fn export_state(&self) -> BalanceState {
        BalanceState::Online {
            q: self.gate.q.clone(),
            heaps: self.gate.heap_values(),
        }
    }

    /// Replica merge: mean the duals, and merge the per-expert
    /// top-heaps as a *scaled* union — concatenate every replica's
    /// retained values, sort descending, keep every R-th. A plain
    /// union would re-contribute the post-sync shared content R times
    /// at every sync (replicas leave a sync with identical heaps),
    /// letting duplicated historical maxima crowd out fresh values and
    /// inflate the (cap+1)-th-largest statistic that sets q. Thinning
    /// by R is idempotent when replicas are identical, keeps the
    /// sketch at single-shard scale (matching the per-replica cap),
    /// and the bounded rebuild keeps it from ever growing.
    fn merge_state(&mut self, states: &[BalanceState]) {
        let m = self.gate.m;
        let mut qs: Vec<&[f32]> = Vec::new();
        let mut unions: Vec<Vec<f32>> = vec![Vec::new(); m];
        for s in states {
            if let BalanceState::Online { q, heaps } = s {
                if q.len() != m || heaps.len() != m {
                    continue;
                }
                qs.push(q);
                for (j, h) in heaps.iter().enumerate() {
                    unions[j].extend_from_slice(h);
                }
            }
        }
        if qs.is_empty() {
            return;
        }
        let r = qs.len();
        for u in unions.iter_mut() {
            // LINT-ALLOW(panic): heap values are finite gate scores
            // (never NaN), so partial_cmp always succeeds
            u.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let thinned: Vec<f32> =
                u.iter().copied().step_by(r).collect();
            *u = thinned;
        }
        self.gate.q = mean_vec(&qs);
        self.gate.rebuild_heaps(&unions);
    }

    /// Adopt a snapshot wholesale: duals, plus the per-expert top-heaps
    /// rebuilt through the bounded push (seeding cannot over-grow the
    /// sketch). A bare [`BalanceState::Dual`] seed (forecast-derived)
    /// warm-starts the duals alone.
    // COLD: sync/warm-start seam (replica merge, forecast seeding) —
    // outside the steady-state zero-alloc contract
    fn seed_state(&mut self, state: &BalanceState) {
        match state {
            BalanceState::Online { q, heaps }
                if q.len() == self.gate.m
                    && heaps.len() == self.gate.m =>
            {
                self.gate.q = q.clone();
                self.gate.rebuild_heaps(heaps);
            }
            BalanceState::Dual(q) if q.len() == self.gate.m => {
                self.gate.q = q.clone();
            }
            _ => {}
        }
    }
}

/// Algorithm 4 (`bip::approx::ApproxGate`) as a batch strategy: constant
/// O(m·b) state regardless of how many batches have streamed through.
pub struct ApproxBip {
    pub gate: ApproxGate,
    pub buckets: usize,
}

impl ApproxBip {
    pub fn new(
        m: usize,
        k: usize,
        cap: usize,
        t_iters: usize,
        buckets: usize,
    ) -> Self {
        ApproxBip {
            gate: ApproxGate::new(m, k, cap, t_iters, buckets),
            buckets,
        }
    }
}

impl RoutingStrategy for ApproxBip {
    fn name(&self) -> String {
        format!("bip-approx(T={},b={})", self.gate.t_iters, self.buckets)
    }

    // COLD: allocating compat seam — serving drives route_batch_into;
    // the static hot-path lint stops here
    fn route_batch(&mut self, inst: &Instance) -> Routing {
        let assignment = (0..inst.n)
            .map(|i| self.gate.route_token(inst.row(i)))
            .collect();
        Routing { assignment }
    }

    fn route_batch_into(
        &mut self,
        inst: &Instance,
        arena: &mut ScoreArena,
        out: &mut AssignmentBuf,
    ) {
        arena.prepare_gate(self.gate.m);
        out.reset(inst.n, inst.k);
        for i in 0..inst.n {
            let len = self.gate.route_token_into(
                inst.row(i),
                &mut arena.topk_idx,
                out.row_mut(i),
            );
            out.set_len(i, len);
        }
    }

    fn state_bytes(&self) -> usize {
        self.gate.state_bytes()
    }

    fn export_state(&self) -> BalanceState {
        BalanceState::Approx {
            q: self.gate.q.clone(),
            hists: self.gate.hist_counts(),
        }
    }

    /// Replica merge: mean the duals, and merge the histograms as a
    /// *scaled* union — element-wise rounded mean of the bucket counts.
    /// A plain count union would multiply the totals by R at every sync
    /// (each replica re-contributing the previous union), blowing up
    /// the rank scale; the mean keeps the sketch at single-stream scale
    /// while still blending every replica's observations.
    fn merge_state(&mut self, states: &[BalanceState]) {
        let m = self.gate.m;
        let b = self.buckets;
        let mut qs: Vec<&[f32]> = Vec::new();
        let mut sums: Vec<Vec<u64>> = vec![vec![0u64; b]; m];
        for s in states {
            if let BalanceState::Approx { q, hists } = s {
                if q.len() != m
                    || hists.len() != m
                    || hists.iter().any(|h| h.len() != b)
                {
                    continue;
                }
                qs.push(q);
                for (j, h) in hists.iter().enumerate() {
                    for (acc, &c) in sums[j].iter_mut().zip(h) {
                        *acc += c as u64;
                    }
                }
            }
        }
        if qs.is_empty() {
            return;
        }
        let r = qs.len() as u64;
        let merged: Vec<Vec<u32>> = sums
            .into_iter()
            .map(|row| {
                row.into_iter()
                    .map(|s| ((s + r / 2) / r) as u32)
                    .collect()
            })
            .collect();
        self.gate.q = mean_vec(&qs);
        self.gate.set_hist_counts(&merged);
    }

    /// Adopt a snapshot wholesale: duals + histogram counts. A bare
    /// [`BalanceState::Dual`] seed warm-starts the duals alone.
    // COLD: sync/warm-start seam (replica merge, forecast seeding) —
    // outside the steady-state zero-alloc contract
    fn seed_state(&mut self, state: &BalanceState) {
        match state {
            BalanceState::Approx { q, hists }
                if q.len() == self.gate.m
                    && hists.len() == self.gate.m
                    && hists.iter().all(|h| h.len() == self.buckets) =>
            {
                self.gate.q = q.clone();
                self.gate.set_hist_counts(hists);
            }
            BalanceState::Dual(q) if q.len() == self.gate.m => {
                self.gate.q = q.clone();
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn batches(seed: u64, count: usize) -> Vec<Instance> {
        let mut rng = Pcg64::new(seed);
        (0..count)
            .map(|_| Instance::synthetic(256, 16, 4, 2.0, 3.0, &mut rng))
            .collect()
    }

    fn avg_vio(strategy: &mut dyn RoutingStrategy, insts: &[Instance]) -> f64 {
        let mut sum = 0.0;
        for inst in insts {
            sum += strategy.route_batch(inst).max_violation(inst);
        }
        sum / insts.len() as f64
    }

    #[test]
    fn strategy_ordering_matches_paper_shape() {
        // on a skewed score stream: bip << lossfree < greedy
        let insts = batches(1, 20);
        let vio_greedy = avg_vio(&mut Greedy, &insts);
        let vio_lf = avg_vio(&mut LossFree::new(16, 1e-3), &insts);
        let vio_bip = avg_vio(&mut Bip::new(4), &insts);
        assert!(vio_bip < 0.35, "bip {vio_bip}");
        assert!(vio_bip < vio_lf, "bip {vio_bip} lf {vio_lf}");
        assert!(vio_lf <= vio_greedy + 0.05,
                "lf {vio_lf} greedy {vio_greedy}");
    }

    #[test]
    fn lossfree_bias_accumulates_toward_balance() {
        // with a large-enough u and many identical batches, loss-free does
        // converge — the paper's point is it needs MANY batches
        let insts = batches(2, 200);
        let mut lf = LossFree::new(16, 1e-2);
        let first = lf.route_batch(&insts[0]).max_violation(&insts[0]);
        for inst in &insts {
            lf.route_batch(inst);
        }
        let last = lf
            .route_batch(insts.last().unwrap())
            .max_violation(insts.last().unwrap());
        assert!(last < first, "first {first} last {last}");
    }

    #[test]
    fn aux_loss_mirrors_track_loss_value() {
        let insts = batches(3, 3);
        let mut aux = AuxLoss::new(0.1);
        aux.route_batch(&insts[0]);
        assert!(aux.last_aux_loss > 0.0);
        // alpha scales it linearly
        let mut aux2 = AuxLoss::new(0.2);
        aux2.route_batch(&insts[0]);
        assert!((aux2.last_aux_loss / aux.last_aux_loss - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bip_warm_start_persists_across_batches() {
        let insts = batches(4, 5);
        let mut bip = Bip::new(2);
        bip.route_batch(&insts[0]);
        let q1 = bip.q().unwrap().to_vec();
        for inst in &insts[1..] {
            bip.route_batch(inst);
        }
        let q5 = bip.q().unwrap().to_vec();
        assert_ne!(q1, q5);
        assert!(q5.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn names_are_descriptive() {
        assert_eq!(Greedy.name(), "greedy");
        assert!(Bip::new(8).name().contains("T=8"));
        assert!(LossFree::new(4, 1e-3).name().contains("u=0.001"));
        assert!(OnlineBip::new(8, 2, 64, 4).name().contains("T=4"));
        assert!(ApproxBip::new(8, 2, 64, 4, 32).name().contains("b=32"));
    }

    #[test]
    fn gate_wrappers_match_direct_gate_streams() {
        // routing a batch through the wrapper must equal streaming the
        // rows through a bare gate: same tokens, same order, same duals
        let insts = batches(7, 3);
        let (m, k) = (16usize, 4usize);
        let cap = insts.iter().map(|i| i.n).sum::<usize>() * k / m;
        let mut wrapper = OnlineBip::new(m, k, cap, 3);
        let mut bare = crate::bip::online::OnlineGate::new(m, k, cap, 3);
        for inst in &insts {
            let routed = wrapper.route_batch(inst);
            for i in 0..inst.n {
                assert_eq!(routed.assignment[i], bare.route_token(inst.row(i)));
            }
        }
    }

    #[test]
    fn state_bytes_grow_only_where_expected() {
        let insts = batches(8, 4);
        assert_eq!(Greedy.state_bytes(), 0);

        let mut online = OnlineBip::new(16, 4, 1024, 2);
        let mut approx = ApproxBip::new(16, 4, 1024, 2, 64);
        assert_eq!(online.state_bytes(), 16 * 4); // just q before any batch
        let approx_initial = approx.state_bytes();
        for inst in &insts {
            online.route_batch(inst);
            approx.route_batch(inst);
        }
        assert!(online.state_bytes() > 16 * 4);
        // Algorithm 4: histogram state is constant in the stream length
        assert_eq!(approx.state_bytes(), approx_initial);

        let mut bip = Bip::new(2);
        assert_eq!(bip.state_bytes(), 0);
        bip.route_batch(&insts[0]);
        // the full standalone Algorithm 1 footprint: q + p plus the
        // fallback arena's O(n·m) transpose + order-key scratch
        let (n, m) = (insts[0].n, insts[0].m);
        let expect = (m + n) * 4 + 2 * (n * m) * 4;
        assert_eq!(bip.state_bytes(), expect);
        // and it dwarfs Algorithm 4's constant-space sketch, which is
        // the §5.2 comparison the serving report draws
        assert!(bip.state_bytes() > approx.state_bytes());
    }

    #[test]
    fn route_batch_into_matches_route_batch_for_every_strategy() {
        use crate::perf::{AssignmentBuf, ScoreArena};
        // the zero-allocation path must take identical decisions AND
        // leave identical balancer state as the allocating path, batch
        // after warm-started batch
        let insts = batches(41, 4);
        let (m, k, cap) = (16usize, 4usize, 1024usize);
        let make = || -> Vec<Box<dyn RoutingStrategy>> {
            vec![
                Box::new(Greedy),
                Box::new(LossFree::new(m, 1e-2)),
                Box::new(Bip::new(3)),
                Box::new(PredictiveBip::new(3, vec![0.1; m])),
                Box::new(OnlineBip::new(m, k, cap, 3)),
                Box::new(ApproxBip::new(m, k, cap, 3, 64)),
            ]
        };
        let mut compat = make();
        let mut fast = make();
        let mut arena = ScoreArena::new();
        let mut buf = AssignmentBuf::new();
        for inst in &insts {
            for (a, b) in compat.iter_mut().zip(fast.iter_mut()) {
                let want = a.route_batch(inst);
                b.route_batch_into(inst, &mut arena, &mut buf);
                assert_eq!(
                    buf.to_routing().assignment,
                    want.assignment,
                    "{} diverged",
                    a.name()
                );
                match (a.export_state(), b.export_state()) {
                    (BalanceState::None, BalanceState::None) => {}
                    (sa, sb) => {
                        assert_eq!(sa.primary(), sb.primary(),
                                   "{} state diverged", a.name());
                    }
                }
            }
        }
    }

    #[test]
    fn adaptive_bip_strategy_reports_iteration_savings() {
        let insts = batches(42, 6);
        let mut adaptive = Bip::new(16);
        adaptive.set_solver_tol(0.05);
        assert!(adaptive.name().contains("tol=0.05"), "{}", adaptive.name());
        assert!(adaptive.name().contains("T<=16"), "{}", adaptive.name());
        let mut total = 0usize;
        for inst in &insts {
            adaptive.route_batch(inst);
            assert!(adaptive.last_iters >= 1);
            assert!(adaptive.last_iters <= 16);
            total += adaptive.last_iters;
        }
        assert!(
            total < 6 * 16,
            "adaptive never early-exited ({total} iters)"
        );
        // fixed-T keeps the plain name and runs every iteration
        let mut fixed = Bip::new(16);
        fixed.route_batch(&insts[0]);
        assert_eq!(fixed.last_iters, 16);
        assert!(fixed.name().contains("T=16"));
    }

    #[test]
    fn lossfree_zero_error_takes_zero_step() {
        // a perfectly balanced batch: token i prefers expert i, k=1,
        // so every load equals the mean load of 1 — no bias may move
        let m = 4;
        let mut scores = vec![0.0f32; m * m];
        for i in 0..m {
            scores[i * m + i] = 1.0;
        }
        let inst = Instance { n: m, m, k: 1, cap: m, scores };
        let mut lf = LossFree::new(m, 0.1);
        lf.route_batch(&inst);
        assert_eq!(
            lf.bias,
            vec![0.0; m],
            "sign(0) must be 0: balanced experts keep their bias"
        );
    }

    #[test]
    fn lossfree_merge_averages_biases() {
        let insts = batches(21, 6);
        let mut a = LossFree::new(16, 1e-2);
        let mut b = LossFree::new(16, 1e-2);
        for inst in &insts[..3] {
            a.route_batch(inst);
        }
        for inst in &insts[3..] {
            b.route_batch(inst);
        }
        let states = [a.export_state(), b.export_state()];
        let want: Vec<f32> = a
            .bias
            .iter()
            .zip(&b.bias)
            .map(|(x, y)| (x + y) / 2.0)
            .collect();
        a.merge_state(&states);
        b.merge_state(&states);
        assert_eq!(a.bias, want);
        assert_eq!(a.bias, b.bias, "replicas must leave the sync equal");
    }

    #[test]
    fn bip_merge_averages_duals_and_seeds_cold_replicas() {
        let insts = batches(22, 4);
        let mut a = Bip::new(3);
        let mut cold = Bip::new(3);
        for inst in &insts {
            a.route_batch(inst);
        }
        assert!(matches!(cold.export_state(), BalanceState::None));
        let states = [a.export_state(), cold.export_state()];
        let q_before = a.q().unwrap().to_vec();
        a.merge_state(&states);
        cold.merge_state(&states);
        // only one Dual state in the slice: the mean is just a's q,
        // and the cold replica adopts it wholesale
        assert_eq!(a.q().unwrap(), q_before.as_slice());
        assert_eq!(cold.q().unwrap(), q_before.as_slice());
    }

    #[test]
    fn online_and_approx_merges_leave_replicas_identical() {
        let insts = batches(23, 6);
        let (m, k, cap) = (16usize, 4usize, 512usize);
        let mut on_a = OnlineBip::new(m, k, cap, 3);
        let mut on_b = OnlineBip::new(m, k, cap, 3);
        let mut ap_a = ApproxBip::new(m, k, cap, 3, 64);
        let mut ap_b = ApproxBip::new(m, k, cap, 3, 64);
        for inst in &insts[..3] {
            on_a.route_batch(inst);
            ap_a.route_batch(inst);
        }
        for inst in &insts[3..] {
            on_b.route_batch(inst);
            ap_b.route_batch(inst);
        }
        let on_states = [on_a.export_state(), on_b.export_state()];
        on_a.merge_state(&on_states);
        on_b.merge_state(&on_states);
        assert_eq!(on_a.gate.q, on_b.gate.q);
        let (mut ha, mut hb) =
            (on_a.gate.heap_values(), on_b.gate.heap_values());
        for (a, b) in ha.iter_mut().zip(hb.iter_mut()) {
            a.sort_by(|x, y| x.partial_cmp(y).unwrap());
            b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        }
        assert_eq!(ha, hb, "merged heaps must hold the same multiset");
        // heap union stays bounded: re-merging cannot grow the state
        let bytes = on_a.state_bytes();
        let again = [on_a.export_state(), on_b.export_state()];
        on_a.merge_state(&again);
        assert_eq!(on_a.state_bytes(), bytes);

        let ap_states = [ap_a.export_state(), ap_b.export_state()];
        ap_a.merge_state(&ap_states);
        ap_b.merge_state(&ap_states);
        assert_eq!(ap_a.gate.q, ap_b.gate.q);
        assert_eq!(ap_a.gate.hist_counts(), ap_b.gate.hist_counts());
        // scaled union: merged totals stay at single-stream scale
        let total: u64 = ap_a
            .gate
            .hist_counts()
            .iter()
            .flat_map(|h| h.iter().map(|&c| c as u64))
            .sum();
        let single: u64 = ap_states
            .iter()
            .map(|s| match s {
                BalanceState::Approx { hists, .. } => hists
                    .iter()
                    .flat_map(|h| h.iter().map(|&c| c as u64))
                    .sum::<u64>(),
                _ => 0,
            })
            .max()
            .unwrap();
        assert!(
            total <= single + (16 * 64) as u64,
            "merged totals {total} must not exceed one stream {single} \
             beyond rounding"
        );
    }

    #[test]
    fn greedy_export_is_none_and_merge_is_noop() {
        let mut g = Greedy;
        assert!(matches!(g.export_state(), BalanceState::None));
        g.merge_state(&[BalanceState::Bias(vec![1.0; 4])]);
        assert_eq!(g.state_bytes(), 0);
    }

    #[test]
    fn primary_covers_every_state_variant() {
        assert!(BalanceState::None.primary().is_none());
        assert_eq!(
            BalanceState::Bias(vec![1.0, 2.0]).primary(),
            Some(&[1.0, 2.0][..])
        );
        assert_eq!(
            BalanceState::Dual(vec![3.0]).primary(),
            Some(&[3.0][..])
        );
        assert_eq!(
            BalanceState::Online { q: vec![4.0], heaps: vec![vec![]] }
                .primary(),
            Some(&[4.0][..])
        );
        assert_eq!(
            BalanceState::Approx { q: vec![5.0, 6.0], hists: vec![] }
                .primary(),
            Some(&[5.0, 6.0][..])
        );
    }

    #[test]
    fn online_merge_ignores_misshapen_sketches() {
        // a replica slice can carry foreign shapes (config drift,
        // version skew): the merge must use only the well-shaped states
        // and never panic or corrupt the gate
        let insts = batches(31, 4);
        let (m, k, cap) = (16usize, 4usize, 512usize);
        let mut a = OnlineBip::new(m, k, cap, 3);
        let mut b = OnlineBip::new(m, k, cap, 3);
        for inst in &insts[..2] {
            a.route_batch(inst);
        }
        for inst in &insts[2..] {
            b.route_batch(inst);
        }
        let good = [a.export_state(), b.export_state()];
        let mut want_a = OnlineBip::new(m, k, cap, 3);
        let mut want_b = OnlineBip::new(m, k, cap, 3);
        for inst in &insts[..2] {
            want_a.route_batch(inst);
        }
        for inst in &insts[2..] {
            want_b.route_batch(inst);
        }
        want_a.merge_state(&good);
        want_b.merge_state(&good);

        // misshapen: wrong dual width, wrong heap count, foreign variant
        let noisy = [
            good[0].clone(),
            BalanceState::Online {
                q: vec![9.0; m / 2],
                heaps: vec![vec![9.0]; m / 2],
            },
            BalanceState::Online {
                q: vec![9.0; m],
                heaps: vec![vec![9.0]; m - 1],
            },
            BalanceState::Bias(vec![9.0; m]),
            good[1].clone(),
        ];
        a.merge_state(&noisy);
        b.merge_state(&noisy);
        assert_eq!(a.gate.q, want_a.gate.q);
        assert_eq!(b.gate.q, want_b.gate.q);
        let (mut ha, mut hw) =
            (a.gate.heap_values(), want_a.gate.heap_values());
        for (x, y) in ha.iter_mut().zip(hw.iter_mut()) {
            x.sort_by(|p, q| p.partial_cmp(q).unwrap());
            y.sort_by(|p, q| p.partial_cmp(q).unwrap());
        }
        assert_eq!(ha, hw, "noise must not leak into the heap union");
    }

    #[test]
    fn approx_merge_ignores_misshapen_sketches() {
        let insts = batches(32, 4);
        let (m, k, cap, b_buckets) = (16usize, 4usize, 512usize, 64usize);
        let mut a = ApproxBip::new(m, k, cap, 3, b_buckets);
        let mut b = ApproxBip::new(m, k, cap, 3, b_buckets);
        for inst in &insts[..2] {
            a.route_batch(inst);
        }
        for inst in &insts[2..] {
            b.route_batch(inst);
        }
        let good = [a.export_state(), b.export_state()];
        let mut want = ApproxBip::new(m, k, cap, 3, b_buckets);
        for inst in &insts[..2] {
            want.route_batch(inst);
        }
        want.merge_state(&good);

        let noisy = [
            good[0].clone(),
            // wrong bucket count in one expert's histogram
            BalanceState::Approx {
                q: vec![1.0; m],
                hists: {
                    let mut h = vec![vec![1u32; b_buckets]; m];
                    h[3] = vec![1u32; b_buckets / 2];
                    h
                },
            },
            // wrong expert count
            BalanceState::Approx {
                q: vec![1.0; m + 1],
                hists: vec![vec![1u32; b_buckets]; m + 1],
            },
            BalanceState::None,
            good[1].clone(),
        ];
        a.merge_state(&noisy);
        assert_eq!(a.gate.q, want.gate.q);
        assert_eq!(a.gate.hist_counts(), want.gate.hist_counts());
    }

    #[test]
    fn predictive_bip_with_empty_seed_is_bit_identical_to_bip() {
        let insts = batches(33, 5);
        let mut bip = Bip::new(3);
        let mut pred = PredictiveBip::new(3, Vec::new());
        for inst in &insts {
            assert_eq!(
                bip.route_batch(inst).assignment,
                pred.route_batch(inst).assignment
            );
        }
        assert_eq!(bip.q().unwrap(), pred.q().unwrap());
        assert_eq!(bip.state_bytes(), pred.state_bytes());
    }

    #[test]
    fn predictive_bip_seed_shapes_the_first_route_only_as_a_warm_start() {
        let insts = batches(34, 3);
        let m = 16;
        // a seed penalizing the first quarter of experts
        let mut seed = vec![0.0f32; m];
        for q in seed.iter_mut().take(m / 4) {
            *q = 0.2;
        }
        let mut pred = PredictiveBip::new(0, seed.clone());
        let mut warm_bip = Bip::new(0);
        warm_bip.seed_state(&BalanceState::Dual(seed.clone()));
        for inst in &insts {
            // T=0: both route directly with the seeded duals
            assert_eq!(
                pred.route_batch(inst).assignment,
                warm_bip.route_batch(inst).assignment
            );
        }
        assert_eq!(pred.q().unwrap(), seed.as_slice());
        assert!(pred.name().contains("predictive"));
        // a misshapen seed degrades to cold start instead of panicking
        let mut bad = PredictiveBip::new(2, vec![1.0; 3]);
        let mut cold = Bip::new(2);
        assert_eq!(
            bad.route_batch(&insts[0]).assignment,
            cold.route_batch(&insts[0]).assignment
        );
    }

    #[test]
    fn seed_state_ignores_foreign_variants() {
        let insts = batches(35, 2);
        let mut lf = LossFree::new(16, 1e-2);
        lf.route_batch(&insts[0]);
        let bias = lf.bias.clone();
        lf.seed_state(&BalanceState::Online {
            q: vec![1.0; 16],
            heaps: vec![vec![]; 16],
        });
        lf.seed_state(&BalanceState::Bias(vec![1.0; 5]));
        assert_eq!(lf.bias, bias, "foreign/misshapen seeds are ignored");
        // the forecast dual seed lands with flipped sign
        lf.seed_state(&BalanceState::Dual(vec![0.5; 16]));
        assert!(lf.bias.iter().all(|&b| b == -0.5));

        let mut g = Greedy;
        g.seed_state(&BalanceState::Dual(vec![1.0; 16]));
        assert!(matches!(g.export_state(), BalanceState::None));
    }

    #[test]
    fn bip_with_pool_routes_identically_to_serial() {
        let insts = batches(24, 4);
        let pool = std::sync::Arc::new(crate::util::pool::Pool::new(3));
        let mut serial = Bip::new(3);
        let mut parallel = Bip::with_pool(3, pool);
        for inst in &insts {
            let a = serial.route_batch(inst);
            let b = parallel.route_batch(inst);
            assert_eq!(a.assignment, b.assignment);
        }
        assert_eq!(serial.q().unwrap(), parallel.q().unwrap());
        // shard staging on the pooled side is excluded from the
        // accounting, so the footprints still match exactly
        assert_eq!(serial.state_bytes(), parallel.state_bytes());
    }
}
