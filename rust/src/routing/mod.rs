//! Host-side routing strategies behind one trait.
//!
//! The production routing runs inside the AOT train step (L2/L1); these
//! host mirrors exist for (a) the solver benches and cluster-sim ablations
//! that sweep routing policies without touching PJRT, and (b) equivalence
//! tests against the in-graph implementations through the probe artifact.

use crate::bip::approx::ApproxGate;
use crate::bip::dual::DualState;
use crate::bip::online::OnlineGate;
use crate::bip::{Instance, Routing};
use crate::util::stats::topk_indices;

/// A stateful routing policy over a stream of score batches.
pub trait RoutingStrategy {
    fn name(&self) -> String;
    /// Route one batch, updating internal state (bias vectors etc.).
    fn route_batch(&mut self, inst: &Instance) -> Routing;
    /// Bytes of persistent balancing state (dual vectors, heaps,
    /// histograms) — the §5.2 footprint the serving report tracks.
    fn state_bytes(&self) -> usize {
        0
    }
}

/// Plain top-k on raw scores.
pub struct Greedy;

impl RoutingStrategy for Greedy {
    fn name(&self) -> String {
        "greedy".into()
    }

    fn route_batch(&mut self, inst: &Instance) -> Routing {
        crate::bip::greedy_topk(inst)
    }
}

/// Loss-Controlled baseline. The auxiliary loss influences routing only
/// through training the router weights, which a host-side mirror cannot
/// do — so its *routing decision* is greedy top-k (as in the real method)
/// and the aux-loss value is tracked for reporting.
pub struct AuxLoss {
    pub alpha: f64,
    pub last_aux_loss: f64,
}

impl AuxLoss {
    pub fn new(alpha: f64) -> Self {
        AuxLoss { alpha, last_aux_loss: 0.0 }
    }
}

impl RoutingStrategy for AuxLoss {
    fn name(&self) -> String {
        format!("aux(alpha={})", self.alpha)
    }

    fn route_batch(&mut self, inst: &Instance) -> Routing {
        let routing = crate::bip::greedy_topk(inst);
        let loads = routing.loads(inst.m);
        let scale = inst.m as f64 / (inst.k * inst.n) as f64;
        let mut aux = 0.0;
        for j in 0..inst.m {
            let f_j = loads[j] as f64 * scale;
            let p_j: f64 = (0..inst.n)
                .map(|i| inst.score(i, j) as f64)
                .sum::<f64>()
                / inst.n as f64;
            aux += f_j * p_j;
        }
        self.last_aux_loss = self.alpha * aux;
        routing
    }
}

/// Loss-Free baseline (Wang et al. 2024): additive bias b, per-batch sign
/// update b_j += u * sign(mean - load_j).
pub struct LossFree {
    pub u: f32,
    pub bias: Vec<f32>,
}

impl LossFree {
    pub fn new(m: usize, u: f32) -> Self {
        LossFree { u, bias: vec![0.0; m] }
    }
}

impl RoutingStrategy for LossFree {
    fn name(&self) -> String {
        format!("lossfree(u={})", self.u)
    }

    fn route_batch(&mut self, inst: &Instance) -> Routing {
        let mut biased = vec![0.0f32; inst.m];
        let assignment: Vec<Vec<u32>> = (0..inst.n)
            .map(|i| {
                let row = inst.row(i);
                for j in 0..inst.m {
                    biased[j] = row[j] + self.bias[j];
                }
                topk_indices(&biased, inst.k)
                    .into_iter()
                    .map(|e| e as u32)
                    .collect()
            })
            .collect();
        let routing = Routing { assignment };
        let loads = routing.loads(inst.m);
        let mean = inst.n as f32 * inst.k as f32 / inst.m as f32;
        for j in 0..inst.m {
            self.bias[j] += self.u * (mean - loads[j] as f32).signum();
        }
        routing
    }

    fn state_bytes(&self) -> usize {
        self.bias.len() * 4
    }
}

/// BIP-Based Balancing (Algorithm 1): warm-started dual state + T
/// iterations per batch.
pub struct Bip {
    pub t_iters: usize,
    state: Option<DualState>,
}

impl Bip {
    pub fn new(t_iters: usize) -> Self {
        Bip { t_iters, state: None }
    }

    pub fn q(&self) -> Option<&[f32]> {
        self.state.as_ref().map(|s| s.q.as_slice())
    }
}

impl RoutingStrategy for Bip {
    fn name(&self) -> String {
        format!("bip(T={})", self.t_iters)
    }

    fn route_batch(&mut self, inst: &Instance) -> Routing {
        let state = self
            .state
            .get_or_insert_with(|| DualState::new(inst.m));
        state.update(inst, self.t_iters);
        state.route(inst)
    }

    fn state_bytes(&self) -> usize {
        self.state
            .as_ref()
            .map(|s| (s.q.len() + s.p.len()) * 4)
            .unwrap_or(0)
    }
}

/// Algorithm 3 (`bip::online::OnlineGate`) as a batch strategy: tokens
/// stream through the gate in row order and the duals + per-expert
/// top-heaps persist across batches. This is the serving router's exact
/// online policy; `cap` is the *stream-level* expert capacity
/// (total_tokens * k / m), per §5 semantics.
pub struct OnlineBip {
    pub gate: OnlineGate,
}

impl OnlineBip {
    pub fn new(m: usize, k: usize, cap: usize, t_iters: usize) -> Self {
        OnlineBip { gate: OnlineGate::new(m, k, cap, t_iters) }
    }
}

impl RoutingStrategy for OnlineBip {
    fn name(&self) -> String {
        format!("bip-online(T={})", self.gate.t_iters)
    }

    fn route_batch(&mut self, inst: &Instance) -> Routing {
        let assignment = (0..inst.n)
            .map(|i| self.gate.route_token(inst.row(i)))
            .collect();
        Routing { assignment }
    }

    fn state_bytes(&self) -> usize {
        self.gate.state_bytes()
    }
}

/// Algorithm 4 (`bip::approx::ApproxGate`) as a batch strategy: constant
/// O(m·b) state regardless of how many batches have streamed through.
pub struct ApproxBip {
    pub gate: ApproxGate,
    pub buckets: usize,
}

impl ApproxBip {
    pub fn new(
        m: usize,
        k: usize,
        cap: usize,
        t_iters: usize,
        buckets: usize,
    ) -> Self {
        ApproxBip {
            gate: ApproxGate::new(m, k, cap, t_iters, buckets),
            buckets,
        }
    }
}

impl RoutingStrategy for ApproxBip {
    fn name(&self) -> String {
        format!("bip-approx(T={},b={})", self.gate.t_iters, self.buckets)
    }

    fn route_batch(&mut self, inst: &Instance) -> Routing {
        let assignment = (0..inst.n)
            .map(|i| self.gate.route_token(inst.row(i)))
            .collect();
        Routing { assignment }
    }

    fn state_bytes(&self) -> usize {
        self.gate.state_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn batches(seed: u64, count: usize) -> Vec<Instance> {
        let mut rng = Pcg64::new(seed);
        (0..count)
            .map(|_| Instance::synthetic(256, 16, 4, 2.0, 3.0, &mut rng))
            .collect()
    }

    fn avg_vio(strategy: &mut dyn RoutingStrategy, insts: &[Instance]) -> f64 {
        let mut sum = 0.0;
        for inst in insts {
            sum += strategy.route_batch(inst).max_violation(inst);
        }
        sum / insts.len() as f64
    }

    #[test]
    fn strategy_ordering_matches_paper_shape() {
        // on a skewed score stream: bip << lossfree < greedy
        let insts = batches(1, 20);
        let vio_greedy = avg_vio(&mut Greedy, &insts);
        let vio_lf = avg_vio(&mut LossFree::new(16, 1e-3), &insts);
        let vio_bip = avg_vio(&mut Bip::new(4), &insts);
        assert!(vio_bip < 0.35, "bip {vio_bip}");
        assert!(vio_bip < vio_lf, "bip {vio_bip} lf {vio_lf}");
        assert!(vio_lf <= vio_greedy + 0.05,
                "lf {vio_lf} greedy {vio_greedy}");
    }

    #[test]
    fn lossfree_bias_accumulates_toward_balance() {
        // with a large-enough u and many identical batches, loss-free does
        // converge — the paper's point is it needs MANY batches
        let insts = batches(2, 200);
        let mut lf = LossFree::new(16, 1e-2);
        let first = lf.route_batch(&insts[0]).max_violation(&insts[0]);
        for inst in &insts {
            lf.route_batch(inst);
        }
        let last = lf
            .route_batch(insts.last().unwrap())
            .max_violation(insts.last().unwrap());
        assert!(last < first, "first {first} last {last}");
    }

    #[test]
    fn aux_loss_mirrors_track_loss_value() {
        let insts = batches(3, 3);
        let mut aux = AuxLoss::new(0.1);
        aux.route_batch(&insts[0]);
        assert!(aux.last_aux_loss > 0.0);
        // alpha scales it linearly
        let mut aux2 = AuxLoss::new(0.2);
        aux2.route_batch(&insts[0]);
        assert!((aux2.last_aux_loss / aux.last_aux_loss - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bip_warm_start_persists_across_batches() {
        let insts = batches(4, 5);
        let mut bip = Bip::new(2);
        bip.route_batch(&insts[0]);
        let q1 = bip.q().unwrap().to_vec();
        for inst in &insts[1..] {
            bip.route_batch(inst);
        }
        let q5 = bip.q().unwrap().to_vec();
        assert_ne!(q1, q5);
        assert!(q5.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn names_are_descriptive() {
        assert_eq!(Greedy.name(), "greedy");
        assert!(Bip::new(8).name().contains("T=8"));
        assert!(LossFree::new(4, 1e-3).name().contains("u=0.001"));
        assert!(OnlineBip::new(8, 2, 64, 4).name().contains("T=4"));
        assert!(ApproxBip::new(8, 2, 64, 4, 32).name().contains("b=32"));
    }

    #[test]
    fn gate_wrappers_match_direct_gate_streams() {
        // routing a batch through the wrapper must equal streaming the
        // rows through a bare gate: same tokens, same order, same duals
        let insts = batches(7, 3);
        let (m, k) = (16usize, 4usize);
        let cap = insts.iter().map(|i| i.n).sum::<usize>() * k / m;
        let mut wrapper = OnlineBip::new(m, k, cap, 3);
        let mut bare = crate::bip::online::OnlineGate::new(m, k, cap, 3);
        for inst in &insts {
            let routed = wrapper.route_batch(inst);
            for i in 0..inst.n {
                assert_eq!(routed.assignment[i], bare.route_token(inst.row(i)));
            }
        }
    }

    #[test]
    fn state_bytes_grow_only_where_expected() {
        let insts = batches(8, 4);
        assert_eq!(Greedy.state_bytes(), 0);

        let mut online = OnlineBip::new(16, 4, 1024, 2);
        let mut approx = ApproxBip::new(16, 4, 1024, 2, 64);
        assert_eq!(online.state_bytes(), 16 * 4); // just q before any batch
        let approx_initial = approx.state_bytes();
        for inst in &insts {
            online.route_batch(inst);
            approx.route_batch(inst);
        }
        assert!(online.state_bytes() > 16 * 4);
        // Algorithm 4: histogram state is constant in the stream length
        assert_eq!(approx.state_bytes(), approx_initial);

        let mut bip = Bip::new(2);
        assert_eq!(bip.state_bytes(), 0);
        bip.route_batch(&insts[0]);
        assert!(bip.state_bytes() > 0);
    }
}
