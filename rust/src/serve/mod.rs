//! Online inference serving on the streaming BIP solvers (§5).
//!
//! The paper's online variants (Algorithm 3 `bip::online`, Algorithm 4
//! `bip::approx`) are streaming balancers — exactly what an inference
//! router needs: per-token decisions, persistent duals, bounded state.
//! This subsystem turns them into a serving stack:
//!
//! * [`traffic`] — scenario-diverse synthetic request generator
//!   (steady, bursty, diurnal, adversarially drifting skew,
//!   multi-tenant), all seeded and reproducible;
//! * [`scheduler`] — admission control + bounded FIFO queue +
//!   deadline-aware micro-batch formation;
//! * [`router`] — per-layer gates behind `routing::RoutingStrategy`
//!   with hard per-expert capacity enforcement and expert-parallel
//!   placement accounting;
//! * [`slo`] — latency percentiles, throughput/goodput, MaxVio reuse;
//! * [`sim`] — the virtual-time event loop tying it together, with
//!   service times from `parallel::ServeCost` so imbalance costs
//!   latency the way a straggling device would;
//! * [`replica`] — the replica-sharded thread-parallel engine: R
//!   router replicas behind one admission queue, least-work dispatch
//!   on the shared `util::pool::Pool`, and periodic mergeable-state
//!   reconciliation (`RoutingStrategy::export_state`/`merge_state`).
//!
//! Both event loops also exist as `*_with` variants taking an explicit
//! request source and an optional `trace::TraceRecorder` — the seam the
//! `trace/` subsystem records, replays, and counterfactually re-routes
//! through (`Scenario::Replayed`) — and as forecast-driven variants:
//! `run_scenario_seeded` / `run_replicated_seeded` warm-start every
//! layer's balance state from forecast dual seeds,
//! `run_scenario_predictive` sheds predicted overload ahead of the
//! queue, and `run_autoscaled` sizes the active replica set from the
//! predicted aggregate rate (`forecast::control`).
//!
//! Driven by the `bip-moe serve` + `bip-moe forecast` subcommands,
//! `bench_serving`, and `bench_forecast`.

pub mod replica;
pub mod router;
pub mod scheduler;
pub mod sim;
pub mod slo;
pub mod traffic;

pub use replica::{
    run_autoscaled, run_replicated, run_replicated_seeded,
    run_replicated_with, ReplicaConfig, ReplicaOutcome, ReplicaSet,
    SyncEvent,
};
pub use router::{BatchOutcome, Policy, RouterConfig, ServingRouter};
pub use scheduler::{Admission, MicroBatcher, SchedulerConfig};
pub use sim::{
    run_scenario, run_scenario_observed, run_scenario_predictive,
    run_scenario_seeded, run_scenario_with, Completion, ServeConfig,
    ServeOutcome,
};
pub use slo::{ReplicaSummary, ServeReport, SloTracker};
pub use traffic::{Request, Scenario, TrafficConfig, TrafficGenerator};
