//! Capacity-aware per-layer serving router.
//!
//! Each MoE layer owns one [`RoutingStrategy`] (greedy / Loss-Free /
//! BIP dual per batch / Algorithm 3 / Algorithm 4 — the last two wrap
//! `bip::online::OnlineGate` and `bip::approx::ApproxGate`). The router
//! then *enforces* a hard per-expert capacity per micro-batch:
//! `cap = ceil(batch_n * k / m * capacity_factor)`. A token whose chosen
//! expert is full is rerouted to its best-scoring expert with room (an
//! overflow); if no distinct expert has room the slot is dropped (a
//! degradation). Per-expert loads can therefore never exceed the cap —
//! the property the tests pin — and balanced policies show up directly
//! as fewer overflows and lower per-layer MaxVio.
//!
//! Device-level accounting runs against an expert-parallel
//! [`Placement`]: static block placement by default, or periodically
//! refreshed LPT placement from the observed cumulative loads.

use std::sync::Arc;

use crate::bip::Instance;
use crate::metrics::maxvio::BalanceTracker;
use crate::obs::event::{self, EventKind};
use crate::parallel::placement::{greedy_placement, Placement};
use crate::parallel::Mesh;
use crate::perf::{AssignmentBuf, ScoreArena};
use crate::prof::{Frame, ProfGuard};
use crate::routing::{
    ApproxBip, BalanceState, Bip, Greedy, LossFree, OnlineBip,
    PredictiveBip, RoutingStrategy,
};
use crate::telemetry::{self, Counter, Gauge, Span, SpanKind};
use crate::util::pool::Pool;
use crate::util::stats::Summary;

use super::traffic::Request;

/// Which balancing policy every layer's gate runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Raw top-k — the unbalanced baseline.
    Greedy,
    /// Loss-Free additive bias (Wang et al., 2024).
    LossFree,
    /// Algorithm 1: warm-started dual ascent once per micro-batch.
    BipBatch,
    /// Algorithm 3: per-token online gate with exact top-heaps.
    Online,
    /// Algorithm 4: per-token online gate with constant-space histograms.
    Approx,
    /// Algorithm 1 warm-started from a forecast-derived dual seed
    /// (`routing::PredictiveBip`); cold (unseeded) it equals `BipBatch`.
    Predictive,
}

impl Policy {
    pub fn all() -> [Policy; 6] {
        [
            Policy::Greedy,
            Policy::LossFree,
            Policy::BipBatch,
            Policy::Online,
            Policy::Approx,
            Policy::Predictive,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            Policy::Greedy => "greedy",
            Policy::LossFree => "lossfree",
            Policy::BipBatch => "bip-batch",
            Policy::Online => "bip-online",
            Policy::Approx => "bip-approx",
            Policy::Predictive => "bip-predictive",
        }
    }

    pub fn parse(s: &str) -> Option<Policy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "greedy" | "topk" => Some(Policy::Greedy),
            "lossfree" | "loss-free" => Some(Policy::LossFree),
            "bip" | "bip-batch" | "batch" => Some(Policy::BipBatch),
            "online" | "bip-online" => Some(Policy::Online),
            "approx" | "bip-approx" => Some(Policy::Approx),
            "predictive" | "bip-predictive" => Some(Policy::Predictive),
            _ => None,
        }
    }

    /// Valid CLI spellings, for error messages.
    pub fn names() -> Vec<&'static str> {
        Policy::all().iter().map(|p| p.name()).collect()
    }

    /// BIP-balanced policies (vs the baselines).
    pub fn is_bip(self) -> bool {
        matches!(
            self,
            Policy::BipBatch
                | Policy::Online
                | Policy::Approx
                | Policy::Predictive
        )
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct RouterConfig {
    pub m: usize,
    pub k: usize,
    pub n_layers: usize,
    /// Algorithm 1/3/4 refinement iterations
    pub t_iters: usize,
    /// Algorithm 4 histogram buckets
    pub buckets: usize,
    /// total tokens the stream-level gates (Alg 3/4) size their expert
    /// capacity against — typically the expected request count
    pub expected_stream: usize,
    /// per-batch per-expert cap = ceil(batch_n * k / m * capacity_factor)
    pub capacity_factor: f64,
    pub n_devices: usize,
    /// Some(n): refresh the expert placement by LPT from cumulative
    /// observed loads every n batches; None: static block placement
    pub lpt_refresh: Option<u64>,
    /// Loss-Free bias step size
    pub lossfree_u: f32,
    /// Convergence-adaptive Algorithm 1 tolerance (`--solver-tol`):
    /// with `> 0`, the bip-batch/bip-predictive per-batch solve
    /// early-exits once the duals go quiet and the routed MaxVio stops
    /// improving (never more than `solver_tol` above the fixed-T
    /// result on the paper's gate sizes — pinned by the dual tests).
    /// 0 keeps the fixed-T solver bit-identically.
    pub solver_tol: f64,
    /// Iteration cap for the adaptive solver (`--solver-t-max`): the
    /// Algorithm 1 T used by bip-batch/bip-predictive layers when
    /// both it and `solver_tol` are `> 0`; otherwise `t_iters`
    /// governs (the fixed-T path ignores this knob entirely).
    /// Online/approx gates always use `t_iters` (their per-token
    /// refinement has no batch fixpoint to detect).
    pub solver_t_max: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            m: 16,
            k: 4,
            n_layers: 4,
            t_iters: 4,
            buckets: 128,
            expected_stream: 4096,
            capacity_factor: 2.0,
            n_devices: 4,
            lpt_refresh: None,
            lossfree_u: 1e-2,
            solver_tol: 0.0,
            solver_t_max: 0,
        }
    }
}

/// Per-batch routing outcome the simulator consumes. `Default` is the
/// empty outcome callers reuse across batches
/// ([`ServingRouter::route_batch_into`] refills every field).
#[derive(Clone, Debug, Default)]
pub struct BatchOutcome {
    /// row-major (n_layers, m) routed loads
    pub loads: Vec<f32>,
    /// mean over layers of this batch's per-layer MaxVio
    pub batch_vio: f64,
    /// tokens rerouted because their chosen expert was full
    pub overflow: u64,
    /// expert slots dropped because no distinct expert had room
    pub degraded: u64,
    /// mean over layers of max-device-load / mean-device-load
    pub device_imbalance: f64,
    /// `[layer][token]` enforced chosen experts — populated only when
    /// [`ServingRouter::capture_assignments`] is set (trace recording);
    /// `None` on the production path, which allocates nothing for it
    pub assignment: Option<Vec<Vec<Vec<u16>>>>,
}

pub struct ServingRouter {
    cfg: RouterConfig,
    policy: Policy,
    layers: Vec<Box<dyn RoutingStrategy>>,
    pub placement: Placement,
    /// cumulative per-expert load (summed over layers) for LPT refresh
    cum_loads: Vec<f64>,
    batches: u64,
    pub overflow_total: u64,
    pub degraded_total: u64,
    pub balance: BalanceTracker,
    pub imbalance: Summary,
    /// collect per-token post-enforcement assignments into
    /// [`BatchOutcome::assignment`] (trace recording); off by default
    pub capture_assignments: bool,
    /// one score-arena shared by every layer: the O(n·m) solver
    /// scratch exists once per router, and the steady-state hot path
    /// allocates nothing (`perf::arena` ownership rules)
    arena: ScoreArena,
    /// reusable per-layer routing output (replaces per-token `Vec`s)
    assignment: AssignmentBuf,
}

impl ServingRouter {
    pub fn new(policy: Policy, cfg: RouterConfig) -> ServingRouter {
        ServingRouter::new_with_pool(policy, cfg, None)
    }

    /// Like [`ServingRouter::new`], with a shared thread pool the
    /// Algorithm 1 per-batch dual update chunks its p/q phases onto
    /// (bit-identical to the serial path; only `Policy::BipBatch` has a
    /// parallelizable batch solve).
    pub fn new_with_pool(
        policy: Policy,
        cfg: RouterConfig,
        pool: Option<Arc<Pool>>,
    ) -> ServingRouter {
        assert!(cfg.m >= cfg.k && cfg.k >= 1 && cfg.n_layers >= 1);
        assert!(cfg.m % cfg.n_devices == 0,
                "experts {} must divide over devices {}", cfg.m,
                cfg.n_devices);
        assert!(cfg.capacity_factor >= 1.0);
        assert!(
            cfg.lpt_refresh.map_or(true, |n| n > 0),
            "lpt_refresh must be >= 1 batch"
        );
        assert!(
            cfg.solver_tol.is_finite() && cfg.solver_tol >= 0.0,
            "solver_tol must be finite and >= 0, got {}",
            cfg.solver_tol
        );
        let gate_cap =
            (cfg.expected_stream * cfg.k / cfg.m).max(1);
        // the adaptive solver's iteration cap (bip-batch/predictive
        // only); 0 follows the shared t_iters knob, and with the
        // adaptive solver disabled (solver_tol = 0) the cap is
        // ignored entirely — --t alone governs the fixed-T path
        let bip_t = if cfg.solver_tol > 0.0 && cfg.solver_t_max > 0 {
            cfg.solver_t_max
        } else {
            cfg.t_iters
        };
        let bip_tol = cfg.solver_tol as f32;
        let layers: Vec<Box<dyn RoutingStrategy>> = (0..cfg.n_layers)
            .map(|_| -> Box<dyn RoutingStrategy> {
                match policy {
                    Policy::Greedy => Box::new(Greedy),
                    Policy::LossFree => {
                        Box::new(LossFree::new(cfg.m, cfg.lossfree_u))
                    }
                    Policy::BipBatch => {
                        let mut bip = match &pool {
                            Some(p) => {
                                Bip::with_pool(bip_t, p.clone())
                            }
                            None => Bip::new(bip_t),
                        };
                        bip.set_solver_tol(bip_tol);
                        Box::new(bip)
                    }
                    // constructed cold (empty seed, == BipBatch);
                    // `seed_layers` installs the forecast duals
                    Policy::Predictive => {
                        let mut pred = match &pool {
                            Some(p) => PredictiveBip::with_pool(
                                bip_t,
                                Vec::new(),
                                p.clone(),
                            ),
                            None => {
                                PredictiveBip::new(bip_t, Vec::new())
                            }
                        };
                        pred.set_solver_tol(bip_tol);
                        Box::new(pred)
                    }
                    Policy::Online => Box::new(OnlineBip::new(
                        cfg.m, cfg.k, gate_cap, cfg.t_iters,
                    )),
                    Policy::Approx => Box::new(ApproxBip::new(
                        cfg.m, cfg.k, gate_cap, cfg.t_iters, cfg.buckets,
                    )),
                }
            })
            .collect();
        let placement =
            Placement::block(&Mesh::new(cfg.n_devices, cfg.m));
        let balance = BalanceTracker::new(cfg.n_layers, 0, cfg.k);
        telemetry::gauge_set(Gauge::RouterLayers, cfg.n_layers as f64);
        telemetry::gauge_set(Gauge::RouterExperts, cfg.m as f64);
        let mut arena = ScoreArena::new();
        arena.dev_loads.resize(cfg.n_devices, 0.0);
        arena.occ.resize(cfg.m, 0);
        ServingRouter {
            cum_loads: vec![0.0; cfg.m],
            cfg,
            policy,
            layers,
            placement,
            batches: 0,
            overflow_total: 0,
            degraded_total: 0,
            balance,
            imbalance: Summary::new(),
            capture_assignments: false,
            arena,
            assignment: AssignmentBuf::new(),
        }
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    pub fn policy_label(&self) -> String {
        // LINT-ALLOW(panic): constructors reject n_layers == 0
        self.layers[0].name()
    }

    /// Hard per-expert cap for a batch of `n` tokens.
    pub fn batch_cap(&self, n: usize) -> usize {
        ((n * self.cfg.k) as f64 / self.cfg.m as f64
            * self.cfg.capacity_factor)
            .ceil()
            .max(1.0) as usize
    }

    /// Persistent balancing + routing-scratch state, bytes: every
    /// layer's gate state, plus the shared score-arena and the
    /// reusable assignment buffer (counted once per router — the
    /// layers share them).
    pub fn state_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.state_bytes()).sum::<usize>()
            + self.arena.state_bytes()
            + self.assignment.state_bytes()
    }

    /// Micro-batches routed so far.
    pub fn batches_routed(&self) -> u64 {
        self.batches
    }

    /// Snapshot every layer's mergeable balance state (replica sync).
    pub fn export_states(&self) -> Vec<BalanceState> {
        self.layers.iter().map(|l| l.export_state()).collect()
    }

    /// Reconcile every layer with the corresponding layer of every
    /// replica: `all[r][l]` is replica r's state for layer l. Each
    /// replica is handed the identical slice, so the merge leaves all
    /// replicas with identical balance state.
    pub fn merge_states(&mut self, all: &[Vec<BalanceState>]) {
        for (l, layer) in self.layers.iter_mut().enumerate() {
            let states: Vec<BalanceState> = all
                .iter()
                .filter_map(|r| r.get(l).cloned())
                .collect();
            layer.merge_state(&states);
        }
    }

    /// Warm-start every layer from per-layer states — forecast dual
    /// seeds (`forecast::control::seed_states`) or a prior run's
    /// `export_states`. Extra states are ignored; missing layers stay
    /// cold. Call before the first batch is routed.
    pub fn seed_layers(&mut self, states: &[BalanceState]) {
        for (layer, state) in self.layers.iter_mut().zip(states) {
            layer.seed_state(state);
        }
    }

    /// Keep a bounded per-batch load-fraction history on the balance
    /// tracker (`forecast::fit::LoadSeries::from_tracker` consumes it).
    pub fn track_load_history(&mut self, cap: usize) {
        self.balance.enable_load_history(self.cfg.m, cap);
    }

    /// Route one micro-batch through every layer, enforcing capacity.
    /// Allocating convenience over [`ServingRouter::route_batch_into`]
    /// (the replicated engine and the trace tooling use it; the
    /// single-server event loop and the benches reuse one outcome).
    // COLD: allocating convenience seam over route_batch_into; the
    // static hot-path lint stops here
    pub fn route_batch(&mut self, batch: &[Request]) -> BatchOutcome {
        let mut out = BatchOutcome::default();
        self.route_batch_into(batch, &mut out);
        out
    }

    /// The zero-allocation hot path: identical routing, enforcement
    /// and accounting, written into a caller-reused outcome. In steady
    /// state (warm arena, no LPT refresh due, capture off) this makes
    /// no heap allocation — `bench_hotpath` and `integration_perf`
    /// install a counting allocator and pin the zero for every policy.
    // HOT: the serving hot path — no locks; allocations only on the
    // waived cold branches (capture, LPT refresh; see analysis/waivers.txt)
    pub fn route_batch_into(
        &mut self,
        batch: &[Request],
        out: &mut BatchOutcome,
    ) {
        // span + counters below are preallocated atomics
        // (`telemetry::registry`): the zero-alloc guarantee holds with
        // telemetry enabled, which `integration_perf` pins
        let _span = Span::enter(SpanKind::RouteBatch);
        let (m, k, n_layers) = (self.cfg.m, self.cfg.k, self.cfg.n_layers);
        let n = batch.len();
        assert!(n > 0);
        // open the causal context: every event below (LayerRoute,
        // SolverExit, DualExit, BatchDone) keys on this batch ordinal
        event::begin_batch(
            self.batches,
            batch.first().map_or(0, |r| r.id),
            n,
        );
        // sampled top-K-vs-gate-argmax agreement: every 16th batch
        let sampled = telemetry::enabled() && self.batches % 16 == 0;
        let mut agree = 0u64;
        let mut agree_n = 0u64;
        // refresh BEFORE routing: this batch must be accounted and priced
        // under the placement learned from *previous* batches, never one
        // computed with hindsight from its own loads
        if let Some(every) = self.cfg.lpt_refresh {
            if self.batches > 0 && self.batches % every == 0 {
                let profile: Vec<f32> =
                    self.cum_loads.iter().map(|&x| x as f32).collect();
                self.placement = greedy_placement(
                    &profile,
                    self.cfg.n_devices,
                    Some(m / self.cfg.n_devices),
                );
            }
        }
        let cap = self.batch_cap(n);
        out.loads.clear();
        out.loads.resize(n_layers * m, 0.0);
        out.assignment = None;
        let mut overflow = 0u64;
        let mut degraded = 0u64;
        let mut imbalance_sum = 0.0;
        self.arena.occ.resize(m, 0);
        let mut captured: Option<Vec<Vec<Vec<u16>>>> = self
            .capture_assignments
            .then(|| Vec::with_capacity(n_layers));

        for l in 0..n_layers {
            let _prof_layer = ProfGuard::enter(Frame::LayerRoute);
            event::set_layer_ctx(l);
            {
                let _prof = ProfGuard::enter(Frame::ScoreFill);
                self.arena.scores.clear();
                self.arena.scores.reserve(n * m);
                for r in batch {
                    self.arena
                        .scores
                        .extend_from_slice(r.layer_scores(l, m));
                }
            }
            if self.layers[l].wants_transpose() {
                // build the solver's column-major copy fill-side,
                // while the batch scores are still cache-hot; the dual
                // solve consumes it via the arena's shape-stamped
                // token instead of transposing again
                let _prof = ProfGuard::enter(Frame::Transpose);
                self.arena.fill_transpose(n, m);
            }
            // lend the arena's score buffer to the Instance for the
            // duration of the strategy call (moved back below)
            let inst = Instance {
                n,
                m,
                k,
                cap,
                scores: std::mem::take(&mut self.arena.scores),
            };
            self.layers[l].route_batch_into(
                &inst,
                &mut self.arena,
                &mut self.assignment,
            );

            self.arena.occ.iter_mut().for_each(|o| *o = 0);
            let mut layer_cap: Option<Vec<Vec<u16>>> = captured
                .is_some()
                .then(|| Vec::with_capacity(n));
            let prof_topk = ProfGuard::enter(Frame::TopK);
            for i in 0..n {
                self.arena.chosen.clear();
                for &e in self.assignment.token(i).iter().take(k) {
                    if self.arena.occ[e as usize] < cap as u32
                        && !self.arena.chosen.contains(&e)
                    {
                        self.arena.chosen.push(e);
                        self.arena.occ[e as usize] += 1;
                        continue;
                    }
                    // full (or duplicate): reroute to the best-scoring
                    // expert that still has room
                    overflow += 1;
                    let row = inst.row(i);
                    let mut best: Option<u32> = None;
                    for j in 0..m as u32 {
                        if self.arena.occ[j as usize] < cap as u32
                            && !self.arena.chosen.contains(&j)
                            && best.map_or(true, |b| {
                                row[j as usize] > row[b as usize]
                            })
                        {
                            best = Some(j);
                        }
                    }
                    match best {
                        Some(j) => {
                            self.arena.chosen.push(j);
                            self.arena.occ[j as usize] += 1;
                        }
                        None => degraded += 1,
                    }
                }
                if sampled {
                    // does the *enforced* top-K still contain the raw
                    // gate's argmax expert?
                    let row = inst.row(i);
                    let mut arg = 0usize;
                    for j in 1..m {
                        if row[j] > row[arg] {
                            arg = j;
                        }
                    }
                    if self
                        .arena
                        .chosen
                        .iter()
                        .any(|&e| e as usize == arg)
                    {
                        agree += 1;
                    }
                    agree_n += 1;
                }
                if let Some(lc) = layer_cap.as_mut() {
                    lc.push(
                        self.arena
                            .chosen
                            .iter()
                            .map(|&e| e as u16)
                            .collect(),
                    );
                }
                let lrow = &mut out.loads[l * m..(l + 1) * m];
                for &e in &self.arena.chosen {
                    lrow[e as usize] += 1.0;
                }
            }
            drop(prof_topk);
            if let Some(all) = captured.as_mut() {
                // LINT-ALLOW(panic): layer_cap is set at the top of
                // every layer iteration when capture is enabled
                all.push(layer_cap.take().expect("capture is on"));
            }
            let lrow = &out.loads[l * m..(l + 1) * m];
            telemetry::expert_tokens_add_f32(l, lrow);
            imbalance_sum += self
                .placement
                .imbalance_into(lrow, &mut self.arena.dev_loads);
            for (j, &x) in lrow.iter().enumerate() {
                self.cum_loads[j] += x as f64;
            }
            // return the lent score buffer to the arena
            let Instance { scores, .. } = inst;
            self.arena.scores = scores;
        }

        self.balance.push_batch_sized(&out.loads, m, n);
        // LINT-ALLOW(panic): push_batch_sized just appended a value
        let batch_vio = *self.balance.global_series.last().unwrap() as f64;
        let device_imbalance = imbalance_sum / n_layers as f64;
        self.imbalance.push(device_imbalance);
        self.overflow_total += overflow;
        self.degraded_total += degraded;
        self.batches += 1;

        out.batch_vio = batch_vio;
        out.overflow = overflow;
        out.degraded = degraded;
        out.device_imbalance = device_imbalance;
        out.assignment = captured;

        event::record_ctx_event(
            EventKind::BatchDone,
            f64::to_bits(batch_vio),
        );
        telemetry::counter_add(Counter::RouterBatches, 1);
        telemetry::counter_add(Counter::RouterTokens, n as u64);
        telemetry::counter_add(Counter::RouterOverflow, overflow);
        telemetry::counter_add(Counter::RouterDegraded, degraded);
        telemetry::gauge_set(Gauge::RouterLastBatchVio, batch_vio);
        if sampled {
            telemetry::counter_add(Counter::RouterTopkAgree, agree);
            telemetry::counter_add(
                Counter::RouterTopkSampled,
                agree_n,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::traffic::{Scenario, TrafficConfig, TrafficGenerator};

    fn requests(scenario: Scenario, n: usize, seed: u64) -> Vec<Request> {
        TrafficGenerator::new(TrafficConfig {
            scenario,
            n_requests: n,
            seed,
            ..Default::default()
        })
        .collect()
    }

    fn router(policy: Policy) -> ServingRouter {
        ServingRouter::new(policy, RouterConfig::default())
    }

    #[test]
    fn capacity_is_never_exceeded_under_any_policy() {
        // the core property: whatever the strategy proposes, enforced
        // per-expert loads stay within the hard cap — across policies,
        // scenarios, and ragged batch sizes
        let reqs = requests(Scenario::Adversarial, 300, 3);
        for policy in Policy::all() {
            let mut r = router(policy);
            let mut start = 0;
            for size in [64usize, 17, 3, 64, 64, 64, 24] {
                let batch = &reqs[start..start + size];
                start += size;
                let cap = r.batch_cap(size) as f32;
                let out = r.route_batch(batch);
                for l in 0..4 {
                    for &load in &out.loads[l * 16..(l + 1) * 16] {
                        assert!(
                            load <= cap,
                            "{policy:?}: load {load} > cap {cap}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn work_is_conserved_across_layers() {
        // routed slots + degraded slots == n * k * n_layers, exactly
        let reqs = requests(Scenario::Bursty, 128, 4);
        for policy in Policy::all() {
            let mut r = router(policy);
            let out = r.route_batch(&reqs);
            let routed: f32 = out.loads.iter().sum();
            assert_eq!(
                routed as u64 + out.degraded,
                128 * 4 * 4,
                "{policy:?}"
            );
        }
    }

    #[test]
    fn bip_policies_overflow_less_than_greedy_on_skewed_traffic() {
        let reqs = requests(Scenario::Steady, 512, 5);
        let mut totals = Vec::new();
        for policy in [Policy::Greedy, Policy::Online, Policy::BipBatch] {
            let mut r = router(policy);
            for chunk in reqs.chunks(64) {
                r.route_batch(chunk);
            }
            totals.push((policy, r.overflow_total, r.balance.avg_max_vio()));
        }
        let (_, greedy_of, greedy_vio) = totals[0];
        for &(policy, of, vio) in &totals[1..] {
            assert!(
                of < greedy_of,
                "{policy:?} overflow {of} vs greedy {greedy_of}"
            );
            assert!(
                vio < greedy_vio,
                "{policy:?} vio {vio} vs greedy {greedy_vio}"
            );
        }
    }

    #[test]
    fn lpt_refresh_improves_device_imbalance_for_greedy() {
        let reqs = requests(Scenario::Steady, 768, 6);
        let run = |lpt: Option<u64>| -> f64 {
            let mut r = ServingRouter::new(
                Policy::Greedy,
                RouterConfig { lpt_refresh: lpt, ..Default::default() },
            );
            for chunk in reqs.chunks(64) {
                r.route_batch(chunk);
            }
            r.imbalance.mean
        };
        let block = run(None);
        let lpt = run(Some(2));
        assert!(lpt < block, "lpt {lpt} block {block}");
    }

    #[test]
    fn captured_assignments_match_the_enforced_loads() {
        let reqs = requests(Scenario::Adversarial, 96, 8);
        for policy in [Policy::Greedy, Policy::Online] {
            let mut r = router(policy);
            r.capture_assignments = true;
            let out = r.route_batch(&reqs);
            let asn = out.assignment.as_ref().expect("capture on");
            assert_eq!(asn.len(), 4, "one entry per layer");
            let mut loads = vec![0.0f32; 4 * 16];
            for (l, layer) in asn.iter().enumerate() {
                assert_eq!(layer.len(), 96, "one entry per token");
                for tok in layer {
                    assert!(tok.len() <= 4);
                    for &e in tok {
                        loads[l * 16 + e as usize] += 1.0;
                    }
                }
            }
            assert_eq!(loads, out.loads, "{policy:?}");
            // off by default: the production path allocates nothing
            let mut plain = router(policy);
            assert!(plain.route_batch(&reqs).assignment.is_none());
        }
    }

    #[test]
    fn predictive_policy_is_cold_bip_until_seeded() {
        let reqs = requests(Scenario::Steady, 128, 9);
        let mut bip = router(Policy::BipBatch);
        let mut pred = router(Policy::Predictive);
        let a = bip.route_batch(&reqs);
        let b = pred.route_batch(&reqs);
        assert_eq!(a.loads, b.loads, "cold predictive == bip-batch");

        // seeding a fresh predictive router with bip's learned duals
        // adopts them layer for layer
        let states = bip.export_states();
        let mut seeded = router(Policy::Predictive);
        seeded.seed_layers(&states);
        let adopted = seeded.export_states();
        for (l, (s, w)) in states.iter().zip(&adopted).enumerate() {
            assert_eq!(s.primary(), w.primary(), "layer {l}");
        }
    }

    #[test]
    fn load_history_is_bounded_and_normalized() {
        let mut r = router(Policy::Greedy);
        r.track_load_history(4);
        let reqs = requests(Scenario::Steady, 6 * 64, 11);
        for chunk in reqs.chunks(64) {
            r.route_batch(chunk);
        }
        let h = r.balance.load_history.as_ref().expect("enabled");
        assert_eq!(h.per_layer.len(), 4);
        for ring in &h.per_layer {
            assert_eq!(ring.len(), 4, "ring keeps the last cap batches");
            for row in ring {
                assert_eq!(row.len(), 16);
                let sum: f32 = row.iter().sum();
                assert!((sum - 1.0).abs() < 1e-4, "sum {sum}");
            }
        }
    }

    #[test]
    fn state_bytes_sum_layers_plus_arena() {
        let mut r = router(Policy::Approx);
        assert!(r.state_bytes() > 0);
        let reqs = requests(Scenario::Steady, 2 * 64, 7);
        // the shared arena sizes itself to the first batch shape...
        r.route_batch(&reqs[..64]);
        let warm = r.state_bytes();
        // ...then the footprint is constant batch over batch (Alg 4's
        // gate state is constant-space, and the arena is warm)
        r.route_batch(&reqs[64..]);
        assert_eq!(r.state_bytes(), warm);
    }

    #[test]
    fn route_batch_into_matches_route_batch() {
        // the reusable-outcome hot path and the allocating convenience
        // must agree on every policy, batch after batch — loads, vio,
        // overflow accounting, the lot
        let reqs = requests(Scenario::Adversarial, 3 * 64, 12);
        for policy in Policy::all() {
            let mut a = router(policy);
            let mut b = router(policy);
            let mut out = super::BatchOutcome::default();
            for chunk in reqs.chunks(64) {
                let want = a.route_batch(chunk);
                b.route_batch_into(chunk, &mut out);
                assert_eq!(out.loads, want.loads, "{policy:?}");
                assert_eq!(out.batch_vio, want.batch_vio, "{policy:?}");
                assert_eq!(out.overflow, want.overflow, "{policy:?}");
                assert_eq!(out.degraded, want.degraded, "{policy:?}");
                assert_eq!(
                    out.device_imbalance, want.device_imbalance,
                    "{policy:?}"
                );
                assert!(out.assignment.is_none());
            }
            assert_eq!(a.state_bytes(), b.state_bytes(), "{policy:?}");
            assert_eq!(a.overflow_total, b.overflow_total);
        }
    }

    #[test]
    fn solver_tol_keeps_capacity_and_tracks_fixed_t_balance() {
        // --solver-tol wiring: the adaptive bip-batch router stays
        // capacity-feasible and lands within tol of the fixed-T
        // balance on a skewed stream (the dual tests pin the tight
        // margins; this is the serving-level integration)
        let reqs = requests(Scenario::Steady, 8 * 64, 13);
        let run = |tol: f64, t_max: usize| {
            let mut r = ServingRouter::new(
                Policy::BipBatch,
                RouterConfig {
                    // t_iters drives the fixed path (tol = 0);
                    // solver_t_max caps the adaptive one (tol > 0)
                    t_iters: t_max,
                    solver_tol: tol,
                    solver_t_max: t_max,
                    ..Default::default()
                },
            );
            for chunk in reqs.chunks(64) {
                let out = r.route_batch(chunk);
                let cap = r.batch_cap(64) as f32;
                for &load in &out.loads {
                    assert!(load <= cap, "load {load} > cap {cap}");
                }
            }
            r.balance.avg_max_vio()
        };
        let fixed = run(0.0, 16);
        let adaptive = run(0.1, 16);
        assert!(
            adaptive <= fixed + 0.1,
            "adaptive {adaptive} fixed {fixed}"
        );
    }
}
