//! Scenario-diverse synthetic request traffic for the serving stack.
//!
//! A [`TrafficGenerator`] is an iterator of timestamped [`Request`]s.
//! Both the arrival process and the expert-affinity profile are
//! scenario-driven, so one serving pipeline can be stressed with calm
//! steady load, Poisson bursts, diurnal ramps, adversarially *drifting*
//! expert skew (the worst case for stale balancer state), and
//! multi-tenant mixes where every tenant prefers different experts.
//! All randomness flows from a seeded [`Pcg64`]; a (config, seed) pair
//! reproduces the identical stream.

use crate::util::rng::Pcg64;

/// Virtual-time unit used across `serve/`: microseconds.
pub const US_PER_SEC: f64 = 1e6;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// Deterministic interarrivals at the mean rate, static mild skew.
    Steady,
    /// Markov-modulated Poisson: calm phases broken by 8x burst episodes.
    Bursty,
    /// Sinusoidal rate ramp — three full "days" over the run.
    Diurnal,
    /// Steady arrivals, but the strongly-preferred hot-expert set rotates
    /// through the run, invalidating whatever the balancer has learned.
    Adversarial,
    /// Poisson mix of tenants with Zipf-ish weights, each tenant with its
    /// own hot experts.
    MultiTenant,
    /// Flat affinity for the first third of the run, then traffic
    /// progressively concentrates onto a tiny expert set (a degraded /
    /// hot shard) — the planted routing-collapse signature the obs
    /// anomaly detector must flag early.
    Degraded,
    /// Steady mild skew, but the arrival rate surges 6x through the
    /// middle third of the run — a load anomaly that is NOT a routing
    /// collapse (the detector's false-positive discrimination case).
    FlashCrowd,
    /// A recorded request stream re-driven from a trace
    /// (`trace::replay`): never generated, so it is excluded from
    /// [`Scenario::all`] and rejected by [`TrafficGenerator::new`].
    Replayed,
}

impl Scenario {
    pub fn all() -> [Scenario; 7] {
        [
            Scenario::Steady,
            Scenario::Bursty,
            Scenario::Diurnal,
            Scenario::Adversarial,
            Scenario::MultiTenant,
            Scenario::Degraded,
            Scenario::FlashCrowd,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            Scenario::Steady => "steady",
            Scenario::Bursty => "bursty",
            Scenario::Diurnal => "diurnal",
            Scenario::Adversarial => "adversarial",
            Scenario::MultiTenant => "multitenant",
            Scenario::Degraded => "degraded",
            Scenario::FlashCrowd => "flashcrowd",
            Scenario::Replayed => "replayed",
        }
    }

    /// Case-insensitive, whitespace-tolerant. CLI surfaces that reject
    /// a `None` should list [`Scenario::names`] in the error.
    pub fn parse(s: &str) -> Option<Scenario> {
        match s.trim().to_ascii_lowercase().as_str() {
            "steady" => Some(Scenario::Steady),
            "bursty" | "burst" => Some(Scenario::Bursty),
            "diurnal" => Some(Scenario::Diurnal),
            "adversarial" | "adv" => Some(Scenario::Adversarial),
            "multitenant" | "multi-tenant" | "tenants" => {
                Some(Scenario::MultiTenant)
            }
            "degraded" | "degrade" => Some(Scenario::Degraded),
            "flashcrowd" | "flash-crowd" | "flash" => {
                Some(Scenario::FlashCrowd)
            }
            "replayed" | "replay" => Some(Scenario::Replayed),
            _ => None,
        }
    }

    /// The generative scenario names, for CLI error messages.
    pub fn names() -> Vec<&'static str> {
        Scenario::all().iter().map(|s| s.name()).collect()
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct TrafficConfig {
    pub scenario: Scenario,
    pub n_requests: usize,
    /// mean offered load, requests per second of virtual time
    pub rate_per_s: f64,
    pub n_layers: usize,
    pub m: usize,
    pub k: usize,
    pub n_tenants: usize,
    /// per-request latency SLO; deadline = arrival + slo
    pub slo_us: u64,
    /// per-logit Gaussian noise scale before the softmax (same
    /// convention as `Instance::synthetic`'s `temp`): larger = noisier
    /// per-token preferences around the scenario's fixed skew, NOT a
    /// softmax temperature
    pub temp: f64,
    /// strength of the scenario's expert-affinity skew
    pub skew: f64,
    pub seed: u64,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            scenario: Scenario::Steady,
            n_requests: 4096,
            rate_per_s: 100_000.0,
            n_layers: 4,
            m: 16,
            k: 4,
            n_tenants: 4,
            slo_us: 20_000,
            temp: 2.0,
            skew: 3.5,
            seed: 1,
        }
    }
}

/// One inference request: a token with per-layer router scores.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    pub tenant: u32,
    pub arrival_us: u64,
    pub deadline_us: u64,
    /// row-major (n_layers, m) softmax router scores
    pub scores: Vec<f32>,
}

impl Request {
    pub fn layer_scores(&self, layer: usize, m: usize) -> &[f32] {
        &self.scores[layer * m..(layer + 1) * m]
    }
}

pub struct TrafficGenerator {
    cfg: TrafficConfig,
    rng: Pcg64,
    clock_us: f64,
    emitted: usize,
    /// requests remaining in the current burst episode (Bursty)
    burst_left: u32,
    /// (n_tenants, n_layers, m) affinity logits
    affinity: Vec<f64>,
    /// tenant sampling weights (MultiTenant)
    tenant_w: Vec<f64>,
}

fn exp_sample(rng: &mut Pcg64) -> f64 {
    -(1.0 - rng.next_f64()).ln()
}

impl TrafficGenerator {
    pub fn new(cfg: TrafficConfig) -> TrafficGenerator {
        assert!(cfg.rate_per_s > 0.0 && cfg.m >= cfg.k && cfg.k >= 1);
        assert!(
            cfg.scenario != Scenario::Replayed,
            "Scenario::Replayed streams from a recorded trace \
             (trace::replay), not the generator"
        );
        let mut rng = Pcg64::with_stream(cfg.seed, 0x5e21);
        let t = cfg.n_tenants.max(1);
        let (l, m) = (cfg.n_layers, cfg.m);
        let mut affinity = vec![0.0f64; t * l * m];
        match cfg.scenario {
            // static linear skew shared by every tenant and layer — every
            // token prefers the low-index experts (the paper's hard case)
            Scenario::Steady
            | Scenario::Bursty
            | Scenario::Diurnal
            | Scenario::FlashCrowd => {
                for slot in affinity.chunks_mut(m) {
                    for (j, a) in slot.iter_mut().enumerate() {
                        *a = cfg.skew * (m - 1 - j) as f64
                            / (m - 1).max(1) as f64;
                    }
                }
            }
            // the hot set is injected per request (rotating for
            // Adversarial, progressively ramping for Degraded); the
            // base affinity stays flat
            Scenario::Adversarial | Scenario::Degraded => {}
            // each (tenant, layer) draws its own hot quarter of experts
            Scenario::MultiTenant => {
                let hot = (m / 4).max(1);
                for slot in affinity.chunks_mut(m) {
                    let mut order: Vec<usize> = (0..m).collect();
                    rng.shuffle(&mut order);
                    for &j in &order[..hot] {
                        slot[j] = cfg.skew;
                    }
                    for a in slot.iter_mut() {
                        *a += rng.normal() * 0.3;
                    }
                }
            }
            // LINT-ALLOW(panic): Replayed is rejected before dispatch
            Scenario::Replayed => unreachable!("rejected above"),
        }
        let tenant_w: Vec<f64> =
            (0..t).map(|i| 1.0 / (i + 1) as f64).collect();
        TrafficGenerator {
            cfg,
            rng,
            clock_us: 0.0,
            emitted: 0,
            burst_left: 0,
            affinity,
            tenant_w,
        }
    }

    pub fn config(&self) -> &TrafficConfig {
        &self.cfg
    }

    fn interarrival_us(&mut self) -> f64 {
        let base = US_PER_SEC / self.cfg.rate_per_s;
        match self.cfg.scenario {
            Scenario::Steady
            | Scenario::Adversarial
            | Scenario::Degraded => base,
            Scenario::FlashCrowd => {
                let n = self.cfg.n_requests.max(1);
                let mid = self.emitted >= n / 3
                    && self.emitted < 2 * n / 3;
                if mid {
                    base / 6.0
                } else {
                    base
                }
            }
            Scenario::Bursty => {
                if self.burst_left == 0 && self.rng.next_f64() < 0.02 {
                    self.burst_left = 64;
                }
                let mult = if self.burst_left > 0 {
                    self.burst_left -= 1;
                    8.0
                } else {
                    0.875
                };
                exp_sample(&mut self.rng) * base / mult
            }
            Scenario::Diurnal => {
                let period =
                    (self.cfg.n_requests as f64 * base / 3.0).max(base);
                let phase = self.clock_us / period
                    * std::f64::consts::TAU;
                let mult = 0.3 + 0.7 * (1.0 + phase.sin());
                exp_sample(&mut self.rng) * base / mult
            }
            Scenario::MultiTenant => exp_sample(&mut self.rng) * base,
            // LINT-ALLOW(panic): Replayed is rejected at construction
            Scenario::Replayed => unreachable!("rejected at construction"),
        }
    }

    fn pick_tenant(&mut self) -> usize {
        let t = self.cfg.n_tenants.max(1);
        match self.cfg.scenario {
            Scenario::MultiTenant => self.rng.weighted(&self.tenant_w),
            _ => self.emitted % t,
        }
    }

    /// Adversarial drift: which expert offset the hot quarter starts at
    /// for the current position in the stream (8 rotations per run).
    fn adversarial_phase(&self) -> usize {
        let n = self.cfg.n_requests.max(1);
        let hot = (self.cfg.m / 4).max(1);
        (self.emitted * 8 / n) * hot % self.cfg.m
    }

    /// Degraded-expert ramp: 0 for the first third of the stream, then
    /// a linear climb to full strength by the two-thirds mark. Applied
    /// to the first `m/8` experts, so traffic collapses onto exactly
    /// the top-K set the obs detector's concentration score watches.
    fn degraded_boost(&self) -> f64 {
        let n = self.cfg.n_requests.max(1);
        let third = (n / 3).max(1);
        if self.emitted < third {
            return 0.0;
        }
        let prog = (self.emitted - third) as f64 / third as f64;
        (self.cfg.skew + 2.0) * prog.min(1.0)
    }

    fn scores_for(&mut self, tenant: usize) -> Vec<f32> {
        let (l_count, m) = (self.cfg.n_layers, self.cfg.m);
        let adversarial = self.cfg.scenario == Scenario::Adversarial;
        let (phase, hot) = (self.adversarial_phase(), (m / 4).max(1));
        let deg_boost = if self.cfg.scenario == Scenario::Degraded {
            self.degraded_boost()
        } else {
            0.0
        };
        let deg_hot = (m / 8).max(1);
        let mut out = Vec::with_capacity(l_count * m);
        let mut logits = vec![0.0f64; m];
        for l in 0..l_count {
            let base = &self.affinity[(tenant * l_count + l) * m..][..m];
            for j in 0..m {
                let mut a = base[j];
                if adversarial && (j + m - phase) % m < hot {
                    a += self.cfg.skew + 2.0;
                }
                if j < deg_hot {
                    a += deg_boost;
                }
                logits[j] = self.rng.normal() * self.cfg.temp + a;
            }
            let maxv = logits.iter().cloned().fold(f64::MIN, f64::max);
            let mut total = 0.0;
            for x in logits.iter_mut() {
                *x = (*x - maxv).exp();
                total += *x;
            }
            for x in &logits {
                out.push((x / total) as f32);
            }
        }
        out
    }
}

impl Iterator for TrafficGenerator {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        if self.emitted >= self.cfg.n_requests {
            return None;
        }
        self.clock_us += self.interarrival_us();
        let tenant = self.pick_tenant();
        let scores = self.scores_for(tenant);
        let arrival_us = self.clock_us as u64;
        let req = Request {
            id: self.emitted as u64,
            tenant: tenant as u32,
            arrival_us,
            deadline_us: arrival_us + self.cfg.slo_us,
            scores,
        };
        self.emitted += 1;
        Some(req)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(scenario: Scenario) -> TrafficConfig {
        TrafficConfig { scenario, n_requests: 512, seed: 9, ..Default::default() }
    }

    #[test]
    fn deterministic_per_seed_and_ordered() {
        for scenario in Scenario::all() {
            let a: Vec<Request> =
                TrafficGenerator::new(cfg(scenario)).collect();
            let b: Vec<Request> =
                TrafficGenerator::new(cfg(scenario)).collect();
            assert_eq!(a.len(), 512);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.arrival_us, y.arrival_us);
                assert_eq!(x.scores, y.scores);
                assert_eq!(x.tenant, y.tenant);
            }
            for w in a.windows(2) {
                assert!(w[0].arrival_us <= w[1].arrival_us);
                assert!(w[0].id < w[1].id);
            }
            for r in &a {
                assert_eq!(r.deadline_us, r.arrival_us + 20_000);
            }
        }
    }

    #[test]
    fn scores_are_per_layer_softmax() {
        let gen = TrafficGenerator::new(cfg(Scenario::MultiTenant));
        for r in gen.take(16) {
            for l in 0..4 {
                let row = r.layer_scores(l, 16);
                let sum: f32 = row.iter().sum();
                assert!((sum - 1.0).abs() < 1e-4);
                assert!(row.iter().all(|&s| s >= 0.0));
            }
        }
    }

    #[test]
    fn adversarial_hot_set_rotates() {
        let reqs: Vec<Request> =
            TrafficGenerator::new(cfg(Scenario::Adversarial)).collect();
        let m = 16;
        let hot_expert = |r: &Request| -> usize {
            let row = r.layer_scores(0, m);
            (0..m).max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap())
                .unwrap()
        };
        // modal hot expert early vs late must differ (the set rotated)
        let mode = |rs: &[Request]| -> usize {
            let mut counts = vec![0usize; m];
            for r in rs {
                counts[hot_expert(r)] += 1;
            }
            (0..m).max_by_key(|&j| counts[j]).unwrap()
        };
        assert_ne!(mode(&reqs[..64]), mode(&reqs[448..]));
    }

    #[test]
    fn bursty_is_burstier_than_steady() {
        let gaps = |scenario| -> Vec<f64> {
            let reqs: Vec<Request> =
                TrafficGenerator::new(cfg(scenario)).collect();
            reqs.windows(2)
                .map(|w| (w[1].arrival_us - w[0].arrival_us) as f64)
                .collect()
        };
        let cv = |xs: &[f64]| -> f64 {
            let mean = xs.iter().sum::<f64>() / xs.len() as f64;
            let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
                / xs.len() as f64;
            var.sqrt() / mean
        };
        assert!(cv(&gaps(Scenario::Bursty)) > cv(&gaps(Scenario::Steady)) + 0.5);
    }

    #[test]
    fn replayed_is_parseable_but_never_generated() {
        assert_eq!(Scenario::parse("replayed"), Some(Scenario::Replayed));
        assert_eq!(Scenario::Replayed.name(), "replayed");
        // all() enumerates only the generative scenarios
        assert!(!Scenario::all().contains(&Scenario::Replayed));
    }

    #[test]
    fn parse_is_case_insensitive_and_trims() {
        assert_eq!(Scenario::parse("STEADY"), Some(Scenario::Steady));
        assert_eq!(Scenario::parse(" Bursty "), Some(Scenario::Bursty));
        assert_eq!(
            Scenario::parse("Multi-Tenant"),
            Some(Scenario::MultiTenant)
        );
        assert_eq!(Scenario::parse("warmup"), None);
        // every canonical name round-trips through parse
        for s in Scenario::all() {
            assert_eq!(Scenario::parse(s.name()), Some(s));
        }
        assert_eq!(
            Scenario::names(),
            vec!["steady", "bursty", "diurnal", "adversarial",
                 "multitenant", "degraded", "flashcrowd"]
        );
    }

    #[test]
    #[should_panic(expected = "recorded trace")]
    fn replayed_traffic_cannot_be_generated() {
        TrafficGenerator::new(cfg(Scenario::Replayed));
    }

    #[test]
    fn degraded_concentrates_late_but_not_early() {
        let reqs: Vec<Request> =
            TrafficGenerator::new(cfg(Scenario::Degraded)).collect();
        let m = 16;
        let deg_hot = m / 8; // the boosted expert set
        let hot_share = |rs: &[Request]| -> f64 {
            let on_hot = rs
                .iter()
                .filter(|r| {
                    let row = r.layer_scores(0, m);
                    let arg = (0..m)
                        .max_by(|&a, &b| {
                            row[a].partial_cmp(&row[b]).unwrap()
                        })
                        .unwrap();
                    arg < deg_hot
                })
                .count();
            on_hot as f64 / rs.len() as f64
        };
        // flat affinity early: argmax lands on the to-be-degraded set
        // at roughly its uniform share; late it dominates
        assert!(hot_share(&reqs[..128]) < 0.5, "early already hot");
        assert!(hot_share(&reqs[400..]) > 0.7, "late not collapsed");
    }

    #[test]
    fn flashcrowd_surges_through_the_middle_third() {
        let reqs: Vec<Request> =
            TrafficGenerator::new(cfg(Scenario::FlashCrowd)).collect();
        let mean_gap = |rs: &[Request]| -> f64 {
            rs.windows(2)
                .map(|w| (w[1].arrival_us - w[0].arrival_us) as f64)
                .sum::<f64>()
                / (rs.len() - 1) as f64
        };
        let early = mean_gap(&reqs[..160]);
        let mid = mean_gap(&reqs[176..336]);
        assert!(
            mid < early / 3.0,
            "middle third must arrive much faster: {mid} vs {early}"
        );
    }

    #[test]
    fn multitenant_prefers_heavy_tenants_and_varies_affinity() {
        let reqs: Vec<Request> =
            TrafficGenerator::new(cfg(Scenario::MultiTenant)).collect();
        let mut counts = vec![0usize; 4];
        for r in &reqs {
            counts[r.tenant as usize] += 1;
        }
        assert!(counts[0] > counts[3], "{counts:?}");
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
    }
}
