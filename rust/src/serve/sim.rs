//! End-to-end serving simulation: traffic → admission/micro-batching →
//! capacity-aware BIP routing → service-time model → SLO accounting.
//!
//! Virtual-time event loop with a single model server: the server
//! processes micro-batches sequentially; a batch's service time comes
//! from [`ServeCost`] (attention + expert-FFN straggler + all-to-all,
//! forward only), so imbalance — the hottest *device* under the current
//! placement — directly slows the batch down. Arrivals that find the
//! bounded queue full are rejected; queued requests whose deadline
//! passes before service are dropped. Everything is deterministic given
//! the traffic seed.

use crate::forecast::PredictiveAdmission;
use crate::obs::event::{self, EventKind};
use crate::obs::ObsController;
use crate::parallel::{DeviceProfile, Mesh, ModelCost, ServeCost};
use crate::prof::{Frame, ProfGuard};
use crate::routing::BalanceState;
use crate::telemetry::{self, Counter, Gauge};
use crate::trace::TraceRecorder;

use super::router::{Policy, RouterConfig, ServingRouter};
use super::scheduler::{MicroBatcher, SchedulerConfig};
use super::slo::{ServeReport, SloTracker};
use super::traffic::{Request, TrafficConfig, TrafficGenerator};

#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    pub traffic: TrafficConfig,
    pub sched: SchedulerConfig,
    pub router: RouterConfig,
    pub policy: Policy,
}

impl ServeConfig {
    /// Wire a consistent config: the router inherits the traffic's
    /// (m, k, n_layers) and sizes its stream-level gates to the run.
    pub fn new(
        traffic: TrafficConfig,
        sched: SchedulerConfig,
        mut router: RouterConfig,
        policy: Policy,
    ) -> ServeConfig {
        router.m = traffic.m;
        router.k = traffic.k;
        router.n_layers = traffic.n_layers;
        router.expected_stream = traffic.n_requests;
        ServeConfig { traffic, sched, router, policy }
    }
}

/// The service-time model both the single-server loop and the
/// replicated engine (`serve::replica`) price micro-batches with — one
/// constructor so their costs can never drift apart.
pub(crate) fn serve_cost_for(router: &RouterConfig) -> ServeCost {
    ServeCost::new(
        Mesh::new(router.n_devices, router.m),
        DeviceProfile::rtx4090(),
        ModelCost::paper_16e(),
    )
}

/// One served request, in completion order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Completion {
    pub id: u64,
    pub tenant: u32,
    pub arrival_us: u64,
    pub completion_us: u64,
}

pub struct ServeOutcome {
    pub report: ServeReport,
    /// completion log, in service order (for fairness/ordering checks)
    pub completions: Vec<Completion>,
    /// MaxVio of the first routed micro-batch (0.0 if nothing routed) —
    /// the from-the-first-step number the forecast warm start targets
    pub first_batch_vio: f64,
}

/// Run one (scenario, policy) serving simulation to completion.
pub fn run_scenario(cfg: &ServeConfig) -> ServeOutcome {
    run_scenario_hooked(
        cfg,
        TrafficGenerator::new(cfg.traffic.clone()),
        None,
        None,
        None,
        None,
    )
}

/// [`run_scenario`] with the observability controller attached: every
/// `tick_every` routed batches the controller scrapes the registry,
/// runs one anomaly-detector tick, and lets the flight recorder dump
/// an incident if a trigger fires ([`crate::obs`]).
pub fn run_scenario_observed(
    cfg: &ServeConfig,
    obs: &mut ObsController,
) -> ServeOutcome {
    run_scenario_hooked(
        cfg,
        TrafficGenerator::new(cfg.traffic.clone()),
        None,
        None,
        None,
        Some(obs),
    )
}

/// [`run_scenario`] over an explicit request source — the seam the
/// trace subsystem records and replays through. `source` is any
/// timestamp-ordered request iterator (a [`TrafficGenerator`], or a
/// recorded arrival stream); `recorder`, when present, captures the
/// offered stream, every routed frame, and the completion log
/// ([`crate::trace`]). With `recorder = None` this is exactly the
/// production path: no assignment buffers are allocated and no clones
/// are made.
pub fn run_scenario_with(
    cfg: &ServeConfig,
    source: impl Iterator<Item = Request>,
    recorder: Option<&mut TraceRecorder>,
) -> ServeOutcome {
    run_scenario_hooked(cfg, source, recorder, None, None, None)
}

/// [`run_scenario`] with every layer's balance state warm-started
/// before the first batch (forecast dual seeds via
/// `forecast::control::seed_states`, or a prior run's exported states).
pub fn run_scenario_seeded(
    cfg: &ServeConfig,
    seeds: &[BalanceState],
) -> ServeOutcome {
    run_scenario_hooked(
        cfg,
        TrafficGenerator::new(cfg.traffic.clone()),
        None,
        Some(seeds),
        None,
        None,
    )
}

/// [`run_scenario`] with forecast-gated admission (and optionally a
/// warm start): predicted-overload traffic is shed before it queues.
pub fn run_scenario_predictive(
    cfg: &ServeConfig,
    seeds: Option<&[BalanceState]>,
    admission: &mut PredictiveAdmission,
) -> ServeOutcome {
    run_scenario_hooked(
        cfg,
        TrafficGenerator::new(cfg.traffic.clone()),
        None,
        seeds,
        Some(admission),
        None,
    )
}

/// The one event loop behind every single-server entry point; the
/// hooks are all zero-cost when absent.
pub(crate) fn run_scenario_hooked(
    cfg: &ServeConfig,
    source: impl Iterator<Item = Request>,
    mut recorder: Option<&mut TraceRecorder>,
    seeds: Option<&[BalanceState]>,
    mut admission: Option<&mut PredictiveAdmission>,
    mut obs: Option<&mut ObsController>,
) -> ServeOutcome {
    // root profiler frame: declared first so it drops last and its
    // inclusive time covers the whole event loop + drain accounting
    let _prof_serve = ProfGuard::enter(Frame::Serve);
    let mut gen = source;
    let mut batcher = MicroBatcher::new(cfg.sched.clone());
    let mut router = ServingRouter::new(cfg.policy, cfg.router.clone());
    router.capture_assignments = recorder.is_some();
    if let Some(states) = seeds {
        router.seed_layers(states);
    }
    let serve_cost = serve_cost_for(&cfg.router);
    let mut slo = SloTracker::new(cfg.traffic.slo_us);
    let mut completions = Vec::new();
    let mut first_batch_vio: Option<f64> = None;
    // one outcome reused across the run: with no recorder attached the
    // routing hot path makes zero steady-state heap allocations
    let mut outcome = super::router::BatchOutcome::default();

    let mut now: u64 = 0;
    let mut server_free: u64 = 0;
    let mut next_arrival = gen.next();

    loop {
        // ingest every arrival due by `now`
        while next_arrival
            .as_ref()
            .map_or(false, |r| r.arrival_us <= now)
        {
            // LINT-ALLOW(panic): the loop condition just observed
            // Some(..)
            let req = next_arrival.take().unwrap();
            if let Some(rec) = recorder.as_deref_mut() {
                rec.record_arrival(&req);
            }
            // forecast-gated admission sheds ahead of the queue; the
            // shed still counts offered + rejected (work conservation)
            let shed = admission
                .as_deref_mut()
                .map_or(false, |a| !a.admit(req.arrival_us));
            if shed {
                batcher.shed();
                telemetry::counter_add(Counter::ServeShed, 1);
                event::record_event(EventKind::Shed, req.id, 0);
            } else {
                batcher.offer(req);
            }
            next_arrival = gen.next();
        }
        telemetry::gauge_set(
            Gauge::ServeQueueDepth,
            batcher.queue_len() as f64,
        );

        // serve: the single model server closes a batch when idle
        if now >= server_free && batcher.ready(now) {
            let batch = batcher.take_batch(now);
            if !batch.is_empty() {
                {
                    let _prof =
                        ProfGuard::enter(Frame::Dispatch);
                    router.route_batch_into(&batch, &mut outcome);
                }
                first_batch_vio.get_or_insert(outcome.batch_vio);
                let service_us = serve_cost
                    .batch_us(
                        &router.placement,
                        &outcome.loads,
                        cfg.router.m,
                    )
                    .max(1.0) as u64;
                server_free = now + service_us;
                if let Some(rec) = recorder.as_deref_mut() {
                    // consumes the outcome's assignment/load buffers
                    rec.record_frame(
                        0,
                        now,
                        service_us,
                        &batch,
                        &mut outcome,
                    );
                }
                for r in &batch {
                    slo.record(r.arrival_us, server_free, r.deadline_us);
                    completions.push(Completion {
                        id: r.id,
                        tenant: r.tenant,
                        arrival_us: r.arrival_us,
                        completion_us: server_free,
                    });
                }
                if let Some(o) = obs.as_deref_mut() {
                    o.on_batch();
                }
            }
            // re-evaluate immediately: the queue may hold another full
            // batch, or only expired requests that were just dropped
            continue;
        }

        // advance virtual time to the next event
        let mut t_next: Option<u64> = None;
        if now < server_free {
            t_next = Some(server_free);
        }
        if let Some(r) = &next_arrival {
            t_next =
                Some(t_next.map_or(r.arrival_us, |t| t.min(r.arrival_us)));
        }
        if now >= server_free {
            if let Some(flush) = batcher.flush_at() {
                t_next = Some(t_next.map_or(flush, |t| t.min(flush)));
            }
        }
        match t_next {
            // progress is guaranteed: every candidate lies in the future
            // (arrivals <= now were ingested; ready(now) was false, so
            // the flush timer is > now; server_free > now by the guard)
            Some(t) => now = t.max(now + 1),
            None => break, // no arrivals left, queue empty: done
        }
    }

    debug_assert!(batcher.conserves_work());
    let stats = batcher.stats;
    let horizon_s = slo.last_completion_us as f64 / 1e6;
    let report = ServeReport {
        scenario: cfg.traffic.scenario.name().to_string(),
        policy: router.policy().name().to_string(),
        offered: stats.offered,
        admitted: stats.admitted,
        rejected: stats.rejected,
        expired: stats.expired,
        completed: slo.completed,
        slo_violations: slo.violations,
        p50_ms: slo.latency_us(0.50) / 1e3,
        p95_ms: slo.latency_us(0.95) / 1e3,
        p99_ms: slo.latency_us(0.99) / 1e3,
        throughput_rps: slo.throughput_rps(),
        goodput_rps: slo.goodput_rps(),
        avg_max_vio: router.balance.avg_max_vio(),
        sup_max_vio: router.balance.sup_max_vio(),
        overflow: router.overflow_total,
        degraded: router.degraded_total,
        device_imbalance: router.imbalance.mean,
        state_bytes: router.state_bytes(),
        horizon_s,
    };
    if let Some(rec) = recorder.as_deref_mut() {
        rec.set_completions(&completions);
    }
    // final detector verdict at drain, so short runs still tick
    if let Some(o) = obs.as_deref_mut() {
        o.force_tick();
    }
    ServeOutcome {
        report,
        completions,
        first_batch_vio: first_batch_vio.unwrap_or(0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::traffic::Scenario;

    fn config(scenario: Scenario, policy: Policy) -> ServeConfig {
        ServeConfig::new(
            TrafficConfig {
                scenario,
                n_requests: 1024,
                seed: 11,
                ..Default::default()
            },
            SchedulerConfig::default(),
            RouterConfig::default(),
            policy,
        )
    }

    #[test]
    fn steady_run_completes_everything_and_is_deterministic() {
        let cfg = config(Scenario::Steady, Policy::Online);
        let a = run_scenario(&cfg);
        let b = run_scenario(&cfg);
        assert!(a.report.conserves_work());
        assert_eq!(a.report.offered, 1024);
        // moderate load: nothing rejected, nothing expired
        assert_eq!(a.report.rejected, 0);
        assert_eq!(a.report.completed, 1024);
        assert!(a.report.p50_ms > 0.0);
        assert!(a.report.p50_ms <= a.report.p95_ms);
        assert!(a.report.p95_ms <= a.report.p99_ms);
        assert!(a.report.throughput_rps > 0.0);
        assert_eq!(a.report.completed, b.report.completed);
        assert_eq!(a.report.p99_ms, b.report.p99_ms);
        assert_eq!(a.completions.len(), b.completions.len());
    }

    #[test]
    fn completions_never_reorder_within_a_tenant() {
        for policy in [Policy::Greedy, Policy::Approx] {
            let out =
                run_scenario(&config(Scenario::MultiTenant, policy));
            let mut last_id = vec![None::<u64>; 8];
            for c in &out.completions {
                let slot = &mut last_id[c.tenant as usize];
                if let Some(prev) = *slot {
                    assert!(
                        c.id > prev,
                        "tenant {} reordered: {} after {}",
                        c.tenant,
                        c.id,
                        prev
                    );
                }
                *slot = Some(c.id);
            }
        }
    }

    #[test]
    fn completion_times_are_causal_and_monotone() {
        let out = run_scenario(&config(Scenario::Bursty, Policy::BipBatch));
        let mut prev = 0u64;
        for c in &out.completions {
            assert!(c.completion_us > c.arrival_us);
            assert!(c.completion_us >= prev);
            prev = c.completion_us;
        }
    }

    #[test]
    fn noop_seeds_reproduce_the_unseeded_run_exactly() {
        use crate::routing::BalanceState;
        let cfg = config(Scenario::Bursty, Policy::Online);
        let plain = run_scenario(&cfg);
        let seeded = run_scenario_seeded(
            &cfg,
            &[BalanceState::None, BalanceState::None],
        );
        assert_eq!(plain.report.completed, seeded.report.completed);
        assert_eq!(plain.report.avg_max_vio, seeded.report.avg_max_vio);
        assert_eq!(plain.report.p99_ms, seeded.report.p99_ms);
        assert_eq!(plain.first_batch_vio, seeded.first_batch_vio);
        assert!(plain.first_batch_vio.is_finite());
    }

    #[test]
    fn predictive_admission_sheds_overload_and_conserves_work() {
        use crate::forecast::PredictiveAdmission;
        // heavy offered load against a deliberately tiny admitted
        // capacity: the gate must shed, and the books must balance
        let mut cfg = config(Scenario::Steady, Policy::Online);
        cfg.traffic.rate_per_s = 400_000.0;
        let mut adm = PredictiveAdmission::new(1_000, 50_000.0, 1.0);
        let out = run_scenario_predictive(&cfg, None, &mut adm);
        assert!(adm.shed > 0, "gate never shed under 8x overload");
        assert_eq!(out.report.offered, 1024);
        assert!(out.report.rejected >= adm.shed);
        assert!(out.report.conserves_work(), "{:?}", out.report);
        // calm traffic passes untouched
        let calm_cfg = config(Scenario::Steady, Policy::Online);
        let mut calm = PredictiveAdmission::new(1_000, 1e9, 1.0);
        let calm_out = run_scenario_predictive(&calm_cfg, None, &mut calm);
        assert_eq!(calm.shed, 0);
        assert_eq!(calm_out.report.completed, 1024);
    }
}
