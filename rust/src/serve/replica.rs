//! Replica-sharded, thread-parallel serving engine.
//!
//! A [`ReplicaSet`] owns R independent [`ServingRouter`] replicas — R
//! model servers, each with its own gates, capacity accounting and
//! placement — and a shared [`Pool`]. The virtual-time event loop
//! dispatches every ready micro-batch to the free replica with the
//! least cumulative dispatched work (deterministic tie-break on the
//! replica index), and batches dispatched at the same instant are
//! routed *concurrently* on the pool. Inside each routing job, the
//! Algorithm 1 per-batch dual update additionally chunks its p/q
//! phases onto the very same pool ([`DualState::update_parallel`]) —
//! the pool's help-while-wait discipline makes that nesting safe.
//!
//! Scale-out would wreck the paper's from-the-first-step balance claim
//! if each replica had to re-learn its gate state from its 1/R shard
//! of the traffic. The state every policy learns is tiny and mergeable
//! — Loss-Free's bias (Wang et al. 2024) and the BIP duals q are O(m)
//! vectors; Alg 3/4 add bounded order-statistic sketches — so every
//! `sync_every` dispatched batches the set reconciles:
//! [`ServingRouter::export_states`] from all replicas, one
//! deterministic [`ServingRouter::merge_states`] on each, leaving all
//! replicas with identical balance state. Each sync records the spread
//! of per-replica MaxVio and the dual/bias divergence before and after
//! the merge ([`SyncEvent`]), which is the evidence the replica sweep
//! in `bench_serving` reports.
//!
//! With R = 1 the loop reduces exactly to `sim::run_scenario` — pinned
//! bit-for-bit by the integration tests.

use std::sync::Arc;

use crate::forecast::{AutoScaler, ScaleEvent};
use crate::obs::event::{self, EventKind};
use crate::prof::{Frame, ProfGuard};
use crate::routing::BalanceState;
use crate::telemetry::{self, Counter, Gauge, Span, SpanKind};
use crate::trace::TraceRecorder;
use crate::util::pool::Pool;
use crate::util::stats::Summary;

use super::router::{BatchOutcome, ServingRouter};
use super::scheduler::MicroBatcher;
use super::sim::{serve_cost_for, Completion, ServeConfig};
use super::slo::{ReplicaSummary, ServeReport, SloTracker};
use super::traffic::{Request, TrafficGenerator};

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplicaConfig {
    /// independent router replicas (model servers)
    pub replicas: usize,
    /// shared worker-pool threads (batch-level + Alg 1 chunk-level)
    pub threads: usize,
    /// reconcile balance state every this many dispatched micro-batches
    /// across the set; 0 disables syncing
    pub sync_every: u64,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        ReplicaConfig { replicas: 1, threads: 1, sync_every: 16 }
    }
}

/// One balance-state reconciliation, with the divergence it erased.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SyncEvent {
    /// global dispatched-batch count when the sync fired
    pub at_batch: u64,
    /// spread (max − min) of per-replica mean MaxVio over the window
    /// since the previous sync, measured just before merging
    pub vio_spread_before: f64,
    /// the same spread over the window *after* this sync (filled at the
    /// next sync boundary, or at end of run for the last event)
    pub vio_spread_after: f64,
    /// mean abs deviation of the per-replica dual/bias vectors from
    /// their cross-replica mean, before the merge…
    pub state_div_before: f64,
    /// …and after it (0 up to f32 rounding: replicas leave identical)
    pub state_div_after: f64,
}

/// Everything a replicated run reports.
pub struct ReplicaOutcome {
    /// aggregate over the whole set, same shape as a single-server run
    pub report: ServeReport,
    pub per_replica: Vec<ReplicaSummary>,
    pub syncs: Vec<SyncEvent>,
    /// completion log in dispatch order (batches in flight on different
    /// replicas may *complete* out of order — expected at R > 1)
    pub completions: Vec<Completion>,
    /// total micro-batches dispatched across the set
    pub batches: u64,
    /// MaxVio of the first routed micro-batch (0.0 if nothing routed)
    pub first_batch_vio: f64,
    /// replica-count changes, when a `forecast::AutoScaler` drove the run
    pub scale_events: Vec<ScaleEvent>,
}

/// R routers + the shared pool + the sync bookkeeping.
pub struct ReplicaSet {
    routers: Vec<Option<ServingRouter>>,
    pool: Arc<Pool>,
    sync_every: u64,
    since_sync: u64,
    batches: u64,
    /// per-replica MaxVio accumulated since the last sync
    window: Vec<Summary>,
    pub syncs: Vec<SyncEvent>,
}

impl ReplicaSet {
    pub fn new(cfg: &ServeConfig, rcfg: &ReplicaConfig) -> ReplicaSet {
        let r = rcfg.replicas.max(1);
        let pool = Arc::new(Pool::new(rcfg.threads.max(1)));
        // each replica's stream-level gates (Alg 3/4) see ~1/R of the
        // request stream, so their capacity rate is sized to the shard
        let mut router_cfg = cfg.router.clone();
        router_cfg.expected_stream =
            (cfg.router.expected_stream / r).max(1);
        let routers = (0..r)
            .map(|_| {
                Some(ServingRouter::new_with_pool(
                    cfg.policy,
                    router_cfg.clone(),
                    Some(pool.clone()),
                ))
            })
            .collect();
        ReplicaSet {
            routers,
            pool,
            sync_every: rcfg.sync_every,
            since_sync: 0,
            batches: 0,
            window: vec![Summary::new(); r],
            syncs: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.routers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.routers.is_empty()
    }

    pub fn batches(&self) -> u64 {
        self.batches
    }

    fn router(&self, i: usize) -> &ServingRouter {
        // LINT-ALLOW(panic): routers are only taken inside
        // route_parallel, which checks every one back in before
        // returning
        self.routers[i].as_ref().expect("router checked in")
    }

    /// Enable per-token assignment capture on every replica (trace
    /// recording). Off by default: the production path allocates no
    /// assignment buffers.
    pub fn set_capture(&mut self, on: bool) {
        for r in self.routers.iter_mut() {
            if let Some(router) = r.as_mut() {
                router.capture_assignments = on;
            }
        }
    }

    /// Warm-start every replica's layers from the same per-layer seeds
    /// (forecast duals or a prior run's exports) — the replicated
    /// analogue of `ServingRouter::seed_layers`. Seeding every replica
    /// identically preserves the leave-syncs-identical invariant.
    pub fn seed_all(&mut self, seeds: &[BalanceState]) {
        for r in self.routers.iter_mut() {
            if let Some(router) = r.as_mut() {
                router.seed_layers(seeds);
            }
        }
    }

    /// Route one micro-batch per (replica, batch) pair concurrently on
    /// the shared pool, returning `(replica, service_us, batch,
    /// outcome)` in dispatch order. Routers move into the worker jobs and are
    /// checked back in before returning, so the set is always whole
    /// between calls; a periodic state sync fires here once
    /// `sync_every` dispatches have accumulated.
    fn route_parallel(
        &mut self,
        cost: &Arc<crate::parallel::ServeCost>,
        m: usize,
        dispatch: Vec<(usize, Vec<Request>)>,
    ) -> Vec<(usize, u64, Vec<Request>, BatchOutcome)> {
        let items: Vec<(usize, ServingRouter, Vec<Request>)> = dispatch
            .into_iter()
            .map(|(i, b)| {
                // LINT-ALLOW(panic): dispatch indices are distinct,
                // so each router is taken at most once per call
                (i, self.routers[i].take().expect("free replica"), b)
            })
            .collect();
        let cost = cost.clone();
        let routed = self.pool.map(items, move |(i, mut router, batch)| {
            // per-replica dispatch latency, measured on the worker
            // thread (exercises the registry's shard-per-thread path)
            let span = Span::enter(SpanKind::ReplicaDispatch);
            // worker threads have their own TLS frame stack, so
            // Dispatch is their root frame; the scrape merges shards
            let prof = ProfGuard::enter(Frame::Dispatch);
            // tag the worker thread before routing so every event the
            // batch drops (BatchStart .. BatchDone) carries replica i
            event::set_replica_ctx(i);
            let outcome = router.route_batch(&batch);
            let service_us = cost
                .batch_us(&router.placement, &outcome.loads, m)
                .max(1.0) as u64;
            event::record_ctx_event(EventKind::Dispatch, service_us);
            drop(prof);
            drop(span);
            (i, router, batch, outcome, service_us)
        });
        telemetry::counter_add(
            Counter::ReplicaDispatches,
            routed.len() as u64,
        );
        let mut out = Vec::with_capacity(routed.len());
        for (i, router, batch, outcome, service_us) in routed {
            self.routers[i] = Some(router);
            self.window[i].push(outcome.batch_vio);
            self.batches += 1;
            self.since_sync += 1;
            out.push((i, service_us, batch, outcome));
        }
        if self.routers.len() > 1
            && self.sync_every > 0
            && self.since_sync >= self.sync_every
        {
            self.since_sync = 0;
            self.sync();
        }
        out
    }

    /// Reconcile balance state across replicas: export everyone, merge
    /// the identical slice into everyone, record the divergence erased.
    fn sync(&mut self) {
        let _prof = ProfGuard::enter(Frame::MergeSync);
        let spread = window_spread(&self.window);
        if let Some(prev) = self.syncs.last_mut() {
            prev.vio_spread_after = spread;
        }
        let states: Vec<Vec<BalanceState>> = self
            .routers
            .iter()
            // LINT-ALLOW(panic): sync runs between route_parallel
            // calls, when every router is checked back in
            .map(|r| r.as_ref().expect("checked in").export_states())
            .collect();
        let div_before = state_divergence(&states);
        event::record_event(
            EventKind::Sync,
            self.syncs.len() as u64,
            f64::to_bits(div_before),
        );
        for r in self.routers.iter_mut() {
            // LINT-ALLOW(panic): same invariant as the export above
            r.as_mut().expect("checked in").merge_states(&states);
        }
        let after: Vec<Vec<BalanceState>> = self
            .routers
            .iter()
            // LINT-ALLOW(panic): same invariant as the export above
            .map(|r| r.as_ref().expect("checked in").export_states())
            .collect();
        self.syncs.push(SyncEvent {
            at_batch: self.batches,
            vio_spread_before: spread,
            vio_spread_after: 0.0,
            state_div_before: div_before,
            state_div_after: state_divergence(&after),
        });
        telemetry::counter_add(Counter::ReplicaSyncs, 1);
        telemetry::gauge_set(
            Gauge::ReplicaLastSyncDivergence,
            div_before,
        );
        for w in self.window.iter_mut() {
            *w = Summary::new();
        }
    }

    /// Close the MaxVio window of the final sync event at end of run.
    fn finish(&mut self) {
        if let Some(prev) = self.syncs.last_mut() {
            prev.vio_spread_after = window_spread(&self.window);
        }
    }
}

/// Spread (max − min) of per-replica window-mean MaxVio; 0 unless at
/// least two replicas routed something in the window.
fn window_spread(window: &[Summary]) -> f64 {
    let means: Vec<f64> = window
        .iter()
        .filter(|s| s.n > 0)
        .map(|s| s.mean)
        .collect();
    if means.len() < 2 {
        return 0.0;
    }
    let max = means.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let min = means.iter().cloned().fold(f64::INFINITY, f64::min);
    max - min
}

/// Mean abs deviation of every replica's per-layer dual/bias vector
/// from the cross-replica mean vector, averaged over layers, replicas
/// and components. 0 when no policy state is exported (greedy).
fn state_divergence(states: &[Vec<BalanceState>]) -> f64 {
    if states.is_empty() {
        return 0.0;
    }
    // LINT-ALLOW(panic): the is_empty early-return above proves
    // states[0] exists
    let layers = states[0].len();
    let mut dev_sum = 0.0f64;
    let mut dev_n = 0u64;
    for l in 0..layers {
        let vecs: Vec<&[f32]> = states
            .iter()
            .filter_map(|r| r.get(l).and_then(|s| s.primary()))
            .collect();
        if vecs.len() < 2 {
            continue;
        }
        // LINT-ALLOW(panic): the len < 2 guard above proves vecs[0]
        // exists
        let len = vecs[0].len();
        if vecs.iter().any(|v| v.len() != len) {
            continue;
        }
        for j in 0..len {
            let mean = vecs.iter().map(|v| v[j] as f64).sum::<f64>()
                / vecs.len() as f64;
            for v in &vecs {
                dev_sum += (v[j] as f64 - mean).abs();
                dev_n += 1;
            }
        }
    }
    if dev_n == 0 {
        0.0
    } else {
        dev_sum / dev_n as f64
    }
}

/// Run one (scenario, policy) simulation on R replicas to completion.
///
/// Same virtual-time semantics as [`super::sim::run_scenario`], with R
/// servers: arrivals feed one admission-controlled queue; every ready
/// micro-batch goes to the free replica with the least cumulative
/// work; concurrent dispatches route in parallel on the shared pool.
pub fn run_replicated(
    cfg: &ServeConfig,
    rcfg: &ReplicaConfig,
) -> ReplicaOutcome {
    run_replicated_hooked(
        cfg,
        rcfg,
        TrafficGenerator::new(cfg.traffic.clone()),
        None,
        None,
        None,
    )
}

/// [`run_replicated`] over an explicit request source — the trace
/// subsystem's record/replay seam (see [`super::sim::run_scenario_with`]
/// for the single-server analogue). When `recorder` is present, every
/// routed frame is tagged with its replica and the merge-sync events
/// are recorded alongside the completion log.
pub fn run_replicated_with(
    cfg: &ServeConfig,
    rcfg: &ReplicaConfig,
    source: impl Iterator<Item = Request>,
    recorder: Option<&mut TraceRecorder>,
) -> ReplicaOutcome {
    run_replicated_hooked(cfg, rcfg, source, recorder, None, None)
}

/// [`run_replicated`] with every replica warm-started from the same
/// per-layer forecast seeds before the first dispatch.
pub fn run_replicated_seeded(
    cfg: &ServeConfig,
    rcfg: &ReplicaConfig,
    seeds: &[BalanceState],
) -> ReplicaOutcome {
    run_replicated_hooked(
        cfg,
        rcfg,
        TrafficGenerator::new(cfg.traffic.clone()),
        None,
        Some(seeds),
        None,
    )
}

/// [`run_replicated`] under a `forecast::AutoScaler`: `rcfg.replicas`
/// replicas exist, but each dispatch only considers the scaler's
/// currently *active* prefix, so predicted load ramps grow the set
/// ahead of demand and calm windows shrink it. Scale-downs drain
/// gracefully — a deactivated replica finishes its batch in flight and
/// simply stops receiving work (its balance state stays mergeable, so
/// a later scale-up rejoins warm). Optionally warm-started.
pub fn run_autoscaled(
    cfg: &ServeConfig,
    rcfg: &ReplicaConfig,
    seeds: Option<&[BalanceState]>,
    scaler: &mut AutoScaler,
) -> ReplicaOutcome {
    run_replicated_hooked(
        cfg,
        rcfg,
        TrafficGenerator::new(cfg.traffic.clone()),
        None,
        seeds,
        Some(scaler),
    )
}

/// The one event loop behind every replicated entry point.
fn run_replicated_hooked(
    cfg: &ServeConfig,
    rcfg: &ReplicaConfig,
    source: impl Iterator<Item = Request>,
    mut recorder: Option<&mut TraceRecorder>,
    seeds: Option<&[BalanceState]>,
    mut scaler: Option<&mut AutoScaler>,
) -> ReplicaOutcome {
    let r = rcfg.replicas.max(1);
    let mut set = ReplicaSet::new(cfg, rcfg);
    set.set_capture(recorder.is_some());
    if let Some(states) = seeds {
        set.seed_all(states);
    }
    let serve_cost = Arc::new(serve_cost_for(&cfg.router));
    let m = cfg.router.m;

    let mut gen = source;
    let mut batcher = MicroBatcher::new(cfg.sched.clone());
    let mut slo = SloTracker::new(cfg.traffic.slo_us);
    let mut completions = Vec::new();

    let mut now: u64 = 0;
    let mut server_free = vec![0u64; r];
    let mut work_us = vec![0u64; r];
    let mut served_reqs = vec![0u64; r];
    let mut first_batch_vio: Option<f64> = None;
    let mut next_arrival = gen.next();

    loop {
        // ingest every arrival due by `now`
        while next_arrival
            .as_ref()
            .map_or(false, |req| req.arrival_us <= now)
        {
            // LINT-ALLOW(panic): the loop condition just observed
            // Some(..)
            let req = next_arrival.take().unwrap();
            if let Some(rec) = recorder.as_deref_mut() {
                rec.record_arrival(&req);
            }
            if let Some(sc) = scaler.as_deref_mut() {
                sc.on_arrival(req.arrival_us);
            }
            batcher.offer(req);
            next_arrival = gen.next();
        }

        // dispatch: each ready batch to the free replica with the least
        // cumulative dispatched work (tie -> lowest index), considering
        // only the autoscaler's active prefix when one drives the run
        let active =
            scaler.as_deref().map_or(r, |sc| sc.active().min(r));
        if scaler.is_some() {
            telemetry::gauge_set(
                Gauge::AutoscaleReplicas,
                active as f64,
            );
        }
        let mut dispatch: Vec<(usize, Vec<Request>)> = Vec::new();
        loop {
            if !batcher.ready(now) {
                break;
            }
            let mut target: Option<usize> = None;
            for i in 0..active {
                if now >= server_free[i]
                    && !dispatch.iter().any(|d| d.0 == i)
                {
                    let better = match target {
                        None => true,
                        Some(b) => work_us[i] < work_us[b],
                    };
                    if better {
                        target = Some(i);
                    }
                }
            }
            let Some(i) = target else { break };
            let batch = batcher.take_batch(now);
            if batch.is_empty() {
                // the queue held only expired requests; they were
                // dropped and counted — re-evaluate
                continue;
            }
            dispatch.push((i, batch));
        }

        if !dispatch.is_empty() {
            for (i, service_us, batch, mut outcome) in
                set.route_parallel(&serve_cost, m, dispatch)
            {
                first_batch_vio.get_or_insert(outcome.batch_vio);
                server_free[i] = now + service_us;
                work_us[i] += service_us;
                served_reqs[i] += batch.len() as u64;
                if let Some(rec) = recorder.as_deref_mut() {
                    // consumes the outcome's assignment/load buffers
                    rec.record_frame(
                        i,
                        now,
                        service_us,
                        &batch,
                        &mut outcome,
                    );
                }
                for req in &batch {
                    slo.record(
                        req.arrival_us,
                        server_free[i],
                        req.deadline_us,
                    );
                    completions.push(Completion {
                        id: req.id,
                        tenant: req.tenant,
                        arrival_us: req.arrival_us,
                        completion_us: server_free[i],
                    });
                }
            }
            // re-evaluate immediately: the queue may hold more ready
            // batches for replicas still free at `now`
            continue;
        }

        // advance virtual time to the next event
        let mut t_next: Option<u64> = None;
        if let Some(t) = server_free
            .iter()
            .copied()
            .filter(|&t| t > now)
            .min()
        {
            t_next = Some(t);
        }
        if let Some(req) = &next_arrival {
            t_next = Some(
                t_next.map_or(req.arrival_us, |t| t.min(req.arrival_us)),
            );
        }
        // only a free *active* replica can act on a flush — waking for
        // an idle deactivated one would busy-step the clock instead
        if server_free[..active].iter().any(|&t| now >= t) {
            if let Some(flush) = batcher.flush_at() {
                t_next = Some(t_next.map_or(flush, |t| t.min(flush)));
            }
        }
        match t_next {
            // progress is guaranteed: every candidate lies in the
            // future (same argument as the single-server loop)
            Some(t) => now = t.max(now + 1),
            None => break, // no arrivals left, queue empty: done
        }
    }
    set.finish();
    if let Some(sc) = scaler.as_deref_mut() {
        sc.finish();
    }

    debug_assert!(batcher.conserves_work());
    let stats = batcher.stats;
    let horizon_s = slo.last_completion_us as f64 / 1e6;

    // aggregate balance across replicas, weighted by batches routed
    let mut vio_wsum = 0.0f64;
    let mut imb_wsum = 0.0f64;
    let mut batches_total = 0u64;
    let mut sup = f64::NEG_INFINITY;
    let mut overflow = 0u64;
    let mut degraded = 0u64;
    let mut state_bytes = 0usize;
    let mut per_replica = Vec::with_capacity(r);
    for i in 0..r {
        let router = set.router(i);
        let b = router.balance.batches();
        batches_total += b;
        vio_wsum += router.balance.avg_max_vio() * b as f64;
        imb_wsum += router.imbalance.mean * router.imbalance.n as f64;
        sup = sup.max(router.balance.sup_max_vio());
        overflow += router.overflow_total;
        degraded += router.degraded_total;
        state_bytes += router.state_bytes();
    }
    for i in 0..r {
        let router = set.router(i);
        per_replica.push(ReplicaSummary {
            replica: i,
            batches: router.balance.batches(),
            served: served_reqs[i],
            avg_max_vio: router.balance.avg_max_vio(),
            sup_max_vio: router.balance.sup_max_vio(),
            overflow: router.overflow_total,
            degraded: router.degraded_total,
            state_bytes: router.state_bytes(),
            busy_us: work_us[i],
        });
    }
    let report = ServeReport {
        scenario: cfg.traffic.scenario.name().to_string(),
        policy: set.router(0).policy().name().to_string(),
        offered: stats.offered,
        admitted: stats.admitted,
        rejected: stats.rejected,
        expired: stats.expired,
        completed: slo.completed,
        slo_violations: slo.violations,
        p50_ms: slo.latency_us(0.50) / 1e3,
        p95_ms: slo.latency_us(0.95) / 1e3,
        p99_ms: slo.latency_us(0.99) / 1e3,
        throughput_rps: slo.throughput_rps(),
        goodput_rps: slo.goodput_rps(),
        // r == 1 takes the router's own mean directly: the weighted
        // form (mean·b)/b is not a bitwise identity in f64, and the
        // R = 1 path must reproduce run_scenario exactly
        avg_max_vio: if r == 1 {
            set.router(0).balance.avg_max_vio()
        } else if batches_total > 0 {
            vio_wsum / batches_total as f64
        } else {
            0.0
        },
        sup_max_vio: if r == 1 {
            set.router(0).balance.sup_max_vio()
        } else if batches_total > 0 {
            sup
        } else {
            0.0
        },
        overflow,
        degraded,
        device_imbalance: if r == 1 {
            set.router(0).imbalance.mean
        } else if batches_total > 0 {
            imb_wsum / batches_total as f64
        } else {
            0.0
        },
        state_bytes,
        horizon_s,
    };
    if let Some(rec) = recorder.as_deref_mut() {
        rec.set_syncs(&set.syncs);
        rec.set_completions(&completions);
    }
    ReplicaOutcome {
        report,
        per_replica,
        syncs: set.syncs.clone(),
        completions,
        batches: set.batches(),
        first_batch_vio: first_batch_vio.unwrap_or(0.0),
        scale_events: scaler
            .as_deref()
            .map(|sc| sc.events.clone())
            .unwrap_or_default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::router::{Policy, RouterConfig};
    use crate::serve::scheduler::SchedulerConfig;
    use crate::serve::traffic::{Scenario, TrafficConfig};

    fn config(scenario: Scenario, policy: Policy) -> ServeConfig {
        ServeConfig::new(
            TrafficConfig {
                scenario,
                n_requests: 2_000,
                rate_per_s: 120_000.0,
                n_layers: 2,
                seed: 9,
                ..Default::default()
            },
            SchedulerConfig::default(),
            RouterConfig::default(),
            policy,
        )
    }

    #[test]
    fn replicated_run_conserves_work() {
        for policy in [Policy::Greedy, Policy::Online, Policy::BipBatch] {
            let cfg = config(Scenario::Bursty, policy);
            let rcfg = ReplicaConfig {
                replicas: 3,
                threads: 2,
                sync_every: 8,
            };
            let out = run_replicated(&cfg, &rcfg);
            assert!(
                out.report.conserves_work(),
                "{policy:?}: {:?}",
                out.report
            );
            assert_eq!(
                out.report.completed,
                out.completions.len() as u64
            );
            assert_eq!(
                out.batches,
                out.per_replica.iter().map(|p| p.batches).sum::<u64>()
            );
            // every replica took a share of a 2k-request stream
            for p in &out.per_replica {
                assert!(p.batches > 0, "replica {} starved", p.replica);
            }
        }
    }

    #[test]
    fn replicated_run_is_deterministic() {
        let cfg = config(Scenario::MultiTenant, Policy::Online);
        let rcfg =
            ReplicaConfig { replicas: 4, threads: 3, sync_every: 8 };
        let a = run_replicated(&cfg, &rcfg);
        let b = run_replicated(&cfg, &rcfg);
        assert_eq!(a.report.completed, b.report.completed);
        assert_eq!(a.report.p99_ms, b.report.p99_ms);
        assert_eq!(a.report.avg_max_vio, b.report.avg_max_vio);
        assert_eq!(a.batches, b.batches);
        assert_eq!(a.syncs.len(), b.syncs.len());
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.completion_us, y.completion_us);
        }
    }

    #[test]
    fn syncs_fire_and_erase_state_divergence() {
        let cfg = config(Scenario::Bursty, Policy::LossFree);
        let rcfg =
            ReplicaConfig { replicas: 4, threads: 2, sync_every: 4 };
        let out = run_replicated(&cfg, &rcfg);
        assert!(!out.syncs.is_empty(), "sync_every=4 must fire");
        for s in &out.syncs {
            assert!(s.state_div_before.is_finite());
            assert!(
                s.state_div_after <= 1e-6,
                "merge must leave replicas identical, got {}",
                s.state_div_after
            );
        }
        // replicas genuinely diverge between syncs (different shards)
        assert!(
            out.syncs.iter().any(|s| s.state_div_before > 0.0),
            "expected nonzero divergence before some sync"
        );
    }

    #[test]
    fn sync_every_zero_never_syncs() {
        let cfg = config(Scenario::Steady, Policy::BipBatch);
        let rcfg =
            ReplicaConfig { replicas: 2, threads: 2, sync_every: 0 };
        let out = run_replicated(&cfg, &rcfg);
        assert!(out.syncs.is_empty());
        assert!(out.report.conserves_work());
    }

    #[test]
    fn noop_seeds_reproduce_the_replicated_run_exactly() {
        let cfg = config(Scenario::Bursty, Policy::Online);
        let rcfg =
            ReplicaConfig { replicas: 3, threads: 2, sync_every: 8 };
        let plain = run_replicated(&cfg, &rcfg);
        let seeds = vec![BalanceState::None; cfg.router.n_layers];
        let seeded = run_replicated_seeded(&cfg, &rcfg, &seeds);
        assert_eq!(plain.report.completed, seeded.report.completed);
        assert_eq!(plain.report.avg_max_vio, seeded.report.avg_max_vio);
        assert_eq!(plain.report.p99_ms, seeded.report.p99_ms);
        assert_eq!(plain.first_batch_vio, seeded.first_batch_vio);
        assert!(plain.scale_events.is_empty());
    }

    #[test]
    fn autoscaled_run_conserves_work_and_stays_in_bounds() {
        use crate::forecast::{AutoScaler, ScalePolicy};
        let cfg = config(Scenario::Bursty, Policy::Online);
        let rcfg =
            ReplicaConfig { replicas: 4, threads: 2, sync_every: 8 };
        let run = |policy| {
            let mut sc = AutoScaler::new(
                policy, 2_000, 45_000.0, 0.9, 1, 4,
            );
            let out = run_autoscaled(&cfg, &rcfg, None, &mut sc);
            (out, sc)
        };
        for policy in [ScalePolicy::Predictive, ScalePolicy::Reactive] {
            let (out, sc) = run(policy);
            assert!(
                out.report.conserves_work(),
                "{policy:?}: {:?}",
                out.report
            );
            assert_eq!(out.report.offered, 2_000, "{policy:?}");
            for e in &out.scale_events {
                assert!(e.to >= 1 && e.to <= 4, "{policy:?} {e:?}");
                assert_ne!(e.from, e.to);
            }
            assert_eq!(out.scale_events.len(), sc.events.len());
            let rate = sc.oracle_match_rate();
            assert!((0.0..=1.0).contains(&rate), "{policy:?} {rate}");
            // bursty at 120k rps against 45k-rps replicas must need
            // more than the 1-replica floor at least once
            assert!(
                sc.events.iter().any(|e| e.to > 1)
                    || sc.windows.iter().all(|w| w.active == 1),
                "{policy:?}"
            );
            // deterministic: a fresh scaler reproduces the run
            let (again, _) = run(policy);
            assert_eq!(out.report.completed, again.report.completed);
            assert_eq!(out.report.p99_ms, again.report.p99_ms);
            assert_eq!(out.scale_events, again.scale_events);
        }
    }
}
