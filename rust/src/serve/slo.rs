//! SLO accounting for the serving stack: latency percentiles,
//! throughput/goodput, and the balance + capacity counters that tie the
//! report back to the paper's MaxVio metric.

use crate::util::json::Json;
use crate::util::stats::quantile;

/// Collects per-request latencies and deadline outcomes.
#[derive(Clone, Debug, Default)]
pub struct SloTracker {
    pub slo_us: u64,
    latencies_us: Vec<f64>,
    pub completed: u64,
    /// completed, but after the deadline
    pub violations: u64,
    pub last_completion_us: u64,
}

impl SloTracker {
    pub fn new(slo_us: u64) -> SloTracker {
        SloTracker { slo_us, ..Default::default() }
    }

    pub fn record(
        &mut self,
        arrival_us: u64,
        completion_us: u64,
        deadline_us: u64,
    ) {
        self.latencies_us
            .push(completion_us.saturating_sub(arrival_us) as f64);
        self.completed += 1;
        if completion_us > deadline_us {
            self.violations += 1;
        }
        self.last_completion_us = self.last_completion_us.max(completion_us);
    }

    /// Latency quantile in microseconds (0.0 when nothing completed).
    pub fn latency_us(&self, q: f64) -> f64 {
        if self.latencies_us.is_empty() {
            0.0
        } else {
            quantile(&self.latencies_us, q)
        }
    }

    /// Completed requests per second of virtual time.
    pub fn throughput_rps(&self) -> f64 {
        if self.last_completion_us == 0 {
            0.0
        } else {
            self.completed as f64
                / (self.last_completion_us as f64 / 1e6)
        }
    }

    /// Requests completed *within* their deadline, per second.
    pub fn goodput_rps(&self) -> f64 {
        if self.last_completion_us == 0 {
            0.0
        } else {
            (self.completed - self.violations) as f64
                / (self.last_completion_us as f64 / 1e6)
        }
    }
}

/// Everything one (scenario, policy) serving run reports.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub scenario: String,
    pub policy: String,
    pub offered: u64,
    pub admitted: u64,
    pub rejected: u64,
    pub expired: u64,
    pub completed: u64,
    pub slo_violations: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub throughput_rps: f64,
    pub goodput_rps: f64,
    /// AvgMaxVio / SupMaxVio over micro-batches (mean over layers)
    pub avg_max_vio: f64,
    pub sup_max_vio: f64,
    pub overflow: u64,
    pub degraded: u64,
    pub device_imbalance: f64,
    pub state_bytes: usize,
    pub horizon_s: f64,
}

impl ServeReport {
    pub fn headers() -> &'static [&'static str] {
        &[
            "Policy", "Done", "Drop", "p50ms", "p95ms", "p99ms",
            "Req/s", "AvgMaxVio", "SupMaxVio", "Overflow", "DevImb",
            "StateKB",
        ]
    }

    pub fn table_row(&self) -> Vec<String> {
        vec![
            self.policy.clone(),
            format!("{}", self.completed),
            format!("{}", self.rejected + self.expired),
            format!("{:.2}", self.p50_ms),
            format!("{:.2}", self.p95_ms),
            format!("{:.2}", self.p99_ms),
            format!("{:.0}", self.throughput_rps),
            format!("{:.4}", self.avg_max_vio),
            format!("{:.4}", self.sup_max_vio),
            format!("{}", self.overflow),
            format!("{:.3}", self.device_imbalance),
            format!("{:.1}", self.state_bytes as f64 / 1024.0),
        ]
    }

    /// `admitted = completed + expired` — nothing vanishes in flight.
    pub fn conserves_work(&self) -> bool {
        self.offered == self.admitted + self.rejected
            && self.admitted == self.completed + self.expired
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", Json::Str(self.scenario.clone())),
            ("policy", Json::Str(self.policy.clone())),
            ("offered", Json::Num(self.offered as f64)),
            ("admitted", Json::Num(self.admitted as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("expired", Json::Num(self.expired as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("slo_violations", Json::Num(self.slo_violations as f64)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p95_ms", Json::Num(self.p95_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            ("goodput_rps", Json::Num(self.goodput_rps)),
            ("avg_max_vio", Json::Num(self.avg_max_vio)),
            ("sup_max_vio", Json::Num(self.sup_max_vio)),
            ("overflow", Json::Num(self.overflow as f64)),
            ("degraded", Json::Num(self.degraded as f64)),
            ("device_imbalance", Json::Num(self.device_imbalance)),
            ("state_bytes", Json::Num(self.state_bytes as f64)),
            ("horizon_s", Json::Num(self.horizon_s)),
        ])
    }
}

/// Per-replica accounting for the replicated serving engine
/// (`serve::replica::run_replicated`): each replica's share of the
/// stream plus its own balance quality, so divergence across replicas
/// is visible next to the aggregate [`ServeReport`].
#[derive(Clone, Debug)]
pub struct ReplicaSummary {
    pub replica: usize,
    /// micro-batches this replica routed
    pub batches: u64,
    /// requests this replica served
    pub served: u64,
    pub avg_max_vio: f64,
    pub sup_max_vio: f64,
    pub overflow: u64,
    pub degraded: u64,
    pub state_bytes: usize,
    /// virtual time this replica spent serving, microseconds
    pub busy_us: u64,
}

impl ReplicaSummary {
    pub fn headers() -> &'static [&'static str] {
        &[
            "Replica", "Batches", "Served", "AvgMaxVio", "SupMaxVio",
            "Overflow", "StateKB", "BusyMs",
        ]
    }

    pub fn table_row(&self) -> Vec<String> {
        vec![
            format!("{}", self.replica),
            format!("{}", self.batches),
            format!("{}", self.served),
            format!("{:.4}", self.avg_max_vio),
            format!("{:.4}", self.sup_max_vio),
            format!("{}", self.overflow),
            format!("{:.1}", self.state_bytes as f64 / 1024.0),
            format!("{:.2}", self.busy_us as f64 / 1e3),
        ]
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("replica", Json::Num(self.replica as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("served", Json::Num(self.served as f64)),
            ("avg_max_vio", Json::Num(self.avg_max_vio)),
            ("sup_max_vio", Json::Num(self.sup_max_vio)),
            ("overflow", Json::Num(self.overflow as f64)),
            ("degraded", Json::Num(self.degraded as f64)),
            ("state_bytes", Json::Num(self.state_bytes as f64)),
            ("busy_us", Json::Num(self.busy_us as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replica_summary_rows_align_with_headers() {
        let r = ReplicaSummary {
            replica: 2,
            batches: 10,
            served: 640,
            avg_max_vio: 0.2,
            sup_max_vio: 0.9,
            overflow: 3,
            degraded: 0,
            state_bytes: 4096,
            busy_us: 12_000,
        };
        assert_eq!(r.table_row().len(), ReplicaSummary::headers().len());
        let j = r.to_json();
        assert_eq!(j.path("served").unwrap().as_usize(), Some(640));
        assert_eq!(j.path("replica").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn tracker_percentiles_and_rates() {
        let mut t = SloTracker::new(1_000);
        // 100 requests, latencies 1..=100 us, arrivals at 0
        for i in 1..=100u64 {
            t.record(0, i, 1_000);
        }
        assert_eq!(t.completed, 100);
        assert_eq!(t.violations, 0);
        assert!((t.latency_us(0.5) - 50.5).abs() < 1e-9);
        assert!(t.latency_us(0.99) > 98.0);
        // horizon = last completion = 100us -> 100 / 1e-4 s = 1e6 req/s
        assert!((t.throughput_rps() - 1e6).abs() < 1.0);
        assert_eq!(t.throughput_rps(), t.goodput_rps());
    }

    #[test]
    fn deadline_violations_split_goodput() {
        let mut t = SloTracker::new(10);
        t.record(0, 5, 10); // in time
        t.record(0, 50, 10); // violated
        assert_eq!(t.violations, 1);
        assert!(t.goodput_rps() < t.throughput_rps());
    }

    #[test]
    fn empty_tracker_is_quiet() {
        let t = SloTracker::new(10);
        assert_eq!(t.latency_us(0.99), 0.0);
        assert_eq!(t.throughput_rps(), 0.0);
    }

    #[test]
    fn zero_admission_percentiles_are_zero_for_every_quantile() {
        // the trace-diff report path builds SloTrackers straight from
        // completion logs; a zero-admission trace has none, and every
        // quantile must come back 0.0 — never an interpolation into an
        // empty sample (NaN/panic)
        let t = SloTracker::new(10);
        for q in [0.0, 0.25, 0.5, 0.95, 0.99, 1.0] {
            let v = t.latency_us(q);
            assert_eq!(v, 0.0, "q={q}");
            assert!(v.is_finite());
        }
        assert_eq!(t.goodput_rps(), 0.0);
        assert_eq!(t.violations, 0);
        assert_eq!(t.completed, 0);
    }

    #[test]
    fn report_json_and_table_row_agree() {
        let r = ServeReport {
            scenario: "steady".into(),
            policy: "bip-online".into(),
            offered: 100,
            admitted: 90,
            rejected: 10,
            expired: 5,
            completed: 85,
            slo_violations: 2,
            p50_ms: 1.5,
            p95_ms: 3.0,
            p99_ms: 4.0,
            throughput_rps: 1000.0,
            goodput_rps: 980.0,
            avg_max_vio: 0.12,
            sup_max_vio: 0.5,
            overflow: 7,
            degraded: 0,
            device_imbalance: 1.1,
            state_bytes: 2048,
            horizon_s: 0.085,
        };
        assert!(r.conserves_work());
        assert_eq!(r.table_row().len(), ServeReport::headers().len());
        let j = r.to_json();
        assert_eq!(j.path("completed").unwrap().as_usize(), Some(85));
        assert_eq!(j.path("policy").unwrap().as_str(), Some("bip-online"));
        // round-trips through the emitter
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(re.path("avg_max_vio").unwrap().as_f64(), Some(0.12));
    }
}
