//! Micro-batching scheduler: admission control, a bounded FIFO queue,
//! and deadline-aware batch formation.
//!
//! Invariants the property tests pin:
//!
//! * **bounded queue** — an arrival beyond `queue_cap` is rejected at
//!   admission, never silently queued;
//! * **FIFO per tenant** — the queue is globally FIFO and batches close
//!   from the head, so no two requests of one tenant ever reorder;
//! * **work conservation** — every offered request is accounted exactly
//!   once: `offered = admitted + rejected` and
//!   `admitted = batched + expired + len(queue)` at every instant.

use std::collections::VecDeque;

use crate::obs::event::{self, EventKind};
use crate::prof::{Frame, ProfGuard};

use super::traffic::Request;

#[derive(Clone, Debug, PartialEq)]
pub struct SchedulerConfig {
    /// admission bound: arrivals beyond this queue depth are rejected
    pub queue_cap: usize,
    /// close a batch as soon as this many requests wait
    pub batch_max: usize,
    /// ... or as soon as the oldest waiter has waited this long
    pub max_wait_us: u64,
    /// drop queued requests whose deadline passed before service starts
    pub drop_expired: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            queue_cap: 512,
            batch_max: 64,
            max_wait_us: 2_000,
            drop_expired: true,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    Admitted,
    Rejected,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct SchedStats {
    pub offered: u64,
    pub admitted: u64,
    pub rejected: u64,
    /// admitted but dropped at batch formation (deadline already passed)
    pub expired: u64,
    pub batches: u64,
    /// requests handed out in batches (serviced)
    pub batched: u64,
}

pub struct MicroBatcher {
    cfg: SchedulerConfig,
    queue: VecDeque<Request>,
    pub stats: SchedStats,
}

impl MicroBatcher {
    pub fn new(cfg: SchedulerConfig) -> MicroBatcher {
        assert!(cfg.batch_max >= 1 && cfg.queue_cap >= 1);
        MicroBatcher { cfg, queue: VecDeque::new(), stats: SchedStats::default() }
    }

    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Admission control: bounded queue, reject-on-full. Each verdict
    /// drops an Admit/Reject causal event keyed by the request id.
    pub fn offer(&mut self, req: Request) -> Admission {
        let _prof = ProfGuard::enter(Frame::Admission);
        self.stats.offered += 1;
        if self.queue.len() >= self.cfg.queue_cap {
            self.stats.rejected += 1;
            event::record_event(
                EventKind::Reject,
                req.id,
                self.queue.len() as u64,
            );
            return Admission::Rejected;
        }
        self.stats.admitted += 1;
        event::record_event(EventKind::Admit, req.id, req.arrival_us);
        self.queue.push_back(req);
        Admission::Admitted
    }

    /// Account one offered request shed *upstream* of the queue
    /// (forecast-gated admission, `forecast::control::PredictiveAdmission`):
    /// counted offered + rejected, never enqueued, so
    /// [`MicroBatcher::conserves_work`] keeps holding on gated runs.
    pub fn shed(&mut self) {
        self.stats.offered += 1;
        self.stats.rejected += 1;
    }

    /// Should a batch close now? True once the queue holds a full batch
    /// or the oldest waiter has hit `max_wait_us`.
    pub fn ready(&self, now_us: u64) -> bool {
        if self.queue.len() >= self.cfg.batch_max {
            return true;
        }
        self.queue
            .front()
            .map(|r| now_us >= r.arrival_us + self.cfg.max_wait_us)
            .unwrap_or(false)
    }

    /// Earliest future instant at which `ready` turns true without any
    /// new arrival (the event loop's flush timer). None when idle.
    pub fn flush_at(&self) -> Option<u64> {
        self.queue
            .front()
            .map(|r| r.arrival_us + self.cfg.max_wait_us)
    }

    /// Close a batch: up to `batch_max` requests from the head, in FIFO
    /// order. Expired requests are dropped (and counted), not served.
    pub fn take_batch(&mut self, now_us: u64) -> Vec<Request> {
        let mut batch = Vec::new();
        while batch.len() < self.cfg.batch_max {
            let Some(req) = self.queue.pop_front() else { break };
            if self.cfg.drop_expired && req.deadline_us < now_us {
                self.stats.expired += 1;
                continue;
            }
            batch.push(req);
        }
        if !batch.is_empty() {
            self.stats.batches += 1;
            self.stats.batched += batch.len() as u64;
        }
        batch
    }

    /// `offered = admitted + rejected` and
    /// `admitted = batched + expired + queued` — must hold always.
    pub fn conserves_work(&self) -> bool {
        let s = &self.stats;
        s.offered == s.admitted + s.rejected
            && s.admitted
                == s.batched + s.expired + self.queue.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, tenant: u32, arrival_us: u64, deadline_us: u64) -> Request {
        Request { id, tenant, arrival_us, deadline_us, scores: Vec::new() }
    }

    #[test]
    fn admission_control_bounds_the_queue() {
        let mut b = MicroBatcher::new(SchedulerConfig {
            queue_cap: 4,
            ..Default::default()
        });
        for i in 0..10 {
            b.offer(req(i, 0, i, i + 1000));
        }
        assert_eq!(b.queue_len(), 4);
        assert_eq!(b.stats.admitted, 4);
        assert_eq!(b.stats.rejected, 6);
        assert!(b.conserves_work());
    }

    #[test]
    fn batches_close_on_size_or_age() {
        let mut b = MicroBatcher::new(SchedulerConfig {
            batch_max: 3,
            max_wait_us: 100,
            ..Default::default()
        });
        b.offer(req(0, 0, 10, 10_000));
        assert!(!b.ready(50));
        assert_eq!(b.flush_at(), Some(110));
        assert!(b.ready(110)); // age trigger
        b.offer(req(1, 0, 20, 10_000));
        b.offer(req(2, 0, 30, 10_000));
        assert!(b.ready(31)); // size trigger
        let batch = b.take_batch(31);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.flush_at(), None);
        assert!(b.conserves_work());
    }

    #[test]
    fn upstream_sheds_count_as_rejections() {
        let mut b = MicroBatcher::new(SchedulerConfig::default());
        b.offer(req(0, 0, 0, 1000));
        b.shed();
        b.shed();
        assert_eq!(b.stats.offered, 3);
        assert_eq!(b.stats.rejected, 2);
        assert_eq!(b.queue_len(), 1);
        assert!(b.conserves_work());
    }

    #[test]
    fn fifo_order_is_preserved_across_batches() {
        let mut b = MicroBatcher::new(SchedulerConfig {
            batch_max: 4,
            ..Default::default()
        });
        for i in 0..10 {
            b.offer(req(i, (i % 2) as u32, i, i + 100_000));
        }
        let mut seen = Vec::new();
        while b.queue_len() > 0 {
            seen.extend(b.take_batch(50).into_iter().map(|r| r.id));
        }
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
        assert!(b.conserves_work());
    }

    #[test]
    fn expired_requests_are_dropped_and_counted() {
        let mut b = MicroBatcher::new(SchedulerConfig {
            batch_max: 8,
            ..Default::default()
        });
        b.offer(req(0, 0, 0, 50)); // will be expired at t=100
        b.offer(req(1, 0, 0, 500));
        b.offer(req(2, 0, 0, 50)); // expired too
        b.offer(req(3, 0, 0, 500));
        let batch = b.take_batch(100);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(b.stats.expired, 2);
        assert_eq!(b.stats.batched, 2);
        assert!(b.conserves_work());
    }

    #[test]
    fn drop_expired_can_be_disabled() {
        let mut b = MicroBatcher::new(SchedulerConfig {
            drop_expired: false,
            ..Default::default()
        });
        b.offer(req(0, 0, 0, 50));
        let batch = b.take_batch(100);
        assert_eq!(batch.len(), 1);
        assert_eq!(b.stats.expired, 0);
        assert!(b.conserves_work());
    }
}
