//! Experiment runner + cache shared by the table/figure benches.
//!
//! Every bench needs the same training runs (config x mode x T); runs are
//! expensive, so results are cached under `reports/<label>/run.json` and
//! reused when the artifact fingerprint + step count match. Figures read
//! the CSV series the recorder dumped alongside.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::runtime::Engine;
use crate::train::TrainDriver;
use crate::util::json::Json;

/// Summary of one completed training run (parsed back from run.json).
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub label: String,
    pub steps: u64,
    pub avg_max_vio: f64,
    pub sup_max_vio: f64,
    pub perplexity: f64,
    pub sim_hours_full: f64,
    pub wall_seconds: f64,
    pub layer_avg: Vec<f64>,
    pub dir: PathBuf,
}

impl RunSummary {
    pub fn from_run_json(path: &Path) -> Result<RunSummary> {
        let j = Json::parse(&std::fs::read_to_string(path)?)
            .map_err(|e| anyhow!("{e}"))?;
        let getf = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
        Ok(RunSummary {
            label: j
                .get("run_id")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            steps: getf("steps") as u64,
            avg_max_vio: getf("avg_max_vio"),
            sup_max_vio: getf("sup_max_vio"),
            perplexity: getf("perplexity"),
            sim_hours_full: getf("sim_hours_full"),
            wall_seconds: getf("total_wall_s"),
            layer_avg: j
                .get("layer_avg_max_vio")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default(),
            dir: path.parent().unwrap().to_path_buf(),
        })
    }

    /// Load the per-step MaxVio series (global or one layer) from the CSVs
    /// the recorder wrote.
    pub fn series(&self, which: &str) -> Result<Vec<f32>> {
        let path = self.dir.join(format!("maxvio_{which}.csv"));
        let text = std::fs::read_to_string(&path)?;
        Ok(text
            .lines()
            .skip(1)
            .filter_map(|l| l.split(',').nth(1))
            .filter_map(|v| v.parse().ok())
            .collect())
    }
}

/// The standard method grid of Tables 2/3: Loss-Controlled, Loss-Free and
/// BIP with the paper's T sweep.
pub fn method_grid(bip_ts: &[usize]) -> Vec<(String, String, usize)> {
    let mut grid = vec![
        ("Loss-Controlled".to_string(), "aux".to_string(), 0),
        ("Loss-Free".to_string(), "lossfree".to_string(), 0),
    ];
    for &t in bip_ts {
        grid.push((format!("BIP, T={t}"), "bip".to_string(), t));
    }
    grid
}

/// Run (or reuse) one training experiment; returns its summary.
pub fn run_or_load(
    engine: &Engine,
    driver: &TrainDriver,
    reports_dir: &Path,
) -> Result<RunSummary> {
    let run_json = reports_dir.join(driver.run_label()).join("run.json");
    if let Ok(cached) = RunSummary::from_run_json(&run_json) {
        if cached.steps == driver.steps && cached.perplexity.is_finite() {
            println!("[cached] {}", driver.run_label());
            return Ok(cached);
        }
    }
    println!("[running] {} ({} steps)", driver.run_label(), driver.steps);
    let outcome = driver.run(engine)?;
    outcome.dump(reports_dir)?;
    RunSummary::from_run_json(&run_json)
}

/// Paper reference values for side-by-side comparison in the bench output.
/// (AvgMaxVio, SupMaxVio, Perplexity, TrainingHours) per method label.
pub fn paper_table2() -> Vec<(&'static str, [f64; 4])> {
    vec![
        ("Loss-Controlled", [0.3852, 1.5245, 12.4631, 4.6126]),
        ("Loss-Free", [0.1275, 1.7702, 11.1311, 4.3558]),
        ("BIP, T=2", [0.0529, 0.2019, 11.2417, 3.9547]),
        ("BIP, T=4", [0.0602, 0.1726, 10.6856, 4.0051]),
        ("BIP, T=8", [0.0626, 0.1727, 10.7291, 4.0623]),
        ("BIP, T=14", [0.0547, 0.1925, 10.7408, 4.177]),
    ]
}

pub fn paper_table3() -> Vec<(&'static str, [f64; 4])> {
    vec![
        ("Loss-Controlled", [0.7158, 2.3841, 9.9956, 23.7726]),
        ("Loss-Free", [0.3366, 2.7121, 10.2975, 23.9557]),
        ("BIP, T=2", [0.0513, 0.5613, 10.6916, 20.4569]),
        ("BIP, T=4", [0.0496, 0.4107, 10.1299, 20.3046]),
        ("BIP, T=8", [0.0441, 0.2372, 10.0677, 20.4572]),
        ("BIP, T=14", [0.0529, 0.1946, 9.9071, 20.4799]),
    ]
}

/// Per-layer AvgMaxVio reference rows (Tables 4 and 5).
pub fn paper_table4() -> Vec<(&'static str, [f64; 8])> {
    vec![
        ("Auxiliary Loss",
         [0.8988, 1.1607, 1.1717, 1.1726, 1.1528, 1.14, 1.1403, 1.1216]),
        ("Loss Free",
         [0.364, 0.3044, 0.3341, 0.3556, 0.3279, 0.4681, 0.4827, 0.3693]),
        ("BIP, T=4",
         [0.2024, 0.1314, 0.1722, 0.2153, 0.1584, 0.1879, 0.1998, 0.2065]),
    ]
}

pub fn paper_table5() -> Vec<(&'static str, [f64; 8])> {
    vec![
        ("Auxiliary Loss",
         [2.469, 2.4456, 2.4983, 2.478, 2.4586, 2.3725, 2.2958, 2.177]),
        ("Loss Free",
         [1.5253, 1.0639, 1.0399, 1.0587, 1.036, 1.1521, 1.1314, 1.1126]),
        ("BIP, T=14",
         [0.1676, 0.1138, 0.1133, 0.1109, 0.1342, 0.1356, 0.2743, 0.1888]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_paper_methods() {
        let g = method_grid(&[2, 4, 8, 14]);
        assert_eq!(g.len(), 6);
        assert_eq!(g[0].1, "aux");
        assert_eq!(g[5], ("BIP, T=14".into(), "bip".into(), 14));
    }

    #[test]
    fn run_summary_round_trip() {
        let dir = std::env::temp_dir().join(format!(
            "bipmoe-sum-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let json = r#"{"run_id":"x","steps":10,"avg_max_vio":0.05,
            "sup_max_vio":0.2,"perplexity":11.5,"sim_hours_full":4.0,
            "total_wall_s":12.5,"layer_avg_max_vio":[0.1,0.2]}"#;
        std::fs::write(dir.join("run.json"), json).unwrap();
        std::fs::write(dir.join("maxvio_global.csv"),
                       "step,maxvio\n0,0.5\n1,0.25\n").unwrap();
        let s = RunSummary::from_run_json(&dir.join("run.json")).unwrap();
        assert_eq!(s.steps, 10);
        assert_eq!(s.layer_avg, vec![0.1, 0.2]);
        assert_eq!(s.series("global").unwrap(), vec![0.5, 0.25]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn paper_references_have_expected_shape() {
        assert_eq!(paper_table2().len(), 6);
        assert_eq!(paper_table3().len(), 6);
        // the paper's own claim: BIP T=4 beats Loss-Controlled on every
        // column of Table 2
        let t2 = paper_table2();
        let (_, aux) = t2[0];
        let (_, bip4) = t2[3];
        assert!(bip4[0] < aux[0] && bip4[2] < aux[2] && bip4[3] < aux[3]);
    }
}
