//! Bench harness (criterion is unavailable offline): named timed sections,
//! warmup + repeated measurement, and paper-table output via
//! [`crate::metrics::table::TablePrinter`].
//!
//! Every `cargo bench` target (`rust/benches/*.rs`, harness = false) uses
//! this module; results additionally land as CSV/JSON under `reports/`.

pub mod experiments;

use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;

/// One micro-benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub secs_per_iter: Summary,
}

impl Measurement {
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12}  ± {:>10}   ({} iters)",
            self.name,
            crate::util::timer::human_duration(Duration::from_secs_f64(
                self.secs_per_iter.mean
            )),
            crate::util::timer::human_duration(Duration::from_secs_f64(
                self.secs_per_iter.std()
            )),
            self.iters
        )
    }
}

/// Micro-bench runner: warmup, then sample `samples` times, each sample
/// running the closure enough times to fill `min_sample_time`.
pub struct Bencher {
    pub min_sample_time: Duration,
    pub samples: usize,
    pub results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            min_sample_time: Duration::from_millis(30),
            samples: 8,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            min_sample_time: Duration::from_millis(10),
            samples: 3,
            results: Vec::new(),
        }
    }

    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &Measurement {
        f(); // warmup
        let mut per_iter = Summary::new();
        let mut total_iters = 0u64;
        for _ in 0..self.samples {
            let t0 = Instant::now();
            let mut iters = 0u64;
            while t0.elapsed() < self.min_sample_time {
                f();
                iters += 1;
            }
            per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
            total_iters += iters;
        }
        self.results.push(Measurement {
            name: name.to_string(),
            iters: total_iters,
            secs_per_iter: per_iter,
        });
        println!("{}", self.results.last().unwrap().report_line());
        self.results.last().unwrap()
    }
}

impl Measurement {
    /// Machine-readable form for BENCH_*.json perf records.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("mean_us", Json::Num(self.secs_per_iter.mean * 1e6)),
            ("std_us", Json::Num(self.secs_per_iter.std() * 1e6)),
            ("iters", Json::Num(self.iters as f64)),
        ])
    }
}

/// Schema version stamped into every `BENCH_*.json` record; bump when
/// the payload shape changes so cross-PR consumers can detect drift
/// (the `bench-honesty` lint requires every writer to stamp it).
pub const BENCH_SCHEMA_VERSION: u32 = 1;

/// Write a `BENCH_<name>.json` perf record under `reports/` (or
/// `$BIP_MOE_REPORTS`) so the perf trajectory is tracked across PRs.
/// The payload is wrapped with the crate version and schema version.
pub fn write_bench_json(name: &str, results: Json) -> std::io::Result<PathBuf> {
    let dir = PathBuf::from(
        std::env::var("BIP_MOE_REPORTS").unwrap_or_else(|_| "reports".into()),
    );
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("BENCH_{name}.json"));
    let doc = Json::obj(vec![
        ("bench", Json::Str(name.to_string())),
        ("schema_version", Json::Num(BENCH_SCHEMA_VERSION as f64)),
        ("version", Json::Str(crate::VERSION.to_string())),
        ("results", results),
    ]);
    std::fs::write(&path, doc.to_string())?;
    Ok(path)
}

/// Shared env knobs for the table/figure benches.
pub struct BenchConfig {
    /// full-scale run (BIP_MOE_FULL=1) vs quick default
    pub full: bool,
    /// training steps per method
    pub steps: u64,
    /// held-out eval batches for perplexity
    pub eval_batches: u64,
}

impl BenchConfig {
    pub fn from_env(quick_steps: u64, full_steps: u64) -> Self {
        let full = std::env::var("BIP_MOE_FULL").as_deref() == Ok("1");
        let steps = std::env::var("BIP_MOE_STEPS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(if full { full_steps } else { quick_steps });
        BenchConfig {
            full,
            steps,
            eval_batches: if full { 32 } else { 8 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_measures_something() {
        let mut b = Bencher::quick();
        let m = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(m.secs_per_iter.mean > 0.0);
        assert!(m.iters >= 3);
    }

    #[test]
    fn measurement_json_round_trips() {
        let mut s = Summary::new();
        s.push(1e-6);
        s.push(3e-6);
        let m = Measurement {
            name: "x".into(),
            iters: 2,
            secs_per_iter: s,
        };
        let j = m.to_json();
        assert_eq!(j.path("name").unwrap().as_str(), Some("x"));
        assert!((j.path("mean_us").unwrap().as_f64().unwrap() - 2.0).abs()
            < 1e-9);
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(re.path("iters").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn bench_config_defaults_quick() {
        std::env::remove_var("BIP_MOE_FULL");
        std::env::remove_var("BIP_MOE_STEPS");
        let c = BenchConfig::from_env(60, 400);
        assert!(!c.full);
        assert_eq!(c.steps, 60);
    }
}
