//! Run recorder: collects the full time series of one training run and
//! dumps the CSV/JSON files every figure and table is rebuilt from.
//!
//! Output layout under `reports/<run-id>/`:
//!   run.json                — summary (AvgMaxVio, SupMaxVio, ppl, times)
//!   maxvio_global.csv       — step, maxvio           (Figures 1-2)
//!   maxvio_layer<L>.csv     — step, maxvio per layer (Figures 3-18)
//!   loss.csv                — step, train nll/token
//!   layer_avg.csv           — layer, avgmaxvio, supmaxvio (Tables 4-5)

use std::path::{Path, PathBuf};

use super::maxvio::BalanceTracker;
use crate::util::csv::CsvWriter;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct RunRecorder {
    pub run_id: String,
    pub balance: BalanceTracker,
    pub loss_series: Vec<f32>,
    pub drop_series: Vec<f32>,
    pub step_wall: Vec<f32>,
    pub meta: Vec<(String, Json)>,
}

impl RunRecorder {
    pub fn new(run_id: &str, n_layers: usize, n_tokens: usize, k: usize) -> Self {
        RunRecorder {
            run_id: run_id.to_string(),
            balance: BalanceTracker::new(n_layers, n_tokens, k),
            loss_series: Vec::new(),
            drop_series: Vec::new(),
            step_wall: Vec::new(),
            meta: Vec::new(),
        }
    }

    pub fn push_step(
        &mut self,
        loads: &[f32],
        m: usize,
        loss_per_token: f32,
        mean_drop: f32,
        wall_secs: f32,
    ) {
        self.balance.push_batch(loads, m);
        self.loss_series.push(loss_per_token);
        self.drop_series.push(mean_drop);
        self.step_wall.push(wall_secs);
    }

    pub fn set_meta(&mut self, key: &str, value: Json) {
        self.meta.push((key.to_string(), value));
    }

    pub fn total_wall(&self) -> f64 {
        self.step_wall.iter().map(|&x| x as f64).sum()
    }

    pub fn summary_json(&self) -> Json {
        let mut pairs = vec![
            ("run_id", Json::Str(self.run_id.clone())),
            ("steps", Json::Num(self.balance.batches() as f64)),
            ("avg_max_vio", Json::Num(self.balance.avg_max_vio())),
            ("sup_max_vio", Json::Num(self.balance.sup_max_vio())),
            ("final_loss", Json::Num(
                self.loss_series.last().copied().unwrap_or(f32::NAN) as f64)),
            ("total_wall_s", Json::Num(self.total_wall())),
            ("mean_drop_frac", Json::Num(
                self.drop_series.iter().map(|&x| x as f64).sum::<f64>()
                    / self.drop_series.len().max(1) as f64)),
            ("layer_avg_max_vio", Json::Arr(
                (0..self.balance.n_layers)
                    .map(|l| Json::Num(self.balance.layer_avg(l)))
                    .collect())),
            ("layer_sup_max_vio", Json::Arr(
                (0..self.balance.n_layers)
                    .map(|l| Json::Num(self.balance.layer_sup(l)))
                    .collect())),
        ];
        for (k, v) in &self.meta {
            pairs.push((k.as_str(), v.clone()));
        }
        Json::obj(pairs)
    }

    /// Write every series + the summary under `dir/<run_id>/`. If that
    /// directory already exists (a rerun with the same run id), the
    /// output is uniquified to `<run_id>-2`, `-3`, ... instead of
    /// silently overwriting the earlier run's series; the actual path
    /// is returned.
    pub fn dump(&self, dir: &Path) -> std::io::Result<PathBuf> {
        let mut out = dir.join(&self.run_id);
        let mut suffix = 2;
        while out.exists() {
            out = dir.join(format!("{}-{}", self.run_id, suffix));
            suffix += 1;
        }
        std::fs::create_dir_all(&out)?;

        std::fs::write(out.join("run.json"),
                       format!("{}\n", self.summary_json()))?;

        let mut w = CsvWriter::create(out.join("maxvio_global.csv"),
                                      &["step", "maxvio"])?;
        for (i, v) in self.balance.global_series.iter().enumerate() {
            w.row([i.to_string(), format!("{v:.6}")])?;
        }
        w.finish()?;

        for l in 0..self.balance.n_layers {
            let mut w = CsvWriter::create(
                out.join(format!("maxvio_layer{}.csv", l + 1)),
                &["step", "maxvio"])?;
            for (i, v) in self.balance.series[l].iter().enumerate() {
                w.row([i.to_string(), format!("{v:.6}")])?;
            }
            w.finish()?;
        }

        let mut w = CsvWriter::create(out.join("loss.csv"),
                                      &["step", "nll_per_token", "drop_frac",
                                        "wall_s"])?;
        for i in 0..self.loss_series.len() {
            w.row([
                i.to_string(),
                format!("{:.6}", self.loss_series[i]),
                format!("{:.6}", self.drop_series[i]),
                format!("{:.6}", self.step_wall[i]),
            ])?;
        }
        w.finish()?;

        let mut w = CsvWriter::create(out.join("layer_avg.csv"),
                                      &["layer", "avg_max_vio",
                                        "sup_max_vio"])?;
        for l in 0..self.balance.n_layers {
            w.row([
                (l + 1).to_string(),
                format!("{:.6}", self.balance.layer_avg(l)),
                format!("{:.6}", self.balance.layer_sup(l)),
            ])?;
        }
        w.finish()?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunRecorder {
        let mut r = RunRecorder::new("test-run", 2, 8, 2);
        r.set_meta("mode", Json::Str("bip".into()));
        r.push_step(&[4.0, 4.0, 4.0, 4.0, 8.0, 4.0, 2.0, 2.0], 4, 5.5, 0.0,
                    0.1);
        r.push_step(&[8.0, 4.0, 2.0, 2.0, 8.0, 4.0, 2.0, 2.0], 4, 5.0, 0.01,
                    0.1);
        r
    }

    #[test]
    fn summary_fields() {
        let r = sample();
        let j = r.summary_json();
        assert_eq!(j.get("steps").unwrap().as_usize(), Some(2));
        assert!((j.get("avg_max_vio").unwrap().as_f64().unwrap() - 0.75)
            .abs() < 1e-9);
        assert_eq!(j.get("mode").unwrap().as_str(), Some("bip"));
        assert!((j.get("total_wall_s").unwrap().as_f64().unwrap() - 0.2)
            .abs() < 1e-6);
    }

    #[test]
    fn dump_writes_all_files() {
        let dir = std::env::temp_dir().join(format!(
            "bipmoe-rec-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let r = sample();
        let out = r.dump(&dir).unwrap();
        for f in ["run.json", "maxvio_global.csv", "maxvio_layer1.csv",
                  "maxvio_layer2.csv", "loss.csv", "layer_avg.csv"] {
            assert!(out.join(f).exists(), "{f}");
        }
        let text = std::fs::read_to_string(out.join("maxvio_global.csv"))
            .unwrap();
        assert!(text.starts_with("step,maxvio\n0,0.5"));
        let run = Json::parse(
            &std::fs::read_to_string(out.join("run.json")).unwrap())
            .unwrap();
        assert_eq!(run.get("run_id").unwrap().as_str(), Some("test-run"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dump_never_overwrites_an_existing_run() {
        let dir = std::env::temp_dir().join(format!(
            "bipmoe-rec-uniq-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let r = sample();
        let first = r.dump(&dir).unwrap();
        let marker = first.join("maxvio_global.csv");
        let before = std::fs::read_to_string(&marker).unwrap();

        let second = r.dump(&dir).unwrap();
        assert_ne!(first, second);
        assert!(second.ends_with("test-run-2"), "{second:?}");
        let third = r.dump(&dir).unwrap();
        assert!(third.ends_with("test-run-3"), "{third:?}");

        for out in [&first, &second, &third] {
            assert!(out.join("run.json").exists(), "{out:?}");
        }
        // the first run's series were left untouched
        assert_eq!(std::fs::read_to_string(&marker).unwrap(), before);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
