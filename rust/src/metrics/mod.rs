//! Balance + quality metrics, time-series recording, and table rendering.
//!
//! Implements the paper's measurements exactly (§4.1):
//!   MaxVio_batch = max_j Load_j / mean_load - 1
//!   AvgMaxVio    = mean over batches
//!   SupMaxVio    = max  over batches
//! tracked globally AND per MoE layer (Tables 4/5, Figures 3-18), plus
//! perplexity accounting and CSV/JSON dumps that regenerate every figure.

pub mod maxvio;
pub mod recorder;
pub mod table;

pub use maxvio::{max_violation, BalanceTracker, LoadHistory};
pub use recorder::RunRecorder;
pub use table::TablePrinter;

/// Perplexity accumulator: exp(sum nll / n_tokens) over a token stream.
#[derive(Clone, Debug, Default)]
pub struct Perplexity {
    pub nll_sum: f64,
    pub n_tokens: u64,
}

impl Perplexity {
    pub fn push(&mut self, nll_sum: f64, n_tokens: u64) {
        self.nll_sum += nll_sum;
        self.n_tokens += n_tokens;
    }

    pub fn value(&self) -> f64 {
        if self.n_tokens == 0 {
            f64::NAN
        } else {
            (self.nll_sum / self.n_tokens as f64).exp()
        }
    }

    pub fn cross_entropy(&self) -> f64 {
        self.nll_sum / self.n_tokens.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perplexity_accumulates() {
        let mut p = Perplexity::default();
        p.push(200.0, 100);
        p.push(100.0, 100);
        assert!((p.cross_entropy() - 1.5).abs() < 1e-12);
        assert!((p.value() - 1.5f64.exp()).abs() < 1e-9);
    }

    #[test]
    fn empty_is_nan() {
        assert!(Perplexity::default().value().is_nan());
    }
}
