//! MaxVio / AvgMaxVio / SupMaxVio (paper §4.1, after Wang et al. 2024).

use std::collections::VecDeque;

use crate::util::stats::Summary;

/// Bounded per-batch load-fraction history, off by default (the MaxVio
/// scalars are O(1) per batch; the raw fraction vectors are only worth
/// retaining when a forecaster will consume them —
/// `forecast::fit::LoadSeries::from_tracker`).
#[derive(Clone, Debug)]
pub struct LoadHistory {
    pub m: usize,
    /// ring bound, in batches, per layer
    pub cap: usize,
    /// `per_layer[l]` holds the last `cap` batches' per-expert load
    /// fractions for layer l, oldest first
    pub per_layer: Vec<VecDeque<Vec<f32>>>,
}

impl LoadHistory {
    fn new(n_layers: usize, m: usize, cap: usize) -> LoadHistory {
        LoadHistory {
            m,
            cap,
            per_layer: vec![VecDeque::new(); n_layers],
        }
    }

    /// Record one batch's (n_layers, m) loads as per-layer fractions;
    /// a layer that routed nothing is skipped (no fraction exists).
    fn push(&mut self, loads: &[f32], m: usize) {
        for (l, ring) in self.per_layer.iter_mut().enumerate() {
            let row = &loads[l * m..(l + 1) * m];
            let sum: f32 = row.iter().sum();
            if sum <= 0.0 {
                continue;
            }
            ring.push_back(row.iter().map(|&x| x / sum).collect());
            if ring.len() > self.cap {
                ring.pop_front();
            }
        }
    }
}

/// MaxVio for one batch on one gate: max_j load_j / (n k / m) - 1.
/// An empty batch (n_tokens = 0) has no violation by definition — the
/// unguarded division would push inf/NaN through every downstream
/// Summary (the serving path can produce all-expired micro-batches).
pub fn max_violation(loads: &[f32], n_tokens: usize, k: usize) -> f64 {
    let m = loads.len();
    let mean = n_tokens as f64 * k as f64 / m as f64;
    if mean <= 0.0 {
        return 0.0;
    }
    let max = loads.iter().cloned().fold(f32::MIN, f32::max) as f64;
    max / mean - 1.0
}

/// Tracks MaxVio across the whole pre-training run: global (mean over
/// layers of per-layer MaxVio? — NO: the paper's global MaxVio_batch uses
/// the loads summed semantics per gate; we track the MEAN over layers as
/// the batch scalar, plus every per-layer series) and per layer.
///
/// Concretely, per batch we receive the (L, m) load matrix and record:
///   * per-layer MaxVio_l  (Tables 4/5, Figures 3-18)
///   * batch MaxVio = mean_l MaxVio_l (Figures 1-2, Tables 2-3) — the
///     model-level balance scalar.
#[derive(Clone, Debug)]
pub struct BalanceTracker {
    pub n_layers: usize,
    pub n_tokens: usize,
    pub k: usize,
    pub global: Summary,
    pub per_layer: Vec<Summary>,
    /// full series for figure dumps: series[layer][batch]
    pub series: Vec<Vec<f32>>,
    pub global_series: Vec<f32>,
    /// bounded raw-fraction history (None unless enabled)
    pub load_history: Option<LoadHistory>,
}

impl BalanceTracker {
    pub fn new(n_layers: usize, n_tokens: usize, k: usize) -> Self {
        BalanceTracker {
            n_layers,
            n_tokens,
            k,
            global: Summary::new(),
            per_layer: vec![Summary::new(); n_layers],
            series: vec![Vec::new(); n_layers],
            global_series: Vec::new(),
            load_history: None,
        }
    }

    /// Retain the last `cap` batches' per-layer load fractions for
    /// forecaster fitting. Idempotent; history starts empty.
    pub fn enable_load_history(&mut self, m: usize, cap: usize) {
        assert!(m >= 1 && cap >= 1);
        self.load_history = Some(LoadHistory::new(self.n_layers, m, cap));
    }

    /// `loads` is row-major (n_layers, m).
    pub fn push_batch(&mut self, loads: &[f32], m: usize) {
        self.push_batch_sized(loads, m, self.n_tokens);
    }

    /// Same recording with an explicit per-call token count — the serving
    /// path, where micro-batches vary in size (training batches do not).
    pub fn push_batch_sized(
        &mut self,
        loads: &[f32],
        m: usize,
        n_tokens: usize,
    ) {
        assert_eq!(loads.len(), self.n_layers * m);
        if n_tokens == 0 {
            // nothing was routed: recording would divide by a zero mean
            // load and poison the run averages with inf/NaN
            return;
        }
        let mut sum = 0.0;
        for l in 0..self.n_layers {
            let vio = max_violation(
                &loads[l * m..(l + 1) * m],
                n_tokens,
                self.k,
            );
            self.per_layer[l].push(vio);
            self.series[l].push(vio as f32);
            sum += vio;
        }
        let batch_vio = sum / self.n_layers as f64;
        self.global.push(batch_vio);
        self.global_series.push(batch_vio as f32);
        if let Some(h) = &mut self.load_history {
            h.push(loads, m);
        }
    }

    pub fn avg_max_vio(&self) -> f64 {
        self.global.mean
    }

    pub fn sup_max_vio(&self) -> f64 {
        self.global.max
    }

    pub fn layer_avg(&self, layer: usize) -> f64 {
        self.per_layer[layer].mean
    }

    pub fn layer_sup(&self, layer: usize) -> f64 {
        self.per_layer[layer].max
    }

    pub fn batches(&self) -> u64 {
        self.global.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_violation_matches_formula() {
        // n=8 tokens, k=2, m=4 -> mean load 4
        let loads = [4.0f32, 4.0, 4.0, 4.0];
        assert!((max_violation(&loads, 8, 2) - 0.0).abs() < 1e-12);
        let loads = [8.0f32, 4.0, 2.0, 2.0];
        assert!((max_violation(&loads, 8, 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn tracker_avg_and_sup() {
        let mut t = BalanceTracker::new(2, 8, 2);
        // layer vios: batch0 -> (0.0, 1.0) => batch 0.5
        t.push_batch(&[4.0, 4.0, 4.0, 4.0, 8.0, 4.0, 2.0, 2.0], 4);
        // batch1 -> (1.0, 1.0) => batch 1.0
        t.push_batch(&[8.0, 4.0, 2.0, 2.0, 8.0, 4.0, 2.0, 2.0], 4);
        assert!((t.avg_max_vio() - 0.75).abs() < 1e-12);
        assert!((t.sup_max_vio() - 1.0).abs() < 1e-12);
        assert!((t.layer_avg(0) - 0.5).abs() < 1e-12);
        assert!((t.layer_avg(1) - 1.0).abs() < 1e-12);
        assert_eq!(t.batches(), 2);
        assert_eq!(t.series[0].len(), 2);
        assert_eq!(t.global_series, vec![0.5, 1.0]);
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = BalanceTracker::new(2, 8, 2);
        t.push_batch(&[1.0; 7], 4);
    }

    #[test]
    fn empty_batches_are_skipped_not_nan() {
        // regression: an all-expired micro-batch (0 tokens) divided by
        // a zero mean load and pushed inf into the SLO report
        assert_eq!(max_violation(&[0.0, 0.0, 0.0, 0.0], 0, 2), 0.0);
        assert_eq!(max_violation(&[3.0, 0.0, 0.0, 0.0], 0, 2), 0.0);
        let mut t = BalanceTracker::new(2, 0, 2);
        t.push_batch_sized(&[0.0; 8], 4, 0);
        assert_eq!(t.batches(), 0, "empty batch must not be recorded");
        assert_eq!(t.global_series.len(), 0);
        t.push_batch_sized(&[2.0, 2.0, 2.0, 2.0, 2.0, 2.0, 2.0, 2.0], 4, 4);
        t.push_batch_sized(&[0.0; 8], 4, 0);
        assert_eq!(t.batches(), 1);
        assert!(t.avg_max_vio().is_finite());
        assert!(t.sup_max_vio().is_finite());
        assert!((t.avg_max_vio() - 0.0).abs() < 1e-12);
    }

    #[test]
    fn load_history_rings_are_bounded_and_skip_empty_layers() {
        let mut t = BalanceTracker::new(2, 0, 2);
        t.enable_load_history(4, 3);
        for i in 0..5u32 {
            let x = i as f32 + 1.0;
            // layer 0 routed; layer 1 empty on even batches
            let l1 = if i % 2 == 0 { 0.0 } else { x };
            t.push_batch_sized(
                &[x, x, 0.0, 0.0, l1, 0.0, l1, 0.0],
                4,
                4,
            );
        }
        let h = t.load_history.as_ref().unwrap();
        assert_eq!(h.per_layer[0].len(), 3, "bounded at cap");
        assert_eq!(h.per_layer[1].len(), 2, "empty layers skipped");
        for row in h.per_layer[0].iter().chain(h.per_layer[1].iter()) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        }
        // the ring keeps the newest batches: fractions of batch 2..4
        // for layer 0 are all [0.5, 0.5, 0, 0]
        assert_eq!(h.per_layer[0][2], vec![0.5, 0.5, 0.0, 0.0]);
        // disabled by default
        let plain = BalanceTracker::new(2, 0, 2);
        assert!(plain.load_history.is_none());
    }

    #[test]
    fn sized_push_handles_variable_batches() {
        // serving micro-batches: 8 tokens then 4 tokens, k=2, m=4
        let mut t = BalanceTracker::new(1, 0, 2);
        t.push_batch_sized(&[8.0, 4.0, 2.0, 2.0], 4, 8); // mean 4 -> vio 1.0
        t.push_batch_sized(&[2.0, 2.0, 2.0, 2.0], 4, 4); // mean 2 -> vio 0.0
        assert!((t.avg_max_vio() - 0.5).abs() < 1e-12);
        assert!((t.sup_max_vio() - 1.0).abs() < 1e-12);
        assert_eq!(t.batches(), 2);
    }
}
