//! Paper-style table rendering (monospace, right-aligned numeric columns)
//! — the bench harness prints the same rows Tables 2-5 report.

pub struct TablePrinter {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TablePrinter {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        TablePrinter {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, fields: Vec<String>) {
        assert_eq!(fields.len(), self.headers.len());
        self.rows.push(fields);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, f) in row.iter().enumerate() {
                widths[i] = widths[i].max(f.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |fields: &[String], widths: &[usize]| {
            let mut line = String::from("| ");
            for (i, f) in fields.iter().enumerate() {
                if i == 0 {
                    line.push_str(&format!("{:<w$} | ", f, w = widths[i]));
                } else {
                    line.push_str(&format!("{:>w$} | ", f, w = widths[i]));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        out.push_str(&format!("{}\n", "-".repeat(total)));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// ASCII line plot for figure benches (quick visual check of the CSV
/// series without leaving the terminal).
pub fn ascii_plot(series: &[(&str, &[f32])], width: usize, height: usize)
    -> String
{
    let max_y = series
        .iter()
        .flat_map(|(_, s)| s.iter().cloned())
        .fold(0.0f32, f32::max)
        .max(1e-9);
    let max_x = series.iter().map(|(_, s)| s.len()).max().unwrap_or(1);
    let marks = ['*', 'o', '+', 'x', '#'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, s)) in series.iter().enumerate() {
        for (i, &v) in s.iter().enumerate() {
            let x = i * (width - 1) / max_x.max(1);
            let y = ((v / max_y) * (height - 1) as f32).round() as usize;
            let y = height - 1 - y.min(height - 1);
            grid[y][x] = marks[si % marks.len()];
        }
    }
    let mut out = String::new();
    out.push_str(&format!("ymax={max_y:.3}\n"));
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}", marks[si % marks.len()], name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = TablePrinter::new(
            "Table 2", &["Algorithm", "AvgMaxVio", "Perplexity"]);
        t.row(vec!["Loss-Controlled".into(), "0.3852".into(),
                   "12.4631".into()]);
        t.row(vec!["BIP, T=4".into(), "0.0602".into(), "10.6856".into()]);
        let s = t.render();
        assert!(s.contains("== Table 2 =="));
        assert!(s.contains("Loss-Controlled"));
        let lines: Vec<&str> = s.lines().collect();
        // all data lines same width
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = TablePrinter::new("x", &["a", "b"]);
        t.row(vec!["only".into()]);
    }

    #[test]
    fn ascii_plot_shape() {
        let a = [1.0f32, 0.5, 0.2, 0.1];
        let b = [0.1f32, 0.1, 0.1, 0.1];
        let p = ascii_plot(&[("one", &a), ("two", &b)], 40, 10);
        assert_eq!(p.lines().count(), 13); // ymax + 10 rows + axis + legend
        assert!(p.contains("one") && p.contains("two"));
    }
}
