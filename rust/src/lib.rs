//! # bip-moe — BIP-Based Balancing for Mixture-of-Experts pre-training
//!
//! Production-grade reproduction of *"Binary-Integer-Programming Based
//! Algorithm for Expert Load Balancing in Mixture-of-Experts Models"*
//! (Yuan Sun, 2025) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the training coordinator and serving stack:
//!   data pipeline, PJRT runtime, training loop, metrics,
//!   expert-parallel cluster simulator, BIP solver substrate (exact /
//!   dual / online / approx), the §5 online-matching application, and
//!   the `serve/` online inference-serving subsystem (traffic generator,
//!   admission control, micro-batch scheduler, capacity-aware BIP
//!   router), the `trace/` record/replay subsystem (binary routing
//!   traces, deterministic replay, counterfactual policy diffs), and
//!   the `forecast/` subsystem (per-expert load forecasting, proactive
//!   dual warm-start, predictive admission + autoscaling), and the
//!   `perf/` subsystem (shared score-arena for the zero-allocation
//!   serving hot path + counting allocator backing `bench_hotpath`),
//!   and the `telemetry/` subsystem (static zero-allocation metrics
//!   registry, RAII span profiling, Prometheus/JSON exposition), and
//!   the `analysis/` subsystem (self-hosted static lint suite proving
//!   the hot-path/unsafe/telemetry invariants at CI time via
//!   `bip-moe lint --deny`), and the `obs/` subsystem (causal event
//!   tracing, incident flight recorder, online routing-collapse
//!   anomaly detection, and the `bip-moe top` dashboard), and the
//!   `prof/` subsystem (deterministic hierarchical call-path profiler:
//!   flamegraph export, versioned `PROF_*.json` records, and
//!   `bip-moe profile diff` phase-level regression attribution).
//!   Python never runs on the training or serving path.
//! * **L2 (`python/compile/model.py`)** — Minimind-style MoE transformer
//!   (fwd/bwd/AdamW) with the three routing modes (Loss-Controlled,
//!   Loss-Free, BIP), AOT-lowered once to HLO text artifacts.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels: the BIP dual
//!   update (Algorithm 1 lines 7-12), the biased top-k gate, and the
//!   grouped expert FFN with a hand-derived custom VJP.
//!
//! See DESIGN.md for the full system inventory and the per-experiment
//! index (every table and figure of the paper mapped to a bench target).

pub mod analysis;
pub mod bench;
pub mod bip;
pub mod config;
pub mod data;
pub mod forecast;
pub mod matching;
pub mod metrics;
pub mod obs;
pub mod parallel;
pub mod perf;
pub mod prof;
pub mod routing;
pub mod runtime;
pub mod serve;
pub mod telemetry;
pub mod trace;
pub mod train;
pub mod util;

/// Crate version string (also stamped into run reports).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
