//! The lint passes. Each pass reads the per-file [`Model`]s and pushes
//! [`Finding`]s; policy (which constructs count as allocating, which
//! files are hot scope, which dirs must not panic) lives in the
//! constant tables at the top so a reviewer can audit the whole
//! contract in one screen.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::TokKind;
use super::model::{FnItem, Model};
use super::report::Finding;

/// Methods that allocate on every call.
const ALLOC_METHODS: &[&str] =
    &["to_vec", "collect", "to_string", "to_owned", "clone"];
/// Owner types whose constructors allocate.
const ALLOC_TYPES: &[&str] =
    &["Vec", "Box", "String", "VecDeque", "BTreeMap", "HashMap"];
const ALLOC_TYPE_FNS: &[&str] = &["new", "from", "with_capacity", "from_iter"];
const ALLOC_MACROS: &[&str] = &["vec", "format"];

/// Roots of the serving/solver hot path: the per-batch routing entry,
/// the Algorithm-1 dual updates, the branch-free selection and
/// cache-blocked layout kernels under them, the telemetry write seams,
/// and the profiler's per-frame record path (`ProfGuard` enter/drop).
const HOT_ROOTS: &[&str] = &[
    "route_batch_into",
    "update_in",
    "update_parallel_in",
    "update_adaptive_in",
    "update_adaptive_parallel_in",
    "topk_keys_into",
    "select_kth_key",
    "transpose_into",
    "transpose_cols_into",
    "fill_transpose",
    "counter_add",
    "gauge_set",
    "hist_observe",
    "ring_record",
    "expert_tokens_add",
    "expert_tokens_add_f32",
    "record_event",
    "begin_batch",
    "set_layer_ctx",
    "set_replica_ctx",
    "enter",
    "push_frame",
    "pop_frame_record",
    "record_path",
];

/// Files the hot-path closure is resolved within. `src/util/pool.rs`
/// is deliberately absent: the pool is the documented parallelism
/// boundary (it boxes jobs) and the parallel solver variants are
/// benched separately from the zero-alloc serial contract.
const HOT_SCOPE: &[&str] = &[
    "src/serve/router.rs",
    "src/routing/mod.rs",
    "src/bip/dual.rs",
    "src/bip/mod.rs",
    "src/bip/online.rs",
    "src/bip/approx.rs",
    "src/perf/arena.rs",
    "src/perf/kernels.rs",
    "src/perf/block.rs",
    "src/util/stats.rs",
    "src/telemetry/registry.rs",
    "src/telemetry/span.rs",
    "src/obs/event.rs",
    "src/prof/stack.rs",
    "src/prof/frame.rs",
];

/// Directories where panicking constructs need a `// LINT-ALLOW(panic)`.
const PANIC_DIRS: &[&str] = &[
    "src/serve/",
    "src/routing/",
    "src/bip/",
    "src/telemetry/",
    "src/obs/",
    "src/prof/",
];
const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];
const PANIC_METHODS: &[&str] = &["unwrap", "expect"];

const PANIC_ALLOW: &str = "LINT-ALLOW(panic)";

fn finding(out: &mut Vec<Finding>, lint: &str, path: &str, line: u32, msg: String) {
    out.push(Finding {
        lint: lint.to_string(),
        path: path.to_string(),
        line,
        msg,
    });
}

/// A call site edge, pre-resolution.
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord)]
enum Edge {
    /// `.f(…)` — resolves to any in-scope method named f
    Method(String),
    /// `X::f(…)` — resolves within impl blocks of X (or free fns for
    /// module-qualified calls like `registry::counter_add`)
    Qualified(String, String),
    /// `f(…)` — resolves to free functions only
    Bare(String),
}

fn call_edges(m: &Model, f: &FnItem) -> BTreeSet<Edge> {
    let toks = m.body_tokens(f);
    let mut out = BTreeSet::new();
    for x in 0..toks.len() {
        let t = &toks[x];
        if t.kind != TokKind::Ident {
            continue;
        }
        let nxt = if x + 1 < toks.len() { toks[x + 1].text.as_str() } else { "" };
        let prev = if x > 0 { toks[x - 1].text.as_str() } else { "" };
        if nxt != "(" || prev == "fn" {
            continue;
        }
        if prev == "." {
            out.insert(Edge::Method(t.text.clone()));
        } else if prev == ":"
            && x > 2
            && toks[x - 2].text == ":"
            && toks[x - 3].kind == TokKind::Ident
        {
            out.insert(Edge::Qualified(toks[x - 3].text.clone(), t.text.clone()));
        } else if prev != "!" {
            out.insert(Edge::Bare(t.text.clone()));
        }
    }
    out
}

/// `(line, construct)` for every allocating construct in `f`'s body.
fn alloc_sites(m: &Model, f: &FnItem) -> Vec<(u32, String)> {
    let toks = m.body_tokens(f);
    let mut out = Vec::new();
    for x in 0..toks.len() {
        let t = &toks[x];
        if t.kind != TokKind::Ident {
            continue;
        }
        let nxt = if x + 1 < toks.len() { toks[x + 1].text.as_str() } else { "" };
        let prev = if x > 0 { toks[x - 1].text.as_str() } else { "" };
        let prev2 = if x > 1 { toks[x - 2].text.as_str() } else { "" };
        if ALLOC_MACROS.contains(&t.text.as_str()) && nxt == "!" {
            out.push((t.line, format!("{}!", t.text)));
        } else if ALLOC_TYPE_FNS.contains(&t.text.as_str())
            && nxt == "("
            && prev == ":"
            && prev2 == ":"
            && x > 2
            && ALLOC_TYPES.contains(&toks[x - 3].text.as_str())
        {
            out.push((t.line, format!("{}::{}", toks[x - 3].text, t.text)));
        } else if ALLOC_METHODS.contains(&t.text.as_str())
            && nxt == "("
            && prev == "."
        {
            out.push((t.line, format!(".{}()", t.text)));
        }
    }
    out
}

/// hot-path-alloc: no allocating construct may be reachable from the
/// serving/solver hot roots. Reachability is a BFS over resolved call
/// edges within [`HOT_SCOPE`], stopping at `// COLD`-marked fns (the
/// documented allocating compat seams).
pub fn hot_path_alloc(models: &BTreeMap<String, Model>, out: &mut Vec<Finding>) {
    // name -> [(path, fn index)] over hot-scope fns with bodies
    let mut defs: BTreeMap<&str, Vec<(&str, usize)>> = BTreeMap::new();
    for rel in HOT_SCOPE {
        let Some(m) = models.get(*rel) else { continue };
        for (fi, f) in m.fns.iter().enumerate() {
            if f.in_test || f.body.is_none() {
                continue;
            }
            defs.entry(f.name.as_str()).or_default().push((rel, fi));
        }
    }
    let resolve = |caller: &FnItem, edge: &Edge| -> Vec<(String, usize)> {
        let (name, want_type): (&str, Option<Option<&str>>) = match edge {
            Edge::Method(n) => (n.as_str(), None),
            Edge::Qualified(q, n) => {
                let q = if q == "Self" {
                    caller.impl_type.as_deref().unwrap_or("Self")
                } else {
                    q.as_str()
                };
                (n.as_str(), Some(Some(q)))
            }
            Edge::Bare(n) => (n.as_str(), Some(None)),
        };
        let cands = defs.get(name).map(|v| v.as_slice()).unwrap_or(&[]);
        let pick = |keep: &dyn Fn(&FnItem) -> bool| -> Vec<(String, usize)> {
            cands
                .iter()
                .filter(|(r, fi)| keep(&models[*r].fns[*fi]))
                .map(|(r, fi)| (r.to_string(), *fi))
                .collect()
        };
        match want_type {
            // method call: any impl fn with that name
            None => pick(&|f| f.impl_type.is_some()),
            // qualified: impls of that type, falling back to free fns
            // (module-qualified calls like `registry::counter_add`)
            Some(Some(q)) => {
                let typed = pick(&|f| f.impl_type.as_deref() == Some(q));
                if typed.is_empty() {
                    pick(&|f| f.impl_type.is_none())
                } else {
                    typed
                }
            }
            // bare call: free functions only
            Some(None) => pick(&|f| f.impl_type.is_none()),
        }
    };
    let mut reached: BTreeSet<(String, u32)> = BTreeSet::new();
    let mut queue: Vec<(String, usize)> = Vec::new();
    for name in HOT_ROOTS {
        for (rel, fi) in defs.get(*name).map(|v| v.as_slice()).unwrap_or(&[]) {
            let key = (rel.to_string(), models[*rel].fns[*fi].line);
            if reached.insert(key) {
                queue.push((rel.to_string(), *fi));
            }
        }
    }
    while let Some((rel, fi)) = queue.pop() {
        let m = &models[rel.as_str()];
        let f = &m.fns[fi];
        for edge in call_edges(m, f) {
            for (crel, cfi) in resolve(f, &edge) {
                let cf = &models[crel.as_str()].fns[cfi];
                if cf.cold {
                    continue;
                }
                if reached.insert((crel.clone(), cf.line)) {
                    queue.push((crel, cfi));
                }
            }
        }
    }
    for (rel, m) in models {
        for f in &m.fns {
            if !reached.contains(&(rel.clone(), f.line)) {
                continue;
            }
            for (line, what) in alloc_sites(m, f) {
                finding(
                    out,
                    "hot-path-alloc",
                    rel,
                    line,
                    format!(
                        "allocating construct `{what}` in `{}` (reachable \
                         from the serving hot path)",
                        f.name
                    ),
                );
            }
        }
    }
}

/// unsafe-audit: every `unsafe` needs an attached `// SAFETY:` comment,
/// and the per-file unsafe census must match the checked-in inventory
/// in both directions (so new unsafe code forces a reviewed update).
pub fn unsafe_audit(
    models: &BTreeMap<String, Model>,
    inventory: &str,
    out: &mut Vec<Finding>,
) {
    const INV: &str = "analysis/unsafe_inventory.txt";
    let mut actual: BTreeMap<&str, (usize, u32)> = BTreeMap::new();
    for (rel, m) in models {
        if let Some(first) = m.unsafes.first() {
            actual.insert(rel.as_str(), (m.unsafes.len(), first.line));
        }
        for u in &m.unsafes {
            if !u.has_safety {
                finding(
                    out,
                    "unsafe-audit",
                    rel,
                    u.line,
                    format!(
                        "`unsafe` {} without a `// SAFETY:` comment",
                        u.kind.label()
                    ),
                );
            }
        }
    }
    let mut listed: BTreeMap<&str, usize> = BTreeMap::new();
    for (ln0, raw) in inventory.lines().enumerate() {
        let line = ln0 as u32 + 1;
        let s = raw.trim();
        if s.is_empty() || s.starts_with('#') {
            continue;
        }
        let mut parts = s.split_whitespace();
        let entry = (parts.next(), parts.next().and_then(|c| c.parse().ok()));
        let (Some(path), Some(count)) = entry else {
            finding(
                out,
                "unsafe-audit",
                INV,
                line,
                format!("malformed inventory line `{s}` (want `<path> <count>`)"),
            );
            continue;
        };
        listed.insert(path, count);
        match actual.get(path) {
            None => finding(
                out,
                "unsafe-audit",
                INV,
                line,
                format!("inventory lists `{path}` but the file has no unsafe code"),
            ),
            Some(&(have, first_line)) => {
                if have != count {
                    finding(
                        out,
                        "unsafe-audit",
                        path,
                        first_line,
                        format!(
                            "file has {have} unsafe items but the inventory \
                             says {count} (update {INV})"
                        ),
                    );
                }
            }
        }
    }
    for (path, &(have, first_line)) in &actual {
        if !listed.contains_key(path) {
            finding(
                out,
                "unsafe-audit",
                path,
                first_line,
                format!("file has {have} unsafe items but no entry in {INV}"),
            );
        }
    }
}

/// panic-path: no unwrap/expect/panic-family macro/indexing-with-a-
/// literal in the serving modules outside test code, unless annotated
/// `// LINT-ALLOW(panic): <reason>`.
pub fn panic_path(models: &BTreeMap<String, Model>, out: &mut Vec<Finding>) {
    for (rel, m) in models {
        if !PANIC_DIRS.iter().any(|d| rel.starts_with(d)) {
            continue;
        }
        let c = &m.code;
        for x in 0..c.len() {
            let t = &c[x];
            if m.in_test(t.line) {
                continue;
            }
            let nxt = if x + 1 < c.len() { c[x + 1].text.as_str() } else { "" };
            let prev = if x > 0 { c[x - 1].text.as_str() } else { "" };
            let hit: Option<String> = if t.kind == TokKind::Ident
                && PANIC_METHODS.contains(&t.text.as_str())
                && prev == "."
                && nxt == "("
            {
                Some(format!(".{}()", t.text))
            } else if t.kind == TokKind::Ident
                && PANIC_MACROS.contains(&t.text.as_str())
                && nxt == "!"
            {
                Some(format!("{}!", t.text))
            } else if t.kind == TokKind::Punct
                && t.text == "["
                && x > 0
                && (c[x - 1].kind == TokKind::Ident
                    || prev == ")"
                    || prev == "]")
                && x + 2 < c.len()
                && c[x + 1].kind == TokKind::Num
                && c[x + 2].text == "]"
            {
                Some(format!("indexing with literal `[{}]`", c[x + 1].text))
            } else {
                None
            };
            let Some(hit) = hit else { continue };
            if m.comment_above_matches(t.line, |txt| txt.contains(PANIC_ALLOW)) {
                continue;
            }
            finding(
                out,
                "panic-path",
                rel,
                t.line,
                format!("panicking construct {hit} on a serving module"),
            );
        }
    }
}

/// telemetry-naming: every metric name in the registry must match
/// `bip_moe_[a-z0-9_]+` (the `bip_moe_` prefix is prepended at
/// exposition), be unique, and pair with non-empty help text.
pub fn telemetry_naming(models: &BTreeMap<String, Model>, out: &mut Vec<Finding>) {
    const REG: &str = "src/telemetry/registry.rs";
    let Some(m) = models.get(REG) else { return };
    let mut names: Vec<(u32, String)> = Vec::new();
    let mut helps: Vec<(u32, String)> = Vec::new();
    for f in &m.fns {
        if f.in_test || f.body.is_none() {
            continue;
        }
        let dst = match f.name.as_str() {
            "name" => &mut names,
            "help" => &mut helps,
            _ => continue,
        };
        for t in m.body_tokens(f) {
            if t.kind == TokKind::Str {
                dst.push((t.line, t.text.trim_matches('"').to_string()));
            }
        }
    }
    let mut seen: BTreeMap<&str, u32> = BTreeMap::new();
    for (line, val) in &names {
        let ok = !val.is_empty()
            && val.chars().all(|ch| {
                ch.is_ascii_lowercase() || ch.is_ascii_digit() || ch == '_'
            });
        if !ok {
            finding(
                out,
                "telemetry-naming",
                REG,
                *line,
                format!("metric name `bip_moe_{val}` violates bip_moe_[a-z0-9_]+"),
            );
        }
        if let Some(first) = seen.get(val.as_str()) {
            finding(
                out,
                "telemetry-naming",
                REG,
                *line,
                format!("duplicate metric name `{val}` (first at line {first})"),
            );
        } else {
            seen.insert(val.as_str(), *line);
        }
    }
    for (line, val) in &helps {
        if val.trim().is_empty() {
            finding(out, "telemetry-naming", REG, *line, "empty help text".into());
        }
    }
    if names.len() != helps.len() {
        finding(
            out,
            "telemetry-naming",
            REG,
            1,
            format!("{} metric names but {} help strings", names.len(), helps.len()),
        );
    }
}

/// lock-discipline: fns marked `// HOT` may not name `Mutex`/`RwLock`
/// or call `.lock()` — the hot path is sharded atomics only.
pub fn lock_discipline(models: &BTreeMap<String, Model>, out: &mut Vec<Finding>) {
    for (rel, m) in models {
        for f in &m.fns {
            if !f.hot || f.body.is_none() {
                continue;
            }
            let toks = m.body_tokens(f);
            for x in 0..toks.len() {
                let t = &toks[x];
                if t.kind != TokKind::Ident {
                    continue;
                }
                let prev = if x > 0 { toks[x - 1].text.as_str() } else { "" };
                let nxt =
                    if x + 1 < toks.len() { toks[x + 1].text.as_str() } else { "" };
                if t.text == "Mutex"
                    || t.text == "RwLock"
                    || (t.text == "lock" && prev == "." && nxt == "(")
                {
                    finding(
                        out,
                        "lock-discipline",
                        rel,
                        t.line,
                        format!("lock use `{}` inside `// HOT` fn `{}`", t.text, f.name),
                    );
                }
            }
        }
    }
}

/// bench-honesty: a fn that writes a BENCH_*.json or PROF_*.json
/// record (has a `BENCH_`/`PROF_` string literal and calls a `write`)
/// must stamp `schema_version` into the payload, so cross-PR perf
/// consumers can detect shape drift instead of silently comparing
/// unlike records.
pub fn bench_honesty(models: &BTreeMap<String, Model>, out: &mut Vec<Finding>) {
    for (rel, m) in models {
        for f in &m.fns {
            if f.in_test || f.body.is_none() {
                continue;
            }
            let toks = m.body_tokens(f);
            let has_bench_lit = toks.iter().any(|t| {
                t.kind == TokKind::Str
                    && (t.text.contains("BENCH_")
                        || t.text.contains("PROF_"))
            });
            if !has_bench_lit {
                continue;
            }
            let is_writer = call_edges(m, f).iter().any(|e| {
                matches!(
                    e,
                    Edge::Method(n) | Edge::Qualified(_, n) | Edge::Bare(n)
                        if n == "write"
                )
            });
            let has_schema = toks.iter().any(|t| {
                t.kind == TokKind::Str && t.text.contains("schema_version")
            });
            if is_writer && !has_schema {
                finding(
                    out,
                    "bench-honesty",
                    rel,
                    f.line,
                    format!(
                        "`{}` writes a BENCH_/PROF_ record without declaring \
                         schema_version",
                        f.name
                    ),
                );
            }
        }
    }
}

/// Run every pass over `models`.
pub fn run_all(
    models: &BTreeMap<String, Model>,
    inventory: &str,
) -> Vec<Finding> {
    let mut out = Vec::new();
    hot_path_alloc(models, &mut out);
    unsafe_audit(models, inventory, &mut out);
    panic_path(models, &mut out);
    telemetry_naming(models, &mut out);
    lock_discipline(models, &mut out);
    bench_honesty(models, &mut out);
    out
}
