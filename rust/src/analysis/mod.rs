//! # analysis/ — self-hosted static lint suite
//!
//! Parses this repository's own Rust sources (no rustc, no external
//! crates — a hand-rolled [`lexer`] and a brace-matching syntactic
//! [`model`]) and proves the invariants the rest of the codebase
//! claims in prose, at CI time:
//!
//! * **hot-path-alloc** — nothing reachable from the serving/solver
//!   hot roots allocates (the §3 "very small time costs" claim);
//! * **unsafe-audit** — every `unsafe` carries a `// SAFETY:` comment
//!   and matches the checked-in `analysis/unsafe_inventory.txt`;
//! * **panic-path** — no unwrap/expect/panic-family/literal-indexing
//!   in the serving modules without a `// LINT-ALLOW(panic): reason`;
//! * **telemetry-naming** — metric names are `bip_moe_[a-z0-9_]+`,
//!   unique, with non-empty help;
//! * **lock-discipline** — `// HOT` fns never touch Mutex/RwLock;
//! * **bench-honesty** — every BENCH_*.json / PROF_*.json writer
//!   stamps a schema_version.
//!
//! Findings can be waived per line via `analysis/waivers.txt`
//! (mandatory reasons; unused waivers are themselves findings, so a
//! waiver cannot outlive the code it excuses). The CLI surface is
//! `bip-moe lint [--deny] [--json PATH] [--filter LINT] [--root DIR]`.

pub mod lexer;
pub mod lints;
pub mod model;
pub mod report;

use std::collections::BTreeMap;
use std::path::Path;

use model::Model;
pub use report::{render_json, render_text, Finding};

const WAIVERS_PATH: &str = "analysis/waivers.txt";

/// The input to a lint run: `(crate-relative path, source)` pairs plus
/// the waiver and unsafe-inventory files. Tests build one from fixture
/// strings; the CLI loads one from disk with [`SourceSet::from_root`].
pub struct SourceSet {
    pub files: Vec<(String, String)>,
    pub waivers: String,
    pub inventory: String,
}

impl SourceSet {
    /// Load `src/` and `benches/` (recursively, sorted) plus the
    /// `analysis/` policy files from a crate root. Missing policy
    /// files read as empty, which the lints then report against.
    pub fn from_root(root: &Path) -> std::io::Result<SourceSet> {
        let mut files = Vec::new();
        for sub in ["src", "benches"] {
            collect_rs(&root.join(sub), root, &mut files)?;
        }
        let read_opt = |rel: &str| -> String {
            std::fs::read_to_string(root.join(rel)).unwrap_or_default()
        };
        Ok(SourceSet {
            files,
            waivers: read_opt(WAIVERS_PATH),
            inventory: read_opt("analysis/unsafe_inventory.txt"),
        })
    }
}

fn collect_rs(
    dir: &Path,
    root: &Path,
    out: &mut Vec<(String, String)>,
) -> std::io::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    let mut entries: Vec<_> = std::fs::read_dir(dir)?
        .collect::<Result<Vec<_>, _>>()?
        .into_iter()
        .map(|e| e.path())
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, root, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            out.push((rel, std::fs::read_to_string(&path)?));
        }
    }
    Ok(())
}

/// One parsed waiver line: `<lint> <path>:<line> <reason…>`.
struct Waiver {
    lint: String,
    path: String,
    line: u32,
    /// line number inside waivers.txt (for stale-waiver reporting)
    file_line: u32,
}

fn parse_waivers(text: &str, out: &mut Vec<Finding>) -> Vec<Waiver> {
    let mut waivers = Vec::new();
    for (ln0, raw) in text.lines().enumerate() {
        let file_line = ln0 as u32 + 1;
        let s = raw.trim();
        if s.is_empty() || s.starts_with('#') {
            continue;
        }
        let mut parts = s.splitn(3, char::is_whitespace);
        let lint = parts.next().unwrap_or("");
        let key = parts.next().unwrap_or("");
        let reason = parts.next().unwrap_or("").trim();
        let parsed = key
            .rsplit_once(':')
            .and_then(|(p, l)| l.parse::<u32>().ok().map(|l| (p, l)));
        let Some((path, line)) = parsed else {
            out.push(Finding {
                lint: "waiver-syntax".into(),
                path: WAIVERS_PATH.into(),
                line: file_line,
                msg: format!(
                    "malformed waiver `{s}` (want `<lint> <path>:<line> <reason>`)"
                ),
            });
            continue;
        };
        if reason.is_empty() {
            out.push(Finding {
                lint: "waiver-syntax".into(),
                path: WAIVERS_PATH.into(),
                line: file_line,
                msg: format!("waiver `{lint} {key}` has no reason — reasons are mandatory"),
            });
            continue;
        }
        waivers.push(Waiver {
            lint: lint.to_string(),
            path: path.to_string(),
            line,
            file_line,
        });
    }
    waivers
}

/// Lint a [`SourceSet`]: lex + model every file, run all passes, apply
/// waivers (reporting stale ones), then sort and optionally filter to
/// one lint name. This is the single entry point the CLI and the
/// integration tests share.
pub fn run(set: &SourceSet, filter: Option<&str>) -> Vec<Finding> {
    let mut findings: Vec<Finding> = Vec::new();
    let mut models: BTreeMap<String, Model> = BTreeMap::new();
    for (rel, src) in &set.files {
        match lexer::lex(src) {
            Ok(toks) => {
                models.insert(rel.clone(), Model::new(rel, toks));
            }
            Err(e) => findings.push(Finding {
                lint: "lex-error".into(),
                path: rel.clone(),
                line: e.line,
                msg: e.msg.to_string(),
            }),
        }
    }
    findings.extend(lints::run_all(&models, &set.inventory));

    // waivers: drop matching findings, then report unused entries
    let waivers = parse_waivers(&set.waivers, &mut findings);
    let mut used = vec![false; waivers.len()];
    findings.retain(|f| {
        for (i, w) in waivers.iter().enumerate() {
            if w.lint == f.lint && w.path == f.path && w.line == f.line {
                used[i] = true;
                return false;
            }
        }
        true
    });
    for (w, was_used) in waivers.iter().zip(&used) {
        if !was_used {
            findings.push(Finding {
                lint: "stale-waiver".into(),
                path: WAIVERS_PATH.into(),
                line: w.file_line,
                msg: format!(
                    "waiver `{} {}:{}` matches no finding — remove it",
                    w.lint, w.path, w.line
                ),
            });
        }
    }

    if let Some(name) = filter {
        findings.retain(|f| f.lint == name);
    }
    findings.sort();
    findings
}
