//! Syntactic model over the token stream: items the lints reason
//! about — functions (with spans, enclosing impl type, attached
//! comments, `#[cfg(test)]` coverage) and `unsafe` occurrences.
//!
//! This is deliberately NOT an AST. The lints only need four
//! structural facts: where each fn's body starts and ends (brace
//! matching over the comment-stripped token stream), which impl block
//! it sits in (for `X::f` call resolution), which comment text is
//! attached to it (for `// HOT` / `// COLD` / `// SAFETY:` markers),
//! and whether a given line is inside test-gated code.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{Tok, TokKind};

/// One `fn` item.
#[derive(Clone, Debug)]
pub struct FnItem {
    pub name: String,
    /// line of the `fn` keyword
    pub line: u32,
    /// code-token index range of the body `{ … }` (None for trait
    /// method declarations without a default body)
    pub body: Option<(usize, usize)>,
    /// enclosing `impl` block's type name (None for free functions)
    pub impl_type: Option<String>,
    pub in_test: bool,
    /// attached comment carries a `// HOT` marker (lock-discipline scope)
    pub hot: bool,
    /// attached comment carries a `// COLD` marker (hot-path BFS stops)
    pub cold: bool,
    pub is_unsafe: bool,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum UnsafeKind {
    Block,
    Impl,
    Fn,
    Trait,
}

impl UnsafeKind {
    pub fn label(self) -> &'static str {
        match self {
            UnsafeKind::Block => "block",
            UnsafeKind::Impl => "impl",
            UnsafeKind::Fn => "fn",
            UnsafeKind::Trait => "trait",
        }
    }
}

/// One `unsafe` block/impl/fn/trait occurrence.
#[derive(Clone, Debug)]
pub struct UnsafeItem {
    pub line: u32,
    pub kind: UnsafeKind,
    /// a `// SAFETY:` comment is attached (same line or the contiguous
    /// comment block directly above)
    pub has_safety: bool,
}

/// Per-file syntactic model.
pub struct Model {
    pub path: String,
    /// token stream with comments stripped (brace matching and call
    /// scanning operate on this)
    pub code: Vec<Tok>,
    /// line -> concatenated text of every comment token covering it
    comment_lines: BTreeMap<u32, String>,
    /// lines that carry at least one non-comment token
    noncomment_lines: BTreeSet<u32>,
    /// line ranges covered by `#[cfg(test)]` / `#[test]` items
    pub test_ranges: Vec<(u32, u32)>,
    pub fns: Vec<FnItem>,
    pub unsafes: Vec<UnsafeItem>,
}

impl Model {
    pub fn new(path: &str, toks: Vec<Tok>) -> Model {
        let mut comment_lines: BTreeMap<u32, String> = BTreeMap::new();
        let mut noncomment_lines: BTreeSet<u32> = BTreeSet::new();
        for t in &toks {
            if t.kind == TokKind::Comment {
                // a multi-line comment covers every line it spans; each
                // covered line maps to the full comment text so marker
                // searches see the whole annotation
                for (off, _) in t.text.split('\n').enumerate() {
                    let l = t.line + off as u32;
                    comment_lines.entry(l).or_default().push_str(&t.text);
                }
            } else {
                noncomment_lines.insert(t.line);
            }
        }
        let code: Vec<Tok> = toks
            .into_iter()
            .filter(|t| t.kind != TokKind::Comment)
            .collect();
        let mut m = Model {
            path: path.to_string(),
            code,
            comment_lines,
            noncomment_lines,
            test_ranges: Vec::new(),
            fns: Vec::new(),
            unsafes: Vec::new(),
        };
        m.test_ranges = m.find_test_ranges();
        m.fns = m.find_fns();
        m.unsafes = m.find_unsafes();
        m
    }

    fn tok_text(&self, i: usize) -> &str {
        if i < self.code.len() {
            &self.code[i].text
        } else {
            ""
        }
    }

    /// Code index of `{` -> code index of the matching `}`.
    fn match_brace(&self, ci: usize) -> usize {
        let mut depth = 0i64;
        for j in ci..self.code.len() {
            let t = &self.code[j];
            if t.kind == TokKind::Punct && t.text == "{" {
                depth += 1;
            } else if t.kind == TokKind::Punct && t.text == "}" {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
        }
        self.code.len().saturating_sub(1)
    }

    /// Line ranges covered by `#[cfg(test)]` / `#[test]` items.
    fn find_test_ranges(&self) -> Vec<(u32, u32)> {
        let c = &self.code;
        let mut out = Vec::new();
        for j in 0..c.len() {
            if !(c[j].kind == TokKind::Punct && c[j].text == "#") {
                continue;
            }
            if self.tok_text(j + 1) != "[" {
                continue;
            }
            // collect attr idents until the matching ]
            let mut depth = 0i64;
            let mut k = j + 1;
            let mut words: Vec<&str> = Vec::new();
            while k < c.len() {
                let t = &c[k];
                if t.text == "[" {
                    depth += 1;
                } else if t.text == "]" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if t.kind == TokKind::Ident {
                    words.push(&t.text);
                }
                k += 1;
            }
            let is_test = words.contains(&"test")
                && matches!(words.first(), Some(&"cfg") | Some(&"test"));
            if !is_test {
                continue;
            }
            // body of the following item
            let mut m = k;
            while m < c.len()
                && !(c[m].kind == TokKind::Punct
                    && (c[m].text == "{" || c[m].text == ";"))
            {
                m += 1;
            }
            if m < c.len() && c[m].text == "{" {
                let e = self.match_brace(m);
                out.push((c[j].line, c[e].line));
            }
        }
        out
    }

    pub fn in_test(&self, line: u32) -> bool {
        self.test_ranges.iter().any(|&(a, b)| a <= line && line <= b)
    }

    pub fn comment_on(&self, line: u32) -> &str {
        self.comment_lines.get(&line).map(|s| s.as_str()).unwrap_or("")
    }

    /// True if `line` (or the contiguous comment-only block directly
    /// above it) has a comment for which `pred` holds. This is the
    /// shared attachment rule for `// SAFETY:` and `// LINT-ALLOW`.
    pub fn comment_above_matches<F: Fn(&str) -> bool>(
        &self,
        line: u32,
        pred: F,
    ) -> bool {
        if pred(self.comment_on(line)) {
            return true;
        }
        let mut l = line.saturating_sub(1);
        while l > 0
            && self.comment_lines.contains_key(&l)
            && !self.noncomment_lines.contains(&l)
        {
            if pred(self.comment_on(l)) {
                return true;
            }
            l -= 1;
        }
        false
    }

    /// Comment text attached above code token `ci` (contiguous
    /// comment-only lines directly above, plus the same line).
    fn attached_comment(&self, ci: usize) -> String {
        let ln = self.code[ci].line;
        let mut texts: Vec<&str> = Vec::new();
        if let Some(t) = self.comment_lines.get(&ln) {
            texts.push(t);
        }
        let mut l = ln.saturating_sub(1);
        while l > 0
            && self.comment_lines.contains_key(&l)
            && !self.noncomment_lines.contains(&l)
        {
            if let Some(t) = self.comment_lines.get(&l) {
                texts.push(t);
            }
            l -= 1;
        }
        texts.join("\n")
    }

    fn find_fns(&self) -> Vec<FnItem> {
        let c = &self.code;
        let mut out = Vec::new();
        // impl blocks: (body start, body end, type name). The type is
        // the last depth-0 ident before `{`, with `for` resetting it so
        // `impl Trait for Type` yields Type.
        let mut impl_ranges: Vec<(usize, usize, Option<String>)> = Vec::new();
        for j in 0..c.len() {
            if !(c[j].kind == TokKind::Ident && c[j].text == "impl") {
                continue;
            }
            let mut k = j + 1;
            let mut last: Option<&str> = None;
            let mut depth = 0i64;
            while k < c.len() {
                let t = &c[k];
                if t.text == "<" {
                    depth += 1;
                } else if t.text == ">" {
                    depth -= 1;
                } else if t.kind == TokKind::Ident && depth == 0 {
                    if t.text == "for" {
                        last = None;
                    } else if t.text != "where" {
                        last = Some(&t.text);
                    }
                }
                if t.text == "{" && depth <= 0 {
                    break;
                }
                k += 1;
            }
            if k < c.len() {
                let e = self.match_brace(k);
                impl_ranges.push((k, e, last.map(|s| s.to_string())));
            }
        }
        let impl_of = |j: usize| -> Option<String> {
            impl_ranges
                .iter()
                .find(|&&(a, b, _)| a <= j && j <= b)
                .and_then(|(_, _, name)| name.clone())
        };
        for j in 0..c.len() {
            if !(c[j].kind == TokKind::Ident && c[j].text == "fn") {
                continue;
            }
            if j + 1 >= c.len() || c[j + 1].kind != TokKind::Ident {
                continue;
            }
            let name = c[j + 1].text.clone();
            // walk to the body `{` (or the decl-ending `;`)
            let mut k = j + 2;
            let mut pdepth = 0i64;
            let mut body = None;
            while k < c.len() {
                let txt = c[k].text.as_str();
                if txt == "(" || txt == "<" || txt == "[" {
                    pdepth += 1;
                } else if txt == ")" || txt == ">" || txt == "]" {
                    pdepth -= 1;
                } else if txt == "-" && self.tok_text(k + 1) == ">" {
                    k += 2;
                    continue;
                } else if txt == "{" && pdepth <= 0 {
                    body = Some((k, self.match_brace(k)));
                    break;
                } else if txt == ";" && pdepth <= 0 {
                    break;
                }
                k += 1;
            }
            // walk back over modifiers (pub, const, unsafe, extern,
            // async, pub(crate), extern "C") and #[attr] groups to the
            // item start, so attached comments above attributes attach
            let mut is_unsafe = false;
            let mut b = j as i64 - 1;
            while b >= 0 {
                let t = &c[b as usize];
                let modifier = t.kind == TokKind::Ident
                    && matches!(
                        t.text.as_str(),
                        "pub" | "const" | "unsafe" | "extern" | "async"
                            | "crate" | "in" | "super" | "self"
                    );
                if modifier
                    || (t.kind == TokKind::Punct
                        && (t.text == "(" || t.text == ")"))
                    || t.kind == TokKind::Str
                {
                    if modifier && t.text == "unsafe" {
                        is_unsafe = true;
                    }
                    b -= 1;
                } else if t.kind == TokKind::Punct && t.text == "]" {
                    let mut depth = 0i64;
                    while b >= 0 {
                        let u = &c[b as usize];
                        if u.text == "]" {
                            depth += 1;
                        } else if u.text == "[" {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        b -= 1;
                    }
                    b -= 1;
                    if b >= 0 && c[b as usize].text == "#" {
                        b -= 1;
                    }
                } else {
                    break;
                }
            }
            let attach_idx = (b + 1) as usize;
            let comment = self.attached_comment(attach_idx);
            out.push(FnItem {
                name,
                line: c[j].line,
                body,
                impl_type: impl_of(j),
                in_test: self.in_test(c[j].line),
                hot: comment_has_marker(&comment, "HOT"),
                cold: comment_has_marker(&comment, "COLD"),
                is_unsafe,
            });
        }
        out
    }

    fn find_unsafes(&self) -> Vec<UnsafeItem> {
        let c = &self.code;
        let mut out = Vec::new();
        for j in 0..c.len() {
            if !(c[j].kind == TokKind::Ident && c[j].text == "unsafe") {
                continue;
            }
            let kind = match self.tok_text(j + 1) {
                "{" => UnsafeKind::Block,
                "impl" => UnsafeKind::Impl,
                "fn" => UnsafeKind::Fn,
                "trait" => UnsafeKind::Trait,
                _ => continue,
            };
            let ln = c[j].line;
            let has_safety =
                self.comment_above_matches(ln, |t| t.contains("SAFETY"));
            out.push(UnsafeItem { line: ln, kind, has_safety });
        }
        out
    }

    /// Body token slice for a fn (empty for bodiless declarations).
    pub fn body_tokens(&self, f: &FnItem) -> &[Tok] {
        match f.body {
            Some((a, b)) => &self.code[a..=b.min(self.code.len() - 1)],
            None => &[],
        }
    }
}

/// True if `text` contains a `// <MARKER>` comment — slashes, optional
/// whitespace, then the marker at a word boundary (so `// HOT: …` and
/// `/// HOT` match but `// SHOTGUN` and `// HOTEL` do not).
pub fn comment_has_marker(text: &str, marker: &str) -> bool {
    let mut rest = text;
    while let Some(pos) = rest.find("//") {
        let after = rest[pos + 2..].trim_start_matches(['/', ' ', '\t']);
        if let Some(tail) = after.strip_prefix(marker) {
            let boundary = tail
                .chars()
                .next()
                .map(|ch| !(ch.is_ascii_alphanumeric() || ch == '_'))
                .unwrap_or(true);
            if boundary {
                return true;
            }
        }
        rest = &rest[pos + 2..];
    }
    false
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn model(src: &str) -> Model {
        Model::new("src/test_fixture.rs", lex(src).expect("fixture lexes"))
    }

    #[test]
    fn fn_extraction_with_impl_and_markers() {
        let m = model(
            "struct S;\n\
             impl S {\n\
                 // HOT: per-batch\n\
                 #[inline]\n\
                 pub fn go(&self) -> usize { self.len() }\n\
             }\n\
             // COLD: compat seam\n\
             pub fn free() {}\n",
        );
        let go = m.fns.iter().find(|f| f.name == "go").expect("go found");
        assert_eq!(go.impl_type.as_deref(), Some("S"));
        assert!(go.hot && !go.cold);
        let free = m.fns.iter().find(|f| f.name == "free").expect("free found");
        assert!(free.impl_type.is_none());
        assert!(free.cold && !free.hot);
    }

    #[test]
    fn trait_impl_resolves_to_type() {
        let m = model(
            "impl Router for BipRouter {\n\
                 fn route(&mut self) {}\n\
             }\n",
        );
        let f = &m.fns[0];
        assert_eq!(f.impl_type.as_deref(), Some("BipRouter"));
    }

    #[test]
    fn test_ranges_cover_cfg_test_modules() {
        let m = model(
            "fn live() {}\n\
             #[cfg(test)]\n\
             mod tests {\n\
                 #[test]\n\
                 fn t() { live(); }\n\
             }\n",
        );
        assert!(!m.in_test(1));
        assert!(m.in_test(4));
        assert!(m.in_test(5));
        let t = m.fns.iter().find(|f| f.name == "t").expect("t found");
        assert!(t.in_test);
    }

    #[test]
    fn unsafe_detection_and_safety_attachment() {
        let m = model(
            "fn a() {\n\
                 // SAFETY: justified\n\
                 let x = unsafe { core::ptr::read(p) };\n\
                 let y = unsafe { core::ptr::read(q) };\n\
                 let _ = (x, y);\n\
             }\n\
             // SAFETY: delegated\n\
             unsafe impl Send for W {}\n",
        );
        assert_eq!(m.unsafes.len(), 3);
        assert!(m.unsafes[0].has_safety);
        assert!(!m.unsafes[1].has_safety);
        assert_eq!(m.unsafes[2].kind, UnsafeKind::Impl);
        assert!(m.unsafes[2].has_safety);
    }

    #[test]
    fn marker_word_boundary() {
        assert!(comment_has_marker("// HOT: x", "HOT"));
        assert!(comment_has_marker("/// HOT", "HOT"));
        assert!(!comment_has_marker("// HOTEL", "HOT"));
        assert!(!comment_has_marker("// SHOTGUN", "HOT"));
        assert!(!comment_has_marker("no comment HOT", "HOT"));
    }
}
