//! Hand-rolled Rust lexer for the self-hosted lint suite.
//!
//! Deliberately dependency-free (the build is fully offline): a single
//! forward pass over the source chars producing line-stamped tokens.
//! It is NOT a full Rust lexer — it only has to be exact about the
//! places where a naive scanner mis-tokenizes real code in this repo:
//!
//! * nested block comments (`/* /* */ */` — Rust nests, C does not);
//! * raw strings `r"…"` / `r#"…"#` with arbitrary hash counts, and raw
//!   identifiers `r#ident`;
//! * byte strings `b"…"` and byte chars `b'x'`;
//! * char literal vs lifetime (`'a'` is a char, `'a` in `&'a T` is a
//!   lifetime; `'\n'` and `'\''` are escaped chars);
//! * numeric literals with underscores, base prefixes, exponents, and
//!   type suffixes, without eating the `.` of `0..n` or `1.max(x)`.
//!
//! Everything else is an identifier or a one-char punct token, which
//! is all the downstream [`super::model`] layer needs.

/// Token classes. Comments are kept (the lint layer reads `// SAFETY:`
/// and `// LINT-ALLOW` annotations); whitespace is dropped.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TokKind {
    Comment,
    Ident,
    Lifetime,
    Char,
    Num,
    Str,
    Punct,
}

/// One token with its 1-based starting line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// Lex failure (unterminated literal/comment); carries the line where
/// scanning stopped.
#[derive(Clone, Debug)]
pub struct LexError {
    pub line: u32,
    pub msg: &'static str,
}

impl std::fmt::Display for LexError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}: {}", self.line, self.msg)
    }
}

fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_ascii_alphabetic()
}

fn is_ident_cont(c: char) -> bool {
    c == '_' || c.is_ascii_alphanumeric()
}

/// Tokenize `src`. Non-ASCII chars outside strings/comments come out
/// as single punct tokens (fine: they only occur in doc prose here).
pub fn lex(src: &str) -> Result<Vec<Tok>, LexError> {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let text = |a: usize, b: usize| -> String { s[a..b].iter().collect() };
    let mut toks: Vec<Tok> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = s[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // line comment
        if c == '/' && i + 1 < n && s[i + 1] == '/' {
            let mut j = i;
            while j < n && s[j] != '\n' {
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Comment, text: text(i, j), line });
            i = j;
            continue;
        }
        // block comment — Rust block comments NEST
        if c == '/' && i + 1 < n && s[i + 1] == '*' {
            let (start, startline) = (i, line);
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if s[i] == '/' && i + 1 < n && s[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if s[i] == '*' && i + 1 < n && s[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if s[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            if depth != 0 {
                return Err(LexError { line, msg: "unterminated block comment" });
            }
            toks.push(Tok {
                kind: TokKind::Comment,
                text: text(start, i),
                line: startline,
            });
            continue;
        }
        // raw strings / raw idents / byte literals: r"", r#""#, br"", b"", b''
        if (c == 'r' || c == 'b') && i + 1 < n {
            let mut j = i;
            let mut saw_b = false;
            if s[j] == 'b' {
                saw_b = true;
                j += 1;
            }
            let mut saw_r = false;
            if j < n && s[j] == 'r' {
                saw_r = true;
                j += 1;
            }
            if saw_r {
                let mut hashes = 0usize;
                while j < n && s[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && s[j] == '"' {
                    // raw (byte) string: closes at `"` + `hashes` hashes
                    let mut k = j + 1;
                    let mut end = None;
                    while k < n {
                        if s[k] == '"' {
                            let mut h = 0usize;
                            while h < hashes && k + 1 + h < n && s[k + 1 + h] == '#' {
                                h += 1;
                            }
                            if h == hashes {
                                end = Some(k);
                                break;
                            }
                        }
                        k += 1;
                    }
                    let Some(k) = end else {
                        return Err(LexError { line, msg: "unterminated raw string" });
                    };
                    let t = text(i, k + 1 + hashes);
                    let startline = line;
                    line += t.chars().filter(|&ch| ch == '\n').count() as u32;
                    toks.push(Tok { kind: TokKind::Str, text: t, line: startline });
                    i = k + 1 + hashes;
                    continue;
                }
                if hashes == 1 && j < n && is_ident_start(s[j]) {
                    // raw identifier r#ident
                    let mut k = j;
                    while k < n && is_ident_cont(s[k]) {
                        k += 1;
                    }
                    toks.push(Tok { kind: TokKind::Ident, text: text(i, k), line });
                    i = k;
                    continue;
                }
                // plain ident starting with r/b falls through below
            }
            if saw_b && j < n && (s[j] == '"' || s[j] == '\'') {
                let quote = s[j];
                let mut k = j + 1;
                let mut terminated = false;
                while k < n {
                    if s[k] == '\\' {
                        k += 2;
                        continue;
                    }
                    if s[k] == quote {
                        terminated = true;
                        break;
                    }
                    if s[k] == '\n' {
                        line += 1;
                    }
                    k += 1;
                }
                if !terminated {
                    return Err(LexError { line, msg: "unterminated byte literal" });
                }
                let kind = if quote == '"' { TokKind::Str } else { TokKind::Char };
                toks.push(Tok { kind, text: text(i, k + 1), line });
                i = k + 1;
                continue;
            }
        }
        // regular string
        if c == '"' {
            let startline = line;
            let mut k = i + 1;
            let mut terminated = false;
            while k < n {
                if s[k] == '\\' {
                    if k + 1 < n && s[k + 1] == '\n' {
                        line += 1;
                    }
                    k += 2;
                    continue;
                }
                if s[k] == '"' {
                    terminated = true;
                    break;
                }
                if s[k] == '\n' {
                    line += 1;
                }
                k += 1;
            }
            if !terminated {
                return Err(LexError { line, msg: "unterminated string" });
            }
            toks.push(Tok {
                kind: TokKind::Str,
                text: text(i, k + 1),
                line: startline,
            });
            i = k + 1;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            if i + 1 < n && s[i + 1] == '\\' {
                // escaped char: skip the escaped character, then scan
                // to the closing quote (covers '\n', '\\', '\'', '\u{…}')
                let mut k = i + 3;
                while k < n && s[k] != '\'' {
                    k += 1;
                }
                if k >= n {
                    return Err(LexError { line, msg: "unterminated char literal" });
                }
                toks.push(Tok { kind: TokKind::Char, text: text(i, k + 1), line });
                i = k + 1;
                continue;
            }
            // one char then a closing quote => char literal, else lifetime
            if i + 2 < n && s[i + 2] == '\'' && s[i + 1] != '\'' {
                toks.push(Tok { kind: TokKind::Char, text: text(i, i + 3), line });
                i += 3;
                continue;
            }
            let mut k = i + 1;
            while k < n && is_ident_cont(s[k]) {
                k += 1;
            }
            if k == i + 1 {
                return Err(LexError { line, msg: "stray single quote" });
            }
            toks.push(Tok { kind: TokKind::Lifetime, text: text(i, k), line });
            i = k;
            continue;
        }
        // number: base prefixes, underscores, float part only when a
        // digit follows the dot (so `0..n` and `1.max(x)` lex right),
        // exponent, then any type suffix
        if c.is_ascii_digit() {
            let mut k = i;
            let nxt = if i + 1 < n { s[i + 1] } else { '\0' };
            if c == '0' && (nxt == 'x' || nxt == 'o' || nxt == 'b') {
                k = i + 2;
                while k < n && is_ident_cont(s[k]) {
                    k += 1;
                }
            } else {
                while k < n && (s[k].is_ascii_digit() || s[k] == '_') {
                    k += 1;
                }
                if k < n && s[k] == '.' && k + 1 < n && s[k + 1].is_ascii_digit() {
                    k += 1;
                    while k < n && (s[k].is_ascii_digit() || s[k] == '_') {
                        k += 1;
                    }
                }
                if k < n && (s[k] == 'e' || s[k] == 'E') {
                    let plain = k + 1 < n && s[k + 1].is_ascii_digit();
                    let signed = k + 2 < n
                        && (s[k + 1] == '+' || s[k + 1] == '-')
                        && s[k + 2].is_ascii_digit();
                    if plain || signed {
                        k += if signed { 2 } else { 1 };
                        while k < n && (s[k].is_ascii_digit() || s[k] == '_') {
                            k += 1;
                        }
                    }
                }
                // type suffix (u32, f64, usize, …)
                while k < n && is_ident_cont(s[k]) {
                    k += 1;
                }
            }
            toks.push(Tok { kind: TokKind::Num, text: text(i, k), line });
            i = k;
            continue;
        }
        if is_ident_start(c) {
            let mut k = i;
            while k < n && is_ident_cont(s[k]) {
                k += 1;
            }
            toks.push(Tok { kind: TokKind::Ident, text: text(i, k), line });
            i = k;
            continue;
        }
        toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    Ok(toks)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src)
            .expect("fixture must lex")
            .into_iter()
            .map(|t| (t.kind, t.text))
            .collect()
    }

    #[test]
    fn raw_strings_with_hashes() {
        let toks = kinds(r####"let s = r#"quote " inside"#;"####);
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokKind::Str && t == r###"r#"quote " inside"#"###));
        // two hashes, with a `"#` inside that must NOT close it
        let toks = kinds("let s = r##\"one \"# two\"##;");
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokKind::Str && t == "r##\"one \"# two\"##"));
        // raw ident is one Ident token
        let toks = kinds("let r#fn = 1;");
        assert!(toks.iter().any(|(k, t)| *k == TokKind::Ident && t == "r#fn"));
    }

    #[test]
    fn nested_block_comments() {
        let toks = kinds("a /* outer /* inner */ still comment */ b");
        let comments: Vec<_> =
            toks.iter().filter(|(k, _)| *k == TokKind::Comment).collect();
        assert_eq!(comments.len(), 1);
        assert_eq!(comments[0].1, "/* outer /* inner */ still comment */");
        let idents: Vec<_> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Ident)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(idents, vec!["a", "b"]);
        assert!(lex("/* never closed /* */").is_err());
    }

    #[test]
    fn char_vs_lifetime() {
        let toks = kinds("fn f<'a>(x: &'a str) -> char { 'a' }");
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokKind::Lifetime && t == "'a"));
        assert!(toks.iter().any(|(k, t)| *k == TokKind::Char && t == "'a'"));
        // escaped chars, including the escaped quote
        for lit in ["'\\n'", "'\\''", "'\\\\'", "'\\u{1F600}'"] {
            let src = format!("let c = {lit};");
            let toks = kinds(&src);
            assert!(
                toks.iter().any(|(k, t)| *k == TokKind::Char && t == lit),
                "missing char token {lit} in {src}"
            );
        }
        // byte char and byte string
        let toks = kinds("let b = b'x'; let s = b\"bytes\";");
        assert!(toks.iter().any(|(k, t)| *k == TokKind::Char && t == "b'x'"));
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokKind::Str && t == "b\"bytes\""));
    }

    #[test]
    fn numeric_literals() {
        let toks = kinds("let x = 1_000_000u64 + 0xFF_u8 + 2.5e-4f64 + 0b1010;");
        let nums: Vec<_> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Num)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(nums, vec!["1_000_000u64", "0xFF_u8", "2.5e-4f64", "0b1010"]);
        // range and method-on-int must not eat the dot
        let toks = kinds("for i in 0..n { 1.max(i); }");
        let nums: Vec<_> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Num)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(nums, vec!["0", "1"]);
    }

    #[test]
    fn line_numbers_track_multiline_tokens() {
        let src = "a\n\"two\nline\"\nb /* c\nd */ e";
        let toks = lex(src).expect("fixture must lex");
        let find = |txt: &str| {
            toks.iter()
                .find(|t| t.text.starts_with(txt))
                .map(|t| t.line)
                .unwrap_or(0)
        };
        assert_eq!(find("a"), 1);
        assert_eq!(find("\"two"), 2);
        assert_eq!(find("b"), 4);
        assert_eq!(find("/*"), 4);
        assert_eq!(find("e"), 5);
    }

    #[test]
    fn round_trip_preserves_non_whitespace() {
        let src = r#"
            // comment with "a string"
            fn f<'a>(x: &'a [u8]) -> Vec<u8> {
                let s = r#ident; /* nested /* deep */ ok */
                x.iter().map(|b| b + 1_u8).collect()
            }
        "#;
        let toks = lex(src).expect("fixture must lex");
        let got: String = toks
            .iter()
            .flat_map(|t| t.text.chars())
            .filter(|c| !c.is_whitespace())
            .collect();
        let want: String = src.chars().filter(|c| !c.is_whitespace()).collect();
        assert_eq!(got, want);
    }
}
