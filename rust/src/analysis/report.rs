//! Finding type and the two output formats: rustc-style text lines
//! (`file:line: lint-name: message`) for humans and a JSON document
//! for the CI artifact.

use crate::util::json::Json;

/// One lint finding. Ordering is (lint, path, line, msg) so reports
/// group by lint and read top-to-bottom within a file.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub lint: String,
    pub path: String,
    pub line: u32,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}: {}", self.path, self.line, self.lint, self.msg)
    }
}

/// Render findings as rustc-style lines plus a trailing count.
pub fn render_text(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&f.to_string());
        out.push('\n');
    }
    out.push_str(&format!("{} findings\n", findings.len()));
    out
}

/// Render findings as the CI artifact document.
pub fn render_json(findings: &[Finding]) -> Json {
    Json::obj(vec![
        ("schema_version", Json::Num(1.0)),
        ("count", Json::Num(findings.len() as f64)),
        (
            "findings",
            Json::Arr(
                findings
                    .iter()
                    .map(|f| {
                        Json::obj(vec![
                            ("lint", Json::Str(f.lint.clone())),
                            ("path", Json::Str(f.path.clone())),
                            ("line", Json::Num(f.line as f64)),
                            ("msg", Json::Str(f.msg.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Finding> {
        vec![
            Finding {
                lint: "panic-path".into(),
                path: "src/serve/x.rs".into(),
                line: 7,
                msg: "panicking construct .unwrap() on a serving module".into(),
            },
            Finding {
                lint: "hot-path-alloc".into(),
                path: "src/bip/dual.rs".into(),
                line: 3,
                msg: "allocating construct `vec!` in `f`".into(),
            },
        ]
    }

    #[test]
    fn text_format_is_rustc_style() {
        let mut fs = sample();
        fs.sort();
        let text = render_text(&fs);
        assert!(text.starts_with(
            "src/bip/dual.rs:3: hot-path-alloc: allocating construct"
        ));
        assert!(text.contains("src/serve/x.rs:7: panic-path:"));
        assert!(text.ends_with("2 findings\n"));
    }

    #[test]
    fn json_round_trips() {
        let doc = render_json(&sample()).to_string();
        let parsed = Json::parse(&doc).expect("emitted JSON parses");
        assert_eq!(parsed.path("count"), Some(&Json::Num(2.0)));
        assert_eq!(parsed.path("schema_version"), Some(&Json::Num(1.0)));
        match parsed.path("findings") {
            Some(Json::Arr(items)) => assert_eq!(items.len(), 2),
            other => panic!("findings not an array: {other:?}"),
        }
    }
}
