//! Run-configuration layer: typed experiment descriptions that can be
//! loaded from JSON files (`configs/*.json`), merged with CLI overrides,
//! and stamped into run reports — the front door a deployment would use
//! instead of hand-assembled TrainDriver values.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::train::TrainDriver;
use crate::util::json::Json;

/// A named experiment: which model config, routing mode, and budget.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub name: String,
    pub model: String,
    pub mode: String,
    pub bip_t: usize,
    pub steps: u64,
    pub seed: i32,
    pub eval_batches: u64,
    pub sim_devices: usize,
    pub data_seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            name: "default".into(),
            model: "moe16-bench".into(),
            mode: "bip".into(),
            bip_t: 4,
            steps: 100,
            seed: 0,
            eval_batches: 8,
            sim_devices: 4,
            data_seed: 20240601,
        }
    }
}

impl RunConfig {
    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let d = RunConfig::default();
        let gs = |k: &str, dv: &str| {
            j.get(k).and_then(Json::as_str).unwrap_or(dv).to_string()
        };
        let gu = |k: &str, dv: usize| {
            j.get(k).and_then(Json::as_usize).unwrap_or(dv)
        };
        let mode = gs("mode", &d.mode);
        if !["aux", "lossfree", "bip"].contains(&mode.as_str()) {
            return Err(anyhow!("invalid mode {mode:?}"));
        }
        Ok(RunConfig {
            name: gs("name", &d.name),
            model: gs("model", &d.model),
            mode,
            bip_t: gu("bip_t", d.bip_t),
            steps: gu("steps", d.steps as usize) as u64,
            seed: gu("seed", d.seed as usize) as i32,
            eval_batches: gu("eval_batches", d.eval_batches as usize) as u64,
            sim_devices: gu("sim_devices", d.sim_devices),
            data_seed: gu("data_seed", d.data_seed as usize) as u64,
        })
    }

    pub fn load(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text).map_err(|e| anyhow!("{e}"))?)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("model", Json::Str(self.model.clone())),
            ("mode", Json::Str(self.mode.clone())),
            ("bip_t", Json::Num(self.bip_t as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("eval_batches", Json::Num(self.eval_batches as f64)),
            ("sim_devices", Json::Num(self.sim_devices as f64)),
            ("data_seed", Json::Num(self.data_seed as f64)),
        ])
    }

    pub fn driver(&self) -> TrainDriver {
        let mut d =
            TrainDriver::new(&self.model, &self.mode, self.bip_t, self.steps);
        d.seed = self.seed;
        d.eval_batches = self.eval_batches;
        d.sim_devices = self.sim_devices;
        d.data_seed = self.data_seed;
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trip() {
        let cfg = RunConfig {
            name: "exp1".into(),
            model: "moe64-bench".into(),
            mode: "lossfree".into(),
            bip_t: 8,
            steps: 250,
            seed: 3,
            eval_batches: 12,
            sim_devices: 8,
            data_seed: 99,
        };
        let parsed = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(parsed, cfg);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let j = Json::parse(r#"{"model": "tiny", "steps": 7}"#).unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.model, "tiny");
        assert_eq!(cfg.steps, 7);
        assert_eq!(cfg.mode, "bip");
        assert_eq!(cfg.sim_devices, 4);
    }

    #[test]
    fn invalid_mode_rejected() {
        let j = Json::parse(r#"{"mode": "nonsense"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn driver_conversion() {
        let cfg = RunConfig { steps: 42, ..Default::default() };
        let d = cfg.driver();
        assert_eq!(d.steps, 42);
        assert_eq!(d.config, "moe16-bench");
    }

    #[test]
    fn load_from_file() {
        let path = std::env::temp_dir().join(format!(
            "bipmoe-cfg-{}.json", std::process::id()));
        std::fs::write(&path,
                       r#"{"name":"t","model":"tiny","mode":"aux"}"#)
            .unwrap();
        let cfg = RunConfig::load(&path).unwrap();
        assert_eq!(cfg.mode, "aux");
        let _ = std::fs::remove_file(&path);
    }
}
