//! Cache-blocked score-matrix transpose (ISSUE 10).
//!
//! Algorithm 1's q-phase walks expert columns, so the solver keeps an
//! (m, n) column-major copy of the (n, m) batch scores. The naive
//! transpose strides the destination by `n` floats on every element:
//! at serving sizes (n in the thousands) each write lands on a new
//! cacheline and the loop is bound by write misses. Tiling both loops
//! at [`BLOCK`] keeps one `BLOCK x BLOCK` tile — a few KiB, L1/L2
//! resident — live at a time, so destination lines are filled
//! completely while they are hot.
//!
//! The kernel is a pure permutation (every element copied exactly
//! once, no arithmetic), so tiling cannot change the result: the
//! property tests pin it element-for-element against the naive twin
//! [`transpose_ref`]. Parallel callers split the *column* range —
//! columns `j0..j1` of the output occupy the contiguous slice
//! `[(j0 - j0_base) * n ..)`, so workers write disjoint contiguous
//! regions and the serial/parallel outputs are bitwise identical.

/// Tile edge in elements: 32 x 32 f32 tiles = 4 KiB source + 4 KiB
/// destination, comfortably L1-resident while small enough that the
/// paper's gate widths (m = 16..256) still tile the column loop.
pub const BLOCK: usize = 32;

/// Blocked transpose of the row-major (n, m) matrix `src` into the
/// column-major (m, n) buffer `dst` (`dst[j * n + i] = src[i * m + j]`).
// HOT: per-batch layout kernel; no locks, no allocation
pub fn transpose_into(src: &[f32], n: usize, m: usize, dst: &mut [f32]) {
    transpose_cols_into(src, n, m, 0, m, dst);
}

/// Blocked transpose of columns `j0..j1` only: `dst` is the contiguous
/// destination slice for exactly those columns
/// (`dst.len() == (j1 - j0) * n`, column `j` at
/// `dst[(j - j0) * n ..]`). [`transpose_into`] is the `j0 = 0, j1 = m`
/// case; the pool-parallel transpose hands each worker its own
/// disjoint column range.
// HOT: per-batch layout kernel; no locks, no allocation
pub fn transpose_cols_into(
    src: &[f32],
    n: usize,
    m: usize,
    j0: usize,
    j1: usize,
    dst: &mut [f32],
) {
    debug_assert!(j0 <= j1 && j1 <= m);
    debug_assert_eq!(src.len(), n * m);
    debug_assert_eq!(dst.len(), (j1 - j0) * n);
    let mut ib = 0;
    while ib < n {
        let iend = (ib + BLOCK).min(n);
        let mut jb = j0;
        while jb < j1 {
            let jend = (jb + BLOCK).min(j1);
            for i in ib..iend {
                let row = &src[i * m..i * m + m];
                for j in jb..jend {
                    dst[(j - j0) * n + i] = row[j];
                }
            }
            jb = jend;
        }
        ib = iend;
    }
}

/// Naive scalar reference twin of [`transpose_into`] — the
/// element-order the blocked kernel is pinned against, and the
/// baseline the kernel bench prices the tiling against.
pub fn transpose_ref(src: &[f32], n: usize, m: usize, dst: &mut [f32]) {
    debug_assert_eq!(src.len(), n * m);
    debug_assert_eq!(dst.len(), n * m);
    for i in 0..n {
        let row = &src[i * m..i * m + m];
        for j in 0..m {
            dst[j * n + i] = row[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn blocked_transpose_is_bit_identical_to_naive() {
        let mut rng = Pcg64::new(3);
        // shapes straddling the tile edge: smaller, exact multiples,
        // ragged remainders, and degenerate single-row/column cases
        for &(n, m) in &[
            (1usize, 1usize),
            (1, 40),
            (40, 1),
            (7, 5),
            (32, 32),
            (33, 31),
            (64, 16),
            (257, 16),
            (100, 96),
        ] {
            let src: Vec<f32> =
                (0..n * m).map(|_| rng.next_f32() - 0.5).collect();
            let mut blocked = vec![0.0f32; n * m];
            let mut naive = vec![0.0f32; n * m];
            transpose_into(&src, n, m, &mut blocked);
            transpose_ref(&src, n, m, &mut naive);
            assert_eq!(blocked, naive, "n={n} m={m}");
            // double transpose is the identity
            let mut back = vec![0.0f32; n * m];
            transpose_into(&blocked, m, n, &mut back);
            assert_eq!(back, src, "n={n} m={m}");
        }
    }

    #[test]
    fn column_ranges_assemble_the_full_transpose() {
        let mut rng = Pcg64::new(9);
        let (n, m) = (71usize, 37usize);
        let src: Vec<f32> =
            (0..n * m).map(|_| rng.next_f32()).collect();
        let mut whole = vec![0.0f32; n * m];
        transpose_into(&src, n, m, &mut whole);
        // chunked column ranges (ragged split crossing tile edges)
        for splits in [vec![0usize, 37], vec![0, 13, 37], vec![0, 1, 32, 33, 37]] {
            let mut assembled = vec![0.0f32; n * m];
            for w in splits.windows(2) {
                let (j0, j1) = (w[0], w[1]);
                transpose_cols_into(
                    &src,
                    n,
                    m,
                    j0,
                    j1,
                    &mut assembled[j0 * n..j1 * n],
                );
            }
            assert_eq!(assembled, whole, "splits {splits:?}");
        }
    }

    #[test]
    fn empty_column_range_is_a_no_op() {
        let src = vec![1.0f32; 12];
        let mut dst: Vec<f32> = Vec::new();
        transpose_cols_into(&src, 3, 4, 2, 2, &mut dst);
        assert!(dst.is_empty());
    }
}
