//! A counting global allocator for the zero-allocation hot-path gate.
//!
//! No external crates (the build is fully offline), so the counter is a
//! thin wrapper over [`std::alloc::System`] with **thread-local**
//! tallies: the hot-path bench and the `integration_perf` test install
//! it with `#[global_allocator]` and measure only the calling thread,
//! so parallel test threads and pool workers cannot pollute a
//! measurement window.
//!
//! The library never installs it itself — a crate can only have one
//! global allocator, and production binaries should not pay even the
//! thread-local increment. Binaries that want the accounting opt in:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: bip_moe::perf::alloc::CountingAlloc = CountingAlloc;
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
    static FREES: Cell<u64> = const { Cell::new(0) };
    static BYTES: Cell<u64> = const { Cell::new(0) };
}

/// Thread-locally counting wrapper over the system allocator.
pub struct CountingAlloc;

#[inline]
fn bump(cell: &'static std::thread::LocalKey<Cell<u64>>, by: u64) {
    // try_with: allocator calls can outlive thread-local teardown
    let _ = cell.try_with(|c| c.set(c.get() + by));
}

// SAFETY: every method forwards its arguments unchanged to
// `std::alloc::System`, so the GlobalAlloc contract (layout validity,
// pointer provenance, no unwinding) is exactly the system allocator's;
// the only added work is an infallible thread-local counter bump
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: delegates to System.alloc with the caller's layout
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump(&ALLOCS, 1);
        bump(&BYTES, layout.size() as u64);
        System.alloc(layout)
    }

    // SAFETY: delegates to System.dealloc with the caller's ptr/layout
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        bump(&FREES, 1);
        System.dealloc(ptr, layout)
    }

    // SAFETY: delegates to System.realloc with the caller's arguments
    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        // a realloc is a (potential) fresh allocation on the hot path
        bump(&ALLOCS, 1);
        bump(&BYTES, new_size as u64);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: delegates to System.alloc_zeroed with the caller's layout
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump(&ALLOCS, 1);
        bump(&BYTES, layout.size() as u64);
        System.alloc_zeroed(layout)
    }
}

/// Heap allocations (incl. reallocs) made by the current thread since
/// the last [`reset_thread_counts`].
pub fn thread_allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

/// Deallocations made by the current thread since the last reset.
pub fn thread_frees() -> u64 {
    FREES.with(|c| c.get())
}

/// Bytes requested by the current thread since the last reset.
pub fn thread_alloc_bytes() -> u64 {
    BYTES.with(|c| c.get())
}

/// Zero the current thread's counters (start of a measurement window).
pub fn reset_thread_counts() {
    ALLOCS.with(|c| c.set(0));
    FREES.with(|c| c.set(0));
    BYTES.with(|c| c.set(0));
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the library's own test binary does NOT install
    // CountingAlloc (only one global allocator is allowed per binary,
    // and these unit tests must not tax every other test). The
    // counters are exercised end-to-end in tests/integration_perf.rs;
    // here we only pin the bookkeeping arithmetic.
    #[test]
    fn counters_reset_and_accumulate() {
        reset_thread_counts();
        assert_eq!(thread_allocs(), 0);
        assert_eq!(thread_frees(), 0);
        assert_eq!(thread_alloc_bytes(), 0);
        bump(&super::ALLOCS, 2);
        bump(&super::BYTES, 128);
        bump(&super::FREES, 1);
        assert_eq!(thread_allocs(), 2);
        assert_eq!(thread_alloc_bytes(), 128);
        assert_eq!(thread_frees(), 1);
        reset_thread_counts();
        assert_eq!(thread_allocs(), 0);
    }
}
