//! Branch-free selection kernels over `f32_order_key` integer keys
//! (ISSUE 10 — "hot path, round two").
//!
//! The routing inner loop is selection: every token takes a top-K over
//! m biased scores, and every Algorithm 1 iteration takes an order
//! statistic per row/column. The comparator-driven quickselect behind
//! `util::stats::topk_into` branches on every compare and chases the
//! index indirection (`xs[idx[i] as usize]`); for the paper's gate
//! sizes (m = 16..256, k = 2..8) the whole working set fits in
//! registers, so this module specializes by rank:
//!
//! * **k ≤ [`NET_MAX_K`]** — a register-resident insertion network: a
//!   sorted K-register file, each element sinking through K
//!   max/min compare-exchange pairs (straight-line `u64` min/max, no
//!   data-dependent branches).
//! * **k ≤ [`HEAP_MAX_K`]** — a fixed-size stack min-heap over
//!   composite keys; only elements beating the current K-th largest
//!   pay a sift.
//! * **otherwise** — the comparator quickselect, verbatim from
//!   `topk_into` (also exposed as the scalar reference twin
//!   [`topk_ref`] every specialized path is pinned bit-identical to).
//!
//! Bit-identity argument: each candidate is packed into one composite
//! `u64` — order key in the high half, bitwise-NOT index in the low
//! half — so descending composite order IS "value descending, ties to
//! the lower index": exactly the total order `topk_into`/`topk_indices`
//! sort by. All three paths select the unique top-k of that total
//! order, so they agree bit-for-bit (the property tests sweep every
//! dispatch boundary). Inputs must be non-NaN (finite softmax scores
//! minus finite duals) — the reference comparator would panic on NaN,
//! and here a NaN's order key could tie the zero sentinel. One
//! refinement of the comparator order: `+0.0` and `-0.0` compare equal
//! to `partial_cmp` but map to adjacent distinct keys, so a mixed-zero
//! input orders `+0.0` first instead of by index — gate scores are
//! softmax outputs (strictly positive), so no production path feeds
//! mixed zeros.

use crate::util::stats::f32_order_key;

/// Largest k served by the register-resident insertion network.
pub const NET_MAX_K: usize = 4;
/// Largest k served by the fixed-size binary heap.
pub const HEAP_MAX_K: usize = 32;
/// Largest rank [`select_kth_key`] serves with the running-rank
/// network before falling back to integer quickselect.
pub const RANK_MAX: usize = 8;

/// Pack (value key, index) into one comparable word: order key high,
/// `!index` low — larger composite means larger value, or equal value
/// and *lower* index.
#[inline]
fn composite(key: u32, i: usize) -> u64 {
    ((key as u64) << 32) | (!(i as u32)) as u64
}

#[inline]
fn composite_index(c: u64) -> u32 {
    !(c as u32)
}

/// Dispatching branch-free top-K over raw scores: indices of the `k`
/// largest values of `xs`, descending, ties to the lower index,
/// written into `out[..k]`. `idx` is index scratch
/// (`idx.len() == xs.len()`), touched only on the quickselect
/// fallback. Returns `k.min(xs.len())` — the same contract, and
/// bit-identical output, as [`topk_ref`] / `util::stats::topk_indices`.
// HOT: per-token selection kernel; no locks, no allocation
#[inline]
pub fn topk_keys_into(
    xs: &[f32],
    k: usize,
    idx: &mut [u32],
    out: &mut [u32],
) -> usize {
    let k = k.min(xs.len());
    if k == 0 {
        return 0;
    }
    if k <= NET_MAX_K {
        topk_net(xs, k, out)
    } else if k <= HEAP_MAX_K {
        topk_heap(xs, k, out)
    } else {
        topk_quickselect(xs, k, idx, out)
    }
}

/// The insertion network at a fixed K: a descending-sorted K-register
/// file; every element sinks through K compare-exchange pairs. The
/// zero sentinel never survives: any non-NaN f32 has an order key
/// `> 0`, so `n >= K` real composites displace all K sentinels.
// HOT: straight-line per-element compare-exchange; no locks, no allocation
#[inline]
fn topk_net_k<const K: usize>(xs: &[f32], out: &mut [u32]) -> usize {
    let mut best = [0u64; K];
    for (i, &x) in xs.iter().enumerate() {
        let mut c = composite(f32_order_key(x), i);
        for b in best.iter_mut() {
            let hi = (*b).max(c);
            c = (*b).min(c);
            *b = hi;
        }
    }
    for (o, &b) in out[..K].iter_mut().zip(best.iter()) {
        *o = composite_index(b);
    }
    K
}

// HOT: small-k dispatch (k == K exactly; the caller clamped k <= len)
#[inline]
fn topk_net(xs: &[f32], k: usize, out: &mut [u32]) -> usize {
    debug_assert!(k >= 1 && k <= NET_MAX_K && k <= xs.len());
    match k {
        1 => topk_net_k::<1>(xs, out),
        2 => topk_net_k::<2>(xs, out),
        3 => topk_net_k::<3>(xs, out),
        _ => topk_net_k::<4>(xs, out),
    }
}

/// Mid-k path: a fixed-capacity min-heap of the k largest composites —
/// the root is the running k-th largest, and only elements beating it
/// pay a sift. A final in-place descending sort yields the output
/// order.
// HOT: mid-k selection; no locks, no allocation (fixed stack array)
fn topk_heap(xs: &[f32], k: usize, out: &mut [u32]) -> usize {
    debug_assert!(k >= 1 && k <= HEAP_MAX_K && k <= xs.len());
    let mut heap = [0u64; HEAP_MAX_K];
    for (i, &x) in xs.iter().take(k).enumerate() {
        heap[i] = composite(f32_order_key(x), i);
    }
    let mut s = k / 2;
    while s > 0 {
        s -= 1;
        sift_down(&mut heap[..k], s);
    }
    for (i, &x) in xs.iter().enumerate().skip(k) {
        let c = composite(f32_order_key(x), i);
        if c > heap[0] {
            heap[0] = c;
            sift_down(&mut heap[..k], 0);
        }
    }
    let top = &mut heap[..k];
    top.sort_unstable_by(|a, b| b.cmp(a));
    for (o, &c) in out[..k].iter_mut().zip(top.iter()) {
        *o = composite_index(c);
    }
    k
}

// HOT: heap maintenance for topk_heap; no locks, no allocation
#[inline]
fn sift_down(heap: &mut [u64], mut at: usize) {
    loop {
        let l = 2 * at + 1;
        if l >= heap.len() {
            return;
        }
        let r = l + 1;
        let child = if r < heap.len() && heap[r] < heap[l] { r } else { l };
        if heap[child] >= heap[at] {
            return;
        }
        heap.swap(at, child);
        at = child;
    }
}

/// The comparator quickselect (the pre-kernel `topk_into` body): also
/// the large-k fallback, so the reference twin and the fallback path
/// are one implementation.
// HOT: large-k fallback; no locks, no allocation
fn topk_quickselect(
    xs: &[f32],
    k: usize,
    idx: &mut [u32],
    out: &mut [u32],
) -> usize {
    debug_assert_eq!(idx.len(), xs.len());
    debug_assert!(k >= 1 && k <= xs.len());
    for (i, slot) in idx.iter_mut().enumerate() {
        *slot = i as u32;
    }
    let cmp = |&a: &u32, &b: &u32| {
        xs[b as usize]
            .partial_cmp(&xs[a as usize])
            .unwrap()
            .then(a.cmp(&b))
    };
    idx.select_nth_unstable_by(k - 1, cmp);
    let top = &mut idx[..k];
    // total order => unstable sort yields the same output as a stable
    // one, without sort_by's allocation
    top.sort_unstable_by(cmp);
    out[..k].copy_from_slice(top);
    k
}

/// Scalar reference twin of [`topk_keys_into`]: the comparator-driven
/// selection every specialized path is pinned bit-identical to (and
/// the twin the kernel bench prices the dispatch against).
pub fn topk_ref(
    xs: &[f32],
    k: usize,
    idx: &mut [u32],
    out: &mut [u32],
) -> usize {
    let k = k.min(xs.len());
    if k == 0 {
        return 0;
    }
    topk_quickselect(xs, k, idx, out)
}

/// The running-rank network at fixed R: a descending-sorted R-register
/// file of the R largest keys seen; `best[R - 1]` is the R-th largest.
// HOT: straight-line per-element compare-exchange; no locks, no allocation
#[inline]
fn kth_key_net<const R: usize>(v: &[u32]) -> u32 {
    let mut best = [0u32; R];
    for &key in v {
        let mut c = key;
        for b in best.iter_mut() {
            let hi = (*b).max(c);
            c = (*b).min(c);
            *b = hi;
        }
    }
    best[R - 1]
}

/// k-th largest (1-based, pre-clamped into `1..=v.len()`) over raw
/// order keys: ranks up to [`RANK_MAX`] via the branch-free network
/// (reads only), larger ranks via integer quickselect (permutes `v` —
/// callers treat it as scratch either way). An order statistic is a
/// value, not a position: every correct algorithm returns the same key
/// bit-for-bit, so the dispatch cannot change Algorithm 1's duals.
/// Keys must come from non-NaN floats (their keys are `> 0`, so the
/// network's zero sentinel never wins).
// HOT: Algorithm 1 p/q-phase order statistic; no locks, no allocation
pub fn select_kth_key(v: &mut [u32], k: usize) -> u32 {
    debug_assert!(k >= 1 && k <= v.len());
    match k {
        1 => kth_key_net::<1>(v),
        2 => kth_key_net::<2>(v),
        3 => kth_key_net::<3>(v),
        4 => kth_key_net::<4>(v),
        5 => kth_key_net::<5>(v),
        6 => kth_key_net::<6>(v),
        7 => kth_key_net::<7>(v),
        8 => kth_key_net::<8>(v),
        _ => {
            let idx = v.len() - k;
            *v.select_nth_unstable(idx).1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::util::stats::topk_indices;

    fn check_against_reference(xs: &[f32], k: usize) {
        let n = xs.len();
        let mut idx = vec![0u32; n];
        let mut out = vec![u32::MAX; n.max(k).max(1)];
        let wrote = topk_keys_into(xs, k, &mut idx, &mut out);
        let want = topk_indices(xs, k);
        assert_eq!(wrote, want.len(), "count xs={xs:?} k={k}");
        let got: Vec<usize> =
            out[..wrote].iter().map(|&e| e as usize).collect();
        assert_eq!(got, want, "xs={xs:?} k={k}");
        // the reference twin must agree too (it IS the old topk_into)
        let mut rout = vec![u32::MAX; n.max(k).max(1)];
        let rwrote = topk_ref(xs, k, &mut idx, &mut rout);
        assert_eq!(rwrote, wrote);
        assert_eq!(rout[..rwrote], out[..wrote]);
    }

    #[test]
    fn degenerate_shapes_on_every_path() {
        // k = 0 writes nothing
        let xs = [0.3f32, 0.1, 0.9];
        let mut idx = vec![0u32; 3];
        let mut out = vec![7u32; 3];
        assert_eq!(topk_keys_into(&xs, 0, &mut idx, &mut out), 0);
        assert_eq!(out, vec![7u32; 3], "k=0 must not touch out");
        // n = 1 on every requested k (clamps to 1, network path)
        for k in [1usize, 2, 4, 33] {
            check_against_reference(&[0.5f32], k);
        }
        // k = n at a size in each dispatch class: network, heap,
        // quickselect fallback
        let mut rng = Pcg64::new(5);
        for n in [3usize, 20, 40] {
            let xs: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            check_against_reference(&xs, n);
        }
        // k > n clamps to n
        check_against_reference(&[0.2f32, 0.8], 50);
    }

    #[test]
    fn all_equal_scores_tie_break_to_lower_index_on_every_path() {
        // network (k <= 4), heap (k <= 32), fallback (k > 32): the
        // composite's !index low half must order ties ascending
        for (n, ks) in [
            (6usize, vec![1usize, 2, 3, 4]),
            (40, vec![5, 8, 16, 32]),
            (64, vec![33, 48, 64]),
        ] {
            let xs = vec![0.25f32; n];
            for k in ks {
                let mut idx = vec![0u32; n];
                let mut out = vec![u32::MAX; n];
                let wrote = topk_keys_into(&xs, k, &mut idx, &mut out);
                assert_eq!(wrote, k.min(n));
                let want: Vec<u32> = (0..wrote as u32).collect();
                assert_eq!(out[..wrote], want, "n={n} k={k}");
            }
        }
    }

    #[test]
    fn randomized_bit_identity_sweep_across_k_1_to_64() {
        // duplicate-heavy values exercise the tie-break on every
        // dispatch boundary (4 -> 5, 32 -> 33) and beyond
        let mut rng = Pcg64::new(77);
        for trial in 0..120 {
            let n = 1 + rng.below(80) as usize;
            let quantized = trial % 2 == 0;
            let xs: Vec<f32> = (0..n)
                .map(|_| {
                    if quantized {
                        (rng.below(8) as f32) / 8.0
                    } else {
                        rng.next_f32() - 0.5
                    }
                })
                .collect();
            for k in 1..=64usize {
                check_against_reference(&xs, k);
            }
        }
    }

    #[test]
    fn negative_and_extreme_values_round_trip_the_composite() {
        let xs = [-1.5f32, 0.0, -0.0, 3.0e30, -3.0e30, 1.0e-38];
        for k in 1..=xs.len() {
            check_against_reference(&xs, k);
        }
    }

    #[test]
    fn select_kth_key_matches_sort_across_rank_dispatch() {
        let mut rng = Pcg64::new(31);
        for _ in 0..60 {
            let n = 1 + rng.below(40) as usize;
            // duplicates included: equal values collapse to equal keys
            let keys: Vec<u32> = (0..n)
                .map(|_| {
                    f32_order_key((rng.below(12) as f32) / 12.0 - 0.3)
                })
                .collect();
            let mut sorted = keys.clone();
            sorted.sort_unstable_by(|a, b| b.cmp(a));
            // ranks 1..=RANK_MAX hit the network, the rest quickselect
            for k in 1..=n {
                let mut scratch = keys.clone();
                assert_eq!(
                    select_kth_key(&mut scratch, k),
                    sorted[k - 1],
                    "n={n} k={k}"
                );
            }
        }
    }
}
