//! The score-arena: every buffer the serving hot path needs, owned in
//! one place and reused across micro-batches.
//!
//! Ownership rules (DESIGN.md `perf/`):
//!
//! * **One arena per [`crate::serve::ServingRouter`]** — all of a
//!   router's layers share it, so the O(n·m) solver scratch exists once
//!   per router instead of once per layer. Replicas each own a router
//!   and therefore an arena; concurrent replica routing never shares an
//!   arena.
//! * **Handed down, never stored**: `ServingRouter::route_batch_into`
//!   passes `&mut ScoreArena` through
//!   `RoutingStrategy::route_batch_into` into
//!   `bip::dual::DualState::update_in` / `update_parallel_in` /
//!   `update_adaptive_in`. Strategies may use any buffer except
//!   [`ScoreArena::scores`], which the router lends to the
//!   [`crate::bip::Instance`] for the duration of the call.
//! * **Standalone solvers own a fallback arena**: `DualState` keeps a
//!   private arena so `dual::solve` and the trace/counterfactual paths
//!   work without a router; the serving stack bypasses it entirely.
//! * **Steady state is allocation-free**: every `resize` here re-uses
//!   retained capacity once the largest batch shape has been seen. The
//!   hot-path bench (`bench_hotpath`) and the `integration_perf` test
//!   install a counting allocator and pin the zero.
//!
//! `state_bytes` counts every buffer (current lengths), so the serving
//! report's persistent-state accounting stays honest about the arena.

use crate::bip::Routing;
use crate::perf::block;

/// Reusable scratch for score assembly, the Algorithm 1 dual solver,
/// capacity enforcement, and device-placement accounting.
#[derive(Clone, Debug, Default)]
pub struct ScoreArena {
    /// flat (n, m) batch scores the router assembles per layer; lent to
    /// the `Instance` while a strategy routes
    pub scores: Vec<f32>,
    /// (m, n) column-major copy for the solver q-phase
    pub scores_t: Vec<f32>,
    /// n*m quickselect order-key scratch, viewed as row slices
    /// (`[i*m..]`) by the p-phase and column slices (`[j*n..]`) by the
    /// q-phase — one buffer serves both shapes and both the serial and
    /// chunk-parallel paths, so the footprint never depends on which
    /// path routed
    pub order_keys: Vec<u32>,
    /// m: per-token biased scores (s - q, or s + bias)
    pub biased: Vec<f32>,
    /// m: top-k index scratch
    pub topk_idx: Vec<u32>,
    /// k: top-k result scratch (adaptive-solver primal evaluation)
    pub topk_out: Vec<u32>,
    /// m: per-expert load counts (Loss-Free bias step, primal eval)
    pub loads_scratch: Vec<u32>,
    /// n_devices: device-load scratch for placement imbalance
    pub dev_loads: Vec<f64>,
    /// m: per-expert occupancy for capacity enforcement
    pub occ: Vec<u32>,
    /// k: enforced expert choices for one token
    pub chosen: Vec<u32>,
    /// m: previous dual vector (adaptive-solver delta tracking)
    pub prev_q: Vec<f32>,
    /// m: consecutive exactly-unchanged iterations per expert column
    pub calm: Vec<u32>,
    /// m: best-MaxVio dual snapshot the adaptive solver restores
    pub best_q: Vec<f32>,
    /// cacheline-padded per-worker staging rows for the sharded
    /// parallel dual update: worker c writes its chunk's p/q outputs
    /// into `shards[c * stride ..]` (stride rounded up to a 64-byte
    /// line), and a serial gather copies them into `p`/`q` — so no two
    /// workers ever store to the same cacheline (no false sharing).
    /// Deliberately excluded from [`ScoreArena::state_bytes`]: the
    /// accounted footprint is a function of the workload alone, never
    /// of the thread count, so serial and pool-chunked runs report
    /// identical state (the replica-equivalence tests pin this)
    pub shards: Vec<f32>,
    /// shape stamp for a router-provided transpose: `Some((n, m))`
    /// while `scores_t` already holds the (m, n) transpose of the
    /// current batch's scores ([`ScoreArena::fill_transpose`]);
    /// consumed once by [`ScoreArena::take_transpose`]
    transpose_for: Option<(usize, usize)>,
}

impl ScoreArena {
    pub fn new() -> ScoreArena {
        ScoreArena::default()
    }

    /// Size the solver-scratch buffers for an (n, m) batch. Idempotent
    /// and allocation-free once capacity covers the largest batch.
    pub fn prepare_batch(&mut self, n: usize, m: usize) {
        self.scores_t.resize(n * m, 0.0);
        self.order_keys.resize(n * m, 0);
    }

    /// Size the per-gate O(m) scratch (biased scores, top-k, loads).
    pub fn prepare_gate(&mut self, m: usize) {
        self.biased.resize(m, 0.0);
        self.topk_idx.resize(m, 0);
        self.loads_scratch.resize(m, 0);
    }

    /// Size the adaptive-solver bookkeeping and reset the calm counts
    /// (convergence state is per `update_adaptive` call, never carried
    /// across batches).
    pub fn prepare_adaptive(&mut self, m: usize, k: usize) {
        self.prev_q.resize(m, 0.0);
        self.best_q.resize(m, 0.0);
        self.topk_out.resize(k, 0);
        self.calm.resize(m, 0);
        self.calm.iter_mut().for_each(|c| *c = 0);
    }

    /// Grow the padded shard staging buffer to at least `len` floats
    /// (the parallel dual update sizes `len` as the larger of its
    /// p-phase and q-phase chunk geometry). Grow-only, so steady-state
    /// batches allocate nothing and `state_bytes` stays constant.
    pub fn prepare_shards(&mut self, len: usize) {
        if self.shards.len() < len {
            self.shards.resize(len, 0.0);
        }
    }

    /// Fused fill-side transpose: blocked-transpose the (n, m) batch in
    /// `scores` into `scores_t` and stamp it ready, so the per-layer
    /// dual solve reuses this one transpose for all of its p/q phases
    /// instead of re-deriving the column-major copy itself.
    // HOT: per-layer layout step on the serving path; no locks; resize
    // reuses retained capacity once the largest batch shape is seen
    pub fn fill_transpose(&mut self, n: usize, m: usize) {
        self.prepare_batch(n, m);
        block::transpose_into(&self.scores, n, m, &mut self.scores_t);
        self.transpose_for = Some((n, m));
    }

    /// Consume the router-provided transpose for an (n, m) batch: true
    /// iff `scores_t` already holds this exact batch shape's transpose.
    /// Take-once semantics — any stamp (matching or stale) is cleared,
    /// so a later solve against different scores can never reuse it.
    // HOT: solver-side token check; no locks, no allocation
    pub fn take_transpose(&mut self, n: usize, m: usize) -> bool {
        self.transpose_for.take() == Some((n, m))
    }

    /// Bytes currently held across every workload-sized buffer — the
    /// arena's share of the persistent serving state
    /// (`ServingRouter::state_bytes` adds this on top of the per-layer
    /// gate state). `shards` is intentionally not counted: it is sized
    /// by the pool geometry, and the accounted footprint must not
    /// depend on which (serial vs chunked) path routed.
    pub fn state_bytes(&self) -> usize {
        (self.scores.len()
            + self.scores_t.len()
            + self.order_keys.len()
            + self.biased.len()
            + self.topk_idx.len()
            + self.topk_out.len()
            + self.loads_scratch.len()
            + self.occ.len()
            + self.chosen.len()
            + self.prev_q.len()
            + self.calm.len()
            + self.best_q.len())
            * 4
            + self.dev_loads.len() * 8
    }
}

/// Flat, reusable routing output: token i's enforced/proposed experts
/// live in `experts[i*k..i*k + len(i)]`. Replaces the per-token
/// `Vec<Vec<u32>>` of [`Routing`] on the hot path — after warm-up a
/// `reset` + per-row writes allocate nothing.
#[derive(Clone, Debug, Default)]
pub struct AssignmentBuf {
    n: usize,
    k: usize,
    experts: Vec<u32>,
    lens: Vec<u8>,
}

impl AssignmentBuf {
    pub fn new() -> AssignmentBuf {
        AssignmentBuf::default()
    }

    /// Shape the buffer for an (n, k) batch and zero every row length.
    pub fn reset(&mut self, n: usize, k: usize) {
        assert!(k <= u8::MAX as usize, "AssignmentBuf stores row lengths as u8");
        self.n = n;
        self.k = k;
        self.experts.resize(n * k, 0);
        self.lens.resize(n, 0);
        self.lens.iter_mut().for_each(|l| *l = 0);
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Token i's full k-wide slot row, for a strategy to write into;
    /// follow with [`AssignmentBuf::set_len`].
    pub fn row_mut(&mut self, i: usize) -> &mut [u32] {
        &mut self.experts[i * self.k..(i + 1) * self.k]
    }

    pub fn set_len(&mut self, i: usize, len: usize) {
        debug_assert!(len <= self.k);
        self.lens[i] = len as u8;
    }

    /// Copy a whole row in (the allocating-fallback seam).
    pub fn put(&mut self, i: usize, experts: &[u32]) {
        let len = experts.len().min(self.k);
        self.experts[i * self.k..i * self.k + len]
            .copy_from_slice(&experts[..len]);
        self.lens[i] = len as u8;
    }

    /// Token i's routed experts.
    pub fn token(&self, i: usize) -> &[u32] {
        &self.experts[i * self.k..i * self.k + self.lens[i] as usize]
    }

    /// Materialize as the allocating [`Routing`] (compat/test seam).
    pub fn to_routing(&self) -> Routing {
        Routing {
            assignment: (0..self.n).map(|i| self.token(i).to_vec()).collect(),
        }
    }

    pub fn state_bytes(&self) -> usize {
        self.experts.len() * 4 + self.lens.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_state_bytes_count_every_buffer() {
        let mut a = ScoreArena::new();
        assert_eq!(a.state_bytes(), 0);
        a.prepare_batch(8, 4);
        a.prepare_gate(4);
        a.prepare_adaptive(4, 2);
        a.dev_loads.resize(2, 0.0);
        a.occ.resize(4, 0);
        a.chosen.resize(2, 0);
        a.scores.resize(8 * 4, 0.0);
        a.prepare_shards(16);
        // scores + scores_t + order_keys: 3 * n*m * 4B; biased +
        // topk_idx + loads + occ + prev_q + calm + best_q: 7 * m * 4B;
        // topk_out + chosen: 2 * k * 4B; dev_loads: d * 8B. Any newly
        // added arena field must be counted here (or, like `shards`,
        // explicitly documented as pool-geometry state excluded from
        // the accounting) or this exact-equality check fails.
        let expect = 3 * 8 * 4 * 4 + 7 * 4 * 4 + 2 * 2 * 4 + 2 * 8;
        assert_eq!(a.state_bytes(), expect);
        // shard staging is grow-only and never counted: a smaller
        // request keeps the buffer, and the accounted footprint is
        // identical with or without it
        a.prepare_shards(4);
        assert_eq!(a.shards.len(), 16);
        assert_eq!(a.state_bytes(), expect);
    }

    #[test]
    fn transpose_token_is_shape_checked_and_take_once() {
        let mut a = ScoreArena::new();
        a.scores = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // (3, 2)
        a.fill_transpose(3, 2);
        assert_eq!(a.scores_t, vec![1.0, 3.0, 5.0, 2.0, 4.0, 6.0]);
        // wrong shape refuses AND clears the stale stamp
        let mut b = a.clone();
        assert!(!b.take_transpose(2, 3));
        assert!(!b.take_transpose(3, 2));
        // right shape consumes exactly once
        assert!(a.take_transpose(3, 2));
        assert!(!a.take_transpose(3, 2));
    }

    #[test]
    fn prepare_is_idempotent_and_resets_calm() {
        let mut a = ScoreArena::new();
        a.prepare_adaptive(4, 2);
        a.calm[1] = 9;
        a.prepare_adaptive(4, 2);
        assert_eq!(a.calm, vec![0; 4]);
        let bytes = a.state_bytes();
        a.prepare_adaptive(4, 2);
        assert_eq!(a.state_bytes(), bytes);
    }

    #[test]
    fn assignment_buf_round_trips_rows() {
        let mut buf = AssignmentBuf::new();
        buf.reset(3, 2);
        buf.put(0, &[4, 1]);
        buf.row_mut(1).copy_from_slice(&[7, 0]);
        buf.set_len(1, 1);
        assert_eq!(buf.token(0), &[4, 1]);
        assert_eq!(buf.token(1), &[7]);
        assert_eq!(buf.token(2), &[] as &[u32]);
        let routing = buf.to_routing();
        assert_eq!(routing.assignment, vec![vec![4, 1], vec![7], vec![]]);
        // reset reuses the buffers and clears stale lengths
        buf.reset(2, 2);
        assert_eq!(buf.token(0), &[] as &[u32]);
        assert_eq!(buf.state_bytes(), 2 * 2 * 4 + 2);
    }
}
