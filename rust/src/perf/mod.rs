//! Hot-path performance substrate (ISSUE 5).
//!
//! The paper's §3 headline is that BIP balancing works at "very small
//! time costs"; this module is where the serving stack earns that at
//! the systems level:
//!
//! * [`arena`] — the [`ScoreArena`]: one reusable home for the flat
//!   score matrix, the solver transpose + order-key scratch, top-K
//!   index buffers, capacity-enforcement occupancy and
//!   device-placement scratch, threaded from
//!   `serve::ServingRouter::route_batch_into` through
//!   `routing::RoutingStrategy::route_batch_into` into the Algorithm 1
//!   dual update — so the steady-state serving hot path performs zero
//!   heap allocations per micro-batch. [`AssignmentBuf`] is the flat
//!   reusable replacement for the per-token `Vec<Vec<u32>>` routing
//!   output on that path.
//! * [`alloc`] — a thread-locally counting global allocator (std-only;
//!   the build is offline) that `bench_hotpath` and the
//!   `integration_perf` test install to *prove* the zero, batch after
//!   batch, and to price the allocating baseline against it.
//!
//! * [`kernels`] — branch-free top-K / order-statistic selection over
//!   `f32_order_key` integer keys, dispatched by rank (register
//!   insertion networks for k ≤ 4, a fixed stack heap for k ≤ 32,
//!   comparator quickselect beyond), each path pinned bit-identical
//!   to its scalar reference twin.
//! * [`block`] — the cache-blocked (tiled) score-matrix transpose the
//!   Algorithm 1 solver and the router's fused fill-side transpose
//!   share, with a naive reference twin.
//!
//! `bench_hotpath` writes the resulting throughput/allocation/adaptive
//! -solver record to `reports/BENCH_hotpath.json` — the repo's durable
//! perf baseline for the routing hot path; its `kernels` section
//! prices every specialized path against its twin.

pub mod alloc;
pub mod arena;
pub mod block;
pub mod kernels;

pub use arena::{AssignmentBuf, ScoreArena};
