//! Expert-load forecasting with proactive dual warm-start and
//! predictive serving control.
//!
//! The paper's headline result is balance *from the first step*, where
//! bias-adaptation baselines need many steps to converge; "Prediction
//! Is All MoE Needs" (Cong et al. 2024) observes that per-expert loads
//! are highly predictable from recent history. This subsystem exploits
//! both: it learns per-expert load trajectories from what the repo
//! already records (`trace/` files, live `BalanceTracker` histories)
//! and feeds the predictions back into every layer of the stack —
//!
//! * [`model`] — EWMA / Holt-Winters / sliding-window-linear per-expert
//!   forecasters behind one [`LoadForecaster`] trait;
//! * [`fit`] — fitting from recorded traces or live trackers, with
//!   walk-forward held-out-suffix error reporting against the naive
//!   last-value baseline, and the JSON model artifact;
//! * [`control`] — forecasts turned into actions: Algorithm 1 dual
//!   seeds for `routing::PredictiveBip` and the serving warm start,
//!   forecast-gated admission ([`PredictiveAdmission`]), replica
//!   up/down-scaling ([`AutoScaler`]), and the training route-state
//!   warm start ([`route_state_seed`]).
//!
//! Driven by `bip-moe forecast fit|eval|serve` and measured by
//! `bench_forecast` (forecast error by horizon, warm- vs cold-start
//! first-batch MaxVio, dual-iteration savings, predictive- vs
//! reactive-scaling SLO deltas) in `BENCH_forecast.json`.

pub mod control;
pub mod fit;
pub mod model;

pub use control::{
    dual_seed, route_state_seed, seed_states, AutoScaler,
    PredictiveAdmission, ScaleEvent, ScalePolicy, ScalarHolt,
    DEFAULT_SEED_GAIN,
};
pub use fit::{
    eval_model, fit_model, FitReport, ForecastModel, HorizonError,
    LoadSeries,
};
pub use model::{
    build_forecaster, forecaster_from_json, ForecastConfig,
    ForecasterKind, LoadForecaster,
};
