//! Per-expert load forecasters behind one trait.
//!
//! "Prediction Is All MoE Needs" (Cong et al. 2024) observes that
//! per-expert loads are highly predictable from recent history. A
//! [`LoadForecaster`] consumes a stream of per-expert load *fractions*
//! (one observation per routed micro-batch or training step) and
//! predicts the fraction vector `h` steps ahead. Three models cover the
//! workload shapes `serve::traffic` generates:
//!
//! * [`Ewma`] — exponentially weighted level; the right default for
//!   steady or bursty-but-stationary skew;
//! * [`HoltWinters`] — level + trend + optional additive seasonality;
//!   tracks drifting hot sets and periodic (diurnal) load;
//! * [`SlidingLinear`] — per-expert least-squares line over a sliding
//!   window; the strongest extrapolator under sustained linear drift.
//!
//! Forecasts are clamped non-negative and renormalized to sum 1, so a
//! consumer can always treat them as a load distribution (uniform
//! before any observation). Every model serializes to JSON
//! ([`LoadForecaster::to_json`] / [`forecaster_from_json`]) so a fit
//! can be frozen to disk and shipped to a serving or training run.

use std::collections::VecDeque;

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

/// Which forecaster family to fit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ForecasterKind {
    Ewma,
    HoltWinters,
    Linear,
}

impl ForecasterKind {
    pub fn all() -> [ForecasterKind; 3] {
        [
            ForecasterKind::Ewma,
            ForecasterKind::HoltWinters,
            ForecasterKind::Linear,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            ForecasterKind::Ewma => "ewma",
            ForecasterKind::HoltWinters => "holt-winters",
            ForecasterKind::Linear => "linear",
        }
    }

    pub fn parse(s: &str) -> Option<ForecasterKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "ewma" => Some(ForecasterKind::Ewma),
            "holt" | "holt-winters" | "holtwinters" | "hw" => {
                Some(ForecasterKind::HoltWinters)
            }
            "linear" | "lin" | "sliding-linear" => {
                Some(ForecasterKind::Linear)
            }
            _ => None,
        }
    }

    pub fn names() -> Vec<&'static str> {
        ForecasterKind::all().iter().map(|k| k.name()).collect()
    }
}

/// Hyperparameters shared by the forecaster family (each model reads
/// the fields it needs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ForecastConfig {
    /// level smoothing (EWMA / Holt-Winters)
    pub alpha: f64,
    /// trend smoothing (Holt-Winters)
    pub beta: f64,
    /// seasonal smoothing (Holt-Winters, when `period >= 2`)
    pub gamma: f64,
    /// seasonal period in steps; 0 or 1 disables seasonality
    pub period: usize,
    /// sliding-window length (linear)
    pub window: usize,
}

impl Default for ForecastConfig {
    fn default() -> Self {
        ForecastConfig {
            alpha: 0.3,
            beta: 0.05,
            gamma: 0.25,
            period: 0,
            window: 32,
        }
    }
}

/// A stateful per-expert load forecaster over a stream of observations.
///
/// `Send` for parity with `RoutingStrategy`: fitted models move into
/// serving workers.
pub trait LoadForecaster: Send {
    fn name(&self) -> String;
    /// Number of experts this forecaster tracks.
    fn m(&self) -> usize;
    /// Observe one step's per-expert loads (len `m`; any non-negative
    /// scale — normalized to fractions internally).
    fn observe(&mut self, loads: &[f64]);
    /// Predicted per-expert load fractions `h >= 1` steps past the last
    /// observation: non-negative, summing to 1 (uniform before any
    /// observation).
    fn forecast(&self, h: usize) -> Vec<f64>;
    fn observed_steps(&self) -> u64;
    /// Self-describing snapshot; [`forecaster_from_json`] inverts it
    /// bit-exactly (the JSON emitter prints shortest-round-trip floats).
    fn to_json(&self) -> Json;
}

/// Clamp negatives/non-finites to 0 and renormalize to sum 1 (uniform
/// when everything vanishes).
pub(crate) fn normalize(mut v: Vec<f64>) -> Vec<f64> {
    let mut sum = 0.0;
    for x in v.iter_mut() {
        if !x.is_finite() || *x < 0.0 {
            *x = 0.0;
        }
        sum += *x;
    }
    if sum <= 0.0 {
        let m = v.len().max(1);
        return vec![1.0 / m as f64; v.len()];
    }
    for x in v.iter_mut() {
        *x /= sum;
    }
    v
}

fn uniform(m: usize) -> Vec<f64> {
    vec![1.0 / m.max(1) as f64; m]
}

fn arr_f64(j: &Json, m: usize, what: &str) -> Result<Vec<f64>> {
    let arr = j
        .as_arr()
        .ok_or_else(|| anyhow!("forecast model: {what} is not an array"))?;
    if arr.len() != m {
        bail!("forecast model: {what} has {} entries, want {m}", arr.len());
    }
    arr.iter()
        .map(|x| {
            x.as_f64()
                .ok_or_else(|| anyhow!("forecast model: {what} not numeric"))
        })
        .collect()
}

fn json_f64s(j: &Json, key: &str, m: usize) -> Result<Vec<f64>> {
    let v = j
        .get(key)
        .ok_or_else(|| anyhow!("forecast model: missing array {key}"))?;
    arr_f64(v, m, key)
}

fn json_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("forecast model: missing number {key}"))
}

fn json_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("forecast model: missing count {key}"))
}

// ---- EWMA ---------------------------------------------------------------

/// Exponentially weighted moving average of the fraction vector.
#[derive(Clone, Debug)]
pub struct Ewma {
    pub alpha: f64,
    level: Vec<f64>,
    steps: u64,
}

impl Ewma {
    pub fn new(m: usize, alpha: f64) -> Ewma {
        assert!(m >= 1 && alpha > 0.0 && alpha <= 1.0);
        Ewma { alpha, level: uniform(m), steps: 0 }
    }
}

impl LoadForecaster for Ewma {
    fn name(&self) -> String {
        format!("ewma(alpha={})", self.alpha)
    }

    fn m(&self) -> usize {
        self.level.len()
    }

    fn observe(&mut self, loads: &[f64]) {
        assert_eq!(loads.len(), self.level.len());
        let x = normalize(loads.to_vec());
        if self.steps == 0 {
            self.level = x;
        } else {
            for (l, xi) in self.level.iter_mut().zip(&x) {
                *l = self.alpha * xi + (1.0 - self.alpha) * *l;
            }
        }
        self.steps += 1;
    }

    fn forecast(&self, _h: usize) -> Vec<f64> {
        normalize(self.level.clone())
    }

    fn observed_steps(&self) -> u64 {
        self.steps
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("ewma".into())),
            ("m", Json::Num(self.level.len() as f64)),
            ("alpha", Json::Num(self.alpha)),
            ("steps", Json::Num(self.steps as f64)),
            ("level", Json::from_f64s(&self.level)),
        ])
    }
}

// ---- Holt-Winters -------------------------------------------------------

/// Holt-Winters: per-expert level + trend, plus optional additive
/// seasonal components with period `P` (`P < 2` reduces to Holt's
/// double-exponential trend model).
#[derive(Clone, Debug)]
pub struct HoltWinters {
    pub alpha: f64,
    pub beta: f64,
    pub gamma: f64,
    pub period: usize,
    level: Vec<f64>,
    trend: Vec<f64>,
    /// `season[t % period]` is the additive component of slot t
    /// (empty when seasonality is disabled)
    season: Vec<Vec<f64>>,
    steps: u64,
}

impl HoltWinters {
    pub fn new(
        m: usize,
        alpha: f64,
        beta: f64,
        gamma: f64,
        period: usize,
    ) -> HoltWinters {
        assert!(m >= 1 && alpha > 0.0 && alpha <= 1.0);
        assert!((0.0..=1.0).contains(&beta) && (0.0..=1.0).contains(&gamma));
        let season = if period >= 2 {
            vec![vec![0.0; m]; period]
        } else {
            Vec::new()
        };
        HoltWinters {
            alpha,
            beta,
            gamma,
            period,
            level: uniform(m),
            trend: vec![0.0; m],
            season,
            steps: 0,
        }
    }

    /// Seasonal slot of the observation with 0-based index `t`.
    fn slot(&self, t: u64) -> Option<usize> {
        if self.season.is_empty() {
            None
        } else {
            Some((t % self.season.len() as u64) as usize)
        }
    }
}

impl LoadForecaster for HoltWinters {
    fn name(&self) -> String {
        if self.season.is_empty() {
            format!("holt(alpha={},beta={})", self.alpha, self.beta)
        } else {
            format!(
                "holt-winters(alpha={},beta={},gamma={},P={})",
                self.alpha,
                self.beta,
                self.gamma,
                self.season.len()
            )
        }
    }

    fn m(&self) -> usize {
        self.level.len()
    }

    fn observe(&mut self, loads: &[f64]) {
        assert_eq!(loads.len(), self.level.len());
        let x = normalize(loads.to_vec());
        if self.steps == 0 {
            self.level = x;
            self.steps = 1;
            return;
        }
        let slot = self.slot(self.steps);
        for j in 0..self.level.len() {
            let s_old = slot.map_or(0.0, |s| self.season[s][j]);
            let prev = self.level[j];
            self.level[j] = self.alpha * (x[j] - s_old)
                + (1.0 - self.alpha) * (self.level[j] + self.trend[j]);
            self.trend[j] = self.beta * (self.level[j] - prev)
                + (1.0 - self.beta) * self.trend[j];
            if let Some(s) = slot {
                self.season[s][j] = self.gamma * (x[j] - self.level[j])
                    + (1.0 - self.gamma) * s_old;
            }
        }
        self.steps += 1;
    }

    fn forecast(&self, h: usize) -> Vec<f64> {
        if self.steps == 0 {
            return uniform(self.level.len());
        }
        let h = h.max(1);
        // the next unseen observation has index `steps`; `h` steps past
        // the last one is index steps - 1 + h
        let slot = self.slot(self.steps - 1 + h as u64);
        let v: Vec<f64> = (0..self.level.len())
            .map(|j| {
                self.level[j]
                    + h as f64 * self.trend[j]
                    + slot.map_or(0.0, |s| self.season[s][j])
            })
            .collect();
        normalize(v)
    }

    fn observed_steps(&self) -> u64 {
        self.steps
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("holt-winters".into())),
            ("m", Json::Num(self.level.len() as f64)),
            ("alpha", Json::Num(self.alpha)),
            ("beta", Json::Num(self.beta)),
            ("gamma", Json::Num(self.gamma)),
            ("period", Json::Num(self.period as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("level", Json::from_f64s(&self.level)),
            ("trend", Json::from_f64s(&self.trend)),
            (
                "season",
                Json::Arr(
                    self.season.iter().map(|s| Json::from_f64s(s)).collect(),
                ),
            ),
        ])
    }
}

// ---- sliding-window linear ----------------------------------------------

/// Per-expert ordinary-least-squares line over a sliding window of the
/// last `window` observations, extrapolated `h` steps past the window.
#[derive(Clone, Debug)]
pub struct SlidingLinear {
    m: usize,
    pub window: usize,
    hist: VecDeque<Vec<f64>>,
    steps: u64,
}

impl SlidingLinear {
    pub fn new(m: usize, window: usize) -> SlidingLinear {
        assert!(m >= 1 && window >= 2);
        SlidingLinear { m, window, hist: VecDeque::new(), steps: 0 }
    }
}

impl LoadForecaster for SlidingLinear {
    fn name(&self) -> String {
        format!("linear(window={})", self.window)
    }

    fn m(&self) -> usize {
        self.m
    }

    fn observe(&mut self, loads: &[f64]) {
        assert_eq!(loads.len(), self.m);
        self.hist.push_back(normalize(loads.to_vec()));
        if self.hist.len() > self.window {
            self.hist.pop_front();
        }
        self.steps += 1;
    }

    fn forecast(&self, h: usize) -> Vec<f64> {
        let w = self.hist.len();
        match w {
            0 => return uniform(self.m),
            1 => return self.hist[0].clone(),
            _ => {}
        }
        let h = h.max(1);
        // x = 0..w-1, predict at x* = w - 1 + h
        let xbar = (w - 1) as f64 / 2.0;
        let sxx = w as f64 * (w as f64 * w as f64 - 1.0) / 12.0;
        let xstar = (w - 1 + h) as f64;
        let mut out = vec![0.0; self.m];
        for j in 0..self.m {
            let mut ybar = 0.0;
            let mut sxy = 0.0;
            for (i, row) in self.hist.iter().enumerate() {
                ybar += row[j];
                sxy += (i as f64 - xbar) * row[j];
            }
            ybar /= w as f64;
            let slope = sxy / sxx;
            out[j] = ybar + slope * (xstar - xbar);
        }
        normalize(out)
    }

    fn observed_steps(&self) -> u64 {
        self.steps
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("linear".into())),
            ("m", Json::Num(self.m as f64)),
            ("window", Json::Num(self.window as f64)),
            ("steps", Json::Num(self.steps as f64)),
            (
                "hist",
                Json::Arr(
                    self.hist.iter().map(|r| Json::from_f64s(r)).collect(),
                ),
            ),
        ])
    }
}

// ---- construction + JSON round trip -------------------------------------

/// Fresh forecaster of the given kind over `m` experts.
pub fn build_forecaster(
    kind: ForecasterKind,
    m: usize,
    cfg: &ForecastConfig,
) -> Box<dyn LoadForecaster> {
    match kind {
        ForecasterKind::Ewma => Box::new(Ewma::new(m, cfg.alpha)),
        ForecasterKind::HoltWinters => Box::new(HoltWinters::new(
            m, cfg.alpha, cfg.beta, cfg.gamma, cfg.period,
        )),
        ForecasterKind::Linear => {
            Box::new(SlidingLinear::new(m, cfg.window.max(2)))
        }
    }
}

/// Rebuild a forecaster from its [`LoadForecaster::to_json`] snapshot.
pub fn forecaster_from_json(j: &Json) -> Result<Box<dyn LoadForecaster>> {
    let kind = j
        .get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("forecast model: missing kind"))?;
    let m = json_usize(j, "m")?;
    if m == 0 {
        bail!("forecast model: m must be >= 1");
    }
    let steps = json_usize(j, "steps")? as u64;
    // validate before the constructors, whose asserts would abort the
    // process on a hand-edited model file
    let level_rate = |key: &str| -> Result<f64> {
        let x = json_f64(j, key)?;
        if !(x > 0.0 && x <= 1.0) {
            bail!("forecast model: {key}={x} outside (0, 1]");
        }
        Ok(x)
    };
    let unit_rate = |key: &str| -> Result<f64> {
        let x = json_f64(j, key)?;
        if !(0.0..=1.0).contains(&x) {
            bail!("forecast model: {key}={x} outside [0, 1]");
        }
        Ok(x)
    };
    match kind {
        "ewma" => {
            let mut f = Ewma::new(m, level_rate("alpha")?);
            f.level = json_f64s(j, "level", m)?;
            f.steps = steps;
            Ok(Box::new(f))
        }
        "holt-winters" => {
            let period = json_usize(j, "period")?;
            let mut f = HoltWinters::new(
                m,
                level_rate("alpha")?,
                unit_rate("beta")?,
                unit_rate("gamma")?,
                period,
            );
            f.level = json_f64s(j, "level", m)?;
            f.trend = json_f64s(j, "trend", m)?;
            let season = j
                .get("season")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("forecast model: missing season"))?;
            if season.len() != f.season.len() {
                bail!(
                    "forecast model: season has {} slots, want {}",
                    season.len(),
                    f.season.len()
                );
            }
            for (slot, sj) in f.season.iter_mut().zip(season) {
                *slot = arr_f64(sj, m, "season slot")?;
            }
            f.steps = steps;
            Ok(Box::new(f))
        }
        "linear" => {
            let window = json_usize(j, "window")?;
            let mut f = SlidingLinear::new(m, window.max(2));
            let hist = j
                .get("hist")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("forecast model: missing hist"))?;
            for row in hist {
                f.hist.push_back(arr_f64(row, m, "hist row")?);
            }
            if f.hist.len() > f.window {
                bail!(
                    "forecast model: hist of {} exceeds window {}",
                    f.hist.len(),
                    f.window
                );
            }
            f.steps = steps;
            Ok(Box::new(f))
        }
        other => bail!("forecast model: unknown kind {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mae(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f64>()
            / a.len() as f64
    }

    #[test]
    fn forecasts_are_distributions_from_the_start() {
        let cfg = ForecastConfig::default();
        for kind in ForecasterKind::all() {
            let mut f = build_forecaster(kind, 8, &cfg);
            for h in [1usize, 4, 32] {
                let p = f.forecast(h);
                assert_eq!(p.len(), 8);
                assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
                assert!(p.iter().all(|&x| (x - 0.125).abs() < 1e-12),
                        "{kind:?}: uniform before data");
            }
            f.observe(&[4.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 4.0]);
            let p = f.forecast(1);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0), "{kind:?}");
            assert_eq!(f.observed_steps(), 1);
        }
    }

    #[test]
    fn ewma_converges_to_a_constant_signal() {
        let mut f = Ewma::new(4, 0.3);
        let x = [0.4, 0.3, 0.2, 0.1];
        for _ in 0..60 {
            f.observe(&x);
        }
        assert!(mae(&f.forecast(1), &x) < 1e-6);
        // and the horizon does not change a level-only forecast
        assert_eq!(f.forecast(1), f.forecast(16));
    }

    #[test]
    fn holt_tracks_linear_drift_where_ewma_lags() {
        // expert 0 gains 0.005 fraction per step at expert 3's expense
        let series: Vec<Vec<f64>> = (0..80)
            .map(|t| {
                let d = 0.005 * t as f64;
                vec![0.1 + d, 0.3, 0.3, 0.3 - d]
            })
            .collect();
        let mut holt = HoltWinters::new(4, 0.3, 0.2, 0.0, 0);
        let mut ewma = Ewma::new(4, 0.3);
        for s in &series {
            holt.observe(s);
            ewma.observe(s);
        }
        // truth 8 steps past the end of the series
        let truth = normalize(vec![0.1 + 0.005 * 87.0, 0.3, 0.3,
                                   0.3 - 0.005 * 87.0]);
        let he = mae(&holt.forecast(8), &truth);
        let ee = mae(&ewma.forecast(8), &truth);
        assert!(he < ee, "holt {he} !< ewma {ee}");
    }

    #[test]
    fn linear_extrapolates_drift_exactly() {
        let series: Vec<Vec<f64>> = (0..40)
            .map(|t| {
                let d = 0.004 * t as f64;
                vec![0.2 + d, 0.3, 0.3 - d, 0.2]
            })
            .collect();
        let mut lin = SlidingLinear::new(4, 16);
        for s in &series {
            lin.observe(s);
        }
        let truth = normalize(vec![0.2 + 0.004 * 45.0, 0.3,
                                   0.3 - 0.004 * 45.0, 0.2]);
        assert!(mae(&lin.forecast(6), &truth) < 1e-9);
    }

    #[test]
    fn holt_winters_learns_a_periodic_signal() {
        // period-8 square wave between experts 0 and 1
        let series: Vec<Vec<f64>> = (0..96)
            .map(|t| {
                if (t / 4) % 2 == 0 {
                    vec![0.5, 0.1, 0.2, 0.2]
                } else {
                    vec![0.1, 0.5, 0.2, 0.2]
                }
            })
            .collect();
        let mut hw = HoltWinters::new(4, 0.2, 0.0, 0.5, 8);
        let mut ewma = Ewma::new(4, 0.2);
        for s in &series {
            hw.observe(s);
            ewma.observe(s);
        }
        // 4 steps ahead lands in the opposite phase: index 96+3 = 99,
        // (99/4) % 2 = 0 -> expert 0 hot
        let truth = vec![0.5, 0.1, 0.2, 0.2];
        let hwe = mae(&hw.forecast(4), &truth);
        let ee = mae(&ewma.forecast(4), &truth);
        assert!(hwe < ee, "hw {hwe} !< ewma {ee}");
    }

    #[test]
    fn observations_are_normalized_not_trusted() {
        let mut f = Ewma::new(3, 1.0);
        f.observe(&[30.0, 20.0, 50.0]); // raw counts, not fractions
        let p = f.forecast(1);
        assert!((p[0] - 0.3).abs() < 1e-12);
        assert!((p[2] - 0.5).abs() < 1e-12);
        // negative / non-finite garbage is clamped, never propagated
        let mut g = Ewma::new(3, 1.0);
        g.observe(&[-1.0, f64::NAN, 2.0]);
        assert_eq!(g.forecast(1), vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn json_round_trips_every_kind_bit_exactly() {
        let cfg = ForecastConfig { period: 6, ..Default::default() };
        for kind in ForecasterKind::all() {
            let mut f = build_forecaster(kind, 5, &cfg);
            for t in 0..23 {
                let x: Vec<f64> = (0..5)
                    .map(|j| 1.0 + ((t * 7 + j * 3) % 11) as f64)
                    .collect();
                f.observe(&x);
            }
            let j = f.to_json();
            let back = forecaster_from_json(&j).unwrap();
            assert_eq!(back.observed_steps(), f.observed_steps());
            for h in [1usize, 3, 9] {
                assert_eq!(back.forecast(h), f.forecast(h), "{kind:?} h={h}");
            }
            // the snapshot survives the text emitter too
            let text = j.to_string();
            let rebuilt = forecaster_from_json(
                &crate::util::json::Json::parse(&text).unwrap(),
            )
            .unwrap();
            assert_eq!(rebuilt.forecast(2), f.forecast(2), "{kind:?}");
        }
    }

    #[test]
    fn from_json_rejects_malformed_models() {
        assert!(forecaster_from_json(&Json::obj(vec![])).is_err());
        let j = Json::obj(vec![
            ("kind", Json::Str("ewma".into())),
            ("m", Json::Num(3.0)),
            ("alpha", Json::Num(0.3)),
            ("steps", Json::Num(1.0)),
            ("level", Json::from_f64s(&[0.5, 0.5])), // wrong length
        ]);
        assert!(forecaster_from_json(&j).is_err());
        let j = Json::obj(vec![
            ("kind", Json::Str("nope".into())),
            ("m", Json::Num(3.0)),
            ("steps", Json::Num(0.0)),
        ]);
        assert!(forecaster_from_json(&j).is_err());
    }

    #[test]
    fn kind_parse_is_forgiving() {
        assert_eq!(ForecasterKind::parse("EWMA"), Some(ForecasterKind::Ewma));
        assert_eq!(
            ForecasterKind::parse(" holt "),
            Some(ForecasterKind::HoltWinters)
        );
        assert_eq!(
            ForecasterKind::parse("lin"),
            Some(ForecasterKind::Linear)
        );
        assert_eq!(ForecasterKind::parse("arima"), None);
        assert_eq!(ForecasterKind::names().len(), 3);
    }
}
