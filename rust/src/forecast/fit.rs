//! Fitting forecasters from recorded history, with held-out-suffix
//! error reporting.
//!
//! Two fit sources close the loop the ROADMAP asks for:
//!
//! * a recorded **trace** (`trace::format::Trace`) — every frame carries
//!   the enforced per-layer per-expert loads, which normalize to one
//!   fraction vector per micro-batch per layer;
//! * a live **`BalanceTracker`** with its bounded load history enabled
//!   (`metrics::maxvio::BalanceTracker::enable_load_history`) — the
//!   same series captured in-process, no trace file needed.
//!
//! [`fit_model`] fits one forecaster per layer on the full series and
//! reports walk-forward errors on a held-out suffix: the model observes
//! the training prefix, then at every held-out step it first predicts
//! each requested horizon and only then absorbs the step — so every
//! error is out-of-sample. The naive last-value forecast is scored on
//! the same walk as the baseline every model must beat to matter.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::metrics::maxvio::BalanceTracker;
use crate::prof::{Frame, ProfGuard};
use crate::telemetry;
use crate::trace::Trace;
use crate::util::json::Json;

use super::model::{
    build_forecaster, forecaster_from_json, ForecastConfig, ForecasterKind,
    LoadForecaster,
};

/// Per-layer, per-step expert load fractions: `layers[l][step][expert]`.
pub struct LoadSeries {
    pub m: usize,
    pub layers: Vec<Vec<Vec<f64>>>,
}

impl LoadSeries {
    /// Extract the per-layer fraction series from a recorded trace.
    /// Frames whose layer routed nothing (all-degraded) are skipped for
    /// that layer.
    pub fn from_trace(trace: &Trace) -> Result<LoadSeries> {
        let m = trace.meta.serve.router.m;
        let n_layers = trace.meta.serve.router.n_layers;
        let mut layers = vec![Vec::new(); n_layers];
        for f in &trace.frames {
            if f.loads.len() != n_layers * m {
                bail!(
                    "frame {}: loads len {} != {} layers x {} experts",
                    f.seq,
                    f.loads.len(),
                    n_layers,
                    m
                );
            }
            for (l, steps) in layers.iter_mut().enumerate() {
                let row = &f.loads[l * m..(l + 1) * m];
                let sum: f64 = row.iter().map(|&x| x as f64).sum();
                if sum <= 0.0 {
                    continue;
                }
                steps.push(
                    row.iter().map(|&x| x as f64 / sum).collect(),
                );
            }
        }
        Ok(LoadSeries { m, layers })
    }

    /// Extract the series from a live tracker's bounded load history.
    pub fn from_tracker(tracker: &BalanceTracker) -> Result<LoadSeries> {
        let hist = tracker.load_history.as_ref().ok_or_else(|| {
            anyhow!(
                "BalanceTracker has no load history; call \
                 enable_load_history before routing"
            )
        })?;
        let layers: Vec<Vec<Vec<f64>>> = hist
            .per_layer
            .iter()
            .map(|ring| {
                ring.iter()
                    .map(|row| row.iter().map(|&x| x as f64).collect())
                    .collect()
            })
            .collect();
        Ok(LoadSeries { m: hist.m, layers })
    }

    /// Steps available on the shortest layer (each layer fits its own
    /// forecaster, but fit/holdout sizing uses the common length).
    pub fn steps(&self) -> usize {
        self.layers.iter().map(|l| l.len()).min().unwrap_or(0)
    }
}

/// Walk-forward error at one horizon, pooled over layers and steps.
#[derive(Clone, Copy, Debug)]
pub struct HorizonError {
    pub horizon: usize,
    /// mean abs error of the forecast fraction vector vs the realized one
    pub mae: f64,
    /// the same walk scored with the naive last-value forecast
    pub naive_mae: f64,
    pub samples: u64,
}

/// Held-out-suffix report for one fitted model.
#[derive(Clone, Debug)]
pub struct FitReport {
    pub kind: ForecasterKind,
    /// steps in the shortest layer series
    pub steps: usize,
    /// held-out suffix length the errors are measured on
    pub holdout: usize,
    pub by_horizon: Vec<HorizonError>,
}

impl FitReport {
    pub fn headers() -> &'static [&'static str] {
        &["Model", "Horizon", "MAE", "NaiveMAE", "vsNaive", "Samples"]
    }

    pub fn table_rows(&self) -> Vec<Vec<String>> {
        self.by_horizon
            .iter()
            .map(|h| {
                vec![
                    self.kind.name().to_string(),
                    format!("{}", h.horizon),
                    format!("{:.5}", h.mae),
                    format!("{:.5}", h.naive_mae),
                    format!(
                        "{:+.1}%",
                        if h.naive_mae > 0.0 {
                            (h.mae / h.naive_mae - 1.0) * 100.0
                        } else {
                            0.0
                        }
                    ),
                    format!("{}", h.samples),
                ]
            })
            .collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str(self.kind.name().into())),
            ("steps", Json::Num(self.steps as f64)),
            ("holdout", Json::Num(self.holdout as f64)),
            (
                "by_horizon",
                Json::Arr(
                    self.by_horizon
                        .iter()
                        .map(|h| {
                            Json::obj(vec![
                                ("horizon", Json::Num(h.horizon as f64)),
                                ("mae", Json::Num(h.mae)),
                                ("naive_mae", Json::Num(h.naive_mae)),
                                ("samples", Json::Num(h.samples as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

fn mae(pred: &[f64], actual: &[f64]) -> f64 {
    pred.iter()
        .zip(actual)
        .map(|(p, a)| (p - a).abs())
        .sum::<f64>()
        / pred.len().max(1) as f64
}

/// Walk one layer's held-out suffix, accumulating (model, naive) error
/// sums per horizon into `acc`: `acc[i] = (mae_sum, naive_sum, samples)`.
fn walk_layer(
    kind: ForecasterKind,
    cfg: &ForecastConfig,
    m: usize,
    steps: &[Vec<f64>],
    holdout: usize,
    horizons: &[usize],
    acc: &mut [(f64, f64, u64)],
) {
    let split = steps.len() - holdout;
    let mut fc = build_forecaster(kind, m, cfg);
    for s in &steps[..split] {
        fc.observe(s);
    }
    for t in split..steps.len() {
        // having observed steps[..t], forecast(h) targets index t-1+h
        for (i, &h) in horizons.iter().enumerate() {
            let target = t + h - 1;
            if target >= steps.len() {
                continue;
            }
            let (ms, ns, n) = &mut acc[i];
            *ms += mae(&fc.forecast(h), &steps[target]);
            *ns += mae(&steps[t - 1], &steps[target]);
            *n += 1;
        }
        fc.observe(&steps[t]);
    }
}

/// A fitted per-layer forecast model, the artifact `bip-moe forecast
/// fit` writes and `forecast eval|serve` (and the train warm start)
/// consume.
pub struct ForecastModel {
    pub kind: ForecasterKind,
    pub m: usize,
    pub layers: Vec<Box<dyn LoadForecaster>>,
}

impl ForecastModel {
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Forecast for layer `l` (clamped to the last fitted layer, so a
    /// model fitted on fewer layers still seeds a deeper stack).
    pub fn layer_forecast(&self, l: usize, h: usize) -> Vec<f64> {
        let l = l.min(self.layers.len().saturating_sub(1));
        self.layers[l].forecast(h)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::Str("bip-moe-forecast".into())),
            ("version", Json::Str(crate::VERSION.into())),
            ("kind", Json::Str(self.kind.name().into())),
            ("m", Json::Num(self.m as f64)),
            (
                "layers",
                Json::Arr(self.layers.iter().map(|f| f.to_json()).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ForecastModel> {
        if j.get("format").and_then(Json::as_str)
            != Some("bip-moe-forecast")
        {
            bail!("not a bip-moe forecast model");
        }
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .and_then(ForecasterKind::parse)
            .ok_or_else(|| anyhow!("forecast model: bad kind"))?;
        let m = j
            .get("m")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("forecast model: missing m"))?;
        let layers_json = j
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("forecast model: missing layers"))?;
        if layers_json.is_empty() {
            bail!("forecast model: no layers");
        }
        let mut layers = Vec::with_capacity(layers_json.len());
        for lj in layers_json {
            let fc = forecaster_from_json(lj)?;
            if fc.m() != m {
                bail!("forecast model: layer m {} != model m {m}", fc.m());
            }
            layers.push(fc);
        }
        Ok(ForecastModel { kind, m, layers })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing model {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<ForecastModel> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading model {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("parsing model {}: {e}", path.display()))?;
        ForecastModel::from_json(&j)
    }
}

/// Fit one forecaster per layer on the full series and report
/// walk-forward errors on the held-out suffix (pooled over layers).
/// `holdout_frac` of the steps (at least 1, at most steps-1) form the
/// suffix; every layer needs at least 2 steps.
pub fn fit_model(
    kind: ForecasterKind,
    cfg: &ForecastConfig,
    series: &LoadSeries,
    horizons: &[usize],
    holdout_frac: f64,
) -> Result<(ForecastModel, FitReport)> {
    let steps = series.steps();
    if steps < 2 {
        bail!(
            "need at least 2 recorded steps per layer to fit (shortest \
             layer has {steps})"
        );
    }
    if series.layers.is_empty() {
        bail!("series has no layers");
    }
    if horizons.is_empty() || horizons.contains(&0) {
        bail!("horizons must be non-empty and >= 1");
    }
    let _prof = ProfGuard::enter(Frame::ForecastFit);
    let holdout = ((steps as f64 * holdout_frac).round() as usize)
        .clamp(1, steps - 1);

    let mut acc = vec![(0.0f64, 0.0f64, 0u64); horizons.len()];
    let mut layers: Vec<Box<dyn LoadForecaster>> = Vec::new();
    for layer in &series.layers {
        // per-layer holdout of the common length keeps the pooled
        // errors comparable across layers of unequal series length
        walk_layer(kind, cfg, series.m, layer, holdout, horizons, &mut acc);
        let mut fc = build_forecaster(kind, series.m, cfg);
        for s in layer {
            fc.observe(s);
        }
        layers.push(fc);
    }
    let by_horizon = horizons
        .iter()
        .zip(&acc)
        .map(|(&h, &(ms, ns, n))| HorizonError {
            horizon: h,
            mae: if n > 0 { ms / n as f64 } else { 0.0 },
            naive_mae: if n > 0 { ns / n as f64 } else { 0.0 },
            samples: n,
        })
        .collect();
    Ok((
        ForecastModel { kind, m: series.m, layers },
        FitReport { kind, steps, holdout, by_horizon },
    ))
}

/// Continue a fitted model over a fresh series, scoring every horizon
/// walk-forward (the `forecast eval` surface: fit on yesterday's trace,
/// evaluate on today's).
pub fn eval_model(
    model: &mut ForecastModel,
    series: &LoadSeries,
    horizons: &[usize],
) -> Result<FitReport> {
    if series.m != model.m {
        bail!("series m {} != model m {}", series.m, model.m);
    }
    if horizons.is_empty() || horizons.contains(&0) {
        bail!("horizons must be non-empty and >= 1");
    }
    let steps = series.steps();
    if steps == 0 {
        bail!("series has no steps to evaluate on");
    }
    let mut acc = vec![(0.0f64, 0.0f64, 0u64); horizons.len()];
    for (l, layer) in series.layers.iter().enumerate() {
        let fc = {
            let li = l.min(model.layers.len() - 1);
            &mut model.layers[li]
        };
        for t in 0..layer.len() {
            for (i, &h) in horizons.iter().enumerate() {
                let target = t + h - 1;
                if target >= layer.len() {
                    continue;
                }
                let (ms, ns, n) = &mut acc[i];
                *ms += mae(&fc.forecast(h), &layer[target]);
                // naive: the last value the model has absorbed — before
                // any eval step that is the fit series' final level
                let naive = if t > 0 {
                    layer[t - 1].clone()
                } else {
                    fc.forecast(1)
                };
                *ns += mae(&naive, &layer[target]);
                *n += 1;
            }
            fc.observe(&layer[t]);
        }
    }
    let by_horizon: Vec<HorizonError> = horizons
        .iter()
        .zip(&acc)
        .map(|(&h, &(ms, ns, n))| HorizonError {
            horizon: h,
            mae: if n > 0 { ms / n as f64 } else { 0.0 },
            naive_mae: if n > 0 { ns / n as f64 } else { 0.0 },
            samples: n,
        })
        .collect();
    for h in &by_horizon {
        telemetry::counter_add(
            telemetry::Counter::ForecastEvalSamples,
            h.samples,
        );
        telemetry::hist_observe(telemetry::Hist::ForecastAbsErr, h.mae);
    }
    if let Some(h0) = by_horizon.first() {
        telemetry::gauge_set(telemetry::Gauge::ForecastLastMae, h0.mae);
    }
    Ok(FitReport { kind: model.kind, steps, holdout: steps, by_horizon })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{
        Policy, ReplicaConfig, RouterConfig, Scenario, SchedulerConfig,
        ServeConfig, TrafficConfig, TrafficGenerator,
    };
    use crate::trace::TraceRecorder;

    fn synthetic_series(steps: usize) -> LoadSeries {
        // two layers, 4 experts, slow drift
        let layer = |phase: f64| -> Vec<Vec<f64>> {
            (0..steps)
                .map(|t| {
                    let d = 0.002 * t as f64 + phase;
                    vec![0.3 + d, 0.3 - d, 0.2, 0.2]
                })
                .collect()
        };
        LoadSeries { m: 4, layers: vec![layer(0.0), layer(0.05)] }
    }

    fn recorded_trace(seed: u64) -> Trace {
        let cfg = ServeConfig::new(
            TrafficConfig {
                scenario: Scenario::Steady,
                n_requests: 512,
                seed,
                ..Default::default()
            },
            SchedulerConfig::default(),
            RouterConfig::default(),
            Policy::Greedy,
        );
        let mut rec = TraceRecorder::new(&cfg, &ReplicaConfig::default());
        crate::serve::run_scenario_with(
            &cfg,
            TrafficGenerator::new(cfg.traffic.clone()),
            Some(&mut rec),
        );
        rec.into_trace()
    }

    #[test]
    fn series_from_trace_has_per_layer_fractions() {
        let trace = recorded_trace(3);
        let series = LoadSeries::from_trace(&trace).unwrap();
        assert_eq!(series.m, 16);
        assert_eq!(series.layers.len(), 4);
        assert!(series.steps() >= 4, "{}", series.steps());
        for layer in &series.layers {
            for step in layer {
                assert_eq!(step.len(), 16);
                let sum: f64 = step.iter().sum();
                assert!((sum - 1.0).abs() < 1e-9);
                assert!(step.iter().all(|&x| x >= 0.0));
            }
        }
    }

    #[test]
    fn fit_reports_holdout_errors_and_linear_beats_naive_on_drift() {
        let series = synthetic_series(120);
        let cfg = ForecastConfig::default();
        let (model, report) = fit_model(
            ForecasterKind::Linear,
            &cfg,
            &series,
            &[1, 8],
            0.25,
        )
        .unwrap();
        assert_eq!(model.n_layers(), 2);
        assert_eq!(report.holdout, 30);
        assert_eq!(report.by_horizon.len(), 2);
        for h in &report.by_horizon {
            assert!(h.samples > 0);
            assert!(h.mae.is_finite() && h.naive_mae.is_finite());
        }
        // at horizon 8 the linear extrapolator must beat last-value
        let h8 = &report.by_horizon[1];
        assert!(
            h8.mae < h8.naive_mae,
            "mae {} !< naive {}",
            h8.mae,
            h8.naive_mae
        );
    }

    #[test]
    fn fit_rejects_degenerate_inputs() {
        let series = synthetic_series(1);
        let cfg = ForecastConfig::default();
        assert!(fit_model(ForecasterKind::Ewma, &cfg, &series, &[1], 0.25)
            .is_err());
        let series = synthetic_series(10);
        assert!(fit_model(ForecasterKind::Ewma, &cfg, &series, &[], 0.25)
            .is_err());
        assert!(fit_model(ForecasterKind::Ewma, &cfg, &series, &[0], 0.25)
            .is_err());
        let empty = LoadSeries { m: 4, layers: Vec::new() };
        assert!(fit_model(ForecasterKind::Ewma, &cfg, &empty, &[1], 0.25)
            .is_err());
    }

    #[test]
    fn fit_from_a_recorded_trace_is_deterministic() {
        let cfg = ForecastConfig::default();
        let fit = |trace: &Trace| -> String {
            let series = LoadSeries::from_trace(trace).unwrap();
            let (model, _) = fit_model(
                ForecasterKind::HoltWinters,
                &cfg,
                &series,
                &[1, 4],
                0.25,
            )
            .unwrap();
            model.to_json().to_string()
        };
        let a = recorded_trace(9);
        let b = recorded_trace(9);
        assert_eq!(fit(&a), fit(&b), "same trace, same model, bit for bit");
    }

    #[test]
    fn model_json_round_trips_forecasts_exactly() {
        let series = synthetic_series(50);
        let cfg = ForecastConfig { period: 5, ..Default::default() };
        for kind in ForecasterKind::all() {
            let (model, _) =
                fit_model(kind, &cfg, &series, &[1], 0.2).unwrap();
            let text = model.to_json().to_string();
            let back = ForecastModel::from_json(
                &Json::parse(&text).unwrap(),
            )
            .unwrap();
            assert_eq!(back.m, model.m);
            assert_eq!(back.n_layers(), model.n_layers());
            for l in 0..model.n_layers() {
                for h in [1usize, 4] {
                    assert_eq!(
                        back.layer_forecast(l, h),
                        model.layer_forecast(l, h),
                        "{kind:?} layer {l} h={h}"
                    );
                }
            }
        }
        assert!(ForecastModel::from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn eval_continues_a_fitted_model_on_a_fresh_series() {
        let fit_series = synthetic_series(60);
        let cfg = ForecastConfig::default();
        let (mut model, _) = fit_model(
            ForecasterKind::Linear,
            &cfg,
            &fit_series,
            &[1],
            0.25,
        )
        .unwrap();
        // continuation of the same drift, 60 steps later
        let eval_series = LoadSeries {
            m: 4,
            // both layers share the drift tail
            layers: (0..fit_series.layers.len())
                .map(|_| {
                    (60..90)
                        .map(|t| {
                            let d = 0.002 * t as f64;
                            vec![0.3 + d, 0.3 - d, 0.2, 0.2]
                        })
                        .collect::<Vec<_>>()
                })
                .collect(),
        };
        let report =
            eval_model(&mut model, &eval_series, &[1, 4]).unwrap();
        assert_eq!(report.by_horizon.len(), 2);
        for h in &report.by_horizon {
            assert!(h.samples > 0);
            assert!(h.mae < 0.05, "drift continuation mae {}", h.mae);
        }
        // shape mismatches are errors, not panics
        let bad = LoadSeries { m: 3, layers: vec![vec![vec![1.0; 3]]] };
        assert!(eval_model(&mut model, &bad, &[1]).is_err());
    }
}
