//! Turning forecasts into control actions.
//!
//! Three consumers of a fitted [`ForecastModel`]:
//!
//! * **Dual warm start** ([`dual_seed`] / [`seed_states`]): convert a
//!   predicted load-fraction vector into an Algorithm 1 dual seed. On a
//!   deterministic stream whose per-expert score profile equals `pred`,
//!   Algorithm 1's fixpoint is `q_j = relu(pred_j − pred_(k+1))` (the
//!   (k+1)-th largest profile entry): the q-phase maps every hot expert
//!   down to the (k+1)-th level so top-k of `s − q` spreads. Recorded
//!   load fractions under-state demand — the serving router clips them
//!   at `capacity_factor ×` fair share — so the seed is amplified by
//!   [`DEFAULT_SEED_GAIN`]. `routing::PredictiveBip` starts from this q
//!   and the per-batch dual update refines it, so the very first
//!   micro-batch routes against the predicted hot set (`bench_forecast`
//!   measures the first-batch MaxVio drop and the dual-iteration
//!   savings).
//! * **Predictive admission** ([`PredictiveAdmission`]): forecast the
//!   next window's arrival rate and deterministically shed the traffic
//!   that would exceed the serving capacity *before* it queues, instead
//!   of letting the bounded queue absorb the burst and blow p99.
//! * **Autoscaling** ([`AutoScaler`]): forecast the aggregate rate and
//!   size the active replica set ahead of the load; the reactive
//!   variant (scale on the last observed window) is the baseline, and
//!   the hindsight oracle scores both.
//!
//! [`route_state_seed`] is the training-side consumer: it warm-starts a
//! run's `(n_layers, m)` route-state tensor from a prior run's trace.

use anyhow::{bail, Result};

use crate::routing::BalanceState;
use crate::trace::Trace;

use super::fit::{fit_model, ForecastModel, LoadSeries};
use super::model::{ForecastConfig, ForecasterKind};

/// Amplification applied to load-fraction dual seeds. Enforced loads in
/// a trace are clipped at `capacity_factor ×` fair share (default 2×),
/// so the fraction profile under-states the raw score skew the duals
/// must counter; 2× restores the scale at the default capacity factor.
pub const DEFAULT_SEED_GAIN: f64 = 2.0;

/// Algorithm 1 dual seed from a predicted load-fraction vector:
/// `q_j = gain * relu(pred_j − (k+1)-th largest of pred)`.
pub fn dual_seed(pred: &[f64], k: usize, gain: f64) -> Vec<f32> {
    let m = pred.len();
    if m == 0 {
        return Vec::new();
    }
    let mut sorted: Vec<f64> = pred.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    // the (k+1)-th largest (clamped: with m <= k every entry is top-k
    // and the seed is all-zero via the smallest entry)
    let thr = sorted[k.min(m - 1)];
    pred.iter()
        .map(|&p| ((p - thr).max(0.0) * gain) as f32)
        .collect()
}

/// One [`BalanceState::Dual`] per layer from a fitted model's
/// one-step-ahead forecasts — what `ServingRouter::seed_layers` (and
/// `ReplicaSet::seed_all`) consume. Models fitted on fewer layers than
/// the stack reuse their last layer.
pub fn seed_states(
    model: &ForecastModel,
    n_layers: usize,
    k: usize,
    gain: f64,
) -> Vec<BalanceState> {
    (0..n_layers)
        .map(|l| {
            BalanceState::Dual(dual_seed(&model.layer_forecast(l, 1), k, gain))
        })
        .collect()
}

/// Warm-start a training run's route-state tensor (row-major
/// `(n_layers, m)`) from a prior run's recorded trace: fit a quick EWMA
/// on the trace's load series and seed every layer's dual vector.
pub fn route_state_seed(
    trace: &Trace,
    n_layers: usize,
    m: usize,
    k: usize,
    gain: f64,
) -> Result<Vec<f32>> {
    if trace.meta.serve.router.m != m {
        bail!(
            "trace has {} experts, the training config has {m}",
            trace.meta.serve.router.m
        );
    }
    let series = LoadSeries::from_trace(trace)?;
    let (model, _) = fit_model(
        ForecasterKind::Ewma,
        &ForecastConfig::default(),
        &series,
        &[1],
        0.25,
    )?;
    let mut out = Vec::with_capacity(n_layers * m);
    for l in 0..n_layers {
        out.extend(dual_seed(&model.layer_forecast(l, 1), k, gain));
    }
    Ok(out)
}

/// Scalar Holt (double-exponential) smoother for aggregate rates.
#[derive(Clone, Copy, Debug)]
pub struct ScalarHolt {
    pub alpha: f64,
    pub beta: f64,
    level: f64,
    trend: f64,
    steps: u64,
}

impl ScalarHolt {
    pub fn new(alpha: f64, beta: f64) -> ScalarHolt {
        assert!(alpha > 0.0 && alpha <= 1.0 && (0.0..=1.0).contains(&beta));
        ScalarHolt { alpha, beta, level: 0.0, trend: 0.0, steps: 0 }
    }

    pub fn observe(&mut self, x: f64) {
        if self.steps == 0 {
            self.level = x;
        } else {
            let prev = self.level;
            self.level = self.alpha * x
                + (1.0 - self.alpha) * (self.level + self.trend);
            self.trend = self.beta * (self.level - prev)
                + (1.0 - self.beta) * self.trend;
        }
        self.steps += 1;
    }

    /// Predicted value `h >= 1` steps ahead, floored at 0 (rates cannot
    /// be negative); 0 before any observation.
    pub fn forecast(&self, h: usize) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        (self.level + h.max(1) as f64 * self.trend).max(0.0)
    }

    pub fn observed_steps(&self) -> u64 {
        self.steps
    }
}

/// Forecast-gated admission: shed offered traffic the serving set is
/// predicted not to sustain over the next window. Deterministic — the
/// shed decision is a pure function of the arrival stream.
#[derive(Clone, Debug)]
pub struct PredictiveAdmission {
    /// rate-accounting window, virtual microseconds
    pub window_us: u64,
    /// requests/s the serving set can sustain (calibrate from a
    /// measured run's throughput)
    pub capacity_rps: f64,
    /// admit up to `headroom * capacity_rps` of predicted demand
    pub headroom: f64,
    rate: ScalarHolt,
    window_start: u64,
    in_window: u64,
    predicted_rps: f64,
    /// fractional-shed accumulator (error-diffusion, not RNG)
    debt: f64,
    /// requests shed by prediction
    pub shed: u64,
    /// windows closed so far
    pub windows: u64,
}

impl PredictiveAdmission {
    pub fn new(
        window_us: u64,
        capacity_rps: f64,
        headroom: f64,
    ) -> PredictiveAdmission {
        assert!(window_us > 0 && capacity_rps > 0.0 && headroom > 0.0);
        PredictiveAdmission {
            window_us,
            capacity_rps,
            headroom,
            rate: ScalarHolt::new(0.4, 0.1),
            window_start: 0,
            in_window: 0,
            predicted_rps: 0.0,
            debt: 0.0,
            shed: 0,
            windows: 0,
        }
    }

    fn roll_to(&mut self, now_us: u64) {
        let behind = (now_us.saturating_sub(self.window_start))
            / self.window_us;
        if behind == 0 {
            return;
        }
        let secs = self.window_us as f64 / 1e6;
        // close the current window, then account idle gap windows —
        // capped: after a long gap the smoother has decayed to ~0 anyway
        for _ in 0..behind.min(64) {
            self.rate.observe(self.in_window as f64 / secs);
            self.in_window = 0;
            self.windows += 1;
        }
        self.predicted_rps = self.rate.forecast(1);
        self.window_start += behind * self.window_us;
    }

    /// Account one offered arrival; false means shed it (the caller
    /// must still count it offered + rejected, e.g. `MicroBatcher::shed`).
    pub fn admit(&mut self, arrival_us: u64) -> bool {
        self.roll_to(arrival_us);
        self.in_window += 1;
        let budget = self.capacity_rps * self.headroom;
        if self.predicted_rps <= budget {
            return true;
        }
        // shed the predicted excess fraction by error diffusion
        self.debt += 1.0 - budget / self.predicted_rps;
        if self.debt >= 1.0 {
            self.debt -= 1.0;
            self.shed += 1;
            false
        } else {
            true
        }
    }
}

/// How the autoscaler picks the next window's replica count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScalePolicy {
    /// scale to the *forecast* next-window rate
    Predictive,
    /// scale to the last *observed* window rate (always one window late)
    Reactive,
}

impl ScalePolicy {
    pub fn name(self) -> &'static str {
        match self {
            ScalePolicy::Predictive => "predictive",
            ScalePolicy::Reactive => "reactive",
        }
    }
}

/// One replica-count change.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScaleEvent {
    pub at_us: u64,
    pub from: usize,
    pub to: usize,
    /// the rate the decision was made against
    pub decided_rps: f64,
    /// the rate observed over the window that just closed
    pub observed_rps: f64,
}

/// Per-window log for the hindsight oracle.
#[derive(Clone, Copy, Debug)]
pub struct WindowObs {
    pub start_us: u64,
    pub arrivals: u64,
    /// replicas active while the window ran
    pub active: usize,
}

/// Forecast-driven replica up/down-scaling. The serving loop feeds it
/// every ingested arrival and reads [`AutoScaler::active`] when picking
/// dispatch targets; decisions fire on window boundaries.
#[derive(Clone, Debug)]
pub struct AutoScaler {
    pub policy: ScalePolicy,
    pub window_us: u64,
    /// requests/s one replica can sustain
    pub replica_rps: f64,
    /// target utilization: scale so predicted rate <= headroom *
    /// active * replica_rps
    pub headroom: f64,
    pub min_replicas: usize,
    pub max_replicas: usize,
    rate: ScalarHolt,
    window_start: u64,
    in_window: u64,
    active: usize,
    pub events: Vec<ScaleEvent>,
    pub windows: Vec<WindowObs>,
}

impl AutoScaler {
    pub fn new(
        policy: ScalePolicy,
        window_us: u64,
        replica_rps: f64,
        headroom: f64,
        min_replicas: usize,
        max_replicas: usize,
    ) -> AutoScaler {
        assert!(window_us > 0 && replica_rps > 0.0 && headroom > 0.0);
        assert!(1 <= min_replicas && min_replicas <= max_replicas);
        AutoScaler {
            policy,
            window_us,
            replica_rps,
            headroom,
            min_replicas,
            max_replicas,
            // aggressive tracking: scaling must anticipate ramps, and a
            // sluggish level forfeits the one-window lead over reactive
            rate: ScalarHolt::new(0.9, 0.6),
            window_start: 0,
            in_window: 0,
            active: min_replicas,
            events: Vec::new(),
            windows: Vec::new(),
        }
    }

    pub fn active(&self) -> usize {
        self.active
    }

    /// Replicas needed to serve `rps` at the target utilization.
    pub fn desired(&self, rps: f64) -> usize {
        ((rps / (self.replica_rps * self.headroom)).ceil() as usize)
            .clamp(self.min_replicas, self.max_replicas)
    }

    /// Account one ingested arrival; window boundaries crossed since
    /// the last call close (logging + scale decision), then the arrival
    /// lands in the current window.
    pub fn on_arrival(&mut self, arrival_us: u64) {
        while arrival_us >= self.window_start + self.window_us {
            let secs = self.window_us as f64 / 1e6;
            let observed_rps = self.in_window as f64 / secs;
            self.windows.push(WindowObs {
                start_us: self.window_start,
                arrivals: self.in_window,
                active: self.active,
            });
            self.rate.observe(observed_rps);
            let decided_rps = match self.policy {
                ScalePolicy::Predictive => self.rate.forecast(1),
                ScalePolicy::Reactive => observed_rps,
            };
            let want = self.desired(decided_rps);
            if want != self.active {
                self.events.push(ScaleEvent {
                    at_us: self.window_start + self.window_us,
                    from: self.active,
                    to: want,
                    decided_rps,
                    observed_rps,
                });
                self.active = want;
            }
            self.in_window = 0;
            self.window_start += self.window_us;
            // long idle gap: decay the smoother once per empty window,
            // but never loop unbounded on a sparse stream
            if arrival_us >= self.window_start + 64 * self.window_us {
                let skip = (arrival_us - self.window_start)
                    / self.window_us;
                self.window_start += skip * self.window_us;
            }
        }
        self.in_window += 1;
    }

    /// Close the final partial window (end of run) so the oracle sees it.
    pub fn finish(&mut self) {
        if self.in_window > 0 {
            self.windows.push(WindowObs {
                start_us: self.window_start,
                arrivals: self.in_window,
                active: self.active,
            });
            self.in_window = 0;
        }
    }

    /// Hindsight oracle: the fraction of windows whose active count
    /// equaled the count the window's *own* observed rate needed. The
    /// reactive baseline is always one window late on every transition;
    /// an accurate forecaster closes that gap.
    pub fn oracle_match_rate(&self) -> f64 {
        if self.windows.is_empty() {
            return 1.0;
        }
        let secs = self.window_us as f64 / 1e6;
        let matched = self
            .windows
            .iter()
            .filter(|w| w.active == self.desired(w.arrivals as f64 / secs))
            .count();
        matched as f64 / self.windows.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dual_seed_is_the_fixpoint_of_the_predicted_profile() {
        // m=8, k=2: threshold is the 3rd largest (0.2); only the two
        // hotter experts get positive duals
        let pred = [0.30, 0.25, 0.20, 0.05, 0.05, 0.05, 0.05, 0.05];
        let q = dual_seed(&pred, 2, 1.0);
        assert_eq!(q.len(), 8);
        assert!((q[0] - 0.10).abs() < 1e-6);
        assert!((q[1] - 0.05).abs() < 1e-6);
        assert!(q[2..].iter().all(|&x| x == 0.0), "{q:?}");
        // gain scales linearly
        let q2 = dual_seed(&pred, 2, 2.0);
        assert!((q2[0] - 0.20).abs() < 1e-6);
        // uniform prediction seeds nothing
        let qu = dual_seed(&[0.125; 8], 2, DEFAULT_SEED_GAIN);
        assert!(qu.iter().all(|&x| x == 0.0));
        // degenerate shapes stay in bounds
        assert!(dual_seed(&[], 2, 1.0).is_empty());
        let q1 = dual_seed(&[1.0], 4, 1.0);
        assert_eq!(q1, vec![0.0]);
    }

    #[test]
    fn scalar_holt_tracks_a_ramp() {
        let mut h = ScalarHolt::new(0.5, 0.3);
        for t in 0..40 {
            h.observe(100.0 + 10.0 * t as f64);
        }
        // next value is 100 + 10*40 = 500; the trend model gets close
        // where a last-value forecast is off by the full slope
        let pred = h.forecast(1);
        assert!((pred - 500.0).abs() < 5.0, "pred {pred}");
        assert!(h.forecast(5) > pred);
        // floored at zero on a collapsing series
        let mut d = ScalarHolt::new(0.5, 0.5);
        for t in 0..30 {
            d.observe((300.0 - 30.0 * t as f64).max(0.0));
        }
        assert!(d.forecast(8) >= 0.0);
    }

    #[test]
    fn predictive_admission_sheds_the_predicted_excess() {
        // capacity 50 req/s, headroom 1.0, window 1s; offer 100 req/s
        let mut adm = PredictiveAdmission::new(1_000_000, 50.0, 1.0);
        let mut admitted = 0u64;
        let mut offered = 0u64;
        // 10 virtual seconds of 100 evenly spaced arrivals per second
        for s in 0..10u64 {
            for i in 0..100u64 {
                offered += 1;
                if adm.admit(s * 1_000_000 + i * 10_000) {
                    admitted += 1;
                }
            }
        }
        assert_eq!(offered, admitted + adm.shed);
        // the first window is un-forecast (admit-all); once the rate is
        // learned, ~half of each window is shed
        assert!(adm.shed >= 300, "shed {}", adm.shed);
        assert!(admitted >= 500, "admitted {admitted}");
        assert!(adm.windows >= 9);
        // under-capacity traffic is never shed
        let mut calm = PredictiveAdmission::new(1_000_000, 50.0, 1.0);
        for s in 0..5u64 {
            for i in 0..20u64 {
                assert!(calm.admit(s * 1_000_000 + i * 50_000));
            }
        }
        assert_eq!(calm.shed, 0);
    }

    #[test]
    fn autoscaler_scales_with_the_rate_and_logs_events() {
        // one replica serves 100 req/s; offered rate ramps 50 -> 350
        let mk = |policy| {
            AutoScaler::new(policy, 1_000_000, 100.0, 1.0, 1, 4)
        };
        for policy in [ScalePolicy::Predictive, ScalePolicy::Reactive] {
            let mut sc = mk(policy);
            assert_eq!(sc.active(), 1);
            let mut t = 0u64;
            for w in 0..12u64 {
                let rate = 50 + w * 30; // arrivals this window
                for i in 0..rate {
                    sc.on_arrival(t + i * (1_000_000 / rate));
                }
                t += 1_000_000;
            }
            sc.finish();
            assert!(sc.active() >= 3, "{policy:?} ended at {}", sc.active());
            assert!(!sc.events.is_empty());
            for e in &sc.events {
                assert!(e.to >= 1 && e.to <= 4);
                assert_ne!(e.from, e.to);
            }
            assert!(!sc.windows.is_empty());
            let rate = sc.oracle_match_rate();
            assert!((0.0..=1.0).contains(&rate), "{rate}");
        }
    }

    #[test]
    fn predictive_scaler_leads_reactive_on_a_steady_ramp() {
        // under a linear ramp the forecaster anticipates next window's
        // rate, so across the run the predictive scaler matches the
        // hindsight oracle at least as often as the reactive one
        let run = |policy| -> f64 {
            let mut sc =
                AutoScaler::new(policy, 1_000_000, 100.0, 1.0, 1, 8);
            let mut t = 0u64;
            for w in 0..16u64 {
                let rate = 40 + w * 45;
                for i in 0..rate {
                    sc.on_arrival(t + i * (1_000_000 / rate));
                }
                t += 1_000_000;
            }
            sc.finish();
            sc.oracle_match_rate()
        };
        let pred = run(ScalePolicy::Predictive);
        let reac = run(ScalePolicy::Reactive);
        assert!(pred >= reac, "predictive {pred} !>= reactive {reac}");
    }

    #[test]
    fn idle_gaps_do_not_stall_the_controllers() {
        let mut adm = PredictiveAdmission::new(1_000, 1000.0, 1.0);
        adm.admit(0);
        // a huge virtual-time jump must neither loop forever nor panic
        assert!(adm.admit(10_000_000_000));
        let mut sc =
            AutoScaler::new(ScalePolicy::Predictive, 1_000, 1000.0, 1.0, 1, 4);
        sc.on_arrival(0);
        sc.on_arrival(10_000_000_000);
        sc.on_arrival(10_000_000_100);
        assert_eq!(sc.active(), 1);
    }
}
