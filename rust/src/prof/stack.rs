//! The profiler hot path: thread-local frame stack + sharded,
//! lock-free call-path tables.
//!
//! Ownership rules (see DESIGN.md):
//!
//! * The **frame stack is thread-local** — a [`ProfGuard`] must drop on
//!   the thread that entered it (RAII makes this structural; guards are
//!   `!Send` because they borrow nothing but the TLS stack).
//! * A call path is the packed sequence of active frames, one byte per
//!   level (`Frame::code()`), innermost in the low byte. Depth is
//!   capped at [`MAX_DEPTH`]; deeper frames are *dropped and counted*
//!   (`prof_stack_overflow_total`), never truncated mid-path.
//! * Aggregation is per-path into [`N_SHARDS`] static open-addressing
//!   tables (claimed with a CAS on the packed path key, updated with
//!   relaxed `fetch_add`). The same path may live in several shards —
//!   the scrape in [`crate::prof::export`] merges them, exactly like
//!   the telemetry registry's shard merge.
//! * Steady state performs **zero heap allocations**: no boxing, no
//!   formatting, no locks — the `hot-path-alloc` / `lock-discipline`
//!   lints gate `enter`/`push_frame`/`pop_frame_record`/`record_path`.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::perf::alloc::thread_allocs;
use crate::telemetry::registry::shard_index;
use crate::telemetry::{counter_add, Counter};

use super::frame::Frame;

/// Maximum nesting depth of live profiler frames per thread.
pub const MAX_DEPTH: usize = 8;
/// Path-table shards (mirrors the telemetry registry's shard count).
pub const N_SHARDS: usize = 16;
/// Open-addressing slots per shard (power of two).
const SLOTS_PER_SHARD: usize = 256;
/// Linear-probe bound before a record is dropped (and counted).
const PROBE_LIMIT: usize = 32;

/// Profiler master switch. Defaults on: the record path is a handful
/// of TLS cell writes plus one sharded `fetch_add` per frame exit, and
/// a live `bip-moe serve` must move `prof_frames_total` for the
/// `metrics check` CI gate.
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Enable/disable frame recording (scrapes still work while disabled).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Release);
}

/// Is frame recording enabled?
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Acquire)
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

// HOT: monotonic ns since the profiler epoch (first frame ever entered)
#[inline]
fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// One aggregated (call path → totals) cell.
struct Slot {
    /// packed path key; 0 = empty
    key: AtomicU64,
    incl_ns: AtomicU64,
    excl_ns: AtomicU64,
    calls: AtomicU64,
    allocs: AtomicU64,
}

#[allow(clippy::declare_interior_mutable_const)]
const SLOT_INIT: Slot = Slot {
    key: AtomicU64::new(0),
    incl_ns: AtomicU64::new(0),
    excl_ns: AtomicU64::new(0),
    calls: AtomicU64::new(0),
    allocs: AtomicU64::new(0),
};

#[allow(clippy::declare_interior_mutable_const)]
const SHARD_INIT: [Slot; SLOTS_PER_SHARD] = [SLOT_INIT; SLOTS_PER_SHARD];

/// The static path tables: ~160 KiB of atomics, fully preallocated.
static TABLES: [[Slot; SLOTS_PER_SHARD]; N_SHARDS] = [SHARD_INIT; N_SHARDS];

/// Per-thread frame stack. All cells are const-initialized; entering a
/// frame touches no heap.
struct TlsStack {
    depth: Cell<usize>,
    /// packed path of the live frames (innermost = low byte)
    path: Cell<u64>,
    start_ns: [Cell<u64>; MAX_DEPTH],
    /// ns spent in already-popped direct children of each level
    child_ns: [Cell<u64>; MAX_DEPTH],
    /// `thread_allocs()` snapshot at frame entry
    alloc0: [Cell<u64>; MAX_DEPTH],
}

#[allow(clippy::declare_interior_mutable_const)]
const CELL0: Cell<u64> = Cell::new(0);

thread_local! {
    static STACK: TlsStack = const {
        TlsStack {
            depth: Cell::new(0),
            path: Cell::new(0),
            start_ns: [CELL0; MAX_DEPTH],
            child_ns: [CELL0; MAX_DEPTH],
            alloc0: [CELL0; MAX_DEPTH],
        }
    };
}

/// RAII guard for one profiler frame: [`ProfGuard::enter`] pushes,
/// drop pops and records the (inclusive, exclusive, allocs) totals
/// into this thread's shard under the full call path.
#[must_use = "a ProfGuard records its frame when dropped"]
pub struct ProfGuard {
    live: bool,
    /// ties the guard to the entering thread's TLS stack
    _not_send: std::marker::PhantomData<*const ()>,
}

impl ProfGuard {
    // HOT: per-frame entry — TLS cell writes only
    #[inline]
    pub fn enter(frame: Frame) -> ProfGuard {
        if !enabled() {
            return ProfGuard {
                live: false,
                _not_send: std::marker::PhantomData,
            };
        }
        let live = STACK.with(|s| push_frame(s, frame));
        ProfGuard { live, _not_send: std::marker::PhantomData }
    }
}

impl Drop for ProfGuard {
    // HOT: per-frame exit
    #[inline]
    fn drop(&mut self) {
        if self.live {
            STACK.with(pop_frame_record);
        }
    }
}

// HOT: push one frame onto the TLS stack; false = dropped (too deep)
#[inline]
fn push_frame(s: &TlsStack, frame: Frame) -> bool {
    let d = s.depth.get();
    if d >= MAX_DEPTH {
        counter_add(Counter::ProfStackOverflow, 1);
        return false;
    }
    s.path.set((s.path.get() << 8) | frame.code() as u64);
    s.start_ns[d].set(now_ns());
    s.child_ns[d].set(0);
    s.alloc0[d].set(thread_allocs());
    s.depth.set(d + 1);
    true
}

// HOT: pop the innermost frame and record its totals under the path
#[inline]
fn pop_frame_record(s: &TlsStack) {
    let Some(d) = s.depth.get().checked_sub(1) else {
        // unbalanced guard (a reset raced a live frame); drop silently
        return;
    };
    let total = now_ns().saturating_sub(s.start_ns[d].get());
    let excl = total.saturating_sub(s.child_ns[d].get());
    // saturating: a reset_thread_counts() inside the frame window must
    // not wrap the delta
    let allocs = thread_allocs().saturating_sub(s.alloc0[d].get());
    record_path(s.path.get(), total, excl, allocs);
    s.path.set(s.path.get() >> 8);
    s.depth.set(d);
    if let Some(p) = d.checked_sub(1) {
        s.child_ns[p].set(s.child_ns[p].get() + total);
    }
}

// HOT: fibonacci-hash start slot for a packed path
#[inline]
fn slot_hash(path: u64) -> usize {
    (path.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 56) as usize
        & (SLOTS_PER_SHARD - 1)
}

// HOT: aggregate one finished frame into this thread's shard
#[inline]
fn record_path(path: u64, incl_ns: u64, excl_ns: u64, allocs: u64) {
    let shard = &TABLES[shard_index() % N_SHARDS];
    let mut idx = slot_hash(path);
    for _ in 0..PROBE_LIMIT {
        let slot = &shard[idx];
        let k = slot.key.load(Ordering::Acquire);
        let owned = k == path
            || (k == 0
                && match slot.key.compare_exchange(
                    0,
                    path,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => true,
                    Err(actual) => actual == path,
                });
        if owned {
            slot.incl_ns.fetch_add(incl_ns, Ordering::Relaxed);
            slot.excl_ns.fetch_add(excl_ns, Ordering::Relaxed);
            slot.calls.fetch_add(1, Ordering::Relaxed);
            slot.allocs.fetch_add(allocs, Ordering::Relaxed);
            counter_add(Counter::ProfFrames, 1);
            return;
        }
        idx = (idx + 1) & (SLOTS_PER_SHARD - 1);
    }
    // shard full for this probe window: drop + count, never block
    counter_add(Counter::ProfStackOverflow, 1);
}

// COLD: scrape seam — visit every occupied slot across all shards.
// Values are read after the key, so a record racing the scrape is
// either fully visible or attributed to the next scrape.
pub(crate) fn for_each_slot(
    mut f: impl FnMut(u64, u64, u64, u64, u64),
) {
    for shard in &TABLES {
        for slot in shard {
            let key = slot.key.load(Ordering::Acquire);
            if key == 0 {
                continue;
            }
            f(
                key,
                slot.incl_ns.load(Ordering::Relaxed),
                slot.excl_ns.load(Ordering::Relaxed),
                slot.calls.load(Ordering::Relaxed),
                slot.allocs.load(Ordering::Relaxed),
            );
        }
    }
}

// COLD: zero every slot (test/CLI seam between measured runs). Not
// linearizable against concurrent recording — callers quiesce first.
pub fn reset() {
    for shard in &TABLES {
        for slot in shard {
            slot.incl_ns.store(0, Ordering::Relaxed);
            slot.excl_ns.store(0, Ordering::Relaxed);
            slot.calls.store(0, Ordering::Relaxed);
            slot.allocs.store(0, Ordering::Relaxed);
            slot.key.store(0, Ordering::Release);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_path_shifts_round_trip() {
        STACK.with(|s| {
            // drain any depth left over from other tests in this thread
            while s.depth.get() > 0 {
                pop_frame_record(s);
            }
            assert!(push_frame(s, Frame::Serve));
            assert!(push_frame(s, Frame::Dispatch));
            assert_eq!(
                s.path.get(),
                ((Frame::Serve.code() as u64) << 8)
                    | Frame::Dispatch.code() as u64
            );
            pop_frame_record(s);
            assert_eq!(s.path.get(), Frame::Serve.code() as u64);
            pop_frame_record(s);
            assert_eq!(s.path.get(), 0);
            assert_eq!(s.depth.get(), 0);
        });
    }

    #[test]
    fn depth_overflow_drops_not_corrupts() {
        STACK.with(|s| {
            while s.depth.get() > 0 {
                pop_frame_record(s);
            }
            for _ in 0..MAX_DEPTH {
                assert!(push_frame(s, Frame::LayerRoute));
            }
            assert!(!push_frame(s, Frame::TopK), "9th frame must drop");
            assert_eq!(s.depth.get(), MAX_DEPTH);
            for _ in 0..MAX_DEPTH {
                pop_frame_record(s);
            }
            assert_eq!(s.depth.get(), 0);
            assert_eq!(s.path.get(), 0);
        });
    }

    #[test]
    fn unbalanced_pop_is_a_noop() {
        STACK.with(|s| {
            while s.depth.get() > 0 {
                pop_frame_record(s);
            }
            pop_frame_record(s); // must not underflow
            assert_eq!(s.depth.get(), 0);
        });
    }
}
