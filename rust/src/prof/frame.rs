//! The closed set of profiler frames (interned static names).
//!
//! A [`Frame`] is one level of the serving/training call hierarchy.
//! Discriminants are dense and pinned: a call *path* is packed into a
//! `u64` at one byte per level (`discriminant + 1`, so byte 0 means
//! "empty"), which caps the set at 255 frames and the stack at
//! [`crate::prof::MAX_DEPTH`] levels. Adding a frame means appending a
//! variant, extending [`Frame::ALL`], and giving it a name — the
//! `telemetry-naming`-style invariants are pinned by unit tests below.

/// One level of the profiled call hierarchy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Frame {
    /// whole serving run (`serve::run_scenario*` — the root frame)
    Serve = 0,
    /// admission: one request offered to the micro-batcher
    Admission = 1,
    /// one micro-batch dispatched into a router
    Dispatch = 2,
    /// one layer's routing inside `route_batch_into`
    LayerRoute = 3,
    /// gate-score fill into the arena
    ScoreFill = 4,
    /// capacity-enforcing top-K selection sweep
    TopK = 5,
    /// Algorithm 1 dual update (fixed-T or adaptive, whole solve)
    DualUpdate = 6,
    /// Algorithm 1 p-phase (token-side assignment pass)
    DualP = 7,
    /// Algorithm 1 q-phase (expert-side dual adjustment pass)
    DualQ = 8,
    /// replica balance-state merge-sync
    MergeSync = 9,
    /// one training step
    TrainStep = 10,
    /// one forecaster fit over a load series
    ForecastFit = 11,
    /// score-matrix transpose / cache-blocked layout step (fill-side
    /// in the router, or solver-side when no stamped copy exists)
    Transpose = 12,
}

/// Number of frame kinds (== `Frame::ALL.len()`).
pub const N_FRAMES: usize = 13;

impl Frame {
    /// Every frame, indexed by discriminant.
    pub const ALL: [Frame; N_FRAMES] = [
        Frame::Serve,
        Frame::Admission,
        Frame::Dispatch,
        Frame::LayerRoute,
        Frame::ScoreFill,
        Frame::TopK,
        Frame::DualUpdate,
        Frame::DualP,
        Frame::DualQ,
        Frame::MergeSync,
        Frame::TrainStep,
        Frame::ForecastFit,
        Frame::Transpose,
    ];

    /// Static frame name as it appears in folded stacks and
    /// `PROF_*.json` path strings.
    pub fn name(self) -> &'static str {
        match self {
            Frame::Serve => "serve",
            Frame::Admission => "admission",
            Frame::Dispatch => "dispatch",
            Frame::LayerRoute => "layer_route",
            Frame::ScoreFill => "score_fill",
            Frame::TopK => "top_k",
            Frame::DualUpdate => "dual_update",
            Frame::DualP => "dual_p",
            Frame::DualQ => "dual_q",
            Frame::MergeSync => "merge_sync",
            Frame::TrainStep => "train_step",
            Frame::ForecastFit => "forecast_fit",
            Frame::Transpose => "transpose",
        }
    }

    /// Decode one packed path byte (`discriminant + 1`); 0 and
    /// out-of-range codes return `None`.
    pub fn from_code(code: u8) -> Option<Frame> {
        let idx = (code as usize).checked_sub(1)?;
        Frame::ALL.get(idx).copied()
    }

    /// The packed-path byte for this frame.
    pub fn code(self) -> u8 {
        self as u8 + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discriminants_are_dense_and_pinned() {
        for (i, f) in Frame::ALL.iter().enumerate() {
            assert_eq!(*f as usize, i, "{f:?}");
            assert_eq!(Frame::from_code(f.code()), Some(*f));
        }
        assert_eq!(Frame::from_code(0), None);
        assert_eq!(Frame::from_code(N_FRAMES as u8 + 1), None);
        assert!(N_FRAMES <= 255, "one byte per level caps the enum");
    }

    #[test]
    fn names_are_unique_snake_case() {
        let mut seen = std::collections::BTreeSet::new();
        for f in Frame::ALL {
            let n = f.name();
            assert!(!n.is_empty());
            assert!(
                n.chars().all(|c| c.is_ascii_lowercase()
                    || c.is_ascii_digit()
                    || c == '_'),
                "{n}"
            );
            assert!(seen.insert(n), "duplicate frame name {n}");
        }
    }
}
