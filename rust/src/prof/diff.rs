//! Profile diffing: attribute a throughput delta to the guilty call
//! paths.
//!
//! `diff(prev, cur)` aligns two profiles on the union of their path
//! strings and sorts by Δexclusive-ns descending — the path whose own
//! time grew the most is the regression suspect, independent of how
//! its parents moved. Allocation deltas ride along as the second
//! signal: a path that got slower *and* started allocating is almost
//! always a lost arena reuse.

use crate::metrics::TablePrinter;

use super::export::Profile;

/// One aligned path across two profiles.
#[derive(Clone, Debug, PartialEq)]
pub struct DiffRow {
    pub path: String,
    pub prev_excl_ns: u64,
    pub cur_excl_ns: u64,
    /// `cur - prev` exclusive ns (positive = regression)
    pub delta_excl_ns: i64,
    pub prev_allocs: u64,
    pub cur_allocs: u64,
    pub prev_calls: u64,
    pub cur_calls: u64,
}

impl DiffRow {
    /// Per-call exclusive ns in the current profile (0-call safe).
    pub fn cur_ns_per_call(&self) -> f64 {
        if self.cur_calls == 0 {
            0.0
        } else {
            self.cur_excl_ns as f64 / self.cur_calls as f64
        }
    }
}

/// Align two profiles on the union of call paths, sorted by
/// Δexclusive-ns descending (worst regression first).
pub fn diff(prev: &Profile, cur: &Profile) -> Vec<DiffRow> {
    let mut by_path: std::collections::BTreeMap<&str, DiffRow> =
        std::collections::BTreeMap::new();
    for p in &prev.paths {
        by_path.insert(
            p.path.as_str(),
            DiffRow {
                path: p.path.clone(),
                prev_excl_ns: p.exclusive_ns,
                cur_excl_ns: 0,
                delta_excl_ns: 0,
                prev_allocs: p.allocs,
                cur_allocs: 0,
                prev_calls: p.calls,
                cur_calls: 0,
            },
        );
    }
    for c in &cur.paths {
        let row = by_path.entry(c.path.as_str()).or_insert(DiffRow {
            path: c.path.clone(),
            prev_excl_ns: 0,
            cur_excl_ns: 0,
            delta_excl_ns: 0,
            prev_allocs: 0,
            cur_allocs: 0,
            prev_calls: 0,
            cur_calls: 0,
        });
        row.cur_excl_ns = c.exclusive_ns;
        row.cur_allocs = c.allocs;
        row.cur_calls = c.calls;
    }
    let mut rows: Vec<DiffRow> = by_path.into_values().collect();
    for r in &mut rows {
        r.delta_excl_ns =
            r.cur_excl_ns as i64 - r.prev_excl_ns as i64;
    }
    rows.sort_by(|a, b| {
        b.delta_excl_ns
            .cmp(&a.delta_excl_ns)
            .then_with(|| a.path.cmp(&b.path))
    });
    rows
}

/// The `n` paths whose exclusive time regressed the most (positive
/// delta only) — what a failed bench gate prints.
pub fn top_regressions(
    prev: &Profile,
    cur: &Profile,
    n: usize,
) -> Vec<DiffRow> {
    diff(prev, cur)
        .into_iter()
        .filter(|r| r.delta_excl_ns > 0)
        .take(n)
        .collect()
}

/// Render diff rows as the perf-delta table (`prev`/`cur`/Δ exclusive
/// ms, Δ%, alloc and call columns).
pub fn render_table(title: &str, rows: &[DiffRow]) -> TablePrinter {
    let mut t = TablePrinter::new(
        title,
        &[
            "call path",
            "prev excl ms",
            "cur excl ms",
            "delta ms",
            "delta %",
            "allocs prev->cur",
            "calls prev->cur",
        ],
    );
    for r in rows {
        let pct = if r.prev_excl_ns == 0 {
            "new".to_string()
        } else {
            format!(
                "{:+.1}%",
                100.0 * r.delta_excl_ns as f64 / r.prev_excl_ns as f64
            )
        };
        t.row(vec![
            r.path.clone(),
            format!("{:.3}", r.prev_excl_ns as f64 / 1e6),
            format!("{:.3}", r.cur_excl_ns as f64 / 1e6),
            format!("{:+.3}", r.delta_excl_ns as f64 / 1e6),
            pct,
            format!("{} -> {}", r.prev_allocs, r.cur_allocs),
            format!("{} -> {}", r.prev_calls, r.cur_calls),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prof::export::PathStat;

    fn prof(rows: &[(&str, u64, u64, u64)]) -> Profile {
        Profile {
            paths: rows
                .iter()
                .map(|&(p, excl, calls, allocs)| PathStat {
                    path: p.to_string(),
                    depth: p.split(';').count(),
                    inclusive_ns: excl,
                    exclusive_ns: excl,
                    calls,
                    allocs,
                })
                .collect(),
        }
    }

    #[test]
    fn diff_sorts_worst_regression_first() {
        let prev = prof(&[
            ("serve", 100, 1, 0),
            ("serve;dispatch", 500, 10, 0),
        ]);
        let cur = prof(&[
            ("serve", 150, 1, 0),
            ("serve;dispatch", 2000, 10, 3),
        ]);
        let rows = diff(&prev, &cur);
        assert_eq!(rows[0].path, "serve;dispatch");
        assert_eq!(rows[0].delta_excl_ns, 1500);
        assert_eq!(rows[0].cur_allocs, 3);
        assert_eq!(rows[1].path, "serve");
    }

    #[test]
    fn union_includes_new_and_vanished_paths() {
        let prev = prof(&[("serve;old", 100, 1, 0)]);
        let cur = prof(&[("serve;new", 70, 1, 0)]);
        let rows = diff(&prev, &cur);
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().any(|r| r.path == "serve;old"
            && r.delta_excl_ns == -100));
        assert!(rows.iter().any(|r| r.path == "serve;new"
            && r.delta_excl_ns == 70));
    }

    #[test]
    fn self_diff_is_all_zero() {
        let p = prof(&[("serve", 100, 1, 0), ("serve;x", 50, 2, 1)]);
        assert!(diff(&p, &p).iter().all(|r| r.delta_excl_ns == 0));
        assert!(top_regressions(&p, &p, 5).is_empty());
    }

    #[test]
    fn table_renders_every_row() {
        let prev = prof(&[("serve", 100, 1, 0)]);
        let cur = prof(&[("serve", 300, 1, 0)]);
        let t = render_table("d", &diff(&prev, &cur));
        let s = t.render();
        assert!(s.contains("serve"));
        assert!(s.contains("+200.0%"));
    }
}
