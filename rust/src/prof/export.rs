//! Scrape-side profile export: shard merge, collapsed-stack (`folded`)
//! text, a self-contained HTML flamegraph, and the versioned
//! `PROF_*.json` record.
//!
//! Only this side allocates — the record path in [`super::stack`] is
//! allocation-free. A scrape merges the per-shard path tables by
//! packed key (the same path can land in several shards, one per
//! recording thread), decodes each key into the root-first
//! `a;b;c` path string of the folded format, and sorts
//! deterministically by that string.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::frame::Frame;
use super::stack::for_each_slot;

/// `format` tag stamped into every `PROF_*.json` record.
pub const PROFILE_FORMAT: &str = "bip-moe-profile";
/// Schema version of the `PROF_*.json` payload; bump on shape change
/// (the `bench-honesty` lint requires every writer to stamp it).
pub const PROFILE_SCHEMA_VERSION: u32 = 1;

/// Aggregated totals for one call path.
#[derive(Clone, Debug, PartialEq)]
pub struct PathStat {
    /// root-first `;`-joined frame names (the folded-stack id)
    pub path: String,
    /// nesting depth (number of frames in `path`)
    pub depth: usize,
    pub inclusive_ns: u64,
    pub exclusive_ns: u64,
    pub calls: u64,
    /// heap allocations observed inside the frame (CountingAlloc
    /// delta; 0 unless the binary installs the counting allocator)
    pub allocs: u64,
}

/// One merged scrape of the profiler's path tables.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Profile {
    /// sorted by `path` string
    pub paths: Vec<PathStat>,
}

/// Decode a packed path key (innermost frame in the low byte) into the
/// root-first `a;b;c` string and its depth.
fn decode_path(mut key: u64) -> (String, usize) {
    let mut frames = [""; super::stack::MAX_DEPTH];
    let mut n = 0;
    while key != 0 && n < frames.len() {
        let name = match Frame::from_code((key & 0xff) as u8) {
            Some(f) => f.name(),
            None => "unknown",
        };
        frames[n] = name;
        n += 1;
        key >>= 8;
    }
    let mut out = String::new();
    for name in frames[..n].iter().rev() {
        if !out.is_empty() {
            out.push(';');
        }
        out.push_str(name);
    }
    (out, n)
}

impl Profile {
    /// Merge every shard's path table into one profile (scrape seam —
    /// the record side keeps running; totals are monotone).
    pub fn scrape() -> Profile {
        let mut merged: std::collections::BTreeMap<
            u64,
            (u64, u64, u64, u64),
        > = std::collections::BTreeMap::new();
        for_each_slot(|key, incl, excl, calls, allocs| {
            let e = merged.entry(key).or_insert((0, 0, 0, 0));
            e.0 += incl;
            e.1 += excl;
            e.2 += calls;
            e.3 += allocs;
        });
        let mut paths: Vec<PathStat> = merged
            .into_iter()
            .map(|(key, (incl, excl, calls, allocs))| {
                let (path, depth) = decode_path(key);
                PathStat {
                    path,
                    depth,
                    inclusive_ns: incl,
                    exclusive_ns: excl,
                    calls,
                    allocs,
                }
            })
            .collect();
        paths.sort_by(|a, b| a.path.cmp(&b.path));
        Profile { paths }
    }

    /// Collapsed-stack ("folded") text: one `path exclusive_ns` line
    /// per call path, the flamegraph.pl / speedscope input format.
    pub fn folded(&self) -> String {
        let mut out = String::new();
        for p in &self.paths {
            out.push_str(&p.path);
            out.push(' ');
            out.push_str(&p.exclusive_ns.to_string());
            out.push('\n');
        }
        out
    }

    /// Sum of inclusive time over root (depth-1) paths — the profile's
    /// notion of total measured wall-clock per recording thread tree.
    pub fn root_inclusive_ns(&self) -> u64 {
        self.paths
            .iter()
            .filter(|p| p.depth == 1)
            .map(|p| p.inclusive_ns)
            .sum()
    }

    /// Inclusive ns of the path rooted at `root` (exact match on the
    /// first frame name), 0 if absent.
    pub fn root_ns(&self, root: &str) -> u64 {
        self.paths
            .iter()
            .filter(|p| p.depth == 1 && p.path == root)
            .map(|p| p.inclusive_ns)
            .sum()
    }

    /// The versioned machine-readable record (see PROFILE_SCHEMA_VERSION).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::Str(PROFILE_FORMAT.into())),
            (
                "schema_version",
                Json::Num(PROFILE_SCHEMA_VERSION as f64),
            ),
            ("version", Json::Str(crate::VERSION.into())),
            (
                "paths",
                Json::Arr(
                    self.paths
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("path", Json::Str(p.path.clone())),
                                (
                                    "inclusive_ns",
                                    Json::Num(p.inclusive_ns as f64),
                                ),
                                (
                                    "exclusive_ns",
                                    Json::Num(p.exclusive_ns as f64),
                                ),
                                ("calls", Json::Num(p.calls as f64)),
                                ("allocs", Json::Num(p.allocs as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a `PROF_*.json` document back into a profile.
    pub fn from_json(doc: &Json) -> Result<Profile> {
        let fmt = doc.path("format").and_then(|j| j.as_str());
        if fmt != Some(PROFILE_FORMAT) {
            bail!("profile format {fmt:?}, wanted {PROFILE_FORMAT:?}");
        }
        let schema = doc
            .path("schema_version")
            .and_then(|j| j.as_f64())
            .unwrap_or(0.0);
        if schema < 1.0 {
            bail!("profile schema_version {schema} < 1");
        }
        let Some(arr) = doc.path("paths").and_then(|j| j.as_arr()) else {
            bail!("profile has no `paths` array");
        };
        let mut paths = Vec::with_capacity(arr.len());
        for row in arr {
            let Some(path) =
                row.path("path").and_then(|j| j.as_str())
            else {
                bail!("profile row missing `path`");
            };
            let num = |k: &str| -> u64 {
                row.path(k).and_then(|j| j.as_f64()).unwrap_or(0.0)
                    as u64
            };
            paths.push(PathStat {
                path: path.to_string(),
                depth: path.split(';').count(),
                inclusive_ns: num("inclusive_ns"),
                exclusive_ns: num("exclusive_ns"),
                calls: num("calls"),
                allocs: num("allocs"),
            });
        }
        paths.sort_by(|a, b| a.path.cmp(&b.path));
        Ok(Profile { paths })
    }

    /// Load a `PROF_*.json` record from disk.
    pub fn load(path: &Path) -> Result<Profile> {
        let body = std::fs::read_to_string(path)
            .with_context(|| format!("reading profile {}", path.display()))?;
        let doc = Json::parse(&body).map_err(|e| {
            anyhow::anyhow!("profile {} does not parse: {e}", path.display())
        })?;
        Profile::from_json(&doc)
    }

    /// Write the JSON record to an explicit path.
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    /// Self-contained HTML flamegraph (icicle layout, no external
    /// assets): each call path is a positioned `div` whose width is
    /// its inclusive share of the summed root time.
    pub fn html(&self, title: &str) -> String {
        const ROW_PX: usize = 22;
        let total = self.root_inclusive_ns().max(1) as f64;
        // path -> (x offset frac, width frac); children consume their
        // parent's span left to right in sorted order
        let mut geom: std::collections::BTreeMap<&str, (f64, f64)> =
            std::collections::BTreeMap::new();
        let mut consumed: std::collections::BTreeMap<&str, f64> =
            std::collections::BTreeMap::new();
        let mut boxes = String::new();
        let mut max_depth = 1;
        for p in &self.paths {
            let w = p.inclusive_ns as f64 / total;
            let x = match p.path.rsplit_once(';') {
                None => {
                    let x = consumed.get("").copied().unwrap_or(0.0);
                    consumed.insert("", x + w);
                    x
                }
                Some((parent, _)) => {
                    let (px, _) =
                        geom.get(parent).copied().unwrap_or((0.0, 0.0));
                    let used =
                        consumed.get(parent).copied().unwrap_or(0.0);
                    consumed.insert(parent, used + w);
                    px + used
                }
            };
            geom.insert(p.path.as_str(), (x, w));
            max_depth = max_depth.max(p.depth);
            let label = match p.path.rsplit_once(';') {
                Some((_, leaf)) => leaf,
                None => p.path.as_str(),
            };
            // deterministic hue per frame name
            let hue = label
                .bytes()
                .fold(0u32, |h, b| h.wrapping_mul(31).wrapping_add(b as u32))
                % 360;
            boxes.push_str(&format!(
                "<div class=\"f\" style=\"left:{:.4}%;width:{:.4}%;\
                 top:{}px;background:hsl({hue},65%,72%)\" \
                 title=\"{} — incl {:.3} ms, excl {:.3} ms, {} calls, \
                 {} allocs\">{label}</div>\n",
                x * 100.0,
                (w * 100.0).max(0.05),
                (p.depth - 1) * ROW_PX,
                p.path,
                p.inclusive_ns as f64 / 1e6,
                p.exclusive_ns as f64 / 1e6,
                p.calls,
                p.allocs,
            ));
        }
        let esc: String = title
            .chars()
            .map(|c| match c {
                '<' => "&lt;".to_string(),
                '>' => "&gt;".to_string(),
                '&' => "&amp;".to_string(),
                '"' => "&quot;".to_string(),
                c => c.to_string(),
            })
            .collect();
        format!(
            "<!doctype html><html><head><meta charset=\"utf-8\">\
             <title>{esc}</title><style>\
             body{{font:13px monospace;margin:16px}}\
             .fg{{position:relative;border:1px solid #ccc}}\
             .f{{position:absolute;height:{h}px;overflow:hidden;\
             white-space:nowrap;box-sizing:border-box;\
             border:1px solid rgba(0,0,0,.25);padding:1px 3px;\
             font-size:11px}}\
             </style></head><body><h1>{esc}</h1>\
             <p>{fmt} v{sv} — widths are inclusive time as a share of \
             the summed root frames ({tot:.3} ms). Hover a box for \
             exact totals.</p>\
             <div class=\"fg\" style=\"height:{total_h}px\">\n{boxes}\
             </div></body></html>\n",
            h = ROW_PX - 2,
            fmt = PROFILE_FORMAT,
            sv = PROFILE_SCHEMA_VERSION,
            tot = total / 1e6,
            total_h = max_depth * ROW_PX,
        )
    }
}

/// Write `PROF_<name>.json` under `reports/` (or `$BIP_MOE_REPORTS`)
/// with the schema_version stamp — the profile counterpart of
/// `bench::write_bench_json`, captured alongside every gated bench.
pub fn write_prof_json(name: &str, profile: &Profile) -> std::io::Result<PathBuf> {
    let dir = PathBuf::from(
        std::env::var("BIP_MOE_REPORTS").unwrap_or_else(|_| "reports".into()),
    );
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("PROF_{name}.json"));
    let doc = profile.to_json();
    debug_assert!(
        doc.path("schema_version").is_some(),
        "profile reports must carry a schema stamp"
    );
    std::fs::write(&path, doc.to_string())?;
    Ok(path)
}

/// Load the previously committed `PROF_<name>.json`, if any — callers
/// read it *before* overwriting so a regression gate can diff against
/// the prior run.
pub fn load_prev_prof(name: &str) -> Option<Profile> {
    let dir = PathBuf::from(
        std::env::var("BIP_MOE_REPORTS").unwrap_or_else(|_| "reports".into()),
    );
    Profile::load(&dir.join(format!("PROF_{name}.json"))).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Profile {
        Profile {
            paths: vec![
                PathStat {
                    path: "serve".into(),
                    depth: 1,
                    inclusive_ns: 1000,
                    exclusive_ns: 100,
                    calls: 1,
                    allocs: 0,
                },
                PathStat {
                    path: "serve;dispatch".into(),
                    depth: 2,
                    inclusive_ns: 900,
                    exclusive_ns: 900,
                    calls: 3,
                    allocs: 2,
                },
            ],
        }
    }

    #[test]
    fn decode_path_is_root_first() {
        let key = ((Frame::Serve.code() as u64) << 8)
            | Frame::Dispatch.code() as u64;
        let (s, d) = decode_path(key);
        assert_eq!(s, "serve;dispatch");
        assert_eq!(d, 2);
        assert_eq!(decode_path(0), (String::new(), 0));
    }

    #[test]
    fn json_round_trips() {
        let p = sample();
        let doc = Json::parse(&p.to_json().to_string()).unwrap();
        let back = Profile::from_json(&doc).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn folded_lines_carry_exclusive_ns() {
        let text = sample().folded();
        assert!(text.contains("serve 100\n"));
        assert!(text.contains("serve;dispatch 900\n"));
    }

    #[test]
    fn html_is_self_contained_and_mentions_every_path() {
        let html = sample().html("t<est");
        assert!(html.contains("t&lt;est"));
        assert!(html.contains("serve;dispatch"));
        assert!(!html.contains("http://"));
        assert!(!html.contains("https://"));
    }

    #[test]
    fn root_accounting() {
        let p = sample();
        assert_eq!(p.root_inclusive_ns(), 1000);
        assert_eq!(p.root_ns("serve"), 1000);
        assert_eq!(p.root_ns("dispatch"), 0);
    }
}
