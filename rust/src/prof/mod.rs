//! `prof/` — deterministic hierarchical profiler (ISSUE 9).
//!
//! The flat telemetry spans (`telemetry::span`) answer "how long do
//! route-batch calls take on average"; they cannot answer "*where* did
//! the time go when the geomean gate failed". This module layers a
//! call-*path* profiler underneath them:
//!
//! * [`ProfGuard::enter`]`(frame)` pushes one [`Frame`] onto a
//!   fixed-depth thread-local stack; dropping the guard records
//!   inclusive/exclusive ns, a call count, and a CountingAlloc delta
//!   under the full packed call path (admission → dispatch →
//!   layer-route → score-fill → top-K → dual-update p/q → merge-sync,
//!   plus train-step and forecast-fit roots).
//! * The record path is allocation-free and lock-free (sharded static
//!   tables, merged at scrape time like the telemetry registry) and is
//!   gated by the `hot-path-alloc`/`lock-discipline`/`panic-path`
//!   lints.
//! * [`Profile::scrape`] merges the shards; [`Profile::folded`] emits
//!   collapsed-stack text, [`Profile::html`] a self-contained
//!   flamegraph, and [`write_prof_json`] the versioned `PROF_*.json`
//!   record captured alongside every gated bench.
//! * [`diff`](fn@diff) aligns two profiles by path and sorts by
//!   Δexclusive-ns so `bip-moe profile diff` (and a failed bench gate)
//!   can name the guilty phase instead of printing a bare ratio.

pub mod diff;
pub mod export;
pub mod frame;
pub mod stack;

pub use diff::{diff, render_table, top_regressions, DiffRow};
pub use export::{
    load_prev_prof, write_prof_json, PathStat, Profile, PROFILE_FORMAT,
    PROFILE_SCHEMA_VERSION,
};
pub use frame::{Frame, N_FRAMES};
pub use stack::{
    enabled, reset, set_enabled, ProfGuard, MAX_DEPTH, N_SHARDS,
};
