//! Host tensors + conversion to/from PJRT literals.
//!
//! The train/eval steps exchange a handful of flat arrays (see the
//! manifest's I/O specs); this module owns the typed copies and the
//! shape/dtype validation at the rust<->XLA boundary.

use anyhow::{bail, Result};

use super::manifest::{DType, IoSpec};

/// A host tensor: shape + typed storage (f32 or i32 — the only dtypes the
/// AOT interface uses).
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        Tensor::F32 {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product::<usize>().max(1)],
        }
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        Tensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
        }
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn f32s_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn i32s(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let d = self.f32s()?;
        if d.len() != 1 {
            bail!("tensor has {} elements, wanted scalar", d.len());
        }
        Ok(d[0])
    }

    /// Validate against a manifest I/O spec.
    pub fn check_spec(&self, spec: &IoSpec) -> Result<()> {
        if self.dtype() != spec.dtype {
            bail!("{}: dtype mismatch", spec.name);
        }
        if self.shape() != spec.shape.as_slice() {
            bail!(
                "{}: shape {:?} != spec {:?}",
                spec.name,
                self.shape(),
                spec.shape
            );
        }
        Ok(())
    }

    /// Convert to an xla literal (reshaped to the tensor's shape).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> =
            self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        if dims.is_empty() {
            // scalar: vec1 of len 1 -> reshape to rank-0
            Ok(lit.reshape(&[])?)
        } else {
            Ok(lit.reshape(&dims)?)
        }
    }

    /// Read back from a literal, trusting `spec` for shape/dtype.
    pub fn from_literal(lit: &xla::Literal, spec: &IoSpec) -> Result<Tensor> {
        let t = match spec.dtype {
            DType::F32 => Tensor::F32 {
                shape: spec.shape.clone(),
                data: lit.to_vec::<f32>()?,
            },
            DType::I32 => Tensor::I32 {
                shape: spec.shape.clone(),
                data: lit.to_vec::<i32>()?,
            },
        };
        if t.len() != spec.elements() {
            bail!(
                "{}: literal has {} elements, spec wants {}",
                spec.name,
                t.len(),
                spec.elements()
            );
        }
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: &[usize], dtype: DType) -> IoSpec {
        IoSpec { name: name.into(), shape: shape.to_vec(), dtype }
    }

    #[test]
    fn constructors_and_accessors() {
        let t = Tensor::zeros_f32(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), DType::F32);
        assert!(t.f32s().unwrap().iter().all(|&x| x == 0.0));
        assert!(t.i32s().is_err());

        let s = Tensor::scalar_i32(7);
        assert_eq!(s.len(), 1);
        assert_eq!(s.shape(), &[] as &[usize]);
    }

    #[test]
    fn spec_checking() {
        let t = Tensor::zeros_f32(&[4]);
        assert!(t.check_spec(&spec("x", &[4], DType::F32)).is_ok());
        assert!(t.check_spec(&spec("x", &[5], DType::F32)).is_err());
        assert!(t.check_spec(&spec("x", &[4], DType::I32)).is_err());
    }

    #[test]
    fn literal_round_trip_f32() {
        let t = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back =
            Tensor::from_literal(&lit, &spec("x", &[2, 2], DType::F32))
                .unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_round_trip_scalar() {
        let t = Tensor::scalar_i32(42);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit, &spec("s", &[], DType::I32))
            .unwrap();
        assert_eq!(back.i32s().unwrap(), &[42]);
    }

    #[test]
    #[should_panic]
    fn shape_data_mismatch_panics() {
        Tensor::from_f32(&[3], vec![1.0, 2.0]);
    }
}
