pub mod manifest; pub mod tensor; pub mod engine; pub use engine::Engine; pub use manifest::Manifest; pub use tensor::Tensor;
