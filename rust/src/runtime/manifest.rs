//! Typed view over `artifacts/manifest.json` (written by
//! `python/compile/aot.py`): model configs, the flat-theta parameter
//! table, and the artifact grid (config x mode x kind, with I/O specs).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub std: f64,
    pub decay: bool,
}

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub seq_len: usize,
    pub batch_size: usize,
    pub n_tokens: usize,
    pub capacity: usize,
    pub expert_cap: usize,
    pub theta_size: usize,
    pub total_steps: usize,
    pub params: Vec<ParamEntry>,
}

#[derive(Clone, Debug)]
pub struct Artifact {
    pub config: String,
    pub mode: String,
    pub kind: String,
    pub bip_t: Option<usize>,
    pub layer: Option<usize>,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub fingerprint: String,
    pub configs: BTreeMap<String, ModelConfig>,
    pub artifacts: Vec<Artifact>,
}

fn io_specs(j: &Json) -> Result<Vec<IoSpec>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("io specs not an array"))?
        .iter()
        .map(|spec| {
            Ok(IoSpec {
                name: spec
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("spec missing name"))?
                    .to_string(),
                shape: spec
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("spec missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                dtype: DType::parse(
                    spec.get("dtype").and_then(Json::as_str).unwrap_or("f32"),
                )?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let fingerprint = j
            .get("fingerprint")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();

        let mut configs = BTreeMap::new();
        for (name, cj) in j
            .get("configs")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing configs"))?
        {
            let geti = |key: &str| -> Result<usize> {
                cj.get(key)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("config {name} missing {key}"))
            };
            let params = cj
                .get("params")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("config {name} missing params"))?
                .iter()
                .map(|p| {
                    Ok(ParamEntry {
                        name: p
                            .get("name")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("param missing name"))?
                            .to_string(),
                        shape: p
                            .get("shape")
                            .and_then(Json::as_arr)
                            .unwrap_or(&[])
                            .iter()
                            .map(|d| d.as_usize().unwrap_or(0))
                            .collect(),
                        offset: p
                            .get("offset")
                            .and_then(Json::as_usize)
                            .unwrap_or(0),
                        std: p.get("std").and_then(Json::as_f64).unwrap_or(0.0),
                        decay: p
                            .get("decay")
                            .and_then(Json::as_bool)
                            .unwrap_or(false),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            configs.insert(
                name.clone(),
                ModelConfig {
                    name: name.clone(),
                    vocab_size: geti("vocab_size")?,
                    d_model: geti("d_model")?,
                    n_heads: geti("n_heads")?,
                    n_layers: geti("n_layers")?,
                    d_ff: geti("d_ff")?,
                    n_experts: geti("n_experts")?,
                    top_k: geti("top_k")?,
                    seq_len: geti("seq_len")?,
                    batch_size: geti("batch_size")?,
                    n_tokens: geti("n_tokens")?,
                    capacity: geti("capacity")?,
                    expert_cap: geti("expert_cap")?,
                    theta_size: geti("theta_size")?,
                    total_steps: geti("total_steps")?,
                    params,
                },
            );
        }

        let artifacts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
            .iter()
            .map(|a| {
                Ok(Artifact {
                    config: a
                        .get("config")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                    mode: a
                        .get("mode")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                    kind: a
                        .get("kind")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                    bip_t: a.get("bip_T").and_then(Json::as_usize),
                    layer: a.get("layer").and_then(Json::as_usize),
                    file: a
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("artifact missing file"))?
                        .to_string(),
                    inputs: io_specs(
                        a.get("inputs").unwrap_or(&Json::Arr(vec![])))?,
                    outputs: io_specs(
                        a.get("outputs").unwrap_or(&Json::Arr(vec![])))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest { fingerprint, configs, artifacts })
    }

    pub fn config(&self, name: &str) -> Result<&ModelConfig> {
        self.configs.get(name).ok_or_else(|| {
            anyhow!(
                "config {name} not in manifest (have: {:?}); re-run \
                 `make artifacts` with --configs {name}",
                self.configs.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Find an artifact by (config, kind, mode, bip_T).
    pub fn find(
        &self,
        config: &str,
        kind: &str,
        mode: &str,
        bip_t: Option<usize>,
    ) -> Result<&Artifact> {
        self.artifacts
            .iter()
            .find(|a| {
                a.config == config
                    && a.kind == kind
                    && a.mode == mode
                    && (kind != "train" || mode != "bip" || a.bip_t == bip_t)
            })
            .ok_or_else(|| {
                anyhow!(
                    "no artifact config={config} kind={kind} mode={mode} \
                     T={bip_t:?}; re-run `make artifacts`"
                )
            })
    }

    pub fn train_artifact(
        &self,
        config: &str,
        mode: &str,
        bip_t: usize,
    ) -> Result<&Artifact> {
        self.find(config, "train", mode, Some(bip_t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "fingerprint": "deadbeef",
      "configs": {
        "tiny": {
          "vocab_size": 512, "d_model": 32, "n_heads": 4, "n_layers": 2,
          "d_ff": 32, "n_experts": 8, "top_k": 2, "seq_len": 32,
          "batch_size": 2, "n_tokens": 64, "capacity": 32,
          "expert_cap": 16, "theta_size": 74400, "total_steps": 256,
          "params": [
            {"name": "embed", "shape": [512, 32], "offset": 0,
             "std": 0.02, "decay": true},
            {"name": "final_norm", "shape": [32], "offset": 16384,
             "std": 0.0, "decay": false}
          ]
        }
      },
      "artifacts": [
        {"config": "tiny", "mode": "bip", "kind": "train", "bip_T": 4,
         "file": "tiny_bip_T4_train.hlo.txt",
         "inputs": [{"name": "theta", "shape": [74400], "dtype": "f32"},
                    {"name": "tokens", "shape": [2, 33], "dtype": "i32"}],
         "outputs": [{"name": "nll_sum", "shape": [], "dtype": "f32"}]},
        {"config": "tiny", "mode": "aux", "kind": "train",
         "file": "tiny_aux_train.hlo.txt", "inputs": [], "outputs": []},
        {"config": "tiny", "mode": "bip", "kind": "eval",
         "file": "tiny_bip_eval.hlo.txt", "inputs": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn parses_configs_and_params() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let c = m.config("tiny").unwrap();
        assert_eq!(c.n_experts, 8);
        assert_eq!(c.theta_size, 74400);
        assert_eq!(c.params.len(), 2);
        assert!(c.params[0].decay && !c.params[1].decay);
        assert!(m.config("nope").is_err());
    }

    #[test]
    fn finds_artifacts_by_grid_position() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.train_artifact("tiny", "bip", 4).unwrap();
        assert_eq!(a.file, "tiny_bip_T4_train.hlo.txt");
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.inputs[1].elements(), 66);
        assert!(m.train_artifact("tiny", "bip", 14).is_err());
        assert!(m.find("tiny", "eval", "bip", None).is_ok());
        assert!(m.train_artifact("tiny", "aux", 0).is_ok());
    }

    #[test]
    fn scalar_spec_has_one_element() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.train_artifact("tiny", "bip", 4).unwrap();
        assert_eq!(a.outputs[0].elements(), 1);
        assert_eq!(a.outputs[0].shape.len(), 0);
    }
}
