//! PJRT execution engine: loads HLO-text artifacts, compiles them once
//! (per-process cache), and runs them with typed I/O validation.
//!
//! This is the only module that touches the `xla` crate on the hot path.
//! Interchange is HLO *text* (see aot.py: jax >= 0.5 protos are rejected
//! by xla_extension 0.5.1; the text parser reassigns instruction ids).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{Artifact, Manifest};
use super::tensor::Tensor;

pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    artifacts_dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// cumulative (compile_s, execute_s, executions) for perf reporting
    stats: RefCell<EngineStats>,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub compile_seconds: f64,
    pub execute_seconds: f64,
    pub executions: u64,
    pub compiles: u64,
}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            artifacts_dir: artifacts_dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn stats(&self) -> EngineStats {
        *self.stats.borrow()
    }

    /// Compile (or fetch from cache) the executable for an artifact file.
    pub fn load(&self, file: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = self.artifacts_dir.join(file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {file}"))?,
        );
        {
            let mut st = self.stats.borrow_mut();
            st.compile_seconds += t0.elapsed().as_secs_f64();
            st.compiles += 1;
        }
        crate::debug_log!("compiled {file} in {:.2}s",
                          t0.elapsed().as_secs_f64());
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with typed tensors; validates inputs against
    /// the manifest spec and returns outputs parsed per the output spec.
    pub fn run(&self, artifact: &Artifact, inputs: &[Tensor])
        -> Result<Vec<Tensor>>
    {
        if inputs.len() != artifact.inputs.len() {
            bail!(
                "{}: got {} inputs, spec wants {}",
                artifact.file,
                inputs.len(),
                artifact.inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(&artifact.inputs) {
            t.check_spec(spec)
                .with_context(|| format!("input to {}", artifact.file))?;
        }
        let exe = self.load(&artifact.file)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        {
            let mut st = self.stats.borrow_mut();
            st.execute_seconds += t0.elapsed().as_secs_f64();
            st.executions += 1;
        }
        // aot.py lowers with return_tuple=True: decompose and type-check
        let parts = result.to_tuple()?;
        if parts.len() != artifact.outputs.len() {
            bail!(
                "{}: got {} outputs, spec wants {}",
                artifact.file,
                parts.len(),
                artifact.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&artifact.outputs)
            .map(|(lit, spec)| Tensor::from_literal(lit, spec))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    //! Engine tests need built artifacts; they are integration-level and
    //! live in rust/tests/integration_runtime.rs (skipped gracefully when
    //! artifacts/ is absent). Unit coverage here is limited to cache-key
    //! behavior through the public API with a missing file.
    use super::*;

    #[test]
    fn missing_artifact_dir_is_a_clean_error() {
        let err = Engine::new(Path::new("/nonexistent-artifacts"))
            .err()
            .expect("must fail");
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
