//! bip-moe CLI — the L3 coordinator entrypoint.
//!
//! Subcommands (keep this list in sync with `run()` and `print_help()`):
//!   train   train one (config, mode, T) run end-to-end via PJRT
//!   run     run a named experiment from a JSON run-config file
//!   eval    evaluate a checkpoint's held-out perplexity
//!   solve   run the BIP solver family on a synthetic routing instance
//!   match   run the §5 online ad-matching simulation (Alg 3/4)
//!   serve   online inference serving: sweep policy x scenario through
//!           the admission/micro-batch/BIP-router pipeline
//!   trace   record a serving run to a binary routing trace, replay it
//!           bit-identically, counterfactually diff policies on it, or
//!           export it as JSON
//!   info    list artifact manifest contents and engine stats
//!
//! Examples:
//!   bip-moe train --config moe16-bench --mode bip --bip-t 4 --steps 100
//!   bip-moe run --config-file configs/table2.json
//!   bip-moe solve --n 1024 --m 64 --k 8 --skew 3.0 --t 8
//!   bip-moe match --flows 4096 --ads 32 --slots 2
//!   bip-moe serve --scenario bursty --policy online
//!   bip-moe trace record --scenario steady --policy online --out t.trace
//!   bip-moe trace replay --trace t.trace
//!   bip-moe trace diff --trace t.trace --policies bip,lossfree

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use bip_moe::bip::{dual, flow, greedy_topk, Instance};
use bip_moe::matching::simulator::{compare_policies, Workload};
use bip_moe::metrics::TablePrinter;
use bip_moe::runtime::Engine;
use bip_moe::serve::{
    self, Policy, ReplicaConfig, RouterConfig, SchedulerConfig, Scenario,
    ServeConfig, ServeReport, TrafficConfig, TrafficGenerator,
};
use bip_moe::trace::{PolicyDiff, Trace, TraceRecorder};
use bip_moe::train::TrainDriver;
use bip_moe::util::rng::Pcg64;
use bip_moe::util::Args;

fn main() {
    bip_moe::util::log::init_from_env();
    let args = Args::parse_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(args),
        Some("run") => cmd_run(args),
        Some("eval") => cmd_eval(args),
        Some("solve") => cmd_solve(args),
        Some("match") => cmd_match(args),
        Some("serve") => cmd_serve(args),
        Some("trace") => cmd_trace(args),
        Some("info") => cmd_info(args),
        Some(other) => bail!("unknown subcommand {other}; see --help"),
        None => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "bip-moe {} — BIP-Based Balancing for MoE pre-training + serving\n\n\
         usage: bip-moe <train|run|eval|solve|match|serve|trace|info> \
         [--options]\n\n\
         train  --config <name> --mode <aux|lossfree|bip> [--bip-t N]\n\
                [--steps N] [--seed N] [--eval-batches N]\n\
                [--reports DIR] [--save CKPT] [--artifacts DIR]\n\
         run    --config-file configs/<exp>.json [--artifacts DIR]\n\
         eval   --checkpoint CKPT [--eval-batches N] [--artifacts DIR]\n\
         solve  [--n N] [--m M] [--k K] [--skew S] [--t T] [--exact]\n\
         match  [--flows N] [--ads M] [--slots K] [--t T] [--buckets B]\n\
         serve  [--scenario steady|bursty|diurnal|adversarial|\n\
                 multitenant|all] [--policy greedy|lossfree|bip|online|\n\
                 approx|all] [--requests N] [--rate R/s] [--m M] [--k K]\n\
                 [--layers L] [--tenants T] [--t ITERS] [--buckets B]\n\
                 [--batch N] [--queue N] [--max-wait-us U] [--slo-ms MS]\n\
                 [--capacity-factor F] [--devices D] [--placement\n\
                 block|lpt] [--lpt-refresh BATCHES] [--seed N]\n\
                 [--replicas R] [--threads T] [--sync-every BATCHES]\n\
                 [--json PATH]\n\
         trace  record --out PATH [--scenario S] [--policy P]\n\
                 [--requests N] [serve-style knobs incl. --replicas]\n\
                trace replay --trace PATH (asserts bit-identical\n\
                 completions against the recording)\n\
                trace diff --trace PATH [--policies a,b,..] [--json P]\n\
                trace export --trace PATH [--out PATH.json]\n\
         info   [--artifacts DIR]",
        bip_moe::VERSION
    );
}

fn cmd_train(args: &Args) -> Result<()> {
    args.check_known(&[
        "config", "mode", "bip-t", "steps", "seed", "eval-batches",
        "reports", "save", "artifacts", "sim-devices", "data-seed",
    ])
    .map_err(anyhow::Error::msg)?;
    let engine = Engine::new(&artifacts_dir(args))?;
    let mut driver = TrainDriver::new(
        &args.str_or("config", "tiny"),
        &args.str_or("mode", "bip"),
        args.usize_or("bip-t", 4),
        args.u64_or("steps", 50),
    );
    driver.seed = args.usize_or("seed", 0) as i32;
    driver.eval_batches = args.u64_or("eval-batches", 8);
    driver.sim_devices = args.usize_or("sim-devices", 4);
    driver.data_seed = args.u64_or("data-seed", 20240601);

    let outcome = driver.run(&engine)?;
    let reports = PathBuf::from(args.str_or("reports", "reports"));
    let out = outcome.dump(&reports)?;

    let mut table = TablePrinter::new(
        &format!("run {}", driver.run_label()),
        &["Algorithm", "AvgMaxVio", "SupMaxVio", "Perplexity",
          "SimHours(run)"],
    );
    table.row(outcome.table_row(&driver.run_label()));
    table.print();
    println!("reports: {}", out.display());
    println!(
        "engine: {} compiles {:.1}s, {} execs {:.1}s",
        engine.stats().compiles,
        engine.stats().compile_seconds,
        engine.stats().executions,
        engine.stats().execute_seconds
    );

    if let Some(ckpt) = args.get("save") {
        outcome
            .state
            .save(Path::new(ckpt), &driver.config, &driver.mode)?;
        println!("checkpoint: {ckpt}");
    }
    Ok(())
}

/// Run a named experiment from a JSON run-config file (configs/*.json).
fn cmd_run(args: &Args) -> Result<()> {
    args.check_known(&["config-file", "artifacts", "reports", "save"])
        .map_err(anyhow::Error::msg)?;
    let path = args
        .get("config-file")
        .ok_or_else(|| anyhow::anyhow!("--config-file required"))?;
    let run_cfg = bip_moe::config::RunConfig::load(Path::new(path))?;
    println!("experiment {}: {}", run_cfg.name, run_cfg.to_json());
    let engine = Engine::new(&artifacts_dir(args))?;
    let driver = run_cfg.driver();
    let outcome = driver.run(&engine)?;
    let out = outcome
        .dump(Path::new(&args.str_or("reports", "reports")))?;
    let mut table = TablePrinter::new(
        &format!("experiment {}", run_cfg.name),
        &["Algorithm", "AvgMaxVio", "SupMaxVio", "Perplexity",
          "SimHours(run)"],
    );
    table.row(outcome.table_row(&driver.run_label()));
    table.print();
    println!("reports: {}", out.display());
    if let Some(ckpt) = args.get("save") {
        outcome
            .state
            .save(Path::new(ckpt), &driver.config, &driver.mode)?;
        println!("checkpoint: {ckpt}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    args.check_known(&["checkpoint", "eval-batches", "artifacts",
                       "data-seed"])
        .map_err(anyhow::Error::msg)?;
    let ckpt = args
        .get("checkpoint")
        .ok_or_else(|| anyhow::anyhow!("--checkpoint required"))?;
    let (state, config, mode) =
        bip_moe::train::state::TrainState::load(Path::new(ckpt))?;
    let engine = Engine::new(&artifacts_dir(args))?;
    let cfg = engine.manifest().config(&config)?.clone();
    let eval_art = engine.manifest().find(&config, "eval", &mode, None)?
        .clone();

    let corpus = std::sync::Arc::new(bip_moe::data::Corpus::build(
        bip_moe::data::CorpusSpec {
            vocab_size: cfg.vocab_size,
            seed: args.u64_or("data-seed", 20240601),
            ..Default::default()
        },
    ));
    let loader = bip_moe::data::Loader::new(
        corpus, cfg.batch_size, cfg.seq_len,
        bip_moe::data::Split::Test);
    let mut ppl = bip_moe::metrics::Perplexity::default();
    for i in 0..args.u64_or("eval-batches", 16) {
        let batch = loader.batch(i);
        let tokens = bip_moe::runtime::Tensor::from_i32(
            &[cfg.batch_size, cfg.seq_len + 1],
            batch.tokens,
        );
        let outs = engine.run(&eval_art, &[
            state.theta.clone(),
            state.route_state.clone(),
            tokens,
        ])?;
        ppl.push(outs[0].scalar_f32()? as f64, cfg.n_tokens as u64);
    }
    println!(
        "checkpoint {ckpt}: config={config} mode={mode} step={} \
         test-ppl={:.4}",
        state.step_count(),
        ppl.value()
    );
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    args.check_known(&["n", "m", "k", "skew", "temp", "t", "seed", "exact"])
        .map_err(anyhow::Error::msg)?;
    let n = args.usize_or("n", 1024);
    let m = args.usize_or("m", 16);
    let k = args.usize_or("k", 4);
    let t = args.usize_or("t", 4);
    let mut rng = Pcg64::new(args.u64_or("seed", 0));
    let inst = Instance::synthetic(
        n, m, k,
        args.f64_or("temp", 2.0),
        args.f64_or("skew", 3.0),
        &mut rng,
    );

    let mut table = TablePrinter::new(
        &format!("BIP routing instance n={n} m={m} k={k} cap={}", inst.cap),
        &["Solver", "Objective", "MaxVio", "Feasible", "Time"],
    );

    let t0 = std::time::Instant::now();
    let greedy = greedy_topk(&inst);
    table.row(vec![
        "greedy top-k".into(),
        format!("{:.4}", greedy.objective(&inst)),
        format!("{:.4}", greedy.max_violation(&inst)),
        format!("{}", greedy.is_col_feasible(m, inst.cap)),
        format!("{:?}", t0.elapsed()),
    ]);

    let t0 = std::time::Instant::now();
    let (routing, _q) = dual::solve(&inst, t);
    table.row(vec![
        format!("BIP dual (T={t})"),
        format!("{:.4}", routing.objective(&inst)),
        format!("{:.4}", routing.max_violation(&inst)),
        format!("{}", routing.is_col_feasible(m, inst.cap)),
        format!("{:?}", t0.elapsed()),
    ]);

    if args.flag("exact") {
        let t0 = std::time::Instant::now();
        let (exact, obj) = flow::solve_exact(&inst);
        table.row(vec![
            "exact (min-cost flow)".into(),
            format!("{obj:.4}"),
            format!("{:.4}", exact.max_violation(&inst)),
            format!("{}", exact.is_col_feasible(m, inst.cap)),
            format!("{:?}", t0.elapsed()),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_match(args: &Args) -> Result<()> {
    args.check_known(&["flows", "ads", "slots", "t", "buckets", "seed"])
        .map_err(anyhow::Error::msg)?;
    let w = Workload::synthetic(
        args.usize_or("flows", 4096),
        args.usize_or("ads", 32),
        args.usize_or("slots", 2),
        args.u64_or("seed", 42),
    );
    let reports =
        compare_policies(&w, args.usize_or("t", 4),
                         args.usize_or("buckets", 128));
    let mut table = TablePrinter::new(
        &format!(
            "online ad matching: {} flows x {} ads, {} slots, cap {}",
            w.n_flows, w.n_ads, w.slots, w.capacity()
        ),
        &["Policy", "CTR sum", "vs hindsight", "MaxVio", "State bytes"],
    );
    for r in reports {
        table.row(vec![
            r.policy.clone(),
            format!("{:.2}", r.objective),
            format!("{:.3}", r.competitive_ratio),
            format!("{:.3}", r.max_violation),
            format!("{}", r.state_bytes),
        ]);
    }
    table.print();
    Ok(())
}

/// Online serving sweep: policy x scenario through the serve/ pipeline.
/// The greedy baseline always rides along so every table shows the
/// BIP-balanced policies against unbalanced top-k at equal throughput.
fn cmd_serve(args: &Args) -> Result<()> {
    args.check_known(&[
        "scenario", "policy", "requests", "rate", "m", "k", "layers",
        "tenants", "t", "buckets", "batch", "queue", "max-wait-us",
        "slo-ms", "capacity-factor", "devices", "placement",
        "lpt-refresh", "seed", "replicas", "threads", "sync-every",
        "json",
    ])
    .map_err(anyhow::Error::msg)?;

    let scenario_arg = args.str_or("scenario", "all");
    let scenarios: Vec<Scenario> = if scenario_arg == "all" {
        Scenario::all().to_vec()
    } else {
        vec![Scenario::parse(&scenario_arg).ok_or_else(|| {
            anyhow::anyhow!("unknown scenario {scenario_arg}")
        })?]
    };
    if scenarios.contains(&Scenario::Replayed) {
        bail!(
            "scenario 'replayed' is driven by a recorded trace: use \
             `bip-moe trace replay --trace PATH`"
        );
    }
    let policy_arg = args.str_or("policy", "all");
    let mut policies: Vec<Policy> = if policy_arg == "all" {
        Policy::all().to_vec()
    } else {
        vec![Policy::parse(&policy_arg).ok_or_else(|| {
            anyhow::anyhow!("unknown policy {policy_arg}")
        })?]
    };
    if !policies.contains(&Policy::Greedy) {
        policies.insert(0, Policy::Greedy);
    }

    let ServeKnobs { traffic, sched, router, replicas: rknobs } =
        serve_knobs(args, 8192)?;
    let (replicas, threads, sync_every) =
        (rknobs.replicas, rknobs.threads, rknobs.sync_every);

    let mut json_rows = Vec::new();
    for &scenario in &scenarios {
        let mut table = TablePrinter::new(
            &format!(
                "serving {} — {} requests at {:.0}/s, m={} k={} L={} \
                 batch<={} cf={} R={}",
                scenario.name(),
                traffic.n_requests,
                traffic.rate_per_s,
                traffic.m,
                traffic.k,
                traffic.n_layers,
                sched.batch_max,
                router.capacity_factor,
                replicas,
            ),
            ServeReport::headers(),
        );
        let mut replica_tables = Vec::new();
        for &policy in &policies {
            let cfg = ServeConfig::new(
                TrafficConfig { scenario, ..traffic.clone() },
                sched.clone(),
                router.clone(),
                policy,
            );
            if replicas > 1 || threads > 1 {
                let rcfg = serve::ReplicaConfig {
                    replicas,
                    threads,
                    sync_every,
                };
                let outcome = serve::run_replicated(&cfg, &rcfg);
                table.row(outcome.report.table_row());
                let mut pr_table = TablePrinter::new(
                    &format!(
                        "replicas — {} on {} ({} batches, {} syncs)",
                        outcome.report.policy,
                        scenario.name(),
                        outcome.batches,
                        outcome.syncs.len(),
                    ),
                    bip_moe::serve::ReplicaSummary::headers(),
                );
                for p in &outcome.per_replica {
                    pr_table.row(p.table_row());
                }
                replica_tables.push(pr_table);
                let mut row = outcome.report.to_json();
                if let bip_moe::util::Json::Obj(map) = &mut row {
                    map.insert(
                        "replicas".into(),
                        bip_moe::util::Json::Num(replicas as f64),
                    );
                    map.insert(
                        "threads".into(),
                        bip_moe::util::Json::Num(threads as f64),
                    );
                    map.insert(
                        "sync_every".into(),
                        bip_moe::util::Json::Num(sync_every as f64),
                    );
                    map.insert(
                        "batches".into(),
                        bip_moe::util::Json::Num(outcome.batches as f64),
                    );
                    map.insert(
                        "syncs".into(),
                        bip_moe::util::Json::Num(
                            outcome.syncs.len() as f64,
                        ),
                    );
                    map.insert(
                        "per_replica".into(),
                        bip_moe::util::Json::Arr(
                            outcome
                                .per_replica
                                .iter()
                                .map(|p| p.to_json())
                                .collect(),
                        ),
                    );
                    if let Some(last) = outcome.syncs.last() {
                        map.insert(
                            "last_sync_div_before".into(),
                            bip_moe::util::Json::Num(
                                last.state_div_before,
                            ),
                        );
                        map.insert(
                            "last_sync_div_after".into(),
                            bip_moe::util::Json::Num(
                                last.state_div_after,
                            ),
                        );
                    }
                }
                json_rows.push(row);
            } else {
                let outcome = serve::run_scenario(&cfg);
                table.row(outcome.report.table_row());
                json_rows.push(outcome.report.to_json());
            }
        }
        table.print();
        for t in replica_tables {
            t.print();
        }
    }

    if let Some(path) = args.get("json") {
        let doc = bip_moe::util::Json::obj(vec![
            ("version", bip_moe::util::Json::Str(bip_moe::VERSION.into())),
            ("results", bip_moe::util::Json::Arr(json_rows)),
        ]);
        std::fs::write(path, doc.to_string())?;
        println!("report: {path}");
    }
    Ok(())
}

/// Serve-pipeline knobs shared by the `serve` sweep and `trace record`
/// (which freezes one configuration into a trace header). The caller
/// overwrites `traffic.scenario`; only the default request count
/// differs between the two surfaces.
struct ServeKnobs {
    traffic: TrafficConfig,
    sched: SchedulerConfig,
    router: RouterConfig,
    replicas: ReplicaConfig,
}

fn serve_knobs(args: &Args, default_requests: usize) -> Result<ServeKnobs> {
    let m = args.usize_or("m", 16);
    let n_devices = args.usize_or("devices", 4);
    if n_devices == 0 || m % n_devices != 0 {
        bail!("--m {m} must be divisible by --devices {n_devices} (>= 1)");
    }
    let lpt = match args.str_or("placement", "block").as_str() {
        "block" => None,
        "lpt" => match args.u64_or("lpt-refresh", 8) {
            0 => bail!("--lpt-refresh must be >= 1 batches"),
            n => Some(n),
        },
        other => bail!("unknown placement {other} (block|lpt)"),
    };
    let traffic = TrafficConfig {
        scenario: Scenario::Steady, // overwritten by the caller
        n_requests: args.usize_or("requests", default_requests),
        rate_per_s: args.f64_or("rate", 100_000.0),
        n_layers: args.usize_or("layers", 4),
        m,
        k: args.usize_or("k", 4),
        n_tenants: args.usize_or("tenants", 4),
        slo_us: (args.f64_or("slo-ms", 20.0) * 1e3) as u64,
        seed: args.u64_or("seed", 1),
        ..Default::default()
    };
    let sched = SchedulerConfig {
        queue_cap: args.usize_or("queue", 512),
        batch_max: args.usize_or("batch", 64),
        max_wait_us: args.u64_or("max-wait-us", 2_000),
        drop_expired: true,
    };
    let router = RouterConfig {
        t_iters: args.usize_or("t", 4),
        buckets: args.usize_or("buckets", 128),
        capacity_factor: args.f64_or("capacity-factor", 2.0),
        n_devices,
        lpt_refresh: lpt,
        ..Default::default()
    };
    let replicas = ReplicaConfig {
        replicas: args.usize_or("replicas", 1),
        threads: args.usize_or("threads", 1),
        sync_every: args.u64_or("sync-every", 16),
    };
    if replicas.replicas == 0 {
        bail!("--replicas must be >= 1");
    }
    Ok(ServeKnobs { traffic, sched, router, replicas })
}

/// Routing-trace tooling: record a serving run to a versioned binary
/// trace, replay it bit-identically (the regression mode), re-route the
/// recorded gate scores under different policies (the counterfactual
/// diff), or export the trace as JSON.
fn cmd_trace(args: &Args) -> Result<()> {
    args.check_known(&[
        "scenario", "policy", "requests", "rate", "m", "k", "layers",
        "tenants", "t", "buckets", "batch", "queue", "max-wait-us",
        "slo-ms", "capacity-factor", "devices", "placement",
        "lpt-refresh", "seed", "replicas", "threads", "sync-every",
        "out", "trace", "policies", "json",
    ])
    .map_err(anyhow::Error::msg)?;
    match args.positional.first().map(String::as_str) {
        Some("record") => cmd_trace_record(args),
        Some("replay") => cmd_trace_replay(args),
        Some("diff") => cmd_trace_diff(args),
        Some("export") => cmd_trace_export(args),
        Some(other) => bail!("unknown trace action {other}; see --help"),
        None => bail!(
            "usage: bip-moe trace <record|replay|diff|export> [--options]"
        ),
    }
}

/// Build the (ServeConfig, ReplicaConfig) pair `trace record` freezes
/// into the trace header (single scenario + single policy, unlike the
/// `serve` sweep).
fn trace_serve_config(args: &Args) -> Result<(ServeConfig, ReplicaConfig)> {
    let scenario_arg = args.str_or("scenario", "steady");
    let scenario = Scenario::parse(&scenario_arg).ok_or_else(|| {
        anyhow::anyhow!("unknown scenario {scenario_arg}")
    })?;
    if scenario == Scenario::Replayed {
        bail!(
            "trace record needs a generative scenario; 'replayed' is \
             what replay/diff run"
        );
    }
    let policy_arg = args.str_or("policy", "online");
    let policy = Policy::parse(&policy_arg)
        .ok_or_else(|| anyhow::anyhow!("unknown policy {policy_arg}"))?;
    let ServeKnobs { mut traffic, sched, router, replicas } =
        serve_knobs(args, 2048)?;
    traffic.scenario = scenario;
    Ok((ServeConfig::new(traffic, sched, router, policy), replicas))
}

fn cmd_trace_record(args: &Args) -> Result<()> {
    let (cfg, rcfg) = trace_serve_config(args)?;
    let out_path = args.str_or("out", "bip-moe.trace");
    let mut rec = TraceRecorder::new(&cfg, &rcfg);
    let report = if rcfg.replicas > 1 || rcfg.threads > 1 {
        serve::run_replicated_with(
            &cfg,
            &rcfg,
            TrafficGenerator::new(cfg.traffic.clone()),
            Some(&mut rec),
        )
        .report
    } else {
        serve::run_scenario_with(
            &cfg,
            TrafficGenerator::new(cfg.traffic.clone()),
            Some(&mut rec),
        )
        .report
    };
    let trace = rec.into_trace();
    let bytes = trace.save(Path::new(&out_path))?;

    let mut table = TablePrinter::new(
        &format!("recorded {} / {}", report.scenario, report.policy),
        ServeReport::headers(),
    );
    table.row(report.table_row());
    table.print();
    println!(
        "trace: {out_path} ({} arrivals, {} frames, {} syncs, {} \
         completions, {} routed tokens, {bytes} bytes)",
        trace.arrivals.len(),
        trace.frames.len(),
        trace.syncs.len(),
        trace.completions.len(),
        trace.routed_tokens(),
    );
    Ok(())
}

fn cmd_trace_replay(args: &Args) -> Result<()> {
    let path = args
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("--trace PATH required"))?;
    let trace = Trace::load(Path::new(path))?;
    let rep = bip_moe::trace::replay(&trace);
    let mut table = TablePrinter::new(
        &format!(
            "replayed {} / {} from {path}",
            rep.report.scenario, rep.report.policy
        ),
        ServeReport::headers(),
    );
    table.row(rep.report.table_row());
    table.print();
    if !rep.mismatches.is_empty() {
        for m in &rep.mismatches {
            eprintln!("  {m}");
        }
        bail!(
            "replay diverged from the recording in {} place(s)",
            rep.mismatches.len()
        );
    }
    println!(
        "replay OK: {} completions bit-identical to the recording",
        rep.completions.len()
    );
    Ok(())
}

fn cmd_trace_diff(args: &Args) -> Result<()> {
    let path = args
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("--trace PATH required"))?;
    let trace = Trace::load(Path::new(path))?;
    let policies: Vec<Policy> = match args.get("policies") {
        Some(spec) => spec
            .split(',')
            .map(|s| {
                Policy::parse(s.trim()).ok_or_else(|| {
                    anyhow::anyhow!("unknown policy {}", s.trim())
                })
            })
            .collect::<Result<_>>()?,
        None => vec![
            Policy::BipBatch,
            Policy::LossFree,
            Policy::Online,
            Policy::Approx,
        ],
    };
    let diffs = bip_moe::trace::diff_policies(&trace, &policies)?;
    let mut table = TablePrinter::new(
        &format!(
            "counterfactual diff — recorded {} / {} ({} frames, {} \
             tokens)",
            trace.meta.serve.traffic.scenario.name(),
            trace.meta.serve.policy.name(),
            trace.frames.len(),
            trace.routed_tokens(),
        ),
        PolicyDiff::headers(),
    );
    for d in &diffs {
        table.row(d.table_row());
    }
    table.print();
    if let Some(json_path) = args.get("json") {
        let doc = bip_moe::util::Json::obj(vec![
            ("version", bip_moe::util::Json::Str(bip_moe::VERSION.into())),
            (
                "recorded_policy",
                bip_moe::util::Json::Str(
                    trace.meta.serve.policy.name().into(),
                ),
            ),
            (
                "recorded_scenario",
                bip_moe::util::Json::Str(
                    trace.meta.serve.traffic.scenario.name().into(),
                ),
            ),
            (
                "frames",
                bip_moe::util::Json::Num(trace.frames.len() as f64),
            ),
            (
                "results",
                bip_moe::util::Json::Arr(
                    diffs.iter().map(|d| d.to_json()).collect(),
                ),
            ),
        ]);
        std::fs::write(json_path, format!("{doc}\n"))?;
        println!("report: {json_path}");
    }
    Ok(())
}

fn cmd_trace_export(args: &Args) -> Result<()> {
    let path = args
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("--trace PATH required"))?;
    let trace = Trace::load(Path::new(path))?;
    let doc = trace.to_json();
    match args.get("out") {
        Some(out) => {
            std::fs::write(out, format!("{doc}\n"))?;
            println!(
                "json: {out} ({} arrivals, {} frames)",
                trace.arrivals.len(),
                trace.frames.len()
            );
        }
        None => println!("{doc}"),
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.check_known(&["artifacts"]).map_err(anyhow::Error::msg)?;
    let engine = Engine::new(&artifacts_dir(args))?;
    println!("platform: {}", engine.platform());
    println!("fingerprint: {}", engine.manifest().fingerprint);
    let mut table = TablePrinter::new(
        "configs",
        &["name", "theta", "layers", "experts", "top-k", "seq", "batch"],
    );
    for (name, c) in &engine.manifest().configs {
        table.row(vec![
            name.clone(),
            c.theta_size.to_string(),
            c.n_layers.to_string(),
            c.n_experts.to_string(),
            c.top_k.to_string(),
            c.seq_len.to_string(),
            c.batch_size.to_string(),
        ]);
    }
    table.print();
    println!("{} artifacts:", engine.manifest().artifacts.len());
    for a in &engine.manifest().artifacts {
        println!(
            "  {:<44} {:>6} {:>9} T={:?}",
            a.file, a.kind, a.mode, a.bip_t
        );
    }
    Ok(())
}
