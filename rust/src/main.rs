//! bip-moe CLI — the L3 coordinator entrypoint.
//!
//! Subcommands (keep this list in sync with `run()` and `print_help()`):
//!   train   train one (config, mode, T) run end-to-end via PJRT
//!   run     run a named experiment from a JSON run-config file
//!   eval    evaluate a checkpoint's held-out perplexity
//!   solve   run the BIP solver family on a synthetic routing instance
//!   match   run the §5 online ad-matching simulation (Alg 3/4)
//!   serve   online inference serving: sweep policy x scenario through
//!           the admission/micro-batch/BIP-router pipeline
//!   trace   record a serving run to a binary routing trace, replay it
//!           bit-identically, counterfactually diff policies on it, or
//!           export it as JSON
//!   forecast fit a per-expert load forecaster from a recorded trace
//!           (or a live run), evaluate it walk-forward, and serve with
//!           a forecast warm start / predictive autoscaling
//!   metrics attach to a serving run and print periodic counter
//!           deltas from the live telemetry registry (--watch for a
//!           per-tick summary table), or `metrics check` a written
//!           snapshot's core series for CI
//!   top     live dashboard over a driven serving run: per-layer
//!           expert-load heat rows, MaxVio sparkline, collapse score,
//!           and the online anomaly-detector alert feed
//!   profile capture a deterministic hierarchical call-path profile
//!           (admission -> dispatch -> layer-route -> score-fill /
//!           top-k / dual-update) from a serve, train, or router
//!           micro-bench run — writes the versioned PROF_*.json
//!           record plus optional folded-stack text and a
//!           self-contained HTML flamegraph — or `profile diff` two
//!           captures to attribute a regression to the guilty phase
//!   incidents inspect a "BIPI" incident flight-recorder dump (walks
//!           the causal chain of the last routed batch back through
//!           admission, per-layer routing, and solver exit) or
//!           export it as JSON
//!   lint    run the self-hosted static lint suite over this crate's
//!           own sources (hot-path-alloc, unsafe-audit, panic-path,
//!           telemetry-naming, lock-discipline, bench-honesty);
//!           --deny turns findings into a nonzero exit for CI
//!   info    list artifact manifest contents and engine stats
//!
//! Examples:
//!   bip-moe train --config moe16-bench --mode bip --bip-t 4 --steps 100
//!   bip-moe run --config-file configs/table2.json
//!   bip-moe solve --n 1024 --m 64 --k 8 --skew 3.0 --t 8
//!   bip-moe match --flows 4096 --ads 32 --slots 2
//!   bip-moe serve --scenario bursty --policy online
//!   bip-moe trace record --scenario steady --policy online --out t.trace
//!   bip-moe trace replay --trace t.trace
//!   bip-moe trace diff --trace t.trace --policies bip,lossfree
//!   bip-moe forecast fit --trace t.trace --kind holt --out model.json
//!   bip-moe forecast eval --model model.json --trace t2.trace
//!   bip-moe forecast serve --model model.json --scenario bursty
//!   bip-moe metrics --scenario steady --watch --out snap.json
//!   bip-moe metrics check --snapshot snap.json
//!   bip-moe serve --scenario degraded --policy bip --t 0 \
//!           --obs-incidents reports/incidents
//!   bip-moe top --scenario degraded --policy bip --plain
//!   bip-moe profile serve --scenario steady --policy bip \
//!           --html reports/flame.html
//!   bip-moe profile diff reports/PROF_a.json reports/PROF_b.json
//!   bip-moe incidents inspect --file reports/incidents/incident-*.bipi
//!   bip-moe lint --deny --json reports/lint.json

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use bip_moe::bip::{dual, flow, greedy_topk, Instance};
use bip_moe::forecast::{
    eval_model, fit_model, seed_states, AutoScaler, FitReport,
    ForecastConfig, ForecastModel, ForecasterKind, LoadSeries,
    ScalePolicy, DEFAULT_SEED_GAIN,
};
use bip_moe::matching::simulator::{compare_policies, Workload};
use bip_moe::metrics::TablePrinter;
use bip_moe::obs::{
    event, Detector, DetectorConfig, EventKind, Incident, ObsConfig,
    ObsController, RecorderConfig, TopState,
};
use bip_moe::prof;
use bip_moe::routing::BalanceState;
use bip_moe::runtime::Engine;
use bip_moe::serve::{
    self, Policy, ReplicaConfig, RouterConfig, SchedulerConfig, Scenario,
    ServeConfig, ServeReport, ServingRouter, TrafficConfig,
    TrafficGenerator,
};
use bip_moe::telemetry;
use bip_moe::trace::{PolicyDiff, Trace, TraceRecorder};
use bip_moe::train::TrainDriver;
use bip_moe::util::rng::Pcg64;
use bip_moe::util::Args;

fn main() {
    bip_moe::util::log::init_from_env();
    let args = Args::parse_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

/// An unknown --scenario must tell the operator what IS valid.
fn scenario_err(arg: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "unknown scenario '{arg}'; valid: {} (or 'all')",
        Scenario::names().join(", ")
    )
}

fn policy_err(arg: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "unknown policy '{arg}'; valid: {} (or 'all')",
        Policy::names().join(", ")
    )
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(args),
        Some("run") => cmd_run(args),
        Some("eval") => cmd_eval(args),
        Some("solve") => cmd_solve(args),
        Some("match") => cmd_match(args),
        Some("serve") => cmd_serve(args),
        Some("trace") => cmd_trace(args),
        Some("forecast") => cmd_forecast(args),
        Some("metrics") => cmd_metrics(args),
        Some("top") => cmd_top(args),
        Some("profile") => cmd_profile(args),
        Some("incidents") => cmd_incidents(args),
        Some("lint") => cmd_lint(args),
        Some("info") => cmd_info(args),
        Some(other) => bail!("unknown subcommand {other}; see --help"),
        None => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "bip-moe {} — BIP-Based Balancing for MoE pre-training + serving\n\n\
         usage: bip-moe <train|run|eval|solve|match|serve|trace|\
         forecast|metrics|top|profile|incidents|lint|info>\n\
         [--options]\n\n\
         train  --config <name> --mode <aux|lossfree|bip> [--bip-t N]\n\
                [--steps N] [--seed N] [--eval-batches N]\n\
                [--reports DIR] [--save CKPT] [--artifacts DIR]\n\
                [--warm-start-trace PATH]\n\
         run    --config-file configs/<exp>.json [--artifacts DIR]\n\
         eval   --checkpoint CKPT [--eval-batches N] [--artifacts DIR]\n\
         solve  [--n N] [--m M] [--k K] [--skew S] [--t T] [--exact]\n\
         match  [--flows N] [--ads M] [--slots K] [--t T] [--buckets B]\n\
         serve  [--scenario steady|bursty|diurnal|adversarial|\n\
                 multitenant|degraded|flashcrowd|all] [--policy\n\
                 greedy|lossfree|bip|online|\n\
                 approx|all] [--requests N] [--rate R/s] [--m M] [--k K]\n\
                 [--layers L] [--tenants T] [--t ITERS] [--buckets B]\n\
                 [--batch N] [--queue N] [--max-wait-us U] [--slo-ms MS]\n\
                 [--capacity-factor F] [--devices D] [--placement\n\
                 block|lpt] [--lpt-refresh BATCHES] [--seed N]\n\
                 [--solver-tol TOL] [--solver-t-max N] (adaptive\n\
                 Algorithm 1 for bip/bip-predictive: early-exit at\n\
                 TOL, iteration cap N; TOL 0 = fixed-T)\n\
                 [--replicas R] [--threads T] [--sync-every BATCHES]\n\
                 [--json PATH]\n\
                 [--obs-incidents DIR] (enable the observability\n\
                 controller: anomaly detection each --obs-tick batches\n\
                 (default 32), incident flight-recorder dumps to DIR;\n\
                 --obs-vio V adds a batch-MaxVio dump trigger at V;\n\
                 single-replica runs only)\n\
         trace  record --out PATH [--scenario S] [--policy P]\n\
                 [--requests N] [serve-style knobs incl. --replicas]\n\
                trace replay --trace PATH (asserts bit-identical\n\
                 completions against the recording)\n\
                trace diff --trace PATH [--policies a,b,..] [--json P]\n\
                trace export --trace PATH [--out PATH.json]\n\
         forecast fit [--trace PATH | serve-style knobs for a live\n\
                 run] [--kind ewma|holt|linear] [--alpha A] [--beta B]\n\
                 [--gamma G] [--period P] [--window W]\n\
                 [--horizons 1,4,16] [--holdout F] [--out MODEL.json]\n\
                forecast eval --model MODEL.json --trace PATH\n\
                 [--horizons ..] [--json P]\n\
                forecast serve --model MODEL.json [serve-style knobs]\n\
                 [--policy predictive] [--seed-gain G] [--autoscale]\n\
                 [--max-replicas R] [--scale-window-ms MS]\n\
                 [--replica-rps X] [--headroom H] [--json P]\n\
         metrics [serve-style knobs for the driven run]\n\
                 [--interval-ms MS] [--watch] [--out SNAP.json|.prom]\n\
                 (drives one serving run on a background thread and\n\
                 prints periodic counter deltas scraped from the live\n\
                 registry; --watch prints a per-tick summary table)\n\
                metrics check --snapshot PATH (assert the snapshot\n\
                 parses and the core series — telemetry and the obs\n\
                 event ring — are present and nonzero: the CI smoke\n\
                 gate)\n\
         top    [serve-style knobs for the driven run]\n\
                 [--interval-ms MS] [--plain] (live dashboard: expert\n\
                 heat rows, MaxVio sparkline, collapse score, alert\n\
                 feed; --plain renders ASCII without ANSI clearing)\n\
         profile serve [serve-style knobs, single scenario + policy]\n\
                 [--name NAME] [--out PROF.json] [--folded PATH]\n\
                 [--html PATH] (run one serving scenario with the\n\
                 hierarchical profiler and write the PROF_NAME.json\n\
                 call-path record; --folded emits collapsed-stack\n\
                 text, --html a self-contained flamegraph)\n\
                profile train [train-style knobs] [--name NAME]\n\
                 [--out/--folded/--html as above]\n\
                profile bench [--batches N] [router knobs] (profiled\n\
                 route_batch_into microloop, no event loop around it)\n\
                profile diff PREV.json CUR.json [--top N]\n\
                 [--assert-zero] (table sorted by worst exclusive-ns\n\
                 regression, alloc deltas alongside; --assert-zero\n\
                 exits nonzero unless every delta is zero)\n\
         incidents inspect --file PATH.bipi [--events N] (print the\n\
                 header, alert feed, scrape history tail, and the\n\
                 causal chain of the last routed batch)\n\
                incidents export --file PATH.bipi [--out PATH.json]\n\
         lint   [--deny] [--json PATH] [--filter LINT] [--root DIR]\n\
                 (self-hosted static lints over src/ and benches/:\n\
                 hot-path-alloc, unsafe-audit, panic-path,\n\
                 telemetry-naming, lock-discipline, bench-honesty;\n\
                 --deny exits nonzero on any finding — the CI gate)\n\
         info   [--artifacts DIR]\n\n\
         serve also accepts --metrics-out PATH to write a telemetry\n\
         snapshot (JSON, or Prometheus text for .prom/.txt) after the\n\
         sweep; trace record embeds the same scrape into the trace\n\
         (v3+) so trace replay can diff recorded-vs-replayed metrics.",
        bip_moe::VERSION
    );
}

fn cmd_train(args: &Args) -> Result<()> {
    args.check_known(&[
        "config", "mode", "bip-t", "steps", "seed", "eval-batches",
        "reports", "save", "artifacts", "sim-devices", "data-seed",
        "warm-start-trace",
    ])
    .map_err(anyhow::Error::msg)?;
    let engine = Engine::new(&artifacts_dir(args))?;
    let mut driver = TrainDriver::new(
        &args.str_or("config", "tiny"),
        &args.str_or("mode", "bip"),
        args.usize_or("bip-t", 4)?,
        args.u64_or("steps", 50)?,
    );
    driver.seed = args.usize_or("seed", 0)? as i32;
    driver.eval_batches = args.u64_or("eval-batches", 8)?;
    driver.sim_devices = args.usize_or("sim-devices", 4)?;
    driver.data_seed = args.u64_or("data-seed", 20240601)?;
    driver.warm_start_trace =
        args.get("warm-start-trace").map(PathBuf::from);

    let outcome = driver.run(&engine)?;
    let reports = PathBuf::from(args.str_or("reports", "reports"));
    let out = outcome.dump(&reports)?;

    let mut table = TablePrinter::new(
        &format!("run {}", driver.run_label()),
        &["Algorithm", "AvgMaxVio", "SupMaxVio", "Perplexity",
          "SimHours(run)"],
    );
    table.row(outcome.table_row(&driver.run_label()));
    table.print();
    println!("reports: {}", out.display());
    println!(
        "engine: {} compiles {:.1}s, {} execs {:.1}s",
        engine.stats().compiles,
        engine.stats().compile_seconds,
        engine.stats().executions,
        engine.stats().execute_seconds
    );

    if let Some(ckpt) = args.get("save") {
        outcome
            .state
            .save(Path::new(ckpt), &driver.config, &driver.mode)?;
        println!("checkpoint: {ckpt}");
    }
    Ok(())
}

/// Run a named experiment from a JSON run-config file (configs/*.json).
fn cmd_run(args: &Args) -> Result<()> {
    args.check_known(&["config-file", "artifacts", "reports", "save"])
        .map_err(anyhow::Error::msg)?;
    let path = args
        .get("config-file")
        .ok_or_else(|| anyhow::anyhow!("--config-file required"))?;
    let run_cfg = bip_moe::config::RunConfig::load(Path::new(path))?;
    println!("experiment {}: {}", run_cfg.name, run_cfg.to_json());
    let engine = Engine::new(&artifacts_dir(args))?;
    let driver = run_cfg.driver();
    let outcome = driver.run(&engine)?;
    let out = outcome
        .dump(Path::new(&args.str_or("reports", "reports")))?;
    let mut table = TablePrinter::new(
        &format!("experiment {}", run_cfg.name),
        &["Algorithm", "AvgMaxVio", "SupMaxVio", "Perplexity",
          "SimHours(run)"],
    );
    table.row(outcome.table_row(&driver.run_label()));
    table.print();
    println!("reports: {}", out.display());
    if let Some(ckpt) = args.get("save") {
        outcome
            .state
            .save(Path::new(ckpt), &driver.config, &driver.mode)?;
        println!("checkpoint: {ckpt}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    args.check_known(&["checkpoint", "eval-batches", "artifacts",
                       "data-seed"])
        .map_err(anyhow::Error::msg)?;
    let ckpt = args
        .get("checkpoint")
        .ok_or_else(|| anyhow::anyhow!("--checkpoint required"))?;
    let (state, config, mode) =
        bip_moe::train::state::TrainState::load(Path::new(ckpt))?;
    let engine = Engine::new(&artifacts_dir(args))?;
    let cfg = engine.manifest().config(&config)?.clone();
    let eval_art = engine.manifest().find(&config, "eval", &mode, None)?
        .clone();

    let corpus = std::sync::Arc::new(bip_moe::data::Corpus::build(
        bip_moe::data::CorpusSpec {
            vocab_size: cfg.vocab_size,
            seed: args.u64_or("data-seed", 20240601)?,
            ..Default::default()
        },
    ));
    let loader = bip_moe::data::Loader::new(
        corpus, cfg.batch_size, cfg.seq_len,
        bip_moe::data::Split::Test);
    let mut ppl = bip_moe::metrics::Perplexity::default();
    for i in 0..args.u64_or("eval-batches", 16)? {
        let batch = loader.batch(i);
        let tokens = bip_moe::runtime::Tensor::from_i32(
            &[cfg.batch_size, cfg.seq_len + 1],
            batch.tokens,
        );
        let outs = engine.run(&eval_art, &[
            state.theta.clone(),
            state.route_state.clone(),
            tokens,
        ])?;
        ppl.push(outs[0].scalar_f32()? as f64, cfg.n_tokens as u64);
    }
    println!(
        "checkpoint {ckpt}: config={config} mode={mode} step={} \
         test-ppl={:.4}",
        state.step_count(),
        ppl.value()
    );
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    args.check_known(&["n", "m", "k", "skew", "temp", "t", "seed", "exact"])
        .map_err(anyhow::Error::msg)?;
    let n = args.usize_or("n", 1024)?;
    let m = args.usize_or("m", 16)?;
    let k = args.usize_or("k", 4)?;
    let t = args.usize_or("t", 4)?;
    let mut rng = Pcg64::new(args.u64_or("seed", 0)?);
    let inst = Instance::synthetic(
        n, m, k,
        args.f64_or("temp", 2.0)?,
        args.f64_or("skew", 3.0)?,
        &mut rng,
    );

    let mut table = TablePrinter::new(
        &format!("BIP routing instance n={n} m={m} k={k} cap={}", inst.cap),
        &["Solver", "Objective", "MaxVio", "Feasible", "Time"],
    );

    let t0 = std::time::Instant::now();
    let greedy = greedy_topk(&inst);
    table.row(vec![
        "greedy top-k".into(),
        format!("{:.4}", greedy.objective(&inst)),
        format!("{:.4}", greedy.max_violation(&inst)),
        format!("{}", greedy.is_col_feasible(m, inst.cap)),
        format!("{:?}", t0.elapsed()),
    ]);

    let t0 = std::time::Instant::now();
    let (routing, _q) = dual::solve(&inst, t);
    table.row(vec![
        format!("BIP dual (T={t})"),
        format!("{:.4}", routing.objective(&inst)),
        format!("{:.4}", routing.max_violation(&inst)),
        format!("{}", routing.is_col_feasible(m, inst.cap)),
        format!("{:?}", t0.elapsed()),
    ]);

    if args.flag("exact") {
        let t0 = std::time::Instant::now();
        let (exact, obj) = flow::solve_exact(&inst);
        table.row(vec![
            "exact (min-cost flow)".into(),
            format!("{obj:.4}"),
            format!("{:.4}", exact.max_violation(&inst)),
            format!("{}", exact.is_col_feasible(m, inst.cap)),
            format!("{:?}", t0.elapsed()),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_match(args: &Args) -> Result<()> {
    args.check_known(&["flows", "ads", "slots", "t", "buckets", "seed"])
        .map_err(anyhow::Error::msg)?;
    let w = Workload::synthetic(
        args.usize_or("flows", 4096)?,
        args.usize_or("ads", 32)?,
        args.usize_or("slots", 2)?,
        args.u64_or("seed", 42)?,
    );
    let reports =
        compare_policies(&w, args.usize_or("t", 4)?,
                         args.usize_or("buckets", 128)?);
    let mut table = TablePrinter::new(
        &format!(
            "online ad matching: {} flows x {} ads, {} slots, cap {}",
            w.n_flows, w.n_ads, w.slots, w.capacity()
        ),
        &["Policy", "CTR sum", "vs hindsight", "MaxVio", "State bytes"],
    );
    for r in reports {
        table.row(vec![
            r.policy.clone(),
            format!("{:.2}", r.objective),
            format!("{:.3}", r.competitive_ratio),
            format!("{:.3}", r.max_violation),
            format!("{}", r.state_bytes),
        ]);
    }
    table.print();
    Ok(())
}

/// Online serving sweep: policy x scenario through the serve/ pipeline.
/// The greedy baseline always rides along so every table shows the
/// BIP-balanced policies against unbalanced top-k at equal throughput.
fn cmd_serve(args: &Args) -> Result<()> {
    args.check_known(&[
        "scenario", "policy", "requests", "rate", "m", "k", "layers",
        "tenants", "t", "solver-tol", "solver-t-max", "buckets",
        "batch", "queue", "max-wait-us", "slo-ms", "capacity-factor",
        "devices", "placement", "lpt-refresh", "seed", "replicas",
        "threads", "sync-every",
        "json", "metrics-out",
        "obs-incidents", "obs-tick", "obs-vio",
    ])
    .map_err(anyhow::Error::msg)?;

    let scenario_arg =
        args.str_or("scenario", "all").trim().to_ascii_lowercase();
    let scenarios: Vec<Scenario> = if scenario_arg == "all" {
        Scenario::all().to_vec()
    } else {
        vec![Scenario::parse(&scenario_arg)
            .ok_or_else(|| scenario_err(&scenario_arg))?]
    };
    if scenarios.contains(&Scenario::Replayed) {
        bail!(
            "scenario 'replayed' is driven by a recorded trace: use \
             `bip-moe trace replay --trace PATH`"
        );
    }
    let policy_arg =
        args.str_or("policy", "all").trim().to_ascii_lowercase();
    let mut policies: Vec<Policy> = if policy_arg == "all" {
        Policy::all().to_vec()
    } else {
        vec![Policy::parse(&policy_arg)
            .ok_or_else(|| policy_err(&policy_arg))?]
    };
    if !policies.contains(&Policy::Greedy) {
        policies.insert(0, Policy::Greedy);
    }

    let ServeKnobs { traffic, sched, router, replicas: rknobs } =
        serve_knobs(args, 8192)?;
    let (replicas, threads, sync_every) =
        (rknobs.replicas, rknobs.threads, rknobs.sync_every);
    let obs_dir = args.get("obs-incidents").map(PathBuf::from);
    if obs_dir.is_some() && (replicas > 1 || threads > 1) {
        bail!(
            "--obs-incidents drives the single-replica observed loop; \
             drop --replicas/--threads (or leave them at 1)"
        );
    }

    let mut json_rows = Vec::new();
    let mut obs_summaries = Vec::new();
    for &scenario in &scenarios {
        let mut table = TablePrinter::new(
            &format!(
                "serving {} — {} requests at {:.0}/s, m={} k={} L={} \
                 batch<={} cf={} R={}",
                scenario.name(),
                traffic.n_requests,
                traffic.rate_per_s,
                traffic.m,
                traffic.k,
                traffic.n_layers,
                sched.batch_max,
                router.capacity_factor,
                replicas,
            ),
            ServeReport::headers(),
        );
        let mut replica_tables = Vec::new();
        for &policy in &policies {
            let cfg = ServeConfig::new(
                TrafficConfig { scenario, ..traffic.clone() },
                sched.clone(),
                router.clone(),
                policy,
            );
            if replicas > 1 || threads > 1 {
                let rcfg = serve::ReplicaConfig {
                    replicas,
                    threads,
                    sync_every,
                };
                let outcome = serve::run_replicated(&cfg, &rcfg);
                table.row(outcome.report.table_row());
                let mut pr_table = TablePrinter::new(
                    &format!(
                        "replicas — {} on {} ({} batches, {} syncs)",
                        outcome.report.policy,
                        scenario.name(),
                        outcome.batches,
                        outcome.syncs.len(),
                    ),
                    bip_moe::serve::ReplicaSummary::headers(),
                );
                for p in &outcome.per_replica {
                    pr_table.row(p.table_row());
                }
                replica_tables.push(pr_table);
                let mut row = outcome.report.to_json();
                if let bip_moe::util::Json::Obj(map) = &mut row {
                    map.insert(
                        "replicas".into(),
                        bip_moe::util::Json::Num(replicas as f64),
                    );
                    map.insert(
                        "threads".into(),
                        bip_moe::util::Json::Num(threads as f64),
                    );
                    map.insert(
                        "sync_every".into(),
                        bip_moe::util::Json::Num(sync_every as f64),
                    );
                    map.insert(
                        "batches".into(),
                        bip_moe::util::Json::Num(outcome.batches as f64),
                    );
                    map.insert(
                        "syncs".into(),
                        bip_moe::util::Json::Num(
                            outcome.syncs.len() as f64,
                        ),
                    );
                    map.insert(
                        "per_replica".into(),
                        bip_moe::util::Json::Arr(
                            outcome
                                .per_replica
                                .iter()
                                .map(|p| p.to_json())
                                .collect(),
                        ),
                    );
                    if let Some(last) = outcome.syncs.last() {
                        map.insert(
                            "last_sync_div_before".into(),
                            bip_moe::util::Json::Num(
                                last.state_div_before,
                            ),
                        );
                        map.insert(
                            "last_sync_div_after".into(),
                            bip_moe::util::Json::Num(
                                last.state_div_after,
                            ),
                        );
                    }
                }
                json_rows.push(row);
            } else if let Some(dir) = &obs_dir {
                let mut obs =
                    obs_controller(args, dir, scenario, policy)?;
                let outcome =
                    serve::run_scenario_observed(&cfg, &mut obs);
                table.row(outcome.report.table_row());
                json_rows.push(outcome.report.to_json());
                obs_summaries.push(obs_summary(
                    scenario, policy, &obs,
                ));
            } else {
                let outcome = serve::run_scenario(&cfg);
                table.row(outcome.report.table_row());
                json_rows.push(outcome.report.to_json());
            }
        }
        table.print();
        for t in replica_tables {
            t.print();
        }
    }
    for s in &obs_summaries {
        print!("{s}");
    }

    if let Some(path) = args.get("json") {
        let doc = bip_moe::util::Json::obj(vec![
            ("version", bip_moe::util::Json::Str(bip_moe::VERSION.into())),
            ("results", bip_moe::util::Json::Arr(json_rows)),
        ]);
        std::fs::write(path, doc.to_string())?;
        println!("report: {path}");
    }
    if let Some(path) = args.get("metrics-out") {
        telemetry::scrape(telemetry::global()).write(Path::new(path))?;
        println!("metrics: {path}");
    }
    Ok(())
}

/// Serve-pipeline knobs shared by the `serve` sweep and `trace record`
/// (which freezes one configuration into a trace header). The caller
/// overwrites `traffic.scenario`; only the default request count
/// differs between the two surfaces.
struct ServeKnobs {
    traffic: TrafficConfig,
    sched: SchedulerConfig,
    router: RouterConfig,
    replicas: ReplicaConfig,
}

fn serve_knobs(args: &Args, default_requests: usize) -> Result<ServeKnobs> {
    let m = args.usize_or("m", 16)?;
    let n_devices = args.usize_or("devices", 4)?;
    if n_devices == 0 || m % n_devices != 0 {
        bail!("--m {m} must be divisible by --devices {n_devices} (>= 1)");
    }
    let lpt = match args.str_or("placement", "block").as_str() {
        "block" => None,
        "lpt" => match args.u64_or("lpt-refresh", 8)? {
            0 => bail!("--lpt-refresh must be >= 1 batches"),
            n => Some(n),
        },
        other => bail!("unknown placement {other} (block|lpt)"),
    };
    let traffic = TrafficConfig {
        scenario: Scenario::Steady, // overwritten by the caller
        n_requests: args.usize_or("requests", default_requests)?,
        rate_per_s: args.f64_or("rate", 100_000.0)?,
        n_layers: args.usize_or("layers", 4)?,
        m,
        k: args.usize_or("k", 4)?,
        n_tenants: args.usize_or("tenants", 4)?,
        slo_us: (args.f64_or("slo-ms", 20.0)? * 1e3) as u64,
        seed: args.u64_or("seed", 1)?,
        ..Default::default()
    };
    let sched = SchedulerConfig {
        queue_cap: args.usize_or("queue", 512)?,
        batch_max: args.usize_or("batch", 64)?,
        max_wait_us: args.u64_or("max-wait-us", 2_000)?,
        drop_expired: true,
    };
    let solver_tol = args.f64_or("solver-tol", 0.0)?;
    if !solver_tol.is_finite() || solver_tol < 0.0 {
        bail!(
            "--solver-tol must be a finite value >= 0 (got \
             {solver_tol}); 0 keeps the fixed-T solver, > 0 enables \
             the convergence-adaptive Algorithm 1 for the bip-batch / \
             bip-predictive policies"
        );
    }
    let router = RouterConfig {
        t_iters: args.usize_or("t", 4)?,
        buckets: args.usize_or("buckets", 128)?,
        capacity_factor: args.f64_or("capacity-factor", 2.0)?,
        n_devices,
        lpt_refresh: lpt,
        solver_tol,
        // 0 follows --t; the adaptive solver typically wants a higher
        // cap (it early-exits once converged)
        solver_t_max: args.usize_or("solver-t-max", 0)?,
        ..Default::default()
    };
    let replicas = ReplicaConfig {
        replicas: args.usize_or("replicas", 1)?,
        threads: args.usize_or("threads", 1)?,
        sync_every: args.u64_or("sync-every", 16)?,
    };
    if replicas.replicas == 0 {
        bail!("--replicas must be >= 1");
    }
    Ok(ServeKnobs { traffic, sched, router, replicas })
}

/// Build the serve-loop observability controller from `--obs-*` knobs
/// for one (scenario, policy) cell of the sweep.
fn obs_controller(
    args: &Args,
    dir: &Path,
    scenario: Scenario,
    policy: Policy,
) -> Result<ObsController> {
    let vio_threshold = args.f64_or("obs-vio", 0.0)?;
    if !vio_threshold.is_finite() || vio_threshold < 0.0 {
        bail!(
            "--obs-vio must be a finite value >= 0 (got \
             {vio_threshold}); 0 disables the MaxVio dump trigger"
        );
    }
    let cfg = ObsConfig {
        tick_every: args.u64_or("obs-tick", 32)?.max(1),
        detector: DetectorConfig::default(),
        recorder: RecorderConfig {
            out_dir: dir.to_path_buf(),
            scenario: scenario.name().to_string(),
            policy: policy.name().to_string(),
            vio_threshold,
            ..RecorderConfig::default()
        },
    };
    Ok(ObsController::new(cfg))
}

/// Per-cell observability verdict printed after the sweep tables.
fn obs_summary(
    scenario: Scenario,
    policy: Policy,
    obs: &ObsController,
) -> String {
    let mut out = format!(
        "obs {} / {}: {} tick(s), {} alert(s), {} incident(s)\n",
        scenario.name(),
        policy.name(),
        obs.ticks(),
        obs.alerts.len(),
        obs.incidents.len(),
    );
    for a in &obs.alerts {
        out.push_str(&format!(
            "  [t{:>4}] {:<16} {}\n",
            a.tick,
            a.kind.name(),
            a.detail
        ));
    }
    for p in &obs.incidents {
        out.push_str(&format!("  incident: {}\n", p.display()));
    }
    out
}

/// Routing-trace tooling: record a serving run to a versioned binary
/// trace, replay it bit-identically (the regression mode), re-route the
/// recorded gate scores under different policies (the counterfactual
/// diff), or export the trace as JSON.
fn cmd_trace(args: &Args) -> Result<()> {
    args.check_known(&[
        "scenario", "policy", "requests", "rate", "m", "k", "layers",
        "tenants", "t", "solver-tol", "solver-t-max", "buckets",
        "batch", "queue", "max-wait-us", "slo-ms", "capacity-factor",
        "devices", "placement", "lpt-refresh", "seed", "replicas",
        "threads", "sync-every",
        "out", "trace", "policies", "json",
    ])
    .map_err(anyhow::Error::msg)?;
    match args.positional.first().map(String::as_str) {
        Some("record") => cmd_trace_record(args),
        Some("replay") => cmd_trace_replay(args),
        Some("diff") => cmd_trace_diff(args),
        Some("export") => cmd_trace_export(args),
        Some(other) => bail!("unknown trace action {other}; see --help"),
        None => bail!(
            "usage: bip-moe trace <record|replay|diff|export> [--options]"
        ),
    }
}

/// Build the (ServeConfig, ReplicaConfig) pair `trace record` freezes
/// into the trace header (single scenario + single policy, unlike the
/// `serve` sweep).
fn trace_serve_config(args: &Args) -> Result<(ServeConfig, ReplicaConfig)> {
    let scenario_arg = args.str_or("scenario", "steady");
    let scenario = Scenario::parse(&scenario_arg)
        .ok_or_else(|| scenario_err(&scenario_arg))?;
    if scenario == Scenario::Replayed {
        bail!(
            "trace record needs a generative scenario; 'replayed' is \
             what replay/diff run"
        );
    }
    let policy_arg = args.str_or("policy", "online");
    let policy = Policy::parse(&policy_arg)
        .ok_or_else(|| policy_err(&policy_arg))?;
    let ServeKnobs { mut traffic, sched, router, replicas } =
        serve_knobs(args, 2048)?;
    traffic.scenario = scenario;
    Ok((ServeConfig::new(traffic, sched, router, policy), replicas))
}

fn cmd_trace_record(args: &Args) -> Result<()> {
    let (cfg, rcfg) = trace_serve_config(args)?;
    let out_path = args.str_or("out", "bip-moe.trace");
    let mut rec = TraceRecorder::new(&cfg, &rcfg);
    let report = if rcfg.replicas > 1 || rcfg.threads > 1 {
        serve::run_replicated_with(
            &cfg,
            &rcfg,
            TrafficGenerator::new(cfg.traffic.clone()),
            Some(&mut rec),
        )
        .report
    } else {
        serve::run_scenario_with(
            &cfg,
            TrafficGenerator::new(cfg.traffic.clone()),
            Some(&mut rec),
        )
        .report
    };
    rec.capture_telemetry();
    let trace = rec.into_trace();
    let bytes = trace.save(Path::new(&out_path))?;

    let mut table = TablePrinter::new(
        &format!("recorded {} / {}", report.scenario, report.policy),
        ServeReport::headers(),
    );
    table.row(report.table_row());
    table.print();
    println!(
        "trace: {out_path} ({} arrivals, {} frames, {} syncs, {} \
         completions, {} routed tokens, {bytes} bytes)",
        trace.arrivals.len(),
        trace.frames.len(),
        trace.syncs.len(),
        trace.completions.len(),
        trace.routed_tokens(),
    );
    Ok(())
}

fn cmd_trace_replay(args: &Args) -> Result<()> {
    let path = args
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("--trace PATH required"))?;
    let trace = Trace::load(Path::new(path))?;
    let rep = bip_moe::trace::replay(&trace);
    let mut table = TablePrinter::new(
        &format!(
            "replayed {} / {} from {path}",
            rep.report.scenario, rep.report.policy
        ),
        ServeReport::headers(),
    );
    table.row(rep.report.table_row());
    table.print();
    if !rep.mismatches.is_empty() {
        for m in &rep.mismatches {
            eprintln!("  {m}");
        }
        bail!(
            "replay diverged from the recording in {} place(s)",
            rep.mismatches.len()
        );
    }
    if trace.telemetry.is_empty() {
        if trace.version < 3 {
            println!(
                "trace is v{} — no embedded telemetry to diff (v3+ \
                 records a scrape)",
                trace.version
            );
        }
    } else {
        // the replay just drove this process's global registry, so a
        // fresh scrape IS the replayed side of the diff
        let replayed: std::collections::BTreeMap<String, f64> =
            telemetry::scrape_named().into_iter().collect();
        let mut t = TablePrinter::new(
            "telemetry — recorded vs replayed",
            &["Series", "Recorded", "Replayed", "Delta"],
        );
        for (name, rec_v) in &trace.telemetry {
            let rep_v = replayed.get(name).copied().unwrap_or(0.0);
            if *rec_v == 0.0 && rep_v == 0.0 {
                continue;
            }
            t.row(vec![
                name.clone(),
                format!("{rec_v}"),
                format!("{rep_v}"),
                format!("{:+}", rep_v - rec_v),
            ]);
        }
        t.print();
    }
    println!(
        "replay OK: {} completions bit-identical to the recording",
        rep.completions.len()
    );
    Ok(())
}

fn cmd_trace_diff(args: &Args) -> Result<()> {
    let path = args
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("--trace PATH required"))?;
    let trace = Trace::load(Path::new(path))?;
    let policies: Vec<Policy> = match args.get("policies") {
        Some(spec) => spec
            .split(',')
            .map(|s| {
                Policy::parse(s).ok_or_else(|| policy_err(s.trim()))
            })
            .collect::<Result<_>>()?,
        None => vec![
            Policy::BipBatch,
            Policy::LossFree,
            Policy::Online,
            Policy::Approx,
        ],
    };
    let diffs = bip_moe::trace::diff_policies(&trace, &policies)?;
    let mut table = TablePrinter::new(
        &format!(
            "counterfactual diff — recorded {} / {} ({} frames, {} \
             tokens)",
            trace.meta.serve.traffic.scenario.name(),
            trace.meta.serve.policy.name(),
            trace.frames.len(),
            trace.routed_tokens(),
        ),
        PolicyDiff::headers(),
    );
    for d in &diffs {
        table.row(d.table_row());
    }
    table.print();
    if let Some(json_path) = args.get("json") {
        let doc = bip_moe::util::Json::obj(vec![
            ("version", bip_moe::util::Json::Str(bip_moe::VERSION.into())),
            (
                "recorded_policy",
                bip_moe::util::Json::Str(
                    trace.meta.serve.policy.name().into(),
                ),
            ),
            (
                "recorded_scenario",
                bip_moe::util::Json::Str(
                    trace.meta.serve.traffic.scenario.name().into(),
                ),
            ),
            (
                "frames",
                bip_moe::util::Json::Num(trace.frames.len() as f64),
            ),
            (
                "results",
                bip_moe::util::Json::Arr(
                    diffs.iter().map(|d| d.to_json()).collect(),
                ),
            ),
        ]);
        std::fs::write(json_path, format!("{doc}\n"))?;
        println!("report: {json_path}");
    }
    Ok(())
}

fn cmd_trace_export(args: &Args) -> Result<()> {
    let path = args
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("--trace PATH required"))?;
    let trace = Trace::load(Path::new(path))?;
    let doc = trace.to_json();
    match args.get("out") {
        Some(out) => {
            std::fs::write(out, format!("{doc}\n"))?;
            println!(
                "json: {out} ({} arrivals, {} frames)",
                trace.arrivals.len(),
                trace.frames.len()
            );
        }
        None => println!("{doc}"),
    }
    Ok(())
}

/// Expert-load forecasting: fit per-layer forecasters from a recorded
/// trace (or a live routed run), evaluate them walk-forward against a
/// fresh trace, and serve with the forecast warm start / predictive
/// autoscaling. Shares the serve_knobs arg-builder with `serve` and
/// `trace record`, so a pipeline configured once records, fits and
/// serves identically.
fn cmd_forecast(args: &Args) -> Result<()> {
    args.check_known(&[
        // serve-pipeline knobs (shared with `serve` / `trace record`)
        "scenario", "policy", "requests", "rate", "m", "k", "layers",
        "tenants", "t", "solver-tol", "solver-t-max", "buckets",
        "batch", "queue", "max-wait-us", "slo-ms", "capacity-factor",
        "devices", "placement", "lpt-refresh", "seed", "replicas",
        "threads", "sync-every",
        // forecast-specific
        "trace", "model", "kind", "alpha", "beta", "gamma", "period",
        "window", "horizons", "holdout", "out", "seed-gain",
        "autoscale", "max-replicas", "scale-window-ms", "replica-rps",
        "headroom", "json",
    ])
    .map_err(anyhow::Error::msg)?;
    match args.positional.first().map(String::as_str) {
        Some("fit") => cmd_forecast_fit(args),
        Some("eval") => cmd_forecast_eval(args),
        Some("serve") => cmd_forecast_serve(args),
        Some(other) => {
            bail!("unknown forecast action {other}; see --help")
        }
        None => {
            bail!("usage: bip-moe forecast <fit|eval|serve> [--options]")
        }
    }
}

fn forecast_cfg(args: &Args) -> Result<ForecastConfig> {
    let d = ForecastConfig::default();
    Ok(ForecastConfig {
        alpha: args.f64_or("alpha", d.alpha)?,
        beta: args.f64_or("beta", d.beta)?,
        gamma: args.f64_or("gamma", d.gamma)?,
        period: args.usize_or("period", d.period)?,
        window: args.usize_or("window", d.window)?,
    })
}

fn forecast_kind(args: &Args) -> Result<ForecasterKind> {
    let spec = args.str_or("kind", "holt");
    ForecasterKind::parse(&spec).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown forecaster kind '{spec}'; valid: {}",
            ForecasterKind::names().join(", ")
        )
    })
}

fn parse_horizons(args: &Args) -> Result<Vec<usize>> {
    let spec = args.str_or("horizons", "1,4,16");
    let mut out = Vec::new();
    for part in spec.split(',') {
        let h: usize = part.trim().parse().map_err(|_| {
            anyhow::anyhow!("bad horizon '{}' in --horizons", part.trim())
        })?;
        if h == 0 {
            bail!("horizons must be >= 1");
        }
        out.push(h);
    }
    Ok(out)
}

/// The fit series and a label describing where it came from: a trace
/// file, or a live routed run (default greedy — the raw *demand*
/// signal, not an already-balanced trajectory) with the tracker's
/// bounded load history enabled.
fn forecast_series(args: &Args) -> Result<(LoadSeries, String)> {
    if let Some(path) = args.get("trace") {
        let trace = Trace::load(Path::new(path))?;
        let label =
            format!("trace {path} ({} frames)", trace.frames.len());
        return Ok((LoadSeries::from_trace(&trace)?, label));
    }
    let scenario_arg = args.str_or("scenario", "steady");
    let scenario = Scenario::parse(&scenario_arg)
        .ok_or_else(|| scenario_err(&scenario_arg))?;
    if scenario == Scenario::Replayed {
        bail!("forecast fit needs a generative scenario or --trace PATH");
    }
    let policy_arg = args.str_or("policy", "greedy");
    let policy = Policy::parse(&policy_arg)
        .ok_or_else(|| policy_err(&policy_arg))?;
    let ServeKnobs { mut traffic, sched, router, .. } =
        serve_knobs(args, 4096)?;
    traffic.scenario = scenario;
    let cfg = ServeConfig::new(traffic, sched.clone(), router, policy);
    let mut router = ServingRouter::new(policy, cfg.router.clone());
    let batch = sched.batch_max.max(1);
    router.track_load_history(
        (cfg.traffic.n_requests / batch + 2).max(8),
    );
    let reqs: Vec<bip_moe::serve::Request> =
        TrafficGenerator::new(cfg.traffic.clone()).collect();
    for chunk in reqs.chunks(batch) {
        router.route_batch(chunk);
    }
    let label = format!(
        "live {} / {} ({} batches)",
        scenario.name(),
        policy.name(),
        router.batches_routed()
    );
    Ok((LoadSeries::from_tracker(&router.balance)?, label))
}

fn cmd_forecast_fit(args: &Args) -> Result<()> {
    let kind = forecast_kind(args)?;
    let horizons = parse_horizons(args)?;
    let holdout = args.f64_or("holdout", 0.25)?;
    if !(holdout > 0.0 && holdout < 1.0) {
        bail!("--holdout must be a fraction in (0, 1)");
    }
    let (series, label) = forecast_series(args)?;
    let fcfg = forecast_cfg(args)?;
    let (model, report) =
        fit_model(kind, &fcfg, &series, &horizons, holdout)?;
    let mut table = TablePrinter::new(
        &format!(
            "forecast fit {} on {label} — {} layers x {} experts, \
             {} steps, holdout {}",
            kind.name(),
            model.n_layers(),
            model.m,
            report.steps,
            report.holdout
        ),
        FitReport::headers(),
    );
    for row in report.table_rows() {
        table.row(row);
    }
    table.print();
    if let Some(out) = args.get("out") {
        model.save(Path::new(out))?;
        println!("model: {out}");
    }
    Ok(())
}

fn cmd_forecast_eval(args: &Args) -> Result<()> {
    let model_path = args
        .get("model")
        .ok_or_else(|| anyhow::anyhow!("--model PATH required"))?;
    let trace_path = args
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("--trace PATH required"))?;
    let mut model = ForecastModel::load(Path::new(model_path))?;
    let trace = Trace::load(Path::new(trace_path))?;
    let series = LoadSeries::from_trace(&trace)?;
    let horizons = parse_horizons(args)?;
    let report = eval_model(&mut model, &series, &horizons)?;
    let mut table = TablePrinter::new(
        &format!(
            "forecast eval {} on {trace_path} ({} steps)",
            model.kind.name(),
            report.steps
        ),
        FitReport::headers(),
    );
    for row in report.table_rows() {
        table.row(row);
    }
    table.print();
    if let Some(json_path) = args.get("json") {
        let doc = bip_moe::util::Json::obj(vec![
            ("version", bip_moe::util::Json::Str(bip_moe::VERSION.into())),
            ("model", bip_moe::util::Json::Str(model_path.into())),
            ("trace", bip_moe::util::Json::Str(trace_path.into())),
            ("report", report.to_json()),
        ]);
        std::fs::write(json_path, format!("{doc}\n"))?;
        println!("report: {json_path}");
    }
    Ok(())
}

fn cmd_forecast_serve(args: &Args) -> Result<()> {
    let model_path = args
        .get("model")
        .ok_or_else(|| anyhow::anyhow!("--model PATH required"))?;
    let model = ForecastModel::load(Path::new(model_path))?;
    let scenario_arg = args.str_or("scenario", "bursty");
    let scenario = Scenario::parse(&scenario_arg)
        .ok_or_else(|| scenario_err(&scenario_arg))?;
    if scenario == Scenario::Replayed {
        bail!("forecast serve needs a generative scenario");
    }
    let policy_arg = args.str_or("policy", "predictive");
    let policy = Policy::parse(&policy_arg)
        .ok_or_else(|| policy_err(&policy_arg))?;
    let ServeKnobs { mut traffic, sched, router, replicas: rknobs } =
        serve_knobs(args, 8192)?;
    traffic.scenario = scenario;
    if model.m != traffic.m {
        bail!(
            "model has {} experts but the serve config has {}",
            model.m,
            traffic.m
        );
    }
    let gain = args.f64_or("seed-gain", DEFAULT_SEED_GAIN)?;
    let seeds = seed_states(&model, traffic.n_layers, traffic.k, gain);
    // the cold baseline runs the identical pipeline unseeded (for the
    // predictive policy that IS cold-start Bip)
    let cold_policy = if policy == Policy::Predictive {
        Policy::BipBatch
    } else {
        policy
    };
    let warm_cfg = ServeConfig::new(
        traffic.clone(),
        sched.clone(),
        router.clone(),
        policy,
    );
    let cold_cfg =
        ServeConfig::new(traffic.clone(), sched, router, cold_policy);

    if args.flag("autoscale") {
        return forecast_autoscale(
            args, &warm_cfg, &cold_cfg, &rknobs, &seeds,
        );
    }

    let run_one = |cfg: &ServeConfig,
                   seeds: Option<&[BalanceState]>|
     -> (f64, ServeReport) {
        if rknobs.replicas > 1 || rknobs.threads > 1 {
            let out = match seeds {
                Some(s) => serve::run_replicated_seeded(cfg, &rknobs, s),
                None => serve::run_replicated(cfg, &rknobs),
            };
            (out.first_batch_vio, out.report)
        } else {
            let out = match seeds {
                Some(s) => serve::run_scenario_seeded(cfg, s),
                None => serve::run_scenario(cfg),
            };
            (out.first_batch_vio, out.report)
        }
    };
    let (cold_first, cold) = run_one(&cold_cfg, None);
    let (warm_first, warm) = run_one(&warm_cfg, Some(&seeds));

    let mut table = TablePrinter::new(
        &format!(
            "forecast serve {} — model {} ({}), seed gain {gain}, R={}",
            scenario.name(),
            model_path,
            model.kind.name(),
            rknobs.replicas,
        ),
        &[
            "Run", "Policy", "FirstVio", "AvgMaxVio", "SupMaxVio",
            "p99ms", "Done", "Overflow",
        ],
    );
    let mut json_rows = Vec::new();
    for (run, first, rep) in
        [("cold", cold_first, &cold), ("warm", warm_first, &warm)]
    {
        table.row(vec![
            run.into(),
            rep.policy.clone(),
            format!("{first:.4}"),
            format!("{:.4}", rep.avg_max_vio),
            format!("{:.4}", rep.sup_max_vio),
            format!("{:.2}", rep.p99_ms),
            format!("{}", rep.completed),
            format!("{}", rep.overflow),
        ]);
        let mut row = rep.to_json();
        if let bip_moe::util::Json::Obj(map) = &mut row {
            map.insert(
                "run".into(),
                bip_moe::util::Json::Str(run.into()),
            );
            map.insert(
                "first_batch_vio".into(),
                bip_moe::util::Json::Num(first),
            );
        }
        json_rows.push(row);
    }
    table.print();
    println!(
        "first-batch MaxVio: cold {cold_first:.4} -> warm \
         {warm_first:.4} ({:+.1}%)",
        if cold_first > 0.0 {
            (warm_first / cold_first - 1.0) * 100.0
        } else {
            0.0
        }
    );
    if let Some(path) = args.get("json") {
        let doc = bip_moe::util::Json::obj(vec![
            ("version", bip_moe::util::Json::Str(bip_moe::VERSION.into())),
            ("results", bip_moe::util::Json::Arr(json_rows)),
        ]);
        std::fs::write(path, format!("{doc}\n"))?;
        println!("report: {path}");
    }
    Ok(())
}

/// Predictive vs reactive autoscaling on the same warm-started
/// pipeline, sized against a calibrated (or given) per-replica rate.
fn forecast_autoscale(
    args: &Args,
    warm_cfg: &ServeConfig,
    cold_cfg: &ServeConfig,
    rknobs: &ReplicaConfig,
    seeds: &[BalanceState],
) -> Result<()> {
    let max_replicas =
        args.usize_or("max-replicas", rknobs.replicas.max(4))?;
    let rcfg = ReplicaConfig {
        replicas: max_replicas,
        threads: rknobs.threads,
        sync_every: rknobs.sync_every,
    };
    // per-replica serviceable rate: given, or calibrated from a cold
    // single-server run's measured throughput
    let replica_rps = match args.get("replica-rps") {
        Some(_) => args.f64_or("replica-rps", 0.0)?,
        None => serve::run_scenario(cold_cfg)
            .report
            .throughput_rps
            .max(1.0),
    };
    if replica_rps <= 0.0 {
        bail!("--replica-rps must be > 0");
    }
    let window_us = (args.f64_or("scale-window-ms", 2.0)? * 1e3) as u64;
    if window_us == 0 {
        bail!("--scale-window-ms must be > 0");
    }
    let headroom = args.f64_or("headroom", 0.8)?;
    let mut table = TablePrinter::new(
        &format!(
            "autoscaled {} / {} — <= {max_replicas} replicas @ \
             {replica_rps:.0} rps each, window {window_us} us",
            warm_cfg.traffic.scenario.name(),
            warm_cfg.policy.name(),
        ),
        &[
            "Mode", "FirstVio", "AvgMaxVio", "p99ms", "Done", "SloVio",
            "Scales", "OracleMatch",
        ],
    );
    let mut json_rows = Vec::new();
    for mode in [ScalePolicy::Predictive, ScalePolicy::Reactive] {
        let mut scaler = AutoScaler::new(
            mode, window_us, replica_rps, headroom, 1, max_replicas,
        );
        let out =
            serve::run_autoscaled(warm_cfg, &rcfg, Some(seeds), &mut scaler);
        table.row(vec![
            mode.name().into(),
            format!("{:.4}", out.first_batch_vio),
            format!("{:.4}", out.report.avg_max_vio),
            format!("{:.2}", out.report.p99_ms),
            format!("{}", out.report.completed),
            format!("{}", out.report.slo_violations),
            format!("{}", out.scale_events.len()),
            format!("{:.3}", scaler.oracle_match_rate()),
        ]);
        let mut row = out.report.to_json();
        if let bip_moe::util::Json::Obj(map) = &mut row {
            map.insert(
                "mode".into(),
                bip_moe::util::Json::Str(mode.name().into()),
            );
            map.insert(
                "first_batch_vio".into(),
                bip_moe::util::Json::Num(out.first_batch_vio),
            );
            map.insert(
                "scale_events".into(),
                bip_moe::util::Json::Num(out.scale_events.len() as f64),
            );
            map.insert(
                "oracle_match".into(),
                bip_moe::util::Json::Num(scaler.oracle_match_rate()),
            );
        }
        json_rows.push(row);
    }
    table.print();
    if let Some(path) = args.get("json") {
        let doc = bip_moe::util::Json::obj(vec![
            ("version", bip_moe::util::Json::Str(bip_moe::VERSION.into())),
            ("results", bip_moe::util::Json::Arr(json_rows)),
        ]);
        std::fs::write(path, format!("{doc}\n"))?;
        println!("report: {path}");
    }
    Ok(())
}

/// Live metrics surface: drive one serving run on a background thread
/// while the foreground attaches to the in-process global registry and
/// prints periodic counter deltas (`--watch` renders each tick as a
/// summary table instead); plus the CI mode `metrics check --snapshot`
/// asserting a written snapshot parses and its core series moved.
fn cmd_metrics(args: &Args) -> Result<()> {
    args.check_known(&[
        // serve-pipeline knobs (shared with `serve` / `trace record`)
        "scenario", "policy", "requests", "rate", "m", "k", "layers",
        "tenants", "t", "solver-tol", "solver-t-max", "buckets",
        "batch", "queue", "max-wait-us", "slo-ms", "capacity-factor",
        "devices", "placement", "lpt-refresh", "seed", "replicas",
        "threads", "sync-every",
        // metrics-specific
        "interval-ms", "watch", "out", "snapshot",
    ])
    .map_err(anyhow::Error::msg)?;
    match args.positional.first().map(String::as_str) {
        Some("check") => cmd_metrics_check(args),
        None => cmd_metrics_attach(args),
        Some(other) => bail!("unknown metrics action {other}; see --help"),
    }
}

fn cmd_metrics_attach(args: &Args) -> Result<()> {
    let scenario_arg = args.str_or("scenario", "steady");
    let scenario = Scenario::parse(&scenario_arg)
        .ok_or_else(|| scenario_err(&scenario_arg))?;
    if scenario == Scenario::Replayed {
        bail!("metrics needs a generative scenario to drive");
    }
    let policy_arg = args.str_or("policy", "online");
    let policy = Policy::parse(&policy_arg)
        .ok_or_else(|| policy_err(&policy_arg))?;
    let ServeKnobs { mut traffic, sched, router, replicas: rknobs } =
        serve_knobs(args, 65_536)?;
    traffic.scenario = scenario;
    let cfg = ServeConfig::new(traffic, sched, router, policy);
    let interval = std::time::Duration::from_millis(
        args.u64_or("interval-ms", 250)?.max(10),
    );
    let watch = args.flag("watch");

    println!(
        "metrics: attached to {} / {} ({} requests, R={}), scraping \
         every {}ms",
        cfg.traffic.scenario.name(),
        cfg.policy.name(),
        cfg.traffic.n_requests,
        rknobs.replicas,
        interval.as_millis(),
    );
    let run_cfg = cfg.clone();
    let handle = std::thread::spawn(move || {
        if rknobs.replicas > 1 || rknobs.threads > 1 {
            serve::run_replicated(&run_cfg, &rknobs).report
        } else {
            serve::run_scenario(&run_cfg).report
        }
    });

    let mut prev = telemetry::scrape(telemetry::global());
    while !handle.is_finished() {
        std::thread::sleep(interval);
        let cur = telemetry::scrape(telemetry::global());
        print_metrics_tick(&cur, &prev, watch);
        prev = cur;
    }
    let report = handle
        .join()
        .map_err(|_| anyhow::anyhow!("serve thread panicked"))?;

    let last = telemetry::scrape(telemetry::global());
    print_metrics_summary(&last);
    let mut table = TablePrinter::new(
        &format!("served {} / {}", report.scenario, report.policy),
        ServeReport::headers(),
    );
    table.row(report.table_row());
    table.print();
    if let Some(out) = args.get("out") {
        last.write(Path::new(out))?;
        println!("snapshot: {out}");
    }
    Ok(())
}

fn print_metrics_tick(
    cur: &telemetry::Snapshot,
    prev: &telemetry::Snapshot,
    watch: bool,
) {
    if watch {
        let mut table = TablePrinter::new(
            &format!("metrics @ {:.1}s", cur.elapsed_secs),
            &["Series", "Total", "Delta"],
        );
        let mut moved = false;
        for c in telemetry::Counter::ALL {
            let d = cur.counter(c).saturating_sub(prev.counter(c));
            if d > 0 {
                moved = true;
                table.row(vec![
                    c.name().into(),
                    cur.counter(c).to_string(),
                    format!("+{d}"),
                ]);
            }
        }
        if moved {
            table.print();
        } else {
            println!("[{:.1}s] (idle)", cur.elapsed_secs);
        }
    } else {
        let deltas = cur.counter_deltas(prev);
        if deltas.is_empty() {
            println!("[{:.1}s] (idle)", cur.elapsed_secs);
        } else {
            let line = deltas
                .iter()
                .map(|(n, d)| format!("{n} +{d}"))
                .collect::<Vec<_>>()
                .join("  ");
            println!("[{:.1}s] {line}", cur.elapsed_secs);
        }
    }
}

fn print_metrics_summary(snap: &telemetry::Snapshot) {
    let mut table = TablePrinter::new(
        &format!("metrics summary @ {:.1}s", snap.elapsed_secs),
        &["Series", "Value", "p50", "p99"],
    );
    for c in telemetry::Counter::ALL {
        let v = snap.counter(c);
        if v > 0 {
            table.row(vec![
                c.name().into(),
                v.to_string(),
                "-".into(),
                "-".into(),
            ]);
        }
    }
    for g in telemetry::Gauge::ALL {
        let v = snap.gauge(g);
        if v != 0.0 {
            table.row(vec![
                g.name().into(),
                format!("{v:.4}"),
                "-".into(),
                "-".into(),
            ]);
        }
    }
    for h in &snap.hists {
        if h.count() > 0 {
            table.row(vec![
                h.name.into(),
                format!("n={} mean={:.3e}", h.count(), h.mean()),
                format!("{:.3e}", h.quantile(0.5)),
                format!("{:.3e}", h.quantile(0.99)),
            ]);
        }
    }
    table.print();
}

/// The CI smoke gate: a serve run wrote `--metrics-out`; assert the
/// snapshot parses and the core series are present and actually moved.
fn cmd_metrics_check(args: &Args) -> Result<()> {
    let path = args
        .get("snapshot")
        .ok_or_else(|| anyhow::anyhow!("--snapshot PATH required"))?;
    let body = std::fs::read_to_string(path)?;
    let doc = bip_moe::util::Json::parse(&body).map_err(|e| {
        anyhow::anyhow!("metrics snapshot {path} does not parse: {e}")
    })?;
    let fmt = doc.path("format").and_then(|j| j.as_str());
    if fmt != Some(telemetry::SNAPSHOT_FORMAT) {
        bail!(
            "snapshot {path} has format {fmt:?}, wanted {:?}",
            telemetry::SNAPSHOT_FORMAT
        );
    }
    let version =
        doc.path("version").and_then(|j| j.as_f64()).unwrap_or(0.0);
    if version < 1.0 {
        bail!("snapshot {path} reports version {version}");
    }
    let core = [
        "counters.router_batches_total",
        "counters.router_tokens_total",
        "counters.solver_solves_total",
        "histograms.route_batch_seconds.count",
        "gauges.router_experts",
        // the causal event ring rides every routed batch, so a live
        // serve snapshot must show it recording and occupied
        "counters.obs_events_total",
        "gauges.obs_event_ring_occupancy",
        // the hierarchical profiler is on by default, so every routed
        // batch must also record call-path frames
        "counters.prof_frames_total",
    ];
    let mut failures = Vec::new();
    for series in core {
        match doc.path(series).and_then(|j| j.as_f64()) {
            Some(v) if v > 0.0 => println!("  ok   {series} = {v}"),
            Some(v) => {
                failures.push(format!("{series} = {v} (must be > 0)"))
            }
            None => failures.push(format!("{series} missing")),
        }
    }
    // alert/incident volume depends on the scenario — these only have
    // to exist (zero is the healthy steady-state)
    let present = [
        "counters.obs_alerts_total",
        "counters.obs_incidents_total",
        // healthy runs never overflow the profiler's frame stack
        "counters.prof_stack_overflow_total",
    ];
    for series in present {
        match doc.path(series).and_then(|j| j.as_f64()) {
            Some(v) => println!("  ok   {series} = {v} (present)"),
            None => failures.push(format!("{series} missing")),
        }
    }
    if !failures.is_empty() {
        for f in &failures {
            eprintln!("  FAIL {f}");
        }
        bail!(
            "metrics snapshot {path} failed {} core-series check(s)",
            failures.len()
        );
    }
    println!(
        "metrics snapshot {path}: core series present and live \
         (v{version}, {:.1}s elapsed)",
        doc.path("elapsed_secs").and_then(|j| j.as_f64()).unwrap_or(0.0)
    );
    Ok(())
}

/// Hierarchical profiler surface: capture a `PROF_*.json` call-path
/// record from a serve / train / router-microloop run, or diff two
/// captures to attribute a throughput delta to the guilty phase.
fn cmd_profile(args: &Args) -> Result<()> {
    args.check_known(&[
        // serve-pipeline knobs (shared with `serve` / `trace record`)
        "scenario", "policy", "requests", "rate", "m", "k", "layers",
        "tenants", "t", "solver-tol", "solver-t-max", "buckets",
        "batch", "queue", "max-wait-us", "slo-ms", "capacity-factor",
        "devices", "placement", "lpt-refresh", "seed", "replicas",
        "threads", "sync-every",
        // train knobs (profile train, shared with `train`)
        "config", "mode", "bip-t", "steps", "eval-batches", "reports",
        "save", "artifacts", "sim-devices", "data-seed",
        "warm-start-trace",
        // profile-specific
        "name", "out", "folded", "html", "batches", "top",
        "assert-zero",
    ])
    .map_err(anyhow::Error::msg)?;
    match args.positional.first().map(String::as_str) {
        Some("serve") => cmd_profile_serve(args),
        Some("train") => cmd_profile_train(args),
        Some("bench") => cmd_profile_bench(args),
        Some("diff") => cmd_profile_diff(args),
        Some(other) => {
            bail!("unknown profile action {other}; see --help")
        }
        None => bail!(
            "usage: bip-moe profile <serve|train|bench|diff> \
             [--options]"
        ),
    }
}

/// Shared tail of `profile serve|train|bench`: print the call-path
/// table, write the versioned `PROF_<name>.json` record, and honor the
/// optional `--out` / `--folded` / `--html` export knobs.
fn emit_profile(
    args: &Args,
    name: &str,
    profile: &prof::Profile,
    wall: std::time::Duration,
) -> Result<()> {
    let mut table = TablePrinter::new(
        &format!(
            "profile {name} — {} call paths, {:.1} ms root inclusive \
             ({:.1} ms wall)",
            profile.paths.len(),
            profile.root_inclusive_ns() as f64 / 1e6,
            wall.as_secs_f64() * 1e3,
        ),
        &["call path", "calls", "incl ms", "excl ms", "allocs"],
    );
    for p in &profile.paths {
        table.row(vec![
            p.path.clone(),
            p.calls.to_string(),
            format!("{:.3}", p.inclusive_ns as f64 / 1e6),
            format!("{:.3}", p.exclusive_ns as f64 / 1e6),
            p.allocs.to_string(),
        ]);
    }
    table.print();
    let report = prof::write_prof_json(name, profile)?;
    println!("profile: {}", report.display());
    if let Some(path) = args.get("out") {
        profile.write(Path::new(path))?;
        println!("json: {path}");
    }
    if let Some(path) = args.get("folded") {
        std::fs::write(path, profile.folded())?;
        println!("folded: {path}");
    }
    if let Some(path) = args.get("html") {
        std::fs::write(path, profile.html(&format!("bip-moe {name}")))?;
        println!("flamegraph: {path}");
    }
    Ok(())
}

/// One profiled serving run (single scenario + policy, no sweep).
fn cmd_profile_serve(args: &Args) -> Result<()> {
    let scenario_arg = args.str_or("scenario", "steady");
    let scenario = Scenario::parse(&scenario_arg)
        .ok_or_else(|| scenario_err(&scenario_arg))?;
    if scenario == Scenario::Replayed {
        bail!("profile serve needs a generative scenario to drive");
    }
    let policy_arg = args.str_or("policy", "bip");
    let policy = Policy::parse(&policy_arg)
        .ok_or_else(|| policy_err(&policy_arg))?;
    let ServeKnobs { mut traffic, sched, router, replicas: rknobs } =
        serve_knobs(args, 8192)?;
    traffic.scenario = scenario;
    let cfg = ServeConfig::new(traffic, sched, router, policy);

    prof::reset();
    let t0 = std::time::Instant::now();
    let report = if rknobs.replicas > 1 || rknobs.threads > 1 {
        serve::run_replicated(&cfg, &rknobs).report
    } else {
        serve::run_scenario(&cfg).report
    };
    let wall = t0.elapsed();
    let profile = prof::Profile::scrape();
    let mut table = TablePrinter::new(
        &format!("profiled {} / {}", report.scenario, report.policy),
        ServeReport::headers(),
    );
    table.row(report.table_row());
    table.print();
    emit_profile(args, &args.str_or("name", "serve"), &profile, wall)
}

/// One profiled training run (same knobs as `train`).
fn cmd_profile_train(args: &Args) -> Result<()> {
    let engine = Engine::new(&artifacts_dir(args))?;
    let mut driver = TrainDriver::new(
        &args.str_or("config", "tiny"),
        &args.str_or("mode", "bip"),
        args.usize_or("bip-t", 4)?,
        args.u64_or("steps", 50)?,
    );
    driver.seed = args.usize_or("seed", 0)? as i32;
    driver.eval_batches = args.u64_or("eval-batches", 8)?;
    driver.sim_devices = args.usize_or("sim-devices", 4)?;
    driver.data_seed = args.u64_or("data-seed", 20240601)?;
    driver.warm_start_trace =
        args.get("warm-start-trace").map(PathBuf::from);

    prof::reset();
    let t0 = std::time::Instant::now();
    let outcome = driver.run(&engine)?;
    let wall = t0.elapsed();
    let profile = prof::Profile::scrape();
    let mut table = TablePrinter::new(
        &format!("profiled run {}", driver.run_label()),
        &["Algorithm", "AvgMaxVio", "SupMaxVio", "Perplexity",
          "SimHours(run)"],
    );
    table.row(outcome.table_row(&driver.run_label()));
    table.print();
    emit_profile(args, &args.str_or("name", "train"), &profile, wall)
}

/// Profiled `route_batch_into` microloop: the router hot path alone,
/// no event loop or queueing around it (the profiler's counterpart of
/// the bench_hotpath steady-state sections).
fn cmd_profile_bench(args: &Args) -> Result<()> {
    let policy_arg = args.str_or("policy", "bip");
    let policy = Policy::parse(&policy_arg)
        .ok_or_else(|| policy_err(&policy_arg))?;
    let ServeKnobs { traffic, sched, router: rcfg, .. } =
        serve_knobs(args, 256)?;
    let batches = args.usize_or("batches", 256)?.max(1);
    let requests: Vec<_> = TrafficGenerator::new(traffic).collect();
    if requests.is_empty() {
        bail!("--requests must be >= 1");
    }
    let mut router = ServingRouter::new(policy, rcfg);
    let mut out = bip_moe::serve::BatchOutcome::default();
    let batch_max = sched.batch_max.min(requests.len()).max(1);

    // warm the arenas outside the profiled window, like the perf gate
    for chunk in requests.chunks(batch_max).take(8) {
        router.route_batch_into(chunk, &mut out);
    }
    prof::reset();
    let t0 = std::time::Instant::now();
    let mut done = 0;
    'outer: loop {
        for chunk in requests.chunks(batch_max) {
            if done >= batches {
                break 'outer;
            }
            // the event loop normally owns this frame; the microloop
            // enters it so paths keep their serve-shaped root
            let _prof = prof::ProfGuard::enter(prof::Frame::Dispatch);
            router.route_batch_into(chunk, &mut out);
            done += 1;
        }
    }
    let wall = t0.elapsed();
    let profile = prof::Profile::scrape();
    println!(
        "bench: {done} batches of <= {batch_max} requests, policy {}",
        policy.name()
    );
    emit_profile(args, &args.str_or("name", "bench"), &profile, wall)
}

/// Attribute a perf delta: align two `PROF_*.json` captures on call
/// path and rank by exclusive-ns regression.
fn cmd_profile_diff(args: &Args) -> Result<()> {
    let (prev_path, cur_path) =
        match (args.positional.get(1), args.positional.get(2)) {
            (Some(a), Some(b)) => (a, b),
            _ => bail!(
                "usage: bip-moe profile diff PREV.json CUR.json \
                 [--top N] [--assert-zero]"
            ),
        };
    let prev = prof::Profile::load(Path::new(prev_path))?;
    let cur = prof::Profile::load(Path::new(cur_path))?;
    let rows = prof::diff(&prev, &cur);
    let top = args.usize_or("top", 0)?;
    let shown = if top > 0 && top < rows.len() {
        &rows[..top]
    } else {
        &rows[..]
    };
    prof::render_table(
        &format!("profile diff — {prev_path} -> {cur_path}"),
        shown,
    )
    .print();
    let nonzero = rows
        .iter()
        .filter(|r| {
            r.delta_excl_ns != 0 || r.prev_calls != r.cur_calls
        })
        .count();
    if args.flag("assert-zero") && nonzero > 0 {
        bail!(
            "{nonzero} call path(s) differ between {prev_path} and \
             {cur_path} (wanted an identical profile)"
        );
    }
    Ok(())
}

/// Live dashboard: drive one serving run on a background thread, and
/// each interval scrape the global registry, run one anomaly-detector
/// tick, and render the `obs::TopState` frame (heat rows, MaxVio
/// sparkline, collapse score, alert feed).
fn cmd_top(args: &Args) -> Result<()> {
    args.check_known(&[
        // serve-pipeline knobs (shared with `serve` / `metrics`)
        "scenario", "policy", "requests", "rate", "m", "k", "layers",
        "tenants", "t", "solver-tol", "solver-t-max", "buckets",
        "batch", "queue", "max-wait-us", "slo-ms", "capacity-factor",
        "devices", "placement", "lpt-refresh", "seed", "replicas",
        "threads", "sync-every",
        // top-specific
        "interval-ms", "plain",
    ])
    .map_err(anyhow::Error::msg)?;
    let scenario_arg = args.str_or("scenario", "steady");
    let scenario = Scenario::parse(&scenario_arg)
        .ok_or_else(|| scenario_err(&scenario_arg))?;
    if scenario == Scenario::Replayed {
        bail!("top needs a generative scenario to drive");
    }
    let policy_arg = args.str_or("policy", "online");
    let policy = Policy::parse(&policy_arg)
        .ok_or_else(|| policy_err(&policy_arg))?;
    let ServeKnobs { mut traffic, sched, router, replicas: rknobs } =
        serve_knobs(args, 65_536)?;
    traffic.scenario = scenario;
    let cfg = ServeConfig::new(traffic, sched, router, policy);
    let interval = std::time::Duration::from_millis(
        args.u64_or("interval-ms", 250)?.max(10),
    );
    let plain = args.flag("plain");

    let run_cfg = cfg.clone();
    let handle = std::thread::spawn(move || {
        if rknobs.replicas > 1 || rknobs.threads > 1 {
            serve::run_replicated(&run_cfg, &rknobs).report
        } else {
            serve::run_scenario(&run_cfg).report
        }
    });

    let mut detector = Detector::new(DetectorConfig::default());
    let mut state = TopState::new();
    while !handle.is_finished() {
        std::thread::sleep(interval);
        let snap = telemetry::scrape(telemetry::global());
        let alerts = detector.tick(&snap);
        state.update(&snap, &alerts);
        print!("{}", state.render(&snap, plain));
    }
    let report = handle
        .join()
        .map_err(|_| anyhow::anyhow!("serve thread panicked"))?;

    // final frame always in plain mode, so the run's last state stays
    // in the scrollback instead of being cleared away
    let snap = telemetry::scrape(telemetry::global());
    let alerts = detector.tick(&snap);
    state.update(&snap, &alerts);
    print!("{}", state.render(&snap, true));
    println!(
        "done: {} / {} — {} detector tick(s), {} alert(s)",
        report.scenario,
        report.policy,
        detector.ticks(),
        detector.total_alerts,
    );
    Ok(())
}

/// Inspect / export "BIPI" incident flight-recorder dumps.
fn cmd_incidents(args: &Args) -> Result<()> {
    args.check_known(&["file", "out", "events"])
        .map_err(anyhow::Error::msg)?;
    match args.positional.first().map(String::as_str) {
        Some("inspect") => cmd_incidents_inspect(args),
        Some("export") => cmd_incidents_export(args),
        Some(other) => {
            bail!("unknown incidents action {other}; see --help")
        }
        None => {
            bail!("usage: bip-moe incidents <inspect|export> --file P")
        }
    }
}

fn incident_arg(args: &Args) -> Result<(PathBuf, Incident)> {
    let path = PathBuf::from(
        args.get("file")
            .ok_or_else(|| anyhow::anyhow!("--file PATH required"))?,
    );
    let inc = Incident::load(&path)?;
    Ok((path, inc))
}

fn solver_mode_name(mode: u8) -> &'static str {
    match mode {
        0 => "fixed-serial",
        1 => "fixed-parallel",
        2 => "adaptive-serial",
        3 => "adaptive-parallel",
        _ => "unknown",
    }
}

fn cmd_incidents_inspect(args: &Args) -> Result<()> {
    let (path, inc) = incident_arg(args)?;
    let h = &inc.header;
    println!("incident {}", path.display());
    println!(
        "  {} / {} (crate {}), v{}",
        h.scenario, h.policy, h.crate_version, h.version
    );
    println!(
        "  trigger: {} at tick {} — {} (value {:.4}, threshold {:.4})",
        h.trigger.name(),
        h.tick,
        h.reason,
        h.value,
        h.threshold
    );
    if !h.trace_path.is_empty() {
        println!("  trace:   {} (replay link)", h.trace_path);
    }
    println!(
        "  {} event(s), {} scrape(s), {} alert(s)",
        inc.events.len(),
        inc.scrapes.len(),
        inc.alerts.len()
    );

    if !inc.alerts.is_empty() {
        println!("alerts:");
        for a in &inc.alerts {
            println!(
                "  [t{:>4}] {:<16} L{:<2} score {:.3} value {:.3} — {}",
                a.tick,
                a.kind.name(),
                a.layer,
                a.score,
                a.value,
                a.detail
            );
        }
    }

    if let Some((tick, series)) = inc.scrapes.last() {
        println!("last scrape (tick {tick}):");
        for (name, value) in series {
            if *value != 0.0 {
                println!("  {name:<32} {value:.4}");
            }
        }
    }

    print_causal_chain(&inc);

    if let Some(n) = args.get("events") {
        let n: usize = n.parse().unwrap_or(16);
        println!("last {} event(s):", n.min(inc.events.len()));
        let skip = inc.events.len().saturating_sub(n);
        for e in &inc.events[skip..] {
            println!(
                "  #{:<6} {:<12} L{:<2} R{:<2} id {:<8} payload {:#x}",
                e.seq,
                e.kind.name(),
                e.layer,
                e.replica,
                e.id,
                e.payload
            );
        }
    }
    Ok(())
}

/// Walk the last routed batch in the dump back through its causal
/// chain: BatchDone -> BatchStart (first request, size) -> per-layer
/// LayerRoute / SolverExit / DualExit -> replica Dispatch. Everything
/// keys on the batch ordinal the event ring stamped into `id`.
fn print_causal_chain(inc: &Incident) {
    let Some(done) = inc
        .events
        .iter()
        .rev()
        .find(|e| e.kind == EventKind::BatchDone)
    else {
        println!("causal chain: no completed batch in the event ring");
        return;
    };
    let batch = done.id;
    println!(
        "causal chain for batch {batch} (replica {}):",
        done.replica
    );
    for e in inc.events.iter().filter(|e| e.id == batch) {
        match e.kind {
            EventKind::BatchStart => {
                let (first_req, n_tokens) =
                    event::batch_start_fields(e.payload);
                println!(
                    "  batch start    first request {first_req}, \
                     {n_tokens} token(s)"
                );
            }
            EventKind::LayerRoute => {
                println!("  layer {:<2} route", e.layer);
            }
            EventKind::SolverExit => {
                let (mode, capped, iters) =
                    event::solver_exit_fields(e.payload);
                println!(
                    "  layer {:<2} solver {} — {} iteration(s){}",
                    e.layer,
                    solver_mode_name(mode),
                    iters,
                    if capped { " (hit the cap)" } else { "" }
                );
            }
            EventKind::DualExit => {
                let (reason, iters) =
                    event::dual_exit_fields(e.payload);
                println!(
                    "  layer {:<2} dual ascent exit: {} after {} \
                     iteration(s)",
                    e.layer,
                    event::dual_exit_reason_name(reason),
                    iters
                );
            }
            EventKind::Dispatch => {
                println!(
                    "  dispatch       replica {} served in {}us",
                    e.replica, e.payload
                );
            }
            EventKind::BatchDone => {
                println!(
                    "  batch done     MaxVio {:.4}",
                    f64::from_bits(e.payload)
                );
            }
            _ => {}
        }
    }
}

fn cmd_incidents_export(args: &Args) -> Result<()> {
    let (path, inc) = incident_arg(args)?;
    let out = args.get("out").map(PathBuf::from).unwrap_or_else(|| {
        let mut p = path.clone().into_os_string();
        p.push(".json");
        PathBuf::from(p)
    });
    std::fs::write(&out, inc.to_json().to_string())?;
    println!(
        "exported {} ({} events, {} scrapes, {} alerts) -> {}",
        path.display(),
        inc.events.len(),
        inc.scrapes.len(),
        inc.alerts.len(),
        out.display()
    );
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    args.check_known(&["deny", "json", "filter", "root"])
        .map_err(anyhow::Error::msg)?;
    let root = args.str_or("root", env!("CARGO_MANIFEST_DIR"));
    let set = bip_moe::analysis::SourceSet::from_root(Path::new(&root))?;
    let findings = bip_moe::analysis::run(&set, args.get("filter"));
    print!("{}", bip_moe::analysis::render_text(&findings));
    if let Some(out) = args.get("json") {
        if let Some(dir) = Path::new(out).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(
            out,
            bip_moe::analysis::render_json(&findings).to_string(),
        )?;
        println!("wrote {out}");
    }
    if args.flag("deny") && !findings.is_empty() {
        bail!(
            "lint --deny: {} finding(s) over {} files",
            findings.len(),
            set.files.len()
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.check_known(&["artifacts"]).map_err(anyhow::Error::msg)?;
    let engine = Engine::new(&artifacts_dir(args))?;
    println!("platform: {}", engine.platform());
    println!("fingerprint: {}", engine.manifest().fingerprint);
    let mut table = TablePrinter::new(
        "configs",
        &["name", "theta", "layers", "experts", "top-k", "seq", "batch"],
    );
    for (name, c) in &engine.manifest().configs {
        table.row(vec![
            name.clone(),
            c.theta_size.to_string(),
            c.n_layers.to_string(),
            c.n_experts.to_string(),
            c.top_k.to_string(),
            c.seq_len.to_string(),
            c.batch_size.to_string(),
        ]);
    }
    table.print();
    println!("{} artifacts:", engine.manifest().artifacts.len());
    for a in &engine.manifest().artifacts {
        println!(
            "  {:<44} {:>6} {:>9} T={:?}",
            a.file, a.kind, a.mode, a.bip_t
        );
    }
    Ok(())
}
