//! Device mesh + expert placement for the expert-parallel simulator.

/// `n_devices` accelerators, experts block-placed: expert j lives on
/// device j / (m / n_devices).
#[derive(Clone, Debug)]
pub struct Mesh {
    pub n_devices: usize,
    pub n_experts: usize,
}

impl Mesh {
    pub fn new(n_devices: usize, n_experts: usize) -> Mesh {
        assert!(n_experts % n_devices == 0,
                "experts {n_experts} must divide over devices {n_devices}");
        Mesh { n_devices, n_experts }
    }

    pub fn experts_per_device(&self) -> usize {
        self.n_experts / self.n_devices
    }

    pub fn device_of(&self, expert: usize) -> usize {
        expert / self.experts_per_device()
    }

    /// Sum the per-expert loads into per-device loads.
    pub fn device_loads(&self, expert_loads: &[f32]) -> Vec<f64> {
        assert_eq!(expert_loads.len(), self.n_experts);
        let mut out = vec![0.0f64; self.n_devices];
        for (j, &l) in expert_loads.iter().enumerate() {
            out[self.device_of(j)] += l as f64;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_placement() {
        let mesh = Mesh::new(4, 16);
        assert_eq!(mesh.experts_per_device(), 4);
        assert_eq!(mesh.device_of(0), 0);
        assert_eq!(mesh.device_of(3), 0);
        assert_eq!(mesh.device_of(4), 1);
        assert_eq!(mesh.device_of(15), 3);
    }

    #[test]
    fn device_loads_sum() {
        let mesh = Mesh::new(2, 4);
        let loads = [1.0f32, 2.0, 3.0, 4.0];
        assert_eq!(mesh.device_loads(&loads), vec![3.0, 7.0]);
    }

    #[test]
    #[should_panic]
    fn indivisible_experts_rejected() {
        Mesh::new(3, 16);
    }
}
