//! All-to-all communication cost for expert-parallel dispatch/combine.
//!
//! Token dispatch sends each routed token from its source device to the
//! device hosting the chosen expert, then the combine sends activations
//! back. With tokens uniformly sourced across devices (data parallel over
//! the same batch), device d must RECEIVE all tokens routed to its local
//! experts — so an overloaded expert congests its host's ingress link and
//! the all-to-all completes only when the hottest link drains. That is
//! the communication face of the straggler effect.

use super::topology::Mesh;

#[derive(Clone, Copy, Debug)]
pub struct LinkProfile {
    /// per-device ingress/egress bandwidth, bytes/s (NVLink-ish default)
    pub bandwidth: f64,
    /// per-hop latency, seconds
    pub latency: f64,
}

impl Default for LinkProfile {
    fn default() -> Self {
        LinkProfile { bandwidth: 150e9, latency: 5e-6 }
    }
}

/// Seconds for one all-to-all over the given per-expert token loads.
/// `bytes_per_token` = hidden dim * dtype bytes.
pub fn all_to_all_time(
    mesh: &Mesh,
    expert_loads: &[f32],
    bytes_per_token: f64,
    link: &LinkProfile,
) -> f64 {
    let total_tokens: f64 =
        expert_loads.iter().map(|&l| l as f64).sum();
    let device_recv = mesh.device_loads(expert_loads);
    // each device sources total/E tokens (egress is balanced), ingress is
    // load-dependent; the collective finishes when the hottest direction
    // of the hottest device drains.
    let egress = total_tokens / mesh.n_devices as f64;
    let hottest = device_recv
        .iter()
        .cloned()
        .fold(0.0f64, f64::max)
        .max(egress);
    // tokens that stay local (1/E of a device's traffic on average) skip
    // the wire
    let cross_frac = 1.0 - 1.0 / mesh.n_devices as f64;
    hottest * cross_frac * bytes_per_token / link.bandwidth
        + link.latency * (mesh.n_devices as f64 - 1.0).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mesh() -> Mesh {
        Mesh::new(4, 16)
    }

    #[test]
    fn balanced_loads_give_baseline_time() {
        let loads = [64.0f32; 16]; // 1024 routed tokens, 256/device
        let t = all_to_all_time(&mesh(), &loads, 1024.0,
                                &LinkProfile::default());
        let link = LinkProfile::default();
        let expect = 256.0 * 0.75 * 1024.0 / link.bandwidth
            + link.latency * 3.0;
        assert!((t - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn hot_expert_congests_its_host() {
        // paper-scale payloads (64 KiB/token keeps the bandwidth term
        // dominant over per-hop latency, as in a real a2a of activations)
        let mut loads = [32.0f32; 16];
        loads[0] = 512.0; // device 0 ingress explodes
        let t_hot = all_to_all_time(&mesh(), &loads, 65536.0,
                                    &LinkProfile::default());
        let t_cold = all_to_all_time(&mesh(), &[64.0f32; 16], 65536.0,
                                     &LinkProfile::default());
        assert!(t_hot > 1.8 * t_cold, "hot {t_hot} cold {t_cold}");
    }

    #[test]
    fn single_device_pays_only_latency_free_local_copy() {
        let m = Mesh::new(1, 16);
        let t = all_to_all_time(&m, &[64.0f32; 16], 1024.0,
                                &LinkProfile::default());
        assert_eq!(t, 0.0); // no cross traffic, no hops
    }

    #[test]
    fn monotone_in_max_load() {
        let link = LinkProfile::default();
        let mut prev = 0.0;
        for hot in [64.0f32, 128.0, 256.0, 512.0] {
            let mut loads = [64.0f32; 16];
            loads[5] = hot;
            let t = all_to_all_time(&mesh(), &loads, 512.0, &link);
            assert!(t >= prev);
            prev = t;
        }
    }
}
