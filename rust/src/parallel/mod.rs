//! Expert-parallel cluster simulator.
//!
//! The paper's training-time savings (Tables 2-3: BIP saves >= 13% vs
//! Loss-Controlled) come from one mechanism: in expert-parallel execution
//! every device must wait for the device hosting the most-loaded expert,
//! so step time grows with max-load, i.e. with (1 + MaxVio). We cannot
//! measure that on this single-CPU testbed, so we *simulate* the cluster:
//! the simulator consumes the real per-batch per-layer load vectors
//! produced by training and computes step times under a calibrated device
//! profile (see [`cost_model`]). DESIGN.md §Substitutions documents the
//! mapping; the tests pin the model's invariants (monotone in imbalance,
//! exact for perfect balance, additive across layers).

pub mod collective;
pub mod cost_model;
pub mod pipeline;
pub mod placement;
pub mod topology;

pub use cost_model::{ClusterSim, DeviceProfile, ModelCost, ServeCost};
pub use pipeline::{pipeline_makespan, Schedule};
pub use topology::Mesh;
