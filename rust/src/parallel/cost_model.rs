//! Step-time cost model for expert-parallel MoE training.
//!
//! For every training step the simulator receives the measured per-layer
//! per-expert load vector (from the PJRT train step) and computes the
//! wall time that step would have taken on an expert-parallel cluster:
//!
//!   t_step = Σ_layers [ t_attn + t_a2a(loads) * 2          (dispatch+combine)
//!                       + straggler(loads) * t_ffn_token * B ]
//!            * (1 + bwd_ratio)  +  t_fixed
//!
//!   straggler(loads) = max_device Σ_{its experts} load   (tokens)
//!
//! Aux-loss methods add `aux_overhead` (extra loss + grad traffic).
//! Device profiles bundle the calibrated constants; `rtx4090()` and
//! `l20()` approximate the paper's testbeds (Table 1).

use super::collective::{all_to_all_time, LinkProfile};
use super::placement::Placement;
use super::topology::Mesh;

/// Accelerator + link constants. The absolute numbers are vendor-sheet
/// scale (not measured); the *ratios* between methods — which is what the
/// paper's Tables 2-3 compare — depend only on the load vectors.
#[derive(Clone, Copy, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// sustained bf16 FLOP/s per device
    pub flops: f64,
    pub link: LinkProfile,
    /// fixed per-step overhead (optimizer, host sync), seconds
    pub fixed_overhead: f64,
    /// backward/forward cost ratio
    pub bwd_ratio: f64,
}

impl DeviceProfile {
    pub fn rtx4090() -> Self {
        DeviceProfile {
            name: "rtx4090",
            flops: 8.0e13,
            link: LinkProfile { bandwidth: 25e9, latency: 10e-6 },
            fixed_overhead: 3e-3,
            bwd_ratio: 2.0,
        }
    }

    pub fn l20() -> Self {
        DeviceProfile {
            name: "l20",
            flops: 1.0e14,
            link: LinkProfile { bandwidth: 50e9, latency: 8e-6 },
            fixed_overhead: 3e-3,
            bwd_ratio: 2.0,
        }
    }
}

/// Per-token FLOP/byte costs derived from a model configuration.
#[derive(Clone, Copy, Debug)]
pub struct ModelCost {
    /// FLOPs for one token through one expert's FFN (fwd)
    pub ffn_flops_per_token: f64,
    /// FLOPs for one token of attention+norms per layer (fwd, balanced)
    pub attn_flops_per_token: f64,
    /// activation bytes shipped per routed token in each all-to-all
    pub bytes_per_token: f64,
    /// extra fraction of step time for the auxiliary-loss method
    pub aux_overhead: f64,
}

impl ModelCost {
    /// Costs from transformer dimensions (SwiGLU expert: 3 matmuls).
    pub fn from_dims(d_model: usize, d_ff: usize, seq_len: usize) -> Self {
        let d = d_model as f64;
        let f = d_ff as f64;
        ModelCost {
            ffn_flops_per_token: 2.0 * 3.0 * d * f,
            // qkv/o projections + scores: 8 d^2 + 4 d s
            attn_flops_per_token: 8.0 * d * d + 4.0 * d * seq_len as f64,
            bytes_per_token: 2.0 * d, // bf16 activations
            aux_overhead: 0.13,
        }
    }

    /// Paper-scale presets (Table 1): 0.3B/16-expert and 1.1B/64-expert
    /// Minimind-MoE. Dimensions approximated from the released configs.
    pub fn paper_16e() -> Self {
        Self::from_dims(512, 1408, 512)
    }

    pub fn paper_64e() -> Self {
        Self::from_dims(640, 1408, 512)
    }
}

/// Tokens per batch in the paper's training setup (Table 1: max seq 8192;
/// a realistic global batch of 32 sequences). Bench-scale load vectors are
/// rescaled to this volume so simulated hours land on the paper's scale —
/// the rescale is uniform across methods, so ratios are unaffected.
pub const PAPER_TOKENS_PER_BATCH: f64 = 32.0 * 8192.0;

/// The simulator itself: accumulate per-step times for a whole run.
#[derive(Clone, Debug)]
pub struct ClusterSim {
    pub mesh: Mesh,
    pub profile: DeviceProfile,
    pub cost: ModelCost,
    pub aux_method: bool,
    /// uniform load multiplier (paper batch volume / measured volume)
    pub token_scale: f64,
    pub total_seconds: f64,
    pub steps: u64,
    /// cached block placement of `mesh` — step_time is the per-step hot
    /// path and must not rebuild it per call
    block_placement: Placement,
}

impl ClusterSim {
    pub fn new(
        mesh: Mesh,
        profile: DeviceProfile,
        cost: ModelCost,
        aux_method: bool,
    ) -> Self {
        let block_placement = Placement::block(&mesh);
        ClusterSim { mesh, profile, cost, aux_method, token_scale: 1.0,
                     total_seconds: 0.0, steps: 0, block_placement }
    }

    /// Rescale measured load vectors to the paper's batch volume
    /// (`measured_tokens` = n_tokens * top_k routed assignments per gate).
    pub fn with_paper_batch(mut self, measured_tokens: usize) -> Self {
        self.token_scale =
            PAPER_TOKENS_PER_BATCH / measured_tokens.max(1) as f64;
        self
    }

    /// Step time from the (n_layers, m) load matrix (row-major).
    pub fn step_time(&self, loads: &[f32], m: usize) -> f64 {
        let scaled: Vec<f32>;
        let loads: &[f32] = if self.token_scale != 1.0 {
            scaled = loads
                .iter()
                .map(|&l| l * self.token_scale as f32)
                .collect();
            &scaled
        } else {
            loads
        };
        let fwd = forward_seconds(
            &self.mesh,
            &self.profile,
            &self.cost,
            &self.block_placement,
            loads,
            m,
        );
        let mut t = fwd * (1.0 + self.profile.bwd_ratio)
            + self.profile.fixed_overhead;
        if self.aux_method {
            t *= 1.0 + self.cost.aux_overhead;
        }
        t
    }

    pub fn push_step(&mut self, loads: &[f32], m: usize) {
        self.total_seconds += self.step_time(loads, m);
        self.steps += 1;
    }

    pub fn total_hours(&self) -> f64 {
        self.total_seconds / 3600.0
    }

    /// Hours extrapolated to `target_steps` at the observed mean step time.
    pub fn extrapolate_hours(&self, target_steps: u64) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.total_hours() * target_steps as f64 / self.steps as f64
    }
}

/// Shared forward-pass cost of one (n_layers, m) load matrix — the one
/// formula both the training simulator and the serving cost model price
/// with, so the two can never drift apart: per layer, balanced attention
/// + expert-FFN straggler (hottest device under `placement`) + two
/// all-to-alls.
fn forward_seconds(
    mesh: &Mesh,
    profile: &DeviceProfile,
    cost: &ModelCost,
    placement: &Placement,
    loads: &[f32],
    m: usize,
) -> f64 {
    assert_eq!(loads.len() % m, 0);
    assert_eq!(placement.n_devices, mesh.n_devices);
    let n_layers = loads.len() / m;
    let mut fwd = 0.0;
    for l in 0..n_layers {
        let layer = &loads[l * m..(l + 1) * m];
        let total_tokens: f64 = layer.iter().map(|&x| x as f64).sum();
        let per_device_tokens = total_tokens / mesh.n_devices as f64;
        // attention: balanced data parallel over devices
        let attn =
            per_device_tokens * cost.attn_flops_per_token / profile.flops;
        // expert FFN: straggler = hottest device's token count
        let straggler = placement
            .device_loads(layer)
            .into_iter()
            .fold(0.0f64, f64::max);
        let ffn = straggler * cost.ffn_flops_per_token / profile.flops;
        let a2a = all_to_all_time(
            mesh, layer, cost.bytes_per_token, &profile.link,
        );
        fwd += attn + ffn + 2.0 * a2a;
    }
    fwd
}

/// Forward-only micro-batch cost for the serving stack (`serve/`).
///
/// Like [`ClusterSim::step_time`] but: no backward pass, a µs-scale fixed
/// overhead (kernel launch + host sync, not an optimizer step), and an
/// *explicit* expert [`Placement`] for the straggler term — the serving
/// router may re-place experts with LPT, which block-`Mesh` cannot
/// express. The all-to-all estimate still uses the mesh topology (link
/// traffic depends on total routed tokens, which placement barely moves).
#[derive(Clone, Debug)]
pub struct ServeCost {
    pub mesh: Mesh,
    pub profile: DeviceProfile,
    pub cost: ModelCost,
    /// per-micro-batch launch/sync overhead, microseconds
    pub fixed_us: f64,
}

impl ServeCost {
    pub fn new(mesh: Mesh, profile: DeviceProfile, cost: ModelCost) -> Self {
        ServeCost { mesh, profile, cost, fixed_us: 50.0 }
    }

    /// Service time in microseconds for one micro-batch, from its
    /// row-major (n_layers, m) routed-load matrix.
    pub fn batch_us(
        &self,
        placement: &Placement,
        loads: &[f32],
        m: usize,
    ) -> f64 {
        forward_seconds(
            &self.mesh, &self.profile, &self.cost, placement, loads, m,
        ) * 1e6
            + self.fixed_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::placement::greedy_placement;

    fn sim(aux: bool) -> ClusterSim {
        ClusterSim::new(
            Mesh::new(4, 16),
            DeviceProfile::rtx4090(),
            ModelCost::paper_16e(),
            aux,
        )
    }

    fn balanced(n_layers: usize, m: usize, per: f32) -> Vec<f32> {
        vec![per; n_layers * m]
    }

    #[test]
    fn perfectly_balanced_is_the_floor() {
        let s = sim(false);
        let bal = s.step_time(&balanced(8, 16, 256.0), 16);
        // move load around while keeping the total: time must not drop
        let mut skew = balanced(8, 16, 256.0);
        skew[0] = 1024.0;
        skew[1] = 0.0;
        skew[2] = 0.0;
        skew[3] = 0.0; // device 0 holds 1024 instead of 1024... same!
        // (experts 0-3 are one device: shifting inside a device is free)
        let t_inside = s.step_time(&skew, 16);
        assert!((t_inside - bal).abs() / bal < 1e-9);
        // but moving across devices costs
        let mut cross = balanced(8, 16, 256.0);
        cross[0] += 512.0;
        cross[15] -= 512.0;
        assert!(s.step_time(&cross, 16) > bal);
    }

    #[test]
    fn step_time_scales_with_maxvio() {
        let s = sim(false);
        let mut prev = 0.0;
        for hot in [256.0f32, 512.0, 1024.0, 2048.0] {
            let mut loads = balanced(8, 16, 256.0);
            for l in 0..8 {
                loads[l * 16] = hot;
            }
            let t = s.step_time(&loads, 16);
            assert!(t > prev);
            prev = t;
        }
    }

    #[test]
    fn aux_method_pays_overhead() {
        let loads = balanced(8, 16, 256.0);
        let t_plain = sim(false).step_time(&loads, 16);
        let t_aux = sim(true).step_time(&loads, 16);
        assert!((t_aux / t_plain - 1.13).abs() < 1e-9);
    }

    #[test]
    fn accumulation_and_extrapolation() {
        let mut s = sim(false);
        let loads = balanced(8, 16, 256.0);
        for _ in 0..10 {
            s.push_step(&loads, 16);
        }
        assert_eq!(s.steps, 10);
        let h10 = s.total_hours();
        assert!((s.extrapolate_hours(100) - 10.0 * h10).abs() < 1e-12);
    }

    #[test]
    fn serve_cost_is_monotone_in_straggler_and_placement_aware() {
        let mesh = Mesh::new(4, 16);
        let sc = ServeCost::new(
            mesh.clone(),
            DeviceProfile::rtx4090(),
            ModelCost::paper_16e(),
        );
        let block = Placement::block(&mesh);
        let bal = vec![16.0f32; 2 * 16];
        let t_bal = sc.batch_us(&block, &bal, 16);
        assert!(t_bal >= sc.fixed_us);

        // pile load onto device 0's experts: slower under block placement
        let mut skew = bal.clone();
        for l in 0..2 {
            for j in 0..4 {
                skew[l * 16 + j] = 48.0;
            }
            for j in 4..16 {
                skew[l * 16 + j] = 16.0 * 4.0 / 12.0;
            }
        }
        let t_skew = sc.batch_us(&block, &skew, 16);
        assert!(t_skew > t_bal, "skew {t_skew} bal {t_bal}");

        // LPT placement of the same loads removes the straggler
        let lpt = greedy_placement(&skew[..16], 4, Some(4));
        let t_lpt = sc.batch_us(&lpt, &skew, 16);
        assert!(t_lpt < t_skew, "lpt {t_lpt} block {t_skew}");
    }

    #[test]
    fn imbalance_cost_ratio_is_plausible() {
        // MaxVio=1 (one expert at 2x mean on every layer) should cost
        // noticeably more than balanced, but less than 2x (attention and
        // the balanced experts amortize it)
        let s = sim(false);
        let bal = s.step_time(&balanced(8, 16, 256.0), 16);
        let mut skew = balanced(8, 16, 256.0);
        for l in 0..8 {
            // expert 0 at 2x mean, removed evenly from the other device
            // groups to keep totals fixed
            skew[l * 16] = 512.0;
            for j in 4..16 {
                skew[l * 16 + j] = 256.0 - 256.0 / 12.0;
            }
        }
        let t = s.step_time(&skew, 16);
        let ratio = t / bal;
        assert!(ratio > 1.05 && ratio < 2.0, "ratio {ratio}");
    }
}
