//! Microbatch pipeline scheduling (GPipe fill-drain and 1F1B) over the
//! simulated cluster — the L3 scheduler a distributed-training deployment
//! of the paper would run when layers are additionally pipeline-sharded.
//!
//! The makespan model treats each stage's per-microbatch time as given
//! (from the cost model) and simulates the dependency graph exactly; the
//! closed-form GPipe bound (M + S - 1) * t_stage for uniform stages is a
//! test oracle.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// GPipe: all forwards, then all backwards (fill-drain bubble).
    GPipe,
    /// 1F1B: steady-state interleave (same makespan for uniform stages,
    /// lower activation memory; modeled here for the ablation bench).
    OneFOneB,
}

/// Exact makespan (seconds) for `n_micro` microbatches over stages with
/// the given forward times; backward time = fwd * bwd_ratio per stage.
pub fn pipeline_makespan(
    stage_fwd: &[f64],
    n_micro: usize,
    bwd_ratio: f64,
    schedule: Schedule,
) -> f64 {
    let s = stage_fwd.len();
    assert!(s > 0 && n_micro > 0);
    match schedule {
        Schedule::GPipe => {
            // forward wave then backward wave, each a dependency-exact
            // wavefront: finish_f[m][i] = max(finish_f[m-1][i],
            //                                 finish_f[m][i-1]) + t_i
            let fwd_end = wavefront(stage_fwd, n_micro);
            let bwd_times: Vec<f64> =
                stage_fwd.iter().rev().map(|t| t * bwd_ratio).collect();
            // backward starts when ALL forwards done (fill-drain)
            fwd_end + wavefront(&bwd_times, n_micro)
        }
        Schedule::OneFOneB => {
            // steady state: every stage alternates F and B; makespan for
            // uniform-ish stages = warmup (S-1 fwd) + n_micro * (f+b) on
            // the bottleneck stage + drain. We simulate with a per-stage
            // ready-time model.
            let f_bottleneck = stage_fwd.iter().cloned().fold(0.0, f64::max);
            let warmup: f64 = stage_fwd[..s - 1].iter().sum();
            let drain: f64 =
                stage_fwd[..s - 1].iter().map(|t| t * bwd_ratio).sum();
            warmup
                + n_micro as f64 * f_bottleneck * (1.0 + bwd_ratio)
                + drain
        }
    }
}

/// Finish time of the last microbatch through a chain of stages where
/// stage i takes `times[i]` per microbatch (classic pipeline wavefront).
fn wavefront(times: &[f64], n_micro: usize) -> f64 {
    let s = times.len();
    let mut finish = vec![0.0f64; s];
    for _m in 0..n_micro {
        for i in 0..s {
            let dep = if i == 0 { finish[0] - times[0] } else { finish[i - 1] };
            // max(previous microbatch on this stage, previous stage of
            // this microbatch)
            let start = finish[i].max(dep.max(0.0));
            finish[i] = start + times[i];
        }
    }
    finish[s - 1]
}

/// Pipeline bubble fraction: (makespan - ideal) / makespan.
pub fn bubble_fraction(
    stage_fwd: &[f64],
    n_micro: usize,
    bwd_ratio: f64,
    schedule: Schedule,
) -> f64 {
    let makespan = pipeline_makespan(stage_fwd, n_micro, bwd_ratio, schedule);
    let work: f64 =
        stage_fwd.iter().map(|t| t * (1.0 + bwd_ratio)).sum::<f64>()
            * n_micro as f64
            / stage_fwd.len() as f64;
    (makespan - work) / makespan
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_stage_is_sequential() {
        let t = pipeline_makespan(&[2.0], 5, 1.0, Schedule::GPipe);
        assert!((t - (5.0 * 2.0 + 5.0 * 2.0)).abs() < 1e-9);
    }

    #[test]
    fn uniform_stages_match_gpipe_closed_form() {
        // fwd wave over S uniform stages with M microbatches:
        // (M + S - 1) * t ; same for bwd with t*ratio
        let (s, m, t, r) = (4usize, 8usize, 0.5f64, 2.0f64);
        let got = pipeline_makespan(&vec![t; s], m, r, Schedule::GPipe);
        let want = (m + s - 1) as f64 * t + (m + s - 1) as f64 * t * r;
        assert!((got - want).abs() < 1e-9, "got {got} want {want}");
    }

    #[test]
    fn bottleneck_stage_dominates() {
        let uniform = pipeline_makespan(&[1.0, 1.0, 1.0], 16, 1.0,
                                        Schedule::GPipe);
        let skewed = pipeline_makespan(&[1.0, 3.0, 1.0], 16, 1.0,
                                       Schedule::GPipe);
        assert!(skewed > 2.5 * uniform / 1.5);
        // dominated by (M + S - 1) * t_max per wave, roughly
        assert!(skewed >= 16.0 * 3.0 * 2.0);
    }

    #[test]
    fn more_microbatches_shrink_bubble() {
        let b2 = bubble_fraction(&vec![1.0; 4], 2, 1.0, Schedule::GPipe);
        let b32 = bubble_fraction(&vec![1.0; 4], 32, 1.0, Schedule::GPipe);
        assert!(b32 < b2, "b2 {b2} b32 {b32}");
        assert!(b32 < 0.15);
    }

    #[test]
    fn one_f_one_b_close_to_gpipe_for_uniform_stages() {
        let g = pipeline_makespan(&vec![1.0; 4], 16, 1.0, Schedule::GPipe);
        let o = pipeline_makespan(&vec![1.0; 4], 16, 1.0,
                                  Schedule::OneFOneB);
        let rel = (g - o).abs() / g;
        assert!(rel < 0.2, "gpipe {g} 1f1b {o}");
    }

    #[test]
    fn makespan_monotone_in_microbatches() {
        let mut prev = 0.0;
        for m in [1usize, 2, 4, 8] {
            let t = pipeline_makespan(&[0.5, 0.7], m, 1.5, Schedule::GPipe);
            assert!(t > prev);
            prev = t;
        }
    }
}
