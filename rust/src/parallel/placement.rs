//! Expert-to-device placement optimization.
//!
//! Block placement (expert j on device j/(m/E)) is what the simulator —
//! and most training stacks — use by default. When loads are persistently
//! skewed (the baselines' regime), co-locating hot experts multiplies the
//! straggler penalty. This module computes load-aware placements:
//!
//!   * [`greedy_placement`] — LPT bin packing: sort experts by observed
//!     load, assign each to the currently lightest device (classic 4/3-
//!     approximation for makespan).
//!   * [`Placement::imbalance`] — max device load / mean device load, the
//!     quantity the straggler term of the cost model scales with.
//!
//! The ablation bench (`bench_ablations`) quantifies how much placement
//! recovers for the aux baseline vs how little BIP leaves on the table
//! (when loads are already balanced, placement cannot matter — one more
//! angle on the paper's claim).

use super::topology::Mesh;

/// An explicit expert -> device assignment (unlike `Mesh`'s block rule).
#[derive(Clone, Debug)]
pub struct Placement {
    pub n_devices: usize,
    pub device_of: Vec<u32>,
}

impl Placement {
    pub fn block(mesh: &Mesh) -> Placement {
        Placement {
            n_devices: mesh.n_devices,
            device_of: (0..mesh.n_experts)
                .map(|j| mesh.device_of(j) as u32)
                .collect(),
        }
    }

    pub fn device_loads(&self, expert_loads: &[f32]) -> Vec<f64> {
        let mut out = vec![0.0f64; self.n_devices];
        for (j, &l) in expert_loads.iter().enumerate() {
            out[self.device_of[j] as usize] += l as f64;
        }
        out
    }

    /// max device load / mean device load (>= 1; 1 = perfectly spread).
    pub fn imbalance(&self, expert_loads: &[f32]) -> f64 {
        let mut scratch = Vec::new();
        self.imbalance_into(expert_loads, &mut scratch)
    }

    /// [`Placement::imbalance`] against caller-owned device-load
    /// scratch — the serving hot path's allocation-free seam (the
    /// router lends its arena's `dev_loads`).
    pub fn imbalance_into(
        &self,
        expert_loads: &[f32],
        scratch: &mut Vec<f64>,
    ) -> f64 {
        scratch.clear();
        scratch.resize(self.n_devices, 0.0);
        for (j, &l) in expert_loads.iter().enumerate() {
            scratch[self.device_of[j] as usize] += l as f64;
        }
        let total: f64 = scratch.iter().sum();
        if total <= 0.0 {
            return 1.0;
        }
        let mean = total / self.n_devices as f64;
        scratch.iter().cloned().fold(0.0f64, f64::max) / mean
    }

    /// Experts per device (for capacity checks).
    pub fn counts(&self) -> Vec<usize> {
        let mut c = vec![0usize; self.n_devices];
        for &d in &self.device_of {
            c[d as usize] += 1;
        }
        c
    }
}

/// LPT (longest-processing-time) placement from observed per-expert loads,
/// with an optional per-device expert-count cap (memory constraint).
pub fn greedy_placement(
    expert_loads: &[f32],
    n_devices: usize,
    max_experts_per_device: Option<usize>,
) -> Placement {
    let m = expert_loads.len();
    let cap = max_experts_per_device.unwrap_or(m);
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by(|&a, &b| {
        expert_loads[b].partial_cmp(&expert_loads[a]).unwrap()
    });
    let mut device_of = vec![0u32; m];
    let mut dev_load = vec![0.0f64; n_devices];
    let mut dev_count = vec![0usize; n_devices];
    for j in order {
        // lightest device with remaining capacity
        let d = (0..n_devices)
            .filter(|&d| dev_count[d] < cap)
            .min_by(|&a, &b| dev_load[a].partial_cmp(&dev_load[b]).unwrap())
            .expect("capacity must admit all experts");
        device_of[j] = d as u32;
        dev_load[d] += expert_loads[j] as f64;
        dev_count[d] += 1;
    }
    Placement { n_devices, device_of }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn block_placement_matches_mesh() {
        let mesh = Mesh::new(4, 16);
        let p = Placement::block(&mesh);
        assert_eq!(p.device_of[0], 0);
        assert_eq!(p.device_of[15], 3);
        assert_eq!(p.counts(), vec![4, 4, 4, 4]);
    }

    #[test]
    fn lpt_beats_block_on_skewed_loads() {
        // hot experts 0..4 land on device 0 under block placement
        let mut loads = vec![10.0f32; 16];
        for j in 0..4 {
            loads[j] = 100.0;
        }
        let mesh = Mesh::new(4, 16);
        let block = Placement::block(&mesh);
        let lpt = greedy_placement(&loads, 4, Some(4));
        assert!(lpt.imbalance(&loads) < block.imbalance(&loads));
        // LPT spreads the four hot experts across the four devices
        let hot_devices: std::collections::BTreeSet<u32> =
            (0..4).map(|j| lpt.device_of[j]).collect();
        assert_eq!(hot_devices.len(), 4);
    }

    #[test]
    fn lpt_respects_capacity() {
        let mut rng = Pcg64::new(1);
        let loads: Vec<f32> =
            (0..32).map(|_| rng.next_f32() * 50.0).collect();
        let p = greedy_placement(&loads, 8, Some(4));
        assert!(p.counts().iter().all(|&c| c <= 4));
        assert_eq!(p.counts().iter().sum::<usize>(), 32);
    }

    #[test]
    fn lpt_is_near_optimal_on_uniform_loads() {
        let loads = vec![7.0f32; 64];
        let p = greedy_placement(&loads, 8, None);
        assert!((p.imbalance(&loads) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn balanced_loads_leave_nothing_for_placement() {
        // the BIP regime: when expert loads are flat, ANY placement with
        // equal counts is optimal — placement can't add what balancing
        // already achieved
        let mut rng = Pcg64::new(2);
        let loads: Vec<f32> =
            (0..16).map(|_| 100.0 + rng.next_f32()).collect();
        let mesh = Mesh::new(4, 16);
        let block = Placement::block(&mesh).imbalance(&loads);
        let lpt = greedy_placement(&loads, 4, Some(4)).imbalance(&loads);
        assert!((block - lpt).abs() < 0.01, "block {block} lpt {lpt}");
    }

    #[test]
    fn empty_loads_are_safe() {
        let p = greedy_placement(&[0.0; 8], 2, None);
        assert_eq!(p.imbalance(&[0.0; 8]), 1.0);
    }
}
