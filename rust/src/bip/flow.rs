//! Exact solver for the routing BIP via min-cost max-flow.
//!
//! The paper's (BIP) is a transportation problem — its LP relaxation
//! (P-LP) has an integral optimal vertex — so min-cost max-flow on
//!
//!   source --(cap k, cost 0)--> token_i --(cap 1, cost -s_ij)--> expert_j
//!   expert_j --(cap n*k/m, cost 0)--> sink
//!
//! yields the true integer optimum. This is the referee the dual-ascent
//! heuristic (Algorithm 1) is validated against in tests and in the
//! solver bench ("optimality gap" column).
//!
//! Implementation: successive shortest augmenting paths with Johnson
//! potentials (Dijkstra after an initial Bellman-Ford pass), with
//! augmentation by the path's bottleneck capacity.

use super::{Instance, Routing};

#[derive(Clone, Debug)]
struct Edge {
    to: u32,
    cap: i64,
    cost: f64,
    flow: i64,
}

pub struct MinCostFlow {
    graph: Vec<Vec<u32>>, // node -> edge ids
    edges: Vec<Edge>,
}

impl MinCostFlow {
    pub fn new(nodes: usize) -> Self {
        MinCostFlow { graph: vec![Vec::new(); nodes], edges: Vec::new() }
    }

    pub fn add_edge(&mut self, from: usize, to: usize, cap: i64, cost: f64) {
        let id = self.edges.len() as u32;
        self.edges.push(Edge { to: to as u32, cap, cost, flow: 0 });
        self.edges.push(Edge { to: from as u32, cap: 0, cost: -cost, flow: 0 });
        self.graph[from].push(id);
        self.graph[to].push(id + 1);
    }

    fn residual(&self, e: u32) -> i64 {
        let edge = &self.edges[e as usize];
        edge.cap - edge.flow
    }

    /// Max-flow min-cost from s to t. Returns (flow, cost).
    pub fn solve(&mut self, s: usize, t: usize) -> (i64, f64) {
        let n = self.graph.len();
        // Johnson potentials via Bellman-Ford (graph has negative costs).
        let mut pot = vec![f64::INFINITY; n];
        pot[s] = 0.0;
        for _ in 0..n {
            let mut changed = false;
            for u in 0..n {
                if pot[u].is_infinite() {
                    continue;
                }
                for &eid in &self.graph[u] {
                    let e = &self.edges[eid as usize];
                    if e.cap - e.flow > 0 && pot[u] + e.cost < pot[e.to as usize] - 1e-12 {
                        pot[e.to as usize] = pot[u] + e.cost;
                        changed = true;
                    }
                }
            }
            if !changed {
                break;
            }
        }
        for p in pot.iter_mut() {
            if p.is_infinite() {
                *p = 0.0;
            }
        }

        let mut total_flow = 0i64;
        let mut total_cost = 0.0;
        loop {
            // Dijkstra with reduced costs.
            let mut dist = vec![f64::INFINITY; n];
            let mut prev_edge = vec![u32::MAX; n];
            dist[s] = 0.0;
            let mut heap = std::collections::BinaryHeap::new();
            heap.push(HeapItem { dist: 0.0, node: s as u32 });
            while let Some(HeapItem { dist: d, node }) = heap.pop() {
                let u = node as usize;
                if d > dist[u] + 1e-12 {
                    continue;
                }
                for &eid in &self.graph[u] {
                    if self.residual(eid) <= 0 {
                        continue;
                    }
                    let e = &self.edges[eid as usize];
                    let v = e.to as usize;
                    let nd = d + e.cost + pot[u] - pot[v];
                    if nd < dist[v] - 1e-12 {
                        dist[v] = nd;
                        prev_edge[v] = eid;
                        heap.push(HeapItem { dist: nd, node: v as u32 });
                    }
                }
            }
            if prev_edge[t] == u32::MAX {
                break; // no augmenting path
            }
            // bottleneck
            let mut bottleneck = i64::MAX;
            let mut v = t;
            while v != s {
                let eid = prev_edge[v];
                bottleneck = bottleneck.min(self.residual(eid));
                v = self.edges[(eid ^ 1) as usize].to as usize;
            }
            // apply
            let mut v = t;
            while v != s {
                let eid = prev_edge[v];
                self.edges[eid as usize].flow += bottleneck;
                self.edges[(eid ^ 1) as usize].flow -= bottleneck;
                total_cost +=
                    bottleneck as f64 * self.edges[eid as usize].cost;
                v = self.edges[(eid ^ 1) as usize].to as usize;
            }
            total_flow += bottleneck;
            for v in 0..n {
                if dist[v].is_finite() {
                    pot[v] += dist[v];
                }
            }
        }
        (total_flow, total_cost)
    }
}

struct HeapItem {
    dist: f64,
    node: u32,
}

impl PartialEq for HeapItem {
    fn eq(&self, other: &Self) -> bool {
        self.dist == other.dist
    }
}
impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap on dist
        other
            .dist
            .partial_cmp(&self.dist)
            .unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// Exact optimum of the routing BIP. Feasible by construction
/// (loads <= cap, <= k experts per token); maximizes total selected score.
pub fn solve_exact(inst: &Instance) -> (Routing, f64) {
    let n = inst.n;
    let m = inst.m;
    let source = n + m;
    let sink = n + m + 1;
    let mut mcf = MinCostFlow::new(n + m + 2);
    for i in 0..n {
        mcf.add_edge(source, i, inst.k as i64, 0.0);
        for j in 0..m {
            // negative cost == maximize score; shift to keep all path costs
            // negative so max-flow prefers full routing (score > 0 anyway).
            mcf.add_edge(i, n + j, 1, -(inst.score(i, j) as f64));
        }
    }
    for j in 0..m {
        mcf.add_edge(n + j, sink, inst.cap as i64, 0.0);
    }
    let (_flow, cost) = mcf.solve(source, sink);

    let mut assignment = vec![Vec::new(); n];
    for i in 0..n {
        for &eid in &mcf.graph[i] {
            let e = &mcf.edges[eid as usize];
            if e.flow > 0 && (e.to as usize) >= n && (e.to as usize) < n + m {
                assignment[i].push((e.to as usize - n) as u32);
            }
        }
    }
    (Routing { assignment }, -cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bip::greedy_topk;
    use crate::util::rng::Pcg64;

    #[test]
    fn tiny_hand_instance() {
        // 2 tokens, 2 experts, k=1, cap=1: both prefer expert 0, optimum
        // must route one of them to expert 1.
        let inst = Instance {
            n: 2,
            m: 2,
            k: 1,
            cap: 1,
            scores: vec![0.9, 0.1, 0.8, 0.2],
        };
        let (routing, obj) = solve_exact(&inst);
        assert!(routing.is_col_feasible(2, 1));
        assert!((obj - 1.1).abs() < 1e-6); // 0.9 + 0.2
    }

    #[test]
    fn exact_is_feasible_and_dominates_any_feasible_heuristic() {
        let mut rng = Pcg64::new(7);
        for trial in 0..5 {
            let inst = Instance::synthetic(
                48, 8, 2, 2.0, 1.0 + trial as f64, &mut rng);
            let (routing, obj) = solve_exact(&inst);
            assert!(routing.is_row_feasible(inst.k));
            assert!(routing.is_col_feasible(inst.m, inst.cap));
            assert!((routing.objective(&inst) - obj).abs() < 1e-6);
            // feasible "balanced greedy": round-robin by token order
            let rr = Routing {
                assignment: (0..inst.n)
                    .map(|i| {
                        (0..inst.k)
                            .map(|kk| {
                                (((i * inst.k + kk) % inst.m) as u32)
                            })
                            .collect()
                    })
                    .collect(),
            };
            assert!(rr.is_col_feasible(inst.m, inst.cap));
            assert!(obj >= rr.objective(&inst) - 1e-9);
        }
    }

    #[test]
    fn exact_bounded_by_greedy() {
        // greedy ignores capacity => upper bound on the constrained optimum
        let mut rng = Pcg64::new(9);
        let inst = Instance::synthetic(64, 16, 4, 2.0, 3.0, &mut rng);
        let (_, obj) = solve_exact(&inst);
        let greedy_obj = greedy_topk(&inst).objective(&inst);
        assert!(obj <= greedy_obj + 1e-9);
        assert!(obj >= 0.5 * greedy_obj);
    }

    #[test]
    fn routes_full_volume_when_capacity_allows() {
        let mut rng = Pcg64::new(11);
        let inst = Instance::synthetic(32, 8, 2, 1.5, 2.0, &mut rng);
        let (routing, _) = solve_exact(&inst);
        // m*cap == n*k exactly, and every score > 0, so all slots route
        let total: u32 = routing.loads(inst.m).iter().sum();
        assert_eq!(total, (inst.n * inst.k) as u32);
    }
}
